"""The autotuner: measure every (backend, tile_b, n_slots) candidate per
call signature and record the winner in a ``DispatchCache``.

Candidate space (the three knobs the ROADMAP names):

  * backend — jnp segment-scan vs the pallas kernel (the BENCH_embedding
    batch-128 inversion is exactly a wrong static backend choice);
  * tile_b  — bags per grid step (pallas only);
  * n_slots — row-DMA pipeline depth (pallas only; kernels read it off the
    VMEM scratch shape, see ``kernels/embedding_bag._scratch``).

``smoke=True`` keeps the SAME signature suite (the cache's entry keys are
its schema — CI gates key-path parity against the committed file) but
shrinks the candidate set and repeats so the sweep runs in CI seconds.

Timings are best-of-``repeats`` wall-clock of a jitted call, the
``benchmarks/bench_embedding.py`` protocol. Off-TPU the pallas candidates
run in interpret mode — a semantics-true lower bound, which is precisely
what makes the measured (not assumed) choice land on jnp where interpret
mode loses. Every entry always carries BOTH ``jnp_us`` and ``pallas_us``
(plus ``best_us``) so smoke and full runs emit identical key sets.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.tune.dispatch import (CallSignature, DispatchCache, signature)

#: (vocab, dim, batch, bag_len, n_fields) — the rectangular lookup shapes.
#: benchmarks/bench_embedding.py imports this as its CONFIGS, so the bench
#: baselines and the tuned suite cannot drift apart.
PLAIN_CONFIGS = [
    (10_000, 64, 32, 8, 1),
    (10_000, 64, 128, 8, 1),
    (50_000, 128, 64, 16, 1),
    (20_000, 32, 32, 16, 4),      # multi-field fused (B, F, L)
]

#: full sweep: jnp + pallas x {tile_b} x {n_slots}
TILE_B_CANDIDATES = (4, 8, 16)
N_SLOT_CANDIDATES = (2, 4)
#: smoke sweep: one tile, both pipeline depths — enough to exercise every
#: moving part without CI minutes
SMOKE_TILE_B = (8,)
SMOKE_N_SLOTS = (2, 4)

DEFAULT_REPEATS = 3
SMOKE_REPEATS = 2


def candidates(smoke: bool = False) -> list[tuple[str, int, int]]:
    """(backend, tile_b, n_slots) triples to measure. The jnp candidate
    carries the default tile/slots (it uses neither) so its cache entry is
    well-formed."""
    tiles = SMOKE_TILE_B if smoke else TILE_B_CANDIDATES
    slots = SMOKE_N_SLOTS if smoke else N_SLOT_CANDIDATES
    return [("jnp", 8, 2)] + [("pallas", tb, ns)
                              for tb in tiles for ns in slots]


@dataclasses.dataclass
class TuneCase:
    """One signature plus its measurement factory: ``make(backend, tile_b,
    n_slots)`` returns a zero-arg callable running one jitted lookup."""

    sig: CallSignature
    make: Callable[[str, int, int], Callable[[], object]]


def _time_best_us(fn: Callable[[], object], repeats: int) -> float:
    import jax
    jax.block_until_ready(fn())          # compile outside the timed loop
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


# ---------------------------------------------------------------------------
# case builders — deterministic inputs (seeded), one per lookup path
# ---------------------------------------------------------------------------

def plain_case(v: int, d: int, b: int, l: int, f: int,
               seed: int = 0) -> TuneCase:
    import jax
    import jax.numpy as jnp
    from repro.core.embedding import banked_embedding_bag, pack_table
    from repro.core.partitioning import non_uniform_partition

    rng = np.random.default_rng(seed)
    table = rng.standard_normal((v, d)).astype(np.float32)
    bt = pack_table(table, non_uniform_partition(rng.random(v) + 0.1, 8))
    per_field = v // f
    offs = jnp.asarray(np.arange(f) * per_field, jnp.int32) if f > 1 else None
    shape = (b, f, l) if f > 1 else (b, l)
    idx = jnp.asarray(rng.integers(-1, per_field, shape), jnp.int32)

    def make(backend, tile_b, n_slots):
        fn = jax.jit(lambda t, i: banked_embedding_bag(
            t, i, None, backend=backend, field_offsets=offs,
            tile_b=tile_b, n_slots=n_slots))
        return lambda: fn(bt, idx)

    return TuneCase(
        sig=signature("plain", vocab=v, dim=d, batch=b * f, bag_len=l,
                      n_fields=f),
        make=make)


def fused_case(v: int = 2_000, nc: int = 128, d: int = 64, b: int = 32,
               lc: int = 4, lr: int = 8, seed: int = 1) -> TuneCase:
    import jax
    import jax.numpy as jnp
    from repro.core.embedding import banked_cache_residual_bag, pack_table
    from repro.core.partitioning import (non_uniform_partition,
                                         uniform_partition)

    rng = np.random.default_rng(seed)
    table = rng.standard_normal((v, d)).astype(np.float32)
    bt = pack_table(table, non_uniform_partition(rng.random(v) + 0.1, 8))
    cbt = pack_table(rng.standard_normal((nc, d)).astype(np.float32),
                     uniform_partition(nc, 4))
    ci = jnp.asarray(rng.integers(-1, nc, (b, lc)), jnp.int32)
    ri = jnp.asarray(rng.integers(-1, v, (b, lr)), jnp.int32)

    def make(backend, tile_b, n_slots):
        fn = jax.jit(lambda t, c: banked_cache_residual_bag(
            t, c, ci, ri, None, backend=backend, tile_b=tile_b,
            n_slots=n_slots))
        return lambda: fn(bt, cbt)

    return TuneCase(
        sig=signature("fused", vocab=v, dim=d, batch=b,
                      bag_len=f"{lc}+{lr}"),
        make=make)


def csr_case(v: int = 10_000, d: int = 64, num_bags: int = 64,
             avg_len: int = 8, seed: int = 2) -> TuneCase:
    import jax
    import jax.numpy as jnp
    from repro.core.embedding import csr_embedding_bag, pack_table
    from repro.core.partitioning import non_uniform_partition

    rng = np.random.default_rng(seed)
    table = rng.standard_normal((v, d)).astype(np.float32)
    bt = pack_table(table, non_uniform_partition(rng.random(v) + 0.1, 8))
    lens = rng.integers(1, 2 * avg_len, num_bags)
    total = int(lens.sum())
    indices = jnp.asarray(rng.integers(0, v, total), jnp.int32)
    offsets = jnp.asarray(np.concatenate([[0], np.cumsum(lens)[:-1]]),
                          jnp.int32)

    def make(backend, tile_b, n_slots):
        fn = jax.jit(lambda t, i: csr_embedding_bag(
            t, i, offsets, num_bags, None, backend=backend, tile_b=tile_b,
            n_slots=n_slots))
        return lambda: fn(bt, indices)

    return TuneCase(
        sig=signature("csr", vocab=v, dim=d, batch=num_bags,
                      bag_len="ragged"),
        make=make)


def tiered_case(v: int = 2_000, d: int = 64, b: int = 32, l: int = 8,
                hot_dtype: str = "bf16", seed: int = 3) -> TuneCase:
    import jax
    import jax.numpy as jnp
    from repro.core.embedding import pack_table, tiered_embedding_bag
    from repro.core.partitioning import non_uniform_partition
    from repro.quant import QuantSpec, assign_tiers, build_tiered_table

    rng = np.random.default_rng(seed)
    table = (rng.standard_normal((v, d)) * 0.01).astype(np.float32)
    freq = rng.random(v) + 0.1
    bt = pack_table(table, non_uniform_partition(freq, 8))
    # budget below the int8 width forces a mixed bf16/int8/int4 tier map
    ta = assign_tiers(freq, QuantSpec(byte_budget=0.75 * d,
                                      min_hot_rows=16), d)
    tt = build_tiered_table(bt, ta.tier_of_row)
    idx = jnp.asarray(rng.integers(-1, v, (b, l)), jnp.int32)

    def make(backend, tile_b, n_slots):
        fn = jax.jit(lambda fp, i: tiered_embedding_bag(
            fp, tt, i, None, backend=backend, tile_b=tile_b,
            n_slots=n_slots))
        return lambda: fn(bt.packed, idx)

    return TuneCase(
        sig=signature("tiered", vocab=v, dim=d, batch=b, bag_len=l,
                      tier_mix=hot_dtype),
        make=make)


def replicated_case(v: int = 2_000, d: int = 64, b: int = 32, l: int = 8,
                    k_max: int = 4, n_hot: int = 16,
                    seed: int = 4) -> TuneCase:
    import jax
    import jax.numpy as jnp
    from repro.core.embedding import pack_replicated, replicated_embedding_bag
    from repro.core.partitioning import replicated_partition

    rng = np.random.default_rng(seed)
    banks = 8
    table = (rng.standard_normal((v, d)) * 0.1).astype(np.float32)
    freq = rng.random(v) + 0.1
    freq[:n_hot] += 50.0
    copies = np.ones(v, np.int32)
    copies[:n_hot] = k_max
    cap = int(np.ceil((v + n_hot * (k_max - 1)) / banks) * 1.3)
    rplan = replicated_partition(freq, banks, copies=copies,
                                 capacity_rows=cap, k_max=k_max)
    rt = pack_replicated(table, rplan, rows_per_bank=cap)
    idx = np.full((b, l), -1, np.int32)
    for i in range(b):
        k = rng.integers(1, l + 1)
        hot = rng.random(k) < 0.5
        idx[i, :k] = np.where(hot, rng.integers(0, n_hot, k),
                              rng.integers(0, v, k))
    idx = jnp.asarray(idx)

    def make(backend, tile_b, n_slots):
        fn = jax.jit(lambda t, i: replicated_embedding_bag(
            t, i, None, backend=backend, tile_b=tile_b, n_slots=n_slots))
        return lambda: fn(rt, idx)

    return TuneCase(
        sig=signature("replicated", vocab=v, dim=d, batch=b, bag_len=l,
                      k_max=k_max),
        make=make)


def default_signature_suite() -> list[TuneCase]:
    """The committed-cache suite: every BENCH_embedding rectangular shape on
    the plain path, plus one representative case per remaining entry point.
    Smoke mode runs THIS SAME list (key-path parity is the CI gate)."""
    cases = [plain_case(*cfg) for cfg in PLAIN_CONFIGS]
    cases += [fused_case(), csr_case(), tiered_case(), replicated_case()]
    return cases


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------

def tune(cases: list[TuneCase] | None = None, *, smoke: bool = False,
         repeats: int | None = None, arch: str | None = None,
         log: Callable[[str], None] = print) -> DispatchCache:
    """Sweep every candidate for every case; return the populated cache.

    The winner is strict best measured latency. Per-backend minima are
    recorded alongside (``jnp_us``/``pallas_us``) so the committed file
    carries the evidence for each choice — and so ``best_us`` can be checked
    against best-of-both by the bench's dispatched scenario.
    """
    import jax
    if cases is None:
        cases = default_signature_suite()
    if repeats is None:
        repeats = SMOKE_REPEATS if smoke else DEFAULT_REPEATS
    cand = candidates(smoke)
    meta = {
        "arch": arch or (f"{jax.default_backend()}-"
                         + ("compiled" if jax.default_backend() == "tpu"
                            else "interpret")),
        "smoke": smoke,
        "repeats": repeats,
        "n_candidates": len(cand),
    }
    cache = DispatchCache(meta=meta)
    for case in cases:
        per_backend: dict[str, float] = {}
        best = None
        for backend, tile_b, n_slots in cand:
            us = _time_best_us(case.make(backend, tile_b, n_slots), repeats)
            per_backend[backend] = min(per_backend.get(backend, us), us)
            if best is None or us < best[3]:
                best = (backend, tile_b, n_slots, us)
        backend, tile_b, n_slots, us = best
        cache.record(case.sig, backend=backend, tile_b=tile_b,
                     n_slots=n_slots,
                     timings={"best_us": round(us, 3),
                              "jnp_us": round(per_backend["jnp"], 3),
                              "pallas_us": round(per_backend["pallas"], 3)})
        log(f"tuned {case.sig.key()}: {backend} tile_b={tile_b} "
            f"n_slots={n_slots} ({us:.1f}us; jnp {per_backend['jnp']:.1f} "
            f"/ pallas {per_backend['pallas']:.1f})")
    return cache
