"""Kernel autotuner + shape-specialized dispatch (ROADMAP: measured, not
assumed).

``dispatch`` — the persisted per-call-signature decision cache
(``TUNE_dispatch.json``) that ``backend='tuned'`` lookups in
``core/embedding.py`` resolve through at trace time.

``autotune`` — the sweep that produces it: measure every (backend, tile_b,
n_slots) candidate per signature and record the winner.
"""
from repro.tune.dispatch import (CallSignature, Decision, DispatchCache,
                                 decide, default_cache_path, get_cache,
                                 set_cache, signature)

__all__ = [
    "CallSignature",
    "Decision",
    "DispatchCache",
    "decide",
    "default_cache_path",
    "get_cache",
    "set_cache",
    "signature",
]
