"""GNN neighbor sampling (GraphSAGE-style fanout) + CSR utilities.

Host-side (numpy) — sampling is part of the data pipeline, producing padded,
static-shape subgraph batches the jitted train step consumes. This is the real
sampler required by the ``minibatch_lg`` shape (232,965 nodes / 114.6M edges,
batch 1024, fanout 15-10).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    """Compressed sparse row adjacency, host-resident."""

    indptr: np.ndarray   # (n_nodes+1,) int64
    indices: np.ndarray  # (n_edges,) int32  — neighbor ids
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return int(self.indices.shape[0])

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr)


def build_csr(src: np.ndarray, dst: np.ndarray, n_nodes: int) -> CSRGraph:
    """COO edge list -> CSR (by dst, so indices are in-neighbors of each node)."""
    order = np.argsort(dst, kind="stable")
    dst_sorted = dst[order]
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.add.at(indptr, dst_sorted + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSRGraph(indptr=indptr, indices=src[order].astype(np.int32), n_nodes=n_nodes)


@dataclasses.dataclass
class SampledBlock:
    """One bipartite message-passing block (padded static shapes)."""

    src_ids: np.ndarray    # (n_src,) global node ids feeding this layer
    dst_ids: np.ndarray    # (n_dst,) global node ids updated by this layer
    edge_src: np.ndarray   # (n_edges,) local index into src_ids
    edge_dst: np.ndarray   # (n_edges,) local index into dst_ids
    edge_mask: np.ndarray  # (n_edges,) bool — False for padding


class NeighborSampler:
    """Uniform fanout sampler: seeds -> L blocks (innermost first).

    Shapes are padded to the worst case ``n_seeds * prod(fanouts[:k])`` so the
    jitted step sees static shapes across batches.
    """

    def __init__(self, graph: CSRGraph, fanouts: tuple[int, ...], seed: int = 0):
        self.graph = graph
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> list[SampledBlock]:
        blocks: list[SampledBlock] = []
        dst = np.asarray(seeds, dtype=np.int64)
        for fanout in self.fanouts:
            n_dst = dst.shape[0]
            cap = n_dst * fanout
            e_src = np.zeros(cap, dtype=np.int64)
            e_dst = np.zeros(cap, dtype=np.int64)
            mask = np.zeros(cap, dtype=bool)
            k = 0
            g = self.graph
            for j, node in enumerate(dst):
                lo, hi = g.indptr[node], g.indptr[node + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = min(fanout, deg)
                if deg <= fanout:
                    picks = g.indices[lo:hi]
                else:
                    picks = g.indices[lo + self.rng.choice(deg, size=take, replace=False)]
                e_src[k:k + take] = picks
                e_dst[k:k + take] = j
                mask[k:k + take] = True
                k += take
            # src set = dst PREFIX ++ new neighbors — the dst-prefix ordering
            # lets the model take h_dst = h[:n_dst] (models/gat.forward_blocks)
            extra = np.setdiff1d(e_src[mask], dst)
            src_ids = np.concatenate([dst, extra])
            # remap edge endpoints to local indices
            loc = {n: i for i, n in enumerate(src_ids)}
            e_src_loc = np.zeros(cap, dtype=np.int32)
            e_src_loc[mask] = np.array([loc[n] for n in e_src[mask]], dtype=np.int32)
            blocks.append(SampledBlock(
                src_ids=src_ids.astype(np.int64),
                dst_ids=dst.astype(np.int64),
                edge_src=e_src_loc,
                edge_dst=e_dst.astype(np.int32),
                edge_mask=mask,
            ))
            dst = src_ids  # next (outer) layer must cover all current srcs
        return blocks[::-1]  # outermost first for forward pass
