"""EmbeddingBag + segment reductions from JAX first principles.

The multi-hot embedding lookup (``embedding_bag``) is THE hot path of the paper:
DLRM's sparse features are ragged bags of indices per sample; the bag is gathered
from a (vocab, dim) table and reduced (sum/mean).  UPMEM DPUs do the gather+reduce
near memory; our TPU analogue is kernels/embedding_bag.py — this module is the
portable pure-jnp implementation used as the oracle and the CPU path.

Ragged bags are carried in CSR-ish (indices, offsets) form exactly like
``torch.nn.EmbeddingBag``: ``indices`` is the flat int32 stream, ``offsets[i]`` is
the start of bag ``i`` (so ``offsets`` has length ``batch`` and bags are
``indices[offsets[i]:offsets[i+1]]``).  For jit-ability all shapes are static; a
``valid`` length or padded ``-1`` entries mark ragged ends.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Array = jax.Array

# segment reductions — thin wrappers so callers never touch jax.ops directly and
# we keep one place to swap implementations (e.g. sorted segment ids fast path).
segment_sum = jax.ops.segment_sum
segment_max = jax.ops.segment_max


def segment_mean(data: Array, segment_ids: Array, num_segments: int) -> Array:
    tot = jax.ops.segment_sum(data, segment_ids, num_segments)
    cnt = jax.ops.segment_sum(jnp.ones_like(segment_ids, dtype=data.dtype),
                              segment_ids, num_segments)
    return tot / jnp.maximum(cnt, 1.0)[..., None] if data.ndim > 1 else tot / jnp.maximum(cnt, 1.0)


def segment_softmax(scores: Array, segment_ids: Array, num_segments: int) -> Array:
    """Softmax over variable-length segments (GAT edge-softmax primitive)."""
    smax = jax.ops.segment_max(scores, segment_ids, num_segments)
    # -inf for empty segments -> replace to keep exp finite
    smax = jnp.where(jnp.isfinite(smax), smax, 0.0)
    ex = jnp.exp(scores - smax[segment_ids])
    denom = jax.ops.segment_sum(ex, segment_ids, num_segments)
    return ex / jnp.maximum(denom[segment_ids], 1e-20)


def offsets_to_segment_ids(offsets: Array, total: int) -> Array:
    """CSR offsets (len batch, offsets[0]==0) -> per-element bag id (len total)."""
    # scatter 1 at each bag start (except bag 0), cumsum -> segment ids
    marks = jnp.zeros((total,), jnp.int32).at[offsets[1:]].add(1)
    return jnp.cumsum(marks)


@functools.partial(jax.jit, static_argnames=("num_bags", "combiner"))
def embedding_bag(
    table: Array,
    indices: Array,
    offsets: Array,
    *,
    num_bags: int,
    combiner: Literal["sum", "mean"] = "sum",
) -> Array:
    """Ragged multi-hot lookup-and-reduce: the DLRM SparseLengthsSum op.

    ``indices`` entries < 0 are padding and contribute zero (lets callers pad
    ragged bags to a static total length).
    """
    total = indices.shape[0]
    seg = offsets_to_segment_ids(offsets, total)
    valid = indices >= 0
    safe_idx = jnp.where(valid, indices, 0)
    rows = jnp.take(table, safe_idx, axis=0)
    rows = jnp.where(valid[:, None], rows, 0)
    out = jax.ops.segment_sum(rows, seg, num_bags)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(valid.astype(table.dtype), seg, num_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def embedding_bag_fixed(table: Array, idx: Array, *, combiner: str = "sum") -> Array:
    """Dense-rectangular bag lookup: idx (batch, bag_len) -> (batch, dim).

    The common recsys fast path (fixed pooling factor / padded bags). Padding is
    ``-1``. Used by DLRM/DIN at serve time where bag lengths are padded static.
    """
    valid = idx >= 0
    rows = jnp.take(table, jnp.where(valid, idx, 0), axis=0)  # (B, L, D)
    rows = jnp.where(valid[..., None], rows, 0)
    out = rows.sum(axis=1)
    if combiner == "mean":
        out = out / jnp.maximum(valid.sum(axis=1, keepdims=True), 1).astype(out.dtype)
    return out


def embedding_bag_onehot(table: Array, idx: Array) -> Array:
    """MXU-path oracle: bag-sum as one-hot × table matmul (small vocabs only).

    Mathematically identical to ``embedding_bag_fixed(..., 'sum')``; used in
    property tests as an independent oracle and on-TPU for tiny tables where a
    dense matmul beats a gather.
    """
    V = table.shape[0]
    onehot = jax.nn.one_hot(jnp.where(idx >= 0, idx, V), V + 1, dtype=table.dtype)
    onehot = onehot[..., :V]  # padding row falls off
    counts = onehot.sum(axis=1)  # (B, V) multi-hot counts
    return counts @ table
