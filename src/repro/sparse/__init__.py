"""Sparse/ragged primitives built from JAX first principles.

JAX has no native EmbeddingBag and no CSR/CSC sparse support (BCOO only), so the
gather/segment machinery that recsys + GNN architectures need is implemented here
from ``jnp.take`` + ``jax.ops.segment_sum`` — this IS part of the system, not a
stub (see kernel_taxonomy §B.6/B.11).
"""
from repro.sparse.ops import (
    embedding_bag,
    embedding_bag_onehot,
    segment_softmax,
    segment_sum,
    segment_max,
    segment_mean,
)
from repro.sparse.sampler import NeighborSampler, build_csr

__all__ = [
    "embedding_bag",
    "embedding_bag_onehot",
    "segment_softmax",
    "segment_sum",
    "segment_max",
    "segment_mean",
    "NeighborSampler",
    "build_csr",
]
