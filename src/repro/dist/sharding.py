"""NamedSharding policies per model family.

Every function returns a pytree of ``NamedSharding`` matching the structure of
its input struct (ShapeDtypeStructs or real arrays). Policies are guarded by
divisibility — a dim that doesn't divide by the mesh axis falls back to
replication, so any (arch x mesh) cell stays compilable.

Conventions (match the with_sharding_constraints inside the models):
  * LM: vocab-sharded embed/unembed over 'model'; attention/MLP matrices
    sharded on their widest projection dim; KV projections replicated (GQA).
  * recsys: the banked table shards P('model', None) — the shard_map stage-2
    contract in core/embedding.py; everything else (small MLPs) replicates.
  * batches: leading batch dim over the dp axes; 'spread' arrays (retrieval
    candidates, GNN edge lists) over every mesh axis.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.embedding import DistCtx

P = jax.sharding.PartitionSpec


def _ns(dist: DistCtx, *spec_entries) -> jax.sharding.NamedSharding:
    return jax.sharding.NamedSharding(dist.mesh, P(*spec_entries))


def _rep(dist: DistCtx, leaf) -> jax.sharding.NamedSharding:
    return _ns(dist, *([None] * len(leaf.shape)))


def _div(dist: DistCtx, n: int, axes) -> bool:
    if isinstance(axes, str):
        axes = (axes,)
    return n % int(np.prod([dist.mesh.shape[a] for a in axes])) == 0


def _dp_entry(dist: DistCtx):
    return dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0]


def _batch_spec(dist: DistCtx, leaf):
    """Leading dim over dp when divisible; replicate otherwise."""
    if leaf.shape and _div(dist, leaf.shape[0], dist.dp_axes):
        return _ns(dist, _dp_entry(dist), *([None] * (len(leaf.shape) - 1)))
    return _rep(dist, leaf)


def _spread_spec(dist: DistCtx, leaf):
    """Leading dim over EVERY mesh axis (candidate sets, edge lists)."""
    axes = tuple(dist.mesh.axis_names)
    if leaf.shape and _div(dist, leaf.shape[0], axes):
        return _ns(dist, axes, *([None] * (len(leaf.shape) - 1)))
    return _rep(dist, leaf)


# ---------------------------------------------------------------------------
# LM
# ---------------------------------------------------------------------------

def lm_param_shardings(dist: DistCtx, params):
    """Vocab-sharded embed/unembed, head-sharded q/o, ff-sharded MLP."""
    m = dist.bank_axis

    def leaf_sh(path, leaf):
        key = jax.tree_util.keystr(path)
        nd = len(leaf.shape)
        if "embed" in key and nd == 2 and _div(dist, leaf.shape[0], m):
            return _ns(dist, m, None)
        if "unembed" in key and nd == 2 and _div(dist, leaf.shape[1], m):
            return _ns(dist, None, m)
        # stacked per-layer matrices carry a leading n_layers dim
        if nd == 3 and any(k in key for k in ("wq", "w_gate", "w_up")) \
                and _div(dist, leaf.shape[2], m):
            return _ns(dist, None, None, m)
        if nd == 3 and any(k in key for k in ("wo", "w_down")) \
                and _div(dist, leaf.shape[1], m):
            return _ns(dist, None, m, None)
        # MoE expert stacks (L, E, d, ff): expert-parallel over model
        if nd == 4 and _div(dist, leaf.shape[1], m):
            return _ns(dist, None, m, None, None)
        return _rep(dist, leaf)

    return jax.tree_util.tree_map_with_path(leaf_sh, params)


def lm_batch_shardings(dist: DistCtx, batch):
    return jax.tree.map(lambda l: _batch_spec(dist, l), batch)


def kv_cache_shardings(dist: DistCtx, cache_struct,
                       seq_axes: tuple[str, ...] = ("model",),
                       batch_gt1: bool = True):
    """KVCache (k/v (L, B, S, Hkv, Dh), length ()) — seq dim over seq_axes."""
    dp_eff = tuple(a for a in dist.dp_axes if a not in seq_axes)
    seq_entry = seq_axes if len(seq_axes) > 1 else seq_axes[0]

    def leaf_sh(leaf):
        if len(leaf.shape) != 5:
            return _rep(dist, leaf)          # length scalar
        L, B, S, Hkv, Dh = leaf.shape
        bentry = None
        if batch_gt1 and dp_eff and _div(dist, B, dp_eff):
            bentry = dp_eff if len(dp_eff) > 1 else dp_eff[0]
        sentry = seq_entry if _div(dist, S, seq_axes) else None
        return _ns(dist, None, bentry, sentry, None, None)

    return jax.tree.map(leaf_sh, cache_struct)


# ---------------------------------------------------------------------------
# recsys
# ---------------------------------------------------------------------------

def recsys_param_shardings(dist: DistCtx, params):
    """Banked table P(bank_axis, None); small dense params replicated."""
    m = dist.bank_axis

    def leaf_sh(path, leaf):
        key = jax.tree_util.keystr(path)
        if ("packed" in key or "embed" in key) and len(leaf.shape) == 2 \
                and _div(dist, leaf.shape[0], m):
            return _ns(dist, m, None)
        return _rep(dist, leaf)

    return jax.tree_util.tree_map_with_path(leaf_sh, params)


def recsys_batch_shardings(dist: DistCtx, batch,
                           spread_keys: tuple[str, ...] = ()):
    def leaf_sh(path, leaf):
        key = jax.tree_util.keystr(path)
        if any(s in key for s in spread_keys):
            return _spread_spec(dist, leaf)
        return _batch_spec(dist, leaf)

    return jax.tree_util.tree_map_with_path(leaf_sh, batch)


# ---------------------------------------------------------------------------
# GNN
# ---------------------------------------------------------------------------

def gnn_batch_shardings(dist: DistCtx, batch):
    """Edge arrays spread over every axis; node features replicated."""
    def leaf_sh(path, leaf):
        key = jax.tree_util.keystr(path)
        is_edge = "edge_" in key or (
            "block" in key and key.rstrip("']").endswith(("_src", "_dst",
                                                          "_mask")))
        if is_edge:
            return _spread_spec(dist, leaf)
        return _rep(dist, leaf)

    return jax.tree_util.tree_map_with_path(leaf_sh, batch)


# ---------------------------------------------------------------------------
# train state
# ---------------------------------------------------------------------------

def train_state_shardings(dist: DistCtx, state_struct, param_shardings):
    """TrainState shardings: params use ``param_shardings``; optimizer moments
    inherit the sharding of the same-shaped param (Adam m/v, Adagrad rows);
    anything unmatched (scalars, row accumulators) replicates."""
    from repro.train.train_step import TrainState

    by_shape: dict = {}

    def record(leaf, sh):
        by_shape.setdefault((tuple(leaf.shape), str(leaf.dtype)), sh)
        return sh

    jax.tree.map(record, state_struct.params, param_shardings)

    def match(leaf):
        return by_shape.get((tuple(leaf.shape), str(leaf.dtype)),
                            _rep(dist, leaf))

    err = state_struct.err_state
    return TrainState(
        params=param_shardings,
        opt_state=jax.tree.map(match, state_struct.opt_state),
        step=_rep(dist, state_struct.step),
        err_state=None if err is None else jax.tree.map(match, err),
    )
