"""Per-bank fault model for the serving cell: healthy / degraded-slow / dead.

UpDLRM's premise is that embedding reads fan out across many independent DPU
banks — which makes a single slow or dead bank the availability story, not
just the latency story. This module is the *model* half of fault-tolerant
serving (the *mechanism* half — bounded-degraded reads and the recovery
replan — lives in core/embedding.py's ``bank_live`` mask and
workload/runtime.py's ``on_bank_failure``):

  * ``BankFaultState``  — the per-bank health vector, advanced batch-by-batch
    by a deterministic injection schedule (seeded, replayable — every CI run
    and every test sees the identical failure sequence).
  * ``FaultEvent``      — one scheduled transition (bank b enters state s at
    batch t, with a slowdown factor for DEGRADED).

Like the rest of ``repro.dist.fault`` this is deliberately jax-free: it wraps
the host-side serve loop, and its outputs (``live_mask``, ``slow_factor``)
are plain numpy vectors the loop feeds to the jitted step as ARGUMENTS (the
same zero-recompile contract as the remap vectors).
"""
from __future__ import annotations

import dataclasses

import numpy as np

HEALTHY = 0
DEGRADED = 1          # alive but slow: reads land, latency x ``factor``
DEAD = 2              # reads destined here resolve to a degraded substitute

_STATE_NAMES = {"healthy": HEALTHY, "degraded": DEGRADED, "dead": DEAD}
_NAME_OF = {v: k for k, v in _STATE_NAMES.items()}


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """Bank ``bank`` transitions to ``state`` when batch ``batch`` starts.

    ``factor`` is the latency multiplier for DEGRADED (ignored otherwise).
    """

    batch: int
    bank: int
    state: int = DEAD
    factor: float = 1.0

    def __str__(self) -> str:
        extra = f" x{self.factor:g}" if self.state == DEGRADED else ""
        return f"bank {self.bank} -> {_NAME_OF[self.state]}{extra} " \
               f"@batch {self.batch}"


def parse_fault_spec(spec: str) -> FaultEvent:
    """CLI form ``BATCH:BANK[:STATE[:FACTOR]]`` -> FaultEvent.

    ``--inject-bank-failure 12:3`` kills bank 3 at batch 12;
    ``12:3:degraded:4.0`` slows it 4x instead; ``20:3:healthy`` revives it.
    """
    parts = spec.split(":")
    if len(parts) < 2 or len(parts) > 4:
        raise ValueError(f"fault spec {spec!r}: want BATCH:BANK"
                         f"[:STATE[:FACTOR]]")
    batch, bank = int(parts[0]), int(parts[1])
    state = DEAD
    factor = 1.0
    if len(parts) >= 3:
        if parts[2] not in _STATE_NAMES:
            raise ValueError(f"fault spec {spec!r}: state must be one of "
                             f"{sorted(_STATE_NAMES)}")
        state = _STATE_NAMES[parts[2]]
    if len(parts) == 4:
        factor = float(parts[3])
    return FaultEvent(batch=batch, bank=bank, state=state, factor=factor)


class BankFaultState:
    """Per-bank health, driven by a deterministic event schedule.

    ``advance(batch)`` fires every not-yet-fired event with
    ``event.batch <= batch`` (in schedule order) and returns them; the serve
    loop calls it once per micro-batch before building the batch's
    ``bank_live`` argument. Revival (an event back to HEALTHY) is supported —
    a revived bank re-enters the planner's capacity on the next replan.
    """

    def __init__(self, n_banks: int,
                 events: "list[FaultEvent] | tuple[FaultEvent, ...]" = ()):
        for e in events:
            if not (0 <= e.bank < n_banks):
                raise ValueError(f"event {e}: bank out of range "
                                 f"[0, {n_banks})")
        self.n_banks = n_banks
        self.state = np.zeros(n_banks, dtype=np.int32)        # all HEALTHY
        self.factor = np.ones(n_banks, dtype=np.float64)
        self.schedule = sorted(events, key=lambda e: (e.batch, e.bank))
        self.fired: list[FaultEvent] = []
        self._next = 0

    @classmethod
    def from_specs(cls, n_banks: int, specs: "list[str]") -> "BankFaultState":
        return cls(n_banks, [parse_fault_spec(s) for s in specs])

    @classmethod
    def random_schedule(cls, n_banks: int, n_batches: int, *, seed: int,
                        n_failures: int = 1, p_degraded: float = 0.0,
                        degraded_factor: float = 4.0,
                        min_batch: int = 1) -> "BankFaultState":
        """Seeded random injection schedule — deterministic given
        (n_banks, n_batches, seed, knobs): the same seed replays the same
        failure sequence on every run (the testable contract)."""
        rng = np.random.default_rng(seed)
        n_failures = min(n_failures, n_banks - 1)   # keep >= 1 survivor
        banks = rng.choice(n_banks, size=n_failures, replace=False)
        batches = np.sort(rng.integers(min_batch, max(n_batches, min_batch + 1),
                                       size=n_failures))
        events = []
        for t, b in zip(batches, banks):
            degraded = rng.random() < p_degraded
            events.append(FaultEvent(
                batch=int(t), bank=int(b),
                state=DEGRADED if degraded else DEAD,
                factor=degraded_factor if degraded else 1.0))
        return cls(n_banks, events)

    # -- the per-batch hook --------------------------------------------------

    def advance(self, batch: int) -> list[FaultEvent]:
        """Fire every pending event scheduled at or before ``batch``."""
        fired = []
        while self._next < len(self.schedule) \
                and self.schedule[self._next].batch <= batch:
            e = self.schedule[self._next]
            self.state[e.bank] = e.state
            self.factor[e.bank] = e.factor if e.state == DEGRADED else 1.0
            fired.append(e)
            self.fired.append(e)
            self._next += 1
        return fired

    # -- views the serve loop feeds to the jitted step / planner ------------

    def live_mask(self) -> np.ndarray:
        """(n_banks,) bool — False where DEAD (the jit step's argument)."""
        return self.state != DEAD

    def slow_factor(self) -> np.ndarray:
        """(n_banks,) float latency multiplier (1.0 unless DEGRADED)."""
        return np.where(self.state == DEGRADED, self.factor, 1.0)

    def dead_banks(self) -> list[int]:
        return [int(b) for b in np.flatnonzero(self.state == DEAD)]

    def degraded_banks(self) -> list[int]:
        return [int(b) for b in np.flatnonzero(self.state == DEGRADED)]

    def any_fault(self) -> bool:
        return bool((self.state != HEALTHY).any())
