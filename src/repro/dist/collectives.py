"""shard_map'd communication patterns shared across the model zoo.

``seqsharded_decode_attention`` is the flash-decode combine that makes
``long_500k`` (524k-token KV cache, batch 1) fit: the KV cache is sharded on
its sequence axis over ``seq_axes``; each shard computes a partial softmax
(running max / sum-exp / weighted values) over its slice and the partials are
combined with pmax/psum — numerically identical to full attention, O(S/n) HBM
per device, O(Hq*Dh) bytes on the wire.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import shard_map
from repro.core.embedding import DistCtx

Array = jax.Array
P = jax.sharding.PartitionSpec

_NEG = -1e30


def all_mesh_axes(dist: DistCtx) -> tuple[str, ...]:
    """Every mesh axis, as one PartitionSpec entry — shards a big leading dim
    (candidate sets, negative samples) over the whole slice."""
    return tuple(dist.mesh.axis_names)


def _decode_attention_local(q: Array, k_new: Array, v_new: Array,
                            k_cache: Array, v_cache: Array, pos: Array,
                            ) -> tuple[Array, Array, Array]:
    """Reference semantics. q (B, Hq, Dh); k/v_new (B, Hkv, Dh);
    k/v_cache (B, S, Hkv, Dh); pos () int32 = slot for the new token.
    Returns (attn (B, Hq, Dh), k_cache', v_cache')."""
    B, Hq, Dh = q.shape
    Hkv = k_new.shape[1]
    G = Hq // Hkv
    S = k_cache.shape[1]
    kc = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new[:, None].astype(k_cache.dtype), pos, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new[:, None].astype(v_cache.dtype), pos, axis=1)
    qg = q.reshape(B, Hkv, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, kc.astype(jnp.float32),
                   preferred_element_type=jnp.float32) / np.sqrt(Dh)
    mask = jnp.arange(S) <= pos
    s = jnp.where(mask[None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, vc.astype(jnp.float32))
    return o.reshape(B, Hq, Dh).astype(q.dtype), kc, vc


def seqsharded_decode_attention(q: Array, k_new: Array, v_new: Array,
                                k_cache: Array, v_cache: Array, pos: Array,
                                *, dist: DistCtx | None = None,
                                seq_axes: tuple[str, ...] = ("model",),
                                ) -> tuple[Array, Array, Array]:
    """One decode step of GQA attention with a sequence-sharded KV cache.

    The shard owning position ``pos`` writes the new K/V row; every shard
    computes a masked partial softmax over its cache slice; partials combine
    across ``seq_axes`` with the flash-decode (m, l, o) rescaling identity.
    """
    if dist is None:
        return _decode_attention_local(q, k_new, v_new, k_cache, v_cache, pos)

    mesh = dist.mesh
    n_seq = int(np.prod([mesh.shape[a] for a in seq_axes]))
    B, Hq, Dh = q.shape
    S = k_cache.shape[1]
    if n_seq == 1 or S % n_seq != 0:
        return _decode_attention_local(q, k_new, v_new, k_cache, v_cache, pos)

    Hkv = k_new.shape[1]
    G = Hq // Hkv
    dp_eff = tuple(a for a in dist.dp_axes if a not in seq_axes)
    n_dp = int(np.prod([mesh.shape[a] for a in dp_eff])) if dp_eff else 1
    bspec = None
    if dp_eff and B % n_dp == 0:
        bspec = dp_eff if len(dp_eff) > 1 else dp_eff[0]
    seq_entry = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    s_loc = S // n_seq

    def fn(q, kn, vn, kc, vc, pos):
        # linear shard index along the (possibly multi-axis) seq sharding
        idx = jnp.zeros((), jnp.int32)
        for a in seq_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        off = idx * s_loc
        b = q.shape[0]

        # the owning shard inserts the new K/V row; others keep their slice
        lp = jnp.clip(pos - off, 0, s_loc - 1)
        owns = (pos >= off) & (pos < off + s_loc)
        kc_new = jax.lax.dynamic_update_slice_in_dim(
            kc, kn[:, None].astype(kc.dtype), lp, axis=1)
        vc_new = jax.lax.dynamic_update_slice_in_dim(
            vc, vn[:, None].astype(vc.dtype), lp, axis=1)
        kc = jnp.where(owns, kc_new, kc)
        vc = jnp.where(owns, vc_new, vc)

        qg = q.reshape(b, Hkv, G, Dh).astype(jnp.float32)
        s = jnp.einsum("bhgd,bshd->bhgs", qg, kc.astype(jnp.float32),
                       preferred_element_type=jnp.float32) / np.sqrt(Dh)
        mask = (off + jnp.arange(s_loc)) <= pos
        s = jnp.where(mask[None, None, None, :], s, _NEG)
        m = s.max(-1)                                   # (b, Hkv, G)
        m_g = jax.lax.pmax(m, seq_axes)
        p = jnp.exp(s - m_g[..., None])                 # 0 on masked shards
        l_g = jax.lax.psum(p.sum(-1), seq_axes)
        o = jnp.einsum("bhgs,bshd->bhgd", p, vc.astype(jnp.float32))
        o_g = jax.lax.psum(o, seq_axes)
        out = o_g / jnp.maximum(l_g, 1e-30)[..., None]
        return out.reshape(b, Hq, Dh).astype(q.dtype), kc, vc

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, None, None), P(bspec, None, None),
                  P(bspec, None, None),
                  P(bspec, seq_entry, None, None),
                  P(bspec, seq_entry, None, None), P()),
        out_specs=(P(bspec, None, None),
                   P(bspec, seq_entry, None, None),
                   P(bspec, seq_entry, None, None)),
    )(q, k_new, v_new, k_cache, v_cache, pos)
