"""Fault tolerance primitives: injected failures, straggler detection, and
the checkpoint-restart driver loop.

These are deliberately jax-free — they wrap the host-side training loop, not
the compiled step, so they compose with any family's step function.
"""
from __future__ import annotations

import statistics
import time
from collections import deque
from typing import Callable


class InjectedFailure(RuntimeError):
    """Raised by FailureInjector.check at the armed step."""


class FailureInjector:
    """Deterministically crash the training loop once at ``fail_at_step`` —
    the restart path must then restore from checkpoint and replay to an
    identical final state (test_checkpoint_fault exercises this)."""

    def __init__(self, fail_at_step: int):
        self.fail_at_step = fail_at_step
        self.fired = False

    def check(self, step: int) -> None:
        if not self.fired and step == self.fail_at_step:
            self.fired = True
            raise InjectedFailure(f"injected failure at step {step}")


class StragglerWatchdog:
    """Flags steps whose wall time exceeds ``factor`` x the running median.

    ``observe(step, seconds)`` returns True (and records the step in
    ``events`` / fires ``on_straggler(step, seconds, median)``) when the step
    is a straggler. Straggler times are excluded from the history so one slow
    step doesn't inflate the baseline.
    """

    def __init__(self, factor: float = 3.0, window: int = 64,
                 min_history: int = 5,
                 on_straggler: Callable[[int, float, float], None] | None = None,
                 metrics=None):
        self.factor = factor
        self.min_history = min_history
        self.on_straggler = on_straggler
        self.history: deque[float] = deque(maxlen=window)
        self.events: list[int] = []
        if metrics is None:
            from repro.obs import MetricRegistry
            metrics = MetricRegistry()
        self._m_events = metrics.counter("fault.straggler_events_total",
                                         "steps flagged as stragglers")

    def observe(self, step: int, seconds: float) -> bool:
        straggler = False
        if len(self.history) >= self.min_history:
            med = statistics.median(self.history)
            if seconds > self.factor * med:
                straggler = True
                self.events.append(step)
                self._m_events.inc()
                if self.on_straggler is not None:
                    self.on_straggler(step, seconds, med)
        if not straggler:
            self.history.append(seconds)
        return straggler


def backoff_schedule(max_restarts: int, *, base: float = 0.05,
                     factor: float = 2.0, cap: float = 5.0) -> list[float]:
    """The deterministic (jitterless) delay before each restart:
    ``min(base * factor**n, cap)`` for restart n — testable by inspection."""
    return [min(base * factor ** n, cap) for n in range(max_restarts)]


def run_with_restarts(loop: Callable[[int], int], *,
                      restore_step: Callable[[], int],
                      max_restarts: int = 8,
                      retryable: tuple = (Exception,),
                      base_backoff: float = 0.05,
                      backoff_factor: float = 2.0,
                      max_backoff: float = 5.0,
                      sleep: Callable[[float], None] = time.sleep) -> int:
    """Run ``loop(start_step)`` to completion, restarting from
    ``restore_step()`` (the latest durable checkpoint) after each crash.

    Only exceptions matching ``retryable`` are retried — anything else
    (assertion failures, keyboard interrupts, OOMs you have classified as
    fatal) re-raises immediately, so a deterministic bug is never retried
    into the restart budget. Each restart waits a deterministic exponential
    backoff (``min(base * factor**n, cap)``, no jitter — replayable in
    tests; ``sleep`` is injectable for the same reason). Returns the loop's
    final return value; re-raises once the restart budget is exhausted.
    """
    delays = backoff_schedule(max_restarts, base=base_backoff,
                              factor=backoff_factor, cap=max_backoff)
    attempt = 0
    while True:
        try:
            return loop(restore_step())
        except retryable:
            attempt += 1
            if attempt > max_restarts:
                raise
            sleep(delays[attempt - 1])
