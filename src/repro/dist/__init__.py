"""Distribution substrate: collectives, sharding policies, fault tolerance.

Thin modules so model code imports only what it needs:

  * ``collectives``  — shard_map'd communication patterns (flash-decode
                       partial-softmax combine, all-axes spreading)
  * ``sharding``     — NamedSharding policies per model family (dry-run cells
                       and device_put of real params)
  * ``fault``        — failure injection, straggler watchdog, restart loop
                       (exponential backoff + retryable-exception filter)
  * ``bank_fault``   — per-bank health model (healthy / degraded-slow /
                       dead) on a deterministic seeded injection schedule,
                       driving the serve loop's bounded-degraded reads
"""
