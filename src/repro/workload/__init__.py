"""Online workload telemetry + adaptive repartitioning (README.md).

Closes the loop the paper leaves open: §3's partitioners need access
frequencies, this package measures them live, detects drift, replans, and
migrates the banked tables without a serving pause.
"""
from repro.workload.telemetry import (CountMinSketch, DriftDetector,
                                      DriftReport, TableTelemetry,
                                      TopKCounter, rows_from_sparse,
                                      topk_jaccard, weighted_l1)
from repro.workload.trace import (DriftConfig, DriftingZipfTrace,
                                  dlrm_drifting_batch, read_criteo_tsv)
from repro.workload.replanner import PlanUpdate, ReplanConfig, Replanner
from repro.core.cache_runtime import (FixedCachePlan, RewrittenBatch,
                                      VersionedCacheRewriter)
from repro.workload.migrate import (migrate_packed_leaves,
                                    migrate_replicated,
                                    migrate_rowwise_state, migrate_table,
                                    permute_packed_rows)
from repro.workload.runtime import (AdaptiveEmbeddingRuntime, SwapEvent,
                                    unpacked_rows)
from repro.workload.trace import write_criteo_tsv

__all__ = [
    "AdaptiveEmbeddingRuntime", "CountMinSketch", "DriftConfig",
    "DriftDetector", "DriftReport", "DriftingZipfTrace", "FixedCachePlan",
    "PlanUpdate",
    "ReplanConfig", "Replanner", "RewrittenBatch", "SwapEvent",
    "TableTelemetry", "TopKCounter", "VersionedCacheRewriter",
    "dlrm_drifting_batch", "migrate_packed_leaves", "migrate_replicated",
    "migrate_rowwise_state", "migrate_table",
    "permute_packed_rows", "read_criteo_tsv", "rows_from_sparse",
    "topk_jaccard", "unpacked_rows", "weighted_l1", "write_criteo_tsv",
]
