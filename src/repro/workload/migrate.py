"""Live migration: apply a new PartitionPlan to an already-sharded table.

Rebuilding a table from scratch on every replan would stream the full vocab
through host memory and drop the serving loop for seconds; migration reuses
what is already resident:

  * rows whose bank does NOT change are a per-bank permutation gather
    (slot reshuffle inside the bank's own HBM block — no traffic on the wire),
  * rows that change bank ride ONE psum over the bank axis (`repro.dist`
    rendition of a cross-bank row exchange) — COMPACTED to the moved set:
    each bank gathers the rows it is giving up into an (n_moved, D) buffer
    at their host-assigned position in the global moved list, and the
    reduction materializes exactly the moved rows (an incremental replan
    moves a few percent of the vocab, so the wire cost tracks the drift
    instead of the full packed size; ``exchange='full'`` keeps the original
    packed-size buffer as the parity baseline),

and the swap to the new (packed, remap_bank, remap_slot) triple happens
between micro-batches on the host — the jitted serve step never observes a
half-migrated table. Keeping ``rows_per_bank`` at a fixed capacity across
plans keeps every array shape static, so the swap does not trigger a
recompile (runtime.py relies on this).

``migrate_table`` is exact: the result is bit-identical to ``pack_table`` of
the same row values under the new plan (tests/test_workload.py asserts it,
per-bank, on both the single-device and shard_map paths).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import shard_map
from repro.core.embedding import BankedTable, DistCtx
from repro.core.partitioning import PartitionPlan

Array = jax.Array


def _flat_positions(plan: PartitionPlan, rows_per_bank: int) -> np.ndarray:
    return (plan.bank_of_row.astype(np.int64) * rows_per_bank
            + plan.slot_of_row).astype(np.int32)


def resolve_rows_per_bank(plan: PartitionPlan,
                          rows_per_bank: int | None) -> int:
    rpb = int(plan.max_rows_per_bank if rows_per_bank is None
              else rows_per_bank)
    if rpb < plan.max_rows_per_bank:
        raise ValueError(f"rows_per_bank {rpb} < plan max "
                         f"{plan.max_rows_per_bank}")
    return rpb


def permute_packed_rows(arr: Array, old_flat: np.ndarray,
                        new_flat: np.ndarray, new_len: int) -> Array:
    """Reindex the leading (packed-row) dim of ``arr`` from the old flat
    layout to the new one; unpopulated pad rows become zeros (pack_table
    semantics). Works for (R, D) tables and (R,) row-wise optimizer state."""
    out = jnp.zeros((new_len,) + arr.shape[1:], arr.dtype)
    return out.at[jnp.asarray(new_flat)].set(
        jnp.take(arr, jnp.asarray(old_flat), axis=0))


def migrate_table(t: BankedTable, new_plan: PartitionPlan,
                  dist: DistCtx | None = None, *,
                  rows_per_bank: int | None = None,
                  exchange: str = "compact") -> BankedTable:
    """Re-layout ``t`` under ``new_plan`` without re-initializing.

    ``rows_per_bank`` pins the per-bank capacity (pass the table's current
    value to keep shapes — and therefore compiled executables — stable).
    ``exchange`` picks the sharded moved-row path: 'compact' psums only the
    gathered (n_moved, D) buffer, 'full' the original packed-size buffer
    (bit-identical results; tests assert it).
    """
    if new_plan.vocab != t.vocab:
        raise ValueError(f"plan vocab {new_plan.vocab} != table {t.vocab}")
    if exchange not in ("compact", "full"):
        raise ValueError(f"exchange must be 'compact' or 'full', "
                         f"got {exchange!r}")
    new_rpb = resolve_rows_per_bank(new_plan, rows_per_bank)
    old_flat = np.asarray(
        (np.asarray(t.remap_bank, np.int64) * t.rows_per_bank
         + np.asarray(t.remap_slot)), np.int32)
    new_flat = _flat_positions(new_plan, new_rpb)

    if dist is None:
        packed = permute_packed_rows(
            t.packed, old_flat, new_flat, new_plan.n_banks * new_rpb)
    else:
        packed = _migrate_packed_sharded(t, new_plan, new_rpb, dist,
                                         exchange=exchange)

    return BankedTable(
        packed=packed,
        remap_bank=jnp.asarray(new_plan.bank_of_row, jnp.int32),
        remap_slot=jnp.asarray(new_plan.slot_of_row, jnp.int32),
        n_banks=new_plan.n_banks,
        rows_per_bank=new_rpb,
    )


def _migrate_packed_sharded(t: BankedTable, new_plan: PartitionPlan,
                            new_rpb: int, dist: DistCtx, *,
                            exchange: str = "compact") -> Array:
    """shard_map migration: local permutation for stay rows, psum exchange
    for moved rows. Requires the bank count to match the mesh's bank axis
    (as banked_embedding_bag does).

    The moved-row exchange has two shapes: 'compact' (default) enumerates
    the moved set HOST-side (the remaps are concrete between micro-batches —
    the same pre-processing contract as ``shard_csr_batch``) and psums an
    (n_moved, D) buffer where each moved row owns one host-assigned
    position; 'full' scatters into an (n_banks * new_rpb, D) buffer at the
    rows' new flat positions (the original path, kept as parity baseline).
    Both are exact: every buffer position is written by exactly one bank.
    """
    if new_plan.n_banks != t.n_banks:
        raise ValueError("sharded migration keeps the bank count (the mesh "
                         f"axis is fixed): {t.n_banks} -> {new_plan.n_banks}")
    P = jax.sharding.PartitionSpec
    bank = dist.bank_axis
    n_banks = t.n_banks
    D = t.dim
    dtype = t.packed.dtype
    old_bank_h = np.asarray(t.remap_bank, np.int32)
    new_bank_h = np.asarray(new_plan.bank_of_row, np.int32)
    new_bank = jnp.asarray(new_bank_h)
    new_slot = jnp.asarray(new_plan.slot_of_row, jnp.int32)

    def stay_rows(old_local, ob, osl, nb, ns, my):
        mine_old = ob == my
        vals = jnp.take(old_local, jnp.where(mine_old, osl, 0), axis=0)
        vals = jnp.where(mine_old[:, None], vals, jnp.zeros((), dtype))
        stay = mine_old & (nb == my)
        local = jnp.zeros((new_rpb, D), dtype)
        return mine_old, vals, local.at[jnp.where(stay, ns, new_rpb)].set(
            jnp.where(stay[:, None], vals, jnp.zeros((), dtype)),
            mode="drop")

    if exchange == "compact":
        moved_rows = np.nonzero(old_bank_h != new_bank_h)[0]
        if moved_rows.size == 0:
            # pure in-bank permutation: no collective at all
            def fn_local(old_local, ob, osl, nb, ns):
                my = jax.lax.axis_index(bank)
                return stay_rows(old_local, ob, osl, nb, ns, my)[2]

            return shard_map(
                fn_local, mesh=dist.mesh,
                in_specs=(P(bank, None), P(), P(), P(), P()),
                out_specs=P(bank, None),
            )(t.packed, t.remap_bank, t.remap_slot, new_bank, new_slot)

        m_ob = jnp.asarray(old_bank_h[moved_rows])
        m_os = jnp.asarray(np.asarray(t.remap_slot, np.int32)[moved_rows])
        m_nb = jnp.asarray(new_bank_h[moved_rows])
        m_ns = jnp.asarray(new_plan.slot_of_row.astype(np.int32)[moved_rows])

        def fn(old_local, ob, osl, nb, ns, mob, mos, mnb, mns):
            my = jax.lax.axis_index(bank)
            _, _, local = stay_rows(old_local, ob, osl, nb, ns, my)
            # each bank fills ITS outgoing rows at their global moved-list
            # position; the psum materializes the full moved set (n_moved, D)
            out_mine = mob == my
            buf = jnp.take(old_local, jnp.where(out_mine, mos, 0), axis=0)
            buf = jnp.where(out_mine[:, None], buf, jnp.zeros((), dtype))
            buf = jax.lax.psum(buf, bank)
            in_mine = mnb == my
            return local.at[jnp.where(in_mine, mns, new_rpb)].set(
                jnp.where(in_mine[:, None], buf, jnp.zeros((), dtype)),
                mode="drop")

        return shard_map(
            fn, mesh=dist.mesh,
            in_specs=(P(bank, None), P(), P(), P(), P(), P(), P(), P(), P()),
            out_specs=P(bank, None),
        )(t.packed, t.remap_bank, t.remap_slot, new_bank, new_slot,
          m_ob, m_os, m_nb, m_ns)

    def fn_full(old_local, ob, osl, nb, ns):
        my = jax.lax.axis_index(bank)
        mine_old, vals, local = stay_rows(old_local, ob, osl, nb, ns, my)
        # moved rows: scatter into the global layout, exchange via psum
        moved = mine_old & (nb != my)
        flat = jnp.where(moved, nb * new_rpb + ns, n_banks * new_rpb)
        buf = jnp.zeros((n_banks * new_rpb, D), dtype)
        buf = buf.at[flat].set(
            jnp.where(moved[:, None], vals, jnp.zeros((), dtype)),
            mode="drop")
        buf = jax.lax.psum(buf, bank)
        incoming = jax.lax.dynamic_slice(
            buf, (my * new_rpb, 0), (new_rpb, D))
        return local + incoming

    return shard_map(
        fn_full, mesh=dist.mesh,
        in_specs=(P(bank, None), P(), P(), P(), P()),
        out_specs=P(bank, None),
    )(t.packed, t.remap_bank, t.remap_slot, new_bank, new_slot)


def migrate_replicated(base: BankedTable, rplan, *,
                       rows_per_bank: int | None = None):
    """Build the replicated side table for ``rplan`` from a live base table
    — the replica-lane swap's device path.

    Gathers the vocab rows once through the base remap (no host round-trip)
    and scatters every copy the plan calls for; bit-identical to
    ``pack_replicated`` of the unpacked rows (tests assert it), so a
    replica-count change swaps in a table indistinguishable from a fresh
    pack. ``rows_per_bank`` pins the shape across swaps like the other
    lanes.
    """
    from repro.core.embedding import ReplicatedTable
    rpb = int(rplan.max_rows_per_bank if rows_per_bank is None
              else rows_per_bank)
    if rpb < rplan.max_rows_per_bank:
        raise ValueError(f"rows_per_bank {rpb} < replica plan max "
                         f"{rplan.max_rows_per_bank}")
    if rplan.vocab != base.vocab:
        raise ValueError(f"replica plan vocab {rplan.vocab} != table "
                         f"{base.vocab}")
    rows = jnp.take(base.packed, base.flat_remap(), axis=0)     # (V, D)
    vv, rr = np.nonzero(np.arange(rplan.k_max)[None, :]
                        < rplan.copies[:, None])
    pos = (rplan.bank_of_copy[vv, rr].astype(np.int64) * rpb
           + rplan.slot_of_copy[vv, rr]).astype(np.int32)
    packed = jnp.zeros((rplan.n_banks * rpb, base.dim), base.packed.dtype)
    packed = packed.at[jnp.asarray(pos)].set(rows[jnp.asarray(vv)])
    return ReplicatedTable(
        packed=packed,
        remap_bank=jnp.asarray(rplan.bank_of_copy, jnp.int32),
        remap_slot=jnp.asarray(rplan.slot_of_copy, jnp.int32),
        n_banks=rplan.n_banks,
        rows_per_bank=rpb,
        k_max=rplan.k_max,
    )


def migrate_packed_leaves(tree, old_table: BankedTable,
                          new_plan: PartitionPlan, *,
                          rows_per_bank: int | None = None):
    """Migrate every packed-row-aligned leaf of a pytree — params AND
    optimizer state in one pass (train-loop replanning: the row-wise Adagrad
    accumulator must follow its row or hot rows restart cold).

    A leaf participates iff its leading dim equals the packed row count
    (``n_banks * rows_per_bank`` — vocab-scale, so dense layers never
    collide with it in practice).
    """
    plen = old_table.n_banks * old_table.rows_per_bank

    def f(x):
        if hasattr(x, "ndim") and x.ndim >= 1 and x.shape[0] == plen:
            return migrate_rowwise_state(x, old_table, new_plan,
                                         rows_per_bank=rows_per_bank)
        return x

    return jax.tree.map(f, tree)


def migrate_rowwise_state(arr: Array, old_table: BankedTable,
                          new_plan: PartitionPlan, *,
                          rows_per_bank: int | None = None) -> Array:
    """Migrate a packed-row-aligned auxiliary array (e.g. the row-wise
    Adagrad accumulator, shape (n_banks*rows_per_bank,) or (..., D)) with the
    same permutation as the table rows."""
    new_rpb = resolve_rows_per_bank(new_plan, rows_per_bank)
    old_flat = np.asarray(
        (np.asarray(old_table.remap_bank, np.int64) * old_table.rows_per_bank
         + np.asarray(old_table.remap_slot)), np.int32)
    new_flat = _flat_positions(new_plan, new_rpb)
    return permute_packed_rows(arr, old_flat, new_flat,
                               new_plan.n_banks * new_rpb)
