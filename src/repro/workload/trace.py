"""Reproducible drifting workloads: generation + replay.

The paper's Table-1 traces are STATIONARY Zipf draws (data/synthetic.py);
the adaptive runtime needs traffic whose hot set MOVES so a stale plan is
visibly worse than a fresh one. ``DriftingZipfTrace`` produces that, with
three composable drift mechanisms on top of a Zipf(a) popularity base:

  rotation — every ``rotate_every`` bags the rank->item permutation advances
             by ``rotate_frac * n_items`` positions: yesterday's head moves
             into the tail (trending catalogs, news cycles).
  diurnal  — popularity blends between two fixed permutations with a
             sin^2 weight of period ``diurnal_period`` bags (the day/night
             audience swap; the hot set OSCILLATES instead of walking).
  bursts   — with prob ``burst_prob`` per bag a short window of
             ``burst_len`` bags draws half its items from a tiny random
             ``burst_items``-item hot set (flash sales, breaking stories).

Every bag is a pure function of (seed, bag index), so a replanner run and its
static baseline replay the IDENTICAL stream — the property every benchmark
and every drift test here relies on.

``read_criteo_tsv`` ingests real traces in Criteo TSV format
(label \\t 13 dense \\t 26 hex-categorical) so the same loop can be driven by
production logs instead of synthetic drift.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    n_items: int
    zipf_a: float = 1.05
    avg_bag: float = 8.0           # |bag| ~ max(1, Poisson(avg_bag))
    rotate_every: int = 0          # bags between hot-set rotations (0 = off)
    rotate_frac: float = 0.2       # fraction of id space per rotation step
    diurnal_period: int = 0        # bags per "day" (0 = off)
    burst_prob: float = 0.0        # per-bag prob of STARTING a burst window
    burst_len: int = 32            # bags per burst window
    burst_items: int = 16          # size of the burst hot set
    burst_share: float = 0.5       # fraction of a burst bag from the hot set


class DriftingZipfTrace:
    """Deterministic drifting bag stream. ``bag(t)`` is pure in (seed, t)."""

    def __init__(self, cfg: DriftConfig, *, seed: int = 0):
        self.cfg = cfg
        self.seed = seed
        self.t = 0                              # replay clock (next bag index)
        rng = np.random.default_rng((seed, 0xD21F))
        ranks = np.arange(1, cfg.n_items + 1, dtype=np.float64)
        self._base_p = ranks ** (-cfg.zipf_a)
        self._base_p /= self._base_p.sum()
        self._perm_a = rng.permutation(cfg.n_items)
        self._perm_b = rng.permutation(cfg.n_items)

    # -- popularity schedule ------------------------------------------------

    def _schedule(self, t: int) -> tuple[int, float]:
        """(rotation shift, diurnal weight) at bag index t. The diurnal phase
        is quantized to 16 steps per period so the pmf is piecewise-constant
        (cacheable) while still sweeping the full day cycle."""
        cfg = self.cfg
        shift = 0
        if cfg.rotate_every > 0:
            shift = (t // cfg.rotate_every) * max(
                1, int(cfg.rotate_frac * cfg.n_items))
        w = 0.0
        if cfg.diurnal_period > 0:
            step = max(1, cfg.diurnal_period // 16)
            w = float(np.sin(np.pi * ((t // step) * step)
                             / cfg.diurnal_period) ** 2)
        return shift, w

    def popularity(self, t: int) -> np.ndarray:
        """(n_items,) item-sampling pmf at bag index t — pure in (seed, t)."""
        shift, w = self._schedule(t)
        p = np.empty(self.cfg.n_items)
        p[np.roll(self._perm_a, shift)] = self._base_p
        if w > 0.0:
            pb = np.empty(self.cfg.n_items)
            pb[np.roll(self._perm_b, shift)] = self._base_p
            p = (1.0 - w) * p + w * pb
        return p / p.sum()

    def _burst_set(self, t: int) -> np.ndarray | None:
        """Burst hot set active at t, or None. Burst windows are anchored at
        their start bag so every bag in a window shares one hot set."""
        cfg = self.cfg
        if cfg.burst_prob <= 0.0:
            return None
        for start in range(max(0, t - cfg.burst_len + 1), t + 1):
            r = np.random.default_rng((self.seed, 0xB5A7, start))
            if r.random() < cfg.burst_prob:
                return r.choice(cfg.n_items, cfg.burst_items, replace=False)
        return None

    # -- bag generation -----------------------------------------------------

    def bag(self, t: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((self.seed, 0xBA6, t))
        size = max(1, rng.poisson(cfg.avg_bag))
        # popularity varies per WINDOW, not per bag: reuse the cached pmf
        p = self._pmf_at(t)
        out = rng.choice(cfg.n_items, size=size, p=p)
        hot = self._burst_set(t)
        if hot is not None:
            n_hot = int(np.ceil(size * cfg.burst_share))
            out[:n_hot] = rng.choice(hot, n_hot)
        return out.astype(np.int64)

    def _pmf_at(self, t: int) -> np.ndarray:
        # the pmf is a pure function of the (shift, weight) schedule point;
        # cache on that key so the O(n_items) build runs once per boundary
        key = self._schedule(t)
        if getattr(self, "_pmf_key", None) != key:
            self._pmf = self.popularity(t)
            self._pmf_key = key
        return self._pmf

    def bags(self, n: int) -> list[np.ndarray]:
        """Next n bags from the replay clock (advances it)."""
        out = [self.bag(self.t + i) for i in range(n)]
        self.t += n
        return out

    def rect(self, batch: int, bag_len: int) -> np.ndarray:
        """Next ``batch`` bags as a (batch, bag_len) int32 array, -1 padded
        (truncating oversize bags) — the rectangular serve-batch form."""
        out = np.full((batch, bag_len), -1, dtype=np.int32)
        for i, bag in enumerate(self.bags(batch)):
            b = bag[:bag_len]
            out[i, :len(b)] = b
        return out

    def reset(self, t: int = 0) -> None:
        self.t = t


def dlrm_drifting_batch(traces: list[DriftingZipfTrace], batch: int,
                        multi_hot: int) -> np.ndarray:
    """(B, F) one-hot or (B, F, L) multi-hot sparse ids, field f drawn from
    traces[f] — the drifting replacement for data/synthetic.dlrm_batch."""
    cols = [tr.rect(batch, max(multi_hot, 1)) for tr in traces]
    sparse = np.stack(cols, axis=1)                    # (B, F, L)
    if multi_hot == 1:
        return np.maximum(sparse[:, :, 0], 0).astype(np.int32)
    return sparse.astype(np.int32)


# ---------------------------------------------------------------------------
# Criteo-format TSV replay
# ---------------------------------------------------------------------------

def read_criteo_tsv(path: str, *, n_dense: int = 13, n_sparse: int = 26,
                    hash_vocab: int | None = None,
                    max_rows: int | None = None) -> dict:
    """Parse a Criteo-format TSV: label \\t dense*13 \\t hex-categorical*26.

    Missing fields -> -1 (the pipeline's padding id). Hex categoricals are
    parsed as base-16; ``hash_vocab`` folds them into [0, hash_vocab) (the
    standard hashing trick — required before feeding a fixed-vocab table).
    Returns {"label": (N,), "dense": (N, n_dense), "sparse": (N, n_sparse)}.
    """
    labels, dense, sparse = [], [], []
    with open(path) as fh:
        for line in fh:
            parts = line.rstrip("\n").split("\t")
            if len(parts) < 1 + n_dense + n_sparse:
                parts = parts + [""] * (1 + n_dense + n_sparse - len(parts))
            labels.append(float(parts[0] or 0))
            dense.append([float(x) if x else 0.0
                          for x in parts[1:1 + n_dense]])
            row = []
            for x in parts[1 + n_dense:1 + n_dense + n_sparse]:
                if not x:
                    row.append(-1)
                    continue
                try:
                    v = int(x, 16)
                except ValueError:
                    v = zlib.crc32(x.encode())   # deterministic across runs
                row.append(v % hash_vocab if hash_vocab else v)
            sparse.append(row)
            if max_rows is not None and len(labels) >= max_rows:
                break
    return {
        "label": np.asarray(labels, np.float32),
        "dense": np.asarray(dense, np.float32),
        "sparse": np.asarray(sparse, np.int64),
    }


def write_criteo_tsv(path: str, n_rows: int, *, n_fields: int = 26,
                     vocab_per_field: int = 1000, n_dense: int = 13,
                     drift: DriftConfig | None = None, seed: int = 0) -> None:
    """Synthesize a DRIFTING trace in Criteo TSV format (label \\t dense*13 \\t
    hex-categorical*26) — the fixture that lets the real-trace replay path
    (``read_criteo_tsv`` -> ``criteo_row_stream``) run in CI without shipping
    production logs. Field f draws from its own ``DriftingZipfTrace`` (shared
    drift schedule, per-field seed), so the replayed stream exhibits the same
    hot-set rotation the synthetic benchmarks use. ``n_fields`` < 26 leaves
    the remaining categorical columns empty (-1 after parsing), matching real
    Criteo's missing fields.
    """
    if drift is None:
        drift = DriftConfig(n_items=vocab_per_field, zipf_a=1.1, avg_bag=1.0)
    drift = dataclasses.replace(drift, n_items=vocab_per_field, avg_bag=1.0)
    traces = [DriftingZipfTrace(drift, seed=seed + f) for f in range(n_fields)]
    rng = np.random.default_rng((seed, 0xC21E0))
    with open(path, "w") as fh:
        for i in range(n_rows):
            label = int(rng.random() < 0.25)
            dense = [f"{x:.3f}" for x in rng.standard_normal(n_dense)]
            cats = [f"{int(tr.bag(i)[0]):x}" for tr in traces]
            cats += [""] * (26 - n_fields)
            fh.write("\t".join([str(label), *dense, *cats]) + "\n")


def criteo_row_stream(table: dict, field_offsets: np.ndarray):
    """Yield per-example union-vocab row-id bags from a read_criteo_tsv dict —
    the telemetry/replanner feed for real-trace replay."""
    sparse = table["sparse"]
    offs = np.asarray(field_offsets, np.int64)
    for i in range(sparse.shape[0]):
        row = sparse[i]
        valid = row >= 0
        yield (row + offs)[valid]
