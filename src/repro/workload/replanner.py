"""Background replanning: live counters -> fresh §3 partition plan.

The decision loop (README.md):

    every ``check_every`` batches:
        report = DriftDetector.check(telemetry)       # vs plan-time freqs
        if report.drifted:
            freq = telemetry.freq_vector()
            plan = non_uniform_partition(freq, ...)   # or cache-aware
            (cache plan remined + cache table rebuilt when cache-aware)
            -> PlanUpdate for the runtime to migrate + swap

The replanner itself is host-side and cheap (the greedy partitioners are
O(V log B)); the expensive part — moving rows — is migrate.py's job, and
WHETHER to pay it is exactly what the drift detector gates.

``capacity_rows`` should be the serving table's fixed per-bank capacity so
every plan the replanner emits fits the already-allocated packed array
(shape-stable swaps; see migrate.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.cache_runtime import (FixedCachePlan, cap_cache_plan,
                                      entry_banks)
from repro.core.grace import CachePlan, mine_cooccurrence
from repro.core.partitioning import (PartitionPlan, cache_aware_partition,
                                     non_uniform_partition)
from repro.obs import MetricRegistry
from repro.workload.telemetry import DriftDetector, DriftReport, TableTelemetry


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    n_banks: int
    partitioner: str = "non_uniform"       # 'non_uniform' | 'cache_aware'
    capacity_rows: int | None = None       # per-bank row budget (fixed shape)
    check_every: int = 20                  # batches between drift checks
    topk: int = 256                        # hot-set size for the Jaccard test
    min_jaccard: float = 0.5
    max_weighted_l1: float = 0.5
    min_observations: int = 2000
    # past this vocab the drift check runs on the top-K UNION instead of
    # materializing a (vocab,) estimate per check (telemetry.DriftDetector)
    drift_sparse_above: int = 10_000_000
    # telemetry exponential window (TableTelemetry): < 1.0 multiplies all
    # counters by ``telemetry_decay`` every ``telemetry_decay_every`` observed
    # ids. Without it the freq estimate is CUMULATIVE since process start, so
    # a long-lived server's detector goes blind to late drift (the reference
    # rebases onto an average the new regime barely moves) and replans keep
    # re-installing history's plan. Serving loops should set it.
    telemetry_decay: float = 1.0
    telemetry_decay_every: int = 100_000
    # cache-aware only: GRACE re-mining window + knobs
    mine_window: int = 512                 # recent bags kept for re-mining
    mine_top_items: int = 2048
    mine_max_groups: int = 256
    mine_min_support: int = 3
    # cache-aware serving: fixed per-bank cache-entry budget. When set, every
    # PlanUpdate carries ``cache_fixed`` — the re-mined plan padded/truncated
    # to n_banks * cache_rows_per_bank entry positions, so the swapped-in
    # cache table always has the shape the serve jit was compiled for.
    cache_rows_per_bank: int | None = None
    # replan hysteresis: a drift-triggered candidate plan must beat the
    # incumbent's PROJECTED max-bank load share on the recent telemetry
    # window by this relative margin, or the migration is skipped (counted
    # in ``Replanner.n_skipped_replans``). Guards against adversarial
    # rotations where the detector trips but the candidate layout would not
    # actually serve the current traffic better than what is installed.
    # 0.0 disables the gate (every drifted check migrates, PR-4 behavior).
    hysteresis: float = 0.0
    # tiered-precision lane (repro.quant): when set, every replan re-runs
    # the tier assigner on the live frequencies and partitions by BYTE load
    # (freq x bytes-per-row under the new tier map) instead of row load;
    # PlanUpdate carries ``tier_of_row`` for the runtime to re-quantize
    # promoted/demoted rows. ``quant_dim`` is the table's embedding dim
    # (the byte arithmetic needs it). non_uniform partitioner only.
    quant: "object | None" = None          # repro.quant.QuantSpec
    quant_dim: int | None = None
    # hot-row replication lane: > 1 gives the top-R hottest rows
    # ``replicate_k_max`` copies each (core/partitioning.choose_replication
    # picks R from live head mass; copies land on distinct banks and a
    # per-bag hash splits their traffic). Every committed PlanUpdate then
    # carries ``replica_plan`` for the runtime's replica swap lane.
    # ``replicate_max_r`` bounds the capacity cost — and is further clamped
    # so R * (k_max - 1) extra physical rows always fit the fixed
    # ``capacity_rows`` (shape-stable swaps). non_uniform partitioner only.
    replicate_k_max: int = 1
    replicate_max_r: int = 64

    @classmethod
    def for_vocab(cls, vocab: int, n_banks: int, **overrides) -> "ReplanConfig":
        """Defaults scaled to the table size: the hot-set Jaccard needs a k
        well under the vocab (k=vocab makes it identically 1.0), and the
        detector should not arm before ~a few observations per hot row."""
        scaled = dict(
            topk=max(16, min(256, vocab // 8)),
            min_observations=max(256, min(2000, 4 * vocab)),
        )
        scaled.update(overrides)
        return cls(n_banks=n_banks, **scaled)


@dataclasses.dataclass
class PlanUpdate:
    plan: PartitionPlan
    freq: np.ndarray                       # frequencies the plan was built on
    report: DriftReport
    cache_plan: CachePlan | None = None    # cache-aware: remined groups
    # remined plan at the FIXED serving capacity (cache_rows_per_bank set):
    # what the runtime actually swaps into the rewriter + cache table
    cache_fixed: FixedCachePlan | None = None
    # tiered lane (ReplanConfig.quant set): the fresh per-row tier map the
    # plan's byte-load balance was computed under — the runtime re-quantizes
    # exactly the rows whose tier changed (quant.retier_tiered)
    tier_of_row: np.ndarray | None = None
    # replica lane (ReplanConfig.replicate_k_max > 1): the fresh
    # replication-aware plan (core/partitioning.ReplicatedPlan) — the
    # runtime rebuilds the replicated side table from the migrated base
    # (workload.migrate.migrate_replicated) and swaps it versioned
    replica_plan: "object | None" = None


class Replanner:
    """Owns the telemetry + drift detector + replan policy for ONE table
    (DLRM's union-vocab super-table counts as one)."""

    def __init__(self, cfg: ReplanConfig, vocab: int, *,
                 init_freq: np.ndarray | None = None,
                 telemetry: TableTelemetry | None = None,
                 init_plan: PartitionPlan | None = None,
                 metrics: MetricRegistry | None = None):
        if cfg.quant is not None:
            if cfg.partitioner != "non_uniform":
                raise ValueError("ReplanConfig.quant drives byte-load "
                                 "partitioning on the non_uniform path only")
            if cfg.quant_dim is None:
                raise ValueError("ReplanConfig.quant needs quant_dim (the "
                                 "embedding dim) for the byte arithmetic")
        if cfg.replicate_k_max > 1:
            if cfg.partitioner != "non_uniform":
                raise ValueError("ReplanConfig.replicate_k_max rides the "
                                 "non_uniform path only (cache_aware entry "
                                 "placement has no replica axis)")
            if cfg.replicate_k_max > cfg.n_banks:
                raise ValueError(f"replicate_k_max {cfg.replicate_k_max} > "
                                 f"n_banks {cfg.n_banks}: copies must land "
                                 f"on distinct banks")
        self.cfg = cfg
        self.vocab = vocab
        # the INSTALLED plan (+ its capped cache plan, cache_aware), for
        # hysteresis projection; tracked on every committed replan (the
        # runtime seeds the plan with the serving one)
        self.current_plan = init_plan
        self.current_cache_fixed: FixedCachePlan | None = None
        self.telemetry = telemetry or TableTelemetry(
            vocab, decay=cfg.telemetry_decay,
            decay_every=cfg.telemetry_decay_every)
        if init_freq is None:
            init_freq = np.ones(vocab, dtype=np.float64)
        self.detector = DriftDetector(
            init_freq, k=cfg.topk, min_jaccard=cfg.min_jaccard,
            max_weighted_l1=cfg.max_weighted_l1,
            min_observations=cfg.min_observations,
            sparse_above=cfg.drift_sparse_above)
        self._recent_bags: deque[np.ndarray] = deque(maxlen=cfg.mine_window)
        self._batches = 0
        self.n_replans = 0
        self.n_skipped_replans = 0         # hysteresis: drifted but kept plan
        self.last_report: DriftReport | None = None
        # metrics mirror the counters above (pre-registered so the snapshot
        # schema is the same whether or not anything ever drifts)
        self.metrics = metrics if metrics is not None else MetricRegistry()
        m = self.metrics
        self._m_replans = m.counter("replanner.replans_total",
                                    "committed replans (migrations)")
        self._m_skips = m.counter("replanner.hysteresis_skips_total",
                                  "drifted checks where the candidate lost")
        self._m_checks = m.counter("replanner.drift_checks_total",
                                   "cadenced drift-detector runs")
        self._m_drifted = m.counter("replanner.drift_detected_total",
                                    "checks that reported drift")
        self._m_hit_rate = m.gauge("replanner.realized_hit_rate",
                                   "realized/predicted cache saved-reads")
        self._m_hit_rate.set(1.0)
        self._m_slo_pen = m.counter("replanner.slo_penalties_total",
                                    "SLO-watchdog bank penalties received")
        # fault-tolerance state (all-healthy defaults are exactly the legacy
        # planner: no per-bank caps, unit costs — bit-identical plans)
        self.bank_live = np.ones(cfg.n_banks, dtype=bool)
        self.bank_penalty = np.ones(cfg.n_banks, dtype=np.float64)
        # realized-hit-rate feed (cache_aware): what the serve loop actually
        # saved vs what the miner predicted at the last commit
        self._pred_saved_per_bag: float | None = None
        self._realized_saved = 0.0
        self._realized_bags = 0
        # SLO feedback: an armed early check makes the NEXT end_batch run
        # the drift detector off-cadence (set by apply_slo_penalty)
        self._early_check = False

    # -- fault state ---------------------------------------------------------

    def set_bank_health(self, live_mask: np.ndarray) -> None:
        """(n_banks,) bool — False marks a DEAD bank. Every subsequent
        ``build_plan`` treats dead banks as zero-capacity so their rows
        re-pack onto the survivors (the recovery half of bounded-degraded
        serving; the runtime's ``on_bank_failure`` drives this)."""
        live = np.asarray(live_mask, dtype=bool)
        if live.shape != (self.cfg.n_banks,):
            raise ValueError(f"live_mask {live.shape} != ({self.cfg.n_banks},)")
        self.bank_live = live.copy()

    def set_bank_penalty(self, penalty: np.ndarray) -> None:
        """(n_banks,) latency multipliers (1.0 = nominal). A bank observed
        k-times slower accounts each accepted row at k x its frequency, so
        the greedy sheds load off stragglers like it sheds hot rows off
        loaded banks (StragglerWatchdog feedback)."""
        pen = np.asarray(penalty, dtype=np.float64)
        if pen.shape != (self.cfg.n_banks,):
            raise ValueError(f"penalty {pen.shape} != ({self.cfg.n_banks},)")
        if (pen <= 0).any():
            raise ValueError("bank penalties must be positive multipliers")
        self.bank_penalty = pen.copy()

    def apply_slo_penalty(self, penalty: np.ndarray) -> None:
        """SLO-watchdog feedback (obs/slo.py): the MEASURED per-bank traffic
        breached a latency/share objective, so fold the hot bank's observed
        overload into the planner's ``bank_cost`` model (same mechanism as
        the straggler penalty — an overloaded bank accounts each accepted
        row at penalty x its frequency and sheds load on the next plan) and
        arm an early off-cadence drift check so the loop closes without
        waiting out ``check_every``. Measure -> plan feedback edge
        (ARCHITECTURE.md)."""
        self.set_bank_penalty(penalty)
        self._m_slo_pen.inc()
        self._early_check = True

    # -- feeding ------------------------------------------------------------

    def observe_rows(self, rows: np.ndarray) -> None:
        """Union-vocab row ids from one serve/train batch (any shape,
        negatives = padding)."""
        self.telemetry.observe(rows)

    def observe_bags(self, bags: list[np.ndarray]) -> None:
        """Bag-granular feed — also retained for cache re-mining."""
        for bag in bags:
            self.telemetry.observe(bag)
            self._recent_bags.append(np.asarray(bag))

    def observe_cache_hits(self, saved_reads: float, n_bags: int) -> None:
        """Cache-aware serving feedback: ``saved_reads`` row reads were
        actually absorbed by the installed cache over ``n_bags`` bags (a bag
        rewritten to c entries + r residuals saves ``len(bag) - c - r``).
        Accumulated until the next commit; see ``realized_hit_rate``."""
        self._realized_saved += float(saved_reads)
        self._realized_bags += int(n_bags)
        self._m_hit_rate.set(self.realized_hit_rate())

    def realized_hit_rate(self) -> float:
        """REALIZED / PREDICTED saved-reads-per-bag for the installed cache,
        clipped to [0, 1]. 1.0 until both sides exist (no feedback, or no
        committed prediction) — the discount only ever shrinks benefits, and
        only once there is evidence the miner over-promised."""
        if self._pred_saved_per_bag is None or self._pred_saved_per_bag <= 0 \
                or self._realized_bags == 0:
            return 1.0
        realized = self._realized_saved / self._realized_bags
        return float(np.clip(realized / self._pred_saved_per_bag, 0.0, 1.0))

    # -- planning -----------------------------------------------------------

    def build_plan(self, freq: np.ndarray
                   ) -> tuple[PartitionPlan, CachePlan | None,
                              "np.ndarray | None"]:
        """(plan, cache_plan, tier_of_row) from a frequency estimate. With
        ``cfg.quant`` set, tiers come first and the greedy balances BYTE
        load (freq x bytes-per-row under the fresh tier map)."""
        cfg = self.cfg
        # fault/straggler state folds into every plan — but ONLY when
        # non-trivial, so all-healthy serving stays bit-identical to the
        # legacy planner
        all_live = bool(self.bank_live.all())
        unit_cost = bool((self.bank_penalty == 1.0).all())
        if cfg.partitioner == "non_uniform":
            row_weights = None
            tiers = None
            if cfg.quant is not None:
                from repro.quant import assign_tiers, bytes_of_tier
                ta = assign_tiers(freq, cfg.quant, cfg.quant_dim)
                tiers = ta.tier_of_row
                row_weights = bytes_of_tier(
                    tiers, cfg.quant_dim, cfg.quant.hot_dtype
                ).astype(np.float64)
            bank_caps = None
            if not all_live:
                per_bank = cfg.capacity_rows if cfg.capacity_rows is not None \
                    else self.vocab
                bank_caps = np.where(self.bank_live, per_bank, 0)
            plan = non_uniform_partition(
                freq, cfg.n_banks, capacity_rows=cfg.capacity_rows,
                row_weights=row_weights, bank_capacity_rows=bank_caps,
                bank_cost=None if unit_cost else self.bank_penalty)
            return plan, None, tiers
        if cfg.partitioner == "cache_aware":
            if not all_live:
                raise ValueError(
                    "cache_aware replanning cannot exclude dead banks yet — "
                    "Algorithm 1's joint cache/EMT packing has no per-bank "
                    "capacity mask; serve fault recovery runs on the "
                    "non_uniform partitioner")
            if not self._recent_bags:
                raise ValueError("cache_aware replanning needs observe_bags() "
                                 "traffic to re-mine co-occurrence groups")
            cp = mine_cooccurrence(
                list(self._recent_bags), top_items=cfg.mine_top_items,
                max_groups=cfg.mine_max_groups,
                min_support=cfg.mine_min_support)
            # discount the miner's predicted benefits by the hit rate the
            # SERVED traffic realized on the incumbent cache — an
            # over-promising miner stops distorting the bank packing
            rate = self.realized_hit_rate()
            benefits = cp.benefits if rate >= 1.0 \
                else np.asarray(cp.benefits, np.float64) * rate
            plan = cache_aware_partition(
                freq, cp.groups, benefits, cfg.n_banks,
                emt_capacity_rows=cfg.capacity_rows)
            return plan, cp, None
        raise ValueError(f"unknown partitioner {cfg.partitioner!r}")

    def build_replica_plan(self, freq: np.ndarray,
                           tier_of_row: "np.ndarray | None" = None):
        """Fresh replication-aware plan (core.partitioning.ReplicatedPlan)
        for the replica swap lane; None when replication is off
        (``replicate_k_max <= 1``). R comes from live head mass
        (choose_replication), clamped so the ``R * (k - 1)`` extra physical
        rows always fit the fixed per-bank capacity; with the tiered lane on,
        candidates are restricted to the bf16 head (replicas stay
        full-precision); dead banks get zero replica capacity and the copy
        count clamps to the surviving-bank count."""
        cfg = self.cfg
        if cfg.replicate_k_max <= 1:
            return None
        from repro.core.partitioning import (choose_replication,
                                             replicated_partition)
        per_bank = cfg.capacity_rows if cfg.capacity_rows is not None \
            else self.vocab
        bank_caps = None
        if bool(self.bank_live.all()):
            headroom = cfg.n_banks * per_bank - self.vocab
        else:
            bank_caps = np.where(self.bank_live, per_bank, 0)
            headroom = int(bank_caps.sum()) - self.vocab
        # copies must land on distinct LIVE banks
        k_eff = min(cfg.replicate_k_max, int(self.bank_live.sum()))
        if k_eff <= 1 or headroom <= 0:
            copies = np.ones(self.vocab, dtype=np.int32)
        else:
            max_r = max(0, min(cfg.replicate_max_r, headroom // (k_eff - 1)))
            hot = None
            if tier_of_row is not None:
                hot = np.flatnonzero(np.asarray(tier_of_row) == 0)
            copies = choose_replication(freq, cfg.n_banks, k_max=k_eff,
                                        max_r=max_r, hot_rows=hot)
        # k_max stays pinned at the configured width even when fewer copies
        # fit right now, so every emitted plan has the serve jit's map shape
        return replicated_partition(
            freq, cfg.n_banks, copies=copies,
            capacity_rows=cfg.capacity_rows, k_max=cfg.replicate_k_max,
            bank_capacity_rows=bank_caps)

    @staticmethod
    def projected_max_share(plan: PartitionPlan, freq: np.ndarray) -> float:
        """Fraction of ``freq``'s row-read mass landing on the hottest bank
        under ``plan`` — the hysteresis currency: what each layout would
        cost on the RECENT window, not the window it was built from."""
        loads = np.zeros(plan.n_banks)
        np.add.at(loads, plan.bank_of_row, freq)
        total = loads.sum()
        return float(loads.max() / total) if total > 0 else 1.0 / plan.n_banks

    @staticmethod
    def projected_max_share_cached(plan: PartitionPlan, fcp: FixedCachePlan,
                                   bags: list) -> float:
        """Cache-aware hysteresis currency: replay the recent-bag window
        through each (plan, capped cache plan) pair — a cache hit costs ONE
        read on the entry's bank, residual rows read their own banks (the
        same cost model bench_workload's cache scenarios score). Raw row
        share would ignore exactly the reads the cache absorbs, skipping
        candidates whose whole improvement IS a better cache."""
        from repro.core.cache_runtime import rewrite_bag
        loads = np.zeros(plan.n_banks)
        for bag in bags:
            c, r = rewrite_bag(np.asarray(bag), fcp.plan)
            if c:
                np.add.at(loads, fcp.entry_bank[np.asarray(c)], 1.0)
            if r:
                np.add.at(loads, plan.bank_of_row[np.asarray(r)], 1.0)
        total = loads.sum()
        return float(loads.max() / total) if total > 0 else 1.0 / plan.n_banks

    def _cap(self, cache_plan: CachePlan | None,
             plan: PartitionPlan) -> FixedCachePlan | None:
        if cache_plan is None or self.cfg.cache_rows_per_bank is None:
            return None
        return cap_cache_plan(
            cache_plan,
            entry_banks(cache_plan, plan.bank_of_row,
                        plan.cache_bank_of_entry),
            self.cfg.n_banks, self.cfg.cache_rows_per_bank)

    def _commit(self, freq: np.ndarray, plan: PartitionPlan,
                cache_plan: CachePlan | None,
                tier_of_row: "np.ndarray | None", report: DriftReport,
                cache_fixed: FixedCachePlan | None = None) -> PlanUpdate:
        self.detector.rebase(freq)
        self.n_replans += 1
        self._m_replans.inc()
        self.current_plan = plan
        if cache_fixed is None:
            cache_fixed = self._cap(cache_plan, plan)
        self.current_cache_fixed = cache_fixed
        # rebase the realized-hit-rate baseline: predict what the FRESH cache
        # should save per bag on the recent window, reset the realized feed
        self._pred_saved_per_bag = None
        self._realized_saved = 0.0
        self._realized_bags = 0
        self._m_hit_rate.set(1.0)
        if cache_fixed is not None and self._recent_bags:
            from repro.core.cache_runtime import rewrite_bag
            saved = 0
            bags = list(self._recent_bags)
            for bag in bags:
                b = np.asarray(bag)
                b = b[b >= 0]
                c, r = rewrite_bag(b, cache_fixed.plan)
                saved += len(b) - len(c) - len(r)
            self._pred_saved_per_bag = saved / max(len(bags), 1)
        return PlanUpdate(plan=plan, freq=freq, report=report,
                          cache_plan=cache_plan, cache_fixed=cache_fixed,
                          tier_of_row=tier_of_row,
                          replica_plan=self.build_replica_plan(
                              freq, tier_of_row))

    def force_replan(self, report: DriftReport | None = None) -> PlanUpdate:
        """Replan unconditionally — no drift gate, no hysteresis."""
        freq = self.telemetry.freq_vector()
        plan, cache_plan, tiers = self.build_plan(freq)
        if report is None:
            report = self.detector.check(self.telemetry)
        return self._commit(freq, plan, cache_plan, tiers, report)

    def end_batch(self) -> PlanUpdate | None:
        """Advance the batch clock; on cadence, drift-check and (only if
        drifted) emit a PlanUpdate. Returns None when the plan stands —
        including when hysteresis judges the drifted candidate no better
        than the incumbent on the recent window (skips are counted in
        ``n_skipped_replans``; the detector is NOT rebased on a skip, so a
        later check that the incumbent really does lose still trips)."""
        self._batches += 1
        early = self._early_check
        if not early and self._batches % self.cfg.check_every != 0:
            return None
        self._early_check = False
        report = self.detector.check(self.telemetry)
        self.last_report = report
        self._m_checks.inc()
        if not report.drifted:
            return None
        self._m_drifted.inc()
        if self.cfg.hysteresis > 0.0 and self.current_plan is not None:
            freq = self.telemetry.freq_vector()
            plan, cache_plan, tiers = self.build_plan(freq)
            # project in the planner's own currency, not raw row reads:
            #   * quant lane      — freq x bytes under the fresh tier map
            #     (tier is a property of the row, not the plan). Caveat: a
            #     skip also keeps the incumbent TIER map (tiers ship with a
            #     committed PlanUpdate) — acceptable, since a skipped
            #     candidate means the installed byte layout already serves
            #     the window within the margin.
            #   * cache_aware     — replay the recent-bag window through
            #     each (plan, capped cache) pair, so reads the candidate's
            #     cache would absorb count in its favor (needs BOTH sides'
            #     capped plans; falls back to row share when the incumbent
            #     predates the cache lane).
            cache_fixed = self._cap(cache_plan, plan)
            inc_fcp = self.current_cache_fixed
            if cache_fixed is not None and inc_fcp is not None \
                    and self._recent_bags:
                bags = list(self._recent_bags)
                incumbent = self.projected_max_share_cached(
                    self.current_plan, inc_fcp, bags)
                candidate = self.projected_max_share_cached(
                    plan, cache_fixed, bags)
            else:
                proj = freq
                if self.cfg.quant is not None:
                    from repro.quant import bytes_of_tier
                    proj = freq * bytes_of_tier(
                        tiers, self.cfg.quant_dim,
                        self.cfg.quant.hot_dtype).astype(np.float64)
                incumbent = self.projected_max_share(self.current_plan, proj)
                candidate = self.projected_max_share(plan, proj)
            if candidate > incumbent * (1.0 - self.cfg.hysteresis):
                self.n_skipped_replans += 1
                self._m_skips.inc()
                return None
            return self._commit(freq, plan, cache_plan, tiers, report,
                                cache_fixed=cache_fixed)
        return self.force_replan(report)
