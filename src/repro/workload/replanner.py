"""Background replanning: live counters -> fresh §3 partition plan.

The decision loop (README.md):

    every ``check_every`` batches:
        report = DriftDetector.check(telemetry)       # vs plan-time freqs
        if report.drifted:
            freq = telemetry.freq_vector()
            plan = non_uniform_partition(freq, ...)   # or cache-aware
            (cache plan remined + cache table rebuilt when cache-aware)
            -> PlanUpdate for the runtime to migrate + swap

The replanner itself is host-side and cheap (the greedy partitioners are
O(V log B)); the expensive part — moving rows — is migrate.py's job, and
WHETHER to pay it is exactly what the drift detector gates.

``capacity_rows`` should be the serving table's fixed per-bank capacity so
every plan the replanner emits fits the already-allocated packed array
(shape-stable swaps; see migrate.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.core.cache_runtime import (FixedCachePlan, cap_cache_plan,
                                      entry_banks)
from repro.core.grace import CachePlan, mine_cooccurrence
from repro.core.partitioning import (PartitionPlan, cache_aware_partition,
                                     non_uniform_partition)
from repro.workload.telemetry import DriftDetector, DriftReport, TableTelemetry


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    n_banks: int
    partitioner: str = "non_uniform"       # 'non_uniform' | 'cache_aware'
    capacity_rows: int | None = None       # per-bank row budget (fixed shape)
    check_every: int = 20                  # batches between drift checks
    topk: int = 256                        # hot-set size for the Jaccard test
    min_jaccard: float = 0.5
    max_weighted_l1: float = 0.5
    min_observations: int = 2000
    # past this vocab the drift check runs on the top-K UNION instead of
    # materializing a (vocab,) estimate per check (telemetry.DriftDetector)
    drift_sparse_above: int = 10_000_000
    # telemetry exponential window (TableTelemetry): < 1.0 multiplies all
    # counters by ``telemetry_decay`` every ``telemetry_decay_every`` observed
    # ids. Without it the freq estimate is CUMULATIVE since process start, so
    # a long-lived server's detector goes blind to late drift (the reference
    # rebases onto an average the new regime barely moves) and replans keep
    # re-installing history's plan. Serving loops should set it.
    telemetry_decay: float = 1.0
    telemetry_decay_every: int = 100_000
    # cache-aware only: GRACE re-mining window + knobs
    mine_window: int = 512                 # recent bags kept for re-mining
    mine_top_items: int = 2048
    mine_max_groups: int = 256
    mine_min_support: int = 3
    # cache-aware serving: fixed per-bank cache-entry budget. When set, every
    # PlanUpdate carries ``cache_fixed`` — the re-mined plan padded/truncated
    # to n_banks * cache_rows_per_bank entry positions, so the swapped-in
    # cache table always has the shape the serve jit was compiled for.
    cache_rows_per_bank: int | None = None

    @classmethod
    def for_vocab(cls, vocab: int, n_banks: int, **overrides) -> "ReplanConfig":
        """Defaults scaled to the table size: the hot-set Jaccard needs a k
        well under the vocab (k=vocab makes it identically 1.0), and the
        detector should not arm before ~a few observations per hot row."""
        scaled = dict(
            topk=max(16, min(256, vocab // 8)),
            min_observations=max(256, min(2000, 4 * vocab)),
        )
        scaled.update(overrides)
        return cls(n_banks=n_banks, **scaled)


@dataclasses.dataclass
class PlanUpdate:
    plan: PartitionPlan
    freq: np.ndarray                       # frequencies the plan was built on
    report: DriftReport
    cache_plan: CachePlan | None = None    # cache-aware: remined groups
    # remined plan at the FIXED serving capacity (cache_rows_per_bank set):
    # what the runtime actually swaps into the rewriter + cache table
    cache_fixed: FixedCachePlan | None = None


class Replanner:
    """Owns the telemetry + drift detector + replan policy for ONE table
    (DLRM's union-vocab super-table counts as one)."""

    def __init__(self, cfg: ReplanConfig, vocab: int, *,
                 init_freq: np.ndarray | None = None,
                 telemetry: TableTelemetry | None = None):
        self.cfg = cfg
        self.vocab = vocab
        self.telemetry = telemetry or TableTelemetry(
            vocab, decay=cfg.telemetry_decay,
            decay_every=cfg.telemetry_decay_every)
        if init_freq is None:
            init_freq = np.ones(vocab, dtype=np.float64)
        self.detector = DriftDetector(
            init_freq, k=cfg.topk, min_jaccard=cfg.min_jaccard,
            max_weighted_l1=cfg.max_weighted_l1,
            min_observations=cfg.min_observations,
            sparse_above=cfg.drift_sparse_above)
        self._recent_bags: deque[np.ndarray] = deque(maxlen=cfg.mine_window)
        self._batches = 0
        self.n_replans = 0
        self.last_report: DriftReport | None = None

    # -- feeding ------------------------------------------------------------

    def observe_rows(self, rows: np.ndarray) -> None:
        """Union-vocab row ids from one serve/train batch (any shape,
        negatives = padding)."""
        self.telemetry.observe(rows)

    def observe_bags(self, bags: list[np.ndarray]) -> None:
        """Bag-granular feed — also retained for cache re-mining."""
        for bag in bags:
            self.telemetry.observe(bag)
            self._recent_bags.append(np.asarray(bag))

    # -- planning -----------------------------------------------------------

    def build_plan(self, freq: np.ndarray
                   ) -> tuple[PartitionPlan, CachePlan | None]:
        cfg = self.cfg
        if cfg.partitioner == "non_uniform":
            return non_uniform_partition(
                freq, cfg.n_banks, capacity_rows=cfg.capacity_rows), None
        if cfg.partitioner == "cache_aware":
            if not self._recent_bags:
                raise ValueError("cache_aware replanning needs observe_bags() "
                                 "traffic to re-mine co-occurrence groups")
            cp = mine_cooccurrence(
                list(self._recent_bags), top_items=cfg.mine_top_items,
                max_groups=cfg.mine_max_groups,
                min_support=cfg.mine_min_support)
            plan = cache_aware_partition(
                freq, cp.groups, cp.benefits, cfg.n_banks,
                emt_capacity_rows=cfg.capacity_rows)
            return plan, cp
        raise ValueError(f"unknown partitioner {cfg.partitioner!r}")

    def force_replan(self, report: DriftReport | None = None) -> PlanUpdate:
        freq = self.telemetry.freq_vector()
        plan, cache_plan = self.build_plan(freq)
        if report is None:
            report = self.detector.check(self.telemetry)
        self.detector.rebase(freq)
        self.n_replans += 1
        cache_fixed = None
        if cache_plan is not None and self.cfg.cache_rows_per_bank is not None:
            cache_fixed = cap_cache_plan(
                cache_plan,
                entry_banks(cache_plan, plan.bank_of_row,
                            plan.cache_bank_of_entry),
                self.cfg.n_banks, self.cfg.cache_rows_per_bank)
        return PlanUpdate(plan=plan, freq=freq, report=report,
                          cache_plan=cache_plan, cache_fixed=cache_fixed)

    def end_batch(self) -> PlanUpdate | None:
        """Advance the batch clock; on cadence, drift-check and (only if
        drifted) emit a PlanUpdate. Returns None when the plan stands."""
        self._batches += 1
        if self._batches % self.cfg.check_every != 0:
            return None
        report = self.detector.check(self.telemetry)
        self.last_report = report
        if not report.drifted:
            return None
        return self.force_replan(report)
