"""AdaptiveEmbeddingRuntime: the closed loop, packaged for the serve/train CLIs.

Glues the subsystem together around one banked table:

    observe_batch(rows)  ->  telemetry                       (every batch)
    end_batch()          ->  drift check -> replan -> MIGRATE -> atomic swap
                                                             (on cadence)

The swap is atomic with respect to the serving loop because it happens on the
host between micro-batches: the jitted step reads (packed, remap_bank,
remap_slot) as ARGUMENTS (never closure constants), and the runtime replaces
all three references at once. Shapes never change — the table keeps its
initial ``rows_per_bank`` capacity across plans — so a swap costs zero
recompiles.

With ``cache_rows_per_bank`` set, the GRACE cache side swaps under the same
contract: a cache-aware replan carries its re-mined plan at the FIXED entry
capacity (``PlanUpdate.cache_fixed``), the runtime re-sums the surviving
entries from the migrated table's CURRENT row values into a fixed-shape
banked cache table, and publishes (rewrite plan, cache table) atomically
through a ``VersionedCacheRewriter`` — the serve loop rewrites each batch
against the current plan and resolves it against the table version it was
rewritten for, so batches in flight across a swap never mix entry numberings.
The swapped state is bit-identical to tearing the cache path down and
rebuilding it from scratch at the same plan (tests/test_workload.py).

For training, ``migrate_aux`` applies the same row permutation to any
packed-row-aligned extra (the row-wise Adagrad accumulator), keeping the
optimizer's per-row history attached to its row through a migration.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.embedding import BankedTable, DistCtx, pack_table
from repro.core.cache_runtime import (FixedCachePlan, RewrittenBatch,
                                      VersionedCacheRewriter,
                                      build_cache_table,
                                      build_cache_table_fixed, cap_cache_plan,
                                      empty_cache_plan, entry_member_union)
from repro.core.partitioning import PartitionPlan
from repro.obs import NULL_TRACER, MetricRegistry
from repro.workload.migrate import (migrate_replicated, migrate_rowwise_state,
                                    migrate_table)
from repro.workload.replanner import PlanUpdate, ReplanConfig, Replanner


def unpacked_rows(t: BankedTable) -> np.ndarray:
    """(vocab, dim) row values in union-vocab order, gathered host-side from
    the packed layout (the source for cache-entry re-summing)."""
    flat = (np.asarray(t.remap_bank, np.int64) * t.rows_per_bank
            + np.asarray(t.remap_slot))
    return np.asarray(t.packed)[flat]


@dataclasses.dataclass
class SwapEvent:
    """What a completed replan+migration looked like (for logs/benches)."""

    batch: int
    update: PlanUpdate
    old_imbalance: float
    new_imbalance: float
    cache_version: int | None = None    # rewriter version installed (if any)
    cache_entries: int = 0              # live entries in the swapped table
    cache_dropped: int = 0              # mined entries truncated to residual
    tier_version: int | None = None     # tiered lane: version installed
    tier_promoted: int = 0              # rows moved to a MORE precise tier
    tier_demoted: int = 0               # rows moved to a LESS precise tier
    tier_requantized: int = 0           # rows whose payload was rebuilt
    replica_version: int | None = None  # replica lane: version installed
    replica_hot_rows: int = 0           # rows holding > 1 copy in the new plan
    replica_copy_churn: int = 0         # rows whose copy count changed
    # what triggered the swap: "drift" (detector cadence), "bank_failure"
    # (recovery re-pack off dead banks), "straggler" (penalty-driven shed)
    reason: str = "drift"
    # bank_failure only: wall-clock seconds from failure handling entry to
    # the recovered table being live (replan + migrate + swap)
    recovery_s: float | None = None


class AdaptiveEmbeddingRuntime:
    def __init__(self, table: BankedTable, plan: PartitionPlan,
                 cfg: ReplanConfig, *, dist: DistCtx | None = None,
                 init_freq: np.ndarray | None = None,
                 on_swap: Callable[[SwapEvent], None] | None = None,
                 max_cache_per_bag: int = 4,
                 max_residual_per_bag: int = 16,
                 cache_keep: int = 2, tier_keep: int = 2,
                 replica_keep: int = 2,
                 tracer=None, metrics: MetricRegistry | None = None):
        if cfg.capacity_rows is not None \
                and cfg.capacity_rows != table.rows_per_bank:
            raise ValueError(
                f"capacity_rows {cfg.capacity_rows} != table rows_per_bank "
                f"{table.rows_per_bank}: shape-stable swaps need them equal")
        self.table = table
        self.plan = plan
        self.dist = dist
        self.on_swap = on_swap
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricRegistry()
        # pre-register every metric this runtime can emit so a run where a
        # lane never fires still exports its counters at 0 (the snapshot's
        # key-path schema must not depend on which events happened)
        m = self.metrics
        self._m_swaps = m.counter("runtime.swaps_total",
                                  "completed replan+migrate+swap cycles")
        self._m_swaps_by = {r: m.counter(f"runtime.swaps_{r}_total",
                                         f"swaps triggered by {r}")
                            for r in ("drift", "bank_failure", "straggler")}
        self._m_migrate_ms = m.histogram("runtime.migrate_ms",
                                         "host migrate_table wall time")
        self._m_recovery_ms = m.histogram(
            "runtime.recovery_ms",
            "bank-failure handled -> recovered table live")
        self._m_imbalance = m.gauge("runtime.plan_imbalance",
                                    "imbalance of the live plan")
        self._m_cache_version = m.gauge("runtime.cache_version")
        self._m_cache_entries = m.gauge("runtime.cache_entries",
                                        "live entries in the swapped cache")
        self._m_cache_dropped = m.counter("runtime.cache_dropped_total",
                                          "mined entries truncated away")
        self._m_tier_version = m.gauge("runtime.tier_version")
        self._m_tier_promoted = m.counter("runtime.tier_promoted_total")
        self._m_tier_demoted = m.counter("runtime.tier_demoted_total")
        self._m_tier_requant = m.counter("runtime.tier_requantized_total")
        self._m_replica_version = m.gauge("runtime.replica_version")
        self._m_replica_hot = m.gauge("runtime.replica_hot_rows",
                                      "rows holding > 1 copy in the live plan")
        self._m_replica_churn = m.counter(
            "runtime.replica_copy_churn_total",
            "rows whose copy count changed across swaps")
        self.replanner = Replanner(cfg, table.vocab, init_freq=init_freq,
                                   init_plan=plan, metrics=self.metrics)
        self._m_imbalance.set(plan.imbalance())
        self.swaps: list[SwapEvent] = []
        self._batch = 0
        # cache-aware serving: a versioned rewriter starts at version 0 with
        # an EMPTY plan (all-residual) at the fixed capacity, so the serve
        # step compiles once against the final shapes before any swap
        self.rewriter: VersionedCacheRewriter | None = None
        if cfg.cache_rows_per_bank is not None:
            self.rewriter = VersionedCacheRewriter(
                max_cache_per_bag=max_cache_per_bag,
                max_residual_per_bag=max_residual_per_bag, keep=cache_keep)
            self._install_cache(self._empty_cache_fixed())
        # tiered-precision lane (repro.quant): version 0 is quantized from
        # the initial frequencies; every replan re-tiers through the same
        # swap (promoted/demoted rows re-quantized from CURRENT fp values).
        # Same fixed-shape contract as the cache lane: payload/scale/tier
        # shapes depend only on (capacity, dim), so tier swaps feed
        # same-shape arrays to one compiled serve step.
        self.tier_version: int | None = None
        self._tier_keep = int(tier_keep)
        self._tier_states: dict[int, object] = {}
        if cfg.quant is not None:
            if cfg.quant_dim != table.dim:
                raise ValueError(
                    f"quant_dim {cfg.quant_dim} != table dim {table.dim}")
            from repro.quant import assign_tiers, build_tiered_table
            freq0 = init_freq if init_freq is not None \
                else np.ones(table.vocab)
            ta = assign_tiers(freq0, cfg.quant, cfg.quant_dim)
            self.tier_version = 0
            self._tier_states[0] = build_tiered_table(
                table, ta.tier_of_row, hot_dtype=cfg.quant.hot_dtype)
        # hot-row replication lane: version 0 comes from the initial
        # frequencies (an uninformative all-ones prior replicates nothing —
        # bit-identical to single-copy serving until telemetry finds a head).
        # Same fixed-shape contract again: the (vocab, k_max) maps and the
        # n_banks * rows_per_bank packed array never change shape, so
        # replica-count swaps feed same-shape arguments to one compiled step.
        self.replica_version: int | None = None
        self._replica_keep = int(replica_keep)
        self._replica_states: dict[int, tuple[object, object]] = {}
        if cfg.replicate_k_max > 1:
            freq0 = init_freq if init_freq is not None \
                else np.ones(table.vocab)
            rplan0 = self.replanner.build_replica_plan(freq0)
            rtable0 = migrate_replicated(table, rplan0,
                                         rows_per_bank=table.rows_per_bank)
            self.replica_version = 0
            self._replica_states[0] = (rplan0, rtable0)
            self._m_replica_version.set(0)
            self._m_replica_hot.set(rplan0.n_replicated)

    def _empty_cache_fixed(self) -> FixedCachePlan:
        cfg = self.replanner.cfg
        empty = empty_cache_plan()
        return cap_cache_plan(empty, np.zeros(0, np.int32), cfg.n_banks,
                              cfg.cache_rows_per_bank)

    def _install_cache(self, fcp: FixedCachePlan) -> int:
        # re-sum from ONLY the entry-member rows (a device gather of a few
        # hundred rows) — never the (vocab, dim) unpack, which at full scale
        # would be a multi-GB host copy between micro-batches
        import jax.numpy as jnp
        t = self.table
        members = entry_member_union(fcp)
        flat = (self.plan.bank_of_row.astype(np.int64)[members]
                * t.rows_per_bank
                + self.plan.slot_of_row[members])
        rows = np.asarray(jnp.take(t.packed, jnp.asarray(flat), axis=0))
        table = build_cache_table_fixed(rows, fcp, dtype=rows.dtype,
                                        row_ids=members)
        return self.rewriter.install(fcp, table)

    # -- per-batch hooks ----------------------------------------------------

    def observe_batch(self, rows: np.ndarray) -> None:
        """Union-vocab row ids actually looked up this batch (padding < 0)."""
        self.replanner.observe_rows(np.asarray(rows))

    def observe_bags(self, bags: list[np.ndarray]) -> None:
        self.replanner.observe_bags(bags)

    def end_batch(self) -> SwapEvent | None:
        """Advance the clock; migrate + swap if the replanner fired."""
        self._batch += 1
        update = self.replanner.end_batch()
        if update is None:
            return None
        return self.apply(update)

    # -- migration + swap ---------------------------------------------------

    def apply(self, update: PlanUpdate, *, reason: str = "drift") -> SwapEvent:
        import time
        with self.tracer.span("migrate", reason=reason):
            t0 = time.perf_counter()
            new_table = migrate_table(self.table, update.plan, self.dist,
                                      rows_per_bank=self.table.rows_per_bank)
            self._m_migrate_ms.observe((time.perf_counter() - t0) * 1e3)
        return self.apply_migrated(update, new_table, reason=reason)

    def apply_migrated(self, update: PlanUpdate, new_table: BankedTable, *,
                       reason: str = "drift") -> SwapEvent:
        """Swap in a table the CALLER already migrated under ``update.plan``
        (the train loop migrates params + optimizer state together through
        ``migrate_packed_leaves`` and hands the resulting table here); the
        cache and tier lanes still swap versioned through this runtime."""
        with self.tracer.span("swap", reason=reason):
            event = self._apply_migrated(update, new_table, reason)
        self._m_swaps.inc()
        if reason in self._m_swaps_by:
            self._m_swaps_by[reason].inc()
        self._m_imbalance.set(event.new_imbalance)
        if event.cache_version is not None:
            self._m_cache_version.set(event.cache_version)
            self._m_cache_entries.set(event.cache_entries)
            self._m_cache_dropped.inc(event.cache_dropped)
        if event.tier_version is not None:
            self._m_tier_version.set(event.tier_version)
            self._m_tier_promoted.inc(event.tier_promoted)
            self._m_tier_demoted.inc(event.tier_demoted)
            self._m_tier_requant.inc(event.tier_requantized)
        if event.replica_version is not None:
            self._m_replica_version.set(event.replica_version)
            self._m_replica_hot.set(event.replica_hot_rows)
            self._m_replica_churn.inc(event.replica_copy_churn)
        self.tracer.instant("swap_live", batch=event.batch, reason=reason)
        if self.on_swap is not None:
            self.on_swap(event)
        return event

    def _apply_migrated(self, update: PlanUpdate, new_table: BankedTable,
                        reason: str) -> SwapEvent:
        old_imb = self._realized_imbalance(self.plan, update.freq)
        prev_tiered = self._tier_states.get(self.tier_version) \
            if self.tier_version is not None else None
        prev_replica = self._replica_states.get(self.replica_version) \
            if self.replica_version is not None else None
        # callers that drive the replanner directly (the cache-aware train
        # loop) advance its clock but not ours — sync so SwapEvent.batch
        # records when the swap actually happened in either driving mode
        self._batch = max(self._batch, self.replanner._batches)
        event = SwapEvent(batch=self._batch, update=update,
                          old_imbalance=old_imb,
                          new_imbalance=update.plan.imbalance(),
                          reason=reason)
        # the swap: one host-side rebind of all plan-coupled references —
        # in-flight micro-batches already captured the old arrays, the next
        # micro-batch picks up the new ones
        self.table = new_table
        self.plan = update.plan
        self.replanner.current_plan = update.plan
        if self.rewriter is not None:
            # cache lane of the same swap: re-sum the surviving entries from
            # the migrated table's row values and publish (rewrite plan,
            # cache table) as one new version. Non-cache-aware replans (or a
            # mined plan that fit nothing) install the empty plan — stale
            # entry sums must never outlive the plan they were mined under.
            fcp = update.cache_fixed if update.cache_fixed is not None \
                else self._empty_cache_fixed()
            event.cache_version = self._install_cache(fcp)
            event.cache_entries = fcp.n_entries
            event.cache_dropped = fcp.n_dropped
        if self.tier_version is not None:
            # tiered lane: re-tier on the frequencies the plan was built
            # from — hot rows promoted on drift re-read their fp bytes,
            # demoted rows re-quantize from the migrated CURRENT values;
            # stay-tier rows carry their payload through the permutation
            # (bit-identical to a from-scratch rebuild, tests pin it)
            from repro.quant import assign_tiers, retier_tiered
            cfg = self.replanner.cfg
            tiers = update.tier_of_row
            if tiers is None:
                tiers = assign_tiers(update.freq, cfg.quant,
                                     cfg.quant_dim).tier_of_row
            tiered, stats = retier_tiered(prev_tiered, self.table, tiers)
            self.tier_version += 1
            self._tier_states[self.tier_version] = tiered
            for v in [v for v in self._tier_states
                      if v <= self.tier_version - self._tier_keep]:
                del self._tier_states[v]
            event.tier_version = self.tier_version
            event.tier_promoted = stats["n_promoted"]
            event.tier_demoted = stats["n_demoted"]
            event.tier_requantized = stats["n_requantized"]
        if self.replica_version is not None:
            # replica lane: rebuild the replicated side table from the
            # MIGRATED base (every copy of a row reads the same post-migration
            # value — bit-identical to packing from scratch, tests pin it),
            # under the plan the replanner attached; recovery/straggler
            # replans that bypassed _commit recompute it here so the replica
            # layout always reflects the same freq + bank-health state as the
            # base plan it rides with
            rplan = update.replica_plan
            if rplan is None:
                rplan = self.replanner.build_replica_plan(
                    update.freq, update.tier_of_row)
            rtable = migrate_replicated(self.table, rplan,
                                        rows_per_bank=self.table.rows_per_bank)
            self.replica_version += 1
            self._replica_states[self.replica_version] = (rplan, rtable)
            for v in [v for v in self._replica_states
                      if v <= self.replica_version - self._replica_keep]:
                del self._replica_states[v]
            event.replica_version = self.replica_version
            event.replica_hot_rows = rplan.n_replicated
            prev_plan = prev_replica[0] if prev_replica is not None else None
            event.replica_copy_churn = int(
                (prev_plan.copies != rplan.copies).sum()
            ) if prev_plan is not None else rplan.n_replicated
        self.swaps.append(event)
        return event

    # -- fault recovery ------------------------------------------------------

    def on_bank_failure(self, live_mask: np.ndarray) -> SwapEvent:
        """Recovery lane: a bank (or banks) died — re-pack their rows onto
        the survivors NOW, through the ordinary versioned migrate/swap
        machinery (no drift gate, no hysteresis). The migration gathers every
        row from the OLD table's positions — in simulation those bytes are
        still addressable, standing in for the host master table a real
        deployment would re-pack from (the dead bank's MRAM contents are
        gone; its rows' authoritative values are not).

        Call AFTER the serve loop has switched to the degraded ``bank_live``
        argument (reads stay boundedly degraded while this runs). Returns the
        SwapEvent with ``reason="bank_failure"`` and the measured
        ``recovery_s`` (failure handled -> recovered table live).
        """
        import time
        with self.tracer.span("recovery",
                              dead=int((~np.asarray(live_mask)).sum())):
            t0 = time.monotonic()
            self.replanner.set_bank_health(live_mask)
            update = self.replanner.force_replan()
            event = self.apply(update, reason="bank_failure")
            event.recovery_s = time.monotonic() - t0
        self._m_recovery_ms.observe(event.recovery_s * 1e3)
        return event

    def on_straggler(self, penalty: np.ndarray) -> SwapEvent:
        """Straggler lane: feed per-bank latency penalties (1.0 = nominal,
        k = observed k-times slower) into the planner's load model and
        re-pack immediately — slow banks shed load like hot ones do."""
        self.replanner.set_bank_penalty(penalty)
        update = self.replanner.force_replan()
        return self.apply(update, reason="straggler")

    def on_slo_breach(self, penalty: np.ndarray) -> None:
        """SLO-watchdog lane (obs/slo.py): the MEASURED per-bank traffic
        breached an objective. Unlike ``on_straggler`` this does NOT migrate
        immediately — it folds the hot-bank penalty into the planner's
        bank-cost model and arms an early drift check, so the next check
        replans under the measured costs only if the detector agrees the
        traffic actually moved. A breach caused by a transient spike costs
        one extra drift check, not a migration."""
        self.tracer.instant("slo_penalty", batch=self._batch)
        self.replanner.apply_slo_penalty(penalty)

    # -- tiered-precision lane accessors ------------------------------------

    @property
    def tiered(self):
        """The CURRENT TieredTable (quant lane on)."""
        if self.tier_version is None:
            raise ValueError("tiered lane disabled: set ReplanConfig.quant")
        return self._tier_states[self.tier_version]

    def tiered_for(self, version: int):
        """The TieredTable of a still-retained version (mirrors the cache
        lane's ``table_for`` for pipelines deeper than one micro-batch)."""
        try:
            return self._tier_states[version]
        except KeyError:
            raise KeyError(
                f"tier version {version} retired (retained: "
                f"{sorted(self._tier_states)}); raise tier_keep="
            ) from None

    # -- replica lane accessors ----------------------------------------------

    @property
    def replicated(self):
        """The CURRENT (ReplicatedPlan, ReplicatedTable) pair (replica lane
        on). The table's flattened maps + packed array are what the serve
        step takes as arguments; the plan carries copies/load for stats."""
        if self.replica_version is None:
            raise ValueError("replica lane disabled: set "
                             "ReplanConfig.replicate_k_max > 1")
        return self._replica_states[self.replica_version]

    def replicated_for(self, version: int):
        """The (plan, table) pair of a still-retained replica version
        (mirrors ``tiered_for`` for pipelines deeper than one micro-batch)."""
        try:
            return self._replica_states[version]
        except KeyError:
            raise KeyError(
                f"replica version {version} retired (retained: "
                f"{sorted(self._replica_states)}); raise replica_keep="
            ) from None

    def refresh_cache(self) -> int:
        """Re-sum the CURRENT cache plan's entries from the table's current
        row values and publish them as a new rewriter version — the train
        loop's staleness refresh (trained EMT rows drift away from the
        partial sums), without a teardown or re-jit."""
        if self.rewriter is None:
            raise ValueError("cache side disabled: set "
                             "ReplanConfig.cache_rows_per_bank")
        return self._install_cache(self.rewriter.current[0])

    # -- cache-aware serving hooks (rewriter passthroughs) ------------------

    def rewrite(self, union_idx: np.ndarray) -> RewrittenBatch:
        """Host pipeline stage: rewrite a (..., L) union-vocab id batch
        against the CURRENT cache plan; the result is version-tagged.

        Also feeds the replanner's realized-hit-rate estimate: a bag of u
        unique rows rewritten to c entries + r residuals saved ``u - c - r``
        reads — the next re-mine discounts the miner's predicted benefits
        by realized/predicted, so an over-promising cache stops distorting
        the bank packing."""
        if self.rewriter is None:
            raise ValueError("cache side disabled: set "
                             "ReplanConfig.cache_rows_per_bank")
        rb = self.rewriter.rewrite_rect(union_idx)
        flat = np.asarray(union_idx).reshape(-1, union_idx.shape[-1])
        uniq = sum(len(np.unique(row[row >= 0])) for row in flat)
        used = int((rb.cache_idx >= 0).sum() + (rb.residual_idx >= 0).sum())
        self.replanner.observe_cache_hits(uniq - used, flat.shape[0])
        return rb

    def cache_table_for(self, version: int) -> BankedTable:
        """The cache table a version-tagged batch must be served against."""
        return self.rewriter.table_for(version)

    @property
    def cache_table(self) -> BankedTable:
        return self.rewriter.current[1]

    @property
    def cache_plan(self) -> FixedCachePlan:
        return self.rewriter.current[0]

    def migrate_aux(self, arr, update_or_plan) -> "np.ndarray":
        """Permute a packed-row-aligned array (optimizer state) to match a
        plan that apply() is about to install. Call BEFORE apply() — it needs
        the pre-swap remap still on self.table."""
        plan = update_or_plan.plan if isinstance(update_or_plan, PlanUpdate) \
            else update_or_plan
        return migrate_rowwise_state(arr, self.table, plan,
                                     rows_per_bank=self.table.rows_per_bank)

    def rebuild_cache_table(self, update: PlanUpdate,
                            dtype=None) -> BankedTable | None:
        """Cache-aware replans: rebuild the GRACE partial-sum table under the
        new plan (entries re-summed from the CURRENT row values, placed on
        the banks Algorithm 1 chose)."""
        if update.cache_plan is None:
            return None
        # unpack current rows host-side (the cache table is tiny; its source
        # rows are a gather over the members only)
        t = self.table
        cache_np = build_cache_table(unpacked_rows(t), update.cache_plan)
        plan = update.plan
        if plan.cache_bank_of_entry is None:
            from repro.core.partitioning import uniform_partition
            cplan = uniform_partition(cache_np.shape[0], t.n_banks)
        else:
            cplan = _cache_side_plan(plan, update.cache_plan, t.n_banks)
        return pack_table(cache_np, cplan, dtype=dtype)

    @staticmethod
    def _realized_imbalance(plan: PartitionPlan, freq: np.ndarray) -> float:
        """max/mean of the CURRENT traffic under the (possibly stale) plan —
        what the old plan actually costs, as opposed to plan.imbalance()
        which scores it against its own build-time frequencies."""
        loads = np.zeros(plan.n_banks)
        np.add.at(loads, plan.bank_of_row, freq)
        mean = loads.mean()
        return float(loads.max() / mean) if mean > 0 else 1.0


def _cache_side_plan(plan: PartitionPlan, cache_plan, n_banks: int
                     ) -> PartitionPlan:
    """Entry -> (bank, slot) for the partial-sum table: every subset entry
    lives on its mined group's bank (Algorithm 1's co-location invariant);
    groups that overflowed the cache fall back to bank of member 0."""
    n_entries = max(cache_plan.n_entries, 1)
    bank = np.zeros(n_entries, dtype=np.int32)
    for eid, entry in enumerate(cache_plan.entries):
        g = _group_of(cache_plan, eid)
        b = int(plan.cache_bank_of_entry[g]) if g is not None else -1
        bank[eid] = b if b >= 0 else int(plan.bank_of_row[entry.members[0]])
    slot = np.zeros(n_entries, dtype=np.int32)
    rows_per_bank = np.zeros(n_banks, dtype=np.int32)
    for e in range(n_entries):
        slot[e] = rows_per_bank[bank[e]]
        rows_per_bank[bank[e]] += 1
    freq = np.array([e.hits for e in cache_plan.entries], np.float64) \
        if cache_plan.entries else np.zeros(1)
    load = np.zeros(n_banks)
    np.add.at(load, bank, freq[:n_entries])
    return PartitionPlan(n_banks=n_banks, bank_of_row=bank, slot_of_row=slot,
                         rows_per_bank=rows_per_bank, load_per_bank=load)


def _group_of(cache_plan, entry_id: int) -> int | None:
    members = set(cache_plan.entries[entry_id].members)
    for g, grp in enumerate(cache_plan.groups):
        if members <= set(int(x) for x in grp):
            return g
    return None
