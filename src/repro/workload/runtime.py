"""AdaptiveEmbeddingRuntime: the closed loop, packaged for the serve/train CLIs.

Glues the subsystem together around one banked table:

    observe_batch(rows)  ->  telemetry                       (every batch)
    end_batch()          ->  drift check -> replan -> MIGRATE -> atomic swap
                                                             (on cadence)

The swap is atomic with respect to the serving loop because it happens on the
host between micro-batches: the jitted step reads (packed, remap_bank,
remap_slot) as ARGUMENTS (never closure constants), and the runtime replaces
all three references at once. Shapes never change — the table keeps its
initial ``rows_per_bank`` capacity across plans — so a swap costs zero
recompiles.

For training, ``migrate_aux`` applies the same row permutation to any
packed-row-aligned extra (the row-wise Adagrad accumulator), keeping the
optimizer's per-row history attached to its row through a migration.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.embedding import BankedTable, DistCtx, pack_table
from repro.core.cache_runtime import build_cache_table
from repro.core.partitioning import PartitionPlan
from repro.workload.migrate import migrate_rowwise_state, migrate_table
from repro.workload.replanner import PlanUpdate, ReplanConfig, Replanner


@dataclasses.dataclass
class SwapEvent:
    """What a completed replan+migration looked like (for logs/benches)."""

    batch: int
    update: PlanUpdate
    old_imbalance: float
    new_imbalance: float


class AdaptiveEmbeddingRuntime:
    def __init__(self, table: BankedTable, plan: PartitionPlan,
                 cfg: ReplanConfig, *, dist: DistCtx | None = None,
                 init_freq: np.ndarray | None = None,
                 on_swap: Callable[[SwapEvent], None] | None = None):
        if cfg.capacity_rows is not None \
                and cfg.capacity_rows != table.rows_per_bank:
            raise ValueError(
                f"capacity_rows {cfg.capacity_rows} != table rows_per_bank "
                f"{table.rows_per_bank}: shape-stable swaps need them equal")
        self.table = table
        self.plan = plan
        self.dist = dist
        self.on_swap = on_swap
        self.replanner = Replanner(cfg, table.vocab, init_freq=init_freq)
        self.swaps: list[SwapEvent] = []
        self._batch = 0

    # -- per-batch hooks ----------------------------------------------------

    def observe_batch(self, rows: np.ndarray) -> None:
        """Union-vocab row ids actually looked up this batch (padding < 0)."""
        self.replanner.observe_rows(np.asarray(rows))

    def observe_bags(self, bags: list[np.ndarray]) -> None:
        self.replanner.observe_bags(bags)

    def end_batch(self) -> SwapEvent | None:
        """Advance the clock; migrate + swap if the replanner fired."""
        self._batch += 1
        update = self.replanner.end_batch()
        if update is None:
            return None
        return self.apply(update)

    # -- migration + swap ---------------------------------------------------

    def apply(self, update: PlanUpdate) -> SwapEvent:
        old_imb = self._realized_imbalance(self.plan, update.freq)
        new_table = migrate_table(self.table, update.plan, self.dist,
                                  rows_per_bank=self.table.rows_per_bank)
        event = SwapEvent(batch=self._batch, update=update,
                          old_imbalance=old_imb,
                          new_imbalance=update.plan.imbalance())
        # the swap: one host-side rebind of all plan-coupled references —
        # in-flight micro-batches already captured the old arrays, the next
        # micro-batch picks up the new ones
        self.table = new_table
        self.plan = update.plan
        self.swaps.append(event)
        if self.on_swap is not None:
            self.on_swap(event)
        return event

    def migrate_aux(self, arr, update_or_plan) -> "np.ndarray":
        """Permute a packed-row-aligned array (optimizer state) to match a
        plan that apply() is about to install. Call BEFORE apply() — it needs
        the pre-swap remap still on self.table."""
        plan = update_or_plan.plan if isinstance(update_or_plan, PlanUpdate) \
            else update_or_plan
        return migrate_rowwise_state(arr, self.table, plan,
                                     rows_per_bank=self.table.rows_per_bank)

    def rebuild_cache_table(self, update: PlanUpdate,
                            dtype=None) -> BankedTable | None:
        """Cache-aware replans: rebuild the GRACE partial-sum table under the
        new plan (entries re-summed from the CURRENT row values, placed on
        the banks Algorithm 1 chose)."""
        if update.cache_plan is None:
            return None
        import jax.numpy as jnp
        # unpack current rows host-side (the cache table is tiny; its source
        # rows are a gather over the members only)
        t = self.table
        flat = (np.asarray(t.remap_bank, np.int64) * t.rows_per_bank
                + np.asarray(t.remap_slot))
        packed = np.asarray(t.packed)
        rows = packed[flat]                                   # (V, D)
        cache_np = build_cache_table(rows, update.cache_plan)
        plan = update.plan
        if plan.cache_bank_of_entry is None:
            from repro.core.partitioning import uniform_partition
            cplan = uniform_partition(cache_np.shape[0], t.n_banks)
        else:
            cplan = _cache_side_plan(plan, update.cache_plan, t.n_banks)
        return pack_table(cache_np, cplan, dtype=dtype)

    @staticmethod
    def _realized_imbalance(plan: PartitionPlan, freq: np.ndarray) -> float:
        """max/mean of the CURRENT traffic under the (possibly stale) plan —
        what the old plan actually costs, as opposed to plan.imbalance()
        which scores it against its own build-time frequencies."""
        loads = np.zeros(plan.n_banks)
        np.add.at(loads, plan.bank_of_row, freq)
        mean = loads.mean()
        return float(loads.max() / mean) if mean > 0 else 1.0


def _cache_side_plan(plan: PartitionPlan, cache_plan, n_banks: int
                     ) -> PartitionPlan:
    """Entry -> (bank, slot) for the partial-sum table: every subset entry
    lives on its mined group's bank (Algorithm 1's co-location invariant);
    groups that overflowed the cache fall back to bank of member 0."""
    n_entries = max(cache_plan.n_entries, 1)
    bank = np.zeros(n_entries, dtype=np.int32)
    for eid, entry in enumerate(cache_plan.entries):
        g = _group_of(cache_plan, eid)
        b = int(plan.cache_bank_of_entry[g]) if g is not None else -1
        bank[eid] = b if b >= 0 else int(plan.bank_of_row[entry.members[0]])
    slot = np.zeros(n_entries, dtype=np.int32)
    rows_per_bank = np.zeros(n_banks, dtype=np.int32)
    for e in range(n_entries):
        slot[e] = rows_per_bank[bank[e]]
        rows_per_bank[bank[e]] += 1
    freq = np.array([e.hits for e in cache_plan.entries], np.float64) \
        if cache_plan.entries else np.zeros(1)
    load = np.zeros(n_banks)
    np.add.at(load, bank, freq[:n_entries])
    return PartitionPlan(n_banks=n_banks, bank_of_row=bank, slot_of_row=slot,
                         rows_per_bank=rows_per_bank, load_per_bank=load)


def _group_of(cache_plan, entry_id: int) -> int | None:
    members = set(cache_plan.entries[entry_id].members)
    for g, grp in enumerate(cache_plan.groups):
        if members <= set(int(x) for x in grp):
            return g
    return None
