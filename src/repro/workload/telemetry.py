"""Streaming access-frequency telemetry for embedding tables.

The §3 partitioners take a per-row access-frequency vector as input; in
production that vector is not known ahead of time and drifts. This module is
the measurement half of the adaptive loop (README.md):

  ``TopKCounter``    — space-saving heavy-hitter counter. With a budget at
                       least the number of distinct ids seen it is EXACT; under
                       eviction every stored count overestimates the true count
                       by at most the smallest stored count (Metwally et al.).
                       The hot head is what the non-uniform partitioner cares
                       about, so it gets the precise counts.
  ``CountMinSketch`` — d x w conservative estimate for the full-vocab tail:
                       ``query(i) >= true(i)`` always, and
                       ``query(i) <= true(i) + (e / w) * total`` with
                       probability ``>= 1 - exp(-d)`` (Cormode & Muthukrishnan).
                       8 B/cell; w=4096, d=4 tracks a 33M-row vocab in 128 KB.
  ``TableTelemetry`` — the two stitched together behind ``observe(ids)`` /
                       ``freq_vector()``, with optional exponential decay so
                       old traffic ages out instead of anchoring the plan.
  ``DriftDetector``  — compares the live estimate against the frequencies the
                       ACTIVE plan was built from: top-K Jaccard (did the hot
                       set rotate?) + weighted L1 on normalized frequencies
                       (did the mass move?). Either tripping flags drift.

Host-side numpy throughout — telemetry runs in the pre-processing stage
(paper Fig. 4), next to the cache rewriting, never on device.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

_MERSENNE = (1 << 61) - 1


def rows_from_sparse(sparse: np.ndarray,
                     field_offsets: np.ndarray) -> np.ndarray:
    """DLRM sparse batch -> union-vocab row ids for the telemetry feed.

    ``sparse`` is (B, F) one-hot or (B, F, L) multi-hot per-field ids;
    padding (< 0) stays -1. The serve observer tap and the train loop both
    go through here so their telemetry can never diverge.
    """
    sp = np.asarray(sparse)
    offs = np.asarray(field_offsets, np.int64)
    per_field = sp if sp.ndim == 3 else sp[..., None]
    return np.where(per_field >= 0, per_field + offs[None, :, None], -1)


class CountMinSketch:
    """Conservative frequency sketch over non-negative int ids."""

    def __init__(self, width: int = 4096, depth: int = 4, *, seed: int = 0):
        assert width > 0 and depth > 0
        self.width = int(width)
        self.depth = int(depth)
        rng = np.random.default_rng(seed)
        # pairwise-independent row hashes: h_i(x) = ((a_i x + b_i) mod p) mod w.
        # a, b < 2^31 keeps a*x + b inside int64 for any int32 row id.
        self._a = rng.integers(1, 1 << 31, depth, dtype=np.int64)
        self._b = rng.integers(0, 1 << 31, depth, dtype=np.int64)
        self.table = np.zeros((depth, width), dtype=np.float64)
        self.total = 0.0

    @property
    def epsilon(self) -> float:
        """Overestimate bound as a fraction of total mass: e / width."""
        return float(np.e / self.width)

    def _buckets(self, ids: np.ndarray) -> np.ndarray:
        x = np.asarray(ids, dtype=np.int64)[None, :]
        h = (self._a[:, None] * x + self._b[:, None]) % _MERSENNE
        return (h % self.width).astype(np.int64)       # (depth, n)

    def update(self, ids: np.ndarray, counts: np.ndarray | float = 1.0) -> None:
        ids = np.asarray(ids)
        if ids.size == 0:
            return
        c = np.broadcast_to(np.asarray(counts, np.float64), ids.shape)
        rows = self._buckets(ids)
        for d in range(self.depth):
            np.add.at(self.table[d], rows[d], c)
        self.total += float(c.sum())

    def query(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids)
        if ids.size == 0:
            return np.zeros(0)
        rows = self._buckets(ids.reshape(-1))
        est = self.table[np.arange(self.depth)[:, None], rows].min(axis=0)
        return est.reshape(ids.shape)

    def scale(self, gamma: float) -> None:
        self.table *= gamma
        self.total *= gamma


class TopKCounter:
    """Space-saving heavy hitters: exact while under budget, bounded error
    after (a new id inherits ``min_count + c`` when it evicts the coldest).

    Eviction uses a LAZY min-heap over (count, id): every count change pushes
    a fresh entry; pops discard entries whose count is stale. Amortized
    O(log budget) per novel id — this runs synchronously inside the
    MicroBatcher's observer tap, so a per-eviction O(budget) dict scan would
    bill the telemetry straight onto the serve p99 it exists to protect.
    """

    def __init__(self, budget: int = 4096):
        assert budget > 0
        self.budget = int(budget)
        self.counts: dict[int, float] = {}
        self.evictions = 0
        self._heap: list[tuple[float, int]] = []   # (count-at-push, id)

    def _pop_min(self) -> tuple[int, float]:
        """Current coldest (id, count), discarding stale heap entries."""
        while True:
            cnt, i = heapq.heappop(self._heap)
            if self.counts.get(i) == cnt:
                return i, cnt

    def update(self, ids: np.ndarray, counts: np.ndarray | float = 1.0) -> None:
        ids = np.asarray(ids).reshape(-1)
        if ids.size == 0:
            return
        uniq, inv = np.unique(ids, return_inverse=True)
        c = np.broadcast_to(np.asarray(counts, np.float64),
                            ids.shape).reshape(-1)
        agg = np.zeros(uniq.shape[0])
        np.add.at(agg, inv, c)
        for i, cnt in zip(uniq.tolist(), agg.tolist()):
            cur = self.counts.get(i)
            if cur is not None:
                new = cur + cnt
            elif len(self.counts) < self.budget:
                new = cnt
            else:
                victim, floor = self._pop_min()
                del self.counts[victim]
                new = floor + cnt
                self.evictions += 1
            self.counts[i] = new
            heapq.heappush(self._heap, (new, i))
        # stale entries are normally shed by evictions; when the live set
        # fits the budget (no evictions) they would pile up forever in a
        # long-lived serve process — compact once they dominate
        if len(self._heap) > 2 * len(self.counts) + 64:
            self._compact()

    def _compact(self) -> None:
        self._heap = [(c, i) for i, c in self.counts.items()]
        heapq.heapify(self._heap)

    def topk(self, k: int) -> np.ndarray:
        """Hottest ids, count-descending (ties by id for determinism)."""
        items = sorted(self.counts.items(), key=lambda kv: (-kv[1], kv[0]))
        return np.array([i for i, _ in items[:k]], dtype=np.int64)

    def scale(self, gamma: float) -> None:
        for i in self.counts:
            self.counts[i] *= gamma
        # uniform scaling preserves order but invalidates every pushed count;
        # rebuild the heap from the live dict (also sheds stale duplicates)
        self._compact()


@dataclasses.dataclass
class TableTelemetry:
    """Per-table streaming frequency tracker: exact-ish head + sketched tail.

    ``decay`` < 1.0 turns the counters into an exponential moving window:
    every ``decay_every`` observed ids, all counts are multiplied by
    ``decay`` — the replanner then follows the recent distribution instead of
    the all-time one.
    """

    vocab: int
    topk_budget: int = 4096
    sketch_width: int = 4096
    sketch_depth: int = 4
    decay: float = 1.0
    decay_every: int = 100_000
    seed: int = 0

    def __post_init__(self):
        self.sketch = CountMinSketch(self.sketch_width, self.sketch_depth,
                                     seed=self.seed)
        self.head = TopKCounter(self.topk_budget)
        self.n_observed = 0
        self._since_decay = 0

    def observe(self, ids: np.ndarray) -> None:
        """Record one batch of raw row ids (any shape; negatives = padding)."""
        ids = np.asarray(ids).reshape(-1)
        ids = ids[ids >= 0]
        if ids.size == 0:
            return
        self.sketch.update(ids)
        self.head.update(ids)
        self.n_observed += int(ids.size)
        self._since_decay += int(ids.size)
        if self.decay < 1.0 and self._since_decay >= self.decay_every:
            self.sketch.scale(self.decay)
            self.head.scale(self.decay)
            self._since_decay = 0

    def observe_bags(self, bags: list[np.ndarray]) -> None:
        for bag in bags:
            self.observe(bag)

    def topk(self, k: int) -> np.ndarray:
        return self.head.topk(k)

    def freq_on(self, ids: np.ndarray) -> np.ndarray:
        """Estimated frequencies for just ``ids`` — the sparse counterpart of
        ``freq_vector`` (same estimator: exact head counts override the
        sketch), costing O(len(ids)) instead of O(vocab)."""
        ids = np.asarray(ids, np.int64)
        est = self.sketch.query(ids)
        if self.head.counts:
            flat = est.reshape(-1)
            for j, i in enumerate(ids.reshape(-1).tolist()):
                cnt = self.head.counts.get(int(i))
                if cnt is not None:
                    flat[j] = cnt
        return est

    def freq_vector(self) -> np.ndarray:
        """(vocab,) estimated access frequencies: exact head counts override
        the sketch's (over-)estimate; never-seen rows keep the sketch floor
        (an overestimate, which only pads the partitioner conservatively)."""
        est = self.sketch.query(np.arange(self.vocab, dtype=np.int64))
        if self.head.counts:
            ids = np.fromiter(self.head.counts.keys(), np.int64,
                              len(self.head.counts))
            vals = np.fromiter(self.head.counts.values(), np.float64,
                               len(self.head.counts))
            keep = ids < self.vocab
            est[ids[keep]] = vals[keep]
        return est


@dataclasses.dataclass
class DriftReport:
    topk_jaccard: float
    weighted_l1: float
    drifted: bool
    n_observed: int

    def __str__(self) -> str:  # one-line log form for the launch CLIs
        return (f"drift(jaccard={self.topk_jaccard:.3f} "
                f"wl1={self.weighted_l1:.3f} drifted={self.drifted})")


def topk_jaccard(a: np.ndarray, b: np.ndarray) -> float:
    sa, sb = set(a.tolist()), set(b.tolist())
    if not sa and not sb:
        return 1.0
    return len(sa & sb) / max(len(sa | sb), 1)


def weighted_l1(ref: np.ndarray, cur: np.ndarray) -> float:
    """L1 between the two NORMALIZED frequency vectors, in [0, 2]."""
    rs, cs = ref.sum(), cur.sum()
    if rs <= 0 or cs <= 0:
        return 0.0
    return float(np.abs(ref / rs - cur / cs).sum())


@dataclasses.dataclass
class DriftDetector:
    """Trips when live traffic no longer matches the plan-time frequencies.

    ``reference`` is the freq vector the ACTIVE PartitionPlan was built from
    (not last check's snapshot — slow cumulative drift must still trip).

    Past ``sparse_above`` rows the dense path's per-check cost becomes the
    problem it is meant to prevent (a (vocab,) sketch materialization + an
    O(vocab log vocab) argsort on the serve host, every ``check_every``
    batches): the check switches to the TOP-K-UNION form — the live hot set
    comes straight from the space-saving head (no argsort over the vocab),
    and the weighted L1 runs on the union of the reference and live top-K,
    both renormalized over that union. On a fully-observed vocab with
    ``k >= vocab`` the two paths are numerically identical
    (tests/test_workload.py pins it); on a power-law trace the union carries
    almost all the mass, so the thresholds keep their meaning. Replans
    themselves still materialize (vocab,) — they are drift-gated and rare,
    the checks are the steady-state cost.
    """

    reference: np.ndarray
    k: int = 256
    min_jaccard: float = 0.5
    max_weighted_l1: float = 0.5
    min_observations: int = 1000
    sparse_above: int = 10_000_000

    def __post_init__(self):
        self.reference = np.asarray(self.reference, np.float64)
        self._ref_topk = self._topk_of(self.reference)

    def _topk_of(self, freq: np.ndarray) -> np.ndarray:
        k = min(self.k, freq.shape[0])
        return np.argsort(-freq, kind="stable")[:k]

    def rebase(self, reference: np.ndarray) -> None:
        """Point at the frequencies of a freshly-installed plan."""
        self.reference = np.asarray(reference, np.float64)
        self._ref_topk = self._topk_of(self.reference)

    def check(self, telemetry: TableTelemetry) -> DriftReport:
        if telemetry.vocab > self.sparse_above:
            jac, wl1 = self._check_sparse(telemetry)
        else:
            cur = telemetry.freq_vector()
            jac = topk_jaccard(self._ref_topk, self._topk_of(cur))
            wl1 = weighted_l1(self.reference, cur)
        enough = telemetry.n_observed >= self.min_observations
        drifted = enough and (jac < self.min_jaccard
                              or wl1 > self.max_weighted_l1)
        return DriftReport(topk_jaccard=jac, weighted_l1=wl1,
                           drifted=bool(drifted),
                           n_observed=telemetry.n_observed)

    def _check_sparse(self, telemetry: TableTelemetry) -> tuple[float, float]:
        # the head counter can hold out-of-range ids (observe() filters only
        # negatives) — drop them like freq_vector's keep-guard does, or one
        # corrupt log row would crash every later check via reference[union]
        vocab = self.reference.shape[0]
        cur_topk = telemetry.topk(self.k)
        cur_topk = cur_topk[cur_topk < vocab]
        jac = topk_jaccard(self._ref_topk, cur_topk)
        union = np.union1d(self._ref_topk, cur_topk)
        wl1 = weighted_l1(self.reference[union], telemetry.freq_on(union))
        return jac, wl1
