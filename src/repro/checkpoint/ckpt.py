"""Checkpointing: atomic step-numbered sharded saves, async writer thread,
and ELASTIC restore — including re-partitioning banked embedding tables when
the bank count (mesh) changes between save and restore.

Layout:  <dir>/step_<n>.tmp/ -> fsync -> rename to <dir>/step_<n>/
         one .npy per leaf + tree.json manifest (path, dtype, shape).
Atomic rename means a crash mid-save never corrupts the latest checkpoint —
restore always picks the highest COMPLETE step (fault tolerance).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = [(jax.tree_util.keystr(p), v) for p, v in leaves]
    return named, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    named, _ = _flatten(tree)
    manifest = []
    for i, (path, leaf) in enumerate(named):
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
        manifest.append({"path": path, "index": i,
                         "dtype": str(arr.dtype), "shape": list(arr.shape)})
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "tree.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, target_tree, step: int | None = None):
    """Restore into the STRUCTURE of target_tree (shapes may differ for banked
    tables — use reshard_banked_table afterwards for elastic changes)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "tree.json")) as f:
        manifest = json.load(f)
    by_path = {m["path"]: m for m in manifest["leaves"]}
    named, treedef = _flatten(target_tree)
    out = []
    for path, tgt in named:
        m = by_path.get(path)
        if m is None:
            raise KeyError(f"checkpoint missing leaf {path}")
        arr = np.load(os.path.join(d, f"leaf_{m['index']}.npy"))
        out.append(arr)
    leaves_sorted = jax.tree_util.tree_unflatten(
        treedef, [v for v in out])
    return leaves_sorted, step


class AsyncCheckpointer:
    """Fire-and-forget saves on a writer thread; join() before exit.

    Device->host transfer happens on the caller thread (cheap, and the arrays
    are immutable afterwards); disk IO overlaps the next train steps.
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree) -> None:
        host_tree = jax.tree.map(np.asarray, tree)
        self.join()
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()

    def _write(self, step: int, tree) -> None:
        save_checkpoint(self.ckpt_dir, step, tree)
        self._gc()

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.ckpt_dir))
            if m)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s}"),
                          ignore_errors=True)

    def join(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def reshard_banked_table(packed: np.ndarray, old_plan, new_plan) -> np.ndarray:
    """Elastic re-partition: packed rows under old_plan -> packed under
    new_plan (bank count / balance changed — node failure or scale-out).

    Rows are addressed logically (vocab ids), so the migration is two gathers;
    padding rows are dropped/re-created as needed.
    """
    dim = packed.shape[1]
    old_rows = int(old_plan.max_rows_per_bank)
    new_rows = int(new_plan.max_rows_per_bank)
    vocab = old_plan.vocab
    assert new_plan.vocab == vocab
    flat_old = old_plan.bank_of_row.astype(np.int64) * old_rows \
        + old_plan.slot_of_row
    logical = packed[flat_old]                      # (vocab, dim)
    out = np.zeros((new_plan.n_banks * new_rows, dim), packed.dtype)
    flat_new = new_plan.bank_of_row.astype(np.int64) * new_rows \
        + new_plan.slot_of_row
    out[flat_new] = logical
    return out
