"""Checkpointing: sharded save/restore, async writer, elastic re-partition."""
from repro.checkpoint.ckpt import (
    save_checkpoint,
    restore_checkpoint,
    latest_step,
    AsyncCheckpointer,
    reshard_banked_table,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer", "reshard_banked_table"]
