"""GAT (Veličković et al., arXiv:1710.10903) on segment ops.

Message passing = SDDMM (per-edge attention scores) -> segment-softmax over
in-edges -> SpMM (weighted scatter-sum), all built on jax.ops.segment_* since
JAX has no CSR (kernel_taxonomy §B.3). Distribution shards the EDGE LIST over
every mesh axis with full-size node partials psum'd — the paper's stage-2/3
dataflow; the §3.2 greedy balancer assigns edges by degree (DESIGN.md §4).

Three input forms, one kernel:
  full graph  — edge_src/edge_dst over the whole graph
  sampled     — padded bipartite blocks from sparse/sampler.py
  batched mol — block-diagonal edge index over padded small graphs
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.embedding import DistCtx
from repro.models.common import dense_init, shard
from repro.sparse.ops import segment_softmax

from repro.core.compat import shard_map

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str
    d_feat: int
    n_classes: int
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    dtype: Any = jnp.float32
    neg_slope: float = 0.2

    def param_count(self) -> int:
        n = self.d_feat * self.d_hidden * self.n_heads
        n += 2 * self.n_heads * self.d_hidden
        hid = self.d_hidden * self.n_heads
        for _ in range(self.n_layers - 2):
            n += hid * hid + 2 * hid
        n += hid * self.n_classes + 2 * self.n_classes
        return n


def init_params(cfg: GATConfig, key) -> dict:
    layers = []
    dims_in = [cfg.d_feat] + [cfg.d_hidden * cfg.n_heads] * (cfg.n_layers - 1)
    heads = [cfg.n_heads] * (cfg.n_layers - 1) + [1]
    outs = [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    ks = jax.random.split(key, cfg.n_layers * 3)
    for i in range(cfg.n_layers):
        H, O = heads[i], outs[i]
        layers.append({
            "w": dense_init(ks[3 * i], (dims_in[i], H * O), dtype=cfg.dtype),
            "a_src": dense_init(ks[3 * i + 1], (H, O), dtype=cfg.dtype),
            "a_dst": dense_init(ks[3 * i + 2], (H, O), dtype=cfg.dtype),
        })
    return {"layers": layers}


def gat_layer(lw: dict, h_src: Array, h_dst: Array, edge_src: Array,
              edge_dst: Array, edge_mask: Array, n_dst: int, *, heads: int,
              out: int, neg_slope: float, dist: DistCtx | None,
              final: bool) -> Array:
    """One GAT conv. h_src: (Ns, F) features of message sources; h_dst:
    (Nd, F) of updated nodes; edges are (src local, dst local) with mask."""
    z_src = (h_src @ lw["w"]).reshape(-1, heads, out)
    z_dst = (h_dst @ lw["w"]).reshape(-1, heads, out)
    alpha_src = jnp.einsum("nho,ho->nh", z_src, lw["a_src"])
    alpha_dst = jnp.einsum("nho,ho->nh", z_dst, lw["a_dst"])

    def agg(e_src, e_dst, e_mask):
        # SDDMM: per-edge scores
        s = alpha_src[e_src] + alpha_dst[e_dst]                  # (E, H)
        s = jax.nn.leaky_relu(s, neg_slope)
        s = jnp.where(e_mask[:, None], s, -1e30)
        att = segment_softmax(s, e_dst, n_dst)                   # (E, H)
        att = jnp.where(e_mask[:, None], att, 0.0)
        msg = z_src[e_src] * att[..., None]                      # (E, H, O)
        return jax.ops.segment_sum(msg, e_dst, n_dst)            # (Nd, H, O)

    if dist is None:
        hz = agg(edge_src, edge_dst, edge_mask)
    else:
        # edge-sharded: each shard scatters into a full-size node buffer,
        # partials psum'd. NOTE: segment_softmax is computed per-shard which
        # requires the denominators to combine — so we split it: compute
        # unnormalized exp and normalizers as separate psums.
        P = jax.sharding.PartitionSpec
        axes = tuple(dist.dp_axes) + (dist.bank_axis,)
        ax = axes if len(axes) > 1 else axes[0]

        def fn(e_src, e_dst, e_mask):
            s = alpha_src[e_src] + alpha_dst[e_dst]
            s = jax.nn.leaky_relu(s, neg_slope)
            s = jnp.where(e_mask[:, None], s, -1e30)
            # global segment softmax across shards: max -> exp -> sum. The max
            # is a constant shift (softmax-invariant) => stop_gradient, which
            # also sidesteps pmax's missing differentiation rule.
            m_loc = jax.lax.stop_gradient(jax.ops.segment_max(s, e_dst, n_dst))
            m = jax.lax.pmax(jnp.where(jnp.isfinite(m_loc), m_loc, -1e30),
                             axes)
            ex = jnp.exp(s - m[e_dst])
            ex = jnp.where(e_mask[:, None], ex, 0.0)
            denom = jax.lax.psum(jax.ops.segment_sum(ex, e_dst, n_dst), axes)
            msg = z_src[e_src] * (ex / jnp.maximum(denom[e_dst], 1e-20))[..., None]
            return jax.lax.psum(jax.ops.segment_sum(msg, e_dst, n_dst), axes)

        hz = shard_map(
            fn, mesh=dist.mesh,
            in_specs=(P(ax), P(ax), P(ax)), out_specs=P(),
        )(edge_src, edge_dst, edge_mask)

    if final:
        return hz.mean(axis=1)                                   # (Nd, n_classes)
    return jax.nn.elu(hz.reshape(hz.shape[0], heads * out))


def forward_full(cfg: GATConfig, params: dict, batch: dict,
                 dist: DistCtx | None = None) -> Array:
    """Full-graph forward: features (N, F), edge_src/dst (E,) -> logits (N, C)."""
    h = batch["features"].astype(cfg.dtype)
    e_src, e_dst = batch["edge_src"], batch["edge_dst"]
    e_mask = batch.get("edge_mask", jnp.ones_like(e_src, bool))
    n = h.shape[0]
    for i, lw in enumerate(params["layers"]):
        final = i == cfg.n_layers - 1
        heads = 1 if final else cfg.n_heads
        out = cfg.n_classes if final else cfg.d_hidden
        h = gat_layer(lw, h, h, e_src, e_dst, e_mask, n, heads=heads, out=out,
                      neg_slope=cfg.neg_slope, dist=dist, final=final)
    return h


def forward_blocks(cfg: GATConfig, params: dict, batch: dict,
                   dist: DistCtx | None = None) -> Array:
    """Sampled mini-batch forward over bipartite blocks (outermost first).

    Per-block dst counts are derived STATICALLY from array shapes (dst nodes
    of block i are the src prefix of block i+1): the innermost dst count is
    len(labels) (the seeds), and walking outward each src set is
    dst ++ sampled neighbors, so  ndst[i] = ndst[i+1] + len(edges[i+1]).
    """
    n_blocks = cfg.n_layers
    ndst = [0] * n_blocks
    ndst[-1] = batch["labels"].shape[0]
    for i in range(n_blocks - 2, -1, -1):
        ndst[i] = ndst[i + 1] + batch[f"block{i + 1}_src"].shape[0]
    h = batch["block0_feats"].astype(cfg.dtype)
    for i in range(n_blocks):
        lw = params["layers"][i]
        final = i == cfg.n_layers - 1
        heads = 1 if final else cfg.n_heads
        out = cfg.n_classes if final else cfg.d_hidden
        e_src = batch[f"block{i}_src"]
        e_dst = batch[f"block{i}_dst"]
        e_mask = batch[f"block{i}_mask"]
        n_dst = ndst[i]
        # dst nodes are the first n_dst entries of the src set by construction
        h_dst = h[:n_dst]
        h = gat_layer(lw, h, h_dst, e_src, e_dst, e_mask, n_dst, heads=heads,
                      out=out, neg_slope=cfg.neg_slope, dist=dist, final=final)
    return h


def masked_ce_loss(logits: Array, labels: Array, mask: Array) -> Array:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32),
                             labels[:, None].clip(0), axis=-1)[:, 0]
    per = jnp.where(mask, lse - ll, 0.0)
    return per.sum() / jnp.maximum(mask.sum(), 1)


def loss_full(cfg, params, batch, dist=None):
    logits = forward_full(cfg, params, batch, dist)
    return masked_ce_loss(logits, batch["labels"], batch["label_mask"])


def loss_blocks(cfg, params, batch, dist=None):
    logits = forward_blocks(cfg, params, batch, dist)
    return masked_ce_loss(logits, batch["labels"], batch["label_mask"])


def loss_molecule(cfg, params, batch, dist=None):
    """Batched small graphs (block-diag edges): mean-pool readout per graph."""
    logits = forward_full(cfg, params, batch, dist)              # (B*Nn, C)
    gid = batch["graph_ids"]
    n_graphs = batch["labels"].shape[0]
    pooled = jax.ops.segment_sum(logits, gid, n_graphs)
    cnt = jax.ops.segment_sum(jnp.ones_like(gid, logits.dtype), gid, n_graphs)
    pooled = pooled / jnp.maximum(cnt, 1.0)[:, None]
    return masked_ce_loss(pooled, batch["labels"],
                          jnp.ones(n_graphs, bool))
