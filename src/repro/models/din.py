"""DIN — Deep Interest Network (Zhou et al., arXiv:1706.06978).

Target-attention over the user behaviour sequence: attention weights come from
an MLP over [hist, target, hist−target, hist⊙target] (the paper's activation
unit, attn_mlp=80-40), then the weighted history sum is concatenated with the
target embedding and fed to the 200-80 MLP.

Item/category embeddings live in one banked super-table so UpDLRM's
partitioners apply directly (history lookups are multi-hot bags over items —
exactly the paper's access pattern).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import BankedTable, DistCtx, banked_gather
from repro.models.common import dense_init, embed_init, shard, dp
from repro.models.dlrm import _mlp_params, mlp_apply, bce_loss

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str
    n_items: int
    n_cates: int
    embed_dim: int            # 18 per assignment
    seq_len: int              # 100
    attn_mlp: tuple[int, ...]  # (80, 40)
    mlp: tuple[int, ...]       # (200, 80)
    dtype: Any = jnp.float32

    @property
    def total_vocab(self) -> int:
        return self.n_items + self.n_cates

    def param_count(self) -> int:
        d = self.embed_dim * 2  # item ++ cate
        n = self.total_vocab * self.embed_dim
        dims = [4 * d, *self.attn_mlp, 1]
        n += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        dims = [3 * d, *self.mlp, 1]
        n += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        return n


def init_params(cfg: DINConfig, key, plan=None) -> tuple[dict, dict]:
    from repro.core.partitioning import uniform_partition
    k1, k2, k3 = jax.random.split(key, 3)
    if plan is None:
        plan = uniform_partition(cfg.total_vocab, 1)
    rows = int(plan.max_rows_per_bank)
    d = cfg.embed_dim * 2
    params = {
        "emb_packed": embed_init(k1, (plan.n_banks * rows, cfg.embed_dim),
                                 dtype=cfg.dtype),
        "attn": _mlp_params(k2, [4 * d, *cfg.attn_mlp, 1], cfg.dtype),
        "mlp": _mlp_params(k3, [3 * d, *cfg.mlp, 1], cfg.dtype),
    }
    statics = {
        "remap_bank": jnp.asarray(plan.bank_of_row, jnp.int32),
        "remap_slot": jnp.asarray(plan.slot_of_row, jnp.int32),
        "n_banks": plan.n_banks,
        "rows_per_bank": rows,
        "cate_offset": jnp.int32(cfg.n_items),
    }
    return params, statics


def _banked(params, statics) -> BankedTable:
    return BankedTable(packed=params["emb_packed"],
                       remap_bank=statics["remap_bank"],
                       remap_slot=statics["remap_slot"],
                       n_banks=statics["n_banks"],
                       rows_per_bank=statics["rows_per_bank"])


def _pair_embed(t: BankedTable, statics, items: Array, cates: Array,
                dist) -> Array:
    """(item ++ category) embedding: (..., 2*D)."""
    e_i = banked_gather(t, items, dist)
    c_rows = jnp.where(cates >= 0, cates + statics["cate_offset"], -1)
    e_c = banked_gather(t, c_rows, dist)
    return jnp.concatenate([e_i, e_c], axis=-1)


def target_attention(p_attn: dict, hist: Array, target: Array,
                     mask: Array) -> Array:
    """hist (B, L, d), target (B, d) -> weighted sum (B, d). DIN's activation
    unit: w = MLP([h, t, h-t, h*t]); weights are NOT softmax-normalized in the
    original paper — kept raw with mask, as published."""
    B, Lh, d = hist.shape
    t = jnp.broadcast_to(target[:, None], hist.shape)
    feat = jnp.concatenate([hist, t, hist - t, hist * t], axis=-1)
    w = mlp_apply(p_attn, feat, act=jax.nn.sigmoid)[..., 0]     # (B, L)
    w = jnp.where(mask, w, 0.0)
    return jnp.einsum("bl,bld->bd", w, hist)


def forward(cfg: DINConfig, params: dict, statics: dict, batch: dict,
            dist: DistCtx | None = None) -> Array:
    """batch: hist_items/hist_cates (B, L) int32 (-1 pad), target_item/
    target_cate (B,) int32. Returns logits (B,)."""
    t = _banked(params, statics)
    hist = _pair_embed(t, statics, batch["hist_items"], batch["hist_cates"],
                       dist)                                     # (B, L, 2D)
    hist = shard(hist, dist, dp(dist), None, None)
    target = _pair_embed(t, statics, batch["target_item"][:, None],
                         batch["target_cate"][:, None], dist)[:, 0]
    mask = batch["hist_items"] >= 0
    interest = target_attention(params["attn"], hist, target, mask)
    feat = jnp.concatenate([interest, target, interest * target], axis=-1)
    return mlp_apply(params["mlp"], feat)[:, 0]


def loss_fn(cfg: DINConfig, params: dict, statics: dict, batch: dict,
            dist: DistCtx | None = None) -> Array:
    return bce_loss(forward(cfg, params, statics, batch, dist), batch["label"])


def retrieval_scores(cfg: DINConfig, params: dict, statics: dict, batch: dict,
                     dist: DistCtx | None = None) -> Array:
    """One user history × N candidate items -> (N,) scores, candidates sharded
    across the whole mesh (batched target-attention, no loop)."""
    t = _banked(params, statics)
    hist = _pair_embed(t, statics, batch["hist_items"], batch["hist_cates"],
                       dist)                                     # (1, L, 2D)
    mask = batch["hist_items"] >= 0                              # (1, L)
    cand = batch["candidates"]                                   # (N,)
    cand_c = batch["candidate_cates"]
    targ = _pair_embed(t, statics, cand, cand_c, dist)           # (N, 2D)
    if dist is not None:
        from repro.dist.collectives import all_mesh_axes
        targ = shard(targ, dist, all_mesh_axes(dist), None)
    N = targ.shape[0]
    histN = jnp.broadcast_to(hist, (N,) + hist.shape[1:])
    maskN = jnp.broadcast_to(mask, (N,) + mask.shape[1:])
    interest = target_attention(params["attn"], histN, targ, maskN)
    feat = jnp.concatenate([interest, targ, interest * targ], axis=-1)
    return mlp_apply(params["mlp"], feat)[:, 0]
