"""DLRM (Naumov et al., arXiv:1906.00091) with UpDLRM banked embeddings.

All sparse fields share ONE banked super-table (per-field row offsets), so the
paper's partitioners operate on the union vocabulary exactly like the DPU
deployment (each DPU group holds tiles of all tables; Fig. 4). Two lookup
flavours:

  * one-hot fields (Criteo-style ``dlrm-rm2``): dense gather (B, F) -> (B, F, D)
  * multi-hot bags (the paper's Table-1 datasets): (B, T, L) -> bag sums
    (B, T, D), optionally via the cache-aware rewritten form (cache ids +
    residual ids) — Fig. 7's dataflow.

The pairwise dot-product feature interaction is the Pallas ``dot_interaction``
kernel's reference path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import (
    BankedTable, DistCtx, banked_cache_residual_bag, banked_embedding_bag,
    banked_gather, tiered_embedding_bag)
from repro.models.common import dense_init, embed_init, shard, dp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str
    vocab_sizes: tuple[int, ...]       # per sparse field
    embed_dim: int
    n_dense: int
    bot_mlp: tuple[int, ...]           # hidden dims incl. final (== embed_dim)
    top_mlp: tuple[int, ...]           # hidden dims, final 1 appended
    multi_hot: int = 1                 # bag length per field (1 => one-hot)
    interaction: str = "dot"
    dtype: Any = jnp.float32
    # §Perf C2: table STORAGE dtype — bf16 halves every table-sized buffer
    # (gathers, grad scatter, optimizer r/w, stage-3 psum) while the row-wise
    # Adagrad accumulator stays fp32. Dense compute stays cfg.dtype.
    emb_dtype: Any = jnp.float32

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_vocab(self) -> int:
        return int(sum(self.vocab_sizes))

    def field_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]).astype(np.int64)

    def param_count(self) -> int:
        n = self.total_vocab * self.embed_dim
        dims = [self.n_dense, *self.bot_mlp]
        n += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        n_inter = self.n_sparse + 1
        top_in = n_inter * (n_inter - 1) // 2 + self.embed_dim
        dims = [top_in, *self.top_mlp, 1]
        n += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        return n


def _mlp_params(key, dims: Sequence[int], dtype) -> dict:
    ks = jax.random.split(key, len(dims) - 1)
    return {
        "w": [dense_init(k, (a, b), dtype=dtype)
              for k, a, b in zip(ks, dims[:-1], dims[1:])],
        "b": [jnp.zeros((b,), dtype) for b in dims[1:]],
    }


def mlp_apply(p: dict, x: Array, act=jax.nn.relu, final_act=None) -> Array:
    n = len(p["w"])
    for i, (w, b) in enumerate(zip(p["w"], p["b"])):
        x = x @ w + b
        if i < n - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def init_params(cfg: DLRMConfig, key, plan=None,
                rows_per_bank: int | None = None) -> tuple[dict, dict]:
    """Returns (params, statics). ``plan`` is a PartitionPlan over the union
    vocab; statics carries the row remap (untrained int arrays).

    ``rows_per_bank`` over-allocates each bank to a fixed capacity (>= the
    plan's max) so later plans can be swapped in-place without changing the
    packed shape — the adaptive-replanning contract (repro.workload)."""
    from repro.core.partitioning import uniform_partition
    k1, k2, k3 = jax.random.split(key, 3)
    if plan is None:
        plan = uniform_partition(cfg.total_vocab, 1)
    rows_per_bank = int(plan.max_rows_per_bank if rows_per_bank is None
                        else rows_per_bank)
    assert rows_per_bank >= plan.max_rows_per_bank
    packed = embed_init(k1, (plan.n_banks * rows_per_bank, cfg.embed_dim),
                        dtype=cfg.emb_dtype)
    params = {
        "emb_packed": packed,
        "bot": _mlp_params(k2, [cfg.n_dense, *cfg.bot_mlp], cfg.dtype),
        "top": _mlp_params(
            k3,
            [cfg.n_sparse * (cfg.n_sparse + 1) // 2 + cfg.embed_dim,
             *cfg.top_mlp, 1],
            cfg.dtype),
    }
    statics = {
        "remap_bank": jnp.asarray(plan.bank_of_row, jnp.int32),
        "remap_slot": jnp.asarray(plan.slot_of_row, jnp.int32),
        "n_banks": plan.n_banks,
        "rows_per_bank": rows_per_bank,
        "field_offsets": jnp.asarray(cfg.field_offsets(), jnp.int32),
    }
    return params, statics


def _banked(params: dict, statics: dict) -> BankedTable:
    return BankedTable(
        packed=params["emb_packed"],
        remap_bank=statics["remap_bank"],
        remap_slot=statics["remap_slot"],
        n_banks=statics["n_banks"],
        rows_per_bank=statics["rows_per_bank"],
    )


def dot_interaction(z: Array) -> Array:
    """z: (B, F, D) -> (B, F*(F-1)/2) upper-triangular pairwise dots.

    Reference path for kernels/dot_interaction.py.
    """
    B, F, D = z.shape
    zz = jnp.einsum("bfd,bgd->bfg", z, z, preferred_element_type=jnp.float32)
    iu, ju = np.triu_indices(F, k=1)
    return zz[:, iu, ju].astype(z.dtype)


def forward(cfg: DLRMConfig, params: dict, statics: dict, batch: dict,
            dist: DistCtx | None = None, *, backend: str = "auto",
            bwd_backend: str = "auto", tiered=None,
            replicated=None, bank_live: Array | None = None) -> Array:
    """batch: dense (B, n_dense) fp; sparse (B, F) int32 (one-hot fields) or
    (B, F, L) multi-hot. Returns logits (B,).

    ``backend`` selects the stage-2 lookup implementation (core/embedding.py):
    'jnp' scan, 'pallas' fused kernel, or 'auto'. ``bwd_backend`` selects the
    pallas forward's gradient scatter ('auto' follows ``backend``: a pallas
    training step keeps the backward's row traffic on the sorted-run scatter
    kernel). The multi-hot path hands the RAW (B, F, L) per-field ids plus
    ``field_offsets`` to ONE fused banked_embedding_bag call — all F fields
    in a single stage-2 pass, and no (B, F, L, D) gathered intermediate on
    either backend.

    ``tiered`` (a repro.quant.TieredTable quantized FROM ``emb_packed``'s
    layout) reroutes the lookup through the tiered-precision path: values
    come from the quantized payload (dequant in-kernel), gradients flow
    straight through onto ``params['emb_packed']``. The adaptive serve loop
    passes it as a jit ARGUMENT so a live re-tier swap feeds new same-shape
    arrays to the compiled step — zero recompiles (launch/serve.py --quant).
    One-hot fields fold into length-1 bags on this path (same semantics as
    the dense gather).

    ``replicated`` (a core.embedding.ReplicatedTable — the runtime's hot-row
    replica side table) reroutes the lookup through the replica-aware path:
    each bag picks one copy of each row via an in-kernel hash, so hot-row
    traffic splits across the copies' banks. Like ``tiered`` it rides the jit
    as an ARGUMENT with pinned shapes — a live replica-count swap is a pure
    argument change (launch/serve.py --replicate-k-max). Composes with
    ``bank_live``: a surviving copy covers a dead bank's reads before any
    read degrades to the zero row. One-hot fields fold into length-1 bags.
    Mutually exclusive with ``tiered`` (the replicas ARE the full-precision
    head; an in-kernel dequant+replica-select kernel is a ROADMAP item).

    ``bank_live`` ((n_banks,) bool jit argument) enables bounded-degraded
    serving through a bank failure: reads homed on dead banks resolve to the
    zero row (core/embedding.py). Not supported with ``tiered`` — the fault
    lane runs the full-precision path.
    """
    dense, sparse = batch["dense"], batch["sparse"]
    B = dense.shape[0]
    t = _banked(params, statics)
    if replicated is not None:
        if tiered is not None:
            raise ValueError("tiered x replicated serving is not wired — "
                             "replicas are the full-precision head "
                             "(ROADMAP.md)")
        from repro.core.embedding import replicated_embedding_bag
        bags = sparse if sparse.ndim == 3 else sparse[..., None]
        emb = replicated_embedding_bag(                          # (B, F, D)
            replicated, bags, dist, backend=backend,
            bwd_backend=bwd_backend,
            field_offsets=statics["field_offsets"], bank_live=bank_live)
    elif tiered is not None:
        if bank_live is not None:
            raise ValueError("bank_live degraded serving is not wired into "
                             "the tiered lookup path")
        bags = sparse if sparse.ndim == 3 else sparse[..., None]
        emb = tiered_embedding_bag(                              # (B, F, D)
            params["emb_packed"], tiered, bags, dist, backend=backend,
            bwd_backend=bwd_backend,
            field_offsets=statics["field_offsets"])
    elif sparse.ndim == 2:
        # one-hot fields: dense gather; per-field ids -> union-vocab rows
        rows = sparse + statics["field_offsets"][None, :]
        rows = jnp.where(sparse >= 0, rows, -1)
        emb = banked_gather(t, rows, dist, bank_live=bank_live)  # (B, F, D)
    else:
        emb = banked_embedding_bag(                              # (B, F, D)
            t, sparse, dist, backend=backend, bwd_backend=bwd_backend,
            field_offsets=statics["field_offsets"], bank_live=bank_live)
    emb = shard(emb, dist, dp(dist), None, None).astype(cfg.dtype)

    x = mlp_apply(params["bot"], dense.astype(cfg.dtype))        # (B, D)
    z = jnp.concatenate([x[:, None], emb], axis=1)               # (B, F+1, D)
    inter = dot_interaction(z)                                   # (B, P)
    feat = jnp.concatenate([inter, x], axis=-1)
    logit = mlp_apply(params["top"], feat)[:, 0]
    return logit


def forward_cached(cfg: DLRMConfig, params: dict, statics: dict,
                   cache_table: BankedTable, batch: dict,
                   dist: DistCtx | None = None, *, backend: str = "auto",
                   bwd_backend: str = "auto",
                   remap_bank: Array | None = None,
                   remap_slot: Array | None = None,
                   bank_live: Array | None = None) -> Array:
    """Cache-aware path (Fig. 7): batch carries rewritten multi-hot bags:
    ``cache_idx`` (B, T, Lc) entries into the partial-sum cache table and
    ``residual_idx`` (B, T, Lr) union-vocab rows. Bag sum = cache partials +
    residual rows — ONE fused stage-2 pass over both tables (one psum), then
    identical CTR compute.

    ``remap_bank`` / ``remap_slot`` override the EMT remap vectors in
    ``statics``. The adaptive serve loop passes them (and ``cache_table``) as
    jit ARGUMENTS so a live plan/cache swap feeds new same-shape arrays to
    the already-compiled step — zero recompiles (launch/serve.py
    --adaptive --partition cache_aware)."""
    dense = batch["dense"]
    if remap_bank is not None:
        statics = {**statics, "remap_bank": remap_bank,
                   "remap_slot": remap_slot}
    t = _banked(params, statics)
    emb = banked_cache_residual_bag(t, cache_table, batch["cache_idx"],
                                    batch["residual_idx"], dist,
                                    backend=backend,
                                    bwd_backend=bwd_backend,
                                    bank_live=bank_live)
    x = mlp_apply(params["bot"], dense.astype(cfg.dtype))
    z = jnp.concatenate([x[:, None], emb], axis=1)
    inter = dot_interaction(z)
    feat = jnp.concatenate([inter, x], axis=-1)
    return mlp_apply(params["top"], feat)[:, 0]


def bce_loss(logits: Array, labels: Array) -> Array:
    logits = logits.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def loss_fn(cfg: DLRMConfig, params: dict, statics: dict, batch: dict,
            dist: DistCtx | None = None, *, backend: str = "auto",
            bwd_backend: str = "auto", tiered=None) -> Array:
    return bce_loss(forward(cfg, params, statics, batch, dist,
                            backend=backend, bwd_backend=bwd_backend,
                            tiered=tiered),
                    batch["label"])


def retrieval_scores(cfg: DLRMConfig, params: dict, statics: dict,
                     batch: dict, dist: DistCtx | None = None) -> Array:
    """retrieval_cand: one query × N candidate ids for field 0 -> scores (N,).

    Batched-dot formulation: the user side (dense + fields 1..F-1) is computed
    once; candidate embeddings stream through the interaction in a vectorized
    tile, sharded over every mesh axis — never a Python loop.
    """
    dense, sparse, cand = batch["dense"], batch["sparse"], batch["candidates"]
    N = cand.shape[0]
    t = _banked(params, statics)
    x = mlp_apply(params["bot"], dense.astype(cfg.dtype))        # (1, D)
    rows = sparse[:, 1:] + statics["field_offsets"][None, 1:]
    emb_user = banked_gather(t, rows, dist)                      # (1, F-1, D)
    cand_rows = cand + statics["field_offsets"][0]
    emb_cand = banked_gather(t, cand_rows, dist)                 # (N, D)
    if dist is not None:
        from repro.dist.collectives import all_mesh_axes
        emb_cand = shard(emb_cand, dist, all_mesh_axes(dist), None)
    z_user = jnp.concatenate([x[:, None], emb_user], axis=1)     # (1, F, D)
    zu = jnp.broadcast_to(z_user, (N,) + z_user.shape[1:])
    z = jnp.concatenate([zu, emb_cand[:, None]], axis=1)         # (N, F+1, D)
    inter = dot_interaction(z)
    feat = jnp.concatenate([inter, jnp.broadcast_to(x, (N, x.shape[-1]))], -1)
    return mlp_apply(params["top"], feat)[:, 0]
