"""xDeepFM (Lian et al., arXiv:1803.05170): CIN + deep MLP + linear.

CIN layer k: X^{k+1}_{h} = sum_{i,j} W^{k,h}_{ij} (X^k_i ∘ X^0_j) — computed
as an outer product along the embedding dim followed by a field-compressing
einsum (the paper's "1D conv" view). Field embeddings come from one banked
super-table (one-hot fields), so UpDLRM row partitioning applies; partial-sum
caching degenerates to hot-row caching (noted in DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import BankedTable, DistCtx, banked_gather
from repro.models.common import dense_init, embed_init, shard, dp
from repro.models.dlrm import _mlp_params, mlp_apply, bce_loss

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str
    vocab_sizes: tuple[int, ...]   # 39 fields
    embed_dim: int                 # 10
    cin_layers: tuple[int, ...]    # (200, 200, 200)
    mlp: tuple[int, ...]           # (400, 400)
    dtype: Any = jnp.float32

    @property
    def n_fields(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_vocab(self) -> int:
        return int(sum(self.vocab_sizes))

    def field_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]).astype(np.int64)

    def param_count(self) -> int:
        m, D = self.n_fields, self.embed_dim
        n = self.total_vocab * (D + 1)     # embeddings + linear (dim-1) weights
        h_prev = m
        for h in self.cin_layers:
            n += h * h_prev * m
            h_prev = h
        n += sum(self.cin_layers)          # sum-pool -> logit weights
        dims = [m * D, *self.mlp, 1]
        n += sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
        return n


def init_params(cfg: XDeepFMConfig, key, plan=None) -> tuple[dict, dict]:
    from repro.core.partitioning import uniform_partition
    ks = jax.random.split(key, 4 + len(cfg.cin_layers))
    if plan is None:
        plan = uniform_partition(cfg.total_vocab, 1)
    rows = int(plan.max_rows_per_bank)
    m, D = cfg.n_fields, cfg.embed_dim
    cin_w = []
    h_prev = m
    for i, h in enumerate(cfg.cin_layers):
        cin_w.append(dense_init(ks[3 + i], (h, h_prev, m),
                                scale=1.0 / np.sqrt(h_prev * m),
                                dtype=cfg.dtype))
        h_prev = h
    params = {
        "emb_packed": embed_init(ks[0], (plan.n_banks * rows, D),
                                 dtype=cfg.dtype),
        "lin_packed": embed_init(ks[1], (plan.n_banks * rows, 1),
                                 dtype=cfg.dtype),
        "cin_w": cin_w,
        "cin_out": dense_init(ks[2], (int(sum(cfg.cin_layers)), 1),
                              dtype=cfg.dtype),
        "mlp": _mlp_params(ks[-1], [m * D, *cfg.mlp, 1], cfg.dtype),
    }
    statics = {
        "remap_bank": jnp.asarray(plan.bank_of_row, jnp.int32),
        "remap_slot": jnp.asarray(plan.slot_of_row, jnp.int32),
        "n_banks": plan.n_banks,
        "rows_per_bank": rows,
        "field_offsets": jnp.asarray(cfg.field_offsets(), jnp.int32),
    }
    return params, statics


def _banked(params, statics, leaf) -> BankedTable:
    return BankedTable(packed=params[leaf],
                       remap_bank=statics["remap_bank"],
                       remap_slot=statics["remap_slot"],
                       n_banks=statics["n_banks"],
                       rows_per_bank=statics["rows_per_bank"])


def cin(x0: Array, cin_w: list[Array]) -> Array:
    """x0: (B, m, D) -> concat of sum-pooled CIN features (B, sum(H_k))."""
    xk = x0
    pooled = []
    for w in cin_w:
        # z: (B, H_prev, m, D) outer product along fields, shared over D
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)
        xk = jnp.einsum("bhmd,ohm->bod", z, w)      # compress to H_k fields
        pooled.append(xk.sum(-1))                   # (B, H_k)
    return jnp.concatenate(pooled, axis=-1)


def forward(cfg: XDeepFMConfig, params: dict, statics: dict, batch: dict,
            dist: DistCtx | None = None) -> Array:
    """batch: sparse (B, m) int32 field values. Returns logits (B,)."""
    sparse = batch["sparse"]
    rows = sparse + statics["field_offsets"][None, :]
    rows = jnp.where(sparse >= 0, rows, -1)
    emb = banked_gather(_banked(params, statics, "emb_packed"), rows, dist)
    emb = shard(emb, dist, dp(dist), None, None)                # (B, m, D)
    lin = banked_gather(_banked(params, statics, "lin_packed"), rows, dist)
    logit_lin = lin[..., 0].sum(-1)                              # (B,)
    logit_cin = (cin(emb, params["cin_w"]) @ params["cin_out"])[:, 0]
    B = emb.shape[0]
    logit_dnn = mlp_apply(params["mlp"], emb.reshape(B, -1))[:, 0]
    return logit_lin + logit_cin + logit_dnn


def loss_fn(cfg, params, statics, batch, dist=None):
    return bce_loss(forward(cfg, params, statics, batch, dist), batch["label"])


def retrieval_scores(cfg: XDeepFMConfig, params: dict, statics: dict,
                     batch: dict, dist: DistCtx | None = None) -> Array:
    """One query, N candidate values for field 0, batched (N,) scoring."""
    sparse, cand = batch["sparse"], batch["candidates"]          # (1,m), (N,)
    N = cand.shape[0]
    sp = jnp.broadcast_to(sparse, (N, sparse.shape[1]))
    sp = sp.at[:, 0].set(cand)
    return forward(cfg, params, statics, {"sparse": sp}, dist)
