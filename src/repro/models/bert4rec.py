"""BERT4Rec (Sun et al., arXiv:1904.06690): bidirectional transformer over the
user's item sequence, trained with masked-item (cloze) prediction.

Per DESIGN.md §4: the item embedding here is a dense per-position lookup (no
multi-hot reduction), so UpDLRM's partial-sum caching is inapplicable; the
non-uniform row placement still applies and the item table is banked.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.embedding import BankedTable, DistCtx, banked_gather
from repro.models import layers as L
from repro.models.common import dense_init, embed_init, shard, dp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str
    n_items: int               # catalog size; +1 mask token appended
    embed_dim: int             # 64
    n_blocks: int              # 2
    n_heads: int               # 2
    seq_len: int               # 200
    d_ff: int = 256            # 4x embed_dim (paper)
    dtype: Any = jnp.float32
    # "full": softmax over the whole catalog (paper-faithful; fine at the
    # published 3k-50k catalogs). "sampled": shared-negative sampled softmax
    # over masked positions only (§Perf iteration B) — at a 1M-item catalog
    # the full (B, S, V) logits are ~1000x wasted compute/traffic.
    loss: str = "sampled"
    n_negatives: int = 2048
    max_masked: int = 40       # static cap: ceil(0.15 * seq_len) + slack

    @property
    def vocab(self) -> int:
        return self.n_items + 1   # last row = [mask]

    @property
    def mask_token(self) -> int:
        return self.n_items

    def param_count(self) -> int:
        d = self.embed_dim
        per_block = 4 * d * d + 2 * d * self.d_ff + self.d_ff + d + 4 * d
        return self.vocab * d + self.seq_len * d + self.n_blocks * per_block


def init_params(cfg: Bert4RecConfig, key, plan=None) -> tuple[dict, dict]:
    from repro.core.partitioning import uniform_partition
    ks = jax.random.split(key, 12)
    if plan is None:
        plan = uniform_partition(cfg.vocab, 1)
    rows = int(plan.max_rows_per_bank)
    d, ff, NB = cfg.embed_dim, cfg.d_ff, cfg.n_blocks

    def stk(i, *shape):
        return jax.vmap(lambda k: dense_init(k, shape, dtype=cfg.dtype))(
            jax.random.split(ks[i], NB))

    params = {
        "emb_packed": embed_init(ks[0], (plan.n_banks * rows, d),
                                 dtype=cfg.dtype),
        "pos": embed_init(ks[1], (cfg.seq_len, d), dtype=cfg.dtype),
        "blocks": {
            "wq": stk(2, d, d), "wk": stk(3, d, d), "wv": stk(4, d, d),
            "wo": stk(5, d, d),
            "w_in": stk(6, d, ff), "b_in": jnp.zeros((NB, ff), cfg.dtype),
            "w_out": stk(7, ff, d), "b_out": jnp.zeros((NB, d), cfg.dtype),
            "ln1_s": jnp.ones((NB, d), cfg.dtype),
            "ln1_b": jnp.zeros((NB, d), cfg.dtype),
            "ln2_s": jnp.ones((NB, d), cfg.dtype),
            "ln2_b": jnp.zeros((NB, d), cfg.dtype),
        },
        "out_bias": jnp.zeros((cfg.vocab,), cfg.dtype),
    }
    statics = {
        "remap_bank": jnp.asarray(plan.bank_of_row, jnp.int32),
        "remap_slot": jnp.asarray(plan.slot_of_row, jnp.int32),
        "n_banks": plan.n_banks,
        "rows_per_bank": rows,
    }
    return params, statics


def _banked(params, statics) -> BankedTable:
    return BankedTable(packed=params["emb_packed"],
                       remap_bank=statics["remap_bank"],
                       remap_slot=statics["remap_slot"],
                       n_banks=statics["n_banks"],
                       rows_per_bank=statics["rows_per_bank"])


def encode(cfg: Bert4RecConfig, params: dict, statics: dict, items: Array,
           dist: DistCtx | None = None) -> Array:
    """items (B, S) int32 (-1 pad) -> hidden (B, S, d). Bidirectional."""
    B, S = items.shape
    t = _banked(params, statics)
    h = banked_gather(t, items, dist) + params["pos"][None, :S]
    h = shard(h, dist, dp(dist), None, None).astype(cfg.dtype)

    def block(h, bw):
        bw = {k_: v_.astype(cfg.dtype) for k_, v_ in bw.items()}
        x = L.layer_norm(h, bw["ln1_s"], bw["ln1_b"])
        q = (x @ bw["wq"]).reshape(B, S, cfg.n_heads, -1)
        k = (x @ bw["wk"]).reshape(B, S, cfg.n_heads, -1)
        v = (x @ bw["wv"]).reshape(B, S, cfg.n_heads, -1)
        attn = L.blockwise_attention(q, k, v, causal=False,
                                     q_chunk=min(1024, S), kv_chunk=min(1024, S))
        h = h + attn.reshape(B, S, -1) @ bw["wo"]
        x = L.layer_norm(h, bw["ln2_s"], bw["ln2_b"])
        h = h + L.gelu_mlp(x, bw["w_in"], bw["b_in"], bw["w_out"], bw["b_out"])
        return h, None

    h, _ = jax.lax.scan(block, h, params["blocks"])
    return h


def mlm_loss(cfg: Bert4RecConfig, params: dict, statics: dict, batch: dict,
             dist: DistCtx | None = None) -> Array:
    """Cloze objective: ``items`` with mask tokens, ``labels`` original ids at
    masked positions (-100 elsewhere). Output head ties the item embedding.

    cfg.loss == "sampled": gather the <= max_masked masked positions per
    sequence and score each against its label + n_negatives shared negatives
    (batch["negatives"]) — the industry-standard approximation at 1M-item
    catalogs; "full" is the paper-faithful softmax over the catalog.
    """
    items, labels = batch["items"], batch["labels"]
    h = encode(cfg, params, statics, items, dist)
    sel = labels >= 0
    t = _banked(params, statics)

    if cfg.loss == "sampled":
        # static-shape masked-position gather: top_k over the mask
        m = cfg.max_masked
        score, pos = jax.lax.top_k(sel.astype(jnp.int32) * 2 - 1, m)
        valid = score > 0                                        # (B, m)
        h_m = jnp.take_along_axis(h, pos[..., None], axis=1)     # (B, m, d)
        lab = jnp.take_along_axis(jnp.where(sel, labels, 0), pos, axis=1)
        e_pos = banked_gather(t, jnp.where(valid, lab, -1), dist)
        negs = batch["negatives"]                                # (N,)
        e_neg = banked_gather(t, negs, dist)                     # (N, d)
        if dist is not None:
            from repro.dist.collectives import all_mesh_axes
            e_neg = shard(e_neg, dist, all_mesh_axes(dist), None)
        l_pos = jnp.einsum("bmd,bmd->bm", h_m, e_pos,
                           preferred_element_type=jnp.float32)
        l_pos = l_pos + params["out_bias"][jnp.where(valid, lab, 0)]
        l_neg = jnp.einsum("bmd,nd->bmn", h_m, e_neg,
                           preferred_element_type=jnp.float32)
        l_neg = l_neg + params["out_bias"][negs][None, None, :]
        # exclude accidental label==negative collisions
        coll = lab[..., None] == negs[None, None, :]
        l_neg = jnp.where(coll, -1e30, l_neg)
        lse = jnp.logaddexp(
            jax.nn.logsumexp(l_neg, axis=-1), l_pos)
        per_tok = jnp.where(valid, lse - l_pos, 0.0)
        return per_tok.sum() / jnp.maximum(valid.sum(), 1)

    # full-catalog softmax (paper-faithful)
    from repro.core.embedding import lookup_unsharded
    table = lookup_unsharded(t, jnp.arange(cfg.vocab)[:, None],
                             reduce_bag=True)                    # (V, d)
    logits = jnp.einsum("bsd,vd->bsv", h, table,
                        preferred_element_type=jnp.float32)
    logits = logits + params["out_bias"]
    logits = shard(logits, dist, dp(dist), None, "model")
    lse = jax.nn.logsumexp(logits, axis=-1)
    safe = jnp.where(sel, labels, 0)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    per_tok = jnp.where(sel, lse - ll, 0.0)
    return per_tok.sum() / jnp.maximum(sel.sum(), 1)


def loss_fn(cfg, params, statics, batch, dist=None):
    return mlm_loss(cfg, params, statics, batch, dist)


def next_item_scores(cfg: Bert4RecConfig, params: dict, statics: dict,
                     batch: dict, dist: DistCtx | None = None) -> Array:
    """Serving: append [mask] at the last position, score candidates.

    If batch has ``candidates`` (N,), scores only those (retrieval_cand cell,
    candidates sharded across the mesh); otherwise scores the full catalog.
    """
    items = batch["items"]                                       # (B, S)
    h = encode(cfg, params, statics, items, dist)[:, -1]         # (B, d)
    t = _banked(params, statics)
    cand = batch.get("candidates")
    if cand is not None and cand.ndim == 2:
        # per-user candidate slate (two-stage ranking serve): (B, N)
        emb = banked_gather(t, cand, dist)                       # (B, N, d)
        return jnp.einsum("bd,bnd->bn", h, emb,
                          preferred_element_type=jnp.float32)
    if cand is not None:
        emb = banked_gather(t, cand, dist)                       # (N, d)
        if dist is not None:
            from repro.dist.collectives import all_mesh_axes
            emb = shard(emb, dist, all_mesh_axes(dist), None)
        return jnp.einsum("bd,nd->bn", h, emb,
                          preferred_element_type=jnp.float32)
    from repro.core.embedding import lookup_unsharded
    table = lookup_unsharded(t, jnp.arange(cfg.vocab)[:, None], reduce_bag=True)
    return jnp.einsum("bd,vd->bv", h, table,
                      preferred_element_type=jnp.float32) + params["out_bias"]


# retrieval_cand cell entry point (same signature as the other families)
retrieval_scores = next_item_scores
