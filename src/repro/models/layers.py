"""Transformer building blocks: norms, RoPE, blockwise (flash-style) attention,
decode attention (incl. sequence-sharded flash-decode combine), GLU MLP, and a
sort-based capacity MoE layer.

Everything is a pure function over explicit param pytrees. Attention never
materializes the full (S, S) score matrix: queries are processed in chunks and
KV is scanned blockwise with an online-softmax accumulator (fp32), which is
what makes the 32k-prefill shapes compilable within HBM.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import shard_map

Array = jax.Array

NEG_INF = -1e30


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., S, H, Dh), positions: (..., S) int32."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise attention (training / prefill) — online softmax, GQA-aware
# ---------------------------------------------------------------------------

def blockwise_attention(
    q: Array, k: Array, v: Array, *,
    causal: bool,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    q_offset: int = 0,
    unroll: bool = False,
) -> Array:
    """q: (B, Sq, Hq, Dh), k/v: (B, Skv, Hkv, Dh) with Hq % Hkv == 0.

    Flash-style: scan over KV chunks keeping running (max, sum, acc) in fp32.
    q_offset: absolute position of q[0] (for chunked prefill / decode windows).
    """
    B, Sq, Hq, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    groups = Hq // Hkv
    scale = 1.0 / np.sqrt(Dh)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0

    # reshape to grouped heads: (B, S, Hkv, G, Dh)
    qg = q.reshape(B, Sq, Hkv, groups, Dh)

    def one_q_chunk(qc, qpos0):
        # qc: (B, Cq, Hkv, G, Dh)
        def kv_step(carry, inputs):
            m, l, acc = carry
            kc, vc, kpos0 = inputs  # (B, Ck, Hkv, Dh)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                qpos = qpos0 + jnp.arange(qc.shape[1]) + q_offset
                kpos = kpos0 + jnp.arange(kc.shape[1])
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        Cq = qc.shape[1]
        m0 = jnp.full((B, Cq, Hkv, groups), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Cq, Hkv, groups), jnp.float32)
        a0 = jnp.zeros((B, Cq, Hkv, groups, Dh), jnp.float32)
        n_kv = Skv // kv_chunk
        ks = k.reshape(B, n_kv, kv_chunk, Hkv, Dh).swapaxes(0, 1)
        vs = v.reshape(B, n_kv, kv_chunk, Hkv, Dh).swapaxes(0, 1)
        kpos = jnp.arange(n_kv) * kv_chunk
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, kpos),
                                      unroll=n_kv if unroll else 1)
        return acc / jnp.maximum(l, 1e-20)[..., None]

    n_q = Sq // q_chunk
    if n_q == 1:
        out = one_q_chunk(qg, jnp.int32(0)).reshape(B, Sq, Hq, Dh)
        return out.astype(q.dtype)
    qs = qg.reshape(B, n_q, q_chunk, Hkv, groups, Dh).swapaxes(0, 1)
    qpos0 = jnp.arange(n_q) * q_chunk
    out = jax.lax.map(lambda args: one_q_chunk(*args), (qs, qpos0))
    out = out.swapaxes(0, 1).reshape(B, Sq, Hq, Dh)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention — one new token against a KV cache
# ---------------------------------------------------------------------------

def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cache_len: Array) -> Array:
    """q: (B, Hq, Dh); caches: (B, S, Hkv, Dh); cache_len: (B,) valid length.

    O(S) per token — naturally sub-quadratic; this is the ``decode_*`` /
    ``long_500k`` path (DESIGN.md §4 note).
    """
    B, S, Hkv, Dh = k_cache.shape
    Hq = q.shape[1]
    groups = Hq // Hkv
    qg = q.reshape(B, Hkv, groups, Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) / np.sqrt(Dh)
    mask = jnp.arange(S)[None, :] < cache_len[:, None]       # (B, S)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, Dh).astype(q.dtype)


def decode_attention_partial(q: Array, k_shard: Array, v_shard: Array,
                             valid: Array) -> tuple[Array, Array, Array]:
    """Flash-decode partial on one KV sequence shard.

    Returns (o_partial (B,Hq,Dh) fp32, lse-normalizer pieces m (B,Hq), l (B,Hq))
    to be combined across shards:  global softmax = rescale-by-max + sum.
    """
    B, S, Hkv, Dh = k_shard.shape
    Hq = q.shape[1]
    groups = Hq // Hkv
    qg = q.reshape(B, Hkv, groups, Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_shard,
                   preferred_element_type=jnp.float32) / np.sqrt(Dh)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(-1)                                            # (B,Hkv,G)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_shard.dtype), v_shard,
                   preferred_element_type=jnp.float32)
    return (o.reshape(B, Hq, Dh), m.reshape(B, Hq), l.reshape(B, Hq))


def combine_decode_partials(o: Array, m: Array, l: Array, axis_names) -> Array:
    """Cross-shard softmax combine (log-sum-exp trick), inside shard_map."""
    m_glob = jax.lax.pmax(m, axis_names)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axis_names)
    o_glob = jax.lax.psum(o * corr[..., None], axis_names)
    return o_glob / jnp.maximum(l_glob, 1e-20)[..., None]


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def glu_mlp(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    """SwiGLU (llama family)."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x: Array, w_in: Array, b_in: Array, w_out: Array,
             b_out: Array) -> Array:
    return jax.nn.gelu(x @ w_in + b_in) @ w_out + b_out


def mlp_stack(x: Array, weights: list[Array], biases: list[Array],
              act=jax.nn.relu, final_act=None) -> Array:
    """Plain MLP tower (recsys bottom/top MLPs)."""
    for i, (w, b) in enumerate(zip(weights, biases)):
        x = x @ w + b
        if i < len(weights) - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# ---------------------------------------------------------------------------
# MoE: top-k routing + sort-based capacity dispatch (GShard semantics,
# MegaBlocks-style grouped compute, experts shardable on the bank axis)
# ---------------------------------------------------------------------------

class MoEStats(NamedTuple):
    load: Array       # (E,) routed token counts (pre-drop)
    dropped: Array    # () fraction dropped by capacity


def moe_layer(x: Array, w_router: Array, w_gate: Array, w_up: Array,
              w_down: Array, *, top_k: int, capacity_factor: float = 1.25,
              ) -> tuple[Array, MoEStats]:
    """x: (T, d). Experts: w_gate/up (E, d, ff), w_down (E, ff, d).

    Sort-based dispatch: tokens are ranked within their expert via argsort —
    avoids the (T, E, C) one-hot dispatch tensor entirely; the (E, C, d)
    buffer is the only expanded intermediate and shards over the bank axis.
    """
    T, d = x.shape
    E = w_gate.shape[0]
    probs = jax.nn.softmax(x.astype(jnp.float32) @ w_router.astype(jnp.float32))
    gates, eidx = jax.lax.top_k(probs, top_k)                # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)                                # (T*k,)
    tok_of = jnp.repeat(jnp.arange(T), top_k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))       # (E,)
    rank = jnp.arange(T * top_k) - starts[sorted_e]
    C = max(1, int(T * top_k * capacity_factor / E))
    keep = rank < C
    dest = jnp.where(keep, sorted_e * C + rank, E * C)       # drops -> scratch

    xs = x[tok_of[order]]                                    # (T*k, d)
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[dest].add(
        jnp.where(keep[:, None], xs, 0))
    buf = buf[:-1].reshape(E, C, d)

    h = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, w_up)
    y = jnp.einsum("ecf,efd->ecd", h, w_down)                # (E, C, d)

    y_sorted = y.reshape(E * C, d)[jnp.where(keep, dest, 0)]
    y_sorted = jnp.where(keep[:, None], y_sorted, 0)
    y_flat = jnp.zeros((T * top_k, d), x.dtype).at[order].set(y_sorted)
    y_tok = y_flat.reshape(T, top_k, d)
    out = (y_tok * gates[..., None].astype(x.dtype)).sum(axis=1)

    load = jax.ops.segment_sum(jnp.ones_like(flat_e, jnp.float32), flat_e, E)
    dropped = 1.0 - keep.sum().astype(jnp.float32) / (T * top_k)
    return out, MoEStats(load=load, dropped=dropped)


def moe_layer_sharded(x: Array, w_router: Array, w_gate: Array, w_up: Array,
                      w_down: Array, *, top_k: int,
                      capacity_factor: float = 1.25, dist=None) -> Array:
    """Explicit expert-parallel MoE (§Perf iteration A) — shard_map over the
    bank axis with a psum combine, replacing GSPMD's inferred dispatch.

    Why: under pure GSPMD the sort/scatter dispatch of (T·k, d) activations
    against model-sharded experts lowers to repeated full all-reduces —
    ~320 GB/layer/device on the qwen3 train cell. Here every (data, model)
    device routes its LOCAL tokens to its LOCAL experts (router weights are
    replicated so routing decisions agree across banks), computes, and a
    single (T_loc, d) psum over the bank axis merges the per-bank partial
    outputs — the same partial-sum-combine dataflow as the paper's stage 3.
    ICI floor analysis: EP must move O(T_loc·d) across the expert axis;
    psum = all-gather + reduce-scatter = 2·T_loc·d·2B ≈ 0.5 GB/layer — within
    2.3x of the top-k sparse routing floor (a token touches ≤ 8 of 16 banks).

    x: (B, S, d) logical; tokens sharded over dp, experts over the bank axis.
    """
    P = jax.sharding.PartitionSpec
    dp = dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0]
    bank = dist.bank_axis
    E = w_gate.shape[0]
    n_banks = dist.mesh.shape[bank]
    assert E % n_banks == 0
    E_loc = E // n_banks

    def local(xl, wr, wg, wu, wd):
        B_l, S_l, d = xl.shape
        T = B_l * S_l
        xf = xl.reshape(T, d)
        my = jax.lax.axis_index(bank)
        probs = jax.nn.softmax(
            xf.astype(jnp.float32) @ wr.astype(jnp.float32))
        gates, eidx = jax.lax.top_k(probs, top_k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        flat_e = eidx.reshape(-1)
        tok_of = jnp.repeat(jnp.arange(T), top_k)
        # slots routed to MY experts; foreign slots sort to the tail
        e_loc = flat_e - my * E_loc
        key = jnp.where((e_loc >= 0) & (e_loc < E_loc), e_loc, E_loc)
        order = jnp.argsort(key, stable=True)
        sorted_e = key[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(E_loc))
        rank = jnp.arange(T * top_k) - starts[sorted_e]
        C = max(1, int(T * top_k * capacity_factor / E))
        keep = (sorted_e < E_loc) & (rank < C)
        dest = jnp.where(keep, sorted_e * C + rank, E_loc * C)
        # §Perf iteration A2: index-scatter dispatch — scatter token IDS into
        # the buffer slots and gather activations ONCE: the materialized
        # working set is (E_loc*C, d) (the local experts' capacity) instead
        # of (T*k, d) (every slot incl. foreign) — 12x smaller at top-8/16
        # banks.
        tok_sorted = tok_of[order]
        buf_tok = jnp.full((E_loc * C + 1,), T, jnp.int32).at[dest].set(
            jnp.where(keep, tok_sorted, T))[:-1]
        gate_sorted = gates.reshape(-1)[order]
        buf_gate = jnp.zeros((E_loc * C + 1,), jnp.float32).at[dest].set(
            jnp.where(keep, gate_sorted, 0.0))[:-1]
        xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)])
        buf = xf_pad[buf_tok].reshape(E_loc, C, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) \
            * jnp.einsum("ecd,edf->ecf", buf, wu)
        y = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E_loc * C, d)
        y = y * buf_gate[:, None].astype(y.dtype)
        out = jnp.zeros((T + 1, d), xf.dtype).at[buf_tok].add(y)[:-1]
        out = jax.lax.psum(out, bank)
        return out.reshape(B_l, S_l, d)

    return shard_map(
        local, mesh=dist.mesh,
        in_specs=(P(dp, None, None), P(None, None),
                  P(bank, None, None), P(bank, None, None),
                  P(bank, None, None)),
        out_specs=P(dp, None, None),
    )(x, w_router, w_gate, w_up, w_down)
