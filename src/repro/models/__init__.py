"""Model zoo: LM transformers (dense/GQA/MoE), recsys (DLRM/DIN/BERT4Rec/
xDeepFM), and GNN (GAT) — pure-function init/apply pytrees, no framework."""
