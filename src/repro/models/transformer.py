"""LM transformer family (llama-style): dense GQA + MoE variants.

Covers smollm-135m/360m, granite-20b (MQA), qwen3-moe-30b-a3b,
granite-moe-1b-a400m. Pure init/apply; layer weights are stacked on a leading
L axis and the forward is a ``lax.scan`` with full remat per layer (keeps HLO
small and activation memory flat — required for the 20B train dry-run).

GQA handling: KV projections are kept replicated (Hkv is small) and KV heads
are expanded to Hq at the attention site; query heads shard over the ``model``
axis. Decode uses a sequence-sharded KV cache with a flash-decode partial
softmax combine (dist/collectives.py) — this is what makes `long_500k`
(524k-token KV, batch 1) fit: decode attention is O(S), i.e. sub-quadratic,
see DESIGN.md §4.

The unembed is vocab-sharded and the CE loss is computed in sequence chunks so
(B, S, V) logits never materialize.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import DistCtx
from repro.models import layers as L
from repro.models.common import dense_init, embed_init, shard, dp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int                      # dense ff, or per-expert ff when moe set
    vocab: int
    moe: MoESpec | None = None
    mlp_type: str = "swiglu"       # "swiglu" (llama) | "gelu" (gpt-bigcode)
    tied_embeddings: bool = False  # unembed = embed.T (smollm/granite)
    rope_theta: float = 10000.0
    q_chunk: int = 1024
    kv_chunk: int = 1024
    loss_chunk: int = 512
    dtype: Any = jnp.bfloat16      # compute dtype
    param_dtype: Any = jnp.float32
    # Dry-run accounting mode: unroll every scan (layers, KV chunks, loss
    # chunks) so compiled cost_analysis counts ALL iterations — XLA reports
    # while-loop bodies once, which under-counts a 52-layer scan by 52x.
    # Functionally identical; only the HLO shape changes.
    unroll: bool = False
    # "gspmd": inferred sharding of the sort-based dispatch (paper-faithful
    # naive distribution baseline); "shardmap": explicit expert-parallel
    # dispatch + psum combine (§Perf iteration A — ~300x less ICI traffic).
    moe_impl: str = "shardmap"

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def n_mlp_mats(self) -> int:
        return 3 if self.mlp_type == "swiglu" else 2

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 so embed/unembed shard on any model axis;
        pad logits are masked to -inf in the loss and serving heads."""
        return -(-self.vocab // 256) * 256

    def param_count(self) -> int:
        d, ff, V = self.d_model, self.d_ff, self.vocab
        attn = d * self.qkv_dim + 2 * d * self.kv_dim + self.qkv_dim * d
        if self.moe:
            mlp = (self.moe.n_experts * self.n_mlp_mats * d * ff
                   + d * self.moe.n_experts)
        else:
            mlp = self.n_mlp_mats * d * ff
        per_layer = attn + mlp + 2 * d
        emb = V * d if self.tied_embeddings else 2 * V * d
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only) — for 6·N·D."""
        if not self.moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        attn = d * self.qkv_dim + 2 * d * self.kv_dim + self.qkv_dim * d
        mlp = self.moe.top_k * self.n_mlp_mats * d * ff + d * self.moe.n_experts
        emb = self.vocab * d if self.tied_embeddings else 2 * self.vocab * d
        return self.n_layers * (attn + mlp + 2 * d) + emb + d


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg: LMConfig, key) -> dict:
    keys = jax.random.split(key, 8)
    d, pd = cfg.d_model, cfg.param_dtype
    LN = cfg.n_layers

    def stack(initfn, *shape, k, scale=None):
        ks = jax.random.split(k, LN)
        return jax.vmap(lambda kk: initfn(kk, shape, scale=scale, dtype=pd))(ks)

    layer = {
        "ln1": jnp.ones((LN, d), pd),
        "ln2": jnp.ones((LN, d), pd),
        "wq": stack(dense_init, d, cfg.qkv_dim, k=keys[0]),
        "wk": stack(dense_init, d, cfg.kv_dim, k=keys[1]),
        "wv": stack(dense_init, d, cfg.kv_dim, k=keys[2]),
        "wo": stack(dense_init, cfg.qkv_dim, d, k=keys[3]),
    }
    if cfg.moe:
        E, ff = cfg.moe.n_experts, cfg.d_ff
        layer |= {
            "w_router": stack(dense_init, d, E, k=keys[4]),
            "w_gate": stack(dense_init, E, d, ff, k=keys[5],
                            scale=1.0 / np.sqrt(d)),
            "w_up": stack(dense_init, E, d, ff, k=keys[6],
                          scale=1.0 / np.sqrt(d)),
            "w_down": stack(dense_init, E, ff, d, k=keys[7],
                            scale=1.0 / np.sqrt(ff)),
        }
    else:
        ff = cfg.d_ff
        layer |= {
            "w_up": stack(dense_init, d, ff, k=keys[6]),
            "w_down": stack(dense_init, ff, d, k=keys[7]),
        }
        if cfg.mlp_type == "swiglu":
            layer["w_gate"] = stack(dense_init, d, ff, k=keys[5])
    params = {
        "embed": embed_init(keys[4], (cfg.padded_vocab, d), dtype=pd),
        "layers": layer,
        "final_norm": jnp.ones((d,), pd),
    }
    if not cfg.tied_embeddings:
        params["unembed"] = dense_init(keys[3], (d, cfg.padded_vocab),
                                       dtype=pd)
    return params


def unembed_matrix(cfg: LMConfig, params: dict) -> Array:
    """(d, V) output projection — embed.T when tied."""
    if cfg.tied_embeddings:
        return params["embed"].T
    return params["unembed"]


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: LMConfig, dist: DistCtx | None, h: Array, lw: dict,
               positions: Array, causal: bool = True) -> Array:
    """One transformer block. h: (B, S, d)."""
    B, S, d = h.shape
    G = cfg.n_heads // cfg.n_kv_heads
    x = L.rms_norm(h, lw["ln1"].astype(cfg.dtype))
    q = (x @ lw["wq"].astype(cfg.dtype)).reshape(B, S, cfg.n_heads, cfg.d_head)
    k = (x @ lw["wk"].astype(cfg.dtype)).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = (x @ lw["wv"].astype(cfg.dtype)).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    # GQA -> MHA: expand KV to query heads (local slice only under GSPMD)
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    q = shard(q, dist, dp(dist), None, "model", None)
    k = shard(k, dist, dp(dist), None, "model", None)
    v = shard(v, dist, dp(dist), None, "model", None)
    attn = L.blockwise_attention(q, k, v, causal=causal,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                 unroll=cfg.unroll)
    attn = attn.reshape(B, S, cfg.qkv_dim)
    h = h + attn @ lw["wo"].astype(cfg.dtype)
    h = shard(h, dist, dp(dist), None, None)

    x = L.rms_norm(h, lw["ln2"].astype(cfg.dtype))
    if cfg.moe:
        if dist is not None and cfg.moe_impl == "shardmap":
            y = L.moe_layer_sharded(
                x, lw["w_router"].astype(cfg.dtype),
                lw["w_gate"].astype(cfg.dtype), lw["w_up"].astype(cfg.dtype),
                lw["w_down"].astype(cfg.dtype),
                top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, dist=dist)
        else:
            xf = x.reshape(B * S, d)
            y, stats = L.moe_layer(
                xf, lw["w_router"].astype(cfg.dtype),
                lw["w_gate"].astype(cfg.dtype), lw["w_up"].astype(cfg.dtype),
                lw["w_down"].astype(cfg.dtype),
                top_k=cfg.moe.top_k, capacity_factor=cfg.moe.capacity_factor)
            y = y.reshape(B, S, d)
    elif cfg.mlp_type == "swiglu":
        y = L.glu_mlp(x, lw["w_gate"].astype(cfg.dtype),
                      lw["w_up"].astype(cfg.dtype),
                      lw["w_down"].astype(cfg.dtype))
    else:
        y = jax.nn.gelu(x @ lw["w_up"].astype(cfg.dtype)) \
            @ lw["w_down"].astype(cfg.dtype)
    h = h + y
    return shard(h, dist, dp(dist), None, None)


def forward_hidden(cfg: LMConfig, params: dict, tokens: Array,
                   dist: DistCtx | None, causal: bool = True) -> Array:
    """tokens (B, S) -> final hidden (B, S, d). Scan over layers w/ remat."""
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    h = shard(h, dist, dp(dist), None, None)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    body = partial(_layer_fwd, cfg, dist, positions=positions, causal=causal)
    step = jax.checkpoint(lambda hh, lw: (body(hh, lw), None))

    h, _ = jax.lax.scan(step, h, params["layers"],
                        unroll=cfg.n_layers if cfg.unroll else 1)
    return L.rms_norm(h, params["final_norm"].astype(cfg.dtype))


def chunked_ce_loss(cfg: LMConfig, h: Array, unembed: Array, labels: Array,
                    dist: DistCtx | None) -> Array:
    """Mean CE without materializing (B, S, V) logits: scan over S chunks.

    The label log-prob is extracted with a one-hot dot so the vocab-sharded
    logits are never gathered (GSPMD partial-reduces instead).
    """
    B, S, d = h.shape
    c = min(cfg.loss_chunk, S)
    assert S % c == 0
    n = S // c
    w = unembed.astype(cfg.dtype)

    pad_mask = (jnp.arange(cfg.padded_vocab) < cfg.vocab)

    def step(tot, i):
        hc = jax.lax.dynamic_slice_in_dim(h, i * c, c, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
        logits = jnp.einsum("bsd,dv->bsv", hc, w,
                            preferred_element_type=jnp.float32)
        logits = jnp.where(pad_mask, logits, -1e30)   # mask vocab padding
        logits = shard(logits, dist, dp(dist), None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        # bf16 one-hot is EXACT (0/1) and halves this logits-sized buffer
        onehot = jax.nn.one_hot(lc, cfg.padded_vocab, dtype=jnp.bfloat16)
        ll = jnp.einsum("bsv,bsv->bs", logits, onehot,
                        preferred_element_type=jnp.float32)
        return tot + (lse - ll).sum(), None

    tot, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), jnp.arange(n),
                          unroll=n if cfg.unroll else 1)
    return tot / (B * S)


def lm_loss(cfg: LMConfig, params: dict, tokens: Array, labels: Array,
            dist: DistCtx | None = None) -> Array:
    h = forward_hidden(cfg, params, tokens, dist)
    return chunked_ce_loss(cfg, h, unembed_matrix(cfg, params), labels, dist)


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    k: Array        # (L, B, S_max, Hkv, Dh)
    v: Array
    length: Array   # () int32 — tokens already in cache

    @classmethod
    def empty(cls, cfg: LMConfig, batch: int, s_max: int):
        shp = (cfg.n_layers, batch, s_max, cfg.n_kv_heads, cfg.d_head)
        return cls(k=jnp.zeros(shp, cfg.dtype), v=jnp.zeros(shp, cfg.dtype),
                   length=jnp.zeros((), jnp.int32))


def decode_step(cfg: LMConfig, params: dict, cache: KVCache, token: Array,
                dist: DistCtx | None = None,
                seq_axes: tuple[str, ...] = ("model",),
                ) -> tuple[Array, KVCache]:
    """One decode step: token (B,) -> logits (B, V), updated cache.

    KV cache is sequence-sharded over ``seq_axes``; attention uses the
    flash-decode partial-softmax combine across those axes.
    """
    from repro.dist.collectives import seqsharded_decode_attention

    B = token.shape[0]
    d = cfg.d_model
    h = jnp.take(params["embed"], token, axis=0).astype(cfg.dtype)  # (B, d)
    pos = cache.length

    def layer(carry, xs):
        h = carry
        lw, kc, vc = xs
        x = L.rms_norm(h, lw["ln1"].astype(cfg.dtype))
        q = (x @ lw["wq"].astype(cfg.dtype)).reshape(B, cfg.n_heads, cfg.d_head)
        k = (x @ lw["wk"].astype(cfg.dtype)).reshape(B, cfg.n_kv_heads, cfg.d_head)
        v = (x @ lw["wv"].astype(cfg.dtype)).reshape(B, cfg.n_kv_heads, cfg.d_head)
        posb = jnp.full((B, 1), pos)
        q = L.apply_rope(q[:, None], posb, cfg.rope_theta)[:, 0]
        k = L.apply_rope(k[:, None], posb, cfg.rope_theta)[:, 0]
        attn, kc, vc = seqsharded_decode_attention(
            q, k, v, kc, vc, pos, dist=dist, seq_axes=seq_axes)
        h = h + attn.reshape(B, cfg.qkv_dim) @ lw["wo"].astype(cfg.dtype)
        x = L.rms_norm(h, lw["ln2"].astype(cfg.dtype))
        if cfg.moe:
            y, _ = L.moe_layer(
                x, lw["w_router"].astype(cfg.dtype),
                lw["w_gate"].astype(cfg.dtype), lw["w_up"].astype(cfg.dtype),
                lw["w_down"].astype(cfg.dtype),
                top_k=cfg.moe.top_k, capacity_factor=2.0)
        elif cfg.mlp_type == "swiglu":
            y = L.glu_mlp(x, lw["w_gate"].astype(cfg.dtype),
                          lw["w_up"].astype(cfg.dtype),
                          lw["w_down"].astype(cfg.dtype))
        else:
            y = jax.nn.gelu(x @ lw["w_up"].astype(cfg.dtype)) \
                @ lw["w_down"].astype(cfg.dtype)
        return h + y, (kc, vc)

    h, (k_new, v_new) = jax.lax.scan(
        layer, h, (params["layers"], cache.k, cache.v),
        unroll=cfg.n_layers if cfg.unroll else 1)
    h = L.rms_norm(h, params["final_norm"].astype(cfg.dtype))
    logits = jnp.einsum("bd,dv->bv", h,
                        unembed_matrix(cfg, params).astype(cfg.dtype),
                        preferred_element_type=jnp.float32)
    logits = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, logits, -1e30)
    logits = shard(logits, dist, dp(dist) if token.shape[0] > 1 else None, "model")
    return logits, KVCache(k=k_new, v=v_new, length=cache.length + 1)


def prefill(cfg: LMConfig, params: dict, tokens: Array,
            dist: DistCtx | None = None) -> Array:
    """Prefill: (B, S) -> last-position logits (B, V). Chunked attention keeps
    the 32k×32k score matrix off HBM; KV cache fill is a byproduct omitted here
    (the dry-run measures the compute path)."""
    h = forward_hidden(cfg, params, tokens, dist)
    last = h[:, -1]
    logits = jnp.einsum("bd,dv->bv", last,
                        unembed_matrix(cfg, params).astype(cfg.dtype),
                        preferred_element_type=jnp.float32)
    logits = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, logits, -1e30)
    return shard(logits, dist, dp(dist), "model")
