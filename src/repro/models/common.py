"""Shared model plumbing: initializers, dtype policy, mesh-aware constraints."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import DistCtx

Array = jax.Array


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-ish) used across the zoo."""
    if scale is None:
        scale = 1.0 / np.sqrt(shape[0])
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def shard(x: Array, dist: DistCtx | None, *axes) -> Array:
    """with_sharding_constraint if a mesh is active, no-op otherwise.

    axes entries: mesh axis name, tuple of names, or None per array dim.
    """
    if dist is None:
        return x
    spec = jax.sharding.PartitionSpec(*axes)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(dist.mesh, spec))


def dp(dist: DistCtx | None):
    """The batch-sharding axis spec entry for the active mesh."""
    if dist is None:
        return None
    return dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0]


def cast(x: Array, dtype) -> Array:
    return x.astype(dtype) if x.dtype != dtype else x


def split_statics(statics: dict) -> tuple[dict, dict]:
    """Split a model ``statics`` dict into (array leaves, python-int meta).

    The meta ints (n_banks, rows_per_bank, ...) are STATIC — they shape the
    banked-table layout — so they must stay out of jit-traced arguments; the
    launch code passes the arrays as args and re-injects the meta from
    closure:  loss = lambda p, s, b: f(cfg, p, {**s, **meta}, b).
    """
    import numpy as _np
    arrays = {k: v for k, v in statics.items()
              if hasattr(v, "ndim") and not isinstance(v, (int, _np.integer))}
    meta = {k: v for k, v in statics.items() if k not in arrays}
    return arrays, meta
