"""Tiered-precision embedding storage (README.md §byte budget).

The byte-bandwidth counterpart of the §3 partitioners: telemetry decides
which rows deserve full precision (the hot head) and which can shrink to
int8 / packed int4 (the cold tail), `TieredTable` stores the mix in
fixed-shape banked arrays, and the fused lookup kernels dequantize each
DMA'd row in-kernel (kernels/README.md §dequant).
"""
from repro.quant.quantize import (HOT_DTYPES, QuantSpec, TIER_HOT, TIER_INT4,
                                  TIER_INT8, bytes_of_tier, dequant_rows_f32,
                                  quantize_rows, row_bytes, tier_nbytes)
from repro.quant.tiers import TierAssignment, assign_tiers
from repro.quant.tiered import (PAD_TIER, TieredTable, build_tiered_table,
                                modeled_bank_byte_load, packed_tier_map,
                                retier_tiered)

__all__ = [
    "HOT_DTYPES", "PAD_TIER", "QuantSpec", "TIER_HOT", "TIER_INT4",
    "TIER_INT8", "TierAssignment", "TieredTable", "assign_tiers",
    "build_tiered_table", "bytes_of_tier", "dequant_rows_f32",
    "modeled_bank_byte_load", "packed_tier_map", "quantize_rows",
    "retier_tiered", "row_bytes", "tier_nbytes",
]
