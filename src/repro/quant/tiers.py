"""Telemetry-driven tier assignment: frequency estimates -> per-row tiers.

The policy is the byte-bandwidth counterpart of the §3.2 greedy: the
partitioners spread row *reads* across banks; the tier assigner shrinks the
*bytes per read*, spending a byte budget where the telemetry says it buys
the most accuracy — the hot head (which dominates both traffic and gradient
signal) keeps full precision, the cold tail (rarely read, so its
quantization error rarely surfaces) drops to int8/int4.

Deterministic in (freq, spec): ranking uses a stable argsort, so the
replanner's re-tier decisions — and the bench gates built on them — replay
exactly from a seed.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.quant.quantize import (QuantSpec, TIER_HOT, TIER_INT4, TIER_INT8,
                                  tier_nbytes)


@dataclasses.dataclass(frozen=True)
class TierAssignment:
    """Per-vocab-row tier map + the byte accounting it implies."""

    tier_of_row: np.ndarray            # (vocab,) int32
    n_hot: int
    n_int8: int
    n_int4: int
    avg_bytes_per_row: float

    @property
    def counts(self) -> tuple[int, int, int]:
        return self.n_hot, self.n_int8, self.n_int4


def assign_tiers(freq: np.ndarray, spec: QuantSpec, dim: int
                 ) -> TierAssignment:
    """Rank rows by estimated frequency; fit tiers to the byte budget.

    1. the ``spec.min_hot_rows`` hottest rows are pinned to the hot tier,
    2. everything else starts int8,
    3. if the budget is still exceeded and int4 is enabled, the COLDEST rows
       are demoted to int4, exactly as many as the budget arithmetic needs,
    4. if the budget has slack beyond all-int8, extra hottest rows are
       PROMOTED to the hot tier instead.

    A ``byte_budget`` of None skips steps 3-4 (hot head + int8 tail). An
    infeasible budget (below the int4 floor, or below int8 with int4
    disabled) degrades to the closest representable mix — tiering must never
    fail a replan.
    """
    freq = np.asarray(freq, np.float64)
    vocab = freq.shape[0]
    bh, b8, b4 = (int(x) for x in tier_nbytes(dim, spec.hot_dtype))
    order = np.argsort(-freq, kind="stable")

    tier = np.full(vocab, TIER_INT8, np.int32)
    n_hot = min(int(spec.min_hot_rows), vocab)
    tier[order[:n_hot]] = TIER_HOT
    rest = vocab - n_hot
    n4 = 0
    if spec.byte_budget is not None and rest > 0:
        remaining = spec.byte_budget * vocab - n_hot * bh
        if remaining < b8 * rest:
            # b8 == b4 at dim 1 (packing buys nothing): int4 demotion is a
            # no-op there, so the all-int8 tail is already the floor
            if spec.enable_int4 and b8 > b4:
                n4 = int(np.ceil((b8 * rest - remaining) / (b8 - b4)))
                n4 = min(max(n4, 0), rest)
                tier[order[vocab - n4:]] = TIER_INT4
            # int4 off: all-int8 tail is the floor — best effort
        else:
            extra = int((remaining - b8 * rest) // (bh - b8))
            extra = min(max(extra, 0), rest)
            tier[order[n_hot:n_hot + extra]] = TIER_HOT
            n_hot += extra
            rest -= extra
    lut = tier_nbytes(dim, spec.hot_dtype).astype(np.float64)
    avg = float(lut[tier].mean()) if vocab else float(bh)
    return TierAssignment(tier_of_row=tier, n_hot=n_hot,
                          n_int8=vocab - n_hot - n4, n_int4=n4,
                          avg_bytes_per_row=avg)
