"""Row-wise quantization primitives for tiered embedding storage.

UpDLRM's lookup hot path is bound by bytes moved per row (the same Eq. 1
bandwidth term the partitioners balance across banks); this module shrinks
the bytes. Three storage tiers, coded in a per-row ``tier`` map:

  ``TIER_HOT``   — the hot head keeps full precision (bf16 by default, fp32
                   selectable): bytes are the dtype's little-endian bit
                   pattern, dequant is an exact bitcast.
  ``TIER_INT8``  — row-wise symmetric int8: ``scale = amax / 127``,
                   ``q = clip(rint(x / scale), -127, 127)``. Per-element
                   error is bounded by ``scale / 2``.
  ``TIER_INT4``  — two's-complement 4-bit pairs packed one byte per two
                   values (value 2j in the LOW nibble of byte j, 2j+1 in the
                   HIGH nibble); ``scale = amax / 7``.

Every tier's bytes live in ONE ``(rows, row_bytes)`` int8 payload array
(``row_bytes`` = the hot tier's width, so the array shape never depends on
the tier mix — the same fixed-shape trick the adaptive runtime plays with
``rows_per_bank``). A quantized row simply uses a prefix of its byte slot;
the bytes actually *moved* per read are the tier's width, which is what the
benchmarks model and the partitioners balance.

``quantize_rows`` is host-side numpy (it runs on the replan/swap path,
between micro-batches). ``dequant_rows_f32`` is the ONE home of the fp32
dequant math: the jnp fallback scan gathers payload rows and calls it, and
the Pallas kernel calls it on each DMA'd row — identical elementwise fp32
ops, which is what makes kernel-vs-fallback parity bit-exact
(tests/test_quant.py pins it).
"""
from __future__ import annotations

import dataclasses

import numpy as np

TIER_HOT = 0
TIER_INT8 = 1
TIER_INT4 = 2

HOT_DTYPES = ("bf16", "fp32")


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Tiered-precision policy for one banked table.

    ``byte_budget`` is the target AVERAGE stored bytes per row; the tier
    assigner (quant/tiers.py) keeps ``min_hot_rows`` of the hottest rows in
    the hot dtype, fills the rest with int8, and demotes the coldest rows to
    packed int4 until the budget is met (int8-only when ``enable_int4`` is
    off — then a budget below the int8 width is best-effort). ``None``
    means "int8 tail, no int4 pressure": hot head + everything else int8.
    """

    hot_dtype: str = "bf16"            # 'bf16' | 'fp32'
    enable_int4: bool = True
    byte_budget: float | None = None   # target avg stored bytes/row
    min_hot_rows: int = 8              # hot head always kept full-precision

    def __post_init__(self):
        if self.hot_dtype not in HOT_DTYPES:
            raise ValueError(f"hot_dtype must be one of {HOT_DTYPES}, "
                             f"got {self.hot_dtype!r}")


def tier_nbytes(dim: int, hot_dtype: str = "bf16") -> np.ndarray:
    """(3,) stored/moved bytes per row for [TIER_HOT, TIER_INT8, TIER_INT4]."""
    hot = dim * (2 if hot_dtype == "bf16" else 4)
    return np.array([hot, dim, (dim + 1) // 2], dtype=np.int64)


def row_bytes(dim: int, hot_dtype: str = "bf16") -> int:
    """Payload slot width: the hot tier's row size (every tier fits in it)."""
    return int(tier_nbytes(dim, hot_dtype)[TIER_HOT])


def bytes_of_tier(tier: np.ndarray, dim: int,
                  hot_dtype: str = "bf16") -> np.ndarray:
    """Per-row moved-bytes vector for a tier map — the partitioners' and
    benchmarks' byte-load currency (``freq * bytes_of_tier`` is the bank
    byte-load the §3.2 greedy should balance under mixed precision)."""
    return tier_nbytes(dim, hot_dtype)[np.asarray(tier)]


def _hot_np_dtype(hot_dtype: str):
    if hot_dtype == "fp32":
        return np.float32
    import ml_dtypes
    return ml_dtypes.bfloat16


def _pack_int4(q: np.ndarray) -> np.ndarray:
    """(n, D) int8 in [-7, 7] -> (n, ceil(D/2)) packed nibbles."""
    n, d = q.shape
    if d % 2:
        q = np.concatenate([q, np.zeros((n, 1), q.dtype)], axis=1)
    lo = q[:, 0::2].astype(np.int16) & 0xF
    hi = q[:, 1::2].astype(np.int16) & 0xF
    return ((lo | (hi << 4)) & 0xFF).astype(np.uint8).view(np.int8)


def quantize_rows(rows: np.ndarray, tier: np.ndarray, *,
                  hot_dtype: str = "bf16") -> tuple[np.ndarray, np.ndarray]:
    """Quantize (n, D) fp rows into the fixed-width byte payload.

    Returns ``(payload (n, row_bytes) int8, scale (n,) fp32)``. Hot rows
    store their bit pattern with scale 1; quantized rows store the symmetric
    code with ``scale = amax / qmax`` (scale 1 for all-zero rows, so pad
    rows quantize deterministically). Unused trailing bytes stay zero.
    """
    rows = np.asarray(rows, np.float32)
    tier = np.asarray(tier)
    n, d = rows.shape
    payload = np.zeros((n, row_bytes(d, hot_dtype)), np.int8)
    scale = np.ones(n, np.float32)

    hot = tier == TIER_HOT
    if hot.any():
        hb = np.ascontiguousarray(
            rows[hot].astype(_hot_np_dtype(hot_dtype))).view(np.uint8)
        payload[hot, :hb.shape[1]] = hb.view(np.int8)

    for t, qmax, pack in ((TIER_INT8, 127, None), (TIER_INT4, 7, _pack_int4)):
        m = tier == t
        if not m.any():
            continue
        amax = np.abs(rows[m]).max(axis=1)
        s = np.where(amax > 0, amax / qmax, 1.0).astype(np.float32)
        q = np.clip(np.rint(rows[m] / s[:, None]), -qmax, qmax).astype(np.int8)
        pb = q if pack is None else pack(q)
        payload[np.nonzero(m)[0][:, None],
                np.arange(pb.shape[1])[None, :]] = pb
        scale[m] = s
    return payload, scale


def dequant_rows_f32(payload, scale, tier, dim: int,
                     hot_dtype: str = "bf16"):
    """Shared fp32 dequant: payload (..., row_bytes) int8, scale (...,)
    fp32, tier (...,) int -> (..., dim) fp32.

    Pure elementwise jnp — callable from the jnp fallback scan AND from
    inside the Pallas kernel body on a single DMA'd row; both paths run the
    SAME fp32 ops, so their bag sums are bit-identical. All three tier
    interpretations are computed and selected by ``tier`` (no control flow —
    the kernel's grid body stays branch-free).
    """
    import jax
    import jax.numpy as jnp

    b = payload.astype(jnp.int32) & 0xFF           # unsigned byte view
    if hot_dtype == "bf16":
        lo = b[..., 0:2 * dim:2]
        hi = b[..., 1:2 * dim:2]
        bits = ((hi << 8) | lo) << 16              # bf16 bits -> fp32 bits
    else:
        b0 = b[..., 0:4 * dim:4]
        b1 = b[..., 1:4 * dim:4]
        b2 = b[..., 2:4 * dim:4]
        b3 = b[..., 3:4 * dim:4]
        bits = b0 | (b1 << 8) | (b2 << 16) | (b3 << 24)
    hotv = jax.lax.bitcast_convert_type(bits.astype(jnp.int32), jnp.float32)

    s = scale.astype(jnp.float32)[..., None]
    q8 = payload[..., :dim].astype(jnp.float32) * s

    nh = (dim + 1) // 2
    h = payload[..., :nh].astype(jnp.int32)        # sign-extended bytes
    lo4 = ((h & 0xF) ^ 8) - 8                      # low nibble, 4-bit signed
    hi4 = (((h >> 4) & 0xF) ^ 8) - 8
    q4 = jnp.stack([lo4, hi4], axis=-1).reshape(
        *h.shape[:-1], 2 * nh)[..., :dim].astype(jnp.float32) * s

    t = tier[..., None]
    return jnp.where(t == TIER_HOT, hotv,
                     jnp.where(t == TIER_INT8, q8, q4))
