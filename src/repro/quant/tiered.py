"""TieredTable: a mixed-precision banked embedding table.

Same layout contract as ``core.embedding.BankedTable`` — packed rows at
``bank * rows_per_bank + slot``, replicated row->(bank, slot) remap vectors,
fixed per-bank capacity — but the row storage is the tiered byte payload of
``quant/quantize.py`` plus per-row ``scale`` and ``tier`` vectors. Every
array shape depends only on (capacity, dim, hot dtype), NEVER on the tier
mix, so a live re-tier swap feeds same-shape arrays to the compiled serve
step: zero recompiles, the same contract the EMT and cache lanes obey.

Two builders:

  ``build_tiered_table``  — from scratch: quantize every packed row of an fp
      BankedTable by its assigned tier (host-side; runs at startup).
  ``retier_tiered``       — the swap-path incremental: permute the previous
      payload through the migration's row permutation (stay rows keep their
      bytes — the fp values they were quantized from migrated bit-exactly),
      then re-quantize ONLY the rows whose tier changed (hot rows promoted
      on drift read their fp bytes, demoted rows re-quantize from the
      CURRENT fp values) plus newly-padded positions. Bit-identical to a
      from-scratch build at the same (table, tiers) — tests/test_quant.py
      pins it — because row-wise quantization is deterministic per (fp row,
      tier).

This module intentionally imports nothing from ``repro.core`` (core's
embedding layer imports the quant package for the tiered lookup); the fp
source table is duck-typed on the BankedTable fields it reads.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.quantize import (TIER_INT8, quantize_rows, row_bytes,
                                  tier_nbytes)

Array = jax.Array

PAD_TIER = TIER_INT8      # unpopulated slots: int8 zeros, scale 1 (see below)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TieredTable:
    """Pytree: tiered byte payload + per-row scale/tier + remap vectors."""

    payload: Array      # (n_banks * rows_per_bank, row_bytes) int8
    scale: Array        # (n_banks * rows_per_bank,) float32
    tier: Array         # (n_banks * rows_per_bank,) int32
    remap_bank: Array   # (vocab,) int32, replicated
    remap_slot: Array   # (vocab,) int32, replicated
    n_banks: int = dataclasses.field(metadata=dict(static=True))
    rows_per_bank: int = dataclasses.field(metadata=dict(static=True))
    dim: int = dataclasses.field(metadata=dict(static=True))
    hot_dtype: str = dataclasses.field(default="bf16",
                                       metadata=dict(static=True))

    @property
    def vocab(self) -> int:
        return self.remap_bank.shape[0]

    @property
    def row_bytes(self) -> int:
        return self.payload.shape[-1]

    def flat_remap(self) -> Array:
        return (self.remap_bank * self.rows_per_bank
                + self.remap_slot).astype(jnp.int32)

    def tier_of_row(self) -> np.ndarray:
        """(vocab,) tier per union-vocab row (the packed map pulled back
        through the remap) — what a from-scratch rebuild needs."""
        flat = (np.asarray(self.remap_bank, np.int64) * self.rows_per_bank
                + np.asarray(self.remap_slot))
        return np.asarray(self.tier)[flat]


def packed_tier_map(table, tier_of_row: np.ndarray) -> np.ndarray:
    """(capacity,) tier per packed position; pad slots get ``PAD_TIER``."""
    R = table.n_banks * table.rows_per_bank
    flat = (np.asarray(table.remap_bank, np.int64) * table.rows_per_bank
            + np.asarray(table.remap_slot))
    tier = np.full(R, PAD_TIER, np.int32)
    tier[flat] = np.asarray(tier_of_row, np.int32)
    return tier


def build_tiered_table(table, tier_of_row: np.ndarray, *,
                       hot_dtype: str = "bf16") -> TieredTable:
    """Quantize an fp BankedTable's packed rows into a TieredTable.

    Pad slots (all-zero rows) quantize to zero payload with scale 1 under
    ``PAD_TIER`` — deterministic, so the incremental retier can reproduce
    them bit-for-bit.
    """
    tier = packed_tier_map(table, tier_of_row)
    rows = np.asarray(table.packed, np.float32)
    payload, scale = quantize_rows(rows, tier, hot_dtype=hot_dtype)
    return TieredTable(
        payload=jnp.asarray(payload),
        scale=jnp.asarray(scale),
        tier=jnp.asarray(tier),
        remap_bank=table.remap_bank,
        remap_slot=table.remap_slot,
        n_banks=table.n_banks,
        rows_per_bank=table.rows_per_bank,
        dim=int(table.packed.shape[-1]),
        hot_dtype=hot_dtype,
    )


def _permute_rows(arr: np.ndarray, old_flat: np.ndarray,
                  new_flat: np.ndarray, new_len: int) -> np.ndarray:
    out = np.zeros((new_len,) + arr.shape[1:], arr.dtype)
    out[new_flat] = arr[old_flat]
    return out


def retier_tiered(prev: TieredTable, table, tier_of_row: np.ndarray
                  ) -> tuple[TieredTable, dict]:
    """Incremental rebuild for the swap path: ``table`` is the MIGRATED fp
    BankedTable (same row values, new layout), ``tier_of_row`` the fresh
    assignment. Only rows whose tier changed — promotions, demotions — and
    newly-padded slots are re-quantized (a device gather of just those
    rows); stay-tier rows carry their bytes through the row permutation.

    Returns ``(tiered, stats)`` with promoted/demoted/requantized counts.
    Bit-identical to ``build_tiered_table(table, tier_of_row)``.
    """
    old_flat = (np.asarray(prev.remap_bank, np.int64) * prev.rows_per_bank
                + np.asarray(prev.remap_slot))
    new_flat = (np.asarray(table.remap_bank, np.int64) * table.rows_per_bank
                + np.asarray(table.remap_slot))
    R = table.n_banks * table.rows_per_bank
    payload = _permute_rows(np.asarray(prev.payload), old_flat, new_flat, R)
    scale = _permute_rows(np.asarray(prev.scale), old_flat, new_flat, R)
    old_tier_of_row = np.asarray(prev.tier)[old_flat]

    new_tier = packed_tier_map(table, tier_of_row)
    # pad slots: deterministic zero/scale-1/PAD_TIER, matching quantize_rows
    # on an all-zero row (the from-scratch build's pad handling)
    pad = np.ones(R, bool)
    pad[new_flat] = False
    payload[pad] = 0
    scale[pad] = 1.0

    new_row_tier = np.asarray(tier_of_row, np.int32)
    changed_rows = np.nonzero(new_row_tier != old_tier_of_row)[0]
    if changed_rows.size:
        flat = new_flat[changed_rows]
        rows = np.asarray(jnp.take(table.packed, jnp.asarray(flat), axis=0),
                          np.float32)
        pb, sc = quantize_rows(rows, new_row_tier[changed_rows],
                               hot_dtype=prev.hot_dtype)
        payload[flat] = pb
        scale[flat] = sc
    stats = {
        "n_requantized": int(changed_rows.size),
        "n_promoted": int((new_row_tier < old_tier_of_row).sum()),
        "n_demoted": int((new_row_tier > old_tier_of_row).sum()),
    }
    tiered = TieredTable(
        payload=jnp.asarray(payload),
        scale=jnp.asarray(scale),
        tier=jnp.asarray(new_tier),
        remap_bank=table.remap_bank,
        remap_slot=table.remap_slot,
        n_banks=table.n_banks,
        rows_per_bank=table.rows_per_bank,
        dim=prev.dim,
        hot_dtype=prev.hot_dtype,
    )
    return tiered, stats


def modeled_bank_byte_load(tiered_tier_of_row: np.ndarray,
                           bank_of_row: np.ndarray, rows: np.ndarray,
                           dim: int, hot_dtype: str = "bf16",
                           n_banks: int | None = None) -> np.ndarray:
    """(n_banks,) bytes moved per bank for one batch's row reads — the
    byte-bandwidth analogue of bench_workload's row-read counts."""
    nb = int(bank_of_row.max()) + 1 if n_banks is None else n_banks
    lut = tier_nbytes(dim, hot_dtype).astype(np.float64)
    loads = np.zeros(nb)
    rows = np.asarray(rows)
    np.add.at(loads, bank_of_row[rows], lut[tiered_tier_of_row[rows]])
    return loads
