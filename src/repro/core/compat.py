"""Version compatibility shims for the pinned container jax (0.4.x).

``jax.shard_map`` and ``jax.sharding.AxisType`` graduated from
``jax.experimental`` after 0.4.x; model code imports the stable spellings from
here so a future jax bump is a one-file change.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f=None, /, *, mesh, in_specs, out_specs, **kw):
        # 0.4.x shard_map is strict about replication checks that the stable
        # API relaxed; check_rep=False matches post-0.5 default behaviour.
        kw.setdefault("check_rep", False)
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """jax.make_mesh without the axis_types kwarg (absent pre-0.5)."""
    try:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    except (AttributeError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)
