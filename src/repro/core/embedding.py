"""PIMEmbeddingBag: bank-partitioned embedding lookup (the paper's runtime).

The UPMEM dataflow (paper Fig. 4) maps 1:1 onto a ``shard_map`` over the mesh's
``model`` axis (DESIGN.md §2):

  stage 1  indices replicated across the bank axis        (CPU->DPU broadcast)
  stage 2  masked local gather + segment-reduce per bank  (in-DPU lookup+reduce)
  stage 3  psum of partial bag-sums over the bank axis    (DPU->CPU combine)

A table is *packed* by a PartitionPlan (core/partitioning.py): rows are
physically reordered so bank b's rows are contiguous, giving a global
``(n_banks * rows_per_bank, dim)`` array sharded ``P('model', None)`` — each
device holds exactly its bank.  The row->(bank, slot) remap is two replicated
``int32[vocab]`` vectors (8 B/row).

Stage 2 has two interchangeable implementations behind the ``backend`` knob:

  * ``backend='jnp'``    — a segment-scan over the bag length: the accumulator
    is (..., D) and only ONE (..., D) gather lives at a time, so the
    (..., L, D) gathered intermediate of a naive take->mask->sum never
    materializes (the XLA analogue of the paper's in-DPU reduce).
  * ``backend='pallas'`` — the fused TPU kernel (kernels/embedding_bag.py):
    scalar-prefetched indices + remap, double-buffered HBM row DMA, ownership
    mask and per-field offsets applied in-kernel. Off-TPU it runs in
    interpret mode (tests); on TPU it is the production hot path.
  * ``backend='auto'``   — 'pallas' on TPU, 'jnp' elsewhere.

Both run *inside* the shard_map (per bank) and both are differentiable: the
pallas path carries a custom_vjp whose backward is the row scatter-add that is
the exact transpose of the bag sum. The backward has its own backend pair
behind ``bwd_backend`` ('auto' follows the forward): the XLA segment-scan
scatter (``_scatter_bag_ct``), or the Pallas sorted-run scatter kernel
(``kernels/embedding_bag.ct_scatter_bag_pallas``) that keeps the gradient's
irregular row traffic on the same double-buffered near-memory path as the
lookup — a pallas training step never leaves the kernel layer for embedding
traffic.

Column-split mode (the paper's N_c knob) shards the embedding dim instead:
every bank gathers full bags for its dim-slice (no mask, no psum) and stage 3
becomes an all-gather of dim slices — the same Eq. 1 tradeoff with TPU
constants (§Perf explores it).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import shard_map
from repro.core.partitioning import PartitionPlan

Array = jax.Array

BACKENDS = ("auto", "jnp", "pallas", "tuned")


def _resolve_backend(backend: str) -> str:
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    if backend == "tuned":
        raise ValueError("backend='tuned' resolves through the dispatch "
                         "cache at the entry points — this path has no "
                         "tuned signature (pass 'auto')")
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


def _dispatch(path: str, *, vocab: int, dim: int, batch: int, bag_len,
              n_fields: int = 1, k_max: int = 1, tier_mix: str = "none",
              bwd_backend: str = "auto", tile_b: int,
              n_slots: int) -> tuple[str, int, int]:
    """Resolve ``backend='tuned'``: look the call signature up in the
    persisted dispatch cache (repro.tune, TUNE_dispatch.json) and return
    (backend, tile_b, n_slots) — the measured decision on a hit, today's
    defaults (the caller's tile_b/n_slots + the pre-tuner auto rule) on a
    miss. Shapes are static under jit, so this runs at trace time: a pure
    host dict lookup, deterministic per shape, zero recompiles."""
    from repro.tune.dispatch import decide
    d = decide(path, vocab=vocab, dim=dim, batch=batch, bag_len=bag_len,
               n_fields=n_fields, k_max=k_max, tier_mix=tier_mix,
               bwd_backend=bwd_backend, default_tile_b=tile_b,
               default_n_slots=n_slots)
    return d.backend, d.tile_b, d.n_slots


def _default_interpret(interpret: bool | None) -> bool:
    return jax.default_backend() != "tpu" if interpret is None else interpret


def _resolve_bwd(bwd_backend: str, fwd_backend: str) -> str:
    """Backward scatter backend: 'auto' rides the (resolved) forward choice,
    so ``backend='pallas'`` alone puts fwd AND bwd near memory; 'jnp' forces
    the XLA scatter fallback under a pallas forward (the parity baseline).
    Only consulted on the pallas forward — the jnp forward differentiates
    through its scan natively."""
    if bwd_backend not in BACKENDS or bwd_backend == "tuned":
        raise ValueError(f"bwd_backend must be one of "
                         f"{tuple(b for b in BACKENDS if b != 'tuned')}, "
                         f"got {bwd_backend!r} (the tuned dispatch keys on "
                         f"bwd_backend; it does not select one)")
    return fwd_backend if bwd_backend == "auto" else bwd_backend


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BankedTable:
    """Pytree: packed rows + remap. ``packed`` shards P(bank_axis, None)."""

    packed: Array       # (n_banks * rows_per_bank, dim)
    remap_bank: Array   # (vocab,) int32, replicated
    remap_slot: Array   # (vocab,) int32, replicated
    n_banks: int = dataclasses.field(metadata=dict(static=True))
    rows_per_bank: int = dataclasses.field(metadata=dict(static=True))

    @property
    def vocab(self) -> int:
        return self.remap_bank.shape[0]

    @property
    def dim(self) -> int:
        return self.packed.shape[-1]

    def flat_remap(self) -> Array:
        """row -> position in the unsharded packed array."""
        return (self.remap_bank * self.rows_per_bank
                + self.remap_slot).astype(jnp.int32)


def pack_table(table: np.ndarray, plan: PartitionPlan,
               dtype=None) -> BankedTable:
    """Physically reorder rows by the plan; pad banks to a common row count."""
    vocab, dim = table.shape
    rows_per_bank = int(plan.max_rows_per_bank)
    packed = np.zeros((plan.n_banks * rows_per_bank, dim), dtype=table.dtype)
    flat_pos = plan.bank_of_row.astype(np.int64) * rows_per_bank + plan.slot_of_row
    packed[flat_pos] = table
    if dtype is not None:
        packed = packed.astype(dtype)
    return BankedTable(
        packed=jnp.asarray(packed),
        remap_bank=jnp.asarray(plan.bank_of_row, dtype=jnp.int32),
        remap_slot=jnp.asarray(plan.slot_of_row, dtype=jnp.int32),
        n_banks=plan.n_banks,
        rows_per_bank=rows_per_bank,
    )


def init_banked(key, plan: PartitionPlan, dim: int, *, scale: float = 0.01,
                dtype=jnp.float32) -> BankedTable:
    """Random-init a banked table without materializing the unpacked layout."""
    rows_per_bank = int(plan.max_rows_per_bank)
    packed = jax.random.normal(
        key, (plan.n_banks * rows_per_bank, dim), dtype) * scale
    return BankedTable(
        packed=packed,
        remap_bank=jnp.asarray(plan.bank_of_row, dtype=jnp.int32),
        remap_slot=jnp.asarray(plan.slot_of_row, dtype=jnp.int32),
        n_banks=plan.n_banks,
        rows_per_bank=rows_per_bank,
    )


# ---------------------------------------------------------------------------
# replicated table: hot rows live on k banks, a hash splits their traffic
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ReplicatedTable:
    """Pytree: packed rows + replica-axis remap (core/partitioning.py
    ``ReplicatedPlan``). ``remap_bank``/``remap_slot`` are ``(vocab, k_max)``
    with cyclic-padded columns, so any column of row v is a valid copy; the
    lookup picks column ``wang_hash(bag) % k_max`` per bag. ``k_max == 1``
    (or a plan with no replicated rows) is layout-identical to
    ``BankedTable``.
    """

    packed: Array       # (n_banks * rows_per_bank, dim)
    remap_bank: Array   # (vocab, k_max) int32, replicated
    remap_slot: Array   # (vocab, k_max) int32, replicated
    n_banks: int = dataclasses.field(metadata=dict(static=True))
    rows_per_bank: int = dataclasses.field(metadata=dict(static=True))
    k_max: int = dataclasses.field(default=1, metadata=dict(static=True))

    @property
    def vocab(self) -> int:
        return self.remap_bank.shape[0]

    @property
    def dim(self) -> int:
        return self.packed.shape[-1]

    def flat_remap(self) -> Array:
        """(vocab * k_max,) copy -> position in the unsharded packed array —
        the flattened stream the kernel indexes at ``row * k_max + r``."""
        return (self.remap_bank * self.rows_per_bank
                + self.remap_slot).reshape(-1).astype(jnp.int32)

    def flat_bank(self) -> Array:
        """(vocab * k_max,) int32 bank per copy, kernel-stream order."""
        return self.remap_bank.reshape(-1).astype(jnp.int32)


def pack_replicated(table: np.ndarray, rplan, *,
                    rows_per_bank: int | None = None,
                    dtype=None) -> ReplicatedTable:
    """Physically materialize every copy the plan calls for: row v is
    written to all ``copies[v]`` of its (bank, slot) homes."""
    vocab, dim = table.shape
    if rows_per_bank is None:
        rows_per_bank = int(rplan.max_rows_per_bank)
    packed = np.zeros((rplan.n_banks * rows_per_bank, dim), dtype=table.dtype)
    vv, rr = np.nonzero(np.arange(rplan.k_max)[None, :]
                        < rplan.copies[:, None])
    pos = (rplan.bank_of_copy[vv, rr].astype(np.int64) * rows_per_bank
           + rplan.slot_of_copy[vv, rr])
    packed[pos] = table[vv]
    if dtype is not None:
        packed = packed.astype(dtype)
    return ReplicatedTable(
        packed=jnp.asarray(packed),
        remap_bank=jnp.asarray(rplan.bank_of_copy, dtype=jnp.int32),
        remap_slot=jnp.asarray(rplan.slot_of_copy, dtype=jnp.int32),
        n_banks=rplan.n_banks,
        rows_per_bank=rows_per_bank,
        k_max=rplan.k_max,
    )


# ---------------------------------------------------------------------------
# stage 2, jnp backend: segment-scan over the bag length
# ---------------------------------------------------------------------------

def _field_offsets_per_bag(off: Array, n: int) -> Array:
    """Bag n of a flattened (..., F, L) batch belongs to field n % F."""
    return off[jnp.arange(n, dtype=jnp.int32) % off.shape[0]]


def _bag_partial_scan(table: Array, idx: Array, *, remap: Array | None,
                      bank: Array | None, my_bank, off: Array) -> Array:
    """Bag sums over the trailing L WITHOUT a (..., L, D) intermediate.

    Scans the bag length, accumulating one (N, D) gather at a time — the jnp
    rendition of the kernel's streaming accumulate. ``remap`` maps global rows
    to local slots (identity when None); ``bank``/``my_bank`` apply the PIM
    ownership mask (skipped when bank is None); ``off`` is the per-field
    offset vector ((1,) zeros when fields are pre-offset).
    """
    lead, L = idx.shape[:-1], idx.shape[-1]
    flat = idx.reshape(-1, L)
    N = flat.shape[0]
    offs = _field_offsets_per_bag(off, N)
    dim = table.shape[-1]

    def body(acc, j):
        raw = flat[:, j]
        valid = raw >= 0
        row = jnp.where(valid, raw + offs, 0)
        if bank is None:
            mine = valid
        else:
            mine = valid & (bank[row] == my_bank)
        src = row if remap is None else remap[row]
        rows = jnp.take(table, jnp.where(mine, src, 0), axis=0)
        return acc + jnp.where(mine[:, None], rows, 0).astype(acc.dtype), None

    acc, _ = jax.lax.scan(body, jnp.zeros((N, dim), jnp.float32),
                          jnp.arange(L))
    return acc.reshape(*lead, dim).astype(table.dtype)


def _local_gather_partial(table_local: Array, bank: Array, slot: Array,
                          idx: Array, my_bank: Array) -> Array:
    """Dense (non-reducing) lookup partial: (...,) idx -> (..., dim)."""
    safe = jnp.where(idx >= 0, idx, 0)
    owner = bank[safe]
    s = slot[safe]
    mine = (idx >= 0) & (owner == my_bank)
    rows = jnp.take(table_local, jnp.where(mine, s, 0), axis=0)
    return jnp.where(mine[..., None], rows, 0)


# ---------------------------------------------------------------------------
# stage 2, pallas backend: fused kernel + scatter-add custom_vjp
# ---------------------------------------------------------------------------

def _pad_bags(flat: Array, tile_b: int) -> tuple[Array, int]:
    from repro.kernels.embedding_bag import pad_leading
    return pad_leading(flat, tile_b)


def _pad_lanes(table: Array, interpret: bool) -> tuple[Array, int]:
    if interpret:               # no lane constraint off-TPU: skip the copy
        return table, table.shape[-1]
    from repro.kernels.embedding_bag import pad_last_dim
    return pad_last_dim(table)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pallas_bag(cfg: tuple, packed: Array, bank: Array, slot: Array,
                off: Array, my: Array, idx: Array) -> Array:
    """One bank's stage-2 partial bag sums via the fused Pallas kernel.

    cfg = (tile_b, interpret, bwd, n_slots). idx (..., L) raw per-field ids;
    bank/slot the replicated remap; my () int32 bank id (< 0: own everything
    — the unsharded path, where slot is the flat remap). ``bwd`` selects the
    custom_vjp backward: 'pallas' = the sorted-run scatter kernel, 'jnp' =
    the XLA segment-scan scatter. ``n_slots`` is the row-DMA pipeline depth
    (fwd and bwd kernels alike).
    """
    from repro.kernels.embedding_bag import banked_embedding_bag_pallas
    tile_b, interpret, _, n_slots = cfg
    lead, L = idx.shape[:-1], idx.shape[-1]
    flat, n = _pad_bags(idx.reshape(-1, L).astype(jnp.int32), tile_b)
    table, d = _pad_lanes(packed, interpret)
    out = banked_embedding_bag_pallas(
        table, bank, slot, off, my.reshape(1).astype(jnp.int32), flat,
        tile_b=tile_b, interpret=interpret, n_slots=n_slots)
    return out[:n, :d].reshape(*lead, d)


def _pallas_bag_fwd(cfg, packed, bank, slot, off, my, idx):
    return _pallas_bag(cfg, packed, bank, slot, off, my, idx), \
        (packed, bank, slot, off, my, idx)


def _pallas_bag_bwd(cfg, res, ct):
    tile_b, interpret, bwd, n_slots = cfg
    packed, bank, slot, off, my, idx = res
    if bwd == "pallas":
        from repro.kernels.embedding_bag import ct_scatter_bag_pallas
        L = idx.shape[-1]
        d_tab = ct_scatter_bag_pallas(
            ct.reshape(-1, ct.shape[-1]),
            idx.reshape(-1, L).astype(jnp.int32), bank, slot, off,
            my.reshape(1).astype(jnp.int32), packed.shape[0], packed.dtype,
            tile_s=tile_b, interpret=interpret, n_slots=n_slots)
    else:
        d_tab = _scatter_bag_ct(packed.shape, packed.dtype, bank, slot, my,
                                idx, ct, off=off)
    return (d_tab, None, None, None, None, None)


_pallas_bag.defvjp(_pallas_bag_fwd, _pallas_bag_bwd)


# ---------------------------------------------------------------------------
# replicated stage 2: hash-picked replica per bag, k-way gradient scatter
# ---------------------------------------------------------------------------

def _replica_cols(n: int, k_max: int) -> Array:
    """Replica column per flattened bag — the SAME ``wang_hash(bag) % k``
    pick the kernel makes (kernels.embedding_bag.replica_of_bag), so jnp
    and pallas read identical copies."""
    from repro.kernels.embedding_bag import replica_of_bag
    return replica_of_bag(jnp.arange(n, dtype=jnp.int32), k_max)


def _replicated_bag_scan(table: Array, idx: Array, *, bank_flat: Array,
                         slot_flat: Array, my_bank, off: Array,
                         k_max: int) -> Array:
    """jnp fallback for the replicated stage 2: ``_bag_partial_scan``'s
    dataflow with the per-bag replica column folded into the remap index.
    Same j-ascending fp32 accumulation, so it bit-matches the kernel."""
    lead, L = idx.shape[:-1], idx.shape[-1]
    flat = idx.reshape(-1, L)
    N = flat.shape[0]
    offs = _field_offsets_per_bag(off, N)
    rcol = _replica_cols(N, k_max)
    dim = table.shape[-1]

    def body(acc, j):
        raw = flat[:, j]
        valid = raw >= 0
        row = jnp.where(valid, raw + offs, 0)
        rowk = row * k_max + rcol if k_max > 1 else row
        mine = valid & ((my_bank < 0) | (bank_flat[rowk] == my_bank))
        src = jnp.where(mine, slot_flat[rowk], 0)
        rows = jnp.take(table, src, axis=0)
        return acc + jnp.where(mine[:, None], rows, 0).astype(acc.dtype), None

    acc, _ = jax.lax.scan(body, jnp.zeros((N, dim), jnp.float32),
                          jnp.arange(L))
    return acc.reshape(*lead, dim).astype(table.dtype)


def _replicated_scatter_ct(shape, dtype, bank_flat, slot_flat, my, idx, ct,
                           *, off, k_max: int):
    """Transpose of the replicated bag sum (jnp): each entry's cotangent
    lands on the copy its forward read came through, so a row's copies
    together receive exactly the single-copy gradient."""
    L = idx.shape[-1]
    flat = idx.reshape(-1, L)
    N = flat.shape[0]
    ctf = ct.reshape(N, -1).astype(jnp.float32)
    offs = _field_offsets_per_bag(off, N)
    rcol = _replica_cols(N, k_max)

    def body(d_tab, j):
        raw = flat[:, j]
        valid = raw >= 0
        row = jnp.where(valid, raw + offs, 0)
        rowk = row * k_max + rcol if k_max > 1 else row
        mine = valid & ((my < 0) | (bank_flat[rowk] == my))
        src = jnp.where(mine, slot_flat[rowk], 0)
        upd = jnp.where(mine[:, None], ctf, 0)
        return d_tab.at[src].add(upd), None

    d_tab, _ = jax.lax.scan(body, jnp.zeros(shape, jnp.float32),
                            jnp.arange(L))
    return d_tab.astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _replicated_bag(cfg: tuple, packed: Array, bank_flat: Array,
                    slot_flat: Array, off: Array, my: Array,
                    idx: Array) -> Array:
    """Stage-2 partial bag sums over a REPLICATED table.

    cfg = (tile_b, interpret, backend, bwd, k_max, n_slots). bank_flat/
    slot_flat are the flattened (vocab * k_max,) replica-axis remap; each
    bag reads copy ``wang_hash(bag) % k_max``. The pallas path is the
    ordinary banked kernel with ``k_max`` folded into its entry resolver.
    """
    tile_b, interpret, backend, _, k_max, n_slots = cfg
    if backend == "pallas":
        from repro.kernels.embedding_bag import banked_embedding_bag_pallas
        lead, L = idx.shape[:-1], idx.shape[-1]
        flat, n = _pad_bags(idx.reshape(-1, L).astype(jnp.int32), tile_b)
        table, d = _pad_lanes(packed, interpret)
        out = banked_embedding_bag_pallas(
            table, bank_flat, slot_flat, off,
            my.reshape(1).astype(jnp.int32), flat,
            tile_b=tile_b, interpret=interpret, k_max=k_max,
            n_slots=n_slots)
        return out[:n, :d].reshape(*lead, d)
    return _replicated_bag_scan(packed, idx, bank_flat=bank_flat,
                                slot_flat=slot_flat, my_bank=my, off=off,
                                k_max=k_max)


def _replicated_bag_fwd(cfg, packed, bank_flat, slot_flat, off, my, idx):
    out = _replicated_bag(cfg, packed, bank_flat, slot_flat, off, my, idx)
    return out, (packed, bank_flat, slot_flat, off, my, idx)


def _replicated_bag_bwd(cfg, res, ct):
    tile_b, interpret, _, bwd, k_max, n_slots = cfg
    packed, bank_flat, slot_flat, off, my, idx = res
    if bwd == "pallas":
        from repro.kernels.embedding_bag import ct_scatter_bag_pallas
        L = idx.shape[-1]
        d_tab = ct_scatter_bag_pallas(
            ct.reshape(-1, ct.shape[-1]),
            idx.reshape(-1, L).astype(jnp.int32), bank_flat, slot_flat, off,
            my.reshape(1).astype(jnp.int32), packed.shape[0], packed.dtype,
            tile_s=tile_b, interpret=interpret, k_max=k_max,
            n_slots=n_slots)
    else:
        d_tab = _replicated_scatter_ct(packed.shape, packed.dtype, bank_flat,
                                       slot_flat, my, idx, ct, off=off,
                                       k_max=k_max)
    return (d_tab, None, None, None, None, None)


_replicated_bag.defvjp(_replicated_bag_fwd, _replicated_bag_bwd)


# ---------------------------------------------------------------------------
# tiered stage 2: in-kernel dequant forward, straight-through backward
# ---------------------------------------------------------------------------

def _tiered_partial_scan(payload: Array, scale: Array, tier: Array,
                         idx: Array, *, remap: Array, bank: Array, my_bank,
                         off: Array, dim: int, hot_dtype: str) -> Array:
    """jnp fallback for the tiered stage 2: the ``_bag_partial_scan``
    dataflow with the quant package's shared fp32 dequant applied to each
    gathered byte row. Per bag, entries accumulate in the same j-ascending
    fp32 order as the kernel's walk, so the two backends bit-match."""
    from repro.quant.quantize import dequant_rows_f32
    lead, L = idx.shape[:-1], idx.shape[-1]
    flat = idx.reshape(-1, L)
    N = flat.shape[0]
    offs = _field_offsets_per_bag(off, N)

    def body(acc, j):
        raw = flat[:, j]
        valid = raw >= 0
        row = jnp.where(valid, raw + offs, 0)
        mine = valid & ((my_bank < 0) | (bank[row] == my_bank))
        src = jnp.where(mine, remap[row], 0)
        rows = dequant_rows_f32(jnp.take(payload, src, axis=0),
                                jnp.take(scale, src), jnp.take(tier, src),
                                dim, hot_dtype)
        return acc + jnp.where(mine[:, None], rows, 0.0), None

    acc, _ = jax.lax.scan(body, jnp.zeros((N, dim), jnp.float32),
                          jnp.arange(L))
    return acc.reshape(*lead, dim)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _tiered_bag(cfg: tuple, fp_packed: Array, payload: Array,
                scale_bits: Array, tier: Array, bank: Array, slot: Array,
                off: Array, my: Array, idx: Array) -> Array:
    """One bank's tiered stage-2 partial bag sums (fp32).

    cfg = (tile_b, interpret, backend, bwd, dim, hot_dtype, n_slots). The
    forward
    reads ONLY the quantized payload (dequant in-kernel / in-scan);
    ``fp_packed`` — the fp master table the payload was quantized from — is
    the STRAIGHT-THROUGH gradient carrier: the backward scatters the bag
    cotangents onto it exactly like the full-precision lookup's backward,
    so training through mixed tiers updates fp rows as if the lookup had
    been full-precision (quantized rows included).
    """
    tile_b, interpret, backend, _, dim, hot, n_slots = cfg
    if backend == "pallas":
        from repro.kernels.embedding_bag import tiered_embedding_bag_pallas
        lead, L = idx.shape[:-1], idx.shape[-1]
        flat, n = _pad_bags(idx.reshape(-1, L).astype(jnp.int32), tile_b)
        pay, _ = _pad_lanes(payload, interpret)
        out = tiered_embedding_bag_pallas(
            pay, scale_bits, tier, bank, slot, off,
            my.reshape(1).astype(jnp.int32), flat, dim=dim, hot_dtype=hot,
            tile_b=tile_b, interpret=interpret, n_slots=n_slots)
        return out[:n].reshape(*lead, dim)
    scale = jax.lax.bitcast_convert_type(scale_bits, jnp.float32)
    return _tiered_partial_scan(payload, scale, tier, idx, remap=slot,
                                bank=bank, my_bank=my, off=off, dim=dim,
                                hot_dtype=hot)


def _tiered_bag_fwd(cfg, fp_packed, payload, scale_bits, tier, bank, slot,
                    off, my, idx):
    out = _tiered_bag(cfg, fp_packed, payload, scale_bits, tier, bank, slot,
                      off, my, idx)
    return out, (fp_packed, bank, slot, off, my, idx)


def _tiered_bag_bwd(cfg, res, ct):
    tile_b, interpret, _, bwd, _, _, n_slots = cfg
    fp_packed, bank, slot, off, my, idx = res
    if bwd == "pallas":
        from repro.kernels.embedding_bag import ct_scatter_bag_pallas
        L = idx.shape[-1]
        d_tab = ct_scatter_bag_pallas(
            ct.reshape(-1, ct.shape[-1]),
            idx.reshape(-1, L).astype(jnp.int32), bank, slot, off,
            my.reshape(1).astype(jnp.int32), fp_packed.shape[0],
            fp_packed.dtype, tile_s=tile_b, interpret=interpret,
            n_slots=n_slots)
    else:
        d_tab = _scatter_bag_ct(fp_packed.shape, fp_packed.dtype, bank, slot,
                                my, idx, ct, off=off)
    return (d_tab, None, None, None, None, None, None, None, None)


_tiered_bag.defvjp(_tiered_bag_fwd, _tiered_bag_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pallas_cache_bag(cfg: tuple, emt_packed: Array, cache_packed: Array,
                      e_bank: Array, e_slot: Array, c_bank: Array,
                      c_slot: Array, my: Array, cache_idx: Array,
                      resid_idx: Array) -> Array:
    """Fused Fig.-7 stage 2: Σ cache partials + Σ residual rows, one kernel.
    cfg = (tile_b, interpret, bwd, n_slots)."""
    from repro.kernels.embedding_bag import fused_cache_bag_pallas
    tile_b, interpret, _, n_slots = cfg
    lead = cache_idx.shape[:-1]
    ci, n = _pad_bags(cache_idx.reshape(-1, cache_idx.shape[-1])
                      .astype(jnp.int32), tile_b)
    ri, _ = _pad_bags(resid_idx.reshape(-1, resid_idx.shape[-1])
                      .astype(jnp.int32), tile_b)
    emt, d = _pad_lanes(emt_packed, interpret)
    cache, _ = _pad_lanes(cache_packed, interpret)
    out = fused_cache_bag_pallas(
        emt, cache, e_bank, e_slot, c_bank, c_slot,
        my.reshape(1).astype(jnp.int32), ci, ri,
        tile_b=tile_b, interpret=interpret, n_slots=n_slots)
    return out[:n, :d].reshape(*lead, d)


def _pallas_cache_bag_fwd(cfg, emt_packed, cache_packed, e_bank, e_slot,
                          c_bank, c_slot, my, cache_idx, resid_idx):
    out = _pallas_cache_bag(cfg, emt_packed, cache_packed, e_bank, e_slot,
                            c_bank, c_slot, my, cache_idx, resid_idx)
    return out, (emt_packed, cache_packed, e_bank, e_slot, c_bank, c_slot,
                 my, cache_idx, resid_idx)


def _scatter_bag_ct(shape, dtype, bank, slot, my, idx, ct, *, off=None):
    """Transpose of the bag sum: scatter ct rows back onto owned slots.

    Scans L like the forward, so the update buffer is one (N, D) slab — the
    (N*L, D) updates tensor of a flat scatter never materializes. Accumulates
    in fp32 regardless of the table dtype (thousands of colliding adds onto
    hot rows would round to zero in a bf16 accumulator), casting to the table
    dtype at the end — same policy as the kernels' forward accumulator.
    """
    L = idx.shape[-1]
    flat = idx.reshape(-1, L)
    N = flat.shape[0]
    ctf = ct.reshape(N, -1).astype(jnp.float32)
    offs = None if off is None else _field_offsets_per_bag(off, N)

    def body(d_tab, j):
        raw = flat[:, j]
        valid = raw >= 0
        row = jnp.where(valid, raw if offs is None else raw + offs, 0)
        mine = valid & ((my < 0) | (bank[row] == my))
        src = jnp.where(mine, slot[row], 0)
        upd = jnp.where(mine[:, None], ctf, 0)
        return d_tab.at[src].add(upd), None

    d_tab, _ = jax.lax.scan(body, jnp.zeros(shape, jnp.float32),
                            jnp.arange(L))
    return d_tab.astype(dtype)


def _pallas_cache_bag_bwd(cfg, res, ct):
    tile_b, interpret, bwd, n_slots = cfg
    (emt_packed, cache_packed, e_bank, e_slot, c_bank, c_slot, my,
     cache_idx, resid_idx) = res
    if bwd == "pallas":
        # dual scatter: the fused forward summed BOTH streams into one bag
        # row, so the same cotangent scatters onto the EMT (via the residual
        # ids) and the cache table (via the cache ids) — two invocations of
        # the sorted-run kernel, one per destination table
        from repro.kernels.embedding_bag import ct_scatter_bag_pallas
        ctf = ct.reshape(-1, ct.shape[-1])
        zero = jnp.zeros((1,), jnp.int32)
        myk = my.reshape(1).astype(jnp.int32)
        d_emt = ct_scatter_bag_pallas(
            ctf, resid_idx.reshape(-1, resid_idx.shape[-1]).astype(jnp.int32),
            e_bank, e_slot, zero, myk, emt_packed.shape[0], emt_packed.dtype,
            tile_s=tile_b, interpret=interpret, n_slots=n_slots)
        d_cache = ct_scatter_bag_pallas(
            ctf, cache_idx.reshape(-1, cache_idx.shape[-1]).astype(jnp.int32),
            c_bank, c_slot, zero, myk, cache_packed.shape[0],
            cache_packed.dtype, tile_s=tile_b, interpret=interpret,
            n_slots=n_slots)
    else:
        d_emt = _scatter_bag_ct(emt_packed.shape, emt_packed.dtype,
                                e_bank, e_slot, my, resid_idx, ct)
        d_cache = _scatter_bag_ct(cache_packed.shape, cache_packed.dtype,
                                  c_bank, c_slot, my, cache_idx, ct)
    return (d_emt, d_cache, None, None, None, None, None, None, None)


_pallas_cache_bag.defvjp(_pallas_cache_bag_fwd, _pallas_cache_bag_bwd)


# ---------------------------------------------------------------------------
# single-device semantics
# ---------------------------------------------------------------------------

def lookup_unsharded(t: BankedTable, idx: Array, *, reduce_bag: bool,
                     field_offsets: Array | None = None) -> Array:
    """Single-device semantics (CPU path + oracle), scan formulation."""
    off = jnp.zeros((1,), jnp.int32) if field_offsets is None \
        else jnp.asarray(field_offsets, jnp.int32)
    if reduce_bag:
        return _bag_partial_scan(t.packed, idx, remap=t.flat_remap(),
                                 bank=None, my_bank=None, off=off)
    assert field_offsets is None, "dense gather expects pre-offset rows"
    safe = jnp.where(idx >= 0, idx, 0)
    rows = jnp.take(t.packed, t.flat_remap()[safe], axis=0)
    return jnp.where((idx >= 0)[..., None], rows, 0)


# ---------------------------------------------------------------------------
# bounded-degraded reads: the per-bank liveness mask
# ---------------------------------------------------------------------------

def _effective_bank_map(remap_bank: Array, bank_live: Array,
                        n_banks: int) -> Array:
    """Rewrite the row->bank map so DEAD banks own nothing: rows homed on a
    dead bank get bank id ``n_banks``, which no ``axis_index`` ever matches —
    their contribution to the psum is exactly zero (the zero-fill degraded
    substitute), with NO kernel or shard_map changes. ``bank_live`` is a
    (n_banks,) bool jit ARGUMENT, so flipping a bank dead/alive between
    micro-batches is a pure argument change against one executable (the same
    zero-recompile contract as the remap vectors)."""
    return jnp.where(bank_live[remap_bank], remap_bank,
                     jnp.int32(n_banks)).astype(jnp.int32)


def _binary_live_map(remap_bank: Array, bank_live: Array) -> Array:
    """Unsharded rendition of the same trick: the single-device path owns
    everything via ``my_bank < 0``, which would bypass a bank-map mask — so
    degraded single-device lookups pass ``my_bank = 0`` against a binary map
    (0 = row's bank alive, 1 = dead). Ownership machinery unchanged on both
    backends."""
    return jnp.where(bank_live[remap_bank], 0, 1).astype(jnp.int32)


def degraded_row_counts(remap_bank: Array, bank_live: Array, rows: Array,
                        *, per_bag: bool = False) -> Array:
    """Count of reads that resolved to a dead bank.

    ``rows``: union-vocab row ids of any shape ``(B, ...)`` (negatives =
    padding). Returns ``(B,)`` int32 by default — the per-request
    ``degraded_read_count`` surfaced per batch so correctness is *boundedly*
    degraded, never silently wrong: a request with count 0 is bit-exact, a
    request with count k is missing exactly k row contributions.
    ``per_bag=True`` sums only the trailing (bag) axis instead — shape
    ``rows.shape[:-1]``, the granularity ``degraded_mean_fill`` needs.

    ``remap_bank`` may also be a replicated ``(vocab, k_max)`` map: a read
    then counts as degraded only when EVERY copy of its row is dead — any
    surviving replica serves it loss-free (``_replica_failover_maps``).
    """
    valid = rows >= 0
    safe = jnp.where(valid, rows, 0)
    live = bank_live[remap_bank[safe]]
    if remap_bank.ndim == 2:
        live = live.any(axis=-1)
    dead = valid & ~live
    if per_bag:
        return dead.sum(axis=-1).astype(jnp.int32)
    return dead.reshape(rows.shape[0], -1).sum(axis=-1).astype(jnp.int32)


def degraded_mean_fill(emb: Array, per_bag_counts: Array,
                       fallback_row: Array) -> Array:
    """Optional mean-fill substitute: add ``fallback_row`` (e.g. the table's
    mean row) once per dead read instead of the implicit zero row.
    ``per_bag_counts`` has ``emb``'s leading shape (``degraded_row_counts``
    with ``per_bag=True``). Applied OUTSIDE the bank collective — inside the
    shard_map every bank would add it and the psum would count it n_banks
    times."""
    return emb + per_bag_counts[..., None].astype(emb.dtype) * fallback_row


# ---------------------------------------------------------------------------
# measured traffic: union-vocab rows for the per-bank counters
# ---------------------------------------------------------------------------

def _traffic_rows(idx: Array, field_offsets: Array | None) -> Array:
    """The union-vocab row ids a batch actually reads: ``field_offsets``
    applied per flattened bag (bag n -> field n % F, exactly the
    ``_field_offsets_per_bag`` rule the lookup paths use), padding kept as
    -1. This is what the ``with_traffic`` counters count."""
    if field_offsets is None:
        return idx
    off = jnp.asarray(field_offsets, jnp.int32)
    flat = idx.reshape(-1, idx.shape[-1])
    offs = _field_offsets_per_bag(off, flat.shape[0])
    return jnp.where(flat >= 0, flat + offs[:, None], -1)


# ---------------------------------------------------------------------------
# distributed lookup
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistCtx:
    """Mesh context threaded through model code. None => single-device."""

    mesh: jax.sharding.Mesh
    dp_axes: tuple[str, ...]     # batch-sharded axes, e.g. ('pod', 'data')
    bank_axis: str = "model"

    @property
    def n_banks(self) -> int:
        return self.mesh.shape[self.bank_axis]

    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))


def banked_embedding_bag(t: BankedTable, idx: Array, dist: DistCtx | None,
                         *, reduce_bag: bool = True, backend: str = "auto",
                         bwd_backend: str = "auto",
                         field_offsets: Array | None = None,
                         tile_b: int = 8, n_slots: int = 2,
                         interpret: bool | None = None,
                         bank_live: Array | None = None,
                         with_traffic: bool = False):
    """The paper's stages 1-3. idx (..., L) -> (..., dim) [reduce] or
    (..., L, dim).

    ``field_offsets`` fuses all F fields of a (B, F, L) multi-hot batch into
    one stage-2 pass: bag (b, f) looks up ``idx + field_offsets[f]`` (applied
    in-kernel / in-scan, only to valid entries).

    ``bwd_backend`` selects the pallas forward's gradient scatter ('auto'
    follows ``backend``): 'pallas' keeps the backward's row traffic on the
    near-memory kernel path, 'jnp' is the XLA scatter fallback.

    ``bank_live`` ((n_banks,) bool, optional) is the degraded-serving mask:
    reads homed on a False bank resolve to the zero row (bounded degradation,
    see ``degraded_row_counts``). It rides as a jit ARGUMENT — the effective
    bank map is recomputed per call, so flipping a bank dead/alive never
    recompiles and needs no kernel changes.

    Under a mesh: shard_map over (dp_axes + bank_axis); indices are sharded on
    batch, replicated across banks (stage 1); each bank computes its partial
    with the selected ``backend`` (stage 2); psum over the bank axis (stage 3).

    ``backend='tuned'`` resolves (backend, tile_b, n_slots) through the
    persisted dispatch cache at trace time (repro.tune); a cache miss is the
    deterministic 'auto' default with the caller's tile_b/n_slots.

    ``with_traffic=True`` additionally returns a ``BankTraffic`` of exact
    per-bank measured read/byte counts for this batch — pure jnp on the
    same jit arguments (the ``degraded_row_counts`` pattern: zero extra
    executables, swap-safe). Return becomes ``(out, traffic)``.
    """
    if with_traffic:
        out = banked_embedding_bag(
            t, idx, dist, reduce_bag=reduce_bag, backend=backend,
            bwd_backend=bwd_backend, field_offsets=field_offsets,
            tile_b=tile_b, n_slots=n_slots, interpret=interpret,
            bank_live=bank_live)
        from repro.obs.traffic import bank_read_counts, traffic_from_reads
        reads = bank_read_counts(t.remap_bank,
                                 _traffic_rows(idx, field_offsets),
                                 t.n_banks, bank_live=bank_live)
        row_nbytes = t.packed.shape[-1] * np.dtype(t.packed.dtype).itemsize
        return out, traffic_from_reads(reads, row_nbytes)
    if backend == "tuned" and reduce_bag:
        backend, tile_b, n_slots = _dispatch(
            "plain", vocab=t.vocab, dim=t.dim,
            batch=int(np.prod(idx.shape[:-1])), bag_len=idx.shape[-1],
            n_fields=1 if field_offsets is None
            else int(np.shape(field_offsets)[0]),
            bwd_backend=bwd_backend, tile_b=tile_b, n_slots=n_slots)
    elif backend == "tuned":
        backend = "auto"        # dense gather: no kernel to tune
    backend = _resolve_backend(backend)
    bwd = _resolve_bwd(bwd_backend, backend)
    interpret = _default_interpret(interpret)
    if not reduce_bag and field_offsets is not None:
        raise ValueError("field_offsets requires reduce_bag=True — the dense "
                         "gather path expects pre-offset union-vocab rows")
    off = jnp.zeros((1,), jnp.int32) if field_offsets is None \
        else jnp.asarray(field_offsets, jnp.int32)

    if dist is None:
        if not reduce_bag:
            out = lookup_unsharded(t, idx, reduce_bag=False)
            if bank_live is not None:
                safe = jnp.where(idx >= 0, idx, 0)
                out = jnp.where(bank_live[t.remap_bank[safe]][..., None],
                                out, 0)
            return out
        if bank_live is None:
            bank_map, my = t.remap_bank, jnp.full((), -1, jnp.int32)
        else:
            bank_map = _binary_live_map(t.remap_bank, bank_live)
            my = jnp.zeros((), jnp.int32)
        if backend == "pallas":
            return _pallas_bag((tile_b, interpret, bwd, n_slots), t.packed,
                               bank_map, t.flat_remap(), off, my, idx)
        return _bag_partial_scan(
            t.packed, idx, remap=t.flat_remap(),
            bank=None if bank_live is None else bank_map,
            my_bank=None if bank_live is None else my, off=off)

    P = jax.sharding.PartitionSpec
    # batch shards over dp when divisible; tiny/odd batches (retrieval's B=1
    # query) replicate across dp instead
    dp_ok = idx.shape[0] % dist.dp_size() == 0
    dp = (dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0]) \
        if dp_ok else None
    bank = dist.bank_axis
    idx_spec = P(dp, *([None] * (idx.ndim - 1)))
    out_spec = P(dp, *([None] * (idx.ndim - (1 if reduce_bag else 0))))

    def fn(packed_local, bank_map, slot_map, off_local, idx_local):
        my = jax.lax.axis_index(bank)
        if not reduce_bag:
            part = _local_gather_partial(packed_local, bank_map, slot_map,
                                         idx_local, my)
        elif backend == "pallas":
            part = _pallas_bag((tile_b, interpret, bwd, n_slots),
                               packed_local, bank_map, slot_map, off_local,
                               my.astype(jnp.int32), idx_local)
        else:
            part = _bag_partial_scan(packed_local, idx_local,
                                     remap=slot_map, bank=bank_map,
                                     my_bank=my, off=off_local)
        return jax.lax.psum(part, bank)

    bank_map = t.remap_bank if bank_live is None \
        else _effective_bank_map(t.remap_bank, bank_live, t.n_banks)
    return shard_map(
        fn, mesh=dist.mesh,
        in_specs=(P(bank, None), P(), P(), P(), idx_spec),
        out_specs=out_spec,
    )(t.packed, bank_map, t.remap_slot, off, idx)


def banked_gather(t: BankedTable, idx: Array, dist: DistCtx | None, *,
                  bank_live: Array | None = None) -> Array:
    """Dense per-position lookup (LM token embedding / BERT4Rec item seq)."""
    return banked_embedding_bag(t, idx, dist, reduce_bag=False,
                                bank_live=bank_live)


def _replica_failover_maps(t: ReplicatedTable,
                           bank_live: Array) -> tuple[Array, Array]:
    """(bank_flat, slot_flat) with dead copies rerouted to a live sibling.

    For every (row, column) whose bank is dead, substitute the row's FIRST
    live column — a surviving replica covers a dead bank's head reads
    instantly, with no replan and no kernel change. Rows with NO live copy
    keep a binary dead marker (1 vs my_bank = 0), resolving to the zero-row
    degraded substitute exactly like the single-copy ``_binary_live_map``
    path. Pure jnp on jit ARGUMENTS, so flipping a bank dead/alive never
    recompiles.
    """
    live_rc = bank_live[t.remap_bank]                  # (V, k) bool
    any_live = live_rc.any(axis=1)
    first_live = jnp.argmax(live_rc, axis=1)           # 0 when none live
    col = jnp.arange(t.k_max, dtype=jnp.int32)[None, :]
    eff = jnp.where(live_rc, col, first_live[:, None]).astype(jnp.int32)
    rows = jnp.arange(t.vocab)[:, None]
    eff_bank = t.remap_bank[rows, eff]
    eff_slot = t.remap_slot[rows, eff]
    bank_flat = jnp.where(any_live[:, None], 0, 1).astype(jnp.int32) \
        + jnp.zeros_like(eff)
    slot_flat = (eff_bank * t.rows_per_bank + eff_slot).astype(jnp.int32)
    return bank_flat.reshape(-1), slot_flat.reshape(-1)


def replicated_embedding_bag(t: ReplicatedTable, idx: Array,
                             dist: DistCtx | None, *, backend: str = "auto",
                             bwd_backend: str = "auto",
                             field_offsets: Array | None = None,
                             tile_b: int = 8, n_slots: int = 2,
                             interpret: bool | None = None,
                             bank_live: Array | None = None,
                             with_traffic: bool = False):
    """Stages 1-3 over a REPLICATED table: idx (..., L) -> (..., dim) bag
    sums, with each bag reading copy ``wang_hash(bag) % k_max`` of every row
    it touches — a k-copy hot row's traffic splits k ways with no host-side
    routing. With ``k_max == 1`` (or no replicated rows) this is bit-exact
    to ``banked_embedding_bag``'s unsharded path on both backends.

    Differentiable: the backward scatters each bag's cotangent onto the
    copy its forward read came through, so summing a row's copies recovers
    the single-copy gradient exactly (fp32 accumulation on both backends).

    ``bank_live`` composes replication with fault tolerance: a dead copy's
    reads fail over to the row's first live copy instantly (zero extra
    latency, no replan); only rows with NO live copy degrade to the zero
    row (count them with ``degraded_row_counts`` on the (V, k) remap).

    The sharded (mesh) path is not wired yet — replication currently rides
    the unsharded serve loop; the multi-host mesh item in ROADMAP.md picks
    this up.

    ``with_traffic=True``: return becomes ``(out, BankTraffic)`` — measured
    reads routed to the SAME copy the kernel's wang-hash pick reads (and,
    under ``bank_live``, the same failover column the maps substitute).
    """
    if dist is not None:
        raise ValueError("replicated_embedding_bag is unsharded-only for "
                         "now — see the multi-host serving mesh item in "
                         "ROADMAP.md")
    if with_traffic:
        out = replicated_embedding_bag(
            t, idx, dist, backend=backend, bwd_backend=bwd_backend,
            field_offsets=field_offsets, tile_b=tile_b, n_slots=n_slots,
            interpret=interpret, bank_live=bank_live)
        from repro.obs.traffic import (replicated_bank_read_counts,
                                       traffic_from_reads)
        reads = replicated_bank_read_counts(
            t.remap_bank, _traffic_rows(idx, field_offsets), t.n_banks,
            k_max=t.k_max, bank_live=bank_live)
        row_nbytes = t.packed.shape[-1] * np.dtype(t.packed.dtype).itemsize
        return out, traffic_from_reads(reads, row_nbytes)
    if backend == "tuned":
        backend, tile_b, n_slots = _dispatch(
            "replicated", vocab=t.vocab, dim=t.dim,
            batch=int(np.prod(idx.shape[:-1])), bag_len=idx.shape[-1],
            n_fields=1 if field_offsets is None
            else int(np.shape(field_offsets)[0]),
            k_max=t.k_max, bwd_backend=bwd_backend,
            tile_b=tile_b, n_slots=n_slots)
    backend = _resolve_backend(backend)
    bwd = _resolve_bwd(bwd_backend, backend)
    interpret = _default_interpret(interpret)
    off = jnp.zeros((1,), jnp.int32) if field_offsets is None \
        else jnp.asarray(field_offsets, jnp.int32)
    if bank_live is None:
        bank_flat = t.flat_bank()
        slot_flat = t.flat_remap()
        my = jnp.full((), -1, jnp.int32)
    else:
        bank_flat, slot_flat = _replica_failover_maps(t, bank_live)
        my = jnp.zeros((), jnp.int32)
    cfg = (tile_b, interpret, backend, bwd, t.k_max, n_slots)
    return _replicated_bag(cfg, t.packed, bank_flat, slot_flat, off, my, idx)


def tiered_embedding_bag(fp_packed: Array, tt, idx: Array,
                         dist: DistCtx | None, *, backend: str = "auto",
                         bwd_backend: str = "auto",
                         field_offsets: Array | None = None,
                         tile_b: int = 8, n_slots: int = 2,
                         interpret: bool | None = None,
                         with_traffic: bool = False):
    """Stages 1-3 over a TIERED table (repro.quant.TieredTable): the fused
    lookup path with per-row dequant applied in-kernel (pallas) or in-scan
    (jnp) — idx (..., L) -> (..., dim) fp32 bag sums.

    ``fp_packed`` is the fp master table the payload was quantized from
    (same packed layout as ``tt``): the forward never reads its values, but
    gradients flow straight through onto it (``bwd_backend`` selects the
    scatter like the full-precision path). Serving can pass the live
    ``params['emb_packed']`` unchanged. One-hot fields fold in as length-1
    bags — the dense-gather semantics of ``banked_gather`` at fp32.
    """
    if with_traffic:
        out = tiered_embedding_bag(
            fp_packed, tt, idx, dist, backend=backend,
            bwd_backend=bwd_backend, field_offsets=field_offsets,
            tile_b=tile_b, n_slots=n_slots, interpret=interpret)
        from repro.obs.traffic import tiered_bank_traffic
        from repro.quant import tier_nbytes
        return out, tiered_bank_traffic(
            tt.remap_bank, tt.remap_slot, tt.rows_per_bank, tt.tier,
            tier_nbytes(tt.dim, tt.hot_dtype),
            _traffic_rows(idx, field_offsets), tt.n_banks)
    if backend == "tuned":
        backend, tile_b, n_slots = _dispatch(
            "tiered", vocab=int(tt.remap_bank.shape[0]), dim=tt.dim,
            batch=int(np.prod(idx.shape[:-1])), bag_len=idx.shape[-1],
            n_fields=1 if field_offsets is None
            else int(np.shape(field_offsets)[0]),
            tier_mix=tt.hot_dtype, bwd_backend=bwd_backend,
            tile_b=tile_b, n_slots=n_slots)
    backend = _resolve_backend(backend)
    bwd = _resolve_bwd(bwd_backend, backend)
    interpret = _default_interpret(interpret)
    if fp_packed.shape[0] != tt.payload.shape[0]:
        raise ValueError(
            f"fp table rows {fp_packed.shape[0]} != tiered payload rows "
            f"{tt.payload.shape[0]}: the straight-through gradient needs "
            f"the layout the payload was quantized from")
    off = jnp.zeros((1,), jnp.int32) if field_offsets is None \
        else jnp.asarray(field_offsets, jnp.int32)
    scale_bits = jax.lax.bitcast_convert_type(tt.scale, jnp.int32)
    cfg = (tile_b, interpret, backend, bwd, tt.dim, tt.hot_dtype, n_slots)

    if dist is None:
        return _tiered_bag(cfg, fp_packed, tt.payload, scale_bits, tt.tier,
                           tt.remap_bank, tt.flat_remap(), off,
                           jnp.full((), -1, jnp.int32), idx)

    P = jax.sharding.PartitionSpec
    dp_ok = idx.shape[0] % dist.dp_size() == 0
    dp = (dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0]) \
        if dp_ok else None
    bank_ax = dist.bank_axis
    idx_spec = P(dp, *([None] * (idx.ndim - 1)))
    out_spec = P(dp, *([None] * (idx.ndim - 1)))

    def fn(fp_local, pay_local, sc_local, tier_local, bank_map, slot_map,
           off_local, idx_local):
        my = jax.lax.axis_index(bank_ax)
        part = _tiered_bag(cfg, fp_local, pay_local, sc_local, tier_local,
                           bank_map, slot_map, off_local,
                           my.astype(jnp.int32), idx_local)
        return jax.lax.psum(part, bank_ax)

    return shard_map(
        fn, mesh=dist.mesh,
        in_specs=(P(bank_ax, None), P(bank_ax, None), P(bank_ax),
                  P(bank_ax), P(), P(), P(), idx_spec),
        out_specs=out_spec,
    )(fp_packed, tt.payload, scale_bits, tt.tier, tt.remap_bank,
      tt.remap_slot, off, idx)


def banked_cache_residual_bag(t: BankedTable, cache: BankedTable,
                              cache_idx: Array, residual_idx: Array,
                              dist: DistCtx | None, *, backend: str = "auto",
                              bwd_backend: str = "auto", tile_b: int = 8,
                              n_slots: int = 2,
                              interpret: bool | None = None,
                              bank_live: Array | None = None,
                              with_traffic: bool = False):
    """Cache-aware fused lookup (paper Fig. 7): one stage-2 pass computes
    ``Σ cache_partials + Σ residual_rows`` per bag.

    cache_idx (..., Lc) ids into the partial-sum cache table; residual_idx
    (..., Lr) union-vocab rows into the EMT. Both tables are banked over the
    same axis; the combined partial takes ONE psum (half the stage-3 traffic
    of two separate lookups). ``bwd_backend='pallas'`` routes the dual
    gradient scatter (EMT + cache table) through the sorted-run kernel.

    ``bank_live`` masks BOTH tables: a dead bank loses its EMT rows and its
    cache entries alike (they share the physical bank), each resolving to the
    zero-row degraded substitute. Same zero-recompile argument contract as
    ``banked_embedding_bag``.
    """
    if with_traffic:
        out = banked_cache_residual_bag(
            t, cache, cache_idx, residual_idx, dist, backend=backend,
            bwd_backend=bwd_backend, tile_b=tile_b, n_slots=n_slots,
            interpret=interpret, bank_live=bank_live)
        from repro.obs.traffic import (cached_bank_read_counts,
                                       traffic_from_reads)
        reads = cached_bank_read_counts(
            cache.remap_bank, cache_idx, t.remap_bank, residual_idx,
            t.n_banks, bank_live=bank_live)
        row_nbytes = t.packed.shape[-1] * np.dtype(t.packed.dtype).itemsize
        return out, traffic_from_reads(reads, row_nbytes)
    if backend == "tuned":
        backend, tile_b, n_slots = _dispatch(
            "fused", vocab=t.vocab, dim=t.dim,
            batch=int(np.prod(cache_idx.shape[:-1])),
            bag_len=f"{cache_idx.shape[-1]}+{residual_idx.shape[-1]}",
            bwd_backend=bwd_backend, tile_b=tile_b, n_slots=n_slots)
    backend = _resolve_backend(backend)
    bwd = _resolve_bwd(bwd_backend, backend)
    interpret = _default_interpret(interpret)

    if dist is None:
        if bank_live is None:
            e_bank, c_bank = t.remap_bank, cache.remap_bank
            my = jnp.full((), -1, jnp.int32)
        else:
            e_bank = _binary_live_map(t.remap_bank, bank_live)
            c_bank = _binary_live_map(cache.remap_bank, bank_live)
            my = jnp.zeros((), jnp.int32)
        if backend == "pallas":
            return _pallas_cache_bag(
                (tile_b, interpret, bwd, n_slots), t.packed, cache.packed,
                e_bank, t.flat_remap(), c_bank,
                cache.flat_remap(), my, cache_idx, residual_idx)
        zero = jnp.zeros((1,), jnp.int32)
        scan_bank = None if bank_live is None else e_bank
        scan_cbank = None if bank_live is None else c_bank
        scan_my = None if bank_live is None else my
        part = _bag_partial_scan(t.packed, residual_idx,
                                 remap=t.flat_remap(), bank=scan_bank,
                                 my_bank=scan_my, off=zero)
        return part + _bag_partial_scan(
            cache.packed, cache_idx, remap=cache.flat_remap(),
            bank=scan_cbank, my_bank=scan_my, off=zero).astype(part.dtype)

    P = jax.sharding.PartitionSpec
    dp_ok = cache_idx.shape[0] % dist.dp_size() == 0
    dp = (dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0]) \
        if dp_ok else None
    bank = dist.bank_axis
    ci_spec = P(dp, *([None] * (cache_idx.ndim - 1)))
    ri_spec = P(dp, *([None] * (residual_idx.ndim - 1)))
    out_spec = P(dp, *([None] * (cache_idx.ndim - 1)))

    def fn(emt_local, cache_local, e_bank, e_slot, c_bank, c_slot,
           ci_local, ri_local):
        my = jax.lax.axis_index(bank)
        if backend == "pallas":
            part = _pallas_cache_bag(
                (tile_b, interpret, bwd, n_slots), emt_local, cache_local,
                e_bank, e_slot,
                c_bank, c_slot, my.astype(jnp.int32), ci_local, ri_local)
        else:
            zero = jnp.zeros((1,), jnp.int32)
            part = _bag_partial_scan(emt_local, ri_local, remap=e_slot,
                                     bank=e_bank, my_bank=my, off=zero)
            part = part + _bag_partial_scan(
                cache_local, ci_local, remap=c_slot, bank=c_bank, my_bank=my,
                off=zero).astype(part.dtype)
        return jax.lax.psum(part, bank)

    if bank_live is None:
        e_map, c_map = t.remap_bank, cache.remap_bank
    else:
        e_map = _effective_bank_map(t.remap_bank, bank_live, t.n_banks)
        c_map = _effective_bank_map(cache.remap_bank, bank_live, cache.n_banks)
    return shard_map(
        fn, mesh=dist.mesh,
        in_specs=(P(bank, None), P(bank, None), P(), P(), P(), P(),
                  ci_spec, ri_spec),
        out_specs=out_spec,
    )(t.packed, cache.packed, e_map, t.remap_slot,
      c_map, cache.remap_slot, cache_idx, residual_idx)


# ---------------------------------------------------------------------------
# CSR-ragged lookup
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _pallas_csr_bag(cfg: tuple, packed: Array, bank: Array, slot: Array,
                    my: Array, indices: Array, seg: Array,
                    offs_ext: Array) -> Array:
    """cfg = (tile_b, interpret, num_bags_padded, bwd, n_slots)."""
    from repro.kernels.embedding_bag import csr_bag_pallas
    tile_b, interpret, nb_pad, _, n_slots = cfg
    table, d = _pad_lanes(packed, interpret)
    out = csr_bag_pallas(table, bank, slot, my.reshape(1).astype(jnp.int32),
                         indices.astype(jnp.int32), seg.astype(jnp.int32),
                         offs_ext.astype(jnp.int32), nb_pad,
                         tile_b=tile_b, interpret=interpret,
                         n_slots=n_slots)
    return out[:, :d]


def _pallas_csr_bag_fwd(cfg, packed, bank, slot, my, indices, seg, offs_ext):
    return _pallas_csr_bag(cfg, packed, bank, slot, my, indices, seg,
                           offs_ext), (packed, bank, slot, my, indices, seg)


def _pallas_csr_bag_bwd(cfg, res, ct):
    tile_b, interpret, nb_pad, bwd, n_slots = cfg
    packed, bank, slot, my, indices, seg = res
    if bwd == "pallas":
        from repro.kernels.embedding_bag import ct_scatter_csr_pallas
        d_tab = ct_scatter_csr_pallas(
            ct, indices, seg, bank, slot, my.reshape(1).astype(jnp.int32),
            packed.shape[0], packed.dtype, tile_s=tile_b,
            interpret=interpret, n_slots=n_slots)
        return (d_tab, None, None, None, None, None, None)
    valid = indices >= 0
    row = jnp.where(valid, indices, 0)
    mine = valid & ((my < 0) | (bank[row] == my))
    src = jnp.where(mine, slot[row], 0)
    upd = jnp.where(mine[:, None], ct[seg], 0).astype(jnp.float32)
    d_tab = jnp.zeros(packed.shape, jnp.float32).at[src].add(upd)
    return (d_tab.astype(packed.dtype), None, None, None, None, None, None)


_pallas_csr_bag.defvjp(_pallas_csr_bag_fwd, _pallas_csr_bag_bwd)


def csr_embedding_bag(t: BankedTable, indices: Array, offsets: Array,
                      num_bags: int, dist: DistCtx | None, *,
                      backend: str = "auto", bwd_backend: str = "auto",
                      tile_b: int = 8, n_slots: int = 2,
                      interpret: bool | None = None,
                      with_traffic: bool = False):
    """CSR-ragged variant (indices flat + offsets), bag-summed.

    Ragged bags cannot shard on batch without equal per-shard totals, so the
    flat stream is replicated across dp as well — used for the paper-faithful
    serving path at modest batch (the paper's batch is 64); the rectangular
    ``banked_embedding_bag`` is the scale path.

    The pallas backend walks each tile's contiguous CSR range with the same
    double-buffered row DMA as the rectangular kernel (bag id = prefetched
    segment id), so ragged bags fuse without padding to a rectangle.
    """
    if with_traffic:
        out = csr_embedding_bag(
            t, indices, offsets, num_bags, dist, backend=backend,
            bwd_backend=bwd_backend, tile_b=tile_b, n_slots=n_slots,
            interpret=interpret)
        from repro.obs.traffic import bank_read_counts, traffic_from_reads
        reads = bank_read_counts(t.remap_bank, indices, t.n_banks)
        row_nbytes = t.packed.shape[-1] * np.dtype(t.packed.dtype).itemsize
        return out, traffic_from_reads(reads, row_nbytes)
    if backend == "tuned":
        backend, tile_b, n_slots = _dispatch(
            "csr", vocab=t.vocab, dim=t.dim, batch=int(num_bags),
            bag_len="ragged", bwd_backend=bwd_backend,
            tile_b=tile_b, n_slots=n_slots)
    backend = _resolve_backend(backend)
    bwd = _resolve_bwd(bwd_backend, backend)
    interpret = _default_interpret(interpret)
    from repro.sparse.ops import offsets_to_segment_ids
    total = indices.shape[0]
    seg = offsets_to_segment_ids(offsets, total)
    nb_pad = -(-num_bags // tile_b) * tile_b
    offs_ext = jnp.concatenate(
        [offsets.astype(jnp.int32),
         jnp.full((nb_pad + 1 - num_bags,), total, jnp.int32)])

    if dist is None:
        if backend == "pallas":
            out = _pallas_csr_bag((tile_b, interpret, nb_pad, bwd, n_slots),
                                  t.packed,
                                  t.remap_bank, t.flat_remap(),
                                  jnp.full((), -1, jnp.int32), indices, seg,
                                  offs_ext)
            return out[:num_bags]
        rows = lookup_unsharded(t, indices[:, None], reduce_bag=True)
        return jax.ops.segment_sum(rows, seg, num_bags)

    P = jax.sharding.PartitionSpec

    def fn(packed_local, bank_map, slot_map, idx_local, seg_local, offs_local):
        my = jax.lax.axis_index(dist.bank_axis)
        if backend == "pallas":
            part = _pallas_csr_bag((tile_b, interpret, nb_pad, bwd, n_slots),
                                   packed_local, bank_map, slot_map,
                                   my.astype(jnp.int32), idx_local,
                                   seg_local, offs_local)[:num_bags]
        else:
            part = _local_gather_partial(packed_local, bank_map, slot_map,
                                         idx_local, my)
            part = jax.ops.segment_sum(part, seg_local, num_bags)
        return jax.lax.psum(part, dist.bank_axis)

    return shard_map(
        fn, mesh=dist.mesh,
        in_specs=(P(dist.bank_axis, None), P(), P(), P(), P(), P()),
        out_specs=P(),
    )(t.packed, t.remap_bank, t.remap_slot, indices, seg, offs_ext)


# ---------------------------------------------------------------------------
# CSR batch sharding: balanced split of the ragged stream over dp shards
# ---------------------------------------------------------------------------

def balanced_csr_shards(offsets: np.ndarray, n_shards: int) -> np.ndarray:
    """(n_shards + 1,) bag-aligned cut points with near-equal per-shard INDEX
    totals (not bag counts — ragged bags make those very different).

    Cut k lands on the bag boundary closest to total * k / n_shards; with
    any bag smaller than total / n_shards the per-shard imbalance is at most
    one bag's length.
    """
    offsets = np.asarray(offsets, np.int64)
    num_bags = offsets.shape[0] - 1
    total = int(offsets[-1])
    targets = total * np.arange(1, n_shards) / n_shards
    cuts = np.searchsorted(offsets, targets, side="left")
    # snap to the nearer of the two surrounding boundaries
    left = np.clip(cuts - 1, 0, num_bags)
    cuts = np.where(targets - offsets[left] < offsets[np.clip(cuts, 0,
                                                              num_bags)]
                    - targets, left, cuts)
    cuts = np.clip(cuts, 0, num_bags)
    bounds = np.concatenate([[0], np.maximum.accumulate(cuts), [num_bags]])
    return bounds.astype(np.int64)


def shard_csr_batch(indices: np.ndarray, offsets: np.ndarray,
                    n_shards: int) -> dict:
    """Host-side prep (pre-processing stage, like ``rewrite_bags``): split a
    CSR batch into ``n_shards`` equal-total slices, padded to one static
    shape. Returns stacked per-shard arrays ready for
    ``csr_embedding_bag_sharded``:

      idx (S, cap)   flat row ids, -1 padded
      seg (S, cap)   GLOBAL bag id per entry (num_bags on padding)
      bounds (S+1,)  the bag cut points
    """
    indices = np.asarray(indices)
    offsets = np.asarray(offsets, np.int64)
    num_bags = offsets.shape[0] - 1
    seg = np.repeat(np.arange(num_bags), np.diff(offsets))
    bounds = balanced_csr_shards(offsets, n_shards)
    caps = offsets[bounds[1:]] - offsets[bounds[:-1]]
    cap = max(int(caps.max()), 1)
    idx_s = np.full((n_shards, cap), -1, dtype=np.int32)
    seg_s = np.full((n_shards, cap), num_bags, dtype=np.int32)
    for s in range(n_shards):
        lo, hi = int(offsets[bounds[s]]), int(offsets[bounds[s + 1]])
        idx_s[s, :hi - lo] = indices[lo:hi]
        seg_s[s, :hi - lo] = seg[lo:hi]
    return {"idx": idx_s, "seg": seg_s, "bounds": bounds}


def csr_embedding_bag_sharded(t: BankedTable, indices: np.ndarray,
                              offsets: np.ndarray, num_bags: int,
                              dist: DistCtx | None, *, backend: str = "auto",
                              bwd_backend: str = "auto", tile_b: int = 8,
                              n_slots: int = 2,
                              interpret: bool | None = None) -> Array:
    """CSR bag sums with the flat stream SHARDED over dp (vs the replicating
    ``csr_embedding_bag``): each dp shard owns a contiguous bag range chosen
    by ``balanced_csr_shards`` so per-shard index totals are near-equal, does
    its own stage 2 against its bank slice, and the (num_bags, D) partials
    combine in one psum over (dp, bank).

    ``indices``/``offsets`` must be HOST (concrete) arrays — the balanced
    split is data-dependent and runs in the pre-processing stage. ``offsets``
    may be starts-only (length num_bags, ``csr_embedding_bag``'s convention)
    or include the trailing total (length num_bags + 1).
    """
    indices = np.asarray(indices)
    offsets = np.asarray(offsets, np.int64)
    if offsets.shape[0] == num_bags:       # starts-only -> append the total
        offsets = np.concatenate([offsets, [indices.shape[0]]])
    assert offsets.shape[0] == num_bags + 1, (offsets.shape, num_bags)
    if dist is None or dist.dp_size() == 1:
        return csr_embedding_bag(t, jnp.asarray(indices),
                                 jnp.asarray(offsets[:num_bags]), num_bags,
                                 dist, backend=backend,
                                 bwd_backend=bwd_backend, tile_b=tile_b,
                                 n_slots=n_slots, interpret=interpret)
    if backend == "tuned":
        backend, tile_b, n_slots = _dispatch(
            "csr", vocab=t.vocab, dim=t.dim, batch=int(num_bags),
            bag_len="ragged", bwd_backend=bwd_backend,
            tile_b=tile_b, n_slots=n_slots)
    backend = _resolve_backend(backend)
    bwd = _resolve_bwd(bwd_backend, backend)
    interpret = _default_interpret(interpret)
    nd = dist.dp_size()
    sh = shard_csr_batch(indices, offsets, nd)
    nb_pad = -(-num_bags // tile_b) * tile_b
    bounds = sh["bounds"]
    # per-shard clipped cumulative offsets: bags outside the shard's range
    # collapse to empty [x, x) spans, so the CSR kernel's per-tile walk
    # touches only owned entries
    offs_ext = np.concatenate([offsets, np.full(nb_pad + 1 - num_bags - 1,
                                                offsets[-1])])
    lo = offsets[bounds[:-1]][:, None]                     # (S, 1)
    hi = offsets[bounds[1:]][:, None]
    offs_s = np.clip(offs_ext[None, :] - lo, 0, hi - lo).astype(np.int32)

    P = jax.sharding.PartitionSpec
    dp = dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0]
    bank = dist.bank_axis

    def fn(packed_local, bank_map, slot_map, idx_s, seg_s, offs_local):
        my = jax.lax.axis_index(bank)
        idx_local = idx_s[0]
        seg_local = seg_s[0]
        if backend == "pallas":
            part = _pallas_csr_bag((tile_b, interpret, nb_pad, bwd, n_slots),
                                   packed_local, bank_map, slot_map,
                                   my.astype(jnp.int32), idx_local,
                                   seg_local, offs_local[0])[:num_bags]
        else:
            part = _local_gather_partial(packed_local, bank_map, slot_map,
                                         idx_local, my)
            part = jax.ops.segment_sum(part, seg_local, num_bags)
        return jax.lax.psum(part, (*dist.dp_axes, bank))

    return shard_map(
        fn, mesh=dist.mesh,
        in_specs=(P(bank, None), P(), P(), P(dp, None), P(dp, None),
                  P(dp, None)),
        out_specs=P(),
    )(t.packed, t.remap_bank, t.remap_slot, jnp.asarray(sh["idx"]),
      jnp.asarray(sh["seg"]), jnp.asarray(offs_s))


# ---------------------------------------------------------------------------
# column-split table (the paper's N_c axis, TPU rendition)
# ---------------------------------------------------------------------------

def col_split_embedding_bag(table: Array, idx: Array, dist: DistCtx | None,
                            *, reduce_bag: bool = True) -> Array:
    """Uniform column split: table (vocab, dim) sharded P(None, bank_axis).

    Every bank gathers ALL bag indices for its dim slice; no mask, no psum —
    stage 3 is an implicit all-gather when the consumer needs the full dim.
    Expressed via GSPMD sharding constraint so XLA schedules the collective.
    """
    valid = idx >= 0
    rows = jnp.take(table, jnp.where(valid, idx, 0), axis=0)
    rows = jnp.where(valid[..., None], rows, 0)
    out = rows.sum(axis=-2) if reduce_bag else rows
    if dist is not None:
        P = jax.sharding.PartitionSpec
        dp = dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0]
        spec = P(dp, *([None] * (out.ndim - 2)), dist.bank_axis)
        out = jax.lax.with_sharding_constraint(
            out, jax.sharding.NamedSharding(dist.mesh, spec))
    return out
