"""PIMEmbeddingBag: bank-partitioned embedding lookup (the paper's runtime).

The UPMEM dataflow (paper Fig. 4) maps 1:1 onto a ``shard_map`` over the mesh's
``model`` axis (DESIGN.md §2):

  stage 1  indices replicated across the bank axis        (CPU->DPU broadcast)
  stage 2  masked local gather + segment-reduce per bank  (in-DPU lookup+reduce)
  stage 3  psum of partial bag-sums over the bank axis    (DPU->CPU combine)

A table is *packed* by a PartitionPlan (core/partitioning.py): rows are
physically reordered so bank b's rows are contiguous, giving a global
``(n_banks * rows_per_bank, dim)`` array sharded ``P('model', None)`` — each
device holds exactly its bank.  The row->(bank, slot) remap is two replicated
``int32[vocab]`` vectors (8 B/row).

Column-split mode (the paper's N_c knob) shards the embedding dim instead:
every bank gathers full bags for its dim-slice (no mask, no psum) and stage 3
becomes an all-gather of dim slices — the same Eq. 1 tradeoff with TPU
constants (§Perf explores it).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partitioning import PartitionPlan

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BankedTable:
    """Pytree: packed rows + remap. ``packed`` shards P(bank_axis, None)."""

    packed: Array       # (n_banks * rows_per_bank, dim)
    remap_bank: Array   # (vocab,) int32, replicated
    remap_slot: Array   # (vocab,) int32, replicated
    n_banks: int = dataclasses.field(metadata=dict(static=True))
    rows_per_bank: int = dataclasses.field(metadata=dict(static=True))

    @property
    def vocab(self) -> int:
        return self.remap_bank.shape[0]

    @property
    def dim(self) -> int:
        return self.packed.shape[-1]


def pack_table(table: np.ndarray, plan: PartitionPlan,
               dtype=None) -> BankedTable:
    """Physically reorder rows by the plan; pad banks to a common row count."""
    vocab, dim = table.shape
    rows_per_bank = int(plan.max_rows_per_bank)
    packed = np.zeros((plan.n_banks * rows_per_bank, dim), dtype=table.dtype)
    flat_pos = plan.bank_of_row.astype(np.int64) * rows_per_bank + plan.slot_of_row
    packed[flat_pos] = table
    if dtype is not None:
        packed = packed.astype(dtype)
    return BankedTable(
        packed=jnp.asarray(packed),
        remap_bank=jnp.asarray(plan.bank_of_row, dtype=jnp.int32),
        remap_slot=jnp.asarray(plan.slot_of_row, dtype=jnp.int32),
        n_banks=plan.n_banks,
        rows_per_bank=rows_per_bank,
    )


def init_banked(key, plan: PartitionPlan, dim: int, *, scale: float = 0.01,
                dtype=jnp.float32) -> BankedTable:
    """Random-init a banked table without materializing the unpacked layout."""
    rows_per_bank = int(plan.max_rows_per_bank)
    packed = jax.random.normal(
        key, (plan.n_banks * rows_per_bank, dim), dtype) * scale
    return BankedTable(
        packed=packed,
        remap_bank=jnp.asarray(plan.bank_of_row, dtype=jnp.int32),
        remap_slot=jnp.asarray(plan.slot_of_row, dtype=jnp.int32),
        n_banks=plan.n_banks,
        rows_per_bank=rows_per_bank,
    )


# ---------------------------------------------------------------------------
# local (single-shard) reference semantics — also the inside of the shard_map
# ---------------------------------------------------------------------------

def _local_bag_partial(table_local: Array, bank: Array, slot: Array,
                       idx: Array, my_bank: Array) -> Array:
    """Stage 2 on one bank: masked gather of owned rows, zeros elsewhere.

    idx: (..., L) padded with -1.  Returns (..., dim) partial bag sums.
    """
    valid = idx >= 0
    safe = jnp.where(valid, idx, 0)
    owner = bank[safe]
    s = slot[safe]
    mine = valid & (owner == my_bank)
    rows = jnp.take(table_local, jnp.where(mine, s, 0), axis=0)
    rows = jnp.where(mine[..., None], rows, 0)
    return rows.sum(axis=-2)


def _local_gather_partial(table_local: Array, bank: Array, slot: Array,
                          idx: Array, my_bank: Array) -> Array:
    """Dense (non-reducing) lookup partial: (...,) idx -> (..., dim)."""
    safe = jnp.where(idx >= 0, idx, 0)
    owner = bank[safe]
    s = slot[safe]
    mine = (idx >= 0) & (owner == my_bank)
    rows = jnp.take(table_local, jnp.where(mine, s, 0), axis=0)
    return jnp.where(mine[..., None], rows, 0)


def lookup_unsharded(t: BankedTable, idx: Array, *, reduce_bag: bool) -> Array:
    """Single-device semantics (CPU path + oracle): loop banks via reshape."""
    table = t.packed.reshape(t.n_banks, t.rows_per_bank, t.dim)
    flat = t.remap_bank * t.rows_per_bank + t.remap_slot
    safe = jnp.where(idx >= 0, idx, 0)
    rows = jnp.take(table.reshape(-1, t.dim), flat[safe], axis=0)
    rows = jnp.where((idx >= 0)[..., None], rows, 0)
    return rows.sum(axis=-2) if reduce_bag else rows


# ---------------------------------------------------------------------------
# distributed lookup
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistCtx:
    """Mesh context threaded through model code. None => single-device."""

    mesh: jax.sharding.Mesh
    dp_axes: tuple[str, ...]     # batch-sharded axes, e.g. ('pod', 'data')
    bank_axis: str = "model"

    @property
    def n_banks(self) -> int:
        return self.mesh.shape[self.bank_axis]

    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))


def banked_embedding_bag(t: BankedTable, idx: Array, dist: DistCtx | None,
                         *, reduce_bag: bool = True) -> Array:
    """The paper's stages 1-3. idx (B, L) -> (B, dim) [reduce] or (B, L, dim).

    Under a mesh: shard_map over (dp_axes + bank_axis); indices are sharded on
    batch, replicated across banks (stage 1); each bank computes its partial
    (stage 2); psum over the bank axis (stage 3).
    """
    if dist is None:
        return lookup_unsharded(t, idx, reduce_bag=reduce_bag)

    P = jax.sharding.PartitionSpec
    # batch shards over dp when divisible; tiny/odd batches (retrieval's B=1
    # query) replicate across dp instead
    dp_ok = idx.shape[0] % dist.dp_size() == 0
    dp = (dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0]) \
        if dp_ok else None
    bank = dist.bank_axis
    idx_spec = P(dp, *([None] * (idx.ndim - 1)))
    out_spec = P(dp, *([None] * (idx.ndim - (1 if reduce_bag else 0))))

    def fn(packed_local, bank_map, slot_map, idx_local):
        my = jax.lax.axis_index(bank)
        if reduce_bag:
            part = _local_bag_partial(packed_local, bank_map, slot_map,
                                      idx_local, my)
        else:
            part = _local_gather_partial(packed_local, bank_map, slot_map,
                                         idx_local, my)
        return jax.lax.psum(part, bank)

    return jax.shard_map(
        fn, mesh=dist.mesh,
        in_specs=(P(bank, None), P(), P(), idx_spec),
        out_specs=out_spec,
    )(t.packed, t.remap_bank, t.remap_slot, idx)


def banked_gather(t: BankedTable, idx: Array, dist: DistCtx | None) -> Array:
    """Dense per-position lookup (LM token embedding / BERT4Rec item seq)."""
    return banked_embedding_bag(t, idx, dist, reduce_bag=False)


def csr_embedding_bag(t: BankedTable, indices: Array, offsets: Array,
                      num_bags: int, dist: DistCtx | None) -> Array:
    """CSR-ragged variant (indices flat + offsets), bag-summed.

    Ragged bags cannot shard on batch without equal per-shard totals, so the
    flat stream is replicated across dp as well — used for the paper-faithful
    serving path at modest batch (the paper's batch is 64); the rectangular
    ``banked_embedding_bag`` is the scale path.
    """
    from repro.sparse.ops import offsets_to_segment_ids
    total = indices.shape[0]
    seg = offsets_to_segment_ids(offsets, total)

    if dist is None:
        rows = lookup_unsharded(t, indices[:, None], reduce_bag=True)
        return jax.ops.segment_sum(rows, seg, num_bags)

    P = jax.sharding.PartitionSpec

    def fn(packed_local, bank_map, slot_map, idx_local, seg_local):
        my = jax.lax.axis_index(dist.bank_axis)
        part = _local_gather_partial(packed_local, bank_map, slot_map,
                                     idx_local, my)
        part = jax.ops.segment_sum(part, seg_local, num_bags)
        return jax.lax.psum(part, dist.bank_axis)

    return jax.shard_map(
        fn, mesh=dist.mesh,
        in_specs=(P(dist.bank_axis, None), P(), P(), P(), P()),
        out_specs=P(),
    )(t.packed, t.remap_bank, t.remap_slot, indices, seg)


# ---------------------------------------------------------------------------
# column-split table (the paper's N_c axis, TPU rendition)
# ---------------------------------------------------------------------------

def col_split_embedding_bag(table: Array, idx: Array, dist: DistCtx | None,
                            *, reduce_bag: bool = True) -> Array:
    """Uniform column split: table (vocab, dim) sharded P(None, bank_axis).

    Every bank gathers ALL bag indices for its dim slice; no mask, no psum —
    stage 3 is an implicit all-gather when the consumer needs the full dim.
    Expressed via GSPMD sharding constraint so XLA schedules the collective.
    """
    valid = idx >= 0
    rows = jnp.take(table, jnp.where(valid, idx, 0), axis=0)
    rows = jnp.where(valid[..., None], rows, 0)
    out = rows.sum(axis=-2) if reduce_bag else rows
    if dist is not None:
        P = jax.sharding.PartitionSpec
        dp = dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0]
        spec = P(dp, *([None] * (out.ndim - 2)), dist.bank_axis)
        out = jax.lax.with_sharding_constraint(
            out, jax.sharding.NamedSharding(dist.mesh, spec))
    return out
