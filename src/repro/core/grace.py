"""GRACE-lite: co-occurrence mining -> partial-sum cache lists.

The paper adopts GRACE (Ye et al., ASPLOS'23) as an off-the-shelf component: a
graph-based miner that finds frequently co-occurring item groups whose partial
sums are cached ("a cache list of {a,b,c} means partial sums a+b, a+c, b+c and
a+b+c are cached").  UpDLRM explicitly "does not rely on GRACE and can work
with any other caching technique" (§5) — so we implement a self-contained
greedy co-occurrence miner with the same interface: it consumes an access
trace and emits (groups, benefits).

Host-side numpy; runs in the pre-processing stage (Fig. 4).
"""
from __future__ import annotations

import dataclasses
from collections import Counter

import numpy as np


@dataclasses.dataclass
class CacheEntry:
    """One cached partial sum: the row ids whose sum is stored."""

    members: tuple[int, ...]
    hits: float  # times this exact subset co-occurred in the trace


@dataclasses.dataclass
class CachePlan:
    groups: list[np.ndarray]       # mined co-occurrence groups (cache lists)
    benefits: np.ndarray           # est. reduced memory accesses per group
    entries: list[CacheEntry]      # explicit cached subsets (incl. pairwise)
    entry_of_subset: dict[tuple[int, ...], int]  # subset -> entry id

    @property
    def n_entries(self) -> int:
        return len(self.entries)


def mine_cooccurrence(
    trace: list[np.ndarray],
    *,
    top_items: int = 4096,
    max_groups: int = 512,
    max_group_size: int = 3,
    min_support: int = 2,
) -> CachePlan:
    """Greedy frequent-group miner over a bag trace.

    1. restrict to the `top_items` hottest items (power-law: these dominate),
    2. count pair co-occurrences among them,
    3. greedily grow groups (pair -> triple) by shared-neighbor support,
    4. benefit(group) = co-occurrence count * (|group| - 1)   — each full-group
       hit turns |group| row reads into one partial-sum read.
    """
    freq = Counter()
    for bag in trace:
        freq.update(int(i) for i in np.unique(bag))
    hot = {i for i, _ in freq.most_common(top_items)}

    pair_count: Counter = Counter()
    for bag in trace:
        items = sorted(set(int(i) for i in bag) & hot)
        for a_i in range(len(items)):
            for b_i in range(a_i + 1, len(items)):
                pair_count[(items[a_i], items[b_i])] += 1

    groups: list[np.ndarray] = []
    benefits: list[float] = []
    used: set[int] = set()
    for (a, b), cnt in pair_count.most_common():
        if cnt < min_support or len(groups) >= max_groups:
            break
        if a in used or b in used:
            continue
        group = [a, b]
        if max_group_size >= 3:
            # best third member co-occurring with both
            best_c, best_cnt = None, min_support - 1
            for c in hot:
                if c in used or c == a or c == b:
                    continue
                cc = min(pair_count.get(tuple(sorted((a, c))), 0),
                         pair_count.get(tuple(sorted((b, c))), 0))
                if cc > best_cnt:
                    best_c, best_cnt = c, cc
            if best_c is not None:
                group.append(best_c)
        used.update(group)
        groups.append(np.array(sorted(group), dtype=np.int64))
        benefits.append(float(cnt) * (len(group) - 1))

    # explicit cached subsets: all 2..n subsets of each group (paper §3.3)
    entries: list[CacheEntry] = []
    entry_of_subset: dict[tuple[int, ...], int] = {}
    for g, cnt in zip(groups, benefits):
        members = [int(x) for x in g]
        subsets = _subsets(members)
        for s in subsets:
            if s not in entry_of_subset:
                entry_of_subset[s] = len(entries)
                entries.append(CacheEntry(members=s, hits=cnt))
    return CachePlan(groups=groups, benefits=np.array(benefits),
                     entries=entries, entry_of_subset=entry_of_subset)


def _subsets(members: list[int]) -> list[tuple[int, ...]]:
    out: list[tuple[int, ...]] = []
    n = len(members)
    for mask in range(3, 2 ** n):
        if bin(mask).count("1") >= 2:
            out.append(tuple(members[i] for i in range(n) if mask >> i & 1))
    return out
