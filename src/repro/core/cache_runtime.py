"""Cache runtime: request rewriting + partial-sum cache table build/refresh.

The paper's Fig. 7 flow: before dispatch, the host checks each request's index
set against the cache index; matched subsets are replaced by a single cached
partial-sum read, the rest go to the EMT.  We mirror that split:

  host (data pipeline):  rewrite_bags()  — bag indices -> (cache ids, residual
                         ids), padded to static shapes for the jitted step.
  device:                cache partial-sum table is just another (small) bank-
                         partitioned table; the fused lookup adds
                         embedding_bag(cache_table, cache_ids)
                       + embedding_bag(emt, residual_ids).

Training note (beyond the paper, which is inference-only): cached sums go stale
when the EMT trains; ``build_cache_table`` is cheap (one gather+sum per entry)
and is refreshed every ``refresh_every`` steps by the train loop.

Adaptive serving (repro.workload) adds two contracts on top:

  fixed capacity —  ``cap_cache_plan`` pins the cache side to
      ``n_banks * rows_per_bank`` entry positions regardless of what the
      re-miner found (truncating overflow back to residual reads, padding the
      remap vectors with unused positions), the same trick the EMT side plays
      with ``rows_per_bank``: every swap feeds same-shape arrays to the same
      serve executable, so replans never recompile.
  versioning —  ``VersionedCacheRewriter`` tags every rewritten batch with the
      cache-plan version it was rewritten under. A batch in flight across a
      swap carries entry ids from the OLD table's numbering; the serve loop
      resolves it with ``table_for(batch.version)`` so it reads the table it
      was rewritten for, never the one installed after it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.grace import CacheEntry, CachePlan, _subsets


def build_cache_table(table: np.ndarray, plan: CachePlan) -> np.ndarray:
    """(n_entries, dim) partial sums — entry e stores sum(table[members_e])."""
    dim = table.shape[1]
    out = np.zeros((max(plan.n_entries, 1), dim), dtype=table.dtype)
    for e, entry in enumerate(plan.entries):
        out[e] = table[list(entry.members)].sum(axis=0)
    return out


def rewrite_bag(bag: np.ndarray, plan: CachePlan) -> tuple[list[int], list[int]]:
    """One bag -> (cache entry ids, residual row ids).  Greedy largest-subset
    match per group (Fig. 7: {1,4,5} -> cache hit (4+5), residual {1})."""
    present = set(int(i) for i in bag)
    cache_ids: list[int] = []
    for group in plan.groups:
        inter = tuple(sorted(present & set(int(i) for i in group)))
        if len(inter) >= 2:
            eid = plan.entry_of_subset.get(inter)
            if eid is not None:
                cache_ids.append(eid)
                present -= set(inter)
    return cache_ids, sorted(present)


def rewrite_bags(
    bags: list[np.ndarray],
    plan: CachePlan,
    *,
    max_cache_per_bag: int,
    max_residual_per_bag: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Batch rewrite to padded static shapes (-1 padding).

    Returns (cache_idx (B, max_cache), residual_idx (B, max_residual)).
    Overflow beyond the static budgets falls back to residual reads (never
    drops lookups; only loses cache benefit), then truncates with a warning
    count — matching static-shape jit semantics.
    """
    B = len(bags)
    cache_idx = np.full((B, max_cache_per_bag), -1, dtype=np.int32)
    resid_idx = np.full((B, max_residual_per_bag), -1, dtype=np.int32)
    for i, bag in enumerate(bags):
        c, r = rewrite_bag(bag, plan)
        # cache hits beyond the static budget DEGRADE to residual row reads
        # (losing only the benefit, never the lookup)
        for eid in c[max_cache_per_bag:]:
            r.extend(plan.entries[eid].members)
        c = c[:max_cache_per_bag]
        r = sorted(set(r))[:max_residual_per_bag]
        cache_idx[i, :len(c)] = c
        resid_idx[i, :len(r)] = r
    return cache_idx, resid_idx


def measure_hit_rate(bags: list[np.ndarray], plan: CachePlan) -> float:
    """Fraction of row reads eliminated by the cache (Fig. 6's ~40% metric)."""
    saved = 0
    total = 0
    for bag in bags:
        c, r = rewrite_bag(bag, plan)
        total += len(set(int(i) for i in bag))
        saved += len(set(int(i) for i in bag)) - (len(c) + len(r))
    return saved / max(total, 1)


# ---------------------------------------------------------------------------
# fixed-capacity cache side (the adaptive-serving shape contract)
# ---------------------------------------------------------------------------

def empty_cache_plan() -> CachePlan:
    """A CachePlan with no groups: every bag rewrites to pure residual."""
    return CachePlan(groups=[], benefits=np.zeros(0), entries=[],
                     entry_of_subset={})


def entry_banks(plan: CachePlan, bank_of_row: np.ndarray,
                cache_bank_of_group: np.ndarray | None) -> np.ndarray:
    """Entry -> bank under Algorithm 1's co-location invariant: every subset
    entry lives on its mined group's bank; groups the partitioner could not
    place (or plans with no cache side) fall back to the bank of member 0."""
    bank = np.zeros(max(plan.n_entries, 1), dtype=np.int32)
    group_of = {}
    if cache_bank_of_group is not None:
        for g, grp in enumerate(plan.groups):
            # grace._subsets is the SAME enumeration entry_of_subset was
            # built from — entry.members tuples match it exactly
            for sub in _subsets([int(x) for x in grp]):
                group_of.setdefault(sub, g)
    for e, entry in enumerate(plan.entries):
        g = group_of.get(entry.members)
        b = int(cache_bank_of_group[g]) if g is not None else -1
        bank[e] = b if b >= 0 else int(bank_of_row[entry.members[0]])
    return bank[:plan.n_entries] if plan.n_entries else bank[:0]


@dataclasses.dataclass
class FixedCachePlan:
    """A re-mined CachePlan pinned to the serving capacity.

    ``plan`` keeps only the entries that fit (renumbered 0..n_entries-1;
    subsets that overflowed their bank's ``rows_per_bank`` budget are removed
    from ``entry_of_subset`` so ``rewrite_bag`` degrades them to residual row
    reads — losing only the benefit, never the lookup). ``entry_bank`` /
    ``entry_slot`` are PADDED to the full ``n_banks * rows_per_bank``
    capacity: pad ids point at the unused positions, so the remap vectors —
    like the packed cache table — have one shape for the life of the server.
    """

    plan: CachePlan
    entry_bank: np.ndarray      # (capacity,) int32
    entry_slot: np.ndarray      # (capacity,) int32
    n_banks: int
    rows_per_bank: int
    n_dropped: int = 0          # mined entries truncated back to residual

    @property
    def capacity(self) -> int:
        return self.n_banks * self.rows_per_bank

    @property
    def n_entries(self) -> int:
        return self.plan.n_entries


def cap_cache_plan(plan: CachePlan, bank_of_entry: np.ndarray, n_banks: int,
                   rows_per_bank: int) -> FixedCachePlan:
    """Pad/truncate a mined cache plan to the fixed serving capacity.

    Entries keep their mined order; each takes the next free slot on its
    assigned bank, and entries arriving after their bank is full are DROPPED
    (their subsets leave ``entry_of_subset``, so the rewriter falls back to
    residual reads for them). Remaining capacity is distributed to the
    emptiest banks so the padded remap vectors stay in-range.
    """
    capacity = n_banks * rows_per_bank
    kept: list[int] = []
    bank = np.zeros(capacity, dtype=np.int32)
    slot = np.zeros(capacity, dtype=np.int32)
    used = np.zeros(n_banks, dtype=np.int64)
    for e in range(plan.n_entries):
        b = int(bank_of_entry[e])
        if used[b] >= rows_per_bank:
            continue
        bank[len(kept)] = b
        slot[len(kept)] = used[b]
        used[b] += 1
        kept.append(e)
    # pad ids -> remaining (bank, slot) positions, emptiest bank first
    pos = len(kept)
    while pos < capacity:
        b = int(np.argmin(used))
        bank[pos] = b
        slot[pos] = used[b]
        used[b] += 1
        pos += 1
    new_id = {e: i for i, e in enumerate(kept)}
    entries = [CacheEntry(members=plan.entries[e].members,
                          hits=plan.entries[e].hits) for e in kept]
    entry_of_subset = {s: new_id[e] for s, e in plan.entry_of_subset.items()
                       if e in new_id}
    capped = CachePlan(groups=list(plan.groups),
                       benefits=np.asarray(plan.benefits),
                       entries=entries, entry_of_subset=entry_of_subset)
    return FixedCachePlan(plan=capped, entry_bank=bank, entry_slot=slot,
                          n_banks=n_banks, rows_per_bank=rows_per_bank,
                          n_dropped=plan.n_entries - len(kept))


def entry_member_union(fcp: FixedCachePlan) -> np.ndarray:
    """Sorted union of every kept entry's member rows — all a rebuild needs
    to read from the EMT (a few hundred rows, never the vocab)."""
    if not fcp.plan.entries:
        return np.zeros(0, dtype=np.int64)
    return np.unique(np.fromiter(
        (m for e in fcp.plan.entries for m in e.members), np.int64))


def build_cache_table_fixed(rows: np.ndarray, fcp: FixedCachePlan, dtype=None,
                            row_ids: np.ndarray | None = None):
    """Fixed-shape banked GRACE table: entry e (re-summed from the CURRENT
    ``rows`` values) at packed position ``entry_bank[e] * rows_per_bank +
    entry_slot[e]``; pad positions stay zero. The returned BankedTable's
    shapes depend only on (capacity, dim) — never on what was mined — which
    is what lets a swap reuse the compiled serve step.

    ``rows`` is indexed by union-vocab row id — either the full (vocab, dim)
    array, or, with ``row_ids``, just those rows (the serve-loop swap passes
    ``entry_member_union(fcp)`` so a rebuild never materializes the vocab;
    the member-order summation is identical, so both forms are bit-equal)."""
    import jax.numpy as jnp

    from repro.core.embedding import BankedTable

    dim = rows.shape[1]
    dt = rows.dtype if dtype is None else dtype
    packed = np.zeros((fcp.capacity, dim), dtype=dt)
    n = fcp.n_entries
    flat = (fcp.entry_bank.astype(np.int64) * fcp.rows_per_bank
            + fcp.entry_slot)
    if n:
        if row_ids is not None:
            pos = {int(i): j for j, i in enumerate(np.asarray(row_ids))}
            vals = np.stack([
                rows[[pos[int(m)] for m in e.members]].sum(axis=0)
                for e in fcp.plan.entries]).astype(dt)
        else:
            vals = build_cache_table(rows, fcp.plan).astype(dt)[:n]
        packed[flat[:n]] = vals
    return BankedTable(
        packed=jnp.asarray(packed),
        remap_bank=jnp.asarray(fcp.entry_bank, jnp.int32),
        remap_slot=jnp.asarray(fcp.entry_slot, jnp.int32),
        n_banks=fcp.n_banks,
        rows_per_bank=fcp.rows_per_bank,
    )


# ---------------------------------------------------------------------------
# versioned rewriting (in-flight batches survive a swap)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RewrittenBatch:
    """One micro-batch after cache rewriting, tagged with the cache-plan
    version its entry ids are numbered under."""

    cache_idx: np.ndarray       # (..., Lc) int32, -1 padded
    residual_idx: np.ndarray    # (..., Lr) int32, -1 padded
    version: int


class VersionedCacheRewriter:
    """The host/data-pipeline stage of Fig. 7, made swap-safe.

    Owns the CURRENT (FixedCachePlan, cache BankedTable) pair plus the last
    ``keep - 1`` retired pairs. ``rewrite_rect`` always rewrites against the
    current plan and stamps the batch with its version; ``table_for`` hands
    back the table matching any still-retained version, so a batch rewritten
    just before a swap is served against the entry numbering it was rewritten
    for. ``keep=2`` covers the serve loop's one-batch in-flight window;
    deeper pipelines raise it.
    """

    def __init__(self, *, max_cache_per_bag: int, max_residual_per_bag: int,
                 keep: int = 2):
        assert keep >= 1
        self.max_cache_per_bag = int(max_cache_per_bag)
        self.max_residual_per_bag = int(max_residual_per_bag)
        self.keep = int(keep)
        self.version = -1
        self._states: dict[int, tuple[FixedCachePlan, object]] = {}

    def install(self, fcp: FixedCachePlan, table) -> int:
        """Atomically publish a new (plan, table) pair; returns its version.
        Called on the host between micro-batches — the next ``rewrite_rect``
        uses the new plan, already-rewritten batches keep resolving."""
        self.version += 1
        self._states[self.version] = (fcp, table)
        for v in [v for v in self._states if v <= self.version - self.keep]:
            del self._states[v]
        return self.version

    @property
    def current(self) -> tuple[FixedCachePlan, object]:
        return self._states[self.version]

    def plan_for(self, version: int) -> FixedCachePlan:
        return self._state_for(version)[0]

    def table_for(self, version: int):
        return self._state_for(version)[1]

    def _state_for(self, version: int):
        try:
            return self._states[version]
        except KeyError:
            raise KeyError(
                f"cache version {version} retired (retained: "
                f"{sorted(self._states)}); raise keep= for deeper pipelines"
            ) from None

    def rewrite_rect(self, union_idx: np.ndarray) -> RewrittenBatch:
        """(..., L) union-vocab ids (-1 padded) -> version-tagged
        (cache_idx, residual_idx) at the static per-bag budgets."""
        if union_idx.shape[-1] > self.max_residual_per_bag:
            # a bag of L unique rows with no cache hit needs L residual
            # slots; past the budget rewrite_bags would silently DROP
            # lookups (wrong scores), so refuse loudly instead — size
            # max_residual_per_bag to the serve batch's bag length
            raise ValueError(
                f"bag length {union_idx.shape[-1]} > max_residual_per_bag "
                f"{self.max_residual_per_bag}: residual overflow would drop "
                f"lookups")
        fcp, _ = self.current
        lead = union_idx.shape[:-1]
        flat = union_idx.reshape(-1, union_idx.shape[-1])
        bags = [row[row >= 0] for row in flat]
        ci, ri = rewrite_bags(bags, fcp.plan,
                              max_cache_per_bag=self.max_cache_per_bag,
                              max_residual_per_bag=self.max_residual_per_bag)
        return RewrittenBatch(
            cache_idx=ci.reshape(*lead, self.max_cache_per_bag),
            residual_idx=ri.reshape(*lead, self.max_residual_per_bag),
            version=self.version)
