"""Cache runtime: request rewriting + partial-sum cache table build/refresh.

The paper's Fig. 7 flow: before dispatch, the host checks each request's index
set against the cache index; matched subsets are replaced by a single cached
partial-sum read, the rest go to the EMT.  We mirror that split:

  host (data pipeline):  rewrite_bags()  — bag indices -> (cache ids, residual
                         ids), padded to static shapes for the jitted step.
  device:                cache partial-sum table is just another (small) bank-
                         partitioned table; the fused lookup adds
                         embedding_bag(cache_table, cache_ids)
                       + embedding_bag(emt, residual_ids).

Training note (beyond the paper, which is inference-only): cached sums go stale
when the EMT trains; ``build_cache_table`` is cheap (one gather+sum per entry)
and is refreshed every ``refresh_every`` steps by the train loop.
"""
from __future__ import annotations

import numpy as np

from repro.core.grace import CachePlan


def build_cache_table(table: np.ndarray, plan: CachePlan) -> np.ndarray:
    """(n_entries, dim) partial sums — entry e stores sum(table[members_e])."""
    dim = table.shape[1]
    out = np.zeros((max(plan.n_entries, 1), dim), dtype=table.dtype)
    for e, entry in enumerate(plan.entries):
        out[e] = table[list(entry.members)].sum(axis=0)
    return out


def rewrite_bag(bag: np.ndarray, plan: CachePlan) -> tuple[list[int], list[int]]:
    """One bag -> (cache entry ids, residual row ids).  Greedy largest-subset
    match per group (Fig. 7: {1,4,5} -> cache hit (4+5), residual {1})."""
    present = set(int(i) for i in bag)
    cache_ids: list[int] = []
    for group in plan.groups:
        inter = tuple(sorted(present & set(int(i) for i in group)))
        if len(inter) >= 2:
            eid = plan.entry_of_subset.get(inter)
            if eid is not None:
                cache_ids.append(eid)
                present -= set(inter)
    return cache_ids, sorted(present)


def rewrite_bags(
    bags: list[np.ndarray],
    plan: CachePlan,
    *,
    max_cache_per_bag: int,
    max_residual_per_bag: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Batch rewrite to padded static shapes (-1 padding).

    Returns (cache_idx (B, max_cache), residual_idx (B, max_residual)).
    Overflow beyond the static budgets falls back to residual reads (never
    drops lookups; only loses cache benefit), then truncates with a warning
    count — matching static-shape jit semantics.
    """
    B = len(bags)
    cache_idx = np.full((B, max_cache_per_bag), -1, dtype=np.int32)
    resid_idx = np.full((B, max_residual_per_bag), -1, dtype=np.int32)
    for i, bag in enumerate(bags):
        c, r = rewrite_bag(bag, plan)
        # cache hits beyond the static budget DEGRADE to residual row reads
        # (losing only the benefit, never the lookup)
        for eid in c[max_cache_per_bag:]:
            r.extend(plan.entries[eid].members)
        c = c[:max_cache_per_bag]
        r = sorted(set(r))[:max_residual_per_bag]
        cache_idx[i, :len(c)] = c
        resid_idx[i, :len(r)] = r
    return cache_idx, resid_idx


def measure_hit_rate(bags: list[np.ndarray], plan: CachePlan) -> float:
    """Fraction of row reads eliminated by the cache (Fig. 6's ~40% metric)."""
    saved = 0
    total = 0
    for bag in bags:
        c, r = rewrite_bag(bag, plan)
        total += len(set(int(i) for i in bag))
        saved += len(set(int(i) for i in bag)) - (len(c) + len(r))
    return saved / max(total, 1)
