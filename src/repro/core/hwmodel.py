"""Hardware profiles + the paper's analytic three-stage embedding latency model.

Two profiles:
  * UPMEM  — constants from the paper (Fig. 3 MRAM latency curve, 256 DPUs,
             64 MB MRAM, ~800 MB/s MRAM-WRAM per DPU, 350 MHz) so the benchmark
             harness can reproduce Figs. 8–11 under the paper's own cost model.
  * TPUv5e — the adaptation target (197 TFLOP/s bf16, 819 GB/s HBM, 16 GB,
             ~50 GB/s/link ICI) used by the roofline analysis.

The stage model is Eq. 1–3 of the paper:
    T_embed = T_c_comm + T_lkp + T_d_comm
    T_c_comm = per-bank index traffic * t_c      (stage 1: broadcast IDX/OFFSET)
    T_lkp    = per-bank lookups * t_a(N_c*4B)    (stage 2: near-memory gather+reduce)
    T_d_comm = N_c * batch * t_d                 (stage 3: partial sums back)
with the bank's share of lookups depending on the partitioner (uniform => even
split; non-uniform/cache-aware => the partitioner's realized per-bank load).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class UPMEMProfile:
    """Constants for the paper's hardware (UPMEM DPU, Table 2 / §2.2)."""

    n_dpus: int = 256
    mram_bytes: int = 64 * 2**20          # 64 MB per bank
    wram_bytes: int = 64 * 2**10
    tasklets: int = 14                    # per-DPU threads (paper §4.1)
    dpu_hz: float = 350e6
    mram_wram_bw: float = 800e6           # B/s per DPU (paper §2.2)
    # CPU<->DPU DDR4 transfer cost per 4-byte value as seen by ONE bank when
    # all banks transfer concurrently (UPMEM parallel xfer mode; PrIM,
    # arXiv:2105.03814 reports per-DPU shares of rank bandwidth). Calibrated
    # so the stage shares reproduce the paper's Fig. 10 (lookup 71-77% at
    # N_c=2 under U/NU; d_comm rising to ~35% at N_c=8).
    t_c_per_val: float = 4.0 / 500e6      # s per 4B value, CPU->DPU
    t_d_per_val: float = 4.0 / 30e6       # s per 4B value, DPU->CPU (slower dir)

    def mram_read_latency(self, nbytes: float) -> float:
        """Fig. 3: MRAM read latency vs access size.

        Shape measured by the paper (and PrIM, arXiv:2105.03814): a fixed DMA
        setup cost dominates up to ~32 B, then the transfer term takes over and
        latency grows ~linearly to the 2048 B max.
        """
        setup_s = 77e-9                    # ~27 cycles @350 MHz DMA setup
        per_byte = 1.0 / self.mram_wram_bw
        nbytes = float(np.clip(nbytes, 8, 2048))
        # sub-32B reads ride almost entirely on the setup cost (Fig. 3 plateau)
        plateau = setup_s + 32 * per_byte
        if nbytes <= 32:
            return plateau
        return setup_s + nbytes * per_byte


@dataclasses.dataclass(frozen=True)
class TPUv5eProfile:
    """Roofline constants for the adaptation target (per chip)."""

    peak_flops: float = 197e12            # bf16 FLOP/s
    hbm_bw: float = 819e9                 # B/s
    hbm_bytes: int = 16 * 2**30
    ici_bw: float = 50e9                  # B/s per link
    vmem_bytes: int = 128 * 2**20         # ~128 MB VMEM v5e


@dataclasses.dataclass(frozen=True)
class CPUProfile:
    """Xeon Silver 4110 host (paper Table 2): DDR4-2400 x 6ch theoretical
    ~115 GB/s; random row-granular gathers achieve a small fraction of it
    (pointer-chasing, TLB misses) — rand_eff calibrated to published DLRM
    CPU inference studies (Gupta et al., HPCA'20)."""

    ddr_bw: float = 115e9
    rand_eff: float = 0.08            # effective fraction on random gathers
    mlp_gflops: float = 150e9         # sustained CPU GEMM throughput
    pcie_bw: float = 12e9             # effective PCIe 3.0 x16 to GPU


CPU_HOST = CPUProfile()
UPMEM = UPMEMProfile()
TPUV5E = TPUv5eProfile()


def cpu_lookup_time(total_lookups: float, row_bytes: float,
                    cpu: CPUProfile = CPU_HOST) -> float:
    return total_lookups * row_bytes / (cpu.ddr_bw * cpu.rand_eff)


def system_inference_time(
    system: str,
    *,
    batch_size: int,
    avg_reduction: float,
    n_tables: int,
    dim: int,
    mlp_flops: float,
    per_bank_lookup_share: np.ndarray | None = None,
    n_banks: int = 256,
    cache_hit_rate: float = 0.0,
    fae_hot_fraction: float = 0.8,
    n_c: int = 8,
    hw: UPMEMProfile = UPMEM,
    cpu: CPUProfile = CPU_HOST,
) -> float:
    """End-to-end inference-time model for the paper's four systems (Fig. 8).

    DLRM-CPU    : CPU random-gather lookups + CPU MLP.
    DLRM-Hybrid : CPU lookups + PCIe transfer of pooled embeddings + GPU MLP
                  (GPU compute overlapped; PCIe + CPU lookup serialize - §4.2).
    FAE         : hot fraction of lookups served from GPU HBM cache (free vs
                  PCIe), cold remainder follows the hybrid path.
    UpDLRM      : Eq. 1-3 stage model (banked lookups + combine) + CPU MLP.
    """
    row_bytes = dim * 4.0
    total_lookups = batch_size * avg_reduction * n_tables
    t_mlp_cpu = mlp_flops * batch_size / cpu.mlp_gflops
    pooled_bytes = batch_size * n_tables * row_bytes

    # GPU-side fixed cost per inference batch in the hybrid designs: kernel
    # launches + CPU<->GPU sync while the GPU stalls on embedding results —
    # the effect the paper names to explain DLRM-Hybrid ranking WORST (§4.2).
    # Calibrated against Fig. 8's orderings (hybrid < cpu < fae < updlrm).
    gpu_sync_overhead = 1.0e-3

    if system == "cpu":
        return cpu_lookup_time(total_lookups, row_bytes, cpu) + t_mlp_cpu
    if system == "hybrid":
        t_lkp = cpu_lookup_time(total_lookups, row_bytes, cpu)
        t_pcie = pooled_bytes / cpu.pcie_bw
        return t_lkp + t_pcie + 0.1 * t_mlp_cpu + gpu_sync_overhead
    if system == "fae":
        cold = 1.0 - fae_hot_fraction
        t_lkp = cpu_lookup_time(total_lookups * cold, row_bytes, cpu)
        t_pcie = pooled_bytes * cold / cpu.pcie_bw
        return t_lkp + t_pcie + 0.1 * t_mlp_cpu + 0.3 * gpu_sync_overhead
    if system == "updlrm":
        # tables occupy disjoint bank groups and run in parallel
        st = embedding_stage_latency(
            batch_size=batch_size, avg_reduction=avg_reduction, n_c=n_c,
            per_bank_lookup_share=per_bank_lookup_share,
            n_banks=max(1, n_banks // n_tables), hw=hw,
            cache_hit_rate=cache_hit_rate)
        return st.total + t_mlp_cpu
    raise ValueError(system)


@dataclasses.dataclass
class StageLatency:
    c_comm: float
    lookup: float
    d_comm: float

    @property
    def total(self) -> float:
        return self.c_comm + self.lookup + self.d_comm


def updlrm_layout(n_banks_table: int, cols: int, n_c: int
                  ) -> tuple[int, int]:
    """§3.1 bank factorization for one table: banks = row_groups x col_groups.

    A row is split over ``col_groups = C/N_c`` banks (each holding its N_c
    columns); rows distribute over ``row_groups = n_banks_table/col_groups``
    bins — the bins the row partitioners (U/NU/CA) operate on. Larger N_c =>
    fewer column groups => MORE row groups => smaller per-bank lookup share
    but wider (slower past 32 B) MRAM reads and a fatter stage-3 return: the
    paper's Eq. 1 tradeoff.
    """
    col_groups = max(1, cols // n_c)
    row_groups = max(1, n_banks_table // col_groups)
    return row_groups, col_groups


def embedding_stage_latency(
    *,
    batch_size: int,
    avg_reduction: float,
    n_c: int,
    per_bank_lookup_share: np.ndarray | None = None,
    n_banks: int | None = None,
    hw: UPMEMProfile = UPMEM,
    cache_hit_rate: float = 0.0,
    cache_avg_group: float = 2.0,
) -> StageLatency:
    """Eq. 1 of the paper for ONE table, generalized to a per-row-group load
    vector (tables run on disjoint banks in parallel, so the embedding layer
    time is the max over same-profile tables = one table's time).

    per_bank_lookup_share: fraction of the table's lookups landing on each
    ROW GROUP (length = row_groups from updlrm_layout; sums to 1). Uniform
    partitioning => all-equal; skewed traces under uniform => the hottest
    bank bounds stage 2 (banks run in parallel) — exactly why the paper's
    non-uniform partitioning helps.

    cache_hit_rate: fraction of lookups resolved by a cached partial sum;
    each hit replaces ~cache_avg_group row reads with one.
    """
    if per_bank_lookup_share is None:
        assert n_banks is not None
        per_bank_lookup_share = np.full(n_banks, 1.0 / n_banks)

    total_lookups = batch_size * avg_reduction
    # caching collapses groups of cache_avg_group reads into one
    effective_lookups = total_lookups * (1 - cache_hit_rate) \
        + total_lookups * cache_hit_rate / cache_avg_group

    t_a = hw.mram_read_latency(n_c * 4)
    # banks run in parallel => stage-1/2 set by the HOTTEST bank's share;
    # tasklet pipelining overlaps successive MRAM DMAs (§4.4).
    hottest_share = float(np.max(per_bank_lookup_share))
    lkp = effective_lookups * hottest_share * t_a / min(hw.tasklets, 4)

    # stage 1 (paper Eq.): T_c-comm = share * batch * Avg_Red * t_c — each
    # bank receives only the indices of rows it owns; ranks transfer in
    # parallel.
    c_comm = effective_lookups * hottest_share * hw.t_c_per_val

    # stage 3 (paper Eq.): T_d-comm = N_c * batch * t_d — every bank returns
    # an N_c-wide partial per sample; same-size buffers transfer concurrently
    # (§2.2), so no n_banks factor.
    d_comm = n_c * batch_size * hw.t_d_per_val
    return StageLatency(c_comm=c_comm, lookup=lkp, d_comm=d_comm)


def solve_uniform_tile(
    *,
    rows: int,
    cols: int,
    n_banks: int,
    batch_size: int,
    avg_reduction: float,
    hw: UPMEMProfile = UPMEM,
) -> tuple[int, int]:
    """§3.1 uniform-partitioning solver: pick (N_r, N_c) minimizing Eq. 1.

    Constraints (Eq. 2–3): N_r*N_c = R*C/N_banks <= 1.6e7 values (64 MB of 4B),
    N_c in {2,4,6,8}. Exhaustive search over the (tiny) feasible set.
    """
    budget_vals = hw.mram_bytes // 4
    per_bank_vals = rows * cols / n_banks
    if per_bank_vals > budget_vals:
        raise ValueError(
            f"table ({rows}x{cols}) needs more than {n_banks} banks "
            f"({per_bank_vals:.0f} > {budget_vals} values/bank)")
    best, best_t = None, float("inf")
    for k in range(1, 5):
        n_c = 2 * k
        if n_c > cols:
            break
        n_row_groups, n_col_groups = updlrm_layout(n_banks, cols, n_c)
        n_r = int(np.ceil(rows / n_row_groups))
        if n_r * n_c > budget_vals:
            continue
        lat = embedding_stage_latency(
            batch_size=batch_size, avg_reduction=avg_reduction, n_c=n_c,
            n_banks=n_row_groups, hw=hw).total
        if lat < best_t:
            best, best_t = (n_r, n_c), lat
    if best is None:
        raise ValueError("no feasible (N_r, N_c) under the MRAM budget")
    return best
