"""Embedding-table partitioning — the paper's §3 contribution.

Three partitioners, all producing a ``PartitionPlan`` (row -> (bank, slot) map)
that the runtime (core/embedding.py) applies on-device:

  * ``uniform_partition``      §3.1 — equal row blocks per bank; the companion
                               tile solver (N_r, N_c) lives in core/hwmodel.py.
  * ``non_uniform_partition``  §3.2 — greedy frequency-aware bin-packing: sort
                               rows by access frequency descending, assign each
                               to the bank with the lowest aggregate load that
                               still has capacity.  O(R log B) with a heap,
                               optional batching (paper: "one could batch items
                               ... to reduce algorithm complexity").
  * ``cache_aware_partition``  §3.3, Algorithm 1 — joint bin-packing of GRACE
                               cache lists (load-weighted minus the cached-sum
                               benefit) and residual rows, balancing the
                               COMBINED (EMT + cache) access load per bank.

Banks are UPMEM DPUs in the paper; here they are mesh-axis shards (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np


@dataclasses.dataclass
class PartitionPlan:
    """Row -> (bank, slot) assignment for one table (+ optional cache side)."""

    n_banks: int
    bank_of_row: np.ndarray          # (vocab,) int32
    slot_of_row: np.ndarray          # (vocab,) int32  — row index inside its bank
    rows_per_bank: np.ndarray        # (n_banks,) int32
    load_per_bank: np.ndarray        # (n_banks,) float64 — aggregate access freq
    # cache side (cache-aware only): cache entry -> (bank, slot)
    cache_bank_of_entry: np.ndarray | None = None
    cache_slot_of_entry: np.ndarray | None = None
    cache_rows_per_bank: np.ndarray | None = None

    @property
    def vocab(self) -> int:
        return int(self.bank_of_row.shape[0])

    @property
    def max_rows_per_bank(self) -> int:
        return int(self.rows_per_bank.max())

    def imbalance(self) -> float:
        """max/mean aggregate load across banks (1.0 == perfectly balanced)."""
        mean = self.load_per_bank.mean()
        return float(self.load_per_bank.max() / mean) if mean > 0 else 1.0

    def validate(self) -> None:
        assert self.bank_of_row.min() >= 0 and self.bank_of_row.max() < self.n_banks
        for b in range(self.n_banks):
            slots = self.slot_of_row[self.bank_of_row == b]
            assert slots.shape[0] == self.rows_per_bank[b]
            if slots.shape[0]:
                assert slots.min() == 0 and slots.max() == slots.shape[0] - 1
                assert np.unique(slots).shape[0] == slots.shape[0]


def _plan_from_banks(n_banks: int, bank_of_row: np.ndarray,
                     freq: np.ndarray) -> PartitionPlan:
    vocab = bank_of_row.shape[0]
    slot = np.zeros(vocab, dtype=np.int32)
    rows_per_bank = np.zeros(n_banks, dtype=np.int32)
    load = np.zeros(n_banks, dtype=np.float64)
    # stable slot assignment: row order within a bank follows global row id
    for b in range(n_banks):
        members = np.flatnonzero(bank_of_row == b)
        slot[members] = np.arange(members.shape[0], dtype=np.int32)
        rows_per_bank[b] = members.shape[0]
        load[b] = freq[members].sum()
    return PartitionPlan(
        n_banks=n_banks,
        bank_of_row=bank_of_row.astype(np.int32),
        slot_of_row=slot,
        rows_per_bank=rows_per_bank,
        load_per_bank=load,
    )


@dataclasses.dataclass
class ReplicatedPlan:
    """Replication-aware row -> (bank, slot) assignment (§3.2 + hot-row
    replication).

    Row ``v`` owns ``copies[v]`` physical copies, each on a DISTINCT bank.
    The per-row maps are ``(vocab, k_max)``: column ``r`` holds copy
    ``r % copies[v]`` (cyclic padding), so a reader that picks any column in
    ``[0, k_max)`` — e.g. the kernel's ``wang_hash(bag) % k_max`` — always
    lands on a valid copy, and when ``copies[v]`` divides ``k_max`` the
    traffic splits uniformly across the copies. Single-copy rows repeat the
    same (bank, slot) in every column, which makes ``k_max == 1`` (or a plan
    with no replicated rows) bit-identical to the plain ``PartitionPlan``
    layout.
    """

    n_banks: int
    k_max: int
    copies: np.ndarray               # (vocab,) int32 in {1, k_max}
    bank_of_copy: np.ndarray         # (vocab, k_max) int32
    slot_of_copy: np.ndarray         # (vocab, k_max) int32
    rows_per_bank: np.ndarray        # (n_banks,) int32 — physical rows stored
    load_per_bank: np.ndarray        # (n_banks,) float64 — freq split k ways

    @property
    def vocab(self) -> int:
        return int(self.copies.shape[0])

    @property
    def max_rows_per_bank(self) -> int:
        return int(self.rows_per_bank.max())

    @property
    def n_replicated(self) -> int:
        return int((self.copies > 1).sum())

    def imbalance(self) -> float:
        mean = self.load_per_bank.mean()
        return float(self.load_per_bank.max() / mean) if mean > 0 else 1.0

    def max_share(self) -> float:
        """Hottest bank's share of total modeled traffic (ideal: 1/n_banks)."""
        total = self.load_per_bank.sum()
        return float(self.load_per_bank.max() / total) if total > 0 else 0.0

    def validate(self) -> None:
        V, k = self.bank_of_copy.shape
        assert k == self.k_max and self.slot_of_copy.shape == (V, k)
        assert self.bank_of_copy.min() >= 0
        assert self.bank_of_copy.max() < self.n_banks
        cols = np.arange(k)[None, :] % self.copies[:, None]
        # cyclic padding: column r repeats copy r % copies[v]
        base = self.bank_of_copy[np.arange(V)[:, None], cols]
        assert (base == self.bank_of_copy).all()
        for v in np.flatnonzero(self.copies > 1):
            c = int(self.copies[v])
            assert np.unique(self.bank_of_copy[v, :c]).shape[0] == c, \
                "replica copies must land on distinct banks"
        # physical (bank, slot) pairs are unique and dense per bank
        vv, rr = np.nonzero(np.arange(k)[None, :] < self.copies[:, None])
        bb, ss = self.bank_of_copy[vv, rr], self.slot_of_copy[vv, rr]
        for b in range(self.n_banks):
            slots = ss[bb == b]
            assert slots.shape[0] == self.rows_per_bank[b]
            if slots.shape[0]:
                assert slots.min() == 0 and slots.max() == slots.shape[0] - 1
                assert np.unique(slots).shape[0] == slots.shape[0]


def choose_replication(freq: np.ndarray, n_banks: int, *, k_max: int,
                       max_r: int = 256,
                       hot_rows: np.ndarray | None = None) -> np.ndarray:
    """Pick the copy count per row from live head mass.

    A row whose frequency exceeds the perfectly-balanced per-copy load
    ``total / (n_banks * k_max)`` cannot be spread by placement alone — it
    gets ``k_max`` copies; everything else stays single-copy. ``max_r``
    bounds the capacity cost (R extra-copy rows cost ``R * (k_max - 1)``
    physical rows). ``hot_rows`` (e.g. the tiered lane's bf16 head) further
    restricts candidates so replicas stay in the full-precision tier.
    """
    vocab = freq.shape[0]
    copies = np.ones(vocab, dtype=np.int32)
    if k_max <= 1 or vocab == 0:
        return copies
    freq = np.asarray(freq, np.float64)
    total = float(freq.sum())
    if total <= 0:
        return copies
    hot = freq > total / (n_banks * k_max)
    if hot_rows is not None:
        mask = np.zeros(vocab, dtype=bool)
        mask[np.asarray(hot_rows, np.int64)] = True
        hot &= mask
    cand = np.flatnonzero(hot)
    if cand.shape[0] > max_r:
        cand = cand[np.argsort(-freq[cand], kind="stable")[:max_r]]
    copies[cand] = k_max
    return copies


def replicated_partition(
    freq: np.ndarray,
    n_banks: int,
    *,
    copies: np.ndarray,
    capacity_rows: int | None = None,
    k_max: int | None = None,
    bank_capacity_rows: np.ndarray | None = None,
) -> ReplicatedPlan:
    """§3.2 greedy, replication-aware: each row's ``copies[v]`` copies go to
    the ``copies[v]`` least-loaded DISTINCT banks with capacity, each copy
    accounted at ``freq[v] / copies[v]`` (the hash splits reads uniformly).

    With ``copies`` all ones this reduces to exactly the
    ``non_uniform_partition`` greedy (same heap tie-breaking, same stable
    slot order), so the k=1 plan is the single-copy plan. ``k_max`` pins the
    map width independently of ``copies.max()`` so a serve loop can swap
    between replicated and unreplicated plans without a shape change.
    """
    vocab = freq.shape[0]
    freq = np.asarray(freq, np.float64)
    copies = np.asarray(copies, np.int32)
    if copies.shape != (vocab,):
        raise ValueError(f"copies {copies.shape} != ({vocab},)")
    if vocab and copies.min() < 1:
        raise ValueError("copies must be >= 1")
    k_need = int(copies.max()) if vocab else 1
    k_max = k_need if k_max is None else int(k_max)
    if k_need > k_max:
        raise ValueError(f"copies.max() {k_need} > k_max {k_max}")
    if k_need > n_banks:
        raise ValueError(f"copies.max() {k_need} > n_banks {n_banks}: "
                         f"replica copies must land on distinct banks")
    total_rows = int(copies.sum())
    if capacity_rows is None:
        capacity_rows = total_rows
    if bank_capacity_rows is None:
        cap_of = np.full(n_banks, int(capacity_rows), dtype=np.int64)
    else:
        # per-bank override (e.g. 0 rows for a dead bank on the fault path)
        cap_of = np.asarray(bank_capacity_rows, np.int64)
        if cap_of.shape != (n_banks,):
            raise ValueError(f"bank_capacity_rows {cap_of.shape} != ({n_banks},)")
    if int(cap_of.sum()) < total_rows:
        raise ValueError(
            f"capacity exhausted: {int(cap_of.sum())} total rows across "
            f"{n_banks} banks < {total_rows} physical rows (vocab {vocab} + "
            f"{total_rows - vocab} replica copies) — raise capacity_rows or "
            f"lower replication")
    order = np.argsort(-freq, kind="stable")
    bank_cols = np.full((vocab, k_max), -1, dtype=np.int32)
    # heap of (load, rows_used, bank); capacity never grows, so a full bank
    # is dropped for good
    heap: list[tuple[float, int, int]] = [(0.0, 0, b) for b in range(n_banks)]
    heapq.heapify(heap)
    for v in order:
        c = int(copies[v])
        share = float(freq[v]) / c
        chosen: list[tuple[float, int, int]] = []
        for _ in range(c):
            while heap and heap[0][1] >= cap_of[heap[0][2]]:
                heapq.heappop(heap)
            if not heap:
                raise ValueError("capacity exhausted — raise capacity_rows "
                                 "or lower replication")
            chosen.append(heapq.heappop(heap))
        for r, (load, used, b) in enumerate(chosen):
            bank_cols[v, r] = b
            heapq.heappush(heap, (load + share, used + 1, b))
    # stable slot assignment: within a bank, physical rows follow
    # (global row id, copy index) order — the replicated analogue of
    # _plan_from_banks' global-id order
    vv, rr = np.nonzero(np.arange(k_max)[None, :] < copies[:, None])
    bb = bank_cols[vv, rr]
    slot_flat = np.zeros(vv.shape[0], dtype=np.int32)
    for b in range(n_banks):
        m = bb == b
        slot_flat[m] = np.arange(int(m.sum()), dtype=np.int32)
    slot_cols = np.full((vocab, k_max), -1, dtype=np.int32)
    slot_cols[vv, rr] = slot_flat
    cols = np.arange(k_max)[None, :] % copies[:, None]
    rows_idx = np.arange(vocab)[:, None]
    return ReplicatedPlan(
        n_banks=n_banks,
        k_max=k_max,
        copies=copies,
        bank_of_copy=bank_cols[rows_idx, cols].astype(np.int32),
        slot_of_copy=slot_cols[rows_idx, cols].astype(np.int32),
        rows_per_bank=np.bincount(bb, minlength=n_banks).astype(np.int32),
        load_per_bank=np.bincount(bb, weights=(freq / copies)[vv],
                                  minlength=n_banks),
    )


def uniform_partition(vocab: int, n_banks: int,
                      freq: np.ndarray | None = None) -> PartitionPlan:
    """§3.1: contiguous equal row blocks (block b gets rows [b*Nr, (b+1)*Nr))."""
    if freq is None:
        freq = np.ones(vocab, dtype=np.float64)
    n_r = -(-vocab // n_banks)  # ceil
    bank_of_row = np.minimum(np.arange(vocab) // n_r, n_banks - 1)
    return _plan_from_banks(n_banks, bank_of_row.astype(np.int32), freq)


def non_uniform_partition(
    freq: np.ndarray,
    n_banks: int,
    *,
    capacity_rows: int | None = None,
    batch: int = 1,
    row_weights: np.ndarray | None = None,
    bank_capacity_rows: np.ndarray | None = None,
    bank_cost: np.ndarray | None = None,
) -> PartitionPlan:
    """§3.2: greedy frequency bin-packing with a fixed number of bins.

    capacity_rows: per-bank row budget (the 64 MB MRAM constraint / its TPU
    analogue).  batch>1 assigns rows in groups of `batch` (paper's complexity
    note); batch=1 is the exact greedy.

    row_weights: optional per-row cost multiplier — the mixed-precision
    extension. A tiered table (repro.quant) moves a different byte count per
    row read, so the load the greedy balances becomes ``freq * row_weights``
    (bytes moved per bank, Eq. 1's bandwidth term) instead of row reads;
    ``plan.load_per_bank`` then reports byte-load. Capacity still counts
    ROWS (the packed arrays stay rectangular at ``rows_per_bank``).

    bank_capacity_rows: optional (n_banks,) per-bank row budgets overriding
    ``capacity_rows`` — the fault-tolerance hook: a DEAD bank gets capacity
    0 and is excluded from packing entirely, so the replan re-packs its rows
    onto the survivors. Raises with a capacity diagnosis when the surviving
    banks cannot hold the vocab.

    bank_cost: optional (n_banks,) load multiplier per bank — the straggler
    hook: a bank observed k-times slower ACCOUNTS each accepted row at k x
    its frequency, so the greedy sheds load off slow banks exactly like it
    sheds hot rows off loaded ones. ``plan.load_per_bank`` still reports the
    raw (uncosted) traffic.
    """
    vocab = freq.shape[0]
    if row_weights is not None:
        if row_weights.shape[0] != vocab:
            raise ValueError(f"row_weights {row_weights.shape} != vocab "
                             f"{vocab}")
        freq = np.asarray(freq, np.float64) * np.asarray(row_weights,
                                                         np.float64)
    if capacity_rows is None:
        capacity_rows = vocab  # uncapped
    if bank_capacity_rows is None:
        cap_of = np.full(n_banks, capacity_rows, dtype=np.int64)
    else:
        cap_of = np.asarray(bank_capacity_rows, np.int64)
        if cap_of.shape != (n_banks,):
            raise ValueError(f"bank_capacity_rows {cap_of.shape} != "
                             f"({n_banks},)")
        cap_of = np.minimum(cap_of, capacity_rows)
    if cap_of.sum() < vocab:
        n_live = int((cap_of > 0).sum())
        raise ValueError(
            f"capacity exhausted: {n_live}/{n_banks} banks with "
            f"{int(cap_of.sum())} total rows < vocab {vocab} — increase "
            f"banks or capacity (after a bank failure: raise the per-bank "
            f"slack so survivors can absorb the dead bank's rows)")
    cost_of = np.ones(n_banks, dtype=np.float64) if bank_cost is None \
        else np.asarray(bank_cost, np.float64)
    if cost_of.shape != (n_banks,):
        raise ValueError(f"bank_cost {cost_of.shape} != ({n_banks},)")
    order = np.argsort(-freq, kind="stable")
    bank_of_row = np.full(vocab, -1, dtype=np.int32)
    # heap of (costed load, rows_used, bank); zero-capacity (dead) banks
    # never enter it
    heap: list[tuple[float, int, int]] = [(0.0, 0, b) for b in range(n_banks)
                                          if cap_of[b] > 0]
    heapq.heapify(heap)
    parked: list[tuple[float, int, int]] = []
    i = 0
    while i < vocab:
        j = min(i + batch, vocab)
        group = order[i:j]
        gload = float(freq[group].sum())
        # pop until a bank with capacity for the whole group appears
        while heap and heap[0][1] + (j - i) > cap_of[heap[0][2]]:
            parked.append(heapq.heappop(heap))
        if not heap:
            raise ValueError("capacity exhausted — increase banks or capacity")
        load, used, b = heapq.heappop(heap)
        bank_of_row[group] = b
        heapq.heappush(heap, (load + gload * cost_of[b], used + (j - i), b))
        # full banks stay parked (they can never take more rows)
        keep = [p for p in parked if p[1] < cap_of[p[2]]]
        for p in keep:
            heapq.heappush(heap, p)
        parked = [p for p in parked if p[1] >= cap_of[p[2]]]
        i = j
    return _plan_from_banks(n_banks, bank_of_row, freq)


def cache_aware_partition(
    freq: np.ndarray,
    cache_lists: list[np.ndarray],
    benefits: np.ndarray,
    n_banks: int,
    *,
    emt_capacity_rows: int | None = None,
    cache_capacity_entries: int | None = None,
) -> PartitionPlan:
    """§3.3 Algorithm 1: cache-aware non-uniform partitioning.

    cache_lists[g] = row ids of co-occurring group g (GRACE output);
    benefits[g]   = estimated reduction in memory accesses from caching group
                    g's partial sums (Alg. 1 line 5: `benefit = list[-1]`).

    Each cached group's member rows are co-located on one bank together with
    the group's partial-sum cache entries; the bank's accounted load is the
    members' frequency sum MINUS the benefit (lines 9–10).  Residual rows
    follow the plain greedy (lines 11–15).  The returned plan also carries the
    cache-entry placement (entry g lives on the bank of its members).
    """
    vocab = freq.shape[0]
    n_groups = len(cache_lists)
    if emt_capacity_rows is None:
        emt_capacity_rows = vocab
    if cache_capacity_entries is None:
        cache_capacity_entries = max(1, n_groups)

    bank_of_row = np.full(vocab, -1, dtype=np.int32)
    cache_bank = np.full(n_groups, -1, dtype=np.int32)
    load = np.zeros(n_banks, dtype=np.float64)
    rows_used = np.zeros(n_banks, dtype=np.int64)
    cache_used = np.zeros(n_banks, dtype=np.int64)
    in_cache = np.zeros(vocab, dtype=bool)

    # --- lines 4-10: place cache groups first (sorted by member frequency) ---
    group_load = np.array([freq[g].sum() for g in cache_lists])
    for g in np.argsort(-group_load, kind="stable"):
        members = cache_lists[g]
        # bank with lowest current load and enough cache + EMT capacity
        cand = sorted(range(n_banks), key=lambda b: load[b])
        placed = False
        for b in cand:
            if (cache_used[b] + 1 <= cache_capacity_entries
                    and rows_used[b] + members.shape[0] <= emt_capacity_rows):
                new = members[bank_of_row[members] < 0]
                bank_of_row[new] = b
                in_cache[members] = True
                rows_used[b] += new.shape[0]
                cache_used[b] += 1
                cache_bank[g] = b
                load[b] += float(freq[members].sum()) - float(benefits[g])
                placed = True
                break
        if not placed:  # cache full everywhere -> group degrades to plain rows
            continue

    # --- lines 11-15: residual rows by plain greedy ---
    residual = np.flatnonzero(bank_of_row < 0)
    order = residual[np.argsort(-freq[residual], kind="stable")]
    heap = [(load[b], b) for b in range(n_banks)]
    heapq.heapify(heap)
    for r in order:
        parked = []
        while heap and rows_used[heap[0][1]] + 1 > emt_capacity_rows:
            parked.append(heapq.heappop(heap))
        if not heap:
            raise ValueError("EMT capacity exhausted")
        l, b = heapq.heappop(heap)
        bank_of_row[r] = b
        rows_used[b] += 1
        heapq.heappush(heap, (l + float(freq[r]), b))
        for p in parked:
            heapq.heappush(heap, p)

    plan = _plan_from_banks(n_banks, bank_of_row, freq)
    # recompute accounted load including cache benefit (for imbalance reporting)
    acc = np.zeros(n_banks, dtype=np.float64)
    for b in range(n_banks):
        acc[b] = freq[bank_of_row == b].sum()
    for g in range(n_groups):
        if cache_bank[g] >= 0:
            acc[cache_bank[g]] -= float(benefits[g])
    plan.load_per_bank = np.maximum(acc, 0.0)
    # cache entry slots: sequential per bank
    cache_slot = np.full(n_groups, -1, dtype=np.int32)
    cache_rows = np.zeros(n_banks, dtype=np.int32)
    for g in range(n_groups):
        b = cache_bank[g]
        if b >= 0:
            cache_slot[g] = cache_rows[b]
            cache_rows[b] += 1
    plan.cache_bank_of_entry = cache_bank
    plan.cache_slot_of_entry = cache_slot
    plan.cache_rows_per_bank = cache_rows
    return plan


def expert_placement(expert_load: np.ndarray, n_banks: int) -> np.ndarray:
    """Beyond-paper: reuse the §3.2 greedy for MoE expert->device placement.

    MoE expert-dispatch imbalance is the same bin-packing problem as bank-load
    imbalance (DESIGN.md §4).  Returns bank id per expert, balanced by routed
    token counts, equal expert count per bank (capacity = E / n_banks).
    """
    n_exp = expert_load.shape[0]
    cap = -(-n_exp // n_banks)
    plan = non_uniform_partition(expert_load.astype(np.float64), n_banks,
                                 capacity_rows=cap)
    return plan.bank_of_row
