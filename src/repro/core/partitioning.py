"""Embedding-table partitioning — the paper's §3 contribution.

Three partitioners, all producing a ``PartitionPlan`` (row -> (bank, slot) map)
that the runtime (core/embedding.py) applies on-device:

  * ``uniform_partition``      §3.1 — equal row blocks per bank; the companion
                               tile solver (N_r, N_c) lives in core/hwmodel.py.
  * ``non_uniform_partition``  §3.2 — greedy frequency-aware bin-packing: sort
                               rows by access frequency descending, assign each
                               to the bank with the lowest aggregate load that
                               still has capacity.  O(R log B) with a heap,
                               optional batching (paper: "one could batch items
                               ... to reduce algorithm complexity").
  * ``cache_aware_partition``  §3.3, Algorithm 1 — joint bin-packing of GRACE
                               cache lists (load-weighted minus the cached-sum
                               benefit) and residual rows, balancing the
                               COMBINED (EMT + cache) access load per bank.

Banks are UPMEM DPUs in the paper; here they are mesh-axis shards (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np


@dataclasses.dataclass
class PartitionPlan:
    """Row -> (bank, slot) assignment for one table (+ optional cache side)."""

    n_banks: int
    bank_of_row: np.ndarray          # (vocab,) int32
    slot_of_row: np.ndarray          # (vocab,) int32  — row index inside its bank
    rows_per_bank: np.ndarray        # (n_banks,) int32
    load_per_bank: np.ndarray        # (n_banks,) float64 — aggregate access freq
    # cache side (cache-aware only): cache entry -> (bank, slot)
    cache_bank_of_entry: np.ndarray | None = None
    cache_slot_of_entry: np.ndarray | None = None
    cache_rows_per_bank: np.ndarray | None = None

    @property
    def vocab(self) -> int:
        return int(self.bank_of_row.shape[0])

    @property
    def max_rows_per_bank(self) -> int:
        return int(self.rows_per_bank.max())

    def imbalance(self) -> float:
        """max/mean aggregate load across banks (1.0 == perfectly balanced)."""
        mean = self.load_per_bank.mean()
        return float(self.load_per_bank.max() / mean) if mean > 0 else 1.0

    def validate(self) -> None:
        assert self.bank_of_row.min() >= 0 and self.bank_of_row.max() < self.n_banks
        for b in range(self.n_banks):
            slots = self.slot_of_row[self.bank_of_row == b]
            assert slots.shape[0] == self.rows_per_bank[b]
            if slots.shape[0]:
                assert slots.min() == 0 and slots.max() == slots.shape[0] - 1
                assert np.unique(slots).shape[0] == slots.shape[0]


def _plan_from_banks(n_banks: int, bank_of_row: np.ndarray,
                     freq: np.ndarray) -> PartitionPlan:
    vocab = bank_of_row.shape[0]
    slot = np.zeros(vocab, dtype=np.int32)
    rows_per_bank = np.zeros(n_banks, dtype=np.int32)
    load = np.zeros(n_banks, dtype=np.float64)
    # stable slot assignment: row order within a bank follows global row id
    for b in range(n_banks):
        members = np.flatnonzero(bank_of_row == b)
        slot[members] = np.arange(members.shape[0], dtype=np.int32)
        rows_per_bank[b] = members.shape[0]
        load[b] = freq[members].sum()
    return PartitionPlan(
        n_banks=n_banks,
        bank_of_row=bank_of_row.astype(np.int32),
        slot_of_row=slot,
        rows_per_bank=rows_per_bank,
        load_per_bank=load,
    )


def uniform_partition(vocab: int, n_banks: int,
                      freq: np.ndarray | None = None) -> PartitionPlan:
    """§3.1: contiguous equal row blocks (block b gets rows [b*Nr, (b+1)*Nr))."""
    if freq is None:
        freq = np.ones(vocab, dtype=np.float64)
    n_r = -(-vocab // n_banks)  # ceil
    bank_of_row = np.minimum(np.arange(vocab) // n_r, n_banks - 1)
    return _plan_from_banks(n_banks, bank_of_row.astype(np.int32), freq)


def non_uniform_partition(
    freq: np.ndarray,
    n_banks: int,
    *,
    capacity_rows: int | None = None,
    batch: int = 1,
    row_weights: np.ndarray | None = None,
    bank_capacity_rows: np.ndarray | None = None,
    bank_cost: np.ndarray | None = None,
) -> PartitionPlan:
    """§3.2: greedy frequency bin-packing with a fixed number of bins.

    capacity_rows: per-bank row budget (the 64 MB MRAM constraint / its TPU
    analogue).  batch>1 assigns rows in groups of `batch` (paper's complexity
    note); batch=1 is the exact greedy.

    row_weights: optional per-row cost multiplier — the mixed-precision
    extension. A tiered table (repro.quant) moves a different byte count per
    row read, so the load the greedy balances becomes ``freq * row_weights``
    (bytes moved per bank, Eq. 1's bandwidth term) instead of row reads;
    ``plan.load_per_bank`` then reports byte-load. Capacity still counts
    ROWS (the packed arrays stay rectangular at ``rows_per_bank``).

    bank_capacity_rows: optional (n_banks,) per-bank row budgets overriding
    ``capacity_rows`` — the fault-tolerance hook: a DEAD bank gets capacity
    0 and is excluded from packing entirely, so the replan re-packs its rows
    onto the survivors. Raises with a capacity diagnosis when the surviving
    banks cannot hold the vocab.

    bank_cost: optional (n_banks,) load multiplier per bank — the straggler
    hook: a bank observed k-times slower ACCOUNTS each accepted row at k x
    its frequency, so the greedy sheds load off slow banks exactly like it
    sheds hot rows off loaded ones. ``plan.load_per_bank`` still reports the
    raw (uncosted) traffic.
    """
    vocab = freq.shape[0]
    if row_weights is not None:
        if row_weights.shape[0] != vocab:
            raise ValueError(f"row_weights {row_weights.shape} != vocab "
                             f"{vocab}")
        freq = np.asarray(freq, np.float64) * np.asarray(row_weights,
                                                         np.float64)
    if capacity_rows is None:
        capacity_rows = vocab  # uncapped
    if bank_capacity_rows is None:
        cap_of = np.full(n_banks, capacity_rows, dtype=np.int64)
    else:
        cap_of = np.asarray(bank_capacity_rows, np.int64)
        if cap_of.shape != (n_banks,):
            raise ValueError(f"bank_capacity_rows {cap_of.shape} != "
                             f"({n_banks},)")
        cap_of = np.minimum(cap_of, capacity_rows)
    if cap_of.sum() < vocab:
        n_live = int((cap_of > 0).sum())
        raise ValueError(
            f"capacity exhausted: {n_live}/{n_banks} banks with "
            f"{int(cap_of.sum())} total rows < vocab {vocab} — increase "
            f"banks or capacity (after a bank failure: raise the per-bank "
            f"slack so survivors can absorb the dead bank's rows)")
    cost_of = np.ones(n_banks, dtype=np.float64) if bank_cost is None \
        else np.asarray(bank_cost, np.float64)
    if cost_of.shape != (n_banks,):
        raise ValueError(f"bank_cost {cost_of.shape} != ({n_banks},)")
    order = np.argsort(-freq, kind="stable")
    bank_of_row = np.full(vocab, -1, dtype=np.int32)
    # heap of (costed load, rows_used, bank); zero-capacity (dead) banks
    # never enter it
    heap: list[tuple[float, int, int]] = [(0.0, 0, b) for b in range(n_banks)
                                          if cap_of[b] > 0]
    heapq.heapify(heap)
    parked: list[tuple[float, int, int]] = []
    i = 0
    while i < vocab:
        j = min(i + batch, vocab)
        group = order[i:j]
        gload = float(freq[group].sum())
        # pop until a bank with capacity for the whole group appears
        while heap and heap[0][1] + (j - i) > cap_of[heap[0][2]]:
            parked.append(heapq.heappop(heap))
        if not heap:
            raise ValueError("capacity exhausted — increase banks or capacity")
        load, used, b = heapq.heappop(heap)
        bank_of_row[group] = b
        heapq.heappush(heap, (load + gload * cost_of[b], used + (j - i), b))
        # full banks stay parked (they can never take more rows)
        keep = [p for p in parked if p[1] < cap_of[p[2]]]
        for p in keep:
            heapq.heappush(heap, p)
        parked = [p for p in parked if p[1] >= cap_of[p[2]]]
        i = j
    return _plan_from_banks(n_banks, bank_of_row, freq)


def cache_aware_partition(
    freq: np.ndarray,
    cache_lists: list[np.ndarray],
    benefits: np.ndarray,
    n_banks: int,
    *,
    emt_capacity_rows: int | None = None,
    cache_capacity_entries: int | None = None,
) -> PartitionPlan:
    """§3.3 Algorithm 1: cache-aware non-uniform partitioning.

    cache_lists[g] = row ids of co-occurring group g (GRACE output);
    benefits[g]   = estimated reduction in memory accesses from caching group
                    g's partial sums (Alg. 1 line 5: `benefit = list[-1]`).

    Each cached group's member rows are co-located on one bank together with
    the group's partial-sum cache entries; the bank's accounted load is the
    members' frequency sum MINUS the benefit (lines 9–10).  Residual rows
    follow the plain greedy (lines 11–15).  The returned plan also carries the
    cache-entry placement (entry g lives on the bank of its members).
    """
    vocab = freq.shape[0]
    n_groups = len(cache_lists)
    if emt_capacity_rows is None:
        emt_capacity_rows = vocab
    if cache_capacity_entries is None:
        cache_capacity_entries = max(1, n_groups)

    bank_of_row = np.full(vocab, -1, dtype=np.int32)
    cache_bank = np.full(n_groups, -1, dtype=np.int32)
    load = np.zeros(n_banks, dtype=np.float64)
    rows_used = np.zeros(n_banks, dtype=np.int64)
    cache_used = np.zeros(n_banks, dtype=np.int64)
    in_cache = np.zeros(vocab, dtype=bool)

    # --- lines 4-10: place cache groups first (sorted by member frequency) ---
    group_load = np.array([freq[g].sum() for g in cache_lists])
    for g in np.argsort(-group_load, kind="stable"):
        members = cache_lists[g]
        # bank with lowest current load and enough cache + EMT capacity
        cand = sorted(range(n_banks), key=lambda b: load[b])
        placed = False
        for b in cand:
            if (cache_used[b] + 1 <= cache_capacity_entries
                    and rows_used[b] + members.shape[0] <= emt_capacity_rows):
                new = members[bank_of_row[members] < 0]
                bank_of_row[new] = b
                in_cache[members] = True
                rows_used[b] += new.shape[0]
                cache_used[b] += 1
                cache_bank[g] = b
                load[b] += float(freq[members].sum()) - float(benefits[g])
                placed = True
                break
        if not placed:  # cache full everywhere -> group degrades to plain rows
            continue

    # --- lines 11-15: residual rows by plain greedy ---
    residual = np.flatnonzero(bank_of_row < 0)
    order = residual[np.argsort(-freq[residual], kind="stable")]
    heap = [(load[b], b) for b in range(n_banks)]
    heapq.heapify(heap)
    for r in order:
        parked = []
        while heap and rows_used[heap[0][1]] + 1 > emt_capacity_rows:
            parked.append(heapq.heappop(heap))
        if not heap:
            raise ValueError("EMT capacity exhausted")
        l, b = heapq.heappop(heap)
        bank_of_row[r] = b
        rows_used[b] += 1
        heapq.heappush(heap, (l + float(freq[r]), b))
        for p in parked:
            heapq.heappush(heap, p)

    plan = _plan_from_banks(n_banks, bank_of_row, freq)
    # recompute accounted load including cache benefit (for imbalance reporting)
    acc = np.zeros(n_banks, dtype=np.float64)
    for b in range(n_banks):
        acc[b] = freq[bank_of_row == b].sum()
    for g in range(n_groups):
        if cache_bank[g] >= 0:
            acc[cache_bank[g]] -= float(benefits[g])
    plan.load_per_bank = np.maximum(acc, 0.0)
    # cache entry slots: sequential per bank
    cache_slot = np.full(n_groups, -1, dtype=np.int32)
    cache_rows = np.zeros(n_banks, dtype=np.int32)
    for g in range(n_groups):
        b = cache_bank[g]
        if b >= 0:
            cache_slot[g] = cache_rows[b]
            cache_rows[b] += 1
    plan.cache_bank_of_entry = cache_bank
    plan.cache_slot_of_entry = cache_slot
    plan.cache_rows_per_bank = cache_rows
    return plan


def expert_placement(expert_load: np.ndarray, n_banks: int) -> np.ndarray:
    """Beyond-paper: reuse the §3.2 greedy for MoE expert->device placement.

    MoE expert-dispatch imbalance is the same bin-packing problem as bank-load
    imbalance (DESIGN.md §4).  Returns bank id per expert, balanced by routed
    token counts, equal expert count per bank (capacity = E / n_banks).
    """
    n_exp = expert_load.shape[0]
    cap = -(-n_exp // n_banks)
    plan = non_uniform_partition(expert_load.astype(np.float64), n_banks,
                                 capacity_rows=cap)
    return plan.bank_of_row
