"""UpDLRM core: the paper's contribution as composable JAX modules.

- partitioning: §3.1 uniform / §3.2 non-uniform / §3.3 cache-aware (Alg. 1)
- grace:        co-occurrence mining -> cache lists (GRACE-lite)
- embedding:    bank-partitioned lookup runtime (shard_map; stages 1-3)
- cache_runtime: request rewriting + partial-sum cache tables
- hwmodel:      UPMEM + TPUv5e profiles; Eq. 1-3 analytic stage model
"""
from repro.core.partitioning import (
    PartitionPlan,
    uniform_partition,
    non_uniform_partition,
    cache_aware_partition,
    expert_placement,
)
from repro.core.embedding import (
    BankedTable,
    DistCtx,
    pack_table,
    init_banked,
    banked_embedding_bag,
    banked_gather,
    banked_cache_residual_bag,
    csr_embedding_bag,
    col_split_embedding_bag,
    lookup_unsharded,
)
from repro.core.grace import CachePlan, mine_cooccurrence
from repro.core.cache_runtime import (
    build_cache_table,
    rewrite_bag,
    rewrite_bags,
    measure_hit_rate,
)
from repro.core.hwmodel import (
    UPMEM,
    TPUV5E,
    UPMEMProfile,
    TPUv5eProfile,
    embedding_stage_latency,
    solve_uniform_tile,
)

__all__ = [k for k in dir() if not k.startswith("_")]
