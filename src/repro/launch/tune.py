"""Autotune CLI: sweep the kernel candidate space and write the dispatch
cache (``TUNE_dispatch.json``) that ``backend='tuned'`` lookups resolve
through.

Usage::

    python -m repro.launch.tune                      # full sweep -> repo root
    python -m repro.launch.tune --smoke --out /tmp/t.json   # CI smoke mode

Smoke mode keeps the SAME signature suite as the full run (the cache's entry
keys are its schema; CI gates key-path parity against the committed file via
``benchmarks/check_regression.py --tune-baseline``) but shrinks candidates
and repeats to CI seconds.

After the sweep the CLI SELF-CHECKS the file it wrote: reloads it, installs
it as the process cache, and verifies every recorded signature resolves to
exactly the recorded decision — the persistence round-trip that the dispatch
layer depends on.
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser(
        description="kernel autotuner -> TUNE_dispatch.json")
    ap.add_argument("--out", default=None,
                    help="output path (default: TUNE_dispatch.json at the "
                         "repo root, the committed location)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: same signature suite, fewer candidates "
                         "and repeats")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timing repeats per candidate (default 3, smoke 2)")
    ap.add_argument("--arch", default=None,
                    help="label recorded in the cache meta (default: "
                         "'<jax backend>-compiled|interpret')")
    args = ap.parse_args()

    from repro.tune.autotune import tune
    from repro.tune.dispatch import (CACHE_BASENAME, DispatchCache, _repo_root,
                                     set_cache)

    out = args.out or os.path.join(_repo_root(), CACHE_BASENAME)
    cache = tune(smoke=args.smoke, repeats=args.repeats, arch=args.arch)
    cache.save(out)
    print(f"wrote {out}: {len(cache.entries)} entries "
          f"(meta {cache.meta})")

    # self-check: reload what we wrote and confirm the dispatch layer
    # resolves every tuned signature to the recorded decision
    reloaded = DispatchCache.load(out)
    set_cache(reloaded)
    try:
        want = cache.decisions()
        got = reloaded.decisions()
        bad = [k for k in want
               if (want[k].backend, want[k].tile_b, want[k].n_slots)
               != (got[k].backend, got[k].tile_b, got[k].n_slots)]
        if sorted(want) != sorted(got) or bad:
            print(f"self-check FAILED: round-trip decisions diverge "
                  f"({bad or 'key sets differ'})", file=sys.stderr)
            return 1
    finally:
        set_cache(None)
    print(f"self-check OK: {len(want)} decisions round-trip bit-exact")
    return 0


if __name__ == "__main__":
    sys.exit(main())
