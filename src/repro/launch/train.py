"""Training CLI: ``python -m repro.launch.train --arch dlrm-rm2 [...]``.

Runs REDUCED configs end-to-end on local devices (this container is CPU) or
full configs on a real slice — same code path: config -> params -> partition
-> jit(train_step) -> loop with checkpointing, straggler watchdog, restart.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import get_arch
from repro.data import synthetic as syn
from repro.dist.fault import StragglerWatchdog
from repro.obs.cli import add_obs_args, finalize_obs, setup_obs
from repro.train.train_step import TrainState, build_train_step, default_optimizer


def make_batch_fn(spec, cfg):
    fam = spec.family
    if fam == "lm":
        return lambda batch, seed, step: syn.lm_batch(
            batch, 64, cfg.vocab, seed=seed, step=step)
    if fam == "dlrm":
        return lambda batch, seed, step: syn.dlrm_batch(
            cfg.vocab_sizes, cfg.n_dense, batch, seed=seed, step=step,
            multi_hot=cfg.multi_hot)
    if fam == "din":
        return lambda batch, seed, step: syn.din_batch(
            cfg.n_items, cfg.n_cates, cfg.seq_len, batch, seed=seed,
            step=step)
    if fam == "bert4rec":
        return lambda batch, seed, step: syn.bert4rec_batch(
            cfg.n_items, cfg.seq_len, batch, seed=seed, step=step)
    if fam == "xdeepfm":
        return lambda batch, seed, step: syn.xdeepfm_batch(
            cfg.vocab_sizes, batch, seed=seed, step=step)
    raise ValueError(f"use examples/ for family {fam}")


def build_loss(spec, cfg, statics, backend: str | None = None,
               bwd_backend: str | None = None):
    """Family loss + the kwargs train_step should bind at the jit boundary
    (the dlrm embedding backend pair; other families take none)."""
    fam = spec.family
    if fam == "lm":
        from repro.models import transformer as T
        return (lambda p, b, **kw: T.lm_loss(cfg, p, b["tokens"],
                                             b["labels"])), {}
    mod = __import__(f"repro.models.{fam}", fromlist=["loss_fn"])
    kw = {}
    if fam == "dlrm":
        if backend is not None:
            kw["backend"] = backend
        if bwd_backend is not None:
            kw["bwd_backend"] = bwd_backend
    return (lambda p, b, **k: mod.loss_fn(cfg, p, statics, b, **k)), kw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--emb-lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a real accelerator slice)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "jnp", "pallas", "tuned"),
                    help="embedding stage-2 backend (dlrm). 'pallas' keeps "
                         "the WHOLE embedding step near memory: fused "
                         "lookup kernel forward, sorted-run scatter kernel "
                         "backward. 'auto' resolves to 'tuned': per-shape "
                         "decisions from the committed TUNE_dispatch.json "
                         "autotuner cache, old auto rule on a miss")
    ap.add_argument("--bwd-backend", default="auto",
                    choices=("auto", "jnp", "pallas"),
                    help="override the gradient scatter only ('auto' "
                         "follows --backend; 'jnp' = XLA scatter fallback "
                         "under a pallas forward, the parity baseline)")
    ap.add_argument("--adaptive", action="store_true",
                    help="telemetry + drift-triggered repartitioning of the "
                         "banked table during training (dlrm only); the "
                         "row-wise Adagrad state migrates with its rows")
    ap.add_argument("--banks", type=int, default=8,
                    help="bank count for the adaptive partition")
    ap.add_argument("--replan-every", type=int, default=25,
                    help="steps between drift checks (--adaptive)")
    ap.add_argument("--capacity-slack", type=float, default=0.25,
                    help="per-bank row headroom over vocab/banks")
    ap.add_argument("--partition", default="non_uniform",
                    choices=("non_uniform", "cache_aware"),
                    help="adaptive replanner (--adaptive): plain banked "
                         "(§3.2, remaps re-jitted on migration) or the "
                         "fused GRACE cache+residual TRAIN path (§3.3): "
                         "remaps + cache table ride the step as jit "
                         "ARGUMENTS, so migrations and cache refreshes "
                         "swap through the VersionedCacheRewriter with "
                         "ZERO re-jits")
    ap.add_argument("--cache-entries", type=int, default=128,
                    help="TOTAL cache-entry capacity across banks "
                         "(cache_aware; fixed for the life of the run)")
    ap.add_argument("--cache-refresh-every", type=int, default=25,
                    help="steps between partial-sum refreshes: trained EMT "
                         "rows drift away from their cached sums, so the "
                         "entries are re-summed from CURRENT values and "
                         "published as a new rewriter version")
    add_obs_args(ap)
    args = ap.parse_args()
    if args.backend == "auto":
        args.backend = "tuned"   # auto now means: consult the dispatch cache

    spec = get_arch(args.arch)
    cfg = spec.config if args.full else spec.reduced
    key = jax.random.key(args.seed)

    if args.adaptive and args.partition == "cache_aware":
        assert spec.family == "dlrm", "--adaptive drives the banked super-table"
        return _main_train_cached(args, spec, cfg, key)

    tracer, reg, writer = setup_obs(args, label=f"train:{args.arch}")
    m_step_ms = reg.histogram("train.step_ms", "jitted train-step wall time")
    m_migrations = reg.counter("train.migrations_total",
                               "drift-triggered table migrations")
    statics = None
    replanner = None
    cap = None
    if args.adaptive:
        assert spec.family == "dlrm", "--adaptive drives the banked super-table"
        from repro.core.partitioning import non_uniform_partition
        from repro.workload import (ReplanConfig, Replanner,
                                    rows_from_sparse)
        V = cfg.total_vocab
        cap = int(np.ceil(V / args.banks) * (1.0 + args.capacity_slack))
        plan = non_uniform_partition(np.ones(V), args.banks,
                                     capacity_rows=cap)
        replanner = Replanner(
            ReplanConfig.for_vocab(V, args.banks, capacity_rows=cap,
                                   check_every=args.replan_every),
            V, init_freq=np.ones(V), metrics=reg)
    if spec.family == "lm":
        from repro.models import transformer as T
        params = T.init_params(cfg, key)
    elif args.adaptive:
        mod = __import__(f"repro.models.{spec.family}",
                         fromlist=["init_params"])
        params, statics = mod.init_params(cfg, key, plan=plan,
                                          rows_per_bank=cap)
    else:
        mod = __import__(f"repro.models.{spec.family}",
                         fromlist=["init_params"])
        params, statics = mod.init_params(cfg, key)

    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={args.arch} family={spec.family} params={n_params:,}")

    opt = default_optimizer(lr=args.lr, emb_lr=args.emb_lr)
    loss_fn, loss_kw = build_loss(spec, cfg, statics, backend=args.backend,
                                  bwd_backend=args.bwd_backend)
    step_fn = jax.jit(build_train_step(loss_fn, opt,
                                       compress_grads=args.compress_grads,
                                       loss_kwargs=loss_kw))
    state = TrainState.create(params, opt, compress=args.compress_grads)

    start = 0
    ck = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ck and latest_step(args.ckpt_dir) is not None:
        state, start = restore_checkpoint(args.ckpt_dir, state)
        print(f"restored step {start}")
        # --adaptive: the checkpointed emb_packed follows whatever plan was
        # live at save time, NOT the deterministic initial plan — restore the
        # remap vectors saved FOR THIS STEP (per-step files: the restored
        # checkpoint may not be the newest save, e.g. a crash mid-write) or
        # every lookup would silently gather the wrong rows
        if replanner is not None:
            remaps = _load_remaps(args.ckpt_dir, start)
            if remaps is not None:
                statics["remap_bank"] = jnp.asarray(remaps["remap_bank"])
                statics["remap_slot"] = jnp.asarray(remaps["remap_slot"])

    batch_fn = make_batch_fn(spec, cfg)
    wd = StragglerWatchdog(metrics=reg)
    t_begin = time.time()
    n_migrations = 0
    field_offs = np.asarray(statics["field_offsets"]) if replanner else None
    traffic = None
    bank_of_row = None
    if replanner is not None:
        # train-side bank-traffic attribution: the step is re-jitted on
        # migration (remaps are closure constants here), so the recount runs
        # host-side on the SAME rows telemetry observes — the numpy twin of
        # the serve path's in-jit counters, landing in the same obs.bank_*
        # series
        from repro.obs.traffic import TrafficAccumulator, host_bank_read_counts
        row_nbytes = (state.params["emb_packed"].shape[-1]
                      * np.dtype(np.float32).itemsize)
        traffic = TrafficAccumulator(reg, args.banks, row_nbytes=row_nbytes)
        bank_of_row = np.asarray(statics["remap_bank"])  # restore-aware
    for step in range(start, args.steps):
        with tracer.span("rewrite", step=step):
            b = batch_fn(args.batch, args.seed, step)
            if replanner is not None:
                rows = rows_from_sparse(b["sparse"], field_offs)
                replanner.observe_rows(rows)
                traffic.update(
                    host_bank_read_counts(bank_of_row, rows, args.banks))
            b = {k: jnp.asarray(v) for k, v in b.items()}
        t0 = time.time()
        with tracer.span("device_step", step=step):
            state, metrics = step_fn(state, b)
            loss = float(metrics["loss"])
        m_step_ms.observe((time.time() - t0) * 1e3)
        wd.observe(step, time.time() - t0)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({(time.time() - t0) * 1e3:.0f} ms)")
        if replanner is not None:
            update = replanner.end_batch()
            if update is not None:
                # migrate table rows + their row-wise Adagrad history in one
                # pass, swap the remap vectors, rebuild the jitted step (the
                # remaps are closure constants on the train path)
                from repro.core.embedding import BankedTable
                from repro.workload import migrate_packed_leaves
                with tracer.span("migrate", step=step):
                    old_t = BankedTable(packed=state.params["emb_packed"],
                                        remap_bank=statics["remap_bank"],
                                        remap_slot=statics["remap_slot"],
                                        n_banks=args.banks,
                                        rows_per_bank=cap)
                    state = migrate_packed_leaves(state, old_t, update.plan,
                                                  rows_per_bank=cap)
                    statics["remap_bank"] = jnp.asarray(
                        update.plan.bank_of_row, jnp.int32)
                    statics["remap_slot"] = jnp.asarray(
                        update.plan.slot_of_row, jnp.int32)
                    loss_fn, loss_kw = build_loss(
                        spec, cfg, statics, backend=args.backend,
                        bwd_backend=args.bwd_backend)
                    step_fn = jax.jit(build_train_step(
                        loss_fn, opt, compress_grads=args.compress_grads,
                        loss_kwargs=loss_kw))
                n_migrations += 1
                m_migrations.inc()
                bank_of_row = update.plan.bank_of_row
                print(f"  [migrate @step {step}] {update.report} "
                      f"imbalance -> {update.plan.imbalance():.3f}")
        if writer is not None:
            writer.maybe_write(step + 1)
        if ck and (step + 1) % args.ckpt_every == 0:
            if replanner is not None:
                _save_remaps(args.ckpt_dir, statics, step + 1)
            ck.save(step + 1, state)
    if ck:
        if replanner is not None:
            _save_remaps(args.ckpt_dir, statics, args.steps)
        ck.save(args.steps, state)
        ck.join()
    extra = f"; migrations={n_migrations}" if replanner is not None else ""
    if traffic is not None and traffic.batches:
        reads = np.asarray(traffic.reads.values)
        extra += (f"; bank traffic: {int(reads.sum())} reads, "
                  f"max-bank share {reads.max() / max(reads.sum(), 1):.3f} "
                  f"over {traffic.batches} batches")
    print(f"done in {time.time() - t_begin:.1f}s; stragglers={wd.events}"
          + extra)
    finalize_obs(args, tracer, reg, writer, prefix="train")


def _main_train_cached(args, spec, cfg, key) -> None:
    """Cache-aware TRAINING under the adaptive runtime (the PR-4 open item,
    closed): the fused cache+residual loss takes the EMT remap vectors and
    the GRACE cache table as step ARGUMENTS, so a drift migration — and the
    periodic partial-sum refresh that training makes necessary — both swap
    through the ``VersionedCacheRewriter`` between steps, against ONE jitted
    executable. The old path rebuilt the cache table and re-jitted the step
    on every refresh cadence; now a refresh is ``runtime.refresh_cache()``:
    re-sum the surviving entries from the CURRENT trained row values,
    publish as version v+1, done. The row-wise Adagrad accumulator still
    migrates with its rows (``migrate_packed_leaves``) before the runtime
    adopts the migrated table (``apply_migrated``).
    """
    from repro.core.embedding import BankedTable
    from repro.core.partitioning import non_uniform_partition
    from repro.workload import (AdaptiveEmbeddingRuntime, ReplanConfig,
                                migrate_packed_leaves)

    mod = __import__(f"repro.models.{spec.family}", fromlist=["loss_fn"])
    mh = cfg.multi_hot
    assert mh >= 2, ("--partition cache_aware needs multi-hot bags "
                     "(try --arch updlrm-paper); GRACE partial sums fuse "
                     ">=2 lookups of one bag")
    banks = args.banks
    V = cfg.total_vocab
    cap = int(np.ceil(V / banks) * (1.0 + args.capacity_slack))
    crpb = max(1, -(-args.cache_entries // banks))
    plan = non_uniform_partition(np.ones(V), banks, capacity_rows=cap)
    params, statics = mod.init_params(cfg, key, plan=plan, rows_per_bank=cap)
    offs = np.asarray(statics["field_offsets"])

    table = BankedTable(packed=params["emb_packed"],
                        remap_bank=statics["remap_bank"],
                        remap_slot=statics["remap_slot"],
                        n_banks=banks, rows_per_bank=cap)
    rcfg = ReplanConfig.for_vocab(V, banks, capacity_rows=cap,
                                  check_every=args.replan_every,
                                  partitioner="cache_aware",
                                  cache_rows_per_bank=crpb,
                                  mine_min_support=2,
                                  telemetry_decay=0.8,
                                  telemetry_decay_every=4096)
    tracer, reg, writer = setup_obs(args, label=f"train-cached:{args.arch}")
    m_step_ms = reg.histogram("train.step_ms", "jitted train-step wall time")
    m_migrations = reg.counter("train.migrations_total",
                               "drift-triggered table migrations")
    m_refreshes = reg.counter("train.cache_refreshes_total",
                              "periodic partial-sum re-sums (staleness)")
    runtime = AdaptiveEmbeddingRuntime(
        table, plan, rcfg, init_freq=np.ones(V),
        max_cache_per_bag=max(2, mh // 4), max_residual_per_bag=mh,
        tracer=tracer, metrics=reg)

    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={args.arch} family={spec.family} params={n_params:,} "
          f"(cache-aware train, {banks * crpb} entry capacity)")

    kw = {}
    if args.backend is not None:
        kw["backend"] = args.backend
    if args.bwd_backend is not None:
        kw["bwd_backend"] = args.bwd_backend

    def loss_cached(p, b, **k):
        batch_c = {"dense": b["dense"], "cache_idx": b["cache_idx"],
                   "residual_idx": b["residual_idx"]}
        logits = mod.forward_cached(cfg, p, statics, b["cache_table"],
                                    batch_c, remap_bank=b["remap_bank"],
                                    remap_slot=b["remap_slot"], **k)
        return mod.bce_loss(logits, b["label"])

    opt = default_optimizer(lr=args.lr, emb_lr=args.emb_lr)
    step_fn = jax.jit(build_train_step(loss_cached, opt,
                                       compress_grads=args.compress_grads,
                                       loss_kwargs=kw))
    state = TrainState.create(params, opt, compress=args.compress_grads)

    batch_fn = make_batch_fn(spec, cfg)
    wd = StragglerWatchdog(metrics=reg)
    t_begin = time.time()
    n_migrations = n_refreshes = 0
    # bank-traffic attribution on the fused train path: the numpy twin of
    # the serve step's in-jit cache+residual counter, fed from the SAME
    # rewritten bags the step consumes — one obs.bank_* accounting path
    # across serve and train
    from repro.obs.traffic import (TrafficAccumulator,
                                   host_cached_bank_read_counts)
    traffic = TrafficAccumulator(
        reg, banks,
        row_nbytes=int(params["emb_packed"].shape[-1]) * 4)
    for step in range(args.steps):
        with tracer.span("rewrite", step=step):
            b = batch_fn(args.batch, args.seed, step)
            sp = np.asarray(b["sparse"])                   # (B, F, L)
            union = np.where(sp >= 0, sp + offs[None, :, None], -1)
            runtime.observe_bags(
                [bag[bag >= 0]
                 for bag in union.reshape(-1, union.shape[-1])])
            rb = runtime.rewrite(union)
            # everything a swap replaces is a step ARGUMENT; the batch
            # resolves against the cache-table version it was rewritten for
            batch = {"dense": jnp.asarray(b["dense"]),
                     "label": jnp.asarray(b["label"]),
                     "cache_idx": jnp.asarray(rb.cache_idx),
                     "residual_idx": jnp.asarray(rb.residual_idx),
                     "remap_bank": runtime.table.remap_bank,
                     "remap_slot": runtime.table.remap_slot,
                     "cache_table": runtime.cache_table_for(rb.version)}
            traffic.update(host_cached_bank_read_counts(
                np.asarray(batch["cache_table"].remap_bank), rb.cache_idx,
                np.asarray(runtime.table.remap_bank), rb.residual_idx,
                banks))
        t0 = time.time()
        with tracer.span("device_step", step=step):
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
        m_step_ms.observe((time.time() - t0) * 1e3)
        wd.observe(step, time.time() - t0)
        # the trained table: rebind the runtime's view to the new params so
        # replans/refreshes re-sum from CURRENT values
        runtime.table = BankedTable(packed=state.params["emb_packed"],
                                    remap_bank=runtime.table.remap_bank,
                                    remap_slot=runtime.table.remap_slot,
                                    n_banks=banks, rows_per_bank=cap)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({(time.time() - t0) * 1e3:.0f} ms)")
        update = runtime.replanner.end_batch()
        if update is not None:
            # migrate params + row-wise Adagrad state in one pass, then the
            # runtime adopts the migrated table and swaps the cache lane
            # versioned — no step re-jit (remaps are arguments)
            with tracer.span("migrate", step=step):
                state = migrate_packed_leaves(state, runtime.table,
                                              update.plan, rows_per_bank=cap)
                new_table = BankedTable(
                    packed=state.params["emb_packed"],
                    remap_bank=jnp.asarray(update.plan.bank_of_row,
                                           jnp.int32),
                    remap_slot=jnp.asarray(update.plan.slot_of_row,
                                           jnp.int32),
                    n_banks=banks, rows_per_bank=cap)
            event = runtime.apply_migrated(update, new_table)
            n_migrations += 1
            m_migrations.inc()
            print(f"  [migrate @step {step}] {update.report} "
                  f"imbalance -> {update.plan.imbalance():.3f}  "
                  f"cache v{event.cache_version} "
                  f"entries {event.cache_entries}")
        elif (step + 1) % args.cache_refresh_every == 0:
            with tracer.span("cache_refresh", step=step):
                version = runtime.refresh_cache()
            n_refreshes += 1
            m_refreshes.inc()
            print(f"  [cache refresh @step {step}] re-summed "
                  f"{runtime.cache_plan.n_entries} entries -> v{version}")
        if writer is not None:
            writer.maybe_write(step + 1)
    executables = step_fn._cache_size()
    reads = np.asarray(traffic.reads.values)
    print(f"done in {time.time() - t_begin:.1f}s; stragglers={wd.events}; "
          f"migrations={n_migrations} refreshes={n_refreshes}; "
          f"bank traffic: {int(reads.sum())} reads, max-bank share "
          f"{reads.max() / max(reads.sum(), 1):.3f}; "
          f"{executables} step executable(s) "
          f"({'ZERO re-jits' if executables == 1 else 'RE-JITTED'})")
    reg.gauge("jax.step_executables").set(executables)
    finalize_obs(args, tracer, reg, writer, prefix="train")


def _remaps_path(ckpt_dir: str, step: int) -> str:
    import os
    return os.path.join(ckpt_dir, f"adaptive_remaps_{step}.npz")


def _save_remaps(ckpt_dir: str, statics: dict, step: int) -> None:
    """Persist the LIVE plan's remap vectors for THIS checkpoint step — the
    packed table layout and its remaps must restore as a pair, and the
    restored step may be older than the newest remaps (checkpoints are
    written asynchronously and pruned; restore picks the newest COMPLETE
    one). Written synchronously BEFORE ck.save so a crash can only orphan a
    remaps file, never a checkpoint."""
    import os
    os.makedirs(ckpt_dir, exist_ok=True)
    np.savez(_remaps_path(ckpt_dir, step),
             remap_bank=np.asarray(statics["remap_bank"]),
             remap_slot=np.asarray(statics["remap_slot"]))


def _load_remaps(ckpt_dir: str, step: int):
    import os
    p = _remaps_path(ckpt_dir, step)
    if not os.path.exists(p):
        return None     # checkpoint predates --adaptive: initial plan holds
    with np.load(p) as z:
        return {"remap_bank": z["remap_bank"], "remap_slot": z["remap_slot"]}


if __name__ == "__main__":
    main()
