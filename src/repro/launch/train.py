"""Training CLI: ``python -m repro.launch.train --arch dlrm-rm2 [...]``.

Runs REDUCED configs end-to-end on local devices (this container is CPU) or
full configs on a real slice — same code path: config -> params -> partition
-> jit(train_step) -> loop with checkpointing, straggler watchdog, restart.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.configs import get_arch
from repro.data import synthetic as syn
from repro.dist.fault import StragglerWatchdog
from repro.train.train_step import TrainState, build_train_step, default_optimizer


def make_batch_fn(spec, cfg):
    fam = spec.family
    if fam == "lm":
        return lambda batch, seed, step: syn.lm_batch(
            batch, 64, cfg.vocab, seed=seed, step=step)
    if fam == "dlrm":
        return lambda batch, seed, step: syn.dlrm_batch(
            cfg.vocab_sizes, cfg.n_dense, batch, seed=seed, step=step,
            multi_hot=cfg.multi_hot)
    if fam == "din":
        return lambda batch, seed, step: syn.din_batch(
            cfg.n_items, cfg.n_cates, cfg.seq_len, batch, seed=seed,
            step=step)
    if fam == "bert4rec":
        return lambda batch, seed, step: syn.bert4rec_batch(
            cfg.n_items, cfg.seq_len, batch, seed=seed, step=step)
    if fam == "xdeepfm":
        return lambda batch, seed, step: syn.xdeepfm_batch(
            cfg.vocab_sizes, batch, seed=seed, step=step)
    raise ValueError(f"use examples/ for family {fam}")


def build_loss(spec, cfg, statics, backend: str | None = None):
    fam = spec.family
    if fam == "lm":
        from repro.models import transformer as T
        return lambda p, b: T.lm_loss(cfg, p, b["tokens"], b["labels"])
    mod = __import__(f"repro.models.{fam}", fromlist=["loss_fn"])
    kw = {"backend": backend} if backend is not None and fam == "dlrm" else {}
    return lambda p, b: mod.loss_fn(cfg, p, statics, b, **kw)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--emb-lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a real accelerator slice)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "jnp", "pallas"),
                    help="embedding stage-2 backend (dlrm; fwd AND bwd via "
                         "the kernel's scatter-add custom_vjp)")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.config if args.full else spec.reduced
    key = jax.random.key(args.seed)

    statics = None
    if spec.family == "lm":
        from repro.models import transformer as T
        params = T.init_params(cfg, key)
    else:
        mod = __import__(f"repro.models.{spec.family}",
                         fromlist=["init_params"])
        params, statics = mod.init_params(cfg, key)

    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={args.arch} family={spec.family} params={n_params:,}")

    opt = default_optimizer(lr=args.lr, emb_lr=args.emb_lr)
    loss_fn = build_loss(spec, cfg, statics, backend=args.backend)
    step_fn = jax.jit(build_train_step(loss_fn, opt,
                                       compress_grads=args.compress_grads))
    state = TrainState.create(params, opt, compress=args.compress_grads)

    start = 0
    ck = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ck and latest_step(args.ckpt_dir) is not None:
        state, start = restore_checkpoint(args.ckpt_dir, state)
        print(f"restored step {start}")

    batch_fn = make_batch_fn(spec, cfg)
    wd = StragglerWatchdog()
    t_begin = time.time()
    for step in range(start, args.steps):
        b = batch_fn(args.batch, args.seed, step)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        t0 = time.time()
        state, metrics = step_fn(state, b)
        loss = float(metrics["loss"])
        wd.observe(step, time.time() - t0)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({(time.time() - t0) * 1e3:.0f} ms)")
        if ck and (step + 1) % args.ckpt_every == 0:
            ck.save(step + 1, state)
    if ck:
        ck.save(args.steps, state)
        ck.join()
    print(f"done in {time.time() - t_begin:.1f}s; stragglers={wd.events}")


if __name__ == "__main__":
    main()
