"""Roofline-term extraction from compiled dry-run artifacts.

    compute   = HLO_FLOPs_per_device / peak_FLOPs
    memory    = HLO_bytes_per_device / HBM_bw
    collective= collective_operand_bytes_per_device / ICI_bw

cost_analysis() supplies FLOPs/bytes (per device — the SPMD-partitioned entry
computation). Collective bytes are NOT in cost_analysis: we parse the
post-optimization HLO, build a name->shape table from every defining line, and
sum OPERAND sizes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute (async *-start counted once, *-done skipped).
"""
from __future__ import annotations

import math
import re
from typing import Iterable

from repro.core.hwmodel import TPUV5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|[^ ]+)\s+([\w\-]+)\((.*)",
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)


def type_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    return analyze_hlo(hlo_text)["collectives"]


def analyze_hlo(hlo_text: str) -> dict:
    """One-pass HLO analysis: per-collective operand bytes + the
    gather/scatter memory-accounting correction.

    XLA's cost_analysis charges gather/scatter ops for their FULL table
    operand (verified: a 64-row gather from a 1M x 8 table reports 32 MB
    "bytes accessed"). Real hardware reads only the touched rows, so for
    embedding-heavy models the memory term would be phantom-inflated by the
    whole table per lookup op. Correction per op (touched-rows model):
      gather : charged ~ operand+idx+out      -> realistic ~ 2*out+idx
               correction -= (operand - out)          [when operand > out]
      scatter: charged ~ 2*operand+updates+idx -> realistic ~ 3*updates+idx
               correction -= 2*(operand - updates)    [when operand > upd]
    """
    shapes: dict[str, str] = {}
    collectives: list[tuple[str, str]] = []  # (opcode, args_str)
    gs: list[tuple[str, str, str]] = []      # (opcode, result_type, args)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        shapes[name] = type_str
        base = opcode.removesuffix("-start")
        if opcode.endswith("-done"):
            continue
        if base in COLLECTIVE_OPS:
            # operands are up to the closing paren of the call
            args = rest.split("), ")[0]
            collectives.append((base, args))
        elif base in ("gather", "scatter"):
            gs.append((base, type_str, rest.split("), ")[0]))

    out: dict[str, float] = {}
    for base, args in collectives:
        b = 0
        for op_name in _OPERAND_RE.findall(args):
            t = shapes.get(op_name)
            if t:
                b += type_bytes(t)
        out[base] = out.get(base, 0.0) + float(b)

    correction = 0.0
    for base, res_type, args in gs:
        ops = [type_bytes(shapes.get(n, "")) for n in
               _OPERAND_RE.findall(args)]
        if not ops:
            continue
        operand = max(ops)  # the table
        if base == "gather":
            res = type_bytes(res_type)
            if operand > res:
                correction += operand - res
        else:  # scatter(operand, idx, updates)
            updates = sorted(ops)[-2] if len(ops) >= 2 else 0
            if operand > updates:
                correction += 2.0 * (operand - updates)
    return {"collectives": out, "gather_scatter_correction": correction}


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float, hw=TPUV5E) -> dict[str, float]:
    compute = flops / hw.peak_flops
    memory = bytes_accessed / hw.hbm_bw
    collective = collective_bytes / hw.ici_bw
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    total = max(compute, memory, collective)
    terms["bound_s"] = total
    return terms


# ---------------------------------------------------------------------------
# MODEL_FLOPS (useful work) per cell — catches remat/redundancy waste
# ---------------------------------------------------------------------------

def model_flops(arch_id: str, shape_id: str) -> float:
    """Global 'textbook' FLOPs for one step of the cell."""
    from repro.configs import get_arch
    from repro.configs import shapes as SH
    spec = get_arch(arch_id)
    cell = SH.get_cell(arch_id, shape_id)
    d = cell.dims
    fam = spec.family
    cfg = spec.config

    if fam == "lm":
        B, S = d["batch"], d["seq"]
        N = cfg.active_param_count()
        if cell.step_kind == "train":
            # 6·N·D + attention quadratic term (12·L·d_attn·S² per seq ×3)
            attn = 3 * cfg.n_layers * 4 * B * S * S * cfg.qkv_dim
            return 6.0 * N * (B * S) + attn
        if cell.step_kind == "prefill":
            attn = cfg.n_layers * 4 * B * S * S * cfg.qkv_dim * 0.5
            return 2.0 * N * (B * S) + attn
        # decode: one token per sequence + KV attention
        attn = cfg.n_layers * 4 * B * S * cfg.qkv_dim
        return 2.0 * N * B + attn

    if fam in ("dlrm", "din", "bert4rec", "xdeepfm"):
        B = d.get("n_candidates", d["batch"]) if cell.step_kind == "retrieval" \
            else d["batch"]
        dense = _recsys_dense_params(spec)
        mult = 6.0 if cell.step_kind == "train" else 2.0
        return mult * dense * B

    if fam == "gat":
        return _gat_flops(spec, cell)
    raise ValueError(fam)


def _recsys_dense_params(spec) -> float:
    cfg = spec.config
    total = cfg.param_count()
    if spec.family in ("dlrm", "xdeepfm", "din"):
        emb = cfg.total_vocab * cfg.embed_dim
        if spec.family == "xdeepfm":
            emb = cfg.total_vocab * (cfg.embed_dim + 1)
        return max(total - emb, 1)
    # bert4rec: per-sequence transformer cost + the MLM head. The head's
    # useful work depends on the loss: full-catalog softmax scores S x V,
    # sampled softmax scores max_masked x (1 + n_negatives).
    emb = cfg.vocab * cfg.embed_dim
    per_tok = max(cfg.param_count() - emb - cfg.seq_len * cfg.embed_dim, 1)
    body = per_tok * cfg.seq_len
    if getattr(cfg, "loss", "full") == "sampled":
        head = cfg.max_masked * (1 + cfg.n_negatives) * cfg.embed_dim
    else:
        head = cfg.seq_len * cfg.vocab * cfg.embed_dim
    return body + head


def _gat_flops(spec, cell) -> float:
    d = cell.dims
    cfg = spec.config
    H, O = cfg.n_heads, cfg.d_hidden
    if cell.shape_id == "minibatch_lg":
        from repro.configs.shapes import sampled_block_dims
        bd = sampled_block_dims(d["batch_nodes"], d["fanout0"], d["fanout1"])
        n, e = bd["n0"], bd["e0"] + bd["e1"]
        feat = d["d_feat"]
    elif cell.shape_id == "molecule":
        n = d["n_graphs"] * d["nodes_per"]
        e = d["n_graphs"] * d["edges_per"]
        feat = d["d_feat"]
    else:
        n, e, feat = d["n_nodes"], d["n_edges"], d["d_feat"]
    l1 = 2 * n * feat * H * O + 8 * e * H * O
    l2 = 2 * n * H * O * d["n_classes"] + 8 * e * d["n_classes"]
    return 3.0 * (l1 + l2)   # fwd+bwd
