import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ must precede any jax import (see dryrun.py).
"""Layer-extrapolated cost accounting for cells whose FULLY-UNROLLED compile
is intractable on this host (48-layer MoE): lower the SAME cell at n_layers=1
and n_layers=2 (unrolled — both compile in seconds) and extrapolate

    cost(L) = c1 + (L-1) * (c2 - c1)

which is exact for per-layer-identical stacks (all transformer layers here
are identical in shape and sharding). Memory fields are NOT extrapolated —
they come from the rolled full-L compile (the scan's working set is the true
peak) already recorded by dryrun.py; this script only replaces the
flops/bytes/collective fields in that JSON and marks the method.
"""
import argparse
import dataclasses
import json

import jax

from repro.configs import get_arch
from repro.launch import roofline as RL
from repro.launch.cells import _lm_cell, make_dist
from repro.launch.mesh import make_production_mesh


def measure(arch_id: str, shape_id: str, n_layers: int, multi_pod: bool):
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = get_arch(arch_id)
    cell_dims = __import__("repro.configs.shapes",
                           fromlist=["get_cell"]).get_cell(arch_id, shape_id)
    S = cell_dims.dims["seq"]
    kind = cell_dims.step_kind
    cfg = dataclasses.replace(
        spec.config, n_layers=n_layers, unroll=True,
        q_chunk=S if kind != "decode" else spec.config.q_chunk,
        kv_chunk=min(2048, S) if kind != "decode" else spec.config.kv_chunk)
    cell = _lm_cell(arch_id, shape_id, make_dist(mesh), cfg_override=cfg)
    compiled = jax.jit(cell.fn).lower(*cell.args).compile()
    cost = compiled.cost_analysis()
    ana = RL.analyze_hlo(compiled.as_text())
    bytes_acc = max(0.0, float(cost.get("bytes accessed", 0.0))
                    - ana["gather_scatter_correction"])
    return (float(cost.get("flops", 0.0)), bytes_acc, ana["collectives"])


def extrapolate(arch_id: str, shape_id: str, multi_pod: bool,
                out_dir: str) -> dict:
    spec = get_arch(arch_id)
    L = spec.config.n_layers
    f1, b1, c1 = measure(arch_id, shape_id, 1, multi_pod)
    f2, b2, c2 = measure(arch_id, shape_id, 2, multi_pod)
    flops = f1 + (L - 1) * (f2 - f1)
    bytes_acc = b1 + (L - 1) * (b2 - b1)
    # clamp: one-time (layer-independent) collectives can make the per-layer
    # slope slightly negative for an op class — physical floor is c1
    coll = {k: max(c1.get(k, 0.0),
                   c1.get(k, 0.0)
                   + (L - 1) * (c2.get(k, 0.0) - c1.get(k, 0.0)))
            for k in set(c1) | set(c2)}
    coll_total = sum(coll.values())

    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    path = os.path.join(out_dir, f"{mesh_name}__{arch_id}__{shape_id}.json")
    with open(path) as f:
        rec = json.load(f)
    rec["flops_per_device"] = flops
    rec["bytes_per_device"] = bytes_acc
    rec["collective_bytes_per_device"] = coll_total
    rec["collectives"] = coll
    rec["roofline"] = RL.roofline_terms(flops, bytes_acc, coll_total)
    mf = rec["model_flops_global"]
    n_dev = rec["n_devices"]
    rec["useful_flops_ratio"] = (mf / (flops * n_dev)) if flops else None
    rec["accounting"] = "layer-extrapolated (L1/L2 unrolled)"
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    rec = extrapolate(args.arch, args.shape, args.multi, args.out)
    r = rec["roofline"]
    print(f"EXTRAP {args.arch}:{args.shape} dom={r['dominant']} "
          f"bound={r['bound_s'] * 1e3:.2f}ms "
          f"useful={rec['useful_flops_ratio']:.3f}")


if __name__ == "__main__":
    main()
