"""Serving CLI: ``python -m repro.launch.serve --arch dlrm-rm2``.

Simulates the paper's online-inference setup with the MicroBatcher: a stream
of requests, cache-aware rewriting in the pre-process stage, jitted scoring,
p50/p99 latency report.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.serve.serve_step import MicroBatcher, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dlrm-rm2")
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "jnp", "pallas"),
                    help="embedding stage-2 backend (dlrm only)")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    assert spec.family in ("dlrm", "din", "xdeepfm"), "recsys serving CLI"
    cfg = spec.reduced
    mod = __import__(f"repro.models.{spec.family}", fromlist=["forward"])
    params, statics = mod.init_params(cfg, jax.random.key(args.seed))
    from repro.serve.serve_step import build_recsys_serve
    backend = args.backend if spec.family == "dlrm" else None
    serve = jax.jit(build_recsys_serve(mod, cfg, statics, backend=backend))

    rng = np.random.default_rng(args.seed)
    from repro.data import synthetic as syn
    if spec.family == "dlrm":
        proto = syn.dlrm_batch(cfg.vocab_sizes, cfg.n_dense, 1, seed=0,
                               step=0, multi_hot=cfg.multi_hot)
    elif spec.family == "din":
        proto = syn.din_batch(cfg.n_items, cfg.n_cates, cfg.seq_len, 1,
                              seed=0, step=0)
    else:
        proto = syn.xdeepfm_batch(cfg.vocab_sizes, 1, seed=0, step=0)
    proto.pop("label", None)
    pad = {k: v[0] for k, v in proto.items()}

    mb = MicroBatcher(args.batch, pad)
    for rid in range(args.requests):
        feats = {k: v[0] for k, v in _one(spec, cfg, rng, rid).items()}
        mb.submit(Request(rid=rid, features=feats))
        if len(mb.queue) >= args.batch:
            reqs, feats_b = mb.next_batch()
            scores = serve(params, feats_b)
            jax.block_until_ready(scores)
            mb.complete(reqs)
    while mb.ready():
        reqs, feats_b = mb.next_batch()
        jax.block_until_ready(serve(params, feats_b))
        mb.complete(reqs)

    lat = sorted(mb.latencies)
    p50 = lat[len(lat) // 2] * 1e3
    print(f"served {len(lat)} requests  p50={p50:.2f}ms "
          f"p99={mb.p99() * 1e3:.2f}ms")


def _one(spec, cfg, rng, rid):
    from repro.data import synthetic as syn
    if spec.family == "dlrm":
        b = syn.dlrm_batch(cfg.vocab_sizes, cfg.n_dense, 1, seed=1, step=rid,
                           multi_hot=cfg.multi_hot)
    elif spec.family == "din":
        b = syn.din_batch(cfg.n_items, cfg.n_cates, cfg.seq_len, 1, seed=1,
                          step=rid)
    else:
        b = syn.xdeepfm_batch(cfg.vocab_sizes, 1, seed=1, step=rid)
    b.pop("label", None)
    return b


if __name__ == "__main__":
    main()
