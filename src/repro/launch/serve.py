"""Serving CLI: ``python -m repro.launch.serve --arch dlrm-rm2``.

Simulates the paper's online-inference setup with the MicroBatcher: a stream
of requests, cache-aware rewriting in the pre-process stage, jitted scoring,
p50/p99 latency report.

``--adaptive`` (dlrm only) turns on the repro.workload closed loop: requests
come from a DRIFTING Zipf stream, the MicroBatcher's observer tap feeds the
telemetry, and on detected drift the table is repartitioned and live-migrated
between micro-batches. The remap vectors are jit ARGUMENTS (not closure
constants) and the packed shape is pinned to a fixed per-bank capacity, so a
swap never recompiles the serve step.

``--adaptive --partition cache_aware`` serves the FUSED cache+residual path
(paper Fig. 7) under the same loop: every micro-batch is host-rewritten
against the current GRACE plan and version-tagged; a drifted replan re-mines
the co-occurrence groups, migrates the EMT, re-sums the cache table from the
migrated rows at a FIXED entry capacity, and swaps (rewrite plan, cache
table, remap vectors) atomically between micro-batches — batches in flight
across the swap resolve against the cache-table version they were rewritten
for. A compile-count probe (jax.monitoring + the jit cache size) asserts the
whole run used ONE serve executable, and the first swap is verified
bit-identical to tearing down and rebuilding the cache path from scratch
(``--min-swaps`` makes both checks a hard exit code for CI).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.obs.cli import add_obs_args as _add_obs_args
from repro.obs.cli import finalize_obs as _finalize_obs
from repro.obs.cli import setup_obs as _setup_obs
from repro.serve.serve_step import MicroBatcher, Request


def _projected_share(runtime) -> float:
    """Plan-time projected max-bank share of the INSTALLED plan on the
    recent telemetry window — the promise the SLO watchdog's divergence
    check holds the measured traffic against. Cache-aware lanes project
    through the bag-replay model (reads the cache absorbs count for the
    plan), everything else uses the row-share projection."""
    rp = runtime.replanner
    fcp = rp.current_cache_fixed
    if fcp is not None and rp._recent_bags:
        return rp.projected_max_share_cached(runtime.plan, fcp,
                                             list(rp._recent_bags))
    return rp.projected_max_share(runtime.plan, rp.telemetry.freq_vector())


class _TrafficSLO:
    """One serve loop's measured-traffic lane: the TrafficAccumulator
    (``obs.bank_reads`` / ``obs.bank_bytes`` / ``obs.bank_share``), the SLO
    watchdog, and the Chrome-trace counter tracks. Built unconditionally by
    every adaptive main so the metrics snapshot carries the whole ``obs.*``
    family whether or not any SLO check is armed (the CI metrics-schema
    gate keys on the names, not the values)."""

    def __init__(self, args, metrics, tracer, *, banks, dim, row_nbytes,
                 runtime=None):
        from repro.obs.slo import SLOConfig, SLOWatchdog, hot_bank_penalty
        from repro.obs.traffic import TrafficAccumulator
        self.tracer = tracer
        self.banks = banks
        self.acc = TrafficAccumulator(metrics, banks, row_nbytes=row_nbytes)
        self.penalties = 0

        def on_breach(kind, info):
            if runtime is None:
                return
            pen = hot_bank_penalty(info["window_reads"], banks)
            runtime.on_slo_breach(pen)
            self.penalties += 1
            print(f"  [slo breach @batch {info['batch']}] {kind}: "
                  f"{info['value']:.1f} > {info['threshold']:.1f} "
                  f"(hot bank {info['bank']}, penalty "
                  f"x{pen.max():.2f} -> replanner)")

        cfg = SLOConfig(p99_us=args.slo_p99_us, max_share=args.slo_max_share,
                        divergence=args.slo_divergence, window=args.slo_window)
        self.watchdog = SLOWatchdog(cfg, n_banks=banks, dim=dim,
                                    metrics=metrics, tracer=tracer,
                                    on_breach=on_breach)
        if runtime is not None:
            self.watchdog.set_projection(_projected_share(runtime))

    @property
    def breaches(self) -> int:
        return self.watchdog.breaches

    def on_swap(self, runtime) -> None:
        """Refresh the plan-time projection after a live swap."""
        self.watchdog.set_projection(_projected_share(runtime))

    def after_step(self, batch, reads, wall_us, batch_size, *, nbytes=None,
                   p99_ms=None):
        """Fold one batch's measured counts; feed the watchdog."""
        reads = np.asarray(reads)
        share = self.acc.update(reads, nbytes if nbytes is None
                                else np.asarray(nbytes))
        self.tracer.counter(
            "bank_reads", **{f"bank{i}": int(v) for i, v in enumerate(reads)})
        self.tracer.counter("serve_slo", max_bank_share=share,
                            **({} if p99_ms is None else {"p99_ms": p99_ms}))
        self.watchdog.observe(batch, wall_us=wall_us, reads=reads,
                              batch_size=batch_size)
        return share

    def check_contract(self, min_breaches: int) -> None:
        """The CI SLO contract: at least ``min_breaches`` detected AND the
        replanner actually received a penalty for each breach lane."""
        if min_breaches <= 0:
            return
        if self.breaches < min_breaches or self.penalties < 1:
            raise SystemExit(
                f"slo contract violated: breaches={self.breaches} "
                f"(need >= {min_breaches}), replanner penalties="
                f"{self.penalties} (need >= 1)")


class CompileProbe:
    """Counts XLA compilations via jax.monitoring — the zero-recompile
    assertion for live swaps (each jit compilation emits one
    '/jax/…compile…' event; cache hits emit none)."""

    def __init__(self, metrics=None):
        self.compiles = 0
        if metrics is None:
            from repro.obs import MetricRegistry
            metrics = MetricRegistry()
        self._m_compiles = metrics.counter("jax.compiles_total",
                                           "XLA compilations (monitoring)")
        jax.monitoring.register_event_listener(self._on_event)

    def _on_event(self, name: str, **kw) -> None:
        if "compile" in name:
            self.compiles += 1
            self._m_compiles.inc()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dlrm-rm2")
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "jnp", "pallas", "tuned"),
                    help="embedding stage-2 backend (dlrm only). 'auto' "
                         "resolves to 'tuned': per-shape decisions from the "
                         "committed TUNE_dispatch.json autotuner cache, "
                         "falling back to the old auto rule on a cache miss")
    ap.add_argument("--adaptive", action="store_true",
                    help="online telemetry + drift-triggered repartitioning "
                         "with live table migration (dlrm only)")
    ap.add_argument("--partition", default="non_uniform",
                    choices=("non_uniform", "cache_aware"),
                    help="adaptive replanner: plain banked (§3.2) or the "
                         "fused GRACE cache+residual serve path (§3.3)")
    ap.add_argument("--banks", type=int, default=8,
                    help="bank count for the adaptive partition")
    ap.add_argument("--replan-every", type=int, default=8,
                    help="micro-batches between drift checks")
    ap.add_argument("--capacity-slack", type=float, default=0.25,
                    help="per-bank row headroom over vocab/banks")
    ap.add_argument("--cache-entries", type=int, default=128,
                    help="TOTAL cache-entry capacity across banks "
                         "(cache_aware; fixed for the life of the server)")
    ap.add_argument("--drift-rotate-every", type=int, default=512,
                    help="requests between hot-set rotations of the "
                         "synthetic drifting stream")
    ap.add_argument("--min-swaps", type=int, default=0,
                    help="exit nonzero unless at least this many live swaps "
                         "occurred AND the swap invariants (bit-parity with "
                         "a from-scratch rebuild, zero recompiles) held — "
                         "the CI serve-smoke contract")
    ap.add_argument("--replicate-k-max", type=int, default=1,
                    help="hot-row replication on the adaptive serve path "
                         "(dlrm --adaptive, non_uniform): give the "
                         "telemetry-chosen hottest rows up to this many "
                         "copies on distinct banks; an in-kernel per-bag "
                         "hash splits their traffic. 1 = off. Replans "
                         "re-pick the replicated set through the same "
                         "zero-recompile swap")
    ap.add_argument("--replicate-max-r", type=int, default=64,
                    help="cap on the number of replicated rows per plan "
                         "(bounds the extra-copy capacity cost; further "
                         "clamped so the copies always fit the fixed "
                         "per-bank capacity)")
    ap.add_argument("--quant", default="off", choices=("off", "int8", "int4"),
                    help="tiered-precision embedding storage (repro.quant) "
                         "on the adaptive serve path: hot head stays bf16, "
                         "the tail quantizes to int8 (or int8+packed-int4); "
                         "replans re-tier rows through the same zero-"
                         "recompile swap (dlrm --adaptive, non_uniform)")
    ap.add_argument("--quant-byte-budget", type=float, default=None,
                    help="target average STORED bytes per row (README.md "
                         "§byte budget); default: int8 tail (--quant int8) "
                         "or a mostly-int4 mix (--quant int4)")
    ap.add_argument("--quant-hot-rows", type=int, default=8,
                    help="hottest rows pinned to the full-precision tier")
    ap.add_argument("--hysteresis", type=float, default=0.0,
                    help="skip drifted replans whose candidate plan does "
                         "not beat the incumbent's projected max-bank share "
                         "by this relative margin (0 = replan on every "
                         "drifted check)")
    ap.add_argument("--inject-bank-failure", action="append", default=[],
                    metavar="BATCH:BANK[:STATE[:FACTOR]]",
                    help="fault-tolerant serving lane (dlrm --adaptive, "
                         "non_uniform): kill bank BANK at micro-batch BATCH "
                         "(state 'dead', the default), slow it (state "
                         "'degraded', FACTOR x), or revive it ('healthy'). "
                         "Repeatable. Serving continues through the failure "
                         "with bounded-degraded reads; recovery re-packs the "
                         "dead bank's rows onto survivors via the replan "
                         "lane")
    ap.add_argument("--straggler-factor", type=float, default=3.0,
                    help="StragglerWatchdog threshold: a micro-batch whose "
                         "modeled bank time exceeds this multiple of the "
                         "running median flags its slowest bank, feeding a "
                         "latency penalty into the planner's load model")
    ap.add_argument("--slo-p99-us", type=float, default=0.0,
                    help="SLO watchdog (dlrm --adaptive): breach when the "
                         "rolling-window p99 of measured device-step wall "
                         "time exceeds this budget (microseconds; 0 = check "
                         "off). Breaches mark the Chrome trace, bump "
                         "obs.slo_breaches_total, and push a hot-bank "
                         "penalty into the replanner")
    ap.add_argument("--slo-max-share", type=float, default=0.0,
                    help="SLO watchdog: breach when the window-mean MEASURED "
                         "max-bank read share exceeds this fraction "
                         "(0 = check off; 1/banks is perfect balance)")
    ap.add_argument("--slo-divergence", type=float, default=0.0,
                    help="SLO watchdog: breach when the realized modeled "
                         "latency (hwmodel priced at MEASURED bank shares) "
                         "exceeds the plan-time projection by this relative "
                         "margin (0 = check off)")
    ap.add_argument("--slo-window", type=int, default=16,
                    help="micro-batches per SLO evaluation window (also the "
                         "per-check cooldown after a breach fires)")
    ap.add_argument("--min-slo-breaches", type=int, default=0,
                    help="exit nonzero unless at least this many SLO "
                         "breaches were detected AND the replanner received "
                         "the hot-bank penalty — the CI measure->plan "
                         "feedback contract")
    ap.add_argument("--min-recoveries", type=int, default=0,
                    help="exit nonzero unless at least this many "
                         "bank-failure recoveries completed AND the fault "
                         "contracts held (degradation confined to dead-bank "
                         "rows, post-recovery bit-parity with a never-failed "
                         "run, one serve executable) — the CI "
                         "failure-injection contract")
    _add_obs_args(ap)
    args = ap.parse_args()
    if args.backend == "auto":
        args.backend = "tuned"   # auto now means: consult the dispatch cache

    spec = get_arch(args.arch)
    assert spec.family in ("dlrm", "din", "xdeepfm"), "recsys serving CLI"
    cfg = spec.reduced
    mod = __import__(f"repro.models.{spec.family}", fromlist=["forward"])
    if args.adaptive:
        assert spec.family == "dlrm", "--adaptive drives the banked super-table"
        return _main_adaptive(args, spec, cfg, mod)
    params, statics = mod.init_params(cfg, jax.random.key(args.seed))
    from repro.serve.serve_step import build_recsys_serve
    backend = args.backend if spec.family == "dlrm" else None
    serve = jax.jit(build_recsys_serve(mod, cfg, statics, backend=backend))

    rng = np.random.default_rng(args.seed)
    from repro.data import synthetic as syn
    if spec.family == "dlrm":
        proto = syn.dlrm_batch(cfg.vocab_sizes, cfg.n_dense, 1, seed=0,
                               step=0, multi_hot=cfg.multi_hot)
    elif spec.family == "din":
        proto = syn.din_batch(cfg.n_items, cfg.n_cates, cfg.seq_len, 1,
                              seed=0, step=0)
    else:
        proto = syn.xdeepfm_batch(cfg.vocab_sizes, 1, seed=0, step=0)
    proto.pop("label", None)
    pad = {k: v[0] for k, v in proto.items()}

    tracer, metrics, writer = _setup_obs(args, label=f"serve:{args.arch}")
    mb = MicroBatcher(args.batch, pad, metrics=metrics)
    n_batches = 0

    def run_batch():
        nonlocal n_batches
        with tracer.span("rewrite"):
            reqs, feats_b = mb.next_batch()
        with tracer.span("device_step", batch=n_batches):
            scores = serve(params, feats_b)
            jax.block_until_ready(scores)
        mb.complete(reqs)
        n_batches += 1
        if writer is not None:
            writer.maybe_write(n_batches)

    for rid in range(args.requests):
        feats = {k: v[0] for k, v in _one(spec, cfg, rng, rid).items()}
        mb.submit(Request(rid=rid, features=feats))
        if len(mb.queue) >= args.batch:
            run_batch()
    while mb.ready():
        run_batch()

    lat = sorted(mb.latencies)
    p50 = lat[len(lat) // 2] * 1e3
    print(f"served {len(lat)} requests  p50={p50:.2f}ms "
          f"p99={mb.p99() * 1e3:.2f}ms")
    _finalize_obs(args, tracer, metrics, writer, latencies=mb.latencies)


def _main_adaptive(args, spec, cfg, mod) -> None:
    """Drifting traffic -> telemetry -> replan -> migrate -> swap, live."""
    from repro.core.embedding import BankedTable
    from repro.core.partitioning import non_uniform_partition
    from repro.workload import (AdaptiveEmbeddingRuntime, DriftConfig,
                                DriftingZipfTrace, ReplanConfig,
                                dlrm_drifting_batch, rows_from_sparse)

    if args.inject_bank_failure:
        assert args.partition == "non_uniform", (
            "--inject-bank-failure rides the non_uniform adaptive path "
            "(cache_aware recovery packing is a ROADMAP item)")
        assert args.quant == "off", ("--inject-bank-failure serves the "
                                     "full-precision path")
        assert args.replicate_k_max <= 1, (
            "--inject-bank-failure x --replicate-k-max in one run is a "
            "ROADMAP item; replica failover itself is covered by "
            "tests/test_replication.py")
        return _main_adaptive_fault(args, spec, cfg, mod)
    if args.replicate_k_max > 1:
        assert args.partition == "non_uniform", (
            "--replicate-k-max rides the non_uniform adaptive path "
            "(cache_aware entry placement has no replica axis)")
        assert args.quant == "off", (
            "--replicate-k-max serves the full-precision path; the "
            "dequant+replica-select kernel cross-product is a ROADMAP item")
        return _main_adaptive_replicated(args, spec, cfg, mod)
    if args.partition == "cache_aware":
        assert args.quant == "off", ("--quant rides the non_uniform adaptive "
                                     "path; the cache+residual tiered "
                                     "cross-product is a ROADMAP item")
        return _main_adaptive_cached(args, spec, cfg, mod)

    banks = args.banks
    V = cfg.total_vocab
    cap = int(np.ceil(V / banks) * (1.0 + args.capacity_slack))
    plan = non_uniform_partition(np.ones(V), banks, capacity_rows=cap)
    params, statics = mod.init_params(cfg, jax.random.key(args.seed),
                                      plan=plan, rows_per_bank=cap)
    offs = np.asarray(statics["field_offsets"])

    quant_on = args.quant != "off"
    qspec = None
    if quant_on:
        from repro.quant import QuantSpec
        budget = args.quant_byte_budget
        if budget is None and args.quant == "int4":
            # mostly-int4 mix: the packed width plus a little int8 headroom
            budget = cfg.embed_dim // 2 + 2.0
        qspec = QuantSpec(enable_int4=(args.quant == "int4"),
                          byte_budget=budget,
                          min_hot_rows=args.quant_hot_rows)
    tracer, metrics, writer = _setup_obs(
        args, label=f"serve-adaptive:{args.arch}:quant={args.quant}")
    probe = CompileProbe(metrics=metrics) if quant_on else None
    offs_j = jnp.asarray(offs)

    table = BankedTable(packed=params["emb_packed"],
                        remap_bank=statics["remap_bank"],
                        remap_slot=statics["remap_slot"],
                        n_banks=banks, rows_per_bank=cap)
    rcfg = ReplanConfig.for_vocab(V, banks, capacity_rows=cap,
                                  check_every=args.replan_every,
                                  hysteresis=args.hysteresis,
                                  quant=qspec,
                                  quant_dim=cfg.embed_dim if quant_on
                                  else None)
    runtime = AdaptiveEmbeddingRuntime(table, plan, rcfg,
                                       init_freq=np.ones(V),
                                       tracer=tracer, metrics=metrics)
    row_nbytes = (params["emb_packed"].shape[-1]
                  * np.dtype(params["emb_packed"].dtype).itemsize)
    slo = _TrafficSLO(args, metrics, tracer, banks=banks, dim=cfg.embed_dim,
                      row_nbytes=row_nbytes, runtime=runtime)

    # remap vectors (and on --quant the whole TieredTable) enter as
    # ARGUMENTS: a swap feeds new arrays of the same shape to the same
    # executable — zero recompiles across replans / re-tiers
    if quant_on:
        from repro.serve.serve_step import build_recsys_serve_tiered_adaptive
        serve_tiered = jax.jit(build_recsys_serve_tiered_adaptive(
            mod, cfg, statics, backend=args.backend, with_traffic=True))
    else:
        from repro.obs.traffic import bank_read_counts

        @jax.jit
        def serve(params, remap_bank, remap_slot, batch):
            st = {**statics, "remap_bank": remap_bank,
                  "remap_slot": remap_slot}
            logits = mod.forward(cfg, params, st, batch,
                                 backend=args.backend)
            sparse = batch["sparse"]
            o = offs_j[None, :] if sparse.ndim == 2 else offs_j[None, :, None]
            rows = jnp.where(sparse >= 0, sparse + o, -1)
            return jax.nn.sigmoid(logits), bank_read_counts(
                remap_bank, rows, banks)

    def observe(feats, n_real):
        sp = np.asarray(feats["sparse"])[:n_real]        # (n, F) or (n, F, L)
        runtime.observe_batch(rows_from_sparse(sp, offs))

    from repro.serve.serve_step import MicroBatcher, Request
    mh = max(cfg.multi_hot, 1)
    traces = [DriftingZipfTrace(
        DriftConfig(n_items=v, zipf_a=1.05, avg_bag=float(mh),
                    rotate_every=args.drift_rotate_every, rotate_frac=0.25),
        seed=args.seed + f) for f, v in enumerate(cfg.vocab_sizes)]
    rng = np.random.default_rng(args.seed)

    def one_request(rid):
        sparse = dlrm_drifting_batch(traces, 1, cfg.multi_hot)[0]
        return {"dense": rng.standard_normal(cfg.n_dense).astype(np.float32),
                "sparse": sparse}

    pad = one_request(-1)
    mb = MicroBatcher(args.batch, pad, observer=observe, metrics=metrics)
    verify: dict = {}
    state = {"warm_compiles": None, "n_batches": 0}

    def check_retier(event) -> None:
        """First-swap invariant: the incrementally re-tiered table is
        bit-identical to a from-scratch quantization of the migrated fp
        table under the same tier map."""
        from repro.quant import build_tiered_table
        tt = runtime.tiered
        fresh = build_tiered_table(runtime.table, tt.tier_of_row(),
                                   hot_dtype=tt.hot_dtype)
        ok = ((np.asarray(tt.payload) == np.asarray(fresh.payload)).all()
              and (np.asarray(tt.scale) == np.asarray(fresh.scale)).all()
              and (np.asarray(tt.tier) == np.asarray(fresh.tier)).all())
        verify["tier_ok"] = bool(ok)
        print(f"  [re-tier parity] {'OK' if ok else 'MISMATCH'} "
              f"(tier v{event.tier_version})")

    def run_batch():
        with tracer.span("rewrite"):
            reqs, feats = mb.next_batch()
        t0 = time.perf_counter()
        with tracer.span("device_step", batch=state["n_batches"]):
            p = {**params, "emb_packed": runtime.table.packed}
            if quant_on:
                scores, reads, nbytes = serve_tiered(p, runtime.tiered, feats)
            else:
                scores, reads = serve(p, runtime.table.remap_bank,
                                      runtime.table.remap_slot, feats)
                nbytes = None
            jax.block_until_ready(scores)
        wall_us = (time.perf_counter() - t0) * 1e6
        if quant_on and state["warm_compiles"] is None:
            state["warm_compiles"] = probe.compiles
        mb.complete(reqs)
        slo.after_step(state["n_batches"], reads, wall_us, args.batch,
                       nbytes=None if nbytes is None else np.asarray(nbytes),
                       p99_ms=mb.p99() * 1e3)
        state["n_batches"] += 1
        if writer is not None:
            writer.maybe_write(state["n_batches"])
        event = runtime.end_batch()        # drift check -> migrate -> swap
        if event is not None:
            slo.on_swap(runtime)
            msg = (f"  [swap @batch {event.batch}] {event.update.report} "
                   f"imbalance {event.old_imbalance:.3f} -> "
                   f"{event.new_imbalance:.3f}")
            if event.tier_version is not None:
                msg += (f"  tiers v{event.tier_version} "
                        f"+{event.tier_promoted}/-{event.tier_demoted} "
                        f"(requant {event.tier_requantized})")
            print(msg)
            if quant_on and "tier_ok" not in verify:
                check_retier(event)

    for rid in range(args.requests):
        mb.submit(Request(rid=rid, features=one_request(rid)))
        if len(mb.queue) >= args.batch:
            run_batch()
    while mb.ready():
        run_batch()

    lat = sorted(mb.latencies)
    p50 = lat[len(lat) // 2] * 1e3
    rp = runtime.replanner
    print(f"served {len(lat)} requests  p50={p50:.2f}ms "
          f"p99={mb.p99() * 1e3:.2f}ms  replans={rp.n_replans} "
          f"skipped={rp.n_skipped_replans}")
    metrics.gauge("jax.serve_executables").set(
        (serve_tiered if quant_on else serve)._cache_size())
    _finalize_obs(args, tracer, metrics, writer, latencies=mb.latencies)
    if quant_on:
        n_swaps = len(runtime.swaps)
        executables = serve_tiered._cache_size()
        other = probe.compiles - (state["warm_compiles"] or probe.compiles)
        print(f"compile probe: {executables} serve executable(s) across "
              f"{n_swaps} re-tier swap(s) — "
              f"{'ZERO serve recompiles' if executables == 1 else 'RECOMPILED'}"
              f" ({other} host-side compiles outside the serve step); "
              f"re-tier parity: {verify.get('tier_ok', 'n/a')}")
        if args.min_swaps > 0:
            ok = (n_swaps >= args.min_swaps and executables == 1
                  and verify.get("tier_ok", False))
            if not ok:
                raise SystemExit(
                    f"tiered serve contract violated: swaps={n_swaps} "
                    f"(need >= {args.min_swaps}), serve executables="
                    f"{executables} (need 1), "
                    f"re-tier parity={verify.get('tier_ok')}")
    slo.check_contract(args.min_slo_breaches)


def _main_adaptive_replicated(args, spec, cfg, mod) -> None:
    """Hot-row-replicated serving under the adaptive loop: the runtime's
    replica lane maintains a versioned (ReplicatedPlan, ReplicatedTable)
    side state; every drifted replan re-picks the replicated set from live
    head mass and the WHOLE replicated pytree swaps as a jit argument —
    same zero-recompile contract as the remap/cache/tier lanes.

    Contracts (hard exit with --min-swaps): at least that many live swaps,
    ONE serve executable across every replica-count change, and the first
    swapped-in replicated table bit-identical to packing the migrated base
    table's rows from scratch under the same plan (including the serve
    OUTPUT on a held probe batch).
    """
    from repro.core.embedding import BankedTable, pack_replicated
    from repro.core.partitioning import non_uniform_partition
    from repro.serve.serve_step import (
        MicroBatcher, Request, build_recsys_serve_replicated_adaptive)
    from repro.workload import (AdaptiveEmbeddingRuntime, DriftConfig,
                                DriftingZipfTrace, ReplanConfig,
                                dlrm_drifting_batch, rows_from_sparse,
                                unpacked_rows)

    banks = args.banks
    V = cfg.total_vocab
    cap = int(np.ceil(V / banks) * (1.0 + args.capacity_slack))
    plan = non_uniform_partition(np.ones(V), banks, capacity_rows=cap)
    params, statics = mod.init_params(cfg, jax.random.key(args.seed),
                                      plan=plan, rows_per_bank=cap)
    offs = np.asarray(statics["field_offsets"])

    tracer, metrics, writer = _setup_obs(
        args, label=f"serve-replicated:{args.arch}:k={args.replicate_k_max}")
    probe = CompileProbe(metrics=metrics)
    table = BankedTable(packed=params["emb_packed"],
                        remap_bank=statics["remap_bank"],
                        remap_slot=statics["remap_slot"],
                        n_banks=banks, rows_per_bank=cap)
    rcfg = ReplanConfig.for_vocab(V, banks, capacity_rows=cap,
                                  check_every=args.replan_every,
                                  hysteresis=args.hysteresis,
                                  replicate_k_max=args.replicate_k_max,
                                  replicate_max_r=args.replicate_max_r)
    runtime = AdaptiveEmbeddingRuntime(table, plan, rcfg,
                                       init_freq=np.ones(V),
                                       tracer=tracer, metrics=metrics)

    # the WHOLE replicated pytree (packed copies + (vocab, k_max) remap)
    # enters as an ARGUMENT; bank_live composes the fault lane in (all-live
    # here — failover behavior is pinned by tests/test_replication.py)
    serve = jax.jit(build_recsys_serve_replicated_adaptive(
        mod, cfg, statics, backend=args.backend, with_traffic=True))
    all_live = jnp.ones(banks, dtype=bool)
    row_nbytes = (params["emb_packed"].shape[-1]
                  * np.dtype(params["emb_packed"].dtype).itemsize)
    slo = _TrafficSLO(args, metrics, tracer, banks=banks, dim=cfg.embed_dim,
                      row_nbytes=row_nbytes, runtime=runtime)

    def observe(feats, n_real):
        sp = np.asarray(feats["sparse"])[:n_real]
        runtime.observe_batch(rows_from_sparse(sp, offs))

    mh = max(cfg.multi_hot, 1)
    # a much heavier head than the plain loop: replication only matters when
    # SINGLE rows carry > 1/(banks * k_max) of total traffic — with F fields
    # diluting each row to ~1/F of the stream, the per-field head must be
    # steep (zipf 2.0) before any one row crosses that line. Milder streams
    # correctly replicate nothing (copies all 1 — bit-identical serving).
    traces = [DriftingZipfTrace(
        DriftConfig(n_items=v, zipf_a=2.0, avg_bag=float(mh),
                    rotate_every=args.drift_rotate_every, rotate_frac=0.25),
        seed=args.seed + f) for f, v in enumerate(cfg.vocab_sizes)]
    rng = np.random.default_rng(args.seed)

    def one_request(rid):
        sparse = dlrm_drifting_batch(traces, 1, cfg.multi_hot)[0]
        return {"dense": rng.standard_normal(cfg.n_dense).astype(np.float32),
                "sparse": sparse}

    mb = MicroBatcher(args.batch, one_request(-1), observer=observe,
                      metrics=metrics)
    verify: dict = {}
    state = {"warm_compiles": None, "n_batches": 0}

    def check_repack(event) -> None:
        """First-swap invariant: the replica-lane table is bit-identical to
        packing the migrated base table's rows from scratch under the same
        plan — including the serve output on the probe batch."""
        rplan, rtable = runtime.replicated
        fresh = pack_replicated(unpacked_rows(runtime.table), rplan,
                                rows_per_bank=cap)
        arrays_ok = ((np.asarray(rtable.packed)
                      == np.asarray(fresh.packed)).all()
                     and (np.asarray(rtable.remap_bank)
                          == np.asarray(fresh.remap_bank)).all()
                     and (np.asarray(rtable.remap_slot)
                          == np.asarray(fresh.remap_slot)).all())
        feats = verify["feats"]
        swapped, _, _ = serve(params, rtable, all_live, feats)
        scratch, _, _ = serve(params, fresh, all_live, feats)
        out_ok = (np.asarray(swapped) == np.asarray(scratch)).all()
        verify["repack_ok"] = bool(arrays_ok and out_ok)
        print(f"  [replica swap parity] arrays "
              f"{'OK' if arrays_ok else 'MISMATCH'}  outputs "
              f"{'OK' if out_ok else 'MISMATCH'} "
              f"(replica v{event.replica_version})")

    def run_batch():
        with tracer.span("rewrite"):
            reqs, feats = mb.next_batch()
        t0 = time.perf_counter()
        with tracer.span("device_step", batch=state["n_batches"]):
            _, rtable = runtime.replicated
            scores, counts, reads = serve(params, rtable, all_live, feats)
            jax.block_until_ready(scores)
        wall_us = (time.perf_counter() - t0) * 1e6
        assert int(np.asarray(counts).sum()) == 0  # all-live: no degradation
        if state["warm_compiles"] is None:
            state["warm_compiles"] = probe.compiles
        mb.complete(reqs)
        slo.after_step(state["n_batches"], reads, wall_us, args.batch,
                       p99_ms=mb.p99() * 1e3)
        state["n_batches"] += 1
        if writer is not None:
            writer.maybe_write(state["n_batches"])
        event = runtime.end_batch()        # drift check -> migrate -> swap
        if event is not None:
            slo.on_swap(runtime)
            rplan, _ = runtime.replicated
            print(f"  [swap @batch {event.batch}] {event.update.report} "
                  f"imbalance {event.old_imbalance:.3f} -> "
                  f"{event.new_imbalance:.3f}  replicas v"
                  f"{event.replica_version} hot={event.replica_hot_rows} "
                  f"churn={event.replica_copy_churn} "
                  f"modeled share={rplan.max_share():.4f} "
                  f"(ideal {1.0 / banks:.4f})")
            if "repack_ok" not in verify:
                verify["feats"] = feats
                check_repack(event)

    for rid in range(args.requests):
        mb.submit(Request(rid=rid, features=one_request(rid)))
        if len(mb.queue) >= args.batch:
            run_batch()
    while mb.ready():
        run_batch()

    lat = sorted(mb.latencies)
    p50 = lat[len(lat) // 2] * 1e3
    rp = runtime.replanner
    n_swaps = len(runtime.swaps)
    executables = serve._cache_size()
    other = probe.compiles - (state["warm_compiles"] or probe.compiles)
    rplan, _ = runtime.replicated
    print(f"served {len(lat)} requests  p50={p50:.2f}ms "
          f"p99={mb.p99() * 1e3:.2f}ms  replans={rp.n_replans} "
          f"skipped={rp.n_skipped_replans}")
    print(f"replica lane: v{runtime.replica_version}, "
          f"{rplan.n_replicated} replicated row(s) "
          f"(k_max {args.replicate_k_max}), modeled max-bank share "
          f"{rplan.max_share():.4f} vs ideal {1.0 / banks:.4f}")
    print(f"compile probe: {executables} serve executable(s) across "
          f"{n_swaps} replica swap(s) — "
          f"{'ZERO serve recompiles' if executables == 1 else 'RECOMPILED'} "
          f"({other} host-side compiles outside the serve step); "
          f"re-pack parity: {verify.get('repack_ok', 'n/a')}")
    metrics.gauge("jax.serve_executables").set(executables)
    _finalize_obs(args, tracer, metrics, writer, latencies=mb.latencies)
    if args.min_swaps > 0:
        ok = (n_swaps >= args.min_swaps and executables == 1
              and verify.get("repack_ok", False))
        if not ok:
            raise SystemExit(
                f"replicated serve contract violated: swaps={n_swaps} "
                f"(need >= {args.min_swaps}), serve executables="
                f"{executables} (need 1), "
                f"re-pack parity={verify.get('repack_ok')}")
    slo.check_contract(args.min_slo_breaches)


def _main_adaptive_fault(args, spec, cfg, mod) -> None:
    """Fault-tolerant serving: the adaptive loop with an injected per-bank
    fault schedule. The serve step takes a ``bank_live`` mask as one more
    swap-style ARGUMENT and returns (scores, degraded_read_count); a bank
    death triggers the recovery replan (rows re-packed onto survivors
    through the versioned migrate/swap lane), and degraded-slow banks are
    caught by the StragglerWatchdog and shed load via planner penalties.

    Contracts (hard exit with --min-recoveries): degradation confined to
    dead-bank rows (count==0 requests bit-match a never-failed run even
    MID-FAILURE), post-recovery batches fully bit-match a never-failed run
    with zero degraded reads, and the whole failure -> replan -> recovery
    cycle uses ONE serve executable. The never-failed reference is the same
    executable evaluated against the ORIGINAL pack + all-live mask — the
    unsharded bag scan sums bag entries in index order whatever the plan, so
    cross-plan bit-parity is exact, not approximate.
    """
    from repro.core.embedding import BankedTable
    from repro.core.partitioning import non_uniform_partition
    from repro.dist.bank_fault import BankFaultState
    from repro.dist.fault import StragglerWatchdog
    from repro.serve.serve_step import (MicroBatcher, Request,
                                        build_recsys_serve_degraded_adaptive)
    from repro.workload import (AdaptiveEmbeddingRuntime, DriftConfig,
                                DriftingZipfTrace, ReplanConfig,
                                dlrm_drifting_batch, rows_from_sparse)

    banks = args.banks
    V = cfg.total_vocab
    cap = int(np.ceil(V / banks) * (1.0 + args.capacity_slack))
    plan = non_uniform_partition(np.ones(V), banks, capacity_rows=cap)
    params, statics = mod.init_params(cfg, jax.random.key(args.seed),
                                      plan=plan, rows_per_bank=cap)
    offs = np.asarray(statics["field_offsets"])
    fault = BankFaultState.from_specs(banks, args.inject_bank_failure)
    tracer, metrics, writer = _setup_obs(
        args, label=f"serve-fault:{args.arch}")
    probe = CompileProbe(metrics=metrics)
    # fault-lane counters the final snapshot/summary must always carry,
    # fired or not (the CI metrics-schema gate keys on them)
    m_deg_reads = metrics.counter("serve.degraded_reads_total",
                                  "bounded-degraded row reads served")
    m_deg_batches = metrics.counter("serve.degraded_batches_total",
                                    "micro-batches with >0 degraded reads")
    m_faults = metrics.counter("fault.injected_total",
                               "bank-fault schedule events fired")

    table = BankedTable(packed=params["emb_packed"],
                        remap_bank=statics["remap_bank"],
                        remap_slot=statics["remap_slot"],
                        n_banks=banks, rows_per_bank=cap)
    rcfg = ReplanConfig.for_vocab(V, banks, capacity_rows=cap,
                                  check_every=args.replan_every,
                                  hysteresis=args.hysteresis)
    runtime = AdaptiveEmbeddingRuntime(table, plan, rcfg,
                                       init_freq=np.ones(V),
                                       tracer=tracer, metrics=metrics)
    watchdog = StragglerWatchdog(factor=args.straggler_factor,
                                 metrics=metrics)

    serve = jax.jit(build_recsys_serve_degraded_adaptive(
        mod, cfg, statics, backend=args.backend, with_traffic=True))
    all_live = jnp.ones(banks, dtype=bool)
    row_nbytes = (params["emb_packed"].shape[-1]
                  * np.dtype(params["emb_packed"].dtype).itemsize)
    slo = _TrafficSLO(args, metrics, tracer, banks=banks, dim=cfg.embed_dim,
                      row_nbytes=row_nbytes, runtime=runtime)
    # the never-failed reference pack: same executable, original arrays
    orig = (params["emb_packed"], statics["remap_bank"],
            statics["remap_slot"])

    def observe(feats, n_real):
        sp = np.asarray(feats["sparse"])[:n_real]
        runtime.observe_batch(rows_from_sparse(sp, offs))

    mh = max(cfg.multi_hot, 1)
    traces = [DriftingZipfTrace(
        DriftConfig(n_items=v, zipf_a=1.05, avg_bag=float(mh),
                    rotate_every=args.drift_rotate_every, rotate_frac=0.25),
        seed=args.seed + f) for f, v in enumerate(cfg.vocab_sizes)]
    rng = np.random.default_rng(args.seed)

    def one_request(rid):
        sparse = dlrm_drifting_batch(traces, 1, cfg.multi_hot)[0]
        return {"dense": rng.standard_normal(cfg.n_dense).astype(np.float32),
                "sparse": sparse}

    mb = MicroBatcher(args.batch, one_request(-1), observer=observe,
                      metrics=metrics)
    st = {"batch": 0, "handled_dead": frozenset(), "penalized": False,
          "fail_batch": None, "recover_batch": None,
          "confine_ok": True, "confine_checked": 0,
          "recover_parity": None, "degraded_reads": 0, "degraded_batches": 0}
    recoveries: list = []

    def never_failed(feats):
        p0 = {**params, "emb_packed": orig[0]}
        ref, _, _ = serve(p0, orig[1], orig[2], all_live, feats)
        return np.asarray(ref)

    def run_batch():
        b = st["batch"]
        st["batch"] += 1
        for e in fault.advance(b):
            print(f"  [fault @batch {b}] {e}")
            m_faults.inc()
            tracer.instant("fault_injected", batch=b, event=str(e))
            if st["fail_batch"] is None and fault.dead_banks():
                st["fail_batch"] = b
        live = fault.live_mask()
        with tracer.span("rewrite"):
            reqs, feats = mb.next_batch()
        t0 = time.perf_counter()
        with tracer.span("device_step", batch=b):
            p = {**params, "emb_packed": runtime.table.packed}
            scores, counts, reads = serve(p, runtime.table.remap_bank,
                                          runtime.table.remap_slot,
                                          jnp.asarray(live), feats)
            jax.block_until_ready(scores)
        wall_us = (time.perf_counter() - t0) * 1e6
        slo.after_step(b, reads, wall_us, args.batch,
                       p99_ms=mb.p99() * 1e3)
        if writer is not None:
            writer.maybe_write(st["batch"])
        counts = np.asarray(counts)
        n_deg = int(counts.sum())
        st["degraded_reads"] += n_deg
        m_deg_reads.inc(n_deg)
        if n_deg > 0:
            st["degraded_batches"] += 1
            m_deg_batches.inc()
            # confinement: requests that touched NO dead-bank row must be
            # bit-exact vs the never-failed run, mid-failure included
            if st["confine_checked"] < 2:
                st["confine_checked"] += 1
                ref = never_failed(feats)
                exact = np.asarray(scores)[counts == 0] == ref[counts == 0]
                ok = bool(exact.all()) and (counts > 0).any()
                st["confine_ok"] = st["confine_ok"] and ok
                print(f"  [degraded @batch {b}] {n_deg} degraded reads, "
                      f"{int((counts > 0).sum())}/{len(counts)} requests; "
                      f"clean requests bit-exact: {ok}")
        elif st["recover_batch"] is None and st["fail_batch"] is not None \
                and st["handled_dead"]:
            # first clean batch after the recovery swap: full bit-parity
            st["recover_batch"] = b
            ref = never_failed(feats)
            st["recover_parity"] = bool(
                (np.asarray(scores) == ref).all())
            print(f"  [recovered @batch {b}] 0 degraded reads "
                  f"({b - st['fail_batch']} batches after failure); "
                  f"bit-parity with never-failed run: "
                  f"{st['recover_parity']}")
        mb.complete(reqs)

        # recovery lane: any not-yet-handled bank death replans NOW
        dead = frozenset(fault.dead_banks())
        if dead != st["handled_dead"]:
            event = runtime.on_bank_failure(live)
            slo.on_swap(runtime)
            st["handled_dead"] = dead
            recoveries.append(event)
            print(f"  [recovery replan @batch {b}] dead={sorted(dead)} "
                  f"reason={event.reason} "
                  f"recovery={event.recovery_s * 1e3:.1f}ms "
                  f"imbalance {event.old_imbalance:.3f} -> "
                  f"{event.new_imbalance:.3f}")
            return
        # straggler lane: modeled per-bank batch time (reads x slow factor;
        # banks run in parallel, so the batch takes the slowest bank's
        # time). The watchdog sees EVERY batch — healthy batches build the
        # median baseline a degraded bank must then exceed.
        sf = fault.slow_factor()
        rows = rows_from_sparse(np.asarray(feats["sparse"]), offs)
        rows = rows[rows >= 0]
        reads = np.bincount(
            np.asarray(runtime.plan.bank_of_row)[rows], minlength=banks)
        t_bank = reads.astype(np.float64) * sf
        if watchdog.observe(b, float(t_bank.max())) and not st["penalized"]:
            slow = int(np.argmax(t_bank))
            pen = np.ones(banks)
            pen[slow] = float(max(sf[slow], 1.0))
            event = runtime.on_straggler(pen)
            slo.on_swap(runtime)
            st["penalized"] = True
            print(f"  [straggler @batch {b}] bank {slow} flagged "
                  f"(x{pen[slow]:g}); penalty replan "
                  f"imbalance {event.old_imbalance:.3f} -> "
                  f"{event.new_imbalance:.3f}")
            return
        event = runtime.end_batch()            # ordinary drift lane
        if event is not None:
            slo.on_swap(runtime)
            print(f"  [swap @batch {event.batch}] {event.update.report} "
                  f"imbalance {event.old_imbalance:.3f} -> "
                  f"{event.new_imbalance:.3f}")

    for rid in range(args.requests):
        mb.submit(Request(rid=rid, features=one_request(rid)))
        if len(mb.queue) >= args.batch:
            run_batch()
    while mb.ready():
        run_batch()

    lat = sorted(mb.latencies)
    p50 = lat[len(lat) // 2] * 1e3
    rp = runtime.replanner
    executables = serve._cache_size()
    n_rec = len([e for e in recoveries if e.reason == "bank_failure"])
    print(f"served {len(lat)} requests  p50={p50:.2f}ms "
          f"p99={mb.p99() * 1e3:.2f}ms  replans={rp.n_replans} "
          f"skipped={rp.n_skipped_replans}")
    print(f"fault lane: {len(fault.fired)} fault(s) fired, "
          f"{st['degraded_reads']} degraded reads over "
          f"{st['degraded_batches']} batch(es), {n_rec} recovery replan(s), "
          f"{len(watchdog.events)} straggler event(s); "
          f"confinement {'OK' if st['confine_ok'] else 'VIOLATED'}, "
          f"recovery parity {st['recover_parity']}, "
          f"{executables} serve executable(s)")
    print(f"slo lane: {slo.breaches} breach(es) over "
          f"{slo.acc.batches} measured batch(es), "
          f"{slo.penalties} replanner penalt(ies)")
    metrics.gauge("jax.serve_executables").set(executables)
    _finalize_obs(args, tracer, metrics, writer, latencies=mb.latencies)
    if args.min_recoveries > 0:
        ok = (n_rec >= args.min_recoveries and executables == 1
              and st["confine_ok"] and st["recover_parity"] is True)
        if not ok:
            raise SystemExit(
                f"fault-serve contract violated: recoveries={n_rec} "
                f"(need >= {args.min_recoveries}), serve executables="
                f"{executables} (need 1), confinement={st['confine_ok']}, "
                f"recovery parity={st['recover_parity']}")
    slo.check_contract(args.min_slo_breaches)


def _main_adaptive_cached(args, spec, cfg, mod) -> None:
    """The fused cache+residual serve path under the adaptive runtime: every
    batch host-rewritten + version-tagged, live GRACE-table swaps between
    micro-batches, one serve executable for the whole run."""
    from repro.core.cache_runtime import build_cache_table_fixed
    from repro.core.embedding import BankedTable
    from repro.core.partitioning import non_uniform_partition
    from repro.serve.serve_step import build_recsys_serve_cached_adaptive
    from repro.workload import (AdaptiveEmbeddingRuntime, DriftConfig,
                                DriftingZipfTrace, ReplanConfig,
                                dlrm_drifting_batch, unpacked_rows)

    mh = cfg.multi_hot
    assert mh >= 2, ("--partition cache_aware needs multi-hot bags "
                     "(try --arch updlrm-paper); GRACE partial sums fuse "
                     ">=2 lookups of one bag")
    banks = args.banks
    V = cfg.total_vocab
    cap = int(np.ceil(V / banks) * (1.0 + args.capacity_slack))
    crpb = max(1, -(-args.cache_entries // banks))
    plan = non_uniform_partition(np.ones(V), banks, capacity_rows=cap)
    params, statics = mod.init_params(cfg, jax.random.key(args.seed),
                                      plan=plan, rows_per_bank=cap)
    offs = np.asarray(statics["field_offsets"])

    tracer, metrics, writer = _setup_obs(
        args, label=f"serve-cached:{args.arch}")
    probe = CompileProbe(metrics=metrics)
    table = BankedTable(packed=params["emb_packed"],
                        remap_bank=statics["remap_bank"],
                        remap_slot=statics["remap_slot"],
                        n_banks=banks, rows_per_bank=cap)
    rcfg = ReplanConfig.for_vocab(V, banks, capacity_rows=cap,
                                  check_every=args.replan_every,
                                  partitioner="cache_aware",
                                  cache_rows_per_bank=crpb,
                                  mine_min_support=2,
                                  hysteresis=args.hysteresis,
                                  # exponential window: a long-lived server's
                                  # cumulative estimate goes blind to late
                                  # rotations (bench_workload's p99 spike)
                                  telemetry_decay=0.8,
                                  telemetry_decay_every=4096)
    runtime = AdaptiveEmbeddingRuntime(
        table, plan, rcfg, init_freq=np.ones(V),
        max_cache_per_bag=max(2, mh // 4), max_residual_per_bag=mh,
        tracer=tracer, metrics=metrics)

    serve = jax.jit(build_recsys_serve_cached_adaptive(
        mod, cfg, statics, backend=args.backend, with_traffic=True))
    row_nbytes = (params["emb_packed"].shape[-1]
                  * np.dtype(params["emb_packed"].dtype).itemsize)
    slo = _TrafficSLO(args, metrics, tracer, banks=banks, dim=cfg.embed_dim,
                      row_nbytes=row_nbytes, runtime=runtime)

    def union_rect(feats):
        sp = np.asarray(feats["sparse"])                 # (B, F, L)
        return np.where(sp >= 0, sp + offs[None, :, None], -1)

    def observe(feats, n_real):
        sp = np.asarray(feats["sparse"])[:n_real]
        u = np.where(sp >= 0, sp + offs[None, :, None], -1)
        runtime.observe_bags([bag[bag >= 0]
                              for bag in u.reshape(-1, u.shape[-1])])

    traces = [DriftingZipfTrace(
        DriftConfig(n_items=v, zipf_a=1.2, avg_bag=float(mh),
                    rotate_every=args.drift_rotate_every, rotate_frac=0.25),
        seed=args.seed + f) for f, v in enumerate(cfg.vocab_sizes)]
    rng = np.random.default_rng(args.seed)

    def one_request(rid):
        sparse = dlrm_drifting_batch(traces, 1, mh)[0]
        return {"dense": rng.standard_normal(cfg.n_dense).astype(np.float32),
                "sparse": sparse}

    mb = MicroBatcher(args.batch, one_request(-1), observer=observe,
                      metrics=metrics)
    verify: dict = {}
    state = {"warm_compiles": None, "n_batches": 0}

    def check_swap(event) -> None:
        """First-swap invariant: the swapped-in state is bit-identical to a
        from-scratch rebuild of the whole cache path at the same plan."""
        rows = unpacked_rows(runtime.table)
        p = runtime.plan
        fresh = np.zeros_like(np.asarray(runtime.table.packed))
        fresh[p.bank_of_row.astype(np.int64) * cap + p.slot_of_row] = rows
        emt_ok = (np.asarray(runtime.table.packed) == fresh).all()
        fresh_cache = build_cache_table_fixed(rows, runtime.cache_plan,
                                              dtype=fresh.dtype)
        ct = runtime.cache_table
        cache_ok = ((np.asarray(ct.packed)
                     == np.asarray(fresh_cache.packed)).all()
                    and (np.asarray(ct.remap_bank)
                         == np.asarray(fresh_cache.remap_bank)).all()
                    and (np.asarray(ct.remap_slot)
                         == np.asarray(fresh_cache.remap_slot)).all())
        verify.update(arrays_ok=bool(emt_ok and cache_ok),
                      fresh_cache=fresh_cache, version=runtime.rewriter.version)
        print(f"  [swap parity] EMT {'OK' if emt_ok else 'MISMATCH'}  "
              f"cache {'OK' if cache_ok else 'MISMATCH'} "
              f"(version {verify['version']})")

    def run_batch():
        with tracer.span("rewrite"):
            reqs, feats = mb.next_batch()
            rb = runtime.rewrite(union_rect(feats))      # host pipeline, v
        event = runtime.end_batch()                      # may swap to v+1
        if event is not None:
            slo.on_swap(runtime)
            hits = int((rb.cache_idx >= 0).sum())
            print(f"  [swap @batch {event.batch}] {event.update.report} "
                  f"imbalance {event.old_imbalance:.3f} -> "
                  f"{event.new_imbalance:.3f}  cache v{event.cache_version} "
                  f"entries {event.cache_entries} "
                  f"(dropped {event.cache_dropped}, in-flight hits {hits})")
            if "arrays_ok" not in verify:
                check_swap(event)
                verify["feats"] = feats                  # output-parity probe
                verify["rb"] = runtime.rewrite(union_rect(feats))
                verify["table"] = runtime.cache_table    # the swapped-in one
        # the in-flight batch resolves against ITS version's cache table,
        # even when the swap above just retired it from "current"
        t0 = time.perf_counter()
        with tracer.span("device_step", batch=state["n_batches"],
                         cache_version=rb.version):
            batch_c = {"dense": feats["dense"],
                       "cache_idx": jnp.asarray(rb.cache_idx),
                       "residual_idx": jnp.asarray(rb.residual_idx)}
            p = {**params, "emb_packed": runtime.table.packed}
            scores, reads = serve(p, runtime.table.remap_bank,
                                  runtime.table.remap_slot,
                                  runtime.cache_table_for(rb.version), batch_c)
            jax.block_until_ready(scores)
        wall_us = (time.perf_counter() - t0) * 1e6
        if state["warm_compiles"] is None:
            state["warm_compiles"] = probe.compiles      # post-first-compile
        mb.complete(reqs)
        slo.after_step(state["n_batches"], reads, wall_us, args.batch,
                       p99_ms=mb.p99() * 1e3)
        state["n_batches"] += 1
        if writer is not None:
            writer.maybe_write(state["n_batches"])

    for rid in range(args.requests):
        mb.submit(Request(rid=rid, features=one_request(rid)))
        if len(mb.queue) >= args.batch:
            run_batch()
    while mb.ready():
        run_batch()

    # -- post-run invariants -------------------------------------------------
    n_swaps = len(runtime.swaps)
    executables = serve._cache_size()       # 1 == zero serve-step recompiles
    other_compiles = probe.compiles - (state["warm_compiles"]
                                       or probe.compiles)
    out_ok = True
    if verify:
        rb = verify["rb"]
        batch_c = {"dense": verify["feats"]["dense"],
                   "cache_idx": jnp.asarray(rb.cache_idx),
                   "residual_idx": jnp.asarray(rb.residual_idx)}
        p = {**params, "emb_packed": runtime.table.packed}
        swapped, _ = serve(p, runtime.table.remap_bank,
                           runtime.table.remap_slot, verify["table"], batch_c)
        fresh, _ = serve(p, runtime.table.remap_bank, runtime.table.remap_slot,
                         verify["fresh_cache"], batch_c)
        out_ok = bool((np.asarray(swapped) == np.asarray(fresh)).all())

    lat = sorted(mb.latencies)
    p50 = lat[len(lat) // 2] * 1e3
    print(f"served {len(lat)} requests  p50={p50:.2f}ms "
          f"p99={mb.p99() * 1e3:.2f}ms  replans={runtime.replanner.n_replans} "
          f"skipped={runtime.replanner.n_skipped_replans} "
          f"swaps={n_swaps}  cache entries={runtime.cache_plan.n_entries}")
    print(f"compile probe: {executables} serve executable(s) across "
          f"{n_swaps} swap(s) — "
          f"{'ZERO serve recompiles' if executables == 1 else 'RECOMPILED'} "
          f"({other_compiles} host-side compiles outside the serve step, "
          f"migration collectives included); swap parity: "
          f"arrays {'OK' if verify.get('arrays_ok') else 'n/a'}, "
          f"outputs {'OK' if out_ok else 'MISMATCH'}")
    metrics.gauge("jax.serve_executables").set(executables)
    _finalize_obs(args, tracer, metrics, writer, latencies=mb.latencies)
    if args.min_swaps > 0:
        ok = (n_swaps >= args.min_swaps and executables == 1 and out_ok
              and verify.get("arrays_ok", False))
        if not ok:
            raise SystemExit(
                f"serve-smoke contract violated: swaps={n_swaps} "
                f"(need >= {args.min_swaps}), serve executables="
                f"{executables} (need 1), "
                f"parity={verify.get('arrays_ok')}/{out_ok}")
    slo.check_contract(args.min_slo_breaches)


def _one(spec, cfg, rng, rid):
    from repro.data import synthetic as syn
    if spec.family == "dlrm":
        b = syn.dlrm_batch(cfg.vocab_sizes, cfg.n_dense, 1, seed=1, step=rid,
                           multi_hot=cfg.multi_hot)
    elif spec.family == "din":
        b = syn.din_batch(cfg.n_items, cfg.n_cates, cfg.seq_len, 1, seed=1,
                          step=rid)
    else:
        b = syn.xdeepfm_batch(cfg.vocab_sizes, 1, seed=1, step=rid)
    b.pop("label", None)
    return b


if __name__ == "__main__":
    main()
