"""Serving CLI: ``python -m repro.launch.serve --arch dlrm-rm2``.

Simulates the paper's online-inference setup with the MicroBatcher: a stream
of requests, cache-aware rewriting in the pre-process stage, jitted scoring,
p50/p99 latency report.

``--adaptive`` (dlrm only) turns on the repro.workload closed loop: requests
come from a DRIFTING Zipf stream, the MicroBatcher's observer tap feeds the
telemetry, and on detected drift the table is repartitioned and live-migrated
between micro-batches. The remap vectors are jit ARGUMENTS (not closure
constants) and the packed shape is pinned to a fixed per-bank capacity, so a
swap never recompiles the serve step.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.serve.serve_step import MicroBatcher, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dlrm-rm2")
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "jnp", "pallas"),
                    help="embedding stage-2 backend (dlrm only)")
    ap.add_argument("--adaptive", action="store_true",
                    help="online telemetry + drift-triggered repartitioning "
                         "with live table migration (dlrm only)")
    ap.add_argument("--banks", type=int, default=8,
                    help="bank count for the adaptive partition")
    ap.add_argument("--replan-every", type=int, default=8,
                    help="micro-batches between drift checks")
    ap.add_argument("--capacity-slack", type=float, default=0.25,
                    help="per-bank row headroom over vocab/banks")
    ap.add_argument("--drift-rotate-every", type=int, default=512,
                    help="requests between hot-set rotations of the "
                         "synthetic drifting stream")
    args = ap.parse_args()

    spec = get_arch(args.arch)
    assert spec.family in ("dlrm", "din", "xdeepfm"), "recsys serving CLI"
    cfg = spec.reduced
    mod = __import__(f"repro.models.{spec.family}", fromlist=["forward"])
    if args.adaptive:
        assert spec.family == "dlrm", "--adaptive drives the banked super-table"
        return _main_adaptive(args, spec, cfg, mod)
    params, statics = mod.init_params(cfg, jax.random.key(args.seed))
    from repro.serve.serve_step import build_recsys_serve
    backend = args.backend if spec.family == "dlrm" else None
    serve = jax.jit(build_recsys_serve(mod, cfg, statics, backend=backend))

    rng = np.random.default_rng(args.seed)
    from repro.data import synthetic as syn
    if spec.family == "dlrm":
        proto = syn.dlrm_batch(cfg.vocab_sizes, cfg.n_dense, 1, seed=0,
                               step=0, multi_hot=cfg.multi_hot)
    elif spec.family == "din":
        proto = syn.din_batch(cfg.n_items, cfg.n_cates, cfg.seq_len, 1,
                              seed=0, step=0)
    else:
        proto = syn.xdeepfm_batch(cfg.vocab_sizes, 1, seed=0, step=0)
    proto.pop("label", None)
    pad = {k: v[0] for k, v in proto.items()}

    mb = MicroBatcher(args.batch, pad)
    for rid in range(args.requests):
        feats = {k: v[0] for k, v in _one(spec, cfg, rng, rid).items()}
        mb.submit(Request(rid=rid, features=feats))
        if len(mb.queue) >= args.batch:
            reqs, feats_b = mb.next_batch()
            scores = serve(params, feats_b)
            jax.block_until_ready(scores)
            mb.complete(reqs)
    while mb.ready():
        reqs, feats_b = mb.next_batch()
        jax.block_until_ready(serve(params, feats_b))
        mb.complete(reqs)

    lat = sorted(mb.latencies)
    p50 = lat[len(lat) // 2] * 1e3
    print(f"served {len(lat)} requests  p50={p50:.2f}ms "
          f"p99={mb.p99() * 1e3:.2f}ms")


def _main_adaptive(args, spec, cfg, mod) -> None:
    """Drifting traffic -> telemetry -> replan -> migrate -> swap, live."""
    from repro.core.embedding import BankedTable
    from repro.core.partitioning import non_uniform_partition
    from repro.workload import (AdaptiveEmbeddingRuntime, DriftConfig,
                                DriftingZipfTrace, ReplanConfig,
                                dlrm_drifting_batch, rows_from_sparse)

    banks = args.banks
    V = cfg.total_vocab
    cap = int(np.ceil(V / banks) * (1.0 + args.capacity_slack))
    plan = non_uniform_partition(np.ones(V), banks, capacity_rows=cap)
    params, statics = mod.init_params(cfg, jax.random.key(args.seed),
                                      plan=plan, rows_per_bank=cap)
    offs = np.asarray(statics["field_offsets"])

    table = BankedTable(packed=params["emb_packed"],
                        remap_bank=statics["remap_bank"],
                        remap_slot=statics["remap_slot"],
                        n_banks=banks, rows_per_bank=cap)
    rcfg = ReplanConfig.for_vocab(V, banks, capacity_rows=cap,
                                  check_every=args.replan_every)
    runtime = AdaptiveEmbeddingRuntime(table, plan, rcfg,
                                       init_freq=np.ones(V))

    # remap vectors enter as ARGUMENTS: a swap feeds new arrays of the same
    # shape to the same executable — zero recompiles across replans
    @jax.jit
    def serve(params, remap_bank, remap_slot, batch):
        st = {**statics, "remap_bank": remap_bank, "remap_slot": remap_slot}
        logits = mod.forward(cfg, params, st, batch, backend=args.backend)
        return jax.nn.sigmoid(logits)

    def observe(feats, n_real):
        sp = np.asarray(feats["sparse"])[:n_real]        # (n, F) or (n, F, L)
        runtime.observe_batch(rows_from_sparse(sp, offs))

    from repro.serve.serve_step import MicroBatcher, Request
    mh = max(cfg.multi_hot, 1)
    traces = [DriftingZipfTrace(
        DriftConfig(n_items=v, zipf_a=1.05, avg_bag=float(mh),
                    rotate_every=args.drift_rotate_every, rotate_frac=0.25),
        seed=args.seed + f) for f, v in enumerate(cfg.vocab_sizes)]
    rng = np.random.default_rng(args.seed)

    def one_request(rid):
        sparse = dlrm_drifting_batch(traces, 1, cfg.multi_hot)[0]
        return {"dense": rng.standard_normal(cfg.n_dense).astype(np.float32),
                "sparse": sparse}

    pad = one_request(-1)
    mb = MicroBatcher(args.batch, pad, observer=observe)

    def run_batch():
        reqs, feats = mb.next_batch()
        p = {**params, "emb_packed": runtime.table.packed}
        scores = serve(p, runtime.table.remap_bank, runtime.table.remap_slot,
                       feats)
        jax.block_until_ready(scores)
        mb.complete(reqs)
        event = runtime.end_batch()        # drift check -> migrate -> swap
        if event is not None:
            print(f"  [swap @batch {event.batch}] {event.update.report} "
                  f"imbalance {event.old_imbalance:.3f} -> "
                  f"{event.new_imbalance:.3f}")

    for rid in range(args.requests):
        mb.submit(Request(rid=rid, features=one_request(rid)))
        if len(mb.queue) >= args.batch:
            run_batch()
    while mb.ready():
        run_batch()

    lat = sorted(mb.latencies)
    p50 = lat[len(lat) // 2] * 1e3
    print(f"served {len(lat)} requests  p50={p50:.2f}ms "
          f"p99={mb.p99() * 1e3:.2f}ms  replans={runtime.replanner.n_replans}")


def _one(spec, cfg, rng, rid):
    from repro.data import synthetic as syn
    if spec.family == "dlrm":
        b = syn.dlrm_batch(cfg.vocab_sizes, cfg.n_dense, 1, seed=1, step=rid,
                           multi_hot=cfg.multi_hot)
    elif spec.family == "din":
        b = syn.din_batch(cfg.n_items, cfg.n_cates, cfg.seq_len, 1, seed=1,
                          step=rid)
    else:
        b = syn.xdeepfm_batch(cfg.vocab_sizes, 1, seed=1, step=rid)
    b.pop("label", None)
    return b


if __name__ == "__main__":
    main()
