import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax import/init: jax locks the device count on first use.
"""Multi-pod dry-run driver.

For every (arch x input-shape) cell, ``jit(step).lower(...).compile()`` on the
production mesh — (16,16)=256 chips single-pod and (2,16,16)=512 multi-pod —
and record memory_analysis / cost_analysis / per-collective bytes to JSON.
A cell FAILING to lower+compile (sharding mismatch, compile-time OOM,
unsupported collective) is a bug in the framework, not in the cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch dlrm-rm2 --shape train_batch
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import gc
import json
import time
import traceback

import jax

from repro.configs import get_arch, list_archs
from repro.launch import roofline as RL
from repro.launch.cells import build_cell
from repro.launch.mesh import make_production_mesh


def run_cell(arch_id: str, shape_id: str, multi_pod: bool, out_dir: str,
             save_hlo: bool = False) -> dict:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(arch_id, shape_id, mesh)
    lowered = jax.jit(cell.fn).lower(*cell.args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    ana = RL.analyze_hlo(hlo)
    coll = ana["collectives"]
    coll_total = sum(coll.values())
    flops = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))
    # touched-rows correction for gather/scatter (see analyze_hlo docstring)
    bytes_acc = max(0.0, bytes_raw - ana["gather_scatter_correction"])
    terms = RL.roofline_terms(flops, bytes_acc, coll_total)
    mf = RL.model_flops(arch_id, shape_id)
    n_dev = mesh.devices.size

    rec = {
        "arch": arch_id,
        "shape": shape_id,
        "mesh": mesh_name,
        "n_devices": int(n_dev),
        "step_kind": cell.step_kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "bytes_per_device_raw": bytes_raw,
        "collective_bytes_per_device": coll_total,
        "collectives": coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.peak_memory_in_bytes,
            "fits_16gb": bool(mem.peak_memory_in_bytes
                              + mem.argument_size_in_bytes < 16 * 2**30),
        },
        "roofline": terms,
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / (flops * n_dev)) if flops else None,
        "meta": {k: (list(v) if isinstance(v, tuple) else v)
                 for k, v in cell.meta.items()},
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{mesh_name}__{arch_id}__{shape_id}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if save_hlo:
        with open(path.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo)
    del compiled, lowered, cell
    gc.collect()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--rolled", action="store_true",
                    help="compile-pass only: keep scans rolled (fast); "
                         "accounting comes from the exact single-pod runs")
    args = ap.parse_args()
    if args.rolled:
        from repro.launch import cells
        cells.ROLLED_ONLY = True

    cells: list[tuple[str, str]] = []
    if args.all:
        for a in list_archs():
            for s in get_arch(a).shapes:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch_id, shape_id in cells:
        for mp in meshes:
            tag = f"{'multi' if mp else 'single'}:{arch_id}:{shape_id}"
            try:
                rec = run_cell(arch_id, shape_id, mp, args.out,
                               save_hlo=args.save_hlo)
                r = rec["roofline"]
                print(f"OK   {tag:55s} compile={rec['compile_s']:7.1f}s "
                      f"peak={rec['memory']['peak_bytes']/2**30:6.2f}GiB "
                      f"dom={r['dominant']:12s} bound={r['bound_s']*1e3:9.3f}ms",
                      flush=True)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e[:200])
        raise SystemExit(1)
    print("\nall cells passed")


if __name__ == "__main__":
    main()
