"""Production meshes.

Functions (never module-level constants) so importing this module touches no
jax device state — the dry-run must set XLA_FLAGS before first jax init.
"""
from __future__ import annotations

import jax

from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (16, 16) = 256 chips (data, model).
    Multi-pod: (2, 16, 16) = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh over forced host devices — tests and local dry-runs."""
    return make_mesh(shape, axes)


def dp_axes_for(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")
