"""Dry-run cell builders: (arch x shape x mesh) -> (jittable fn, SDS args).

Everything here is allocation-free: parameters/optimizer state/KV caches are
ShapeDtypeStructs with NamedShardings attached; only tiny remap-metadata ints
are computed concretely. ``lower() + compile()`` of the returned pair proves
the cell's sharding config is coherent (the multi-pod dry-run deliverable).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs import shapes as SH
from repro.core.embedding import DistCtx
from repro.dist import sharding as R
from repro.launch.mesh import dp_axes_for
from repro.train import optim as O
from repro.train.train_step import TrainState, build_train_step, default_optimizer

P = jax.sharding.PartitionSpec

EDGE_PAD = 512  # edge lists pad to multiples of this (divides 256 and 512)

# compile-pass-only mode: keep scans ROLLED (fast compiles; identical program
# semantics) — used for the multi-pod verification where cost accounting
# comes from the single-pod exact runs. Set by dryrun --rolled.
ROLLED_ONLY = False


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_id: str
    step_kind: str
    fn: Callable
    args: tuple          # SDS pytrees with shardings attached
    meta: dict


def _attach(struct, shardings):
    """Zip SDS pytree with NamedSharding pytree."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        struct, shardings)


def _sds_shard(dist: DistCtx | None, struct, spec_fn):
    if dist is None:
        return struct
    return jax.tree_util.tree_map_with_path(
        lambda p, l: jax.ShapeDtypeStruct(
            l.shape, l.dtype,
            sharding=jax.sharding.NamedSharding(
                dist.mesh, spec_fn(jax.tree_util.keystr(p), l))),
        struct)


def make_dist(mesh) -> DistCtx:
    return DistCtx(mesh=mesh, dp_axes=dp_axes_for(mesh), bank_axis="model")


def pad_to(n: int, mult: int) -> int:
    return int(math.ceil(n / mult) * mult)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_cell(arch_id: str, shape_id: str, dist: DistCtx,
             cfg_override=None) -> Cell:
    from repro.models import transformer as T
    spec = get_arch(arch_id)
    cfg: T.LMConfig = cfg_override if cfg_override is not None else spec.config
    cell = SH.get_cell(arch_id, shape_id)
    B, S = cell.dims["batch"], cell.dims["seq"]
    kind = cell.step_kind
    if ROLLED_ONLY:
        pass
    elif cfg_override is None and kind in ("train", "prefill"):
        # dry-run accounting config: unroll scans so cost_analysis counts all
        # iterations; q unchunked + kv chunks <= 2048 keep the unrolled HLO
        # tractable (see LMConfig.unroll)
        cfg = dataclasses.replace(cfg, unroll=True, q_chunk=S,
                                  kv_chunk=min(2048, S))
    elif cfg_override is None and kind == "decode":
        cfg = dataclasses.replace(cfg, unroll=True)

    params_struct = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.key(0)))
    p_sh = R.lm_param_shardings(dist, params_struct)
    params_sds = _attach(params_struct, p_sh)
    dpax = dist.dp_axes if len(dist.dp_axes) > 1 else dist.dp_axes[0]

    if kind == "train":
        loss = lambda p, b: T.lm_loss(cfg, p, b["tokens"], b["labels"], dist)
        opt = default_optimizer()
        step = build_train_step(loss, opt)
        state_struct = jax.eval_shape(
            lambda: TrainState.create(T.init_params(cfg, jax.random.key(0)),
                                      opt))
        st_sh = R.train_state_shardings(dist, state_struct, p_sh)
        state_sds = _attach(state_struct, st_sh)
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        batch_sds = _attach(batch, R.lm_batch_shardings(dist, batch))
        return Cell(arch_id, shape_id, kind, step, (state_sds, batch_sds),
                    dict(tokens=B * S))

    if kind == "prefill":
        fn = lambda p, toks: T.prefill(cfg, p, toks, dist)
        batch = jax.ShapeDtypeStruct(
            (B, S), jnp.int32,
            sharding=jax.sharding.NamedSharding(dist.mesh, P(dpax, None)))
        return Cell(arch_id, shape_id, kind, fn, (params_sds, batch),
                    dict(tokens=B * S))

    # decode: seq-sharded KV. long_500k (B=1) spreads seq over ALL axes.
    if B >= dist.dp_size():
        seq_axes = ("model",)
        batch_gt1 = True
    else:
        seq_axes = tuple(dist.mesh.axis_names)
        batch_gt1 = False
    fn = lambda p, c, t: T.decode_step(cfg, p, c, t, dist, seq_axes=seq_axes)
    cache_struct = jax.eval_shape(lambda: T.KVCache.empty(cfg, B, S))
    cache_sds = _attach(cache_struct,
                        R.kv_cache_shardings(dist, cache_struct,
                                             seq_axes=seq_axes,
                                             batch_gt1=batch_gt1))
    tok = jax.ShapeDtypeStruct(
        (B,), jnp.int32,
        sharding=jax.sharding.NamedSharding(
            dist.mesh, P(dpax) if batch_gt1 else P()))
    return Cell(arch_id, shape_id, "decode", fn,
                (params_sds, cache_sds, tok),
                dict(tokens=B, kv_len=S, seq_axes=seq_axes))


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------

def _recsys_modules(family: str):
    if family == "dlrm":
        from repro.models import dlrm as M
    elif family == "din":
        from repro.models import din as M
    elif family == "bert4rec":
        from repro.models import bert4rec as M
    elif family == "xdeepfm":
        from repro.models import xdeepfm as M
    else:
        raise ValueError(family)
    return M


def _recsys_vocab(cfg, family: str) -> int:
    if family in ("dlrm", "xdeepfm"):
        return cfg.total_vocab
    if family == "din":
        return cfg.total_vocab
    return cfg.vocab  # bert4rec


def _recsys_statics_sds(family: str, cfg, vocab: int, dist: DistCtx,
                        n_banks: int) -> tuple[dict, dict]:
    """(statics SDS arrays replicated, meta ints)."""
    rows = pad_to(vocab, n_banks) // n_banks
    arr = {"remap_bank": jax.ShapeDtypeStruct((vocab,), jnp.int32),
           "remap_slot": jax.ShapeDtypeStruct((vocab,), jnp.int32)}
    if family in ("dlrm", "xdeepfm"):
        arr["field_offsets"] = jax.ShapeDtypeStruct(
            (len(cfg.vocab_sizes),), jnp.int32)
    if family == "din":
        arr["cate_offset"] = jax.ShapeDtypeStruct((), jnp.int32)
    arr = _sds_shard(dist, arr, lambda p, l: P(*([None] * len(l.shape))))
    meta = {"n_banks": n_banks, "rows_per_bank": rows}
    return arr, meta


def _recsys_params_struct(M, family: str, cfg, vocab: int, n_banks: int):
    """eval_shape of init with a shape-only fake plan (no numpy alloc)."""
    from repro.core.partitioning import PartitionPlan
    rows = pad_to(vocab, n_banks) // n_banks
    plan = PartitionPlan(
        n_banks=n_banks,
        bank_of_row=np.zeros(vocab, np.int32),
        slot_of_row=np.zeros(vocab, np.int32),
        rows_per_bank=np.full(n_banks, rows, np.int32),
        load_per_bank=np.ones(n_banks),
    )
    params_struct, statics_struct = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.key(0), plan))
    return params_struct


def _recsys_cell(arch_id: str, shape_id: str, dist: DistCtx) -> Cell:
    spec = get_arch(arch_id)
    cfg = spec.config
    fam = spec.family
    M = _recsys_modules(fam)
    kind, batch_struct = SH.batch_struct(arch_id, shape_id)
    vocab = _recsys_vocab(cfg, fam)
    n_banks = dist.mesh.shape["model"]

    params_struct = _recsys_params_struct(M, fam, cfg, vocab, n_banks)
    p_sh = R.recsys_param_shardings(dist, params_struct)
    params_sds = _attach(params_struct, p_sh)
    statics_sds, meta = _recsys_statics_sds(fam, cfg, vocab, dist, n_banks)

    def with_meta(fn):
        return lambda p, s, b: fn(cfg, p, {**s, **meta}, b, dist)

    if kind == "retrieval":
        # candidate sets spread over every mesh axis -> pad to divisibility
        batch_struct = {
            k: (jax.ShapeDtypeStruct((pad_to(v.shape[0], EDGE_PAD),)
                                     + v.shape[1:], v.dtype)
                if k.startswith("candidate") else v)
            for k, v in batch_struct.items()}
    spread = ("candidate",) if kind == "retrieval" else ()
    batch_sds = _attach(batch_struct,
                        R.recsys_batch_shardings(dist, batch_struct,
                                                 spread_keys=spread))

    if kind == "train":
        loss2 = with_meta(M.loss_fn)
        opt = default_optimizer()
        step0 = build_train_step(lambda p, sb: loss2(p, sb[0], sb[1]), opt)
        step = lambda st, s, b: step0(st, (s, b))
        state_struct = jax.eval_shape(
            lambda: TrainState.create(params_struct_to_zeros(params_struct),
                                      opt))
        st_sh = R.train_state_shardings(dist, state_struct, p_sh)
        state_sds = _attach(state_struct, st_sh)
        return Cell(arch_id, shape_id, kind, step,
                    (state_sds, statics_sds, batch_sds),
                    dict(batch=batch_struct_leading(batch_struct)))

    if kind == "retrieval":
        fn = with_meta(M.retrieval_scores)
    elif fam == "bert4rec":
        fn = with_meta(M.next_item_scores)
    else:
        fn = with_meta(M.forward)
    return Cell(arch_id, shape_id, kind, fn,
                (params_sds, statics_sds, batch_sds),
                dict(batch=batch_struct_leading(batch_struct)))


def params_struct_to_zeros(struct):
    """SDS tree -> zeros tree for tracing optimizer.init inside eval_shape."""
    return jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), struct)


def batch_struct_leading(batch_struct) -> int:
    return int(jax.tree.leaves(batch_struct)[0].shape[0])


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _gat_cell(arch_id: str, shape_id: str, dist: DistCtx) -> Cell:
    from repro.models import gat as G
    spec = get_arch(arch_id)
    cell = SH.get_cell(arch_id, shape_id)
    cfg = SH.gat_config_for_shape(spec.config, cell.dims)
    kind, batch_struct = SH.batch_struct(arch_id, shape_id)

    # pad edge arrays to a mesh-divisible multiple (mask handles the tail)
    def pad_edges(tree):
        out = {}
        for k, v in tree.items():
            if (k.startswith("edge_")
                    or (k.startswith("block")
                        and k.endswith(("_src", "_dst", "_mask")))):
                n = pad_to(v.shape[0], EDGE_PAD)
                out[k] = jax.ShapeDtypeStruct((n,) + v.shape[1:], v.dtype)
            else:
                out[k] = v
        if "edge_src" in out and "edge_mask" not in out:
            out["edge_mask"] = jax.ShapeDtypeStruct(
                out["edge_src"].shape, jnp.bool_)
        return out

    batch_struct = pad_edges(batch_struct)
    batch_sds = _attach(batch_struct, R.gnn_batch_shardings(dist, batch_struct))

    if shape_id == "minibatch_lg":
        loss = lambda p, b: G.loss_blocks(cfg, p, b, dist)
    elif shape_id == "molecule":
        loss = lambda p, b: G.loss_molecule(cfg, p, b, dist)
    else:
        loss = lambda p, b: G.loss_full(cfg, p, b, dist)

    opt = O.adam(1e-3)
    step = build_train_step(loss, opt, clip_norm=None)
    params_struct = jax.eval_shape(lambda: G.init_params(cfg, jax.random.key(0)))
    state_struct = jax.eval_shape(
        lambda: TrainState.create(params_struct_to_zeros(params_struct), opt))
    p_sh = jax.tree.map(
        lambda l: jax.sharding.NamedSharding(dist.mesh,
                                             P(*([None] * len(l.shape)))),
        params_struct)
    st_sh = R.train_state_shardings(dist, state_struct, p_sh)
    state_sds = _attach(state_struct, st_sh)
    return Cell(arch_id, shape_id, "train", step, (state_sds, batch_sds),
                dict())


# ---------------------------------------------------------------------------

def build_cell(arch_id: str, shape_id: str, mesh) -> Cell:
    dist = make_dist(mesh)
    fam = get_arch(arch_id).family
    if fam == "lm":
        return _lm_cell(arch_id, shape_id, dist)
    if fam == "gat":
        return _gat_cell(arch_id, shape_id, dist)
    return _recsys_cell(arch_id, shape_id, dist)
