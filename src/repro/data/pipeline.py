"""Deterministic host-sharded data pipeline with background prefetch.

Every batch is a pure function of (seed, step, host_id), so:
  * restart-from-checkpoint replays the identical stream (fault tolerance),
  * each host generates only its slice of the global batch (no host-side
    all-to-all), matching multi-host TPU input pipelines,
  * elastic rescale (n_hosts changes) re-slices the same global stream.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator


class ShardedLoader:
    def __init__(self, gen: Callable[..., dict], *, global_batch: int,
                 n_hosts: int = 1, host_id: int = 0, seed: int = 0,
                 start_step: int = 0, prefetch: int = 2, **gen_kwargs):
        assert global_batch % n_hosts == 0
        self.gen = gen
        self.local_batch = global_batch // n_hosts
        self.n_hosts = n_hosts
        self.host_id = host_id
        self.seed = seed
        self.step = start_step
        self.gen_kwargs = gen_kwargs
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _make(self, step: int) -> dict:
        # host slice: independent substream per (host, step)
        return self.gen(batch=self.local_batch,
                        seed=self.seed * 1_000_003 + self.host_id,
                        step=step, **self.gen_kwargs)

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        try:
            while True:
                yield self._q.get()
        finally:
            self._stop.set()

    def take(self, n: int) -> list[tuple[int, dict]]:
        """Synchronous helper (tests/benches): n batches without the thread."""
        return [(s, self._make(s)) for s in range(self.step, self.step + n)]
