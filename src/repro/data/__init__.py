"""Data substrate: synthetic workload generators (paper Table 1 profiles) and
the deterministic host-sharded pipeline."""
