"""Synthetic workload generators.

``WORKLOADS`` mirrors the paper's Table 1: six datasets in three hotness tiers
with the published average reduction (multi-hot bag size) and item counts.
Popularity is Zipf-distributed with the tier controlling the exponent —
calibrated so the hottest/coldest row-block ratio spans the paper's reported
skew (up to 340x, Fig. 5).

Every generator is deterministic in (seed, step) so a restarted job replays
the exact same stream (fault-tolerance requirement, DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    name: str
    avg_reduction: float
    n_items: int
    zipf_a: float          # popularity exponent (higher => hotter)
    tier: str


# paper Table 1 (avg reduction + #items verbatim; zipf_a per tier)
WORKLOADS = {
    "clo":   WorkloadProfile("AmazonClothes", 52.91, 2_685_059, 0.60, "low"),
    "home":  WorkloadProfile("AmazonHome", 67.56, 1_301_225, 0.65, "low"),
    "meta1": WorkloadProfile("MetaFBGEMM1", 107.2, 5_783_210, 0.90, "medium"),
    "meta2": WorkloadProfile("MetaFBGEMM2", 188.6, 5_999_981, 0.95, "medium"),
    "read":  WorkloadProfile("GoodReads", 245.8, 2_360_650, 1.18, "high"),
    "read2": WorkloadProfile("GoodReads2", 374.08, 2_360_650, 1.22, "high"),
}


def zipf_popularity(n_items: int, a: float, rng: np.random.Generator
                    ) -> np.ndarray:
    """Normalized Zipf pmf over a random permutation of item ids (hot items
    are scattered across the id space, like real catalogs)."""
    ranks = np.arange(1, n_items + 1, dtype=np.float64)
    p = ranks ** (-a)
    p /= p.sum()
    perm = rng.permutation(n_items)
    out = np.empty(n_items)
    out[perm] = p
    return out


def multihot_trace(profile: WorkloadProfile, n_samples: int, *, seed: int = 0,
                   n_items: int | None = None) -> list[np.ndarray]:
    """Bags of item ids: |bag| ~ Poisson(avg_reduction), items ~ Zipf."""
    rng = np.random.default_rng(seed)
    n = n_items or profile.n_items
    p = zipf_popularity(n, profile.zipf_a, rng)
    sizes = np.maximum(1, rng.poisson(profile.avg_reduction, n_samples))
    return [rng.choice(n, size=s, p=p) for s in sizes]


def padded_bags(trace: list[np.ndarray], pad_to: int) -> np.ndarray:
    out = np.full((len(trace), pad_to), -1, dtype=np.int32)
    for i, bag in enumerate(trace):
        b = bag[:pad_to]
        out[i, :len(b)] = b
    return out


# ---------------------------------------------------------------------------
# per-family batch generators (all static-shape, -1 padded)
# ---------------------------------------------------------------------------

def lm_batch(batch: int, seq: int, vocab: int, *, seed: int, step: int) -> dict:
    rng = np.random.default_rng((seed, step))
    toks = rng.integers(0, vocab, (batch, seq), dtype=np.int32)
    labels = np.roll(toks, -1, axis=1)
    return {"tokens": toks, "labels": labels}


def dlrm_batch(vocab_sizes, n_dense: int, batch: int, *, seed: int, step: int,
               multi_hot: int = 1, zipf_a: float = 0.9) -> dict:
    rng = np.random.default_rng((seed, step))
    F = len(vocab_sizes)
    if multi_hot == 1:
        sparse = np.stack([rng.integers(0, v, batch) for v in vocab_sizes],
                          axis=1).astype(np.int32)
    else:
        sparse = np.stack(
            [rng.integers(0, v, (batch, multi_hot)) for v in vocab_sizes],
            axis=1).astype(np.int32)
    return {
        "dense": rng.standard_normal((batch, n_dense)).astype(np.float32),
        "sparse": sparse,
        "label": rng.integers(0, 2, batch).astype(np.float32),
    }


def din_batch(n_items: int, n_cates: int, seq_len: int, batch: int, *,
              seed: int, step: int) -> dict:
    rng = np.random.default_rng((seed, step))
    hist = rng.integers(0, n_items, (batch, seq_len)).astype(np.int32)
    lens = rng.integers(seq_len // 4, seq_len + 1, batch)
    mask = np.arange(seq_len)[None, :] < lens[:, None]
    hist = np.where(mask, hist, -1).astype(np.int32)
    cates = np.where(mask, rng.integers(0, n_cates, (batch, seq_len)), -1)
    return {
        "hist_items": hist,
        "hist_cates": cates.astype(np.int32),
        "target_item": rng.integers(0, n_items, batch).astype(np.int32),
        "target_cate": rng.integers(0, n_cates, batch).astype(np.int32),
        "label": rng.integers(0, 2, batch).astype(np.float32),
    }


def bert4rec_batch(n_items: int, seq_len: int, batch: int, *, seed: int,
                   step: int, mask_rate: float = 0.15,
                   n_negatives: int = 0) -> dict:
    rng = np.random.default_rng((seed, step))
    items = rng.integers(0, n_items, (batch, seq_len)).astype(np.int32)
    sel = rng.random((batch, seq_len)) < mask_rate
    sel[:, -1] = True  # always at least one target
    labels = np.where(sel, items, -100).astype(np.int32)
    masked = np.where(sel, n_items, items).astype(np.int32)  # mask token id
    out = {"items": masked, "labels": labels}
    if n_negatives:
        out["negatives"] = rng.integers(0, n_items,
                                        n_negatives).astype(np.int32)
    return out


def xdeepfm_batch(vocab_sizes, batch: int, *, seed: int, step: int) -> dict:
    rng = np.random.default_rng((seed, step))
    sparse = np.stack([rng.integers(0, v, batch) for v in vocab_sizes],
                      axis=1).astype(np.int32)
    return {"sparse": sparse,
            "label": rng.integers(0, 2, batch).astype(np.float32)}


# ---------------------------------------------------------------------------
# graphs
# ---------------------------------------------------------------------------

def random_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int, *,
                 seed: int = 0, power_law: bool = True) -> dict:
    """Cora/products-like: power-law degree distribution + self loops."""
    rng = np.random.default_rng(seed)
    if power_law:
        w = zipf_popularity(n_nodes, 0.9, rng)
        src = rng.choice(n_nodes, n_edges, p=w)
        dst = rng.choice(n_nodes, n_edges, p=w)
    else:
        src = rng.integers(0, n_nodes, n_edges)
        dst = rng.integers(0, n_nodes, n_edges)
    return {
        "features": rng.standard_normal((n_nodes, d_feat)).astype(np.float32),
        "edge_src": src.astype(np.int32),
        "edge_dst": dst.astype(np.int32),
        "labels": rng.integers(0, n_classes, n_nodes).astype(np.int32),
        "label_mask": (rng.random(n_nodes) < 0.5),
    }


def molecule_batch(n_graphs: int, nodes_per: int, edges_per: int, d_feat: int,
                   n_classes: int, *, seed: int = 0, step: int = 0) -> dict:
    """Block-diagonal batched small graphs."""
    rng = np.random.default_rng((seed, step))
    N = n_graphs * nodes_per
    src = (rng.integers(0, nodes_per, (n_graphs, edges_per))
           + np.arange(n_graphs)[:, None] * nodes_per).reshape(-1)
    dst = (rng.integers(0, nodes_per, (n_graphs, edges_per))
           + np.arange(n_graphs)[:, None] * nodes_per).reshape(-1)
    return {
        "features": rng.standard_normal((N, d_feat)).astype(np.float32),
        "edge_src": src.astype(np.int32),
        "edge_dst": dst.astype(np.int32),
        "graph_ids": np.repeat(np.arange(n_graphs), nodes_per).astype(np.int32),
        "labels": rng.integers(0, n_classes, n_graphs).astype(np.int32),
    }
