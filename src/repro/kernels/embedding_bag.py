"""Pallas TPU kernels: embedding-bag gather+reduce "near memory".

TPU adaptation of the paper's in-DPU lookup (DESIGN.md §5, paper §3.1/Fig. 7).
The table(s) stay in HBM (`pltpu.ANY`); bag indices and the row->(bank, slot)
remap vectors are scalar-prefetched (SMEM) so the kernel can compute HBM row
addresses *before* touching vector memory; rows stream HBM->VMEM through an
N-slot rotation of `pltpu.make_async_copy` DMAs (`n_slots`, default 2 =
classic ping-pong: up to N-1 copies are in flight while entry e is being
accumulated — the pipeline depth the autotuner sweeps). Each grid step owns a tile of
bags and writes only the reduced (tile_b, D) block — the (B*L, D) gathered
matrix a naive XLA gather would materialize never exists.

What runs inside the kernel (vs. the seed's wrapper-side precompute):
  * per-field row offsets      — bag b belongs to field b % n_fields; its raw
    ids are shifted by `field_offsets[f]`, so ALL F sparse fields of a DLRM
    batch are one kernel invocation over (B*F, L) bags
  * bank/slot remap + ownership mask — the PIM stage-2 test `bank[row] == my`
    happens on the prefetched scalars; foreign rows cost no DMA bandwidth to
    accumulate (they are masked), and the wrapper no longer materializes a
    masked index tensor per bank
  * fused cache + residual     — one accumulator walks the cache-entry stream
    then the residual stream (Fig. 7's `Σ cache_partials + Σ residual_rows`)

Ownership is disabled by passing ``my_bank < 0`` (the unsharded path).

The TRAINING BACKWARD lives here too: ``ct_scatter_bag_pallas`` /
``ct_scatter_csr_pallas`` scatter-add the bag cotangents back onto the bank's
rows with the same double-buffered row DMA (cotangents in, accumulated rows
out) — slot collisions are resolved by a slot-sorted permutation computed in
the traced prep, never by atomics (see the backward section below).

Alignment: D is padded to the 128-lane boundary by the wrappers (the TPU
analogue of the paper's 8-byte MRAM alignment rule); each row copy is one
(1, D) DMA — the ``N_c``-wide access of §3.1 with TPU constants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# double-buffered row-DMA accumulate
# ---------------------------------------------------------------------------

def _dma_accumulate(acc, table_ref, buf, sem, start, end, src_fn, meta_fn,
                    row_fn=None):
    """Accumulate table rows for entries [start, end) into per-bag sums.

    ``src_fn(e)``  -> local table row to fetch (already ownership-clamped)
    ``meta_fn(e)`` -> (bag_local, mine) — accumulator row and validity mask
    ``row_fn(e, raw)`` -> fp32 accumulator row from the DMA'd raw row
    (default: a plain fp32 cast; the tiered kernel dequantizes here).

    N-deep rotation over ``buf.shape[0]`` (1, D) VMEM slots: up to N row
    DMAs are in flight at once — the copy for entry e+N-1 is started before
    waiting on entry e, so N-1 HBM fetches overlap the VPU accumulate of the
    current row. The slot count is carried by the scratch SHAPE (see
    ``_scratch``), so the kernels need no extra parameter; N=2 is the
    classic ping-pong and traces the exact pre-N-slot graph. Slot reuse is
    hazard-free by construction: entry e+N-1's slot was last used by entry
    e-1, whose value was consumed (and semaphore waited) one iteration ago.
    """
    n_slots = buf.shape[0]

    def dma(e, slot):
        return pltpu.make_async_copy(
            table_ref.at[pl.ds(src_fn(e), 1), :], buf.at[slot], sem.at[slot])

    for k in range(n_slots - 1):
        @pl.when(start + k < end)
        def _(k=k):
            dma(start + k, k).start()

    def body(e, acc):
        slot = (e - start) % n_slots

        @pl.when(e + (n_slots - 1) < end)
        def _():
            dma(e + n_slots - 1, (slot + n_slots - 1) % n_slots).start()

        dma(e, slot).wait()
        bag_local, mine = meta_fn(e)
        raw = buf[slot][0]
        val = raw.astype(jnp.float32) if row_fn is None else row_fn(e, raw)
        row = jnp.where(mine, val, 0.0)
        return acc.at[bag_local].add(row)

    return jax.lax.fori_loop(start, end, body, acc)


def wang_hash(x: jax.Array) -> jax.Array:
    """Wang's 32-bit integer mix — the cheap deterministic in-kernel hash
    (a handful of shifts/xors/mults, no tables). Shared by the kernels and
    the jnp fallbacks so the replica pick ``wang_hash(bag) % k_max`` is
    bit-identical across backends."""
    x = x.astype(jnp.uint32)
    x = (x ^ jnp.uint32(61)) ^ (x >> 16)
    x = x * jnp.uint32(9)
    x = x ^ (x >> 4)
    x = x * jnp.uint32(0x27D4EB2D)
    x = x ^ (x >> 15)
    return x


def replica_of_bag(bag: jax.Array, k_max: int) -> jax.Array:
    """Replica column for a (global) bag id: hash so consecutive bags spread
    across copies, mod into [0, k_max)."""
    return (wang_hash(bag) % jnp.uint32(k_max)).astype(jnp.int32)


def _entry_fns(idx_ref, bank_ref, slot_ref, off_ref, my, b0, bag_len,
               n_fields, k_max: int = 1):
    """(src_fn, meta_fn) for a rectangular (bags x bag_len) index stream with
    in-kernel field offsets, remap, and ownership mask. ``e`` is the
    tile-LOCAL entry id in [0, tile_b * bag_len).

    ``k_max > 1`` is the replicated-table path: bank/slot are the FLATTENED
    ``(vocab * k_max,)`` replica-axis remap, and each bag reads copy
    ``wang_hash(bag) % k_max`` of every row it touches — replicas split a
    hot row's traffic with no host-side routing. ``k_max == 1`` traces the
    exact single-copy path (no hash in the graph).
    """
    def resolve(e):
        bag = b0 + e // bag_len
        raw = idx_ref[bag * bag_len + e % bag_len]
        valid = raw >= 0
        row = jnp.where(valid, raw + off_ref[bag % n_fields], 0)
        if k_max > 1:
            row = row * k_max + replica_of_bag(bag, k_max)
        mine = valid & ((my < 0) | (bank_ref[row] == my))
        return row, mine

    def src_fn(e):
        row, mine = resolve(e)
        return jnp.where(mine, slot_ref[row], 0)

    def meta_fn(e):
        _, mine = resolve(e)
        return e // bag_len, mine

    return src_fn, meta_fn


def _plain_entry_fns(idx_ref, b0, bag_len):
    """(src_fn, meta_fn) for an identity-mapped index stream — no remap
    vectors, no ownership test (the single-table drop-in wrappers)."""
    def resolve(e):
        raw = idx_ref[(b0 + e // bag_len) * bag_len + e % bag_len]
        return jnp.maximum(raw, 0), raw >= 0

    def src_fn(e):
        return resolve(e)[0]

    def meta_fn(e):
        return e // bag_len, resolve(e)[1]

    return src_fn, meta_fn


# ---------------------------------------------------------------------------
# padding helpers (shared by ops.py and core/embedding.py — ONE home for the
# 128-lane alignment rule and the -1 bag fill)
# ---------------------------------------------------------------------------

def effective_lengths(idx: jax.Array) -> jax.Array:
    """(B, L) -1-padded bags -> (B,) int32 count through the LAST valid
    entry (1 + its position; 0 for all-pad bags). Interior -1 holes are kept
    inside the walk — the in-kernel validity mask still skips them — so the
    early exit is exact for any padding pattern, suffix or not."""
    valid = idx >= 0
    last = idx.shape[1] - jnp.argmax(valid[:, ::-1], axis=1)
    return jnp.where(valid.any(axis=1), last, 0).astype(jnp.int32)


def pad_last_dim(x: jax.Array, mult: int = 128) -> tuple[jax.Array, int]:
    """Pad the trailing dim to a multiple (TPU lane alignment, §3.1 rule)."""
    d = x.shape[-1]
    pad = (-d) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, d


def pad_leading(x: jax.Array, mult: int, fill=-1) -> tuple[jax.Array, int]:
    """Pad the leading dim to a multiple with ``fill`` (-1 = padded bags)."""
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
    return x, n


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _plain_bag_kernel(idx_ref, table_ref, out_ref, buf, sem, *,
                      tile_b: int, bag_len: int, dim: int):
    b0 = pl.program_id(0) * tile_b
    src_fn, meta_fn = _plain_entry_fns(idx_ref, b0, bag_len)
    acc = jnp.zeros((tile_b, dim), jnp.float32)
    acc = _dma_accumulate(acc, table_ref, buf, sem, 0, tile_b * bag_len,
                          src_fn, meta_fn)
    out_ref[...] = acc.astype(out_ref.dtype)


def _plain_fused_kernel(cache_idx_ref, resid_idx_ref, c_len_ref, r_len_ref,
                        cache_ref, emt_ref, out_ref, buf, sem, *,
                        tile_b: int, lc: int, lr: int, dim: int):
    b0 = pl.program_id(0) * tile_b
    acc = jnp.zeros((tile_b, dim), jnp.float32)
    c_src, c_meta = _plain_entry_fns(cache_idx_ref, b0, lc)
    r_src, r_meta = _plain_entry_fns(resid_idx_ref, b0, lr)
    # per-bag early exit on the prefetched effective lengths (CSR-style):
    # the walk stops at each bag's last valid entry instead of masked-
    # accumulating the full L — all-pad bags cost zero DMAs
    for i in range(tile_b):
        acc = _dma_accumulate(acc, cache_ref, buf, sem, i * lc,
                              i * lc + c_len_ref[b0 + i], c_src, c_meta)
        acc = _dma_accumulate(acc, emt_ref, buf, sem, i * lr,
                              i * lr + r_len_ref[b0 + i], r_src, r_meta)
    out_ref[...] = acc.astype(out_ref.dtype)


def _banked_bag_kernel(idx_ref, bank_ref, slot_ref, off_ref, my_ref,
                       table_ref, out_ref, buf, sem, *,
                       tile_b: int, bag_len: int, n_fields: int, dim: int,
                       k_max: int = 1):
    b0 = pl.program_id(0) * tile_b
    src_fn, meta_fn = _entry_fns(idx_ref, bank_ref, slot_ref, off_ref,
                                 my_ref[0], b0, bag_len, n_fields,
                                 k_max=k_max)
    acc = jnp.zeros((tile_b, dim), jnp.float32)
    acc = _dma_accumulate(acc, table_ref, buf, sem, 0, tile_b * bag_len,
                          src_fn, meta_fn)
    out_ref[...] = acc.astype(out_ref.dtype)


def _fused_cache_bag_kernel(cache_idx_ref, resid_idx_ref, c_len_ref,
                            r_len_ref, c_bank_ref, c_slot_ref, r_bank_ref,
                            r_slot_ref, my_ref, zero_off_ref, cache_ref,
                            emt_ref, out_ref, buf, sem, *, tile_b: int,
                            lc: int, lr: int, dim: int):
    """Fig. 7 fused lookup: Σ cache partial-sums + Σ residual EMT rows, one
    accumulator, one output write. Both streams run through the same
    ping-pong buffers; each bag's walk ends at its prefetched effective
    length (c_len/r_len — trailing -1 padding trimmed, CSR-style), so short
    bags in a long-L batch stop early instead of masked-accumulating L."""
    b0 = pl.program_id(0) * tile_b
    my = my_ref[0]
    acc = jnp.zeros((tile_b, dim), jnp.float32)
    c_src, c_meta = _entry_fns(cache_idx_ref, c_bank_ref, c_slot_ref,
                               zero_off_ref, my, b0, lc, 1)
    r_src, r_meta = _entry_fns(resid_idx_ref, r_bank_ref, r_slot_ref,
                               zero_off_ref, my, b0, lr, 1)
    for i in range(tile_b):
        acc = _dma_accumulate(acc, cache_ref, buf, sem, i * lc,
                              i * lc + c_len_ref[b0 + i], c_src, c_meta)
        acc = _dma_accumulate(acc, emt_ref, buf, sem, i * lr,
                              i * lr + r_len_ref[b0 + i], r_src, r_meta)
    out_ref[...] = acc.astype(out_ref.dtype)


def _tiered_bag_kernel(idx_ref, bank_ref, slot_ref, off_ref, my_ref,
                       tier_ref, scale_ref, payload_ref, out_ref, buf, sem, *,
                       tile_b: int, bag_len: int, n_fields: int, dim: int,
                       hot_dtype: str):
    """Banked bag sums over a TIERED byte payload, dequant in-kernel.

    Identical dataflow to ``_banked_bag_kernel`` except the table is the
    quant package's ``(R, row_bytes)`` int8 payload: each DMA moves one
    row's byte slot HBM->VMEM, and the accumulate step dequantizes it to
    fp32 on the fly using the row's ``tier`` and ``scale`` — both
    scalar-prefetched alongside the remap stream, so the dequant parameters
    are known from SMEM before the row's bytes land. The fp32 dequant math
    is ``quant.quantize.dequant_rows_f32``, the SAME function the jnp
    fallback runs, which is what makes kernel-vs-fallback parity bit-exact.

    ``scale_ref`` carries fp32 scales BITCAST to int32 (the scalar-prefetch
    stream stays integer-typed like the remap vectors); the kernel bitcasts
    each scalar back.
    """
    from repro.quant.quantize import dequant_rows_f32
    b0 = pl.program_id(0) * tile_b
    src_fn, meta_fn = _entry_fns(idx_ref, bank_ref, slot_ref, off_ref,
                                 my_ref[0], b0, bag_len, n_fields)

    def row_fn(e, raw):
        s = src_fn(e)
        scale = jax.lax.bitcast_convert_type(scale_ref[s], jnp.float32)
        return dequant_rows_f32(raw, scale, tier_ref[s], dim, hot_dtype)

    acc = jnp.zeros((tile_b, dim), jnp.float32)
    acc = _dma_accumulate(acc, payload_ref, buf, sem, 0, tile_b * bag_len,
                          src_fn, meta_fn, row_fn=row_fn)
    out_ref[...] = acc.astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# backward: sorted-run scatter-add (the transpose of the bag sum, in-kernel)
# ---------------------------------------------------------------------------
#
# The training backward streams each bag's cotangent row back onto every
# owned table slot its entries touched. A naive near-memory scatter would
# race whenever two entries of one tile share a slot (duplicate ids inside a
# bag, or across bags of the same tile); TPUs have no HBM atomics. Instead
# the traced prep walks the same (bank, slot, ownership, offsets) metadata
# as the forward to label every entry with its destination slot, sorts the
# entry stream by that slot, and hands the kernel scalar-prefetched views of
# the sorted order:
#
#   bag_sorted  (E,)    cotangent row (bag id) per sorted position
#   run_of      (E,)    run id per sorted position — a "run" is a maximal
#                       group of entries sharing one destination slot
#   run_starts  (S+1,)  first sorted position of each run; empty tail runs
#                       collapse to [n_valid, n_valid)
#   run_slot    (S,)    destination table row of each run
#   n_run       (1,)    number of live runs
#
# Every slot is touched by exactly ONE run and each grid step owns whole
# runs, so tiles never write the same output row — collision resolution
# costs a sort, not atomics. Within a tile, colliding entries accumulate
# into a (tile_s, D) fp32 VMEM accumulator (one row per run) while their
# cotangent rows stream in through the same two-slot DMA ping-pong as the
# forward; the finished rows stream OUT through a second ping-pong,
# overlapping the write-back of run i with the staging of run i+1.
# Untouched table rows must stay zero, so the d_table output is
# input_output_aliased to a zeros array.
#
# The kernel reads only arrays DERIVED from the sort permutation
# (bag_sorted = bags[perm], run_slot = dest[perm][starts]), never the raw
# ``argsort`` output itself: element-wise loads of an argsort result from
# inside the grid loop miscompile on XLA CPU for SPMD partitions > 0 (the
# shard_map path of this very backward; jax 0.4.x host platform), while
# vectorized gathers of the same permutation are fine — so the permutation
# is applied once in the prep and only its products cross into SMEM.

def scatter_run_metadata(dest: jax.Array, bags: jax.Array, n_rows: int,
                         n_runs_pad: int) -> tuple[jax.Array, ...]:
    """Slot-sorted scatter metadata (the backward kernel's prep stage).

    ``dest`` (E,) int32 holds each entry's destination table slot, or any
    value >= ``n_rows`` for entries that scatter nothing (-1 padding,
    foreign-bank rows); ``bags`` (E,) the cotangent row each entry drags in.
    Returns ``(bag_sorted, run_of, run_starts, run_slot, n_run)`` with the
    run axis padded to ``n_runs_pad`` (>= E, so the grid tiles it
    statically). Entry order is preserved within a run (stable sort) — the
    scatter accumulates per slot in the same order as the XLA fallback,
    which is what makes fp32 parity bit-exact.
    """
    E = dest.shape[0]
    assert n_runs_pad >= E, (n_runs_pad, E)
    perm = jnp.argsort(dest, stable=True).astype(jnp.int32)
    sd = jnp.take(dest, perm)
    bag_sorted = jnp.take(bags, perm).astype(jnp.int32)
    live = sd < n_rows
    n_valid = live.sum().astype(jnp.int32)
    prev = jnp.concatenate([jnp.full((1,), -1, sd.dtype), sd[:-1]])
    new_run = (sd != prev) & live
    n_run = new_run.sum().astype(jnp.int32)
    run_of = jnp.clip(jnp.cumsum(new_run) - 1, 0, None).astype(jnp.int32)
    starts = jnp.sort(jnp.where(new_run, jnp.arange(E, dtype=jnp.int32), E))
    pad = jnp.full((n_runs_pad + 1 - E,), E, jnp.int32)
    run_starts = jnp.minimum(jnp.concatenate([starts, pad]), n_valid)
    # dead runs get an in-bounds row; the n_run guard skips their write
    run_slot = jnp.minimum(sd, n_rows - 1)[
        jnp.minimum(run_starts[:-1], E - 1)].astype(jnp.int32)
    return bag_sorted, run_of, run_starts, run_slot, n_run.reshape(1)


def _ct_scatter_kernel(bag_sorted_ref, run_of_ref, run_starts_ref,
                       run_slot_ref, n_run_ref, ct_ref, dtab_in_ref,
                       dtab_ref, in_buf, in_sem, out_buf, out_sem, *,
                       tile_s: int, dim: int):
    """Grid step t owns runs [s0, s0 + tile_s): stream the runs' cotangent
    rows in (double-buffered), accumulate per run in fp32, stream the
    finished rows out to their table slots (double-buffered). Validity and
    ownership were folded into run membership by the prep sort, so every
    walked entry scatters. ``dtab_in_ref`` is the aliased zeros input — the
    kernel writes through ``dtab_ref`` only."""
    del dtab_in_ref
    s0 = pl.program_id(0) * tile_s
    n_run = n_run_ref[0]

    acc = jnp.zeros((tile_s, dim), jnp.float32)
    acc = _dma_accumulate(acc, ct_ref, in_buf, in_sem,
                          run_starts_ref[s0], run_starts_ref[s0 + tile_s],
                          lambda p: bag_sorted_ref[p],
                          lambda p: (run_of_ref[p] - s0, True))

    # accumulated-row DMA out: two-slot ping-pong (run i's copy is in
    # flight while run i+1's row is staged). Runs are packed to the front
    # globally, so 'run s is live' is the prefix test s < n_run — start and
    # wait guards agree by construction and the semaphores stay balanced.
    def dma(i, slot):
        return pltpu.make_async_copy(
            out_buf.at[slot], dtab_ref.at[pl.ds(run_slot_ref[s0 + i], 1), :],
            out_sem.at[slot])

    for i in range(tile_s):
        slot = i % 2
        if i >= 2:
            @pl.when(s0 + i - 2 < n_run)
            def _(i=i, slot=slot):
                dma(i - 2, slot).wait()

        @pl.when(s0 + i < n_run)
        def _(i=i, slot=slot):
            out_buf[slot] = acc[i][None].astype(out_buf.dtype)
            dma(i, slot).start()

    for i in range(max(tile_s - 2, 0), tile_s):
        @pl.when(s0 + i < n_run)
        def _(i=i):
            dma(i, i % 2).wait()


def _csr_bag_kernel(idx_ref, seg_ref, offs_ref, bank_ref, slot_ref, my_ref,
                    table_ref, out_ref, buf, sem, *, tile_b: int, dim: int):
    """CSR-ragged bags: entries for bags [b0, b0+tile_b) are the contiguous
    index range [offs[b0], offs[b0+tile_b]); per-entry bag = seg[e]."""
    b0 = pl.program_id(0) * tile_b
    my = my_ref[0]

    def resolve(e):
        raw = idx_ref[e]
        valid = raw >= 0
        row = jnp.where(valid, raw, 0)
        mine = valid & ((my < 0) | (bank_ref[row] == my))
        return row, mine

    def src_fn(e):
        row, mine = resolve(e)
        return jnp.where(mine, slot_ref[row], 0)

    def meta_fn(e):
        _, mine = resolve(e)
        return seg_ref[e] - b0, mine

    acc = jnp.zeros((tile_b, dim), jnp.float32)
    acc = _dma_accumulate(acc, table_ref, buf, sem,
                          offs_ref[b0], offs_ref[b0 + tile_b],
                          src_fn, meta_fn)
    out_ref[...] = acc.astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers (shape plumbing only — padding stays in the callers)
# ---------------------------------------------------------------------------

def _scratch(dim: int, dtype, n_slots: int = 2):
    """Row-DMA scratch: ``n_slots`` (1, dim) VMEM slots + matching DMA
    semaphores. ``_dma_accumulate`` reads the pipeline depth off the buffer
    shape, so this is the single knob the autotuner turns."""
    assert n_slots >= 1, n_slots
    return [pltpu.VMEM((n_slots, 1, dim), dtype),
            pltpu.SemaphoreType.DMA((n_slots,))]


def banked_embedding_bag_pallas(table: jax.Array, bank: jax.Array,
                                slot: jax.Array, field_offsets: jax.Array,
                                my_bank: jax.Array, idx: jax.Array, *,
                                tile_b: int = 8, interpret: bool = False,
                                k_max: int = 1, n_slots: int = 2
                                ) -> jax.Array:
    """One bank's stage-2 partial bag sums, remap + mask in-kernel.

    table (R, D) local rows in HBM; bank/slot (V,) int32 remap (prefetched);
    field_offsets (F,) int32; my_bank (1,) int32 (< 0 disables the ownership
    test); idx (NB, L) int32 raw per-field ids, -1 padded. -> (NB, D).

    ``k_max > 1`` serves a REPLICATED table: bank/slot are the flattened
    ``(V * k_max,)`` replica-axis remap and each bag's reads resolve through
    replica column ``wang_hash(bag) % k_max`` (see ``_entry_fns``); the
    kernel body is otherwise unchanged — same prefetch streams, same DMA
    ping-pong, one extra SMEM index multiply per entry.
    """
    NB, L = idx.shape
    R, D = table.shape
    assert NB % tile_b == 0, (NB, tile_b)
    kernel = functools.partial(
        _banked_bag_kernel, tile_b=tile_b, bag_len=L,
        n_fields=field_offsets.shape[0], dim=D, k_max=k_max)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(NB // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((tile_b, D), lambda b, *_: (b, 0)),
        scratch_shapes=_scratch(D, table.dtype, n_slots),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((NB, D), table.dtype),
        interpret=interpret,
    )(idx.reshape(-1), bank, slot, field_offsets, my_bank, table)


def tiered_embedding_bag_pallas(payload: jax.Array, scale_bits: jax.Array,
                                tier: jax.Array, bank: jax.Array,
                                slot: jax.Array, field_offsets: jax.Array,
                                my_bank: jax.Array, idx: jax.Array, *,
                                dim: int, hot_dtype: str = "bf16",
                                tile_b: int = 8, interpret: bool = False,
                                n_slots: int = 2) -> jax.Array:
    """One bank's stage-2 partial bag sums over a TIERED byte payload.

    payload (R, row_bytes) int8 rows in HBM (each DMA slot is sized for the
    HOT tier's width — quantized rows use a prefix of it, packed int4 a
    quarter); scale_bits (R,) int32 = fp32 per-row scales bitcast for the
    scalar-prefetch stream; tier (R,) int32 tier codes; bank/slot (V,) the
    remap; idx (NB, L) raw per-field ids, -1 padded. -> (NB, dim) fp32.
    """
    NB, L = idx.shape
    assert NB % tile_b == 0, (NB, tile_b)
    kernel = functools.partial(
        _tiered_bag_kernel, tile_b=tile_b, bag_len=L,
        n_fields=field_offsets.shape[0], dim=dim, hot_dtype=hot_dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(NB // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((tile_b, dim), lambda b, *_: (b, 0)),
        scratch_shapes=_scratch(payload.shape[-1], payload.dtype, n_slots),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((NB, dim), jnp.float32),
        interpret=interpret,
    )(idx.reshape(-1), bank, slot, field_offsets, my_bank, tier, scale_bits,
      payload)


def embedding_bag_pallas(table: jax.Array, idx: jax.Array, *,
                         tile_b: int = 8, interpret: bool = False,
                         n_slots: int = 2) -> jax.Array:
    """Plain bag sum: table (V, D); idx (B, L) -1 padded -> (B, D).

    Remap-free variant: rows are table positions, so no (V,)-sized scalar
    operands hit SMEM — any vocab size works on real TPUs.
    """
    B, L = idx.shape
    V, D = table.shape
    assert B % tile_b == 0, (B, tile_b)
    kernel = functools.partial(_plain_bag_kernel, tile_b=tile_b, bag_len=L,
                               dim=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((tile_b, D), lambda b, *_: (b, 0)),
        scratch_shapes=_scratch(D, table.dtype, n_slots),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(idx.reshape(-1), table)


def plain_cache_bag_pallas(emt: jax.Array, cache: jax.Array,
                           cache_idx: jax.Array, residual_idx: jax.Array, *,
                           tile_b: int = 8, interpret: bool = False,
                           n_slots: int = 2) -> jax.Array:
    """Fig.-7 fused lookup over unbanked tables (identity layout): no remap
    operands in SMEM. -> (B, D) = Σ cached partials + Σ residual rows."""
    B, Lc = cache_idx.shape
    B2, Lr = residual_idx.shape
    assert B == B2 and B % tile_b == 0, (B, B2, tile_b)
    D = emt.shape[1]
    assert cache.shape[1] == D
    cache = cache.astype(emt.dtype)     # one scratch buffer, one row dtype
    kernel = functools.partial(_plain_fused_kernel, tile_b=tile_b, lc=Lc,
                               lr=Lr, dim=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((tile_b, D), lambda b, *_: (b, 0)),
        scratch_shapes=_scratch(D, emt.dtype, n_slots),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), emt.dtype),
        interpret=interpret,
    )(cache_idx.reshape(-1), residual_idx.reshape(-1),
      effective_lengths(cache_idx), effective_lengths(residual_idx),
      cache, emt)


def fused_cache_bag_pallas(emt: jax.Array, cache: jax.Array,
                           emt_bank: jax.Array, emt_slot: jax.Array,
                           cache_bank: jax.Array, cache_slot: jax.Array,
                           my_bank: jax.Array, cache_idx: jax.Array,
                           residual_idx: jax.Array, *, tile_b: int = 8,
                           interpret: bool = False,
                           n_slots: int = 2) -> jax.Array:
    """emt (R, D), cache (Rc, D); cache_idx (B, Lc), residual_idx (B, Lr)
    (-1 padded) -> (B, D) = Σ cached partials + Σ residual rows, one pass."""
    B, Lc = cache_idx.shape
    B2, Lr = residual_idx.shape
    assert B == B2 and B % tile_b == 0, (B, B2, tile_b)
    D = emt.shape[1]
    assert cache.shape[1] == D
    cache = cache.astype(emt.dtype)     # one scratch buffer, one row dtype
    kernel = functools.partial(_fused_cache_bag_kernel, tile_b=tile_b,
                               lc=Lc, lr=Lr, dim=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=10,
        grid=(B // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((tile_b, D), lambda b, *_: (b, 0)),
        scratch_shapes=_scratch(D, emt.dtype, n_slots),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), emt.dtype),
        interpret=interpret,
    )(cache_idx.reshape(-1), residual_idx.reshape(-1),
      effective_lengths(cache_idx), effective_lengths(residual_idx),
      cache_bank, cache_slot, emt_bank, emt_slot, my_bank,
      jnp.zeros((1,), jnp.int32), cache, emt)


def _scatter_scratch(dim: int, ct_dtype, out_dtype, n_slots: int = 2):
    """Backward scratch: the cotangent INPUT stream shares the N-slot
    ``_dma_accumulate`` pipeline, but the accumulated-row OUTPUT ping-pong in
    ``_ct_scatter_kernel`` is hard-coded two-deep (its start/wait guards are
    written against slot reuse at distance 2), so that pair stays (2, ...)."""
    assert n_slots >= 1, n_slots
    return [pltpu.VMEM((n_slots, 1, dim), ct_dtype),
            pltpu.SemaphoreType.DMA((n_slots,)),
            pltpu.VMEM((2, 1, dim), out_dtype),
            pltpu.SemaphoreType.DMA((2,))]


def _dest_slots(row: jax.Array, valid: jax.Array, bank: jax.Array,
                slot: jax.Array, my_bank: jax.Array,
                n_rows: int) -> jax.Array:
    """The race-freedom invariant, in ONE place: an entry scatters iff it is
    valid AND owned (``my < 0`` disables ownership), onto ``slot[row]``;
    everything else gets the out-of-range sentinel that sorts it out of
    every run."""
    my = my_bank.reshape(())
    mine = valid & ((my < 0) | (bank[row] == my))
    return jnp.where(mine, slot[row], n_rows)


def _ct_scatter_call(ct: jax.Array, dest: jax.Array, bags: jax.Array,
                     n_rows: int, out_dtype, *, tile_s: int,
                     interpret: bool, n_slots: int = 2) -> jax.Array:
    """Shared pallas_call plumbing for the backward scatters: run the sort
    prep, then the sorted-run kernel with the d_table aliased to zeros."""
    E = dest.shape[0]
    n_tiles = max(1, -(-E // tile_s))
    bag_sorted, run_of, run_starts, run_slot, n_run = scatter_run_metadata(
        dest, bags, n_rows, n_tiles * tile_s)
    ctp, d = (ct, ct.shape[-1]) if interpret else pad_last_dim(ct)
    D = ctp.shape[-1]
    kernel = functools.partial(_ct_scatter_kernel, tile_s=tile_s, dim=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=_scatter_scratch(D, ctp.dtype, out_dtype, n_slots),
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, D), out_dtype),
        # d_table aliases a zeros input (operand 6 = 5 scalars + ct): only
        # touched rows are DMA'd, the rest must already BE zero
        input_output_aliases={6: 0},
        interpret=interpret,
    )(bag_sorted, run_of, run_starts, run_slot, n_run, ctp,
      jnp.zeros((n_rows, D), out_dtype))
    return out[:, :d]


def ct_scatter_bag_pallas(ct: jax.Array, idx: jax.Array, bank: jax.Array,
                          slot: jax.Array, field_offsets: jax.Array,
                          my_bank: jax.Array, n_rows: int, out_dtype, *,
                          tile_s: int = 8, interpret: bool = False,
                          k_max: int = 1, n_slots: int = 2) -> jax.Array:
    """Transpose of ``banked_embedding_bag_pallas``: scatter-add the bag
    cotangents back onto one bank's rows, entirely in the kernel layer.

    ct (NB, D) cotangent rows; idx (NB, L) the forward's raw per-field ids
    (-1 padded); bank/slot (V,) the replicated remap; field_offsets (F,);
    my_bank (1,) int32 (< 0: own everything). -> d_table (n_rows, D).

    The prep enumerates entries j-major (e = j*NB + bag: position-major like
    the jnp fallback's scan over L), walks the same remap + ownership +
    offset metadata as the forward to label each entry with its destination
    slot, and sorts — see ``scatter_run_metadata``. fp32 accumulation per
    run, one cast to ``out_dtype`` at the write, matching the fallback's
    accumulation policy bit-for-bit in fp32.

    ``k_max > 1`` is the k-way replicated backward: each entry's destination
    is the SAME hash-picked copy its forward read came through (bank/slot
    flattened ``(V * k_max,)``), so every copy of a row accumulates exactly
    the cotangents of the bags it served — the sorted-run machinery groups
    the per-copy collisions like any other slot collision, and summing a
    row's copies recovers the single-copy gradient.
    """
    NB, L = idx.shape
    E = NB * L
    F = field_offsets.shape[0]
    e = jnp.arange(E, dtype=jnp.int32)
    bag, j = e % NB, e // NB
    raw = idx.reshape(-1)[bag * L + j]
    valid = raw >= 0
    row = jnp.where(valid, raw + field_offsets[bag % F], 0)
    if k_max > 1:
        row = row * k_max + replica_of_bag(bag, k_max)
    dest = _dest_slots(row, valid, bank, slot, my_bank, n_rows)
    return _ct_scatter_call(ct, dest, bag, n_rows, out_dtype,
                            tile_s=tile_s, interpret=interpret,
                            n_slots=n_slots)


def ct_scatter_csr_pallas(ct: jax.Array, indices: jax.Array,
                          seg_ids: jax.Array, bank: jax.Array,
                          slot: jax.Array, my_bank: jax.Array, n_rows: int,
                          out_dtype, *, tile_s: int = 8,
                          interpret: bool = False,
                          n_slots: int = 2) -> jax.Array:
    """Transpose of ``csr_bag_pallas``: ct (num_bags, D) bag cotangents,
    indices/seg_ids (T,) the forward's flat stream (entries keep their
    natural stream order within a run — the single-scatter fallback's
    order). -> (n_rows, D)."""
    valid = indices >= 0
    row = jnp.where(valid, indices, 0)
    dest = _dest_slots(row, valid, bank, slot, my_bank, n_rows)
    return _ct_scatter_call(ct, dest, seg_ids, n_rows, out_dtype,
                            tile_s=tile_s, interpret=interpret,
                            n_slots=n_slots)


def csr_bag_pallas(table: jax.Array, bank: jax.Array, slot: jax.Array,
                   my_bank: jax.Array, indices: jax.Array, seg_ids: jax.Array,
                   offsets_ext: jax.Array, num_bags: int, *, tile_b: int = 8,
                   interpret: bool = False, n_slots: int = 2) -> jax.Array:
    """CSR bag sums: indices (T,) flat stream, seg_ids (T,) bag per entry,
    offsets_ext (num_bags + 1,) with offsets_ext[-1] == T. -> (num_bags, D).
    ``num_bags`` must be a multiple of tile_b (pad offsets with T)."""
    T = indices.shape[0]
    R, D = table.shape
    assert num_bags % tile_b == 0, (num_bags, tile_b)
    assert offsets_ext.shape[0] == num_bags + 1
    kernel = functools.partial(_csr_bag_kernel, tile_b=tile_b, dim=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(num_bags // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((tile_b, D), lambda b, *_: (b, 0)),
        scratch_shapes=_scratch(D, table.dtype, n_slots),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_bags, D), table.dtype),
        interpret=interpret,
    )(indices, seg_ids, offsets_ext, bank, slot, my_bank, table)
