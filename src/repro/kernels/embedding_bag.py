"""Pallas TPU kernel: embedding-bag gather+reduce "near memory".

TPU adaptation of the paper's in-DPU lookup (DESIGN.md §5): the table stays in
HBM (MemorySpace.ANY); bag indices are scalar-prefetched (SMEM) so the kernel
can issue row-granular HBM->VMEM copies; each grid step accumulates ONE batch
tile of bag sums in a VMEM accumulator and writes only the reduced (tile_b, D)
block. The (B*L, D) gathered matrix — the thing a naive XLA gather would
materialize in HBM — never exists.

Alignment: D is padded to the 128-lane boundary by ops.py (the TPU analogue of
the paper's 8-byte MRAM alignment rule); the row copy is one (1, D) DMA, i.e.
the ``N_c``-wide access of §3.1 with TPU constants.

Grid: (B / tile_b,).  One program owns tile_b bags; the inner fori_loop walks
tile_b * L prefetched indices, accumulating valid rows. Bank masking (the PIM
stage-2 ownership test) is precomputed by the wrapper: indices not owned are
already -1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, table_ref, out_ref, *, tile_b: int, bag_len: int,
                dim: int):
    b0 = pl.program_id(0) * tile_b

    def bag_body(i, acc):
        def entry_body(j, acc_row):
            row = idx_ref[(b0 + i) * bag_len + j]
            valid = row >= 0
            safe = jnp.maximum(row, 0)
            vec = table_ref[pl.dslice(safe, 1), :]      # (1, D) HBM->VMEM
            return acc_row + jnp.where(valid, vec[0], 0.0)

        acc_row = jax.lax.fori_loop(0, bag_len, entry_body,
                                    jnp.zeros((dim,), jnp.float32))
        return acc.at[i].set(acc_row)

    acc = jax.lax.fori_loop(0, tile_b, bag_body,
                            jnp.zeros((tile_b, dim), jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


def embedding_bag_pallas(table: jax.Array, idx: jax.Array, *,
                         tile_b: int = 8, interpret: bool = False
                         ) -> jax.Array:
    """table (V, D) in HBM; idx (B, L) int32, -1 padded -> (B, D)."""
    B, L = idx.shape
    V, D = table.shape
    assert B % tile_b == 0, (B, tile_b)
    kernel = functools.partial(_bag_kernel, tile_b=tile_b, bag_len=L, dim=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.MemorySpace.ANY)],
        out_specs=pl.BlockSpec((tile_b, D), lambda b, idx_ref: (b, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(idx.reshape(-1), table)
