"""Pallas TPU kernels: embedding-bag gather+reduce "near memory".

TPU adaptation of the paper's in-DPU lookup (DESIGN.md §5, paper §3.1/Fig. 7).
The table(s) stay in HBM (`pltpu.ANY`); bag indices and the row->(bank, slot)
remap vectors are scalar-prefetched (SMEM) so the kernel can compute HBM row
addresses *before* touching vector memory; rows stream HBM->VMEM through a
two-slot ping-pong of `pltpu.make_async_copy` DMAs (the copy for entry e+1 is
in flight while entry e is being accumulated). Each grid step owns a tile of
bags and writes only the reduced (tile_b, D) block — the (B*L, D) gathered
matrix a naive XLA gather would materialize never exists.

What runs inside the kernel (vs. the seed's wrapper-side precompute):
  * per-field row offsets      — bag b belongs to field b % n_fields; its raw
    ids are shifted by `field_offsets[f]`, so ALL F sparse fields of a DLRM
    batch are one kernel invocation over (B*F, L) bags
  * bank/slot remap + ownership mask — the PIM stage-2 test `bank[row] == my`
    happens on the prefetched scalars; foreign rows cost no DMA bandwidth to
    accumulate (they are masked), and the wrapper no longer materializes a
    masked index tensor per bank
  * fused cache + residual     — one accumulator walks the cache-entry stream
    then the residual stream (Fig. 7's `Σ cache_partials + Σ residual_rows`)

Ownership is disabled by passing ``my_bank < 0`` (the unsharded path).

Alignment: D is padded to the 128-lane boundary by the wrappers (the TPU
analogue of the paper's 8-byte MRAM alignment rule); each row copy is one
(1, D) DMA — the ``N_c``-wide access of §3.1 with TPU constants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# double-buffered row-DMA accumulate
# ---------------------------------------------------------------------------

def _dma_accumulate(acc, table_ref, buf, sem, start, end, src_fn, meta_fn):
    """Accumulate table rows for entries [start, end) into per-bag sums.

    ``src_fn(e)``  -> local table row to fetch (already ownership-clamped)
    ``meta_fn(e)`` -> (bag_local, mine) — accumulator row and validity mask

    Ping-pong over two (1, D) VMEM slots: the DMA for entry e+1 is started
    before waiting on entry e, so the HBM fetch of the next row overlaps the
    VPU accumulate of the current one.
    """
    def dma(e, slot):
        return pltpu.make_async_copy(
            table_ref.at[pl.ds(src_fn(e), 1), :], buf.at[slot], sem.at[slot])

    @pl.when(end > start)
    def _():
        dma(start, 0).start()

    def body(e, acc):
        slot = (e - start) % 2

        @pl.when(e + 1 < end)
        def _():
            dma(e + 1, (slot + 1) % 2).start()

        dma(e, slot).wait()
        bag_local, mine = meta_fn(e)
        row = jnp.where(mine, buf[slot][0].astype(jnp.float32), 0.0)
        return acc.at[bag_local].add(row)

    return jax.lax.fori_loop(start, end, body, acc)


def _entry_fns(idx_ref, bank_ref, slot_ref, off_ref, my, b0, bag_len,
               n_fields):
    """(src_fn, meta_fn) for a rectangular (bags x bag_len) index stream with
    in-kernel field offsets, remap, and ownership mask. ``e`` is the
    tile-LOCAL entry id in [0, tile_b * bag_len)."""
    def resolve(e):
        bag = b0 + e // bag_len
        raw = idx_ref[bag * bag_len + e % bag_len]
        valid = raw >= 0
        row = jnp.where(valid, raw + off_ref[bag % n_fields], 0)
        mine = valid & ((my < 0) | (bank_ref[row] == my))
        return row, mine

    def src_fn(e):
        row, mine = resolve(e)
        return jnp.where(mine, slot_ref[row], 0)

    def meta_fn(e):
        _, mine = resolve(e)
        return e // bag_len, mine

    return src_fn, meta_fn


def _plain_entry_fns(idx_ref, b0, bag_len):
    """(src_fn, meta_fn) for an identity-mapped index stream — no remap
    vectors, no ownership test (the single-table drop-in wrappers)."""
    def resolve(e):
        raw = idx_ref[(b0 + e // bag_len) * bag_len + e % bag_len]
        return jnp.maximum(raw, 0), raw >= 0

    def src_fn(e):
        return resolve(e)[0]

    def meta_fn(e):
        return e // bag_len, resolve(e)[1]

    return src_fn, meta_fn


# ---------------------------------------------------------------------------
# padding helpers (shared by ops.py and core/embedding.py — ONE home for the
# 128-lane alignment rule and the -1 bag fill)
# ---------------------------------------------------------------------------

def effective_lengths(idx: jax.Array) -> jax.Array:
    """(B, L) -1-padded bags -> (B,) int32 count through the LAST valid
    entry (1 + its position; 0 for all-pad bags). Interior -1 holes are kept
    inside the walk — the in-kernel validity mask still skips them — so the
    early exit is exact for any padding pattern, suffix or not."""
    valid = idx >= 0
    last = idx.shape[1] - jnp.argmax(valid[:, ::-1], axis=1)
    return jnp.where(valid.any(axis=1), last, 0).astype(jnp.int32)


def pad_last_dim(x: jax.Array, mult: int = 128) -> tuple[jax.Array, int]:
    """Pad the trailing dim to a multiple (TPU lane alignment, §3.1 rule)."""
    d = x.shape[-1]
    pad = (-d) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, d


def pad_leading(x: jax.Array, mult: int, fill=-1) -> tuple[jax.Array, int]:
    """Pad the leading dim to a multiple with ``fill`` (-1 = padded bags)."""
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
    return x, n


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _plain_bag_kernel(idx_ref, table_ref, out_ref, buf, sem, *,
                      tile_b: int, bag_len: int, dim: int):
    b0 = pl.program_id(0) * tile_b
    src_fn, meta_fn = _plain_entry_fns(idx_ref, b0, bag_len)
    acc = jnp.zeros((tile_b, dim), jnp.float32)
    acc = _dma_accumulate(acc, table_ref, buf, sem, 0, tile_b * bag_len,
                          src_fn, meta_fn)
    out_ref[...] = acc.astype(out_ref.dtype)


def _plain_fused_kernel(cache_idx_ref, resid_idx_ref, c_len_ref, r_len_ref,
                        cache_ref, emt_ref, out_ref, buf, sem, *,
                        tile_b: int, lc: int, lr: int, dim: int):
    b0 = pl.program_id(0) * tile_b
    acc = jnp.zeros((tile_b, dim), jnp.float32)
    c_src, c_meta = _plain_entry_fns(cache_idx_ref, b0, lc)
    r_src, r_meta = _plain_entry_fns(resid_idx_ref, b0, lr)
    # per-bag early exit on the prefetched effective lengths (CSR-style):
    # the walk stops at each bag's last valid entry instead of masked-
    # accumulating the full L — all-pad bags cost zero DMAs
    for i in range(tile_b):
        acc = _dma_accumulate(acc, cache_ref, buf, sem, i * lc,
                              i * lc + c_len_ref[b0 + i], c_src, c_meta)
        acc = _dma_accumulate(acc, emt_ref, buf, sem, i * lr,
                              i * lr + r_len_ref[b0 + i], r_src, r_meta)
    out_ref[...] = acc.astype(out_ref.dtype)


def _banked_bag_kernel(idx_ref, bank_ref, slot_ref, off_ref, my_ref,
                       table_ref, out_ref, buf, sem, *,
                       tile_b: int, bag_len: int, n_fields: int, dim: int):
    b0 = pl.program_id(0) * tile_b
    src_fn, meta_fn = _entry_fns(idx_ref, bank_ref, slot_ref, off_ref,
                                 my_ref[0], b0, bag_len, n_fields)
    acc = jnp.zeros((tile_b, dim), jnp.float32)
    acc = _dma_accumulate(acc, table_ref, buf, sem, 0, tile_b * bag_len,
                          src_fn, meta_fn)
    out_ref[...] = acc.astype(out_ref.dtype)


def _fused_cache_bag_kernel(cache_idx_ref, resid_idx_ref, c_len_ref,
                            r_len_ref, c_bank_ref, c_slot_ref, r_bank_ref,
                            r_slot_ref, my_ref, zero_off_ref, cache_ref,
                            emt_ref, out_ref, buf, sem, *, tile_b: int,
                            lc: int, lr: int, dim: int):
    """Fig. 7 fused lookup: Σ cache partial-sums + Σ residual EMT rows, one
    accumulator, one output write. Both streams run through the same
    ping-pong buffers; each bag's walk ends at its prefetched effective
    length (c_len/r_len — trailing -1 padding trimmed, CSR-style), so short
    bags in a long-L batch stop early instead of masked-accumulating L."""
    b0 = pl.program_id(0) * tile_b
    my = my_ref[0]
    acc = jnp.zeros((tile_b, dim), jnp.float32)
    c_src, c_meta = _entry_fns(cache_idx_ref, c_bank_ref, c_slot_ref,
                               zero_off_ref, my, b0, lc, 1)
    r_src, r_meta = _entry_fns(resid_idx_ref, r_bank_ref, r_slot_ref,
                               zero_off_ref, my, b0, lr, 1)
    for i in range(tile_b):
        acc = _dma_accumulate(acc, cache_ref, buf, sem, i * lc,
                              i * lc + c_len_ref[b0 + i], c_src, c_meta)
        acc = _dma_accumulate(acc, emt_ref, buf, sem, i * lr,
                              i * lr + r_len_ref[b0 + i], r_src, r_meta)
    out_ref[...] = acc.astype(out_ref.dtype)


def _csr_bag_kernel(idx_ref, seg_ref, offs_ref, bank_ref, slot_ref, my_ref,
                    table_ref, out_ref, buf, sem, *, tile_b: int, dim: int):
    """CSR-ragged bags: entries for bags [b0, b0+tile_b) are the contiguous
    index range [offs[b0], offs[b0+tile_b]); per-entry bag = seg[e]."""
    b0 = pl.program_id(0) * tile_b
    my = my_ref[0]

    def resolve(e):
        raw = idx_ref[e]
        valid = raw >= 0
        row = jnp.where(valid, raw, 0)
        mine = valid & ((my < 0) | (bank_ref[row] == my))
        return row, mine

    def src_fn(e):
        row, mine = resolve(e)
        return jnp.where(mine, slot_ref[row], 0)

    def meta_fn(e):
        _, mine = resolve(e)
        return seg_ref[e] - b0, mine

    acc = jnp.zeros((tile_b, dim), jnp.float32)
    acc = _dma_accumulate(acc, table_ref, buf, sem,
                          offs_ref[b0], offs_ref[b0 + tile_b],
                          src_fn, meta_fn)
    out_ref[...] = acc.astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers (shape plumbing only — padding stays in the callers)
# ---------------------------------------------------------------------------

def _scratch(dim: int, dtype):
    return [pltpu.VMEM((2, 1, dim), dtype), pltpu.SemaphoreType.DMA((2,))]


def banked_embedding_bag_pallas(table: jax.Array, bank: jax.Array,
                                slot: jax.Array, field_offsets: jax.Array,
                                my_bank: jax.Array, idx: jax.Array, *,
                                tile_b: int = 8, interpret: bool = False
                                ) -> jax.Array:
    """One bank's stage-2 partial bag sums, remap + mask in-kernel.

    table (R, D) local rows in HBM; bank/slot (V,) int32 remap (prefetched);
    field_offsets (F,) int32; my_bank (1,) int32 (< 0 disables the ownership
    test); idx (NB, L) int32 raw per-field ids, -1 padded. -> (NB, D).
    """
    NB, L = idx.shape
    R, D = table.shape
    assert NB % tile_b == 0, (NB, tile_b)
    kernel = functools.partial(
        _banked_bag_kernel, tile_b=tile_b, bag_len=L,
        n_fields=field_offsets.shape[0], dim=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(NB // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((tile_b, D), lambda b, *_: (b, 0)),
        scratch_shapes=_scratch(D, table.dtype),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((NB, D), table.dtype),
        interpret=interpret,
    )(idx.reshape(-1), bank, slot, field_offsets, my_bank, table)


def embedding_bag_pallas(table: jax.Array, idx: jax.Array, *,
                         tile_b: int = 8, interpret: bool = False
                         ) -> jax.Array:
    """Plain bag sum: table (V, D); idx (B, L) -1 padded -> (B, D).

    Remap-free variant: rows are table positions, so no (V,)-sized scalar
    operands hit SMEM — any vocab size works on real TPUs.
    """
    B, L = idx.shape
    V, D = table.shape
    assert B % tile_b == 0, (B, tile_b)
    kernel = functools.partial(_plain_bag_kernel, tile_b=tile_b, bag_len=L,
                               dim=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((tile_b, D), lambda b, *_: (b, 0)),
        scratch_shapes=_scratch(D, table.dtype),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(idx.reshape(-1), table)


def plain_cache_bag_pallas(emt: jax.Array, cache: jax.Array,
                           cache_idx: jax.Array, residual_idx: jax.Array, *,
                           tile_b: int = 8, interpret: bool = False
                           ) -> jax.Array:
    """Fig.-7 fused lookup over unbanked tables (identity layout): no remap
    operands in SMEM. -> (B, D) = Σ cached partials + Σ residual rows."""
    B, Lc = cache_idx.shape
    B2, Lr = residual_idx.shape
    assert B == B2 and B % tile_b == 0, (B, B2, tile_b)
    D = emt.shape[1]
    assert cache.shape[1] == D
    cache = cache.astype(emt.dtype)     # one scratch buffer, one row dtype
    kernel = functools.partial(_plain_fused_kernel, tile_b=tile_b, lc=Lc,
                               lr=Lr, dim=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((tile_b, D), lambda b, *_: (b, 0)),
        scratch_shapes=_scratch(D, emt.dtype),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), emt.dtype),
        interpret=interpret,
    )(cache_idx.reshape(-1), residual_idx.reshape(-1),
      effective_lengths(cache_idx), effective_lengths(residual_idx),
      cache, emt)


def fused_cache_bag_pallas(emt: jax.Array, cache: jax.Array,
                           emt_bank: jax.Array, emt_slot: jax.Array,
                           cache_bank: jax.Array, cache_slot: jax.Array,
                           my_bank: jax.Array, cache_idx: jax.Array,
                           residual_idx: jax.Array, *, tile_b: int = 8,
                           interpret: bool = False) -> jax.Array:
    """emt (R, D), cache (Rc, D); cache_idx (B, Lc), residual_idx (B, Lr)
    (-1 padded) -> (B, D) = Σ cached partials + Σ residual rows, one pass."""
    B, Lc = cache_idx.shape
    B2, Lr = residual_idx.shape
    assert B == B2 and B % tile_b == 0, (B, B2, tile_b)
    D = emt.shape[1]
    assert cache.shape[1] == D
    cache = cache.astype(emt.dtype)     # one scratch buffer, one row dtype
    kernel = functools.partial(_fused_cache_bag_kernel, tile_b=tile_b,
                               lc=Lc, lr=Lr, dim=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=10,
        grid=(B // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((tile_b, D), lambda b, *_: (b, 0)),
        scratch_shapes=_scratch(D, emt.dtype),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), emt.dtype),
        interpret=interpret,
    )(cache_idx.reshape(-1), residual_idx.reshape(-1),
      effective_lengths(cache_idx), effective_lengths(residual_idx),
      cache_bank, cache_slot, emt_bank, emt_slot, my_bank,
      jnp.zeros((1,), jnp.int32), cache, emt)


def csr_bag_pallas(table: jax.Array, bank: jax.Array, slot: jax.Array,
                   my_bank: jax.Array, indices: jax.Array, seg_ids: jax.Array,
                   offsets_ext: jax.Array, num_bags: int, *, tile_b: int = 8,
                   interpret: bool = False) -> jax.Array:
    """CSR bag sums: indices (T,) flat stream, seg_ids (T,) bag per entry,
    offsets_ext (num_bags + 1,) with offsets_ext[-1] == T. -> (num_bags, D).
    ``num_bags`` must be a multiple of tile_b (pad offsets with T)."""
    T = indices.shape[0]
    R, D = table.shape
    assert num_bags % tile_b == 0, (num_bags, tile_b)
    assert offsets_ext.shape[0] == num_bags + 1
    kernel = functools.partial(_csr_bag_kernel, tile_b=tile_b, dim=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(num_bags // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec((tile_b, D), lambda b, *_: (b, 0)),
        scratch_shapes=_scratch(D, table.dtype),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_bags, D), table.dtype),
        interpret=interpret,
    )(indices, seg_ids, offsets_ext, bank, slot, my_bank, table)
