"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

These define the exact semantics the kernels must match (allclose in tests):
  * embedding_bag_ref    — padded-bag gather+sum:  (B, L) idx -> (B, D)
  * banked_bag_ref       — the PIM stage-2 semantics: remapped, bank-masked
  * cache_bag_ref        — fused cache + EMT bag sum (paper Fig. 7)
  * dot_interaction_ref  — DLRM pairwise-dot upper triangle
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def embedding_bag_ref(table: jax.Array, idx: jax.Array) -> jax.Array:
    """table (V, D); idx (B, L) with -1 padding -> (B, D) bag sums."""
    valid = idx >= 0
    rows = jnp.take(table, jnp.where(valid, idx, 0), axis=0)
    return jnp.where(valid[..., None], rows, 0).sum(axis=1)


def banked_bag_ref(table_local: jax.Array, bank: jax.Array, slot: jax.Array,
                   idx: jax.Array, my_bank: int) -> jax.Array:
    """One bank's partial bag sums (stage 2): only rows owned by my_bank."""
    valid = idx >= 0
    safe = jnp.where(valid, idx, 0)
    mine = valid & (bank[safe] == my_bank)
    rows = jnp.take(table_local, jnp.where(mine, slot[safe], 0), axis=0)
    return jnp.where(mine[..., None], rows, 0).sum(axis=1)


def cache_bag_ref(emt: jax.Array, cache: jax.Array, cache_idx: jax.Array,
                  residual_idx: jax.Array) -> jax.Array:
    """Fused Fig.-7 lookup: cached partial sums + residual EMT rows."""
    return embedding_bag_ref(cache, cache_idx) \
        + embedding_bag_ref(emt, residual_idx)


def dot_interaction_ref(z: jax.Array) -> jax.Array:
    """z (B, F, D) -> (B, F*(F-1)/2) upper-triangle pairwise dots."""
    B, F, D = z.shape
    zz = jnp.einsum("bfd,bgd->bfg", z, z, preferred_element_type=jnp.float32)
    iu, ju = np.triu_indices(F, k=1)
    return zz[:, iu, ju].astype(z.dtype)
