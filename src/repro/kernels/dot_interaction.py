"""Pallas TPU kernel: DLRM pairwise-dot feature interaction.

z (B, F, D) -> upper-triangle of z·zᵀ, (B, F(F-1)/2). The MXU-friendly move:
compute the full (F, F) Gram matrix per batch tile with one (F, D)x(D, F)
matmul (D padded to 128 lanes by ops.py), then extract the triangle with an
iota mask + reshape — no per-pair scalar loops. The Gram tile lives entirely
in VMEM: F is small (27-40 for DLRM/xDeepFM) so tile_b x F x F fits easily.

Output is padded to P_pad (multiple of 128) columns; ops.py slices the valid
P = F(F-1)/2 prefix. Padding (not gathering) keeps the kernel store shape
lane-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dot_kernel(z_ref, out_ref, *, n_fields: int, n_pairs_pad: int):
    z = z_ref[...].astype(jnp.float32)          # (tile_b, F, D)
    gram = jax.lax.dot_general(
        z, z, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)     # (tile_b, F, F)
    iu = jax.lax.broadcasted_iota(jnp.int32, (n_fields, n_fields), 0)
    ju = jax.lax.broadcasted_iota(jnp.int32, (n_fields, n_fields), 1)
    upper = (ju > iu).reshape(-1)               # (F*F,) static mask
    flat = gram.reshape(gram.shape[0], -1)      # (tile_b, F*F)
    # stable-order compaction of the upper triangle into the padded output:
    # position of pair (i,j) = cumsum(upper)-1; scatter via one matmul with a
    # {0,1} selection matrix (static), MXU-friendly and layout-clean.
    pos = jnp.cumsum(upper.astype(jnp.int32)) - 1
    sel = jnp.where(
        upper[:, None]
        & (jax.lax.broadcasted_iota(jnp.int32, (n_fields * n_fields,
                                                n_pairs_pad), 1)
           == pos[:, None]),
        1.0, 0.0)                               # (F*F, P_pad) static
    out_ref[...] = (flat @ sel).astype(out_ref.dtype)


def dot_interaction_pallas(z: jax.Array, *, tile_b: int = 128,
                           interpret: bool = False) -> jax.Array:
    """z (B, F, D) -> (B, P_pad) where the first F(F-1)/2 cols are the pairs."""
    B, F, D = z.shape
    n_pairs = F * (F - 1) // 2
    n_pairs_pad = -(-n_pairs // 128) * 128
    tile_b = min(tile_b, B)
    assert B % tile_b == 0
    kernel = functools.partial(_dot_kernel, n_fields=F,
                               n_pairs_pad=n_pairs_pad)
    return pl.pallas_call(
        kernel,
        grid=(B // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, F, D), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((tile_b, n_pairs_pad), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_pairs_pad), z.dtype),
        interpret=interpret,
    )(z)
