"""Pallas TPU kernel: FUSED cache + EMT bag lookup (paper Fig. 7).

One grid step resolves a whole request tile: walk the request's cache-entry
ids accumulating cached PARTIAL SUMS, then its residual ids accumulating EMT
rows — one VMEM accumulator, one output write. This is the cache-aware
stage 2 as a single kernel: the two tables live in HBM (MemorySpace.ANY) and
only reduced (tile_b, D) bags leave.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _cache_bag_kernel(cache_idx_ref, resid_idx_ref, cache_ref, emt_ref,
                      out_ref, *, tile_b: int, lc: int, lr: int, dim: int):
    b0 = pl.program_id(0) * tile_b

    def one_table(idx_ref, bag_len, table_ref, i, acc_row):
        def entry(j, acc_row):
            row = idx_ref[(b0 + i) * bag_len + j]
            valid = row >= 0
            safe = jnp.maximum(row, 0)
            vec = table_ref[pl.dslice(safe, 1), :]
            return acc_row + jnp.where(valid, vec[0], 0.0)
        return jax.lax.fori_loop(0, bag_len, entry, acc_row)

    def bag_body(i, acc):
        acc_row = jnp.zeros((dim,), jnp.float32)
        acc_row = one_table(cache_idx_ref, lc, cache_ref, i, acc_row)
        acc_row = one_table(resid_idx_ref, lr, emt_ref, i, acc_row)
        return acc.at[i].set(acc_row)

    acc = jax.lax.fori_loop(0, tile_b, bag_body,
                            jnp.zeros((tile_b, dim), jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


def cache_bag_pallas(emt: jax.Array, cache: jax.Array, cache_idx: jax.Array,
                     residual_idx: jax.Array, *, tile_b: int = 8,
                     interpret: bool = False) -> jax.Array:
    """emt (V, D), cache (Nc, D); cache_idx (B, Lc), residual_idx (B, Lr)
    (-1 padded) -> (B, D) = cached partials + residual rows."""
    B, Lc = cache_idx.shape
    _, Lr = residual_idx.shape
    V, D = emt.shape
    assert cache.shape[1] == D
    assert B % tile_b == 0
    kernel = functools.partial(_cache_bag_kernel, tile_b=tile_b, lc=Lc,
                               lr=Lr, dim=D)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B // tile_b,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.MemorySpace.ANY),
                  pl.BlockSpec(memory_space=pltpu.MemorySpace.ANY)],
        out_specs=pl.BlockSpec((tile_b, D), lambda b, *_: (b, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), emt.dtype),
        interpret=interpret,
    )(cache_idx.reshape(-1), residual_idx.reshape(-1), cache, emt)
