"""Fused cache + EMT bag lookup (paper Fig. 7) — subsumed by the generalized
fused kernel in ``kernels/embedding_bag.py``.

This module keeps the historical single-table-layout entry point: both tables
unbanked (identity remap, ownership off). The banked/distributed flavour is
``embedding_bag.fused_cache_bag_pallas`` called with real remap vectors by
``core/embedding.banked_cache_residual_bag``.
"""
from __future__ import annotations

from repro.kernels.embedding_bag import plain_cache_bag_pallas as \
    cache_bag_pallas  # noqa: F401
