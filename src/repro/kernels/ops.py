"""Jit'd public wrappers around the Pallas kernels.

Handle TPU layout rules (pad D to 128 lanes — the §3.1 alignment rule, TPU
constants), pick interpret mode off-TPU automatically, and expose drop-in
replacements for the pure-jnp paths:

    embedding_bag(table, idx)            ~ ref.embedding_bag_ref
    cache_bag(emt, cache, c_idx, r_idx)  ~ ref.cache_bag_ref
    dot_interaction(z)                   ~ ref.dot_interaction_ref
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.cache_bag import cache_bag_pallas
from repro.kernels.dot_interaction import dot_interaction_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# one home for the alignment rules: kernels/embedding_bag.py
from repro.kernels.embedding_bag import pad_last_dim as _pad_dim
from repro.kernels.embedding_bag import pad_leading


def _pad_batch(idx: jax.Array, tile_b: int) -> tuple[jax.Array, int]:
    return pad_leading(idx, tile_b)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def embedding_bag(table: jax.Array, idx: jax.Array, *, tile_b: int = 8,
                  interpret: bool | None = None) -> jax.Array:
    """(V, D) x (B, L) -> (B, D). Pads D to 128 lanes and B to the tile."""
    if interpret is None:
        interpret = not _on_tpu()
    tpad, d0 = _pad_dim(table)
    ipad, b0 = _pad_batch(idx, tile_b)
    out = embedding_bag_pallas(tpad, ipad, tile_b=tile_b,
                               interpret=bool(interpret))
    return out[:b0, :d0]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def embedding_bag_trainable(table: jax.Array, idx: jax.Array,
                            tile_b: int = 8) -> jax.Array:
    """Differentiable wrapper: Pallas kernel forward, scatter-add backward
    (the backward of a bag-sum IS a row scatter — XLA's native scatter is
    already the right kernel for it)."""
    return embedding_bag(table, idx, tile_b=tile_b)


def _bag_fwd(table, idx, tile_b):
    return embedding_bag(table, idx, tile_b=tile_b), (table.shape, idx)


def _bag_bwd(tile_b, res, ct):
    (shape, idx) = res
    B, L = idx.shape
    valid = idx >= 0
    safe = jnp.where(valid, idx, 0).reshape(-1)
    updates = jnp.broadcast_to(ct[:, None, :], (B, L, ct.shape[-1]))
    updates = jnp.where(valid[..., None], updates, 0).reshape(B * L, -1)
    d_table = jnp.zeros(shape, ct.dtype).at[safe].add(updates)
    return (d_table, None)


embedding_bag_trainable.defvjp(_bag_fwd, _bag_bwd)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def cache_bag(emt: jax.Array, cache: jax.Array, cache_idx: jax.Array,
              residual_idx: jax.Array, *, tile_b: int = 8,
              interpret: bool | None = None) -> jax.Array:
    """Fused Fig.-7 lookup: one kernel pass over both index streams."""
    if interpret is None:
        interpret = not _on_tpu()
    epad, d0 = _pad_dim(emt)
    cpad, _ = _pad_dim(cache)
    ci, b0 = _pad_batch(cache_idx, tile_b)
    ri, _ = _pad_batch(residual_idx, tile_b)
    out = cache_bag_pallas(epad, cpad, ci, ri, tile_b=tile_b,
                           interpret=bool(interpret))
    return out[:b0, :d0]


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def dot_interaction(z: jax.Array, *, tile_b: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """(B, F, D) -> (B, F(F-1)/2)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, F, D = z.shape
    zpad, _ = _pad_dim(z)
    zb, b0 = _pad_batch(zpad, min(tile_b, max(8, B)))
    n_pairs = F * (F - 1) // 2
    out = dot_interaction_pallas(zb, tile_b=min(tile_b, zb.shape[0]),
                                 interpret=bool(interpret))
    return out[:b0, :n_pairs]
