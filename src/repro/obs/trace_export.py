"""Chrome-trace / Perfetto JSON export for ``obs.tracing.Tracer``.

The output is the Trace Event Format's JSON-object form
(``{"traceEvents": [...], ...}``): complete ('X') events for spans, instant
('i') events for point marks, counter ('C') events for gauge time-series
(per-bank traffic lanes, rolling p99 — Perfetto draws each ``args`` key as
one series in a counter track), plus 'M' metadata events naming the process
and threads. Load it in Perfetto (ui.perfetto.dev -> Open trace file) or
``chrome://tracing`` as-is.
"""
from __future__ import annotations

import json

from repro.obs.tracing import Tracer


def chrome_trace_events(tracer: Tracer, *, pid: int | None = None,
                        process_name: str = "repro") -> list[dict]:
    """Tracer records -> trace-event dicts (metadata first, then spans in
    start-time order — deterministic for a deterministic run)."""
    if pid is None:
        import os
        pid = os.getpid()
    tids = sorted({r.tid for r in tracer.records}
                  | {r.tid for r in tracer.instants}
                  | {r.tid for r in tracer.counters})
    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": process_name},
    }]
    for i, tid in enumerate(tids):
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid,
                       "args": {"name": f"host-{i}" if i else "serve-loop"}})
    for r in sorted(tracer.records, key=lambda r: (r.ts_us, -r.dur_us)):
        events.append({"name": r.name, "cat": "host", "ph": "X",
                       "ts": r.ts_us, "dur": r.dur_us,
                       "pid": pid, "tid": r.tid, "args": r.args})
    for r in sorted(tracer.instants, key=lambda r: r.ts_us):
        events.append({"name": r.name, "cat": "host", "ph": "i",
                       "ts": r.ts_us, "s": "t",
                       "pid": pid, "tid": r.tid, "args": r.args})
    for r in sorted(tracer.counters, key=lambda r: r.ts_us):
        events.append({"name": r.name, "cat": "counter", "ph": "C",
                       "ts": r.ts_us,
                       "pid": pid, "tid": r.tid, "args": r.values})
    return events


def write_chrome_trace(tracer: Tracer, path: str, *,
                       process_name: str = "repro") -> int:
    """Write the Perfetto-loadable JSON object; returns the event count."""
    events = chrome_trace_events(tracer, process_name=process_name)
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return len(events)
