"""Typed metrics: Counter / Gauge / Histogram behind a process-local registry.

Design constraints (why this is not "just a dict of floats"):

* **Dependency-free.** Producers live everywhere — ``repro.dist.fault`` is
  deliberately jax-free, the benches are numpy-only, the serve loop is
  latency-sensitive — so this module imports only the stdlib and an update
  is a couple of float adds under a lock.
* **Mergeable percentiles.** ``Histogram`` buckets observations into FIXED
  log-spaced bounds (the same bounds for every histogram by default), so
  p50/p99 come from bucket merges — two histograms from two processes or two
  bench shards combine exactly (``merge``), which stored-sample quantiles
  cannot do without shipping the samples.
* **Deterministic snapshots.** ``MetricRegistry.snapshot()`` is a plain dict
  with a FIXED key structure per metric type (no data-dependent keys), sorted
  by metric name — CI gates on the snapshot's key-path schema
  (benchmarks/check_regression.py), so two runs of the same configuration
  must produce structurally identical documents. Producers should create
  their metrics up front (get-or-create in ``__init__``), not lazily at
  event time, so a run where an event never fires still exports the counter
  at 0 instead of dropping the key.

``empirical_percentile`` is the ONE home of the sorted-index percentile
convention every latency report in this repo uses (``s[min(len-1,
int(q*len))]`` — the historical MicroBatcher/bench_workload convention,
kept bit-compatible so committed BENCH baselines reproduce exactly).
"""
from __future__ import annotations

import json
import math
import threading


def log_bucket_bounds(lo_exp: int = -6, hi_exp: int = 9,
                      per_decade: int = 8) -> tuple[float, ...]:
    """Fixed log-spaced bucket upper bounds: ``10**(k/per_decade)`` covering
    [10**lo_exp, 10**hi_exp]. 8/decade => adjacent bounds 1.33x apart, so a
    bucket-derived percentile is within ~33% of the exact one — plenty for
    latency triage, and the bounds never depend on the data (mergeable)."""
    return tuple(10.0 ** (k / per_decade)
                 for k in range(lo_exp * per_decade, hi_exp * per_decade + 1))


DEFAULT_BUCKETS = log_bucket_bounds()


def empirical_percentile(xs, q: float) -> float:
    """Exact sample percentile, index convention ``s[min(len-1, int(q*len))]``
    — the convention MicroBatcher.p99 and every bench scenario gate on.
    Returns 0.0 for an empty sequence."""
    s = sorted(xs)
    if not s:
        return 0.0
    return float(s[min(len(s) - 1, int(q * len(s)))])


def empirical_p99(xs) -> float:
    return empirical_percentile(xs, 0.99)


def empirical_p50(xs) -> float:
    return empirical_percentile(xs, 0.50)


class Counter:
    """Monotone event count. ``inc`` rejects negative deltas — a counter that
    can go down is a gauge, and downstream rate math silently breaks on it."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self._value}


class Gauge:
    """Last-written value (queue depth, hit rate, live-bank count...)."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {"type": self.kind, "value": self._value}


class _VectorMetric:
    """Shared machinery for fixed-size per-index metrics (per-bank series).

    One metric, ``size`` elements; the snapshot rides the element detail as
    a list of ``[index, value]`` pairs — list elements collapse in the
    key-path schema (the Histogram ``buckets`` precedent), so the schema is
    stable for any ``size``. ``label`` names the index dimension for the
    Prometheus exposition (``name{bank="3"}``).
    """

    kind = "vector"

    def __init__(self, name: str, help: str = "", *, size: int,
                 label: str = "bank"):
        if size < 1:
            raise ValueError(f"vector metric {name}: size must be >= 1")
        self.name = name
        self.help = help
        self.size = int(size)
        self.label = label
        self._values = [0.0] * self.size
        self._lock = threading.Lock()

    def _coerce(self, values) -> list[float]:
        values = [float(v) for v in values]
        if len(values) != self.size:
            raise ValueError(f"vector metric {self.name}: got {len(values)} "
                             f"values for size {self.size}")
        return values

    @property
    def values(self) -> list[float]:
        return list(self._values)

    @property
    def total(self) -> float:
        return sum(self._values)

    def snapshot(self) -> dict:
        return {"type": self.kind, "label": self.label,
                "values": [[i, v] for i, v in enumerate(self._values)]}


class VectorCounter(_VectorMetric):
    """Monotone counts over a fixed index space (per-bank reads/bytes);
    ``inc`` takes a full-length vector of non-negative deltas."""

    kind = "vector_counter"

    def inc(self, deltas) -> None:
        deltas = self._coerce(deltas)
        if any(d < 0 for d in deltas):
            raise ValueError(f"counter {self.name}: negative increment")
        with self._lock:
            for i, d in enumerate(deltas):
                self._values[i] += d


class VectorGauge(_VectorMetric):
    """Last-written vector (per-bank queue depth, live-copy counts...)."""

    kind = "vector_gauge"

    def set(self, values) -> None:
        values = self._coerce(values)
        with self._lock:
            self._values = values

    def inc(self, deltas) -> None:
        deltas = self._coerce(deltas)
        with self._lock:                 # gauges may go down
            for i, d in enumerate(deltas):
                self._values[i] += d


class Histogram:
    """Fixed-bound log-bucket histogram.

    ``counts[i]`` holds observations in ``(bounds[i-1], bounds[i]]``
    (``(-inf, bounds[0]]`` for i=0) plus one overflow bucket past the last
    bound. Quantiles walk the cumulative counts and answer the bucket's
    UPPER bound clamped into [min, max] observed — conservative (never
    under-reports a latency) and exact at the extremes.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 bounds: tuple[float, ...] = DEFAULT_BUCKETS):
        if list(bounds) != sorted(bounds) or len(bounds) < 1:
            raise ValueError(f"histogram {name}: bounds must be sorted")
        self.name = name
        self.help = help
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)     # +1 overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = threading.Lock()

    def _bucket_of(self, v: float) -> int:
        import bisect
        return bisect.bisect_left(self.bounds, v)

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.counts[self._bucket_of(v)] += 1
            self.count += 1
            self.sum += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)

    def merge(self, other: "Histogram") -> None:
        """Fold ``other`` into self. Requires identical bounds — the whole
        point of fixed buckets is that merges are exact."""
        if other.bounds != self.bounds:
            raise ValueError(f"histogram {self.name}: cannot merge differing "
                             f"bucket bounds")
        with self._lock:
            for i, c in enumerate(other.counts):
                self.counts[i] += c
            self.count += other.count
            self.sum += other.sum
            self.min = min(self.min, other.min)
            self.max = max(self.max, other.max)

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper bound of the covering bucket,
        clamped to the observed [min, max])."""
        if self.count == 0:
            return 0.0
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        rank = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank and c > 0:
                ub = self.bounds[i] if i < len(self.bounds) else self.max
                return float(min(max(ub, self.min), self.max))
        return float(self.max)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        # fixed key structure (schema-stable); the per-bucket detail rides as
        # a list of [upper_bound, count] pairs — list elements collapse in
        # the key-path schema, so a different set of populated buckets never
        # reads as schema drift
        return {
            "type": self.kind, "count": self.count, "sum": self.sum,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "mean": self.mean,
            "p50": self.quantile(0.50), "p99": self.quantile(0.99),
            "buckets": [[self.bounds[i] if i < len(self.bounds) else math.inf,
                         c]
                        for i, c in enumerate(self.counts) if c > 0],
        }


class MetricRegistry:
    """Process-local, get-or-create home for named metrics.

    Names are dotted strings (``serve.degraded_reads_total``); the registry
    enforces one TYPE per name (a counter re-registered as a gauge is a bug,
    not a merge). ``snapshot()`` sorts by name so the exported document is
    deterministic for a deterministic run.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  bounds: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, bounds=bounds)

    def vector_counter(self, name: str, help: str = "", *, size: int,
                       label: str = "bank") -> VectorCounter:
        return self._get_or_create(VectorCounter, name, help, size=size,
                                   label=label)

    def vector_gauge(self, name: str, help: str = "", *, size: int,
                     label: str = "bank") -> VectorGauge:
        return self._get_or_create(VectorGauge, name, help, size=size,
                                   label=label)

    def get(self, name: str):
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """{name: metric.snapshot()} sorted by name — the document the JSON
        exporter writes and the CI schema gate checks."""
        return {name: self._metrics[name].snapshot()
                for name in sorted(self._metrics)}

    def to_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True)
