"""Registry export: JSON snapshots, Prometheus text exposition, periodic
writes, and the one-line machine-readable summary the CLIs print.

Snapshot document shape (the thing CI's metrics-schema gate checks):

    {"meta": {"label": ..., "schema": 2},
     "metrics": {"<name>": {"type": "counter", "value": ...}, ...}}

Metric names are dotted; the Prometheus exposition sanitizes them to
``[a-zA-Z0-9_]`` (dots -> underscores) per the text-format rules. Vector
metrics (per-bank series) export as LABELED Prometheus series
(``obs_bank_reads{bank="3"} 17.0``) rather than name-mangled flat gauges.

Schema history: 1 = counters/gauges/histograms only; 2 = added the
``vector_counter``/``vector_gauge`` snapshot shape (``{type, label,
values: [[index, value], ...]}``).
"""
from __future__ import annotations

import json
import re

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricRegistry,
                               VectorCounter, VectorGauge)

SNAPSHOT_SCHEMA_VERSION = 2
_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def snapshot_doc(registry: MetricRegistry, *, label: str = "") -> dict:
    """The full snapshot document. ``meta`` keys are FIXED (no timestamps,
    no argv) so the key-path schema is stable run to run."""
    return {"meta": {"label": label, "schema": SNAPSHOT_SCHEMA_VERSION},
            "metrics": registry.snapshot()}


def write_metrics_json(registry: MetricRegistry, path: str, *,
                       label: str = "") -> dict:
    doc = snapshot_doc(registry, label=label)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
    return doc


def prometheus_text(registry: MetricRegistry) -> str:
    """Prometheus text exposition (the /metrics page body). Histograms emit
    the standard cumulative ``_bucket{le=...}`` series + ``_sum``/``_count``."""
    lines: list[str] = []
    for name in registry.names():
        m = registry.get(name)
        pname = _NAME_RE.sub("_", name)
        if m.help:
            lines.append(f"# HELP {pname} {m.help}")
        if isinstance(m, (VectorCounter, VectorGauge)):
            # labeled series, not name-mangled flat gauges: Prometheus has
            # no vector type, so the TYPE line reports the element kind
            kind = "counter" if isinstance(m, VectorCounter) else "gauge"
            lines.append(f"# TYPE {pname} {kind}")
            for i, v in enumerate(m.values):
                lines.append(f'{pname}{{{m.label}="{i}"}} {v!r}')
            continue
        lines.append(f"# TYPE {pname} {m.kind}")
        if isinstance(m, (Counter, Gauge)):
            lines.append(f"{pname} {m.value!r}")
        elif isinstance(m, Histogram):
            cum = 0
            for i, b in enumerate(m.bounds):
                cum += m.counts[i]
                lines.append(f'{pname}_bucket{{le="{b!r}"}} {cum}')
            lines.append(f'{pname}_bucket{{le="+Inf"}} {m.count}')
            lines.append(f"{pname}_sum {m.sum!r}")
            lines.append(f"{pname}_count {m.count}")
    return "\n".join(lines) + "\n"


def summary_dict(registry: MetricRegistry) -> dict:
    """Flat {name: value} view — counters/gauges report their value,
    histograms report {count, mean, p50, p99}. This is what the serve/train
    CLIs print as ONE machine-readable JSON line so CI contracts parse a
    dict instead of grepping free-form prints."""
    out: dict = {}
    for name in registry.names():
        m = registry.get(name)
        if isinstance(m, Histogram):
            out[name] = {"count": m.count, "mean": m.mean,
                         "p50": m.quantile(0.50), "p99": m.quantile(0.99)}
        elif isinstance(m, (VectorCounter, VectorGauge)):
            vals = m.values
            out[name] = {"sum": sum(vals), "max": max(vals),
                         "argmax": int(max(range(len(vals)),
                                           key=vals.__getitem__))}
        else:
            out[name] = m.value
    return out


def summary_line(registry: MetricRegistry, *, tag: str = "OBS_SUMMARY") -> str:
    """``OBS_SUMMARY {...}`` — grep the tag, json-parse the rest."""
    return f"{tag} {json.dumps(summary_dict(registry), sort_keys=True)}"


class PeriodicMetricsWriter:
    """Write the JSON snapshot every ``every`` batches (and once at the end
    via ``flush``). ``every=0`` disables the cadence — only ``flush`` writes.
    Writes are atomic-ish (tmp + rename) so a scraper never reads a torn
    file."""

    def __init__(self, registry: MetricRegistry, path: str, *,
                 every: int = 0, label: str = ""):
        self.registry = registry
        self.path = path
        self.every = int(every)
        self.label = label
        self.n_writes = 0

    def _write(self) -> None:
        import os
        tmp = f"{self.path}.tmp"
        write_metrics_json(self.registry, tmp, label=self.label)
        os.replace(tmp, self.path)
        self.n_writes += 1

    def maybe_write(self, batch: int) -> bool:
        """Call once per batch with the batch index; writes on cadence."""
        if self.every > 0 and batch > 0 and batch % self.every == 0:
            self._write()
            return True
        return False

    def flush(self) -> None:
        self._write()
