"""SLO watchdog: modeled-vs-measured breach detection over rolling windows.

Closes the observation loop the measured traffic counters
(``repro.obs.traffic``) open: every batch feeds its measured per-bank reads
through ``hwmodel.embedding_stage_latency`` to get a *realized* modeled
latency — the paper's Eq.-1 cost priced at the bank shares the hardware
actually saw, not the shares the plan projected. Each full window the
watchdog compares three signals and fires a breach per violated check:

``p99``         empirical p99 of the measured wall-clock batch times (the
                tracer's ``device_step`` spans) over the SLO budget
``hot_bank``    measured max-bank read share over threshold — the plan's
                balance promise broken by real traffic
``divergence``  realized modeled latency vs the plan-time projection —
                the calibration drift signal (same batch, same cost model,
                only the shares differ)

A breach emits an instant into the Chrome trace (an alert marker on the
timeline), increments the ``obs.slo_breaches_*`` counter family, and
invokes ``on_breach`` — the serve loop uses that hook to push a hot-bank
``bank_cost`` penalty into the ``Replanner``, so a measured imbalance
becomes a planning input instead of a log line. After firing, a check
cools down for one full window (deterministic: re-arms exactly ``window``
batches later), so tests and CI contracts can count breaches exactly.

Deliberately jax-free (numpy + ``repro.core.hwmodel`` + the registry):
the watchdog runs host-side between micro-batches on already-pulled
counter values.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from repro.core.hwmodel import embedding_stage_latency
from repro.obs.metrics import empirical_p99


@dataclass(frozen=True)
class SLOConfig:
    """Thresholds; 0 disables a check. ``window`` batches per evaluation."""

    p99_us: float = 0.0          # wall-clock p99 budget (us)
    max_share: float = 0.0       # measured max-bank read share ceiling
    divergence: float = 0.0      # realized/projected - 1 ceiling
    window: int = 16

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"slo window must be >= 1, got {self.window}")

    @property
    def enabled(self) -> bool:
        return (self.p99_us > 0 or self.max_share > 0
                or self.divergence > 0)


CHECKS = ("p99", "hot_bank", "divergence")


def hot_bank_penalty(reads, n_banks: int) -> np.ndarray:
    """Bank-cost multipliers from a measured read vector: the hottest bank
    pays its overload factor (measured share / ideal share, floored at 1),
    everyone else stays at 1 — the shape ``Replanner.set_bank_penalty``
    expects, same as the straggler path."""
    reads = np.asarray(reads, np.float64)
    pen = np.ones(n_banks)
    total = reads.sum()
    if total > 0:
        hot = int(np.argmax(reads))
        pen[hot] = max(1.0, float(reads[hot] / total) * n_banks)
    return pen


class SLOWatchdog:
    """Rolling-window breach detector over measured traffic + wall clock.

    Pre-registers the whole ``obs.slo_*`` family up front (the CI
    metrics-schema gate keys on them), so a run where nothing breaches
    still exports the counters at 0.
    """

    def __init__(self, cfg: SLOConfig, *, n_banks: int, dim: int,
                 metrics=None, tracer=None, on_breach=None, hw=None):
        self.cfg = cfg
        self.n_banks = int(n_banks)
        self.dim = int(dim)
        self.tracer = tracer
        self.on_breach = on_breach
        self.hw = hw
        self._window: deque = deque(maxlen=cfg.window)
        self._cooldown = {k: 0 for k in CHECKS}
        self._projected_share = 1.0 / self.n_banks
        self.breaches = 0
        self._m_total = self._m_kind = None
        self._g_realized = self._g_projected = self._g_share = None
        if metrics is not None:
            self._m_total = metrics.counter(
                "obs.slo_breaches_total", "SLO breaches detected (all checks)")
            self._m_kind = {
                "p99": metrics.counter(
                    "obs.slo_breaches_p99_total",
                    "wall-clock p99 over the SLO budget"),
                "hot_bank": metrics.counter(
                    "obs.slo_breaches_hot_bank_total",
                    "measured max-bank share over threshold"),
                "divergence": metrics.counter(
                    "obs.slo_breaches_divergence_total",
                    "realized modeled latency diverged from the projection"),
            }
            self._g_realized = metrics.gauge(
                "obs.slo_realized_latency_us",
                "modeled embedding-stage latency at MEASURED bank shares")
            self._g_projected = metrics.gauge(
                "obs.slo_projected_latency_us",
                "modeled embedding-stage latency at plan-PROJECTED shares")
            self._g_share = metrics.gauge(
                "obs.slo_projected_share",
                "plan-time projected max-bank share (updated on swaps)")
            self._g_share.set(self._projected_share)

    def set_projection(self, max_share: float) -> None:
        """Install the plan-time projected max-bank share (call at start
        and after every swap — the divergence check compares against the
        LIVE plan's promise)."""
        self._projected_share = float(max_share)
        if self._g_share is not None:
            self._g_share.set(self._projected_share)

    def _modeled_us(self, batch_size: int, total_reads: float,
                    max_share: float) -> float:
        avg_red = total_reads / max(batch_size, 1)
        kw = {} if self.hw is None else {"hw": self.hw}
        lat = embedding_stage_latency(
            batch_size=batch_size, avg_reduction=avg_red, n_c=self.dim,
            per_bank_lookup_share=[max_share], n_banks=self.n_banks, **kw)
        return float(lat.total) * 1e6

    def observe(self, batch: int, *, wall_us: float, reads,
                batch_size: int) -> list[str]:
        """Feed one batch; returns the breach kinds fired (usually [])."""
        reads = np.asarray(reads, np.float64)
        total = float(reads.sum())
        share = float(reads.max() / total) if total else 0.0
        realized = self._modeled_us(batch_size, total, share) if total else 0.0
        projected = (self._modeled_us(batch_size, total,
                                      self._projected_share)
                     if total else 0.0)
        if self._g_realized is not None:
            self._g_realized.set(realized)
            self._g_projected.set(projected)
        self._window.append({"wall_us": float(wall_us), "share": share,
                             "realized": realized, "projected": projected,
                             "reads": reads})
        if len(self._window) < self.cfg.window:
            return []
        return self._evaluate(batch)

    def _evaluate(self, batch: int) -> list[str]:
        w = list(self._window)
        p99_wall = empirical_p99([x["wall_us"] for x in w])
        mean_share = float(np.mean([x["share"] for x in w]))
        mean_real = float(np.mean([x["realized"] for x in w]))
        mean_proj = float(np.mean([x["projected"] for x in w]))
        div = mean_real / mean_proj - 1.0 if mean_proj > 0 else 0.0
        window_reads = np.sum([x["reads"] for x in w], axis=0)
        fired: list[str] = []
        candidates = (
            ("p99", self.cfg.p99_us, p99_wall,
             self.cfg.p99_us > 0 and p99_wall > self.cfg.p99_us),
            ("hot_bank", self.cfg.max_share, mean_share,
             self.cfg.max_share > 0 and mean_share > self.cfg.max_share),
            ("divergence", self.cfg.divergence, div,
             self.cfg.divergence > 0 and div > self.cfg.divergence),
        )
        for kind, threshold, value, hit in candidates:
            if not hit or batch < self._cooldown[kind]:
                continue
            self._cooldown[kind] = batch + self.cfg.window
            fired.append(kind)
            self.breaches += 1
            if self._m_total is not None:
                self._m_total.inc()
                self._m_kind[kind].inc()
            if self.tracer is not None:
                self.tracer.instant("slo_breach", kind=kind, batch=batch,
                                    value=value, threshold=threshold)
            if self.on_breach is not None:
                self.on_breach(kind, {
                    "batch": batch, "value": value, "threshold": threshold,
                    "share": mean_share, "p99_wall_us": p99_wall,
                    "realized_us": mean_real, "projected_us": mean_proj,
                    "window_reads": window_reads,
                    "bank": int(np.argmax(window_reads)),
                })
        return fired
