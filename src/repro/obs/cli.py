"""Shared CLI wiring for the obs layer: the ``--trace-out`` /
``--metrics-out`` / ``--metrics-every`` flags and their setup/teardown, used
identically by ``repro.launch.serve`` and ``repro.launch.train``.
"""
from __future__ import annotations

import argparse

from repro.obs.metrics import (MetricRegistry, empirical_p50, empirical_p99)
from repro.obs.metrics_export import (PeriodicMetricsWriter, summary_line,
                                      write_metrics_json)
from repro.obs.trace_export import write_chrome_trace
from repro.obs.tracing import NULL_TRACER, Tracer


def add_obs_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--trace-out", default=None, metavar="FILE.json",
                    help="write a Chrome-trace/Perfetto JSON of the host "
                         "pipeline stages (rewrite / device_step / migrate / "
                         "swap / recovery spans) to FILE")
    ap.add_argument("--metrics-out", default=None, metavar="FILE.json",
                    help="write the metrics-registry snapshot (counters, "
                         "gauges, latency histograms) to FILE at exit")
    ap.add_argument("--metrics-every", type=int, default=0, metavar="N",
                    help="with --metrics-out: also rewrite the snapshot "
                         "every N micro-batches/steps (0 = only at exit)")


def setup_obs(args, label: str):
    """(tracer, metrics, periodic_writer|None) from the obs CLI flags.
    Tracing is off (NULL_TRACER: spans are no-ops) unless --trace-out was
    given; the registry always exists so producers need no guards."""
    tracer = Tracer() if args.trace_out else NULL_TRACER
    metrics = MetricRegistry()
    writer = None
    if args.metrics_out:
        writer = PeriodicMetricsWriter(metrics, args.metrics_out,
                                       every=args.metrics_every, label=label)
    return tracer, metrics, writer


def finalize_obs(args, tracer, metrics: MetricRegistry, writer,
                 latencies=None, prefix: str = "serve") -> None:
    """End-of-run: fold the latency percentiles into the registry, write the
    trace + final snapshot, and print the ONE machine-readable summary line
    (grep ``OBS_SUMMARY``, json-parse the rest)."""
    if latencies is not None:
        metrics.gauge(f"{prefix}.p50_ms").set(empirical_p50(latencies) * 1e3)
        metrics.gauge(f"{prefix}.p99_ms").set(empirical_p99(latencies) * 1e3)
    if args.trace_out:
        n = write_chrome_trace(tracer, args.trace_out)
        print(f"trace: {n} events -> {args.trace_out}")
    if writer is not None:
        writer.flush()
        print(f"metrics: {len(metrics.names())} series -> {args.metrics_out}")
    print(summary_line(metrics))


__all__ = ["add_obs_args", "setup_obs", "finalize_obs",
           "write_metrics_json"]
