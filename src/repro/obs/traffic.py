"""Measured per-bank traffic: exact read/byte counters from the jit'd step.

Every bank-load number the repo reported before this module was *modeled* —
derived from plans and telemetry. But replica hash routing, cache hits,
degraded reads, and tier byte-widths all bend the traffic a batch actually
generates away from the plan-time projection. These functions compute the
ground truth ON DEVICE, inside the jit'd serve/train step, from the same
remap/tier/replica/bank_live arguments the step already carries — the
``degraded_row_counts`` pattern: pure jnp on jit ARGUMENTS, so the counters
add zero executables and survive live swaps without a recompile.

One device function per lookup path (plain banked, CSR, fused
cache+residual, tiered, replicated), each with a numpy twin
(``host_*``) that the tests bit-match against and the train loop uses for
its host-side recount. The twins reimplement the kernel's routing decisions
exactly: the replicated twin carries its own uint32 wang-hash so the copy
pick matches ``kernels.embedding_bag.replica_of_bag`` bit-for-bit, and the
failover accounting reproduces ``embedding._replica_failover_maps`` (a dead
chosen copy reads the row's FIRST live column; a row with no live copy
reads NO bank).

Counts are reads, not bags: every valid (row >= 0) entry of the batch is
one read on its row's bank, duplicates count separately — the same unit
``hwmodel.embedding_stage_latency`` prices. ``BankTraffic.nbytes`` weights
each read by its row's stored width (uniform ``dim * itemsize`` everywhere
except the tiered path, where the per-row tier code indexes a 3-entry byte
LUT).

This module imports jax (device side) and is deliberately NOT re-exported
by ``repro.obs`` — the obs package root stays stdlib-only for the jax-free
producers. Import it directly: ``from repro.obs.traffic import ...``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.kernels.embedding_bag import replica_of_bag


class BankTraffic(NamedTuple):
    """Per-bank measured traffic for one batch: ``(n_banks,)`` int32 each."""

    reads: jnp.ndarray
    nbytes: jnp.ndarray


def traffic_from_reads(reads, row_nbytes: int) -> BankTraffic:
    """Uniform-width paths: every read moves the same ``row_nbytes``."""
    return BankTraffic(reads=reads,
                       nbytes=reads * jnp.int32(row_nbytes))


# ---------------------------------------------------------------------------
# device-side counters (pure jnp on jit arguments — call INSIDE the jit)
# ---------------------------------------------------------------------------

def bank_read_counts(remap_bank, rows, n_banks: int, *, bank_live=None):
    """Per-bank read counts for a batch of row ids (any shape, -1 padded).

    The plain-banked / CSR / residual-stream counter: each valid entry is
    one read on ``remap_bank[row]``. Under ``bank_live`` a dead bank's
    reads are excluded — they zero-fill instead of moving bytes, exactly
    what ``degraded_row_counts`` counts from the other side.
    """
    rows = rows.reshape(-1)
    valid = rows >= 0
    safe = jnp.where(valid, rows, 0)
    bank = remap_bank[safe]
    if bank_live is not None:
        valid = valid & bank_live[bank]
    return (jnp.zeros(n_banks, jnp.int32)
            .at[bank].add(valid.astype(jnp.int32)))


def cached_bank_read_counts(entry_bank, cache_idx, remap_bank, residual_idx,
                            n_banks: int, *, bank_live=None):
    """Fused cache+residual path: a cache hit is ONE read on the entry's
    bank (``entry_bank[cache_idx]``), residual rows read their own banks.
    Both streams honor ``bank_live``."""
    hits = bank_read_counts(entry_bank, cache_idx, n_banks,
                            bank_live=bank_live)
    residual = bank_read_counts(remap_bank, residual_idx, n_banks,
                                bank_live=bank_live)
    return hits + residual


def tiered_bank_traffic(remap_bank, remap_slot, rows_per_bank: int, tier,
                        byte_lut, rows, n_banks: int) -> BankTraffic:
    """Tiered path: reads as the plain counter, bytes weighted by the row's
    tier width. ``tier`` is the packed-position tier code vector the
    TieredTable carries as a jit argument; ``byte_lut`` is the 3-entry
    bytes-per-tier table (``quant.tier_nbytes`` — static per table config).
    """
    flat = rows.reshape(-1)
    valid = flat >= 0
    safe = jnp.where(valid, flat, 0)
    bank = remap_bank[safe]
    pos = bank * rows_per_bank + remap_slot[safe]
    width = jnp.asarray(byte_lut, jnp.int32)[tier[pos]]
    reads = (jnp.zeros(n_banks, jnp.int32)
             .at[bank].add(valid.astype(jnp.int32)))
    nbytes = (jnp.zeros(n_banks, jnp.int32)
              .at[bank].add(jnp.where(valid, width, 0)))
    return BankTraffic(reads=reads, nbytes=nbytes)


def replicated_bank_read_counts(remap_bank, rows, n_banks: int, *,
                                k_max: int, bank_live=None):
    """Replicated path: bag ``n`` of the flattened batch reads copy
    ``wang_hash(n) % k_max`` — the kernel's replica pick. Under
    ``bank_live`` the failover maps' semantics are reproduced exactly: a
    dead chosen copy reads the row's FIRST live column instead, and a row
    with no live copy reads no bank at all (it zero-fills).

    ``rows``: ``(..., L)`` row ids, -1 padded; leading dims flatten to the
    kernel's per-call bag id (restarting at 0 every batch, like
    ``_replica_cols``). ``remap_bank``: the ``(V, k_max)`` copy->bank map.
    """
    flat = rows.reshape(-1, rows.shape[-1])
    n_bags, bag_len = flat.shape
    cols = replica_of_bag(jnp.arange(n_bags, dtype=jnp.int32), k_max)
    valid = flat >= 0
    safe = jnp.where(valid, flat, 0)
    banks_rc = remap_bank[safe]                              # (B, L, k)
    col_idx = jnp.broadcast_to(cols[:, None, None], (n_bags, bag_len, 1))
    chosen = jnp.take_along_axis(banks_rc, col_idx, axis=2)[..., 0]
    if bank_live is None:
        bank = chosen
    else:
        live_rc = bank_live[banks_rc]                        # (B, L, k)
        any_live = live_rc.any(axis=-1)
        first_live = jnp.argmax(live_rc, axis=-1)
        chosen_live = jnp.take_along_axis(live_rc, col_idx, axis=2)[..., 0]
        eff_col = jnp.where(chosen_live, cols[:, None], first_live)
        bank = jnp.take_along_axis(banks_rc, eff_col[..., None],
                                   axis=2)[..., 0]
        valid = valid & any_live
    return (jnp.zeros(n_banks, jnp.int32)
            .at[bank].add(valid.astype(jnp.int32)))


# ---------------------------------------------------------------------------
# host-side twins (numpy) — the recount the device counters must bit-match
# ---------------------------------------------------------------------------

def host_bank_read_counts(bank_of_row, rows, n_banks: int,
                          *, bank_live=None) -> np.ndarray:
    rows = np.asarray(rows).reshape(-1)
    rows = rows[rows >= 0]
    bank = np.asarray(bank_of_row)[rows]
    if bank_live is not None:
        bank = bank[np.asarray(bank_live)[bank]]
    return np.bincount(bank, minlength=n_banks).astype(np.int64)


def host_cached_bank_read_counts(entry_bank, cache_idx, bank_of_row,
                                 residual_idx, n_banks: int,
                                 *, bank_live=None) -> np.ndarray:
    return (host_bank_read_counts(entry_bank, cache_idx, n_banks,
                                  bank_live=bank_live)
            + host_bank_read_counts(bank_of_row, residual_idx, n_banks,
                                    bank_live=bank_live))


def host_tiered_bank_traffic(bank_of_row, slot_of_row, rows_per_bank: int,
                             tier, byte_lut, rows,
                             n_banks: int) -> tuple[np.ndarray, np.ndarray]:
    rows = np.asarray(rows).reshape(-1)
    rows = rows[rows >= 0]
    bank = np.asarray(bank_of_row)[rows]
    pos = bank * rows_per_bank + np.asarray(slot_of_row)[rows]
    width = np.asarray(byte_lut, np.int64)[np.asarray(tier)[pos]]
    reads = np.bincount(bank, minlength=n_banks).astype(np.int64)
    nbytes = np.bincount(bank, weights=width,
                         minlength=n_banks).astype(np.int64)
    return reads, nbytes


def _wang_hash_np(x: np.ndarray) -> np.ndarray:
    """uint32 wang hash, bit-for-bit the kernel's ``wang_hash``."""
    x = np.asarray(x).astype(np.uint32)
    x = (x ^ np.uint32(61)) ^ (x >> np.uint32(16))
    x = (x * np.uint32(9)).astype(np.uint32)
    x = x ^ (x >> np.uint32(4))
    x = (x * np.uint32(0x27D4EB2D)).astype(np.uint32)
    x = x ^ (x >> np.uint32(15))
    return x


def host_replica_cols(n_bags: int, k_max: int) -> np.ndarray:
    """numpy twin of ``replica_of_bag(arange(n_bags), k_max)``."""
    return (_wang_hash_np(np.arange(n_bags))
            % np.uint32(k_max)).astype(np.int32)


def host_replicated_bank_read_counts(bank_of_copy, rows, n_banks: int, *,
                                     k_max: int, bank_live=None) -> np.ndarray:
    rows = np.asarray(rows)
    flat = rows.reshape(-1, rows.shape[-1])
    cols = host_replica_cols(flat.shape[0], k_max)
    bank_of_copy = np.asarray(bank_of_copy)
    counts = np.zeros(n_banks, np.int64)
    live = None if bank_live is None else np.asarray(bank_live)
    for n, bag in enumerate(flat):
        bag = bag[bag >= 0]
        if bag.size == 0:
            continue
        banks_rc = bank_of_copy[bag]                         # (L, k)
        chosen = banks_rc[:, cols[n]]
        if live is None:
            np.add.at(counts, chosen, 1)
            continue
        live_rc = live[banks_rc]
        any_live = live_rc.any(axis=1)
        first_live = np.argmax(live_rc, axis=1)
        eff = np.where(live_rc[:, cols[n]], cols[n], first_live)
        bank = banks_rc[np.arange(len(bag)), eff]
        np.add.at(counts, bank[any_live], 1)
    return counts


# ---------------------------------------------------------------------------
# host-side aggregation into the metrics registry
# ---------------------------------------------------------------------------

class TrafficAccumulator:
    """Folds per-batch measured counts into the registry's per-bank series.

    Pre-registers the full ``obs.bank_*`` family up front (the CI
    metrics-schema gate keys on them): ``obs.bank_reads`` /
    ``obs.bank_bytes`` vector counters sized ``n_banks``, and
    ``obs.bank_share`` — a histogram of each batch's max-bank read share
    (1/n_banks is perfect balance).
    """

    def __init__(self, metrics, n_banks: int, *, row_nbytes: int = 0):
        self.n_banks = int(n_banks)
        self.row_nbytes = int(row_nbytes)
        self.reads = metrics.vector_counter(
            "obs.bank_reads", "measured row reads per bank (device counters)",
            size=self.n_banks)
        self.nbytes = metrics.vector_counter(
            "obs.bank_bytes", "measured bytes moved per bank",
            size=self.n_banks)
        self.share = metrics.histogram(
            "obs.bank_share", "per-batch max-bank share of measured reads")
        self.batches = 0

    def update(self, reads, nbytes=None) -> float:
        """Fold one batch's counts; returns its max-bank read share."""
        reads = np.asarray(reads, np.float64)
        if nbytes is None:
            nbytes = reads * self.row_nbytes
        self.reads.inc(reads)
        self.nbytes.inc(np.asarray(nbytes, np.float64))
        total = reads.sum()
        share = float(reads.max() / total) if total else 1.0 / self.n_banks
        self.share.observe(share)
        self.batches += 1
        return share
