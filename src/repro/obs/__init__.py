"""repro.obs — unified runtime observability (tracing, metrics, export).

The package ROOT is dependency-free (stdlib only — producers include the
deliberately-jax-free ``repro.dist.fault`` and the numpy-only benches):

* ``tracing``        — ``Tracer.span("device_step")`` host-side spans,
                       instants, and ``counter`` gauge samples;
                       ``trace_export.write_chrome_trace`` emits
                       Perfetto-loadable Chrome-trace JSON ('X'/'i'/'C').
* ``metrics``        — typed ``Counter``/``Gauge``/``Histogram`` (fixed
                       log-spaced buckets: p50/p99 from merges, not stored
                       samples) + fixed-size ``VectorCounter``/
                       ``VectorGauge`` per-bank series behind a
                       ``MetricRegistry``; plus ``empirical_percentile``,
                       the ONE home of the sorted-index percentile
                       convention the latency reports and committed
                       benches share.
* ``metrics_export`` — JSON snapshots (schema-stable: CI gates on the
                       key-path set), Prometheus text exposition (vector
                       metrics as labeled series), periodic writer, and
                       the CLIs' one-line machine summary.

Two submodules are NOT re-exported here, by design — import them directly:

* ``repro.obs.traffic`` — measured per-bank read/byte counters computed
  on-device inside the jit'd step (imports jax) + numpy recount twins and
  the ``TrafficAccumulator`` registry bridge.
* ``repro.obs.slo``     — the rolling-window SLO watchdog (numpy +
  ``repro.core.hwmodel``): modeled-vs-measured breach detection feeding
  the Replanner's bank-cost penalty hook.

See README.md §Observability for the CLI flags (``--trace-out``,
``--metrics-out``, ``--metrics-every``, ``--slo-p99-us``) and the
metric-name glossary.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricRegistry,
                               DEFAULT_BUCKETS, VectorCounter, VectorGauge,
                               empirical_p50, empirical_p99,
                               empirical_percentile, log_bucket_bounds)
from repro.obs.metrics_export import (PeriodicMetricsWriter, prometheus_text,
                                      snapshot_doc, summary_dict,
                                      summary_line, write_metrics_json)
from repro.obs.trace_export import chrome_trace_events, write_chrome_trace
from repro.obs.tracing import NULL_TRACER, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricRegistry", "DEFAULT_BUCKETS",
    "VectorCounter", "VectorGauge",
    "empirical_p50", "empirical_p99", "empirical_percentile",
    "log_bucket_bounds",
    "PeriodicMetricsWriter", "prometheus_text", "snapshot_doc",
    "summary_dict", "summary_line", "write_metrics_json",
    "chrome_trace_events", "write_chrome_trace",
    "NULL_TRACER", "Tracer",
]
