"""Host-side structured tracing: named spans -> Chrome-trace/Perfetto JSON.

The serve/train loops are host-driven: every micro-batch is a sequence of
host stages (assemble/rewrite, jitted device step, telemetry, maybe a
replan+migrate+swap) and the p99 question is always "which stage did the
spike live in". ``Tracer.span`` times those stages with plain
``perf_counter`` reads; ``trace_export.write_chrome_trace`` turns the record
list into the Chrome trace-event JSON Perfetto loads directly.

Contracts:

* **No device-sync side effects.** A span only reads the host clock. The
  caller decides where device work is forced (the serve loops already call
  ``jax.block_until_ready`` at the device-step boundary); a span around an
  UN-synced dispatch measures dispatch cost, which is sometimes exactly what
  you want. Nothing here touches jax, so tracing a jit'd step cannot add
  executables (tests/test_obs.py pins the zero-recompile assert).
* **Near-zero when disabled.** ``Tracer(enabled=False)`` (or the shared
  ``NULL_TRACER``) short-circuits ``span`` to a no-yield-cost context
  manager, so instrumented code paths keep one shape whether or not
  ``--trace-out`` was passed.
* **Thread-correct nesting.** The open-span stack is thread-local; records
  carry the thread id so a future background-planner thread shows up as its
  own Perfetto track.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time


@dataclasses.dataclass
class SpanRecord:
    """One completed span (Chrome trace 'X' event)."""

    name: str
    ts_us: float               # start, microseconds since the tracer epoch
    dur_us: float
    tid: int
    depth: int                 # nesting depth at start (0 = top level)
    args: dict


@dataclasses.dataclass
class InstantRecord:
    """A point event (Chrome trace 'i' event) — swap landed, fault fired."""

    name: str
    ts_us: float
    tid: int
    args: dict


@dataclasses.dataclass
class CounterRecord:
    """A gauge sample (Chrome trace 'C' event) — per-bank traffic, rolling
    p99. Perfetto renders each ``values`` key as one series in a counter
    track named ``name``, so a time-series of these becomes a load lane."""

    name: str
    ts_us: float
    tid: int
    values: dict


class Tracer:
    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.records: list[SpanRecord] = []
        self.instants: list[InstantRecord] = []
        self.counters: list[CounterRecord] = []
        self._epoch = time.perf_counter()
        self._local = threading.local()
        self._lock = threading.Lock()

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Time a host stage. Nestable; ``args`` land in the trace event's
        ``args`` payload (keep them small and JSON-serializable)."""
        if not self.enabled:
            yield
            return
        stack = self._stack()
        depth = len(stack)
        stack.append(name)
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            stack.pop()
            rec = SpanRecord(name=name, ts_us=(t0 - self._epoch) * 1e6,
                             dur_us=(t1 - t0) * 1e6,
                             tid=threading.get_ident(), depth=depth,
                             args=dict(args))
            with self._lock:
                self.records.append(rec)

    def instant(self, name: str, **args) -> None:
        """Mark a point in time (a swap landing, a fault firing)."""
        if not self.enabled:
            return
        rec = InstantRecord(name=name,
                            ts_us=(time.perf_counter() - self._epoch) * 1e6,
                            tid=threading.get_ident(), args=dict(args))
        with self._lock:
            self.instants.append(rec)

    def counter(self, name: str, **values) -> None:
        """Sample a gauge time-series (Chrome 'C' event): one call per
        batch per track; each keyword becomes a series in the track."""
        if not self.enabled:
            return
        rec = CounterRecord(name=name,
                            ts_us=(time.perf_counter() - self._epoch) * 1e6,
                            tid=threading.get_ident(),
                            values={k: float(v) for k, v in values.items()})
        with self._lock:
            self.counters.append(rec)

    # -- inspection helpers (tests, summaries) -------------------------------

    def span_names(self) -> set[str]:
        return {r.name for r in self.records}

    def spans(self, name: str) -> list[SpanRecord]:
        return [r for r in self.records if r.name == name]

    def total_us(self, name: str) -> float:
        """Summed duration of TOP-LEVEL-of-their-name spans. (Nested
        same-name spans would double-count; the serve loops don't nest
        same-name spans.)"""
        return sum(r.dur_us for r in self.records if r.name == name)


NULL_TRACER = Tracer(enabled=False)
