"""Serving substrate: per-family serve-step builders + request batching."""
