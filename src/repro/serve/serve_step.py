"""Serve-step builders per family + a micro-batching request queue.

The recsys serve path is the paper's object of study: p99-latency online
inference (batch 512), offline bulk scoring (262k), and retrieval scoring
(1 query x 1M candidates). The LM paths are prefill and KV-cache decode.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp


def build_recsys_serve(family_mod, cfg, statics, dist=None,
                       backend: str | None = None):
    """CTR scoring: forward + sigmoid.

    ``backend`` selects the embedding stage-2 implementation for families
    that expose the knob (dlrm: 'jnp' | 'pallas' | 'auto'); None keeps the
    family default.
    """
    kw = {} if backend is None else {"backend": backend}

    def serve(params, batch):
        logits = family_mod.forward(cfg, params, statics, batch, dist, **kw)
        return jax.nn.sigmoid(logits)
    return serve


def build_recsys_serve_cached(family_mod, cfg, statics, cache_table,
                              dist=None, backend: str | None = None):
    """Cache-aware CTR scoring (Fig. 7): requests pre-rewritten into
    (cache_idx, residual_idx) bags by the host pipeline."""
    kw = {} if backend is None else {"backend": backend}

    def serve(params, batch):
        logits = family_mod.forward_cached(cfg, params, statics, cache_table,
                                           batch, dist, **kw)
        return jax.nn.sigmoid(logits)
    return serve


def build_recsys_serve_cached_adaptive(family_mod, cfg, statics, dist=None,
                                       backend: str | None = None,
                                       with_traffic: bool = False):
    """Cache-aware CTR scoring under the ADAPTIVE runtime: everything a live
    swap replaces — the EMT remap vectors AND the GRACE cache table — enters
    as an argument of the returned ``serve(params, remap_bank, remap_slot,
    cache_table, batch)``, never as a closure constant. Table shapes are
    pinned (fixed ``rows_per_bank`` on the EMT, fixed ``cache_rows_per_bank``
    on the cache side), so one jit compilation serves every plan version:
    a swap is a pure argument change.

    ``with_traffic=True`` (a BUILD-time flag, not a jit argument) appends a
    measured per-bank read-count vector to the step's outputs:
    ``(scores, bank_reads)``. The counts are pure jnp over the same
    remap/cache arguments the lookup consumes (obs/traffic.py), so the
    traffic-instrumented step still compiles ONE executable across swaps.
    """
    kw = {} if backend is None else {"backend": backend}

    def serve(params, remap_bank, remap_slot, cache_table, batch):
        logits = family_mod.forward_cached(
            cfg, params, statics, cache_table, batch, dist,
            remap_bank=remap_bank, remap_slot=remap_slot, **kw)
        if with_traffic:
            from repro.obs.traffic import cached_bank_read_counts
            reads = cached_bank_read_counts(
                cache_table.remap_bank, batch["cache_idx"],
                remap_bank, batch["residual_idx"], cache_table.n_banks)
            return jax.nn.sigmoid(logits), reads
        return jax.nn.sigmoid(logits)
    return serve


def build_recsys_serve_degraded_adaptive(family_mod, cfg, statics, dist=None,
                                         backend: str | None = None,
                                         with_traffic: bool = False):
    """CTR scoring that stays up through bank failures: the returned
    ``serve(params, remap_bank, remap_slot, bank_live, batch)`` takes the
    per-bank liveness mask as ONE MORE swap-style argument next to the remap
    vectors — reads homed on a dead bank resolve to the zero row
    (core/embedding.py's bounded-degradation contract), and the step returns
    ``(scores, degraded_read_count)`` so every response carries exactly how
    many row contributions it is missing (0 = bit-exact). All-live serving
    through this step is bit-identical to the non-degraded step — the fault
    lane compiles ONE executable and flips the mask argument.

    ``with_traffic=True`` (build-time flag) appends the measured per-bank
    read counts: ``(scores, degraded_counts, bank_reads)``. Reads resolved
    to the zero row on a dead bank are NOT counted as bank traffic (the bank
    never served them) — ``bank_reads.sum() + degraded_counts.sum()`` equals
    the batch's valid lookups.
    """
    from repro.core.embedding import degraded_row_counts
    kw = {} if backend is None else {"backend": backend}

    def serve(params, remap_bank, remap_slot, bank_live, batch):
        st = {**statics, "remap_bank": remap_bank, "remap_slot": remap_slot}
        logits = family_mod.forward(cfg, params, st, batch, dist,
                                    bank_live=bank_live, **kw)
        sparse = batch["sparse"]
        offs = st["field_offsets"]
        offs = offs[None, :] if sparse.ndim == 2 else offs[None, :, None]
        rows = jnp.where(sparse >= 0, sparse + offs, -1)
        counts = degraded_row_counts(remap_bank, bank_live, rows)
        if with_traffic:
            from repro.obs.traffic import bank_read_counts
            reads = bank_read_counts(remap_bank, rows, bank_live.shape[0],
                                     bank_live=bank_live)
            return jax.nn.sigmoid(logits), counts, reads
        return jax.nn.sigmoid(logits), counts
    return serve


def build_recsys_serve_tiered_adaptive(family_mod, cfg, statics, dist=None,
                                       backend: str | None = None,
                                       with_traffic: bool = False):
    """CTR scoring over TIERED-precision embeddings under the adaptive
    runtime: the whole TieredTable pytree — quantized payload, per-row
    scales, tier map, AND the remap vectors — enters as an argument of the
    returned ``serve(params, tiered, batch)``. Payload/scale/tier shapes
    depend only on (capacity, dim, hot dtype), never on the tier mix, so a
    live re-tier swap (hot rows promoted, cold rows demoted on drift) is a
    pure argument change against one compiled executable.

    ``with_traffic=True`` (build-time flag) appends measured per-bank reads
    AND bytes: ``(scores, bank_reads, bank_nbytes)``. Bytes weight each read
    by its row's CURRENT tier width (the tier map rides in the ``tiered``
    argument), so a re-tier swap shows up in the byte series immediately.
    """
    kw = {} if backend is None else {"backend": backend}

    def serve(params, tiered, batch):
        logits = family_mod.forward(cfg, params, statics, batch, dist,
                                    tiered=tiered, **kw)
        if with_traffic:
            from repro.obs.traffic import tiered_bank_traffic
            from repro.quant import tier_nbytes
            sparse = batch["sparse"]
            offs = statics["field_offsets"]
            offs = offs[None, :] if sparse.ndim == 2 else offs[None, :, None]
            rows = jnp.where(sparse >= 0, sparse + offs, -1)
            traffic = tiered_bank_traffic(
                tiered.remap_bank, tiered.remap_slot, tiered.rows_per_bank,
                tiered.tier, tier_nbytes(tiered.dim, tiered.hot_dtype),
                rows, tiered.n_banks)
            return jax.nn.sigmoid(logits), traffic.reads, traffic.nbytes
        return jax.nn.sigmoid(logits)
    return serve


def build_recsys_serve_replicated_adaptive(family_mod, cfg, statics,
                                           dist=None,
                                           backend: str | None = None,
                                           with_traffic: bool = False):
    """CTR scoring over HOT-ROW-REPLICATED embeddings under the adaptive
    runtime: the whole ReplicatedTable pytree — the packed copies plus the
    ``(vocab, k_max)`` replica-axis remap — enters as an argument of the
    returned ``serve(params, replicated, bank_live, batch)``. Map shapes
    depend only on (vocab, k_max) and the packed shape only on the fixed
    per-bank capacity, never on WHICH rows are replicated, so a live
    replica-count swap (telemetry found a new head) is a pure argument
    change against one compiled executable. ``bank_live`` composes the
    fault lane in: a surviving copy covers a dead bank's head reads
    instantly, and the step returns ``(scores, degraded_read_count)`` where
    a read only counts degraded when EVERY copy of the row is dead.

    ``with_traffic=True`` (build-time flag) appends the measured per-bank
    reads — ``(scores, degraded_counts, bank_reads)`` — attributed to the
    copy each bag ACTUALLY reads (the same deterministic bag-hash routing
    and dead-copy failover the kernel applies), so replication's load split
    and a failover's traffic shift are both visible in the series.
    """
    from repro.core.embedding import degraded_row_counts
    kw = {} if backend is None else {"backend": backend}

    def serve(params, replicated, bank_live, batch):
        logits = family_mod.forward(cfg, params, statics, batch, dist,
                                    replicated=replicated,
                                    bank_live=bank_live, **kw)
        sparse = batch["sparse"]
        offs = statics["field_offsets"]
        offs = offs[None, :] if sparse.ndim == 2 else offs[None, :, None]
        rows = jnp.where(sparse >= 0, sparse + offs, -1)
        counts = degraded_row_counts(replicated.remap_bank, bank_live, rows)
        if with_traffic:
            from repro.obs.traffic import replicated_bank_read_counts
            reads = replicated_bank_read_counts(
                replicated.remap_bank, rows, bank_live.shape[0],
                k_max=replicated.k_max, bank_live=bank_live)
            return jax.nn.sigmoid(logits), counts, reads
        return jax.nn.sigmoid(logits), counts
    return serve


def build_retrieval_serve(family_mod, cfg, statics, dist=None, top_k: int = 128):
    """1 query x N candidates -> (top-k scores, top-k ids)."""
    def serve(params, batch):
        scores = family_mod.retrieval_scores(cfg, params, statics, batch, dist)
        return jax.lax.top_k(scores, top_k)
    return serve


def build_lm_decode(cfg, dist=None, seq_axes=("model",)):
    from repro.models.transformer import decode_step

    def serve(params, cache, token):
        return decode_step(cfg, params, cache, token, dist, seq_axes=seq_axes)
    return serve


def build_lm_prefill(cfg, dist=None):
    from repro.models.transformer import prefill

    def serve(params, tokens):
        return prefill(cfg, params, tokens, dist)
    return serve


# ---------------------------------------------------------------------------
# request micro-batcher (the online-inference half of the paper's Fig. 4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    features: dict
    t_arrival: float = dataclasses.field(default_factory=time.monotonic)


class MicroBatcher:
    """Collects requests into fixed-size batches (pad the tail) so the jitted
    serve step sees one static shape; tracks per-request latency.

    ``observer`` is the workload-telemetry tap (repro.workload): called as
    ``observer(feats, n_real)`` on every assembled batch, where ``n_real`` is
    the count of genuine (non-pad) requests — pad rows replicate a prototype
    request and must not be counted as traffic.
    """

    def __init__(self, batch_size: int, pad_request: dict,
                 observer: Callable[[dict, int], None] | None = None,
                 metrics=None):
        self.batch_size = batch_size
        self.pad_request = pad_request
        self.observer = observer
        self.queue: deque[Request] = deque()
        self.latencies: list[float] = []
        if metrics is None:
            from repro.obs import MetricRegistry
            metrics = MetricRegistry()
        self._m_requests = metrics.counter("serve.requests_total",
                                           "completed (non-pad) requests")
        self._m_latency = metrics.histogram(
            "serve.request_latency_ms", "arrival -> completion per request")

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def ready(self) -> bool:
        return len(self.queue) > 0

    def next_batch(self) -> tuple[list[Request], dict]:
        reqs = [self.queue.popleft()
                for _ in range(min(self.batch_size, len(self.queue)))]
        feats = {}
        n_pad = self.batch_size - len(reqs)
        for key in self.pad_request:
            rows = [r.features[key] for r in reqs]
            rows += [self.pad_request[key]] * n_pad
            feats[key] = jnp.stack([jnp.asarray(r) for r in rows])
        if self.observer is not None:
            self.observer(feats, len(reqs))
        return reqs, feats

    def complete(self, reqs: list[Request]) -> None:
        now = time.monotonic()
        for r in reqs:
            lat = now - r.t_arrival
            self.latencies.append(lat)
            self._m_latency.observe(lat * 1e3)
        self._m_requests.inc(len(reqs))

    def p99(self) -> float:
        from repro.obs import empirical_p99
        return empirical_p99(self.latencies)
