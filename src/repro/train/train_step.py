"""Train-step builders: value_and_grad + optimizer + (optional) compression,
with the TrainState pytree and mesh-aware jit wiring.

One builder serves every family: the family module supplies
``loss_fn(params, batch) -> scalar``; distribution comes from param/input
shardings (GSPMD) plus the shard_map islands inside the models (banked
embedding, seq-sharded decode, edge-sharded GNN).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train import optim as O
from repro.train import compress as C


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array
    err_state: Any = None      # error feedback buffers (compression on)

    @classmethod
    def create(cls, params, optimizer: O.Optimizer, compress: bool = False):
        return cls(params=params, opt_state=optimizer.init(params),
                   step=jnp.zeros((), jnp.int32),
                   err_state=C.init_error_state(params) if compress else None)


def _not_table(path: str) -> bool:
    return "packed" not in path and "embed" not in path


def build_train_step(
    loss_fn: Callable[..., jax.Array],
    optimizer: O.Optimizer,
    *,
    clip_norm: float | None = 1.0,
    compress_grads: bool = False,
    clip_include: Callable[[str], bool] = _not_table,
    loss_kwargs: dict | None = None,
) -> Callable[[TrainState, Any], tuple[TrainState, dict]]:
    """Returns step(state, batch) -> (state, metrics). Pure; jit at call site
    with in/out shardings from dist/sharding.py.

    ``loss_kwargs`` are forwarded to every ``loss_fn(params, batch, ...)``
    call — how launch/train.py binds the embedding backend pair
    (``backend``/``bwd_backend``) at the step boundary, so a
    ``backend='pallas'`` step runs the fused lookup kernel forward AND the
    sorted-run scatter kernel backward without a bespoke closure per config.

    Global-norm clipping skips embedding tables by default (§Perf C1): their
    row-wise Adagrad update is per-row scale-invariant and the full-table
    norm pass costs ~2 table reads/writes per step for nothing.
    """
    kw = dict(loss_kwargs or {})

    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = jax.value_and_grad(
            lambda p, b: loss_fn(p, b, **kw))(state.params, batch)
        metrics = {"loss": loss}
        if clip_norm is not None:
            grads, gnorm = O.clip_by_global_norm_filtered(
                grads, clip_norm, clip_include)
            metrics["grad_norm"] = gnorm
        err_state = state.err_state
        if compress_grads:
            grads, err_state = C.compress_roundtrip(grads, err_state)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              state.params, updates)
        return (TrainState(params=params, opt_state=opt_state,
                           step=state.step + 1, err_state=err_state),
                metrics)

    return step


def default_optimizer(lr: float = 1e-3, emb_lr: float = 1e-2) -> O.Optimizer:
    """Adam for dense params, row-wise Adagrad for embedding tables —
    the production DLRM recipe."""
    def is_table(path) -> bool:
        s = jax.tree_util.keystr(path)
        return "packed" in s or "embed" in s

    return O.multi_opt(is_table, O.rowwise_adagrad(emb_lr), O.adam(lr))
