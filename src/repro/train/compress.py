"""Int8 gradient compression with error feedback (1-bit-Adam-style residuals).

Two integration levels:

  * ``compress_roundtrip`` — quantize->dequantize with a persistent error-
    feedback buffer in the train state. Model-agnostic: it simulates exactly
    the numerics the wire-level compression produces, so convergence effects
    are testable on any arch here. (GSPMD owns the actual all-reduce, which
    JAX cannot intercept; the wire integration is the shard_map path below.)
  * ``psum_int8`` — the real wire-level op for explicit-collective (shard_map)
    training steps: per-tensor-scale int8 quantize, integer psum over the DP
    axis, dequantize. Used by train/dp_step.py for the DLRM path, where the
    embedding-gradient all-reduce over the data axis is THE dominant DP
    collective (4x bytes saved vs fp32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def quantize_int8(x: Array) -> tuple[Array, Array]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compress_roundtrip(grads, err_state):
    """Error-feedback quantization: g' = Q(g + e); e' = (g + e) - g'."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), x - deq

    out = jax.tree.map(one, grads, err_state)
    new_g = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_e = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_e


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def psum_int8(x: Array, axis_name, err: Array | None = None
              ) -> tuple[Array, Array]:
    """Wire-level compressed psum (inside shard_map): int8 over the link.

    int32 accumulation avoids overflow up to 2^24 participants; scale is the
    max over participants so all ranks dequantize identically. Returns
    (summed fp32, new error residual) for error feedback.
    """
    xf = x.astype(jnp.float32) + (err if err is not None else 0.0)
    q, scale = quantize_int8(xf)
    scale = jax.lax.pmax(scale, axis_name)          # shared scale
    q = jnp.clip(jnp.round(xf / scale), -127, 127)  # requantize at shared scale
    deq_local = q * scale
    new_err = xf - deq_local
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale, new_err
