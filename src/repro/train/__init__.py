"""Training substrate: optimizers, train-step builders, grad compression."""
