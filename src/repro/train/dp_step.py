"""Explicit data-parallel training step with wire-level int8 gradient psum.

This is the explicit-collective (shard_map) counterpart of train_step.py used
where we control the all-reduce directly: the model is replicated, the batch
shards over the given axes, per-device grads are quantized int8 with error
feedback and psum'd as integers — 4x less DP traffic, convergence preserved by
the residual (tests/test_compress.py). The production GSPMD path simulates the
same numerics via compress_roundtrip (see train/compress.py docstring).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train import compress as C
from repro.train import optim as O
from repro.train.train_step import TrainState

from repro.core.compat import shard_map

P = jax.sharding.PartitionSpec


def build_dp_compressed_step(
    loss_fn: Callable[[Any, Any], jax.Array],
    optimizer: O.Optimizer,
    mesh: jax.sharding.Mesh,
    dp_axes: tuple[str, ...],
):
    """loss_fn(params, local_batch) must be pure-local (dist=None inside)."""
    ax = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]

    def local_grads(params, err, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        out = jax.tree.map(lambda g, e: C.psum_int8(g, dp_axes, e),
                           grads, err)
        grads = jax.tree.map(lambda t: t[0] / n_dp, out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        loss = jax.lax.psum(loss, dp_axes) / n_dp
        return loss, grads, new_err

    def batch_specs(batch):
        return jax.tree.map(
            lambda x: P(ax, *([None] * (x.ndim - 1))), batch)

    def step(state: TrainState, batch):
        sharded = shard_map(
            local_grads, mesh=mesh,
            in_specs=(P(), P(), batch_specs(batch)),
            out_specs=(P(), P(), P()),
        )
        loss, grads, err = sharded(state.params, state.err_state, batch)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              state.params, updates)
        return (TrainState(params=params, opt_state=opt_state,
                           step=state.step + 1, err_state=err),
                {"loss": loss})

    return step
