"""Optimizers from first principles (no optax): Adam, row-wise Adagrad, SGD.

Row-wise Adagrad is the production DLRM choice for embedding tables (one
accumulator per ROW, not per element — 1/dim the optimizer memory, and the
update is scale-invariant per row). ``MultiOpt`` routes param subtrees by
path predicate so models mix Adam (dense) with row-wise Adagrad (tables).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params):
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g, grads), state
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
        return jax.tree.map(lambda m: -lr * m, new_m), new_m

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m, v, p):
            step = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step = step - lr * weight_decay * p
            return step

        return (jax.tree.map(upd, m, v, params),
                {"m": m, "v": v, "t": t})

    return Optimizer(init, update)


def rowwise_adagrad(lr: float, eps: float = 1e-8) -> Optimizer:
    """For 2D (rows, dim) tables: one accumulator per row.

    (§Perf C3 tried an einsum-reduced, per-row-scale variant to avoid fp32
    table-sized intermediates: REFUTED under the bytes-accessed metric —
    +10%, the einsum lowers with full fp32 operand converts. Kept this form.)
    """
    def init(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape[:1], jnp.float32) if p.ndim == 2
            else jnp.zeros_like(p), params)

    def update(grads, state, params):
        def upd(g, a):
            if g.ndim == 2:
                a_new = a + jnp.mean(g.astype(jnp.float32) ** 2, axis=1)
                step = -lr * g / (jnp.sqrt(a_new)[:, None] + eps)
                return step.astype(g.dtype), a_new
            a_new = a + g.astype(jnp.float32) ** 2
            return (-lr * g / (jnp.sqrt(a_new) + eps)).astype(g.dtype), a_new

        out = jax.tree.map(upd, grads, state)
        steps = jax.tree.map(lambda x: x[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda x: x[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return steps, new_state

    return Optimizer(init, update)


def multi_opt(route: Callable[[tuple], bool], opt_true: Optimizer,
              opt_false: Optimizer) -> Optimizer:
    """Route each leaf by its tree path: route(path)=True -> opt_true.

    Typical: ``lambda path: 'packed' in str(path) or 'embed' in str(path)``
    sends embedding tables to row-wise Adagrad, the rest to Adam.
    """
    def split(tree):
        flat = jax.tree_util.tree_flatten_with_path(tree)
        paths = [p for p, _ in flat[0]]
        return flat, paths

    def init(params):
        leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
        st_t = opt_true.init(
            [v for p, v in leaves if route(p)])
        st_f = opt_false.init(
            [v for p, v in leaves if not route(p)])
        return {"true": st_t, "false": st_f}

    def update(grads, state, params):
        gleaves, treedef = jax.tree_util.tree_flatten_with_path(grads)
        pleaves, _ = jax.tree_util.tree_flatten_with_path(params)
        g_t = [v for p, v in gleaves if route(p)]
        g_f = [v for p, v in gleaves if not route(p)]
        p_t = [v for p, v in pleaves if route(p)]
        p_f = [v for p, v in pleaves if not route(p)]
        s_t, st_t = opt_true.update(g_t, state["true"], p_t)
        s_f, st_f = opt_false.update(g_f, state["false"], p_f)
        it_t, it_f = iter(s_t), iter(s_f)
        steps = [next(it_t) if route(p) else next(it_f) for p, _ in gleaves]
        return (jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(grads), steps),
            {"true": st_t, "false": st_f})

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def clip_by_global_norm_filtered(grads, max_norm: float, include):
    """Clip only leaves where include(path) — §Perf C1: embedding tables are
    excluded (row-wise Adagrad is per-row scale-invariant, and a global-norm
    pass over a multi-GB sparse-touched gradient buffer is pure HBM waste)."""
    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    norm = jnp.sqrt(sum(
        jnp.sum(jnp.square(v.astype(jnp.float32)))
        for p, v in flat if include(jax.tree_util.keystr(p))))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    out = jax.tree_util.tree_map_with_path(
        lambda p, g: g * scale if include(jax.tree_util.keystr(p)) else g,
        grads)
    return out, norm


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr
