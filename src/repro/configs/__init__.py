"""Config registry: ``get_arch(arch_id)`` for every assigned architecture
(+ the paper's own updlrm config). See configs/shapes.py for the per-family
input-shape sets and ShapeDtypeStruct builders."""
from repro.configs.registry import ARCHS, ArchSpec, get_arch, list_archs

__all__ = ["ARCHS", "ArchSpec", "get_arch", "list_archs"]
