"""Exact assigned configs (sources in brackets) + reduced smoke variants.

Where the assignment leaves a dimension open (catalog sizes for DIN/BERT4Rec,
molecule features), the choice is recorded inline with rationale.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.models.bert4rec import Bert4RecConfig
from repro.models.din import DINConfig
from repro.models.dlrm import DLRMConfig
from repro.models.gat import GATConfig
from repro.models.transformer import LMConfig, MoESpec

# Criteo-Kaggle per-field cardinalities (facebookresearch/dlrm day-0 counts) —
# the standard public vocab set for DLRM-style models; sum = 33.76M rows.
CRITEO_KAGGLE_VOCABS = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572)

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")
GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                 # lm | dlrm | din | bert4rec | xdeepfm | gat
    config: Any
    reduced: Any
    shapes: tuple[str, ...]
    notes: str = ""


def _lm(arch_id, **kw):
    full = LMConfig(name=arch_id, **kw)
    red = dataclasses.replace(
        full, name=arch_id + "-reduced", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=max(1, 4 * kw["n_kv_heads"] // kw["n_heads"]),
        d_head=16, d_ff=128, vocab=512,
        moe=(MoESpec(8, min(8, full.moe.top_k)) if full.moe else None),
        q_chunk=16, kv_chunk=16, loss_chunk=16)
    return full, red


_smollm360, _smollm360_red = _lm(
    "smollm-360m", n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_head=64, d_ff=2560, vocab=49152, tied_embeddings=True)

_smollm135, _smollm135_red = _lm(
    "smollm-135m", n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_head=64, d_ff=1536, vocab=49152, tied_embeddings=True)

_granite20b, _granite20b_red = _lm(
    "granite-20b", n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_head=128, d_ff=24576, vocab=49152, mlp_type="gelu",
    tied_embeddings=True)

_qwen3moe, _qwen3moe_red = _lm(
    "qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_head=128, d_ff=768, vocab=151936, moe=MoESpec(128, 8))

_granitemoe, _granitemoe_red = _lm(
    "granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
    n_kv_heads=8, d_head=64, d_ff=512, vocab=49155, moe=MoESpec(32, 8),
    tied_embeddings=True)


import jax.numpy as _jnp  # noqa: E402

_dlrm = DLRMConfig(
    name="dlrm-rm2", vocab_sizes=CRITEO_KAGGLE_VOCABS, embed_dim=64,
    n_dense=13, bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256),
    emb_dtype=_jnp.bfloat16)   # §Perf C2 — fp32 Adagrad accumulator kept
_dlrm_red = DLRMConfig(
    name="dlrm-rm2-reduced", vocab_sizes=(100, 80, 60), embed_dim=8,
    n_dense=13, bot_mlp=(32, 8), top_mlp=(32, 16))

# DIN: assignment fixes embed_dim=18, seq=100, attn_mlp=80-40, mlp=200-80.
# Catalog sizes are open — industrial-scale choice (1M items / 1k categories)
# so the retrieval_cand shape (1M candidates) is well-defined.
_din = DINConfig(name="din", n_items=1_000_000, n_cates=1000, embed_dim=18,
                 seq_len=100, attn_mlp=(80, 40), mlp=(200, 80))
_din_red = DINConfig(name="din-reduced", n_items=500, n_cates=20, embed_dim=8,
                     seq_len=10, attn_mlp=(16, 8), mlp=(32, 16))

# BERT4Rec: embed_dim=64, 2 blocks, 2 heads, seq 200 per assignment; 1M-item
# catalog (same rationale as DIN).
# NOTE §Perf iteration B2 tried dtype=bf16 here: REFUTED under the unfused
# bytes-accessed metric (+17.6% — cast passes outweigh the savings the
# accounting can see; on real TPU fusion absorbs them). Kept fp32.
_b4r = Bert4RecConfig(name="bert4rec", n_items=1_000_000, embed_dim=64,
                      n_blocks=2, n_heads=2, seq_len=200)
_b4r_red = Bert4RecConfig(name="bert4rec-reduced", n_items=200, embed_dim=16,
                          n_blocks=2, n_heads=2, seq_len=16, d_ff=32,
                          n_negatives=32, max_masked=8)

# xDeepFM: 39 fields = 26 Criteo sparse + 13 bucketized-dense (64 buckets).
_xdfm = XDeepFMVOCABS = CRITEO_KAGGLE_VOCABS + (64,) * 13
from repro.models.xdeepfm import XDeepFMConfig  # noqa: E402

_xdeepfm = XDeepFMConfig(name="xdeepfm", vocab_sizes=XDeepFMVOCABS,
                         embed_dim=10, cin_layers=(200, 200, 200),
                         mlp=(400, 400))
_xdeepfm_red = XDeepFMConfig(name="xdeepfm-reduced",
                             vocab_sizes=(50,) * 5, embed_dim=4,
                             cin_layers=(8, 8), mlp=(16,))

# GAT: model hyperparams fixed (2L, hidden 8, heads 8, attn aggregator);
# d_feat/classes come from each shape's dataset (configs/shapes.py).
_gat = GATConfig(name="gat-cora", d_feat=1433, n_classes=7, n_layers=2,
                 d_hidden=8, n_heads=8)
_gat_red = GATConfig(name="gat-cora-reduced", d_feat=16, n_classes=3,
                     n_layers=2, d_hidden=4, n_heads=2)

# the paper's own workload: one Table-1 dataset duplicated into 8 EMTs,
# 32-dim embeddings, batch 64 (§4.1)
_updlrm = DLRMConfig(
    name="updlrm-paper", vocab_sizes=(2_360_650,) * 8, embed_dim=32,
    n_dense=13, bot_mlp=(512, 256, 32), top_mlp=(512, 256),
    multi_hot=256)
_updlrm_red = DLRMConfig(
    name="updlrm-paper-reduced", vocab_sizes=(500,) * 8, embed_dim=8,
    n_dense=13, bot_mlp=(32, 8), top_mlp=(32,), multi_hot=16)


ARCHS: dict[str, ArchSpec] = {
    "smollm-360m": ArchSpec("smollm-360m", "lm", _smollm360, _smollm360_red,
                            LM_SHAPES,
                            "[hf:HuggingFaceTB/SmolLM-360M] llama-arch GQA"),
    "smollm-135m": ArchSpec("smollm-135m", "lm", _smollm135, _smollm135_red,
                            LM_SHAPES,
                            "[hf:HuggingFaceTB/SmolLM-135M] llama-arch GQA"),
    "granite-20b": ArchSpec("granite-20b", "lm", _granite20b, _granite20b_red,
                            LM_SHAPES,
                            "[arXiv:2405.04324] MQA kv=1, gelu MLP, tied"),
    "qwen3-moe-30b-a3b": ArchSpec("qwen3-moe-30b-a3b", "lm", _qwen3moe,
                                  _qwen3moe_red, LM_SHAPES,
                                  "[hf:Qwen/Qwen3-30B-A3B] 128e top-8"),
    "granite-moe-1b-a400m": ArchSpec("granite-moe-1b-a400m", "lm",
                                     _granitemoe, _granitemoe_red, LM_SHAPES,
                                     "[hf:ibm-granite/granite-3.0-1b-a400m]"),
    "dlrm-rm2": ArchSpec("dlrm-rm2", "dlrm", _dlrm, _dlrm_red, RECSYS_SHAPES,
                         "[arXiv:1906.00091] Criteo-Kaggle vocabs"),
    "din": ArchSpec("din", "din", _din, _din_red, RECSYS_SHAPES,
                    "[arXiv:1706.06978]"),
    "bert4rec": ArchSpec("bert4rec", "bert4rec", _b4r, _b4r_red,
                         RECSYS_SHAPES, "[arXiv:1904.06690]"),
    "xdeepfm": ArchSpec("xdeepfm", "xdeepfm", _xdeepfm, _xdeepfm_red,
                        RECSYS_SHAPES, "[arXiv:1803.05170]"),
    "gat-cora": ArchSpec("gat-cora", "gat", _gat, _gat_red, GNN_SHAPES,
                         "[arXiv:1710.10903]"),
    # paper-faithful extra (not in the assigned 40 cells; used by benchmarks)
    "updlrm-paper": ArchSpec("updlrm-paper", "dlrm", _updlrm, _updlrm_red,
                             RECSYS_SHAPES, "paper §4.1 workload"),
}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def list_archs(assigned_only: bool = True) -> list[str]:
    out = [a for a in ARCHS if a != "updlrm-paper" or not assigned_only]
    return out
