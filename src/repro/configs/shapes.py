"""Per-family input-shape sets — the assigned 4 shapes per arch — and
builders for (a) ShapeDtypeStruct trees (dry-run, full config, no allocation)
and (b) concrete reduced batches (smoke tests).

Step kinds: "train" (train_step), "serve" (forward/score), "decode"
(one-token serve_step with KV cache), "prefill", "retrieval".
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ArchSpec, get_arch

i32, f32 = jnp.int32, jnp.float32


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    shape_id: str
    step_kind: str
    dims: dict[str, int]          # concrete global dims
    note: str = ""


# ---------------------------------------------------------------------------
# the assigned shape tables
# ---------------------------------------------------------------------------

LM_CELLS = {
    "train_4k": ShapeCell("train_4k", "train",
                          dict(seq=4096, batch=256)),
    "prefill_32k": ShapeCell("prefill_32k", "prefill",
                             dict(seq=32768, batch=32)),
    "decode_32k": ShapeCell("decode_32k", "decode",
                            dict(seq=32768, batch=128)),
    # long-context DECODE: one token vs 524k KV — O(S), sub-quadratic by
    # construction; runs for these full-attention archs (DESIGN.md §4).
    "long_500k": ShapeCell("long_500k", "decode",
                           dict(seq=524288, batch=1)),
}

RECSYS_CELLS = {
    "train_batch": ShapeCell("train_batch", "train", dict(batch=65536)),
    "serve_p99": ShapeCell("serve_p99", "serve", dict(batch=512)),
    "serve_bulk": ShapeCell("serve_bulk", "serve", dict(batch=262144)),
    "retrieval_cand": ShapeCell("retrieval_cand", "retrieval",
                                dict(batch=1, n_candidates=1_000_000)),
}

GNN_CELLS = {
    "full_graph_sm": ShapeCell(
        "full_graph_sm", "train",
        dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7),
        "Cora full-batch"),
    "minibatch_lg": ShapeCell(
        "minibatch_lg", "train",
        dict(n_nodes=232_965, n_edges=114_615_892, batch_nodes=1024,
             fanout0=15, fanout1=10, d_feat=602, n_classes=41),
        "Reddit-scale sampled (d_feat/classes per Reddit)"),
    "ogb_products": ShapeCell(
        "ogb_products", "train",
        dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
             n_classes=47),
        "ogbn-products full-batch"),
    "molecule": ShapeCell(
        "molecule", "train",
        dict(n_graphs=128, nodes_per=30, edges_per=64, d_feat=16,
             n_classes=2),
        "batched small graphs (d_feat=16 atom features — open choice)"),
}

# reduced dims for smoke tests (same structure, tiny)
LM_CELLS_RED = {
    "train_4k": dict(seq=64, batch=4),
    "prefill_32k": dict(seq=64, batch=2),
    "decode_32k": dict(seq=64, batch=2),
    "long_500k": dict(seq=128, batch=1),
}
RECSYS_CELLS_RED = {
    "train_batch": dict(batch=32),
    "serve_p99": dict(batch=8),
    "serve_bulk": dict(batch=64),
    "retrieval_cand": dict(batch=1, n_candidates=64),
}
GNN_CELLS_RED = {
    "full_graph_sm": dict(n_nodes=40, n_edges=120, d_feat=16, n_classes=3),
    "minibatch_lg": dict(batch_nodes=8, fanout0=3, fanout1=2, d_feat=16,
                         n_classes=3),
    "ogb_products": dict(n_nodes=100, n_edges=400, d_feat=16, n_classes=3),
    "molecule": dict(n_graphs=4, nodes_per=6, edges_per=10, d_feat=16,
                     n_classes=3),
}

SLATE = 500  # per-user candidate slate for bert4rec ranking serve


def get_cell(arch_id: str, shape_id: str) -> ShapeCell:
    spec = get_arch(arch_id)
    table = {"lm": LM_CELLS}.get(spec.family,
                                 GNN_CELLS if spec.family == "gat"
                                 else RECSYS_CELLS)
    return table[shape_id]


def gat_config_for_shape(base, dims: dict):
    return dataclasses.replace(base, d_feat=dims["d_feat"],
                               n_classes=dims["n_classes"])


def sampled_block_dims(batch_nodes: int, f0: int, f1: int) -> dict:
    """Worst-case padded sizes for 2-layer fanout sampling."""
    e1 = batch_nodes * f0                  # innermost block edges
    n1 = batch_nodes + e1                  # its src set
    e0 = n1 * f1                           # outer block edges
    n0 = n1 + e0
    return dict(n0=n0, e0=e0, n1=n1, e1=e1)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct builders (dry-run)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(arch_id: str, shape_id: str) -> tuple[str, dict]:
    """(step_kind, batch SDS tree) at FULL config. KV caches for decode cells
    are built separately (they are carried state, not batch)."""
    spec = get_arch(arch_id)
    cell = get_cell(arch_id, shape_id)
    d = cell.dims
    fam = spec.family
    cfg = spec.config

    if fam == "lm":
        B, S = d["batch"], d["seq"]
        if cell.step_kind == "train":
            return "train", {"tokens": _sds((B, S), i32),
                             "labels": _sds((B, S), i32)}
        if cell.step_kind == "prefill":
            return "prefill", {"tokens": _sds((B, S), i32)}
        return "decode", {"token": _sds((B,), i32)}

    if fam == "dlrm":
        B = d["batch"]
        F = cfg.n_sparse
        sp = ((B, F) if cfg.multi_hot == 1 else (B, F, cfg.multi_hot))
        base = {"dense": _sds((B, cfg.n_dense), f32), "sparse": _sds(sp, i32)}
        if cell.step_kind == "train":
            return "train", base | {"label": _sds((B,), f32)}
        if cell.step_kind == "retrieval":
            return "retrieval", base | {
                "candidates": _sds((d["n_candidates"],), i32)}
        return "serve", base

    if fam == "din":
        B = d["batch"]
        base = {"hist_items": _sds((B, cfg.seq_len), i32),
                "hist_cates": _sds((B, cfg.seq_len), i32)}
        if cell.step_kind == "retrieval":
            N = d["n_candidates"]
            return "retrieval", base | {"candidates": _sds((N,), i32),
                                        "candidate_cates": _sds((N,), i32)}
        base |= {"target_item": _sds((B,), i32),
                 "target_cate": _sds((B,), i32)}
        if cell.step_kind == "train":
            return "train", base | {"label": _sds((B,), f32)}
        return "serve", base

    if fam == "bert4rec":
        B = d["batch"]
        base = {"items": _sds((B, cfg.seq_len), i32)}
        if cell.step_kind == "train":
            extra = {"labels": _sds((B, cfg.seq_len), i32)}
            if cfg.loss == "sampled":
                extra["negatives"] = _sds((cfg.n_negatives,), i32)
            return "train", base | extra
        if cell.step_kind == "retrieval":
            return "retrieval", base | {
                "candidates": _sds((d["n_candidates"],), i32)}
        return "serve", base | {"candidates": _sds((B, SLATE), i32)}

    if fam == "xdeepfm":
        B = d["batch"]
        base = {"sparse": _sds((B, cfg.n_fields), i32)}
        if cell.step_kind == "train":
            return "train", base | {"label": _sds((B,), f32)}
        if cell.step_kind == "retrieval":
            return "retrieval", {"sparse": _sds((1, cfg.n_fields), i32),
                                 "candidates": _sds((d["n_candidates"],), i32)}
        return "serve", base

    if fam == "gat":
        if shape_id == "minibatch_lg":
            bd = sampled_block_dims(d["batch_nodes"], d["fanout0"],
                                    d["fanout1"])
            return "train", {
                "block0_feats": _sds((bd["n0"], d["d_feat"]), f32),
                "block0_src": _sds((bd["e0"],), i32),
                "block0_dst": _sds((bd["e0"],), i32),
                "block0_mask": _sds((bd["e0"],), jnp.bool_),
                "block1_src": _sds((bd["e1"],), i32),
                "block1_dst": _sds((bd["e1"],), i32),
                "block1_mask": _sds((bd["e1"],), jnp.bool_),
                "labels": _sds((d["batch_nodes"],), i32),
                "label_mask": _sds((d["batch_nodes"],), jnp.bool_),
            }
        if shape_id == "molecule":
            N = d["n_graphs"] * d["nodes_per"]
            E = d["n_graphs"] * d["edges_per"]
            return "train", {
                "features": _sds((N, d["d_feat"]), f32),
                "edge_src": _sds((E,), i32),
                "edge_dst": _sds((E,), i32),
                "graph_ids": _sds((N,), i32),
                "labels": _sds((d["n_graphs"],), i32),
            }
        return "train", {
            "features": _sds((d["n_nodes"], d["d_feat"]), f32),
            "edge_src": _sds((d["n_edges"],), i32),
            "edge_dst": _sds((d["n_edges"],), i32),
            "labels": _sds((d["n_nodes"],), i32),
            "label_mask": _sds((d["n_nodes"],), jnp.bool_),
        }

    raise ValueError(fam)


# ---------------------------------------------------------------------------
# concrete reduced batches (smoke tests)
# ---------------------------------------------------------------------------

def smoke_batch(arch_id: str, shape_id: str, seed: int = 0
                ) -> tuple[str, Any, dict]:
    """(step_kind, reduced_cfg, concrete batch) at REDUCED config."""
    from repro.data import synthetic as syn
    spec = get_arch(arch_id)
    cfg = spec.reduced
    fam = spec.family
    cell = get_cell(arch_id, shape_id)
    rng = np.random.default_rng(seed)

    if fam == "lm":
        rd = LM_CELLS_RED[shape_id]
        B, S = rd["batch"], rd["seq"]
        if cell.step_kind == "train":
            b = syn.lm_batch(B, S, cfg.vocab, seed=seed, step=0)
            return "train", cfg, b
        if cell.step_kind == "prefill":
            return "prefill", cfg, {"tokens": rng.integers(
                0, cfg.vocab, (B, S)).astype(np.int32)}
        return "decode", cfg, {
            "token": rng.integers(0, cfg.vocab, (B,)).astype(np.int32),
            "s_max": S}

    if fam == "dlrm":
        rd = RECSYS_CELLS_RED[shape_id]
        B = rd["batch"]
        mh = cfg.multi_hot
        b = syn.dlrm_batch(cfg.vocab_sizes, cfg.n_dense, B, seed=seed,
                           step=0, multi_hot=mh)
        if cell.step_kind == "retrieval":
            b = {k: v[:1] for k, v in b.items() if k != "label"}
            b["candidates"] = rng.integers(
                0, cfg.vocab_sizes[0], rd["n_candidates"]).astype(np.int32)
            return "retrieval", cfg, b
        if cell.step_kind == "serve":
            b.pop("label")
            return "serve", cfg, b
        return "train", cfg, b

    if fam == "din":
        rd = RECSYS_CELLS_RED[shape_id]
        B = rd["batch"]
        b = syn.din_batch(cfg.n_items, cfg.n_cates, cfg.seq_len, B,
                          seed=seed, step=0)
        if cell.step_kind == "retrieval":
            N = rd["n_candidates"]
            b = {"hist_items": b["hist_items"][:1],
                 "hist_cates": b["hist_cates"][:1],
                 "candidates": rng.integers(0, cfg.n_items, N).astype(np.int32),
                 "candidate_cates": rng.integers(0, cfg.n_cates, N).astype(np.int32)}
            return "retrieval", cfg, b
        if cell.step_kind == "serve":
            b.pop("label")
            return "serve", cfg, b
        return "train", cfg, b

    if fam == "bert4rec":
        rd = RECSYS_CELLS_RED[shape_id]
        B = rd["batch"]
        b = syn.bert4rec_batch(
            cfg.n_items, cfg.seq_len, B, seed=seed, step=0,
            n_negatives=cfg.n_negatives if cfg.loss == "sampled" else 0)
        if cell.step_kind == "train":
            return "train", cfg, b
        items = rng.integers(0, cfg.n_items, (B, cfg.seq_len)).astype(np.int32)
        if cell.step_kind == "retrieval":
            return "retrieval", cfg, {
                "items": items[:1],
                "candidates": rng.integers(0, cfg.n_items,
                                           rd["n_candidates"]).astype(np.int32)}
        return "serve", cfg, {
            "items": items,
            "candidates": rng.integers(0, cfg.n_items,
                                       (B, 16)).astype(np.int32)}

    if fam == "xdeepfm":
        rd = RECSYS_CELLS_RED[shape_id]
        B = rd["batch"]
        b = syn.xdeepfm_batch(cfg.vocab_sizes, B, seed=seed, step=0)
        if cell.step_kind == "retrieval":
            return "retrieval", cfg, {
                "sparse": b["sparse"][:1],
                "candidates": rng.integers(0, cfg.vocab_sizes[0],
                                           rd["n_candidates"]).astype(np.int32)}
        if cell.step_kind == "serve":
            b.pop("label")
            return "serve", cfg, b
        return "train", cfg, b

    if fam == "gat":
        rd = GNN_CELLS_RED[shape_id]
        gcfg = gat_config_for_shape(cfg, rd)
        if shape_id == "molecule":
            b = syn.molecule_batch(rd["n_graphs"], rd["nodes_per"],
                                   rd["edges_per"], rd["d_feat"],
                                   rd["n_classes"], seed=seed)
            return "train", gcfg, b
        if shape_id == "minibatch_lg":
            b = _smoke_sampled_blocks(rd, seed)
            return "train", gcfg, b
        b = syn.random_graph(rd["n_nodes"], rd["n_edges"], rd["d_feat"],
                             rd["n_classes"], seed=seed)
        return "train", gcfg, b

    raise ValueError(fam)


def _smoke_sampled_blocks(rd: dict, seed: int) -> dict:
    """Run the REAL neighbor sampler on a small random graph -> padded blocks."""
    from repro.data import synthetic as syn
    from repro.sparse.sampler import NeighborSampler, build_csr
    rng = np.random.default_rng(seed)
    g = syn.random_graph(200, 2000, rd["d_feat"], rd["n_classes"], seed=seed)
    csr = build_csr(g["edge_src"].astype(np.int64),
                    g["edge_dst"].astype(np.int64), 200)
    sampler = NeighborSampler(csr, (rd["fanout0"], rd["fanout1"]), seed=seed)
    seeds = rng.choice(200, rd["batch_nodes"], replace=False)
    blocks = sampler.sample(seeds)
    bd = sampled_block_dims(rd["batch_nodes"], rd["fanout0"], rd["fanout1"])

    def pad(a, n, fill=0):
        out = np.full((n,) + a.shape[1:], fill, a.dtype)
        out[:a.shape[0]] = a
        return out

    b0, b1 = blocks[0], blocks[1]
    feats = np.zeros((bd["n0"], rd["d_feat"]), np.float32)
    feats[:len(b0.src_ids)] = g["features"][b0.src_ids]
    return {
        "block0_feats": feats,
        "block0_src": pad(b0.edge_src, bd["e0"]),
        "block0_dst": pad(b0.edge_dst, bd["e0"]),
        "block0_mask": pad(b0.edge_mask, bd["e0"], False),
        "block1_src": pad(b1.edge_src, bd["e1"]),
        "block1_dst": pad(b1.edge_dst, bd["e1"]),
        "block1_mask": pad(b1.edge_mask, bd["e1"], False),
        "labels": g["labels"][seeds].astype(np.int32),
        "label_mask": np.ones(rd["batch_nodes"], bool),
    }
