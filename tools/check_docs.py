#!/usr/bin/env python
"""Docs-drift gate (CI `tests` job, blocking).

Two checks over the user-facing markdown:

1. Every ``--flag`` a doc mentions must exist in some argparse definition
   under ``src/repro/launch/``, ``benchmarks/`` or ``tools/`` — a renamed
   CLI knob whose README still advertises the old name fails CI.
2. Every relative markdown link must resolve to a real file in the repo.

Pure text scan — no imports of the scanned code (jax-free, runs first in
CI before anything heavy). Flags that are real but live outside this
repo's argparse (XLA env flags, pytest's own options) go in ALLOWED_EXTERNAL.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

DOCS = [
    "README.md",
    "ARCHITECTURE.md",
    "src/repro/workload/README.md",
    "src/repro/kernels/README.md",
]

# where --flags are defined (glob patterns relative to the repo root);
# obs/cli.py holds the shared --trace-out/--metrics-out wiring the launch
# CLIs delegate to
ARGPARSE_SOURCES = ["src/repro/launch/*.py", "src/repro/obs/cli.py",
                    "benchmarks/*.py", "tools/*.py"]

# real flags the docs mention that are not this repo's argparse to define
ALLOWED_EXTERNAL = {
    "--xla_force_host_platform_device_count",   # XLA_FLAGS env option
    "--strict-markers",                         # pytest option (pytest.ini)
}

FLAG_MENTION = re.compile(r"(?<![\w/-])--[a-z0-9][a-z0-9_-]*[a-z0-9]")
FLAG_DEF = re.compile(r"""add_argument\(\s*\n?\s*['"](--[a-z0-9][a-z0-9_-]*)""")
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def defined_flags() -> set[str]:
    flags = set()
    for pattern in ARGPARSE_SOURCES:
        for path in REPO.glob(pattern):
            flags.update(FLAG_DEF.findall(path.read_text()))
    return flags


def check_doc(doc: Path, known: set[str]) -> list[str]:
    errors = []
    text = doc.read_text()
    rel = doc.relative_to(REPO)
    for lineno, line in enumerate(text.splitlines(), 1):
        for flag in FLAG_MENTION.findall(line):
            if flag not in known and flag not in ALLOWED_EXTERNAL:
                errors.append(f"{rel}:{lineno}: flag {flag} not defined by "
                              f"any argparse under {ARGPARSE_SOURCES}")
        for target in MD_LINK.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{rel}:{lineno}: broken link {target} "
                              f"(-> {resolved})")
    return errors


def main() -> int:
    known = defined_flags()
    if not known:
        print("check_docs: found no argparse flag definitions — "
              "ARGPARSE_SOURCES is wrong", file=sys.stderr)
        return 2
    errors = []
    for name in DOCS:
        doc = REPO / name
        if not doc.exists():
            errors.append(f"{name}: listed in DOCS but missing")
            continue
        errors.extend(check_doc(doc, known))
    if errors:
        print(f"check_docs: {len(errors)} problem(s)", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print(f"check_docs: OK ({len(DOCS)} docs, {len(known)} known flags)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
