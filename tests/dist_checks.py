"""Multi-device distribution checks — run as a SUBPROCESS by test_dist.py so
the forced 8-device host platform never leaks into the main pytest process.

Each check compares a distributed execution (shard_map / GSPMD on the 4x2
mesh) against the single-device reference — numerically, not just shapes.
Exits nonzero on any mismatch.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.embedding import (DistCtx, banked_embedding_bag, pack_table)
from repro.core.partitioning import non_uniform_partition

P = jax.sharding.PartitionSpec
FAILED = []


def check(name, ok):
    print(("PASS " if ok else "FAIL ") + name, flush=True)
    if not ok:
        FAILED.append(name)


def mesh42():
    from repro.core.compat import make_mesh
    return make_mesh((4, 2), ("data", "model"))


def check_banked_lookup_distributed():
    rng = np.random.default_rng(0)
    V, D, banks = 64, 16, 2
    table = rng.standard_normal((V, D)).astype(np.float32)
    freq = rng.random(V) + 0.1
    plan = non_uniform_partition(freq, banks)
    bt = pack_table(table, plan)
    idx = jnp.array(rng.integers(-1, V, (8, 5)), jnp.int32)
    mesh = mesh42()
    dist = DistCtx(mesh=mesh, dp_axes=("data",))
    got = jax.jit(lambda t, i: banked_embedding_bag(t, i, dist))(bt, idx)
    want = banked_embedding_bag(bt, idx, None)
    check("banked_lookup_distributed", np.allclose(got, want, atol=1e-5))


def check_banked_lookup_grads():
    """d(loss)/d(packed) must match the single-device gradient — the banked
    table trains correctly through the psum combine."""
    rng = np.random.default_rng(1)
    V, D, banks = 32, 8, 2
    table = rng.standard_normal((V, D)).astype(np.float32)
    plan = non_uniform_partition(rng.random(V) + 0.1, banks)
    bt = pack_table(table, plan)
    idx = jnp.array(rng.integers(-1, V, (8, 4)), jnp.int32)
    mesh = mesh42()
    dist = DistCtx(mesh=mesh, dp_axes=("data",))

    def loss_d(packed):
        t2 = jax.tree.map(lambda x: x, bt)
        t2.packed = packed
        return banked_embedding_bag(t2, idx, dist).sum()

    def loss_l(packed):
        t2 = jax.tree.map(lambda x: x, bt)
        t2.packed = packed
        return banked_embedding_bag(t2, idx, None).sum()

    gd = jax.jit(jax.grad(loss_d))(bt.packed)
    gl = jax.grad(loss_l)(bt.packed)
    check("banked_lookup_grads", np.allclose(gd, gl, atol=1e-5))


def check_banked_pallas_backend():
    """Pallas stage 2 (interpret mode) INSIDE the shard_map == jnp backend,
    forward and gradient — the fused-kernel production path."""
    rng = np.random.default_rng(7)
    V, D, banks = 64, 16, 2
    table = rng.standard_normal((V, D)).astype(np.float32)
    plan = non_uniform_partition(rng.random(V) + 0.1, banks)
    bt = pack_table(table, plan)
    fo = jnp.array([0, 20, 40], jnp.int32)
    idx = jnp.array(rng.integers(-1, 20, (8, 3, 5)), jnp.int32)
    mesh = mesh42()
    dist = DistCtx(mesh=mesh, dp_axes=("data",))
    want = banked_embedding_bag(bt, idx, None, backend="jnp",
                                field_offsets=fo)
    got = jax.jit(lambda t, i: banked_embedding_bag(
        t, i, dist, backend="pallas", field_offsets=fo))(bt, idx)
    check("banked_pallas_backend_fwd",
          np.allclose(got, want, atol=1e-5))

    import dataclasses

    def loss(packed, backend, d):
        t2 = dataclasses.replace(bt, packed=packed)
        return (banked_embedding_bag(t2, idx, d, backend=backend,
                                     field_offsets=fo) ** 2).sum()

    gl = jax.grad(lambda p: loss(p, "jnp", None))(bt.packed)
    gd = jax.jit(jax.grad(lambda p: loss(p, "pallas", dist)))(bt.packed)
    check("banked_pallas_backend_grad", np.allclose(gd, gl, atol=1e-4))


def check_seqsharded_decode():
    from repro.dist.collectives import seqsharded_decode_attention
    rng = np.random.default_rng(2)
    B, S, Hq, Hkv, Dh = 4, 16, 4, 2, 8
    q = jnp.array(rng.standard_normal((B, Hq, Dh)), jnp.float32)
    kn = jnp.array(rng.standard_normal((B, Hkv, Dh)), jnp.float32)
    vn = jnp.array(rng.standard_normal((B, Hkv, Dh)), jnp.float32)
    kc = jnp.array(rng.standard_normal((B, S, Hkv, Dh)), jnp.float32)
    vc = jnp.array(rng.standard_normal((B, S, Hkv, Dh)), jnp.float32)
    pos = jnp.int32(7)
    mesh = mesh42()
    dist = DistCtx(mesh=mesh, dp_axes=("data",))
    o_d, kc_d, vc_d = jax.jit(
        lambda q, kn, vn, kc, vc: seqsharded_decode_attention(
            q, kn, vn, kc, vc, pos, dist=dist, seq_axes=("model",)))(
        q, kn, vn, kc, vc)
    o_l, kc_l, vc_l = seqsharded_decode_attention(
        q, kn, vn, kc, vc, pos, dist=None)
    ok = (np.allclose(o_d, o_l, atol=1e-4)
          and np.allclose(kc_d, kc_l, atol=1e-6)
          and np.allclose(vc_d, vc_l, atol=1e-6))
    check("seqsharded_decode", ok)
    # seq sharded over BOTH axes (the long_500k layout, batch replicated)
    dist2 = DistCtx(mesh=mesh, dp_axes=("data",))
    o_d2, kc_d2, _ = jax.jit(
        lambda q, kn, vn, kc, vc: seqsharded_decode_attention(
            q, kn, vn, kc, vc, pos, dist=dist2,
            seq_axes=("data", "model")))(q, kn, vn, kc, vc)
    check("seqsharded_decode_allaxes",
          np.allclose(o_d2, o_l, atol=1e-4)
          and np.allclose(kc_d2, kc_l, atol=1e-6))


def check_gat_edge_sharded():
    from repro.configs import get_arch
    from repro.data.synthetic import random_graph
    from repro.models import gat as G
    cfg = get_arch("gat-cora").reduced
    g = random_graph(40, 128, cfg.d_feat, cfg.n_classes, seed=3)
    batch = {k: jnp.asarray(v) for k, v in g.items()}
    batch["edge_mask"] = jnp.ones_like(batch["edge_src"], bool)
    params = G.init_params(cfg, jax.random.key(0))
    mesh = mesh42()
    dist = DistCtx(mesh=mesh, dp_axes=("data",))
    loss_d = jax.jit(lambda p: G.loss_full(cfg, p, batch, dist))(params)
    loss_l = G.loss_full(cfg, params, batch, None)
    check("gat_edge_sharded_loss", np.allclose(loss_d, loss_l, atol=1e-4))
    gd = jax.jit(jax.grad(lambda p: G.loss_full(cfg, p, batch, dist)))(params)
    gl = jax.grad(lambda p: G.loss_full(cfg, p, batch, None))(params)
    ok = all(np.allclose(a, b, atol=1e-4) for a, b in
             zip(jax.tree.leaves(gd), jax.tree.leaves(gl)))
    check("gat_edge_sharded_grads", ok)


def check_dp_compressed_step():
    from repro.configs import get_arch
    from repro.data.synthetic import dlrm_batch
    from repro.models import dlrm as D
    from repro.train.dp_step import build_dp_compressed_step
    from repro.train.optim import adam
    from repro.train.train_step import TrainState, build_train_step
    cfg = get_arch("dlrm-rm2").reduced
    params, statics = D.init_params(cfg, jax.random.key(0))
    mesh = mesh42()
    loss = lambda p, b: D.loss_fn(cfg, p, statics, b)
    opt = adam(1e-2)
    step_c = build_dp_compressed_step(loss, opt, mesh, ("data", "model"))
    state = TrainState.create(params, opt, compress=True)
    state_ref = TrainState.create(params, opt)
    step_r = jax.jit(build_train_step(loss, opt, clip_norm=None))
    losses_c, losses_r = [], []
    for i in range(15):
        b = dlrm_batch(cfg.vocab_sizes, cfg.n_dense, 64, seed=0, step=0)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        state, mc = step_c(state, b)
        state_ref, mr = step_r(state_ref, b)
        losses_c.append(float(mc["loss"]))
        losses_r.append(float(mr["loss"]))
    # compressed training converges like uncompressed (within tolerance)
    check("dp_compressed_converges",
          losses_c[-1] < losses_c[0]
          and abs(losses_c[-1] - losses_r[-1]) < 0.15)


def check_csr_sharded_lookup():
    """Balanced-split CSR lookup (flat stream sharded over dp) == the
    replicating csr_embedding_bag, jnp and pallas stage 2."""
    from repro.core.embedding import (balanced_csr_shards,
                                      csr_embedding_bag,
                                      csr_embedding_bag_sharded)
    rng = np.random.default_rng(11)
    V, D, banks = 64, 16, 2
    table = rng.standard_normal((V, D)).astype(np.float32)
    plan = non_uniform_partition(rng.random(V) + 0.1, banks)
    bt = pack_table(table, plan)
    lens = rng.integers(1, 9, 13)
    offsets = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    indices = rng.integers(0, V, int(offsets[-1])).astype(np.int32)
    mesh = mesh42()
    dist = DistCtx(mesh=mesh, dp_axes=("data",))
    bounds = balanced_csr_shards(offsets, dist.dp_size())
    totals = offsets[bounds[1:]] - offsets[bounds[:-1]]
    check("csr_split_balanced",
          totals.max() - totals.min() <= lens.max())
    want = csr_embedding_bag(bt, jnp.asarray(indices),
                             jnp.asarray(offsets[:13]), 13, None,
                             backend="jnp")
    for backend in ("jnp", "pallas"):
        got = csr_embedding_bag_sharded(bt, indices, offsets, 13, dist,
                                        backend=backend)
        check(f"csr_sharded_{backend}", np.allclose(got, want, atol=1e-5))
        # single-device fallback (dp collapses away) honors both offset forms
        got1 = csr_embedding_bag_sharded(bt, indices, offsets, 13, None,
                                         backend=backend)
        got2 = csr_embedding_bag_sharded(bt, indices, offsets[:13], 13, None,
                                         backend=backend)
        check(f"csr_sharded_fallback_{backend}",
              np.allclose(got1, want, atol=1e-5)
              and np.allclose(got2, want, atol=1e-5))


def check_migration_sharded():
    """shard_map migration (local permutation + psum row exchange) is
    bit-identical to a fresh pack of the same rows under the new plan —
    on BOTH exchange shapes: 'compact' ((n_moved, D) psum) and 'full'
    (packed-size psum, the parity baseline)."""
    from repro.workload import migrate_table
    rng = np.random.default_rng(13)
    V, D, banks = 96, 8, 2
    table = rng.standard_normal((V, D)).astype(np.float32)
    cap = (V // banks) + 16
    plan_a = non_uniform_partition(rng.random(V) + 0.1, banks,
                                   capacity_rows=cap)
    plan_b = non_uniform_partition(np.roll(rng.random(V) + 0.1, 31), banks,
                                   capacity_rows=cap)
    from repro.workload.migrate import permute_packed_rows
    import dataclasses
    t_a = pack_table(table, plan_a)
    t_a = dataclasses.replace(
        t_a,
        packed=permute_packed_rows(
            jnp.asarray(table),
            np.arange(V, dtype=np.int32),
            (plan_a.bank_of_row.astype(np.int64) * cap
             + plan_a.slot_of_row).astype(np.int32),
            banks * cap),
        rows_per_bank=cap)
    mesh = mesh42()
    dist = DistCtx(mesh=mesh, dp_axes=("data",))
    fresh = np.zeros((banks * cap, D), np.float32)
    fresh[plan_b.bank_of_row.astype(np.int64) * cap + plan_b.slot_of_row] \
        = table
    for exchange in ("compact", "full"):
        t_mig = migrate_table(t_a, plan_b, dist, rows_per_bank=cap,
                              exchange=exchange)
        check(f"migration_sharded_bitexact_{exchange}",
              (np.asarray(t_mig.packed) == fresh).all())
    # no-move replan: the compact path drops the collective entirely and
    # must still reproduce the (identical) layout bit-for-bit
    t_same = migrate_table(t_a, plan_a, dist, rows_per_bank=cap)
    check("migration_sharded_nomove",
          (np.asarray(t_same.packed) == np.asarray(t_a.packed)).all())


def check_cache_swap_sharded():
    """Live cache-path swap ON THE MESH: shard_map-migrated EMT + re-summed
    fixed-capacity GRACE table serve bit-identically (via the fused
    cache+residual lookup with its psum combine) to a from-scratch
    single-device rebuild at the same plan — the serve-side contract of
    launch/serve.py --adaptive --partition cache_aware."""
    import dataclasses as dc
    from repro.core.cache_runtime import (build_cache_table_fixed,
                                          cap_cache_plan, entry_banks)
    from repro.core.embedding import banked_cache_residual_bag
    from repro.core.grace import mine_cooccurrence
    from repro.workload import migrate_table, unpacked_rows
    from repro.workload.migrate import permute_packed_rows

    rng = np.random.default_rng(29)
    V, D, banks, cap, crpb = 96, 8, 2, (96 // 2) + 12, 8
    table = rng.standard_normal((V, D)).astype(np.float32)
    plan_a = non_uniform_partition(rng.random(V) + 0.1, banks,
                                   capacity_rows=cap)
    plan_b = non_uniform_partition(np.roll(rng.random(V) + 0.1, 31), banks,
                                   capacity_rows=cap)
    t_a = pack_table(table, plan_a)
    t_a = dc.replace(
        t_a,
        packed=permute_packed_rows(
            jnp.asarray(table), np.arange(V, dtype=np.int32),
            (plan_a.bank_of_row.astype(np.int64) * cap
             + plan_a.slot_of_row).astype(np.int32), banks * cap),
        rows_per_bank=cap)
    mesh = mesh42()
    dist = DistCtx(mesh=mesh, dp_axes=("data",))

    # the swap, sharded: migrate the EMT on the mesh, re-sum the cache side
    t_mig = migrate_table(t_a, plan_b, dist, rows_per_bank=cap)
    bags = [rng.choice(24, rng.integers(2, 7)) for _ in range(300)]
    cp = mine_cooccurrence(bags, top_items=48, max_groups=16, min_support=2)
    fcp = cap_cache_plan(cp, entry_banks(cp, plan_b.bank_of_row, None),
                         banks, crpb)
    ct = build_cache_table_fixed(unpacked_rows(t_mig), fcp, dtype=np.float32)

    # from-scratch single-device rebuild at the same plan
    t_fresh = dc.replace(
        pack_table(table, plan_b),
        packed=permute_packed_rows(
            jnp.asarray(table), np.arange(V, dtype=np.int32),
            (plan_b.bank_of_row.astype(np.int64) * cap
             + plan_b.slot_of_row).astype(np.int32), banks * cap),
        rows_per_bank=cap)
    ct_fresh = build_cache_table_fixed(table, fcp, dtype=np.float32)
    check("cache_swap_sharded_tables",
          (np.asarray(t_mig.packed) == np.asarray(t_fresh.packed)).all()
          and (np.asarray(ct.packed) == np.asarray(ct_fresh.packed)).all())

    ci = jnp.asarray(rng.integers(-1, fcp.n_entries or 1, (8, 3)), jnp.int32)
    ri = jnp.asarray(rng.integers(-1, V, (8, 6)), jnp.int32)
    fused = jax.jit(lambda t, c: banked_cache_residual_bag(
        t, c, ci, ri, dist, backend="jnp"))
    got = fused(t_mig, ct)
    # swapped vs fresh through the SAME sharded serve step: bit-identical
    # (the tables are; psum order is fixed). vs the single-device reference:
    # numerically equal (the psum's combine order differs in the last ulp).
    check("cache_swap_sharded_serve_bitexact",
          (np.asarray(got) == np.asarray(fused(t_fresh, ct_fresh))).all())
    want = banked_cache_residual_bag(t_fresh, ct_fresh, ci, ri, None,
                                     backend="jnp")
    check("cache_swap_sharded_serve_vs_local",
          np.allclose(got, want, atol=1e-5))


def check_pallas_backward_sharded():
    """The sorted-run Pallas scatter backward INSIDE the shard_map matches
    the XLA scatter fallback and the local jnp gradient, on all three
    custom_vjp paths (rectangular multi-field, fused cache+residual, CSR).
    This is the config that exposed the argsort-consumption miscompile the
    kernels' derived-operand prep works around."""
    import dataclasses
    from repro.core.embedding import (banked_cache_residual_bag,
                                      csr_embedding_bag)
    from repro.core.partitioning import uniform_partition
    rng = np.random.default_rng(23)
    V, D, banks = 64, 16, 2
    table = rng.standard_normal((V, D)).astype(np.float32)
    plan = non_uniform_partition(rng.random(V) + 0.1, banks)
    bt = pack_table(table, plan)
    fo = jnp.array([0, 20, 40], jnp.int32)
    idx = jnp.array(rng.integers(-1, 20, (8, 3, 5)), jnp.int32)
    mesh = mesh42()
    dist = DistCtx(mesh=mesh, dp_axes=("data",))

    def loss(packed, bwd, d):
        t2 = dataclasses.replace(bt, packed=packed)
        return (banked_embedding_bag(t2, idx, d, backend="pallas",
                                     bwd_backend=bwd,
                                     field_offsets=fo) ** 2).sum()

    gl = jax.grad(lambda p: loss(p, "jnp", None))(bt.packed)
    gp = jax.jit(jax.grad(lambda p: loss(p, "pallas", dist)))(bt.packed)
    gs = jax.jit(jax.grad(lambda p: loss(p, "jnp", dist)))(bt.packed)
    check("pallas_bwd_sharded_rect",
          np.allclose(gp, gl, atol=1e-4) and np.allclose(gp, gs, atol=1e-4))

    nc = 24
    ctab = rng.standard_normal((nc, D)).astype(np.float32)
    cbt = pack_table(ctab, uniform_partition(nc, banks))
    ci = jnp.asarray(rng.integers(-1, nc, (8, 3, 4)), jnp.int32)
    ri = jnp.asarray(rng.integers(-1, V, (8, 3, 6)), jnp.int32)

    def loss_c(ep, cp, bwd, d):
        t2 = dataclasses.replace(bt, packed=ep)
        c2 = dataclasses.replace(cbt, packed=cp)
        return (banked_cache_residual_bag(t2, c2, ci, ri, d,
                                          backend="pallas",
                                          bwd_backend=bwd) ** 2).sum()

    ge_p, gc_p = jax.jit(jax.grad(
        lambda e, c: loss_c(e, c, "pallas", dist),
        argnums=(0, 1)))(bt.packed, cbt.packed)
    ge_l, gc_l = jax.grad(
        lambda e, c: loss_c(e, c, "jnp", None),
        argnums=(0, 1))(bt.packed, cbt.packed)
    check("pallas_bwd_sharded_cache",
          np.allclose(ge_p, ge_l, atol=1e-4)
          and np.allclose(gc_p, gc_l, atol=1e-4))

    total, num_bags = 41, 7
    indices = jnp.asarray(rng.integers(-1, V, (total,)), jnp.int32)
    cuts = np.sort(rng.choice(np.arange(1, total), num_bags - 1,
                              replace=False))
    offsets = jnp.asarray(np.concatenate([[0], cuts]), jnp.int32)

    def loss_r(packed, bwd, d):
        t2 = dataclasses.replace(bt, packed=packed)
        return (csr_embedding_bag(t2, indices, offsets, num_bags, d,
                                  backend="pallas",
                                  bwd_backend=bwd) ** 2).sum()

    g_p = jax.jit(jax.grad(lambda p: loss_r(p, "pallas", dist)))(bt.packed)
    g_l = jax.grad(lambda p: loss_r(p, "jnp", None))(bt.packed)
    check("pallas_bwd_sharded_csr", np.allclose(g_p, g_l, atol=1e-4))


def check_tiered_lookup_sharded():
    """Tiered-precision lookup (repro.quant): shard_map stage 2 with
    in-kernel dequant matches the local path on both backends, and the
    straight-through gradient onto the fp master table matches the local
    full-precision gradient."""
    from repro.core.embedding import tiered_embedding_bag
    from repro.quant import QuantSpec, assign_tiers, build_tiered_table

    rng = np.random.default_rng(6)
    V, D, banks = 200, 16, 2
    table = (rng.standard_normal((V, D)) * 0.01).astype(np.float32)
    freq = rng.random(V) + 0.1
    plan = non_uniform_partition(freq, banks)
    bt = pack_table(table, plan)
    tiers = assign_tiers(freq, QuantSpec(byte_budget=12.0, min_hot_rows=4),
                         D).tier_of_row
    tt = build_tiered_table(bt, tiers)
    idx = jnp.array(rng.integers(-1, V, (8, 2, 5)), jnp.int32)
    mesh = mesh42()
    dist = DistCtx(mesh=mesh, dp_axes=("data",))
    loc = tiered_embedding_bag(bt.packed, tt, idx, None, backend="jnp")
    for be in ("jnp", "pallas"):
        sh = tiered_embedding_bag(bt.packed, tt, idx, dist, backend=be)
        check(f"tiered_lookup_sharded_{be}",
              np.allclose(np.asarray(sh), np.asarray(loc), atol=1e-6))
    g_loc = jax.grad(lambda p: tiered_embedding_bag(
        p, tt, idx, None, backend="jnp").sum())(bt.packed)
    g_sh = jax.grad(lambda p: tiered_embedding_bag(
        p, tt, idx, dist, backend="pallas").sum())(bt.packed)
    check("tiered_st_grads_sharded",
          np.allclose(np.asarray(g_sh), np.asarray(g_loc), atol=1e-6))


def check_degraded_serve_through_failure():
    """Fault-tolerant serving ON THE MESH: a bank dies mid-stream and the
    sharded lookup (a) stays bit-identical to the healthy path for requests
    not touching the dead bank, (b) zero-fills exactly the dead-bank rows
    (== the healthy path with those ids masked out), then (c) after the
    recovery replan + sharded migration, bit-matches a fresh pack — the
    serve-side contract of launch/serve.py --inject-bank-failure."""
    import dataclasses as dc
    from repro.core.compat import make_mesh
    from repro.core.embedding import degraded_row_counts
    from repro.workload import migrate_table
    from repro.workload.migrate import permute_packed_rows

    rng = np.random.default_rng(31)
    V, D, banks = 256, 8, 8
    cap = 40                         # 1.25x slack: one death is absorbable
    table = rng.standard_normal((V, D)).astype(np.float32)
    freq = rng.random(V) + 0.1
    plan = non_uniform_partition(freq, banks, capacity_rows=cap)
    t = dc.replace(
        pack_table(table, plan),
        packed=permute_packed_rows(
            jnp.asarray(table), np.arange(V, dtype=np.int32),
            (plan.bank_of_row.astype(np.int64) * cap
             + plan.slot_of_row).astype(np.int32), banks * cap),
        rows_per_bank=cap)
    mesh = make_mesh((1, banks), ("data", "model"))
    dist = DistCtx(mesh=mesh, dp_axes=("data",))
    idx = jnp.asarray(rng.integers(-1, V, (8, 6)), jnp.int32)
    all_live = jnp.ones(banks, dtype=bool)

    # healthy: the mask argument is a no-op bit-for-bit
    healthy = banked_embedding_bag(t, idx, dist)
    with_mask = banked_embedding_bag(t, idx, dist, bank_live=all_live)
    check("degraded_serve_healthy_mask_noop",
          (np.asarray(healthy) == np.asarray(with_mask)).all())

    # kill the most-loaded bank: degraded == healthy with dead ids masked
    dead = int(np.argmax(plan.load_per_bank))
    live = np.ones(banks, dtype=bool)
    live[dead] = False
    got = banked_embedding_bag(t, idx, dist, bank_live=jnp.asarray(live))
    idx_np = np.asarray(idx)
    on_dead = (idx_np >= 0) \
        & (plan.bank_of_row[np.where(idx_np >= 0, idx_np, 0)] == dead)
    masked = jnp.asarray(np.where(on_dead, -1, idx_np))
    want = banked_embedding_bag(t, masked, dist)
    check("degraded_serve_bounded",
          (np.asarray(got) == np.asarray(want)).all() and on_dead.any())
    counts = np.asarray(degraded_row_counts(t.remap_bank,
                                            jnp.asarray(live), idx))
    check("degraded_serve_counts_confined",
          (counts == on_dead.sum(axis=-1)).all())

    # recovery: replan off the dead bank, migrate ON THE MESH, bit-match a
    # fresh pack; the recovered table serves clean (zero degraded reads)
    plan2 = non_uniform_partition(freq, banks, capacity_rows=cap,
                                  bank_capacity_rows=np.where(live, cap, 0))
    t2 = migrate_table(t, plan2, dist, rows_per_bank=cap)
    fresh = np.zeros((banks * cap, D), np.float32)
    fresh[plan2.bank_of_row.astype(np.int64) * cap + plan2.slot_of_row] \
        = table
    check("degraded_recovery_migration_bitexact",
          (np.asarray(t2.packed) == fresh).all()
          and (np.asarray(t2.remap_bank) != dead).all())
    recovered = banked_embedding_bag(t2, idx, dist,
                                     bank_live=jnp.asarray(live))
    counts2 = np.asarray(degraded_row_counts(t2.remap_bank,
                                             jnp.asarray(live), idx))
    check("degraded_recovery_serves_clean",
          (counts2 == 0).all()
          and np.allclose(np.asarray(recovered), np.asarray(healthy),
                          atol=1e-6))


def check_lm_gspmd_matches_local():
    from repro.configs import get_arch
    from repro.models import transformer as T
    cfg = get_arch("smollm-135m").reduced
    params = T.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab)
    labels = jnp.roll(toks, -1, 1)
    mesh = mesh42()
    dist = DistCtx(mesh=mesh, dp_axes=("data",))
    from repro.dist.sharding import lm_param_shardings
    sh = lm_param_shardings(dist, params)
    params_d = jax.device_put(params, sh)
    loss_d = jax.jit(lambda p, t, l: T.lm_loss(cfg, p, t, l, dist))(
        params_d, toks, labels)
    loss_l = T.lm_loss(cfg, params, toks, labels, None)
    check("lm_gspmd_loss_matches", np.allclose(loss_d, loss_l, rtol=2e-3))


if __name__ == "__main__":
    check_banked_lookup_distributed()
    check_banked_lookup_grads()
    check_banked_pallas_backend()
    check_seqsharded_decode()
    check_gat_edge_sharded()
    check_dp_compressed_step()
    check_csr_sharded_lookup()
    check_migration_sharded()
    check_cache_swap_sharded()
    check_pallas_backward_sharded()
    check_tiered_lookup_sharded()
    check_degraded_serve_through_failure()
    check_lm_gspmd_matches_local()
    if FAILED:
        print("FAILED:", FAILED)
        sys.exit(1)
    print("ALL DIST CHECKS PASSED")
