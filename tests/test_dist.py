"""Multi-device distribution correctness — runs dist_checks.py in a
subprocess with 8 forced host devices (keeps this pytest process at 1 device,
as smoke tests/benches require)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(900)
def test_distribution_checks():
    script = os.path.join(os.path.dirname(__file__), "dist_checks.py")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, script], capture_output=True,
                          text=True, env=env, timeout=880)
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distribution checks failed"
    assert "ALL DIST CHECKS PASSED" in proc.stdout
