"""Per-arch smoke tests: REDUCED config, one forward/train step on CPU,
assert output shapes + finite values — every assigned (arch x shape) cell."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.configs.shapes import smoke_batch


def _tree_finite(tree) -> bool:
    return all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


def _init(spec, cfg):
    key = jax.random.key(0)
    fam = spec.family
    if fam == "lm":
        from repro.models import transformer as T
        return T.init_params(cfg, key), None
    if fam == "gat":
        from repro.models import gat as G
        return G.init_params(cfg, key), None
    mod = __import__(f"repro.models.{fam}", fromlist=["init_params"])
    params, statics = mod.init_params(cfg, key)
    return params, statics


CELLS = [(a, s) for a in list_archs() for s in get_arch(a).shapes]


@pytest.mark.parametrize("arch_id,shape_id", CELLS,
                         ids=[f"{a}-{s}" for a, s in CELLS])
def test_cell_smoke(arch_id, shape_id):
    spec = get_arch(arch_id)
    kind, cfg, batch = smoke_batch(arch_id, shape_id)
    params, statics = _init(spec, cfg)
    fam = spec.family
    batch = {k: (jnp.asarray(v) if hasattr(v, "ndim") else v)
             for k, v in batch.items()}

    if fam == "lm":
        from repro.models import transformer as T
        if kind == "train":
            loss = jax.jit(lambda p, b: T.lm_loss(cfg, p, b["tokens"],
                                                  b["labels"]))(params, batch)
            assert loss.shape == () and bool(jnp.isfinite(loss))
        elif kind == "prefill":
            logits = jax.jit(lambda p, t: T.prefill(cfg, p, t))(
                params, batch["tokens"])
            assert logits.shape == (batch["tokens"].shape[0],
                                    cfg.padded_vocab)
            assert bool(jnp.isfinite(logits[:, :cfg.vocab]).all())
        else:  # decode
            B = batch["token"].shape[0]
            cache = T.KVCache.empty(cfg, B, batch["s_max"])
            logits, cache = jax.jit(
                lambda p, c, t: T.decode_step(cfg, p, c, t))(
                params, cache, batch["token"])
            assert logits.shape == (B, cfg.padded_vocab)
            assert int(cache.length) == 1
            assert bool(jnp.isfinite(logits[:, :cfg.vocab]).all())
        return

    if fam == "gat":
        from repro.models import gat as G
        if shape_id == "molecule":
            loss = jax.jit(lambda p: G.loss_molecule(cfg, p, batch))(params)
        elif shape_id == "minibatch_lg":
            loss = jax.jit(lambda p: G.loss_blocks(cfg, p, batch))(params)
        else:
            loss = jax.jit(lambda p: G.loss_full(cfg, p, batch))(params)
        assert loss.shape == () and bool(jnp.isfinite(loss))
        return

    mod = __import__(f"repro.models.{fam}", fromlist=["forward"])
    if kind == "train":
        loss = jax.jit(lambda p: mod.loss_fn(cfg, p, statics, batch))(params)
        assert loss.shape == () and bool(jnp.isfinite(loss))
    elif kind == "retrieval":
        scores = jax.jit(
            lambda p: mod.retrieval_scores(cfg, p, statics, batch))(params)
        assert scores.ndim in (1, 2) and bool(jnp.isfinite(scores).all())
    else:
        if fam == "bert4rec":
            scores = jax.jit(
                lambda p: mod.next_item_scores(cfg, p, statics, batch))(params)
            assert bool(jnp.isfinite(scores).all())
        else:
            logits = jax.jit(
                lambda p: mod.forward(cfg, p, statics, batch))(params)
            assert logits.shape[0] == jax.tree.leaves(batch)[0].shape[0]
            assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch_id", list_archs())
def test_param_count_sanity(arch_id):
    """Full-config param counts land near the published sizes."""
    spec = get_arch(arch_id)
    n = spec.config.param_count()
    expected = {
        "smollm-360m": (3.0e8, 4.3e8),
        "smollm-135m": (1.1e8, 1.7e8),
        "granite-20b": (1.8e10, 2.2e10),
        "qwen3-moe-30b-a3b": (2.8e10, 3.3e10),
        "granite-moe-1b-a400m": (1.1e9, 1.5e9),
        "dlrm-rm2": (2.1e9, 2.3e9),       # 33.76M rows x 64 + MLPs
        "din": (1.8e7, 2.4e7),
        "bert4rec": (6.3e7, 6.9e7),
        "xdeepfm": (3.6e8, 4.2e8),
        "gat-cora": (9e4, 1.2e5),
    }[arch_id]
    assert expected[0] <= n <= expected[1], f"{arch_id}: {n:.3g}"
