"""Training substrate: optimizers, train-step convergence, compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train import compress as C
from repro.train import optim as O
from repro.train.train_step import TrainState, build_train_step, default_optimizer


def _quadratic_loss(target):
    def loss(params, batch):
        return jnp.mean((params["w"] - target) ** 2) + 0.0 * batch["x"].sum()
    return loss


class TestOptimizers:
    def _converges(self, opt, steps=200, tol=0.05):
        target = jnp.array([1.0, -2.0, 3.0])
        loss = _quadratic_loss(target)
        step = build_train_step(loss, opt, clip_norm=None)
        state = TrainState.create({"w": jnp.zeros(3)}, opt)
        batch = {"x": jnp.zeros(1)}
        stepj = jax.jit(step)
        for _ in range(steps):
            state, m = stepj(state, batch)
        return float(m["loss"]) < tol

    def test_sgd(self):
        assert self._converges(O.sgd(0.1))

    def test_sgd_momentum(self):
        assert self._converges(O.sgd(0.05, momentum=0.9))

    def test_adam(self):
        assert self._converges(O.adam(0.1))

    def test_rowwise_adagrad_on_table(self):
        opt = O.rowwise_adagrad(0.5)
        target = jnp.arange(12.0).reshape(4, 3)
        loss = lambda p, b: jnp.mean((p["t"] - target) ** 2)
        step = jax.jit(build_train_step(loss, opt, clip_norm=None))
        state = TrainState.create({"t": jnp.zeros((4, 3))}, opt)
        for _ in range(300):
            state, m = step(state, {})
        assert float(m["loss"]) < 0.5
        # accumulator is per-row
        assert state.opt_state["t"].shape == (4,)

    def test_multi_opt_routing(self):
        opt = default_optimizer(lr=0.05, emb_lr=0.5)
        params = {"emb_packed": jnp.zeros((6, 2)), "dense": {"w": jnp.zeros(3)}}
        target_e = jnp.ones((6, 2))
        target_w = jnp.array([1.0, 2.0, 3.0])
        loss = lambda p, b: (jnp.mean((p["emb_packed"] - target_e) ** 2)
                             + jnp.mean((p["dense"]["w"] - target_w) ** 2))
        step = jax.jit(build_train_step(loss, opt, clip_norm=None))
        state = TrainState.create(params, opt)
        for _ in range(300):
            state, m = step(state, {})
        assert float(m["loss"]) < 0.1

    def test_clip_by_global_norm(self):
        g = {"a": jnp.array([3.0, 4.0])}
        clipped, norm = O.clip_by_global_norm(g, 1.0)
        assert np.isclose(float(norm), 5.0)
        assert np.isclose(float(jnp.linalg.norm(clipped["a"])), 1.0)

    def test_cosine_schedule(self):
        lr = O.cosine_schedule(1.0, warmup=10, total=100)
        assert float(lr(0)) == 0.0
        assert np.isclose(float(lr(10)), 1.0, atol=0.1)
        assert float(lr(100)) < 0.01


class TestCompression:
    def test_quantize_roundtrip_small_error(self):
        rng = np.random.default_rng(0)
        x = jnp.array(rng.standard_normal(1000), jnp.float32)
        q, s = C.quantize_int8(x)
        err = np.abs(np.asarray(C.dequantize_int8(q, s) - x))
        assert err.max() <= float(s) / 2 + 1e-6

    def test_error_feedback_accumulates(self):
        """With error feedback, the QUANTIZED sum over steps tracks the true
        sum (residual carries what quantization dropped)."""
        rng = np.random.default_rng(1)
        g = jnp.array(rng.standard_normal(100) * 1e-3, jnp.float32)
        err = {"g": jnp.zeros(100)}
        tot = np.zeros(100)
        for _ in range(50):
            out, err = C.compress_roundtrip({"g": g}, err)
            tot += np.asarray(out["g"])
        np.testing.assert_allclose(tot, np.asarray(g) * 50, rtol=0.15,
                                   atol=1e-3)

    def test_compressed_training_converges(self):
        opt = O.adam(0.1)
        target = jnp.array([1.0, -2.0, 3.0])
        loss = _quadratic_loss(target)
        step = jax.jit(build_train_step(loss, opt, clip_norm=None,
                                        compress_grads=True))
        state = TrainState.create({"w": jnp.zeros(3)}, opt, compress=True)
        for _ in range(200):
            state, m = step(state, {"x": jnp.zeros(1)})
        assert float(m["loss"]) < 0.05


class TestLMTraining:
    def test_tiny_lm_loss_decreases(self):
        from repro.configs import get_arch
        from repro.data.synthetic import lm_batch
        from repro.models import transformer as T
        cfg = get_arch("smollm-135m").reduced
        params = T.init_params(cfg, jax.random.key(0))
        opt = default_optimizer(lr=3e-3, emb_lr=3e-2)
        loss_fn = lambda p, b: T.lm_loss(cfg, p, b["tokens"], b["labels"])
        step = jax.jit(build_train_step(loss_fn, opt))
        state = TrainState.create(params, opt)
        losses = []
        for i in range(20):
            b = lm_batch(4, 32, cfg.vocab, seed=0, step=0)  # memorize 1 batch
            state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]

    def test_dlrm_train_decreases(self):
        from repro.configs import get_arch
        from repro.data.synthetic import dlrm_batch
        from repro.models import dlrm as D
        cfg = get_arch("dlrm-rm2").reduced
        params, statics = D.init_params(cfg, jax.random.key(0))
        opt = default_optimizer(lr=1e-2, emb_lr=5e-2)
        loss_fn = lambda p, b: D.loss_fn(cfg, p, statics, b)
        step = jax.jit(build_train_step(loss_fn, opt))
        state = TrainState.create(params, opt)
        losses = []
        for i in range(30):
            b = dlrm_batch(cfg.vocab_sizes, cfg.n_dense, 64, seed=0, step=0)
            state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], (losses[0], losses[-1])
