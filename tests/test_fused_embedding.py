"""Parity tests for the fused production lookup path (ISSUE 1 tentpole):

pallas (interpret) backend vs the jnp scan backend vs the kernels/ref.py
oracles — multi-field bags with in-kernel offsets, fused cache+residual,
CSR-ragged bags, and the custom_vjp gradient vs jax.grad of the reference —
across fp32/bf16 tables and odd (non-128-multiple) D.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.embedding import (BankedTable, banked_cache_residual_bag,
                                  banked_embedding_bag, csr_embedding_bag,
                                  pack_table)
from repro.core.partitioning import non_uniform_partition, uniform_partition
from repro.kernels import ref as REF


def _banked(rng, v, d, banks, dtype=jnp.float32):
    table = rng.standard_normal((v, d)).astype(np.float32)
    plan = non_uniform_partition(rng.random(v) + 0.1, banks)
    return table, pack_table(table, plan, dtype=dtype)


def _multihot(rng, b, f, l, vocab_sizes):
    idx = np.full((b, f, l), -1, np.int32)
    for bb in range(b):
        for ff in range(f):
            n = rng.integers(0, l + 1)
            idx[bb, ff, :n] = rng.integers(0, vocab_sizes[ff], n)
    return jnp.asarray(idx)


@pytest.mark.parametrize("d", [16, 33, 128])       # incl. odd D
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_multifield_pallas_matches_jnp_and_ref(d, dtype):
    rng = np.random.default_rng(d)
    vocab_sizes = (40, 30, 30)
    v = sum(vocab_sizes)
    offs = np.concatenate([[0], np.cumsum(vocab_sizes)[:-1]]).astype(np.int32)
    table, bt = _banked(rng, v, d, banks=4, dtype=dtype)
    idx = _multihot(rng, 9, 3, 5, vocab_sizes)
    fo = jnp.asarray(offs)

    got_p = banked_embedding_bag(bt, idx, None, backend="pallas",
                                 field_offsets=fo)
    got_j = banked_embedding_bag(bt, idx, None, backend="jnp",
                                 field_offsets=fo)
    # oracle: offset rows through the reference bag sum on the raw table
    rows = jnp.where(idx >= 0, idx + fo[None, :, None], -1)
    want = REF.embedding_bag_ref(
        jnp.asarray(table, dtype), rows.reshape(-1, idx.shape[-1])
    ).reshape(got_p.shape)

    atol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(got_p, np.float32),
                               np.asarray(got_j, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(got_p, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("d", [8, 33])
def test_fused_cache_residual_matches_ref(d):
    rng = np.random.default_rng(d + 1)
    v, nc = 80, 24
    table, bt = _banked(rng, v, d, banks=4)
    ctab_raw = rng.standard_normal((nc, d)).astype(np.float32)
    cbt = pack_table(ctab_raw, uniform_partition(nc, 2))
    ci = jnp.asarray(rng.integers(-1, nc, (10, 3, 4)), jnp.int32)
    ri = jnp.asarray(rng.integers(-1, v, (10, 3, 6)), jnp.int32)

    got_p = banked_cache_residual_bag(bt, cbt, ci, ri, None,
                                      backend="pallas")
    got_j = banked_cache_residual_bag(bt, cbt, ci, ri, None, backend="jnp")
    want = REF.cache_bag_ref(
        jnp.asarray(table), jnp.asarray(ctab_raw),
        ci.reshape(-1, ci.shape[-1]), ri.reshape(-1, ri.shape[-1])
    ).reshape(got_p.shape)

    np.testing.assert_allclose(np.asarray(got_p), np.asarray(got_j),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want),
                               atol=1e-4)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_multifield_grads_match_reference(backend):
    """custom_vjp scatter-add backward == jax.grad of the reference path."""
    rng = np.random.default_rng(3)
    vocab_sizes = (20, 22)
    v, d = sum(vocab_sizes), 24
    offs = np.concatenate([[0], np.cumsum(vocab_sizes)[:-1]]).astype(np.int32)
    table, bt = _banked(rng, v, d, banks=4)
    idx = _multihot(rng, 8, 2, 5, vocab_sizes)
    fo = jnp.asarray(offs)

    def loss(packed):
        t2 = dataclasses.replace(bt, packed=packed)
        return (banked_embedding_bag(t2, idx, None, backend=backend,
                                     field_offsets=fo) ** 2).sum()

    def loss_ref(packed):
        t2 = dataclasses.replace(bt, packed=packed)
        rows = jnp.where(idx >= 0, idx + fo[None, :, None], -1)
        flat = t2.remap_bank * t2.rows_per_bank + t2.remap_slot
        safe = jnp.where(rows >= 0, rows, 0)
        g = jnp.take(packed, flat[safe], axis=0)
        g = jnp.where((rows >= 0)[..., None], g, 0)
        return (g.sum(-2) ** 2).sum()

    np.testing.assert_allclose(loss(bt.packed), loss_ref(bt.packed),
                               rtol=1e-5)
    got = jax.grad(loss)(bt.packed)
    want = jax.grad(loss_ref)(bt.packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_fused_cache_residual_grads():
    """Gradients flow to BOTH tables through the fused kernel."""
    rng = np.random.default_rng(4)
    v, nc, d = 50, 12, 16
    table, bt = _banked(rng, v, d, banks=2)
    ctab_raw = rng.standard_normal((nc, d)).astype(np.float32)
    cbt = pack_table(ctab_raw, uniform_partition(nc, 2))
    ci = jnp.asarray(rng.integers(-1, nc, (8, 4)), jnp.int32)
    ri = jnp.asarray(rng.integers(-1, v, (8, 6)), jnp.int32)

    def loss(emt_packed, cache_packed, backend):
        t2 = dataclasses.replace(bt, packed=emt_packed)
        c2 = dataclasses.replace(cbt, packed=cache_packed)
        return (banked_cache_residual_bag(t2, c2, ci, ri, None,
                                          backend=backend) ** 2).sum()

    ge_p, gc_p = jax.grad(loss, argnums=(0, 1))(bt.packed, cbt.packed,
                                                "pallas")
    ge_j, gc_j = jax.grad(loss, argnums=(0, 1))(bt.packed, cbt.packed, "jnp")
    np.testing.assert_allclose(np.asarray(ge_p), np.asarray(ge_j), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gc_p), np.asarray(gc_j), atol=1e-4)
    assert float(jnp.abs(gc_p).sum()) > 0     # cache table really trains


def test_bf16_table_grads_accumulate_fp32():
    """Colliding scatter-adds onto a hot row must not round away in bf16:
    the custom_vjp accumulates fp32 and casts once at the end. 300 hits of
    cotangent 1.0 on one row => grad exactly 300 (bf16 sequential adds would
    stall near 256, where the ulp is 2)."""
    rng = np.random.default_rng(0)
    v, d, b, l = 16, 8, 25, 12
    table, bt = _banked(rng, v, d, banks=2, dtype=jnp.bfloat16)
    idx = jnp.zeros((b, l), jnp.int32)            # every entry hits row 0

    def loss(packed):
        t2 = dataclasses.replace(bt, packed=packed)
        return banked_embedding_bag(t2, idx, None, backend="pallas").sum()

    g = jax.grad(loss)(bt.packed)
    hot = int(bt.remap_bank[0]) * bt.rows_per_bank + int(bt.remap_slot[0])
    np.testing.assert_allclose(np.asarray(g, np.float32)[hot],
                               np.full(d, b * l, np.float32))


# ---------------------------------------------------------------------------
# Pallas backward (sorted-run scatter kernel) vs the XLA scatter fallback
# (ISSUE 3 tentpole): same pallas forward, bwd_backend='pallas' vs 'jnp'.
# fp32 must BIT-match (the prep's stable slot-sort preserves the fallback's
# per-slot accumulation order); bf16 tolerance-matches (both accumulate
# fp32, cast once).
# ---------------------------------------------------------------------------

def _grad_pair(loss_of_bwd, *args):
    gp = jax.grad(lambda *a: loss_of_bwd("pallas", *a), argnums=tuple(
        range(len(args))))(*args)
    gj = jax.grad(lambda *a: loss_of_bwd("jnp", *a), argnums=tuple(
        range(len(args))))(*args)
    return gp, gj


def _assert_bwd_match(gp, gj, dtype):
    for p, j in zip(jax.tree.leaves(gp), jax.tree.leaves(gj)):
        if dtype == jnp.float32:
            np.testing.assert_array_equal(np.asarray(p), np.asarray(j))
        else:
            np.testing.assert_allclose(np.asarray(p, np.float32),
                                       np.asarray(j, np.float32), atol=0.3)


@pytest.mark.parametrize("d", [16, 33, 128])       # incl. odd D
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_bwd_rect_sweep(d, dtype):
    """Rectangular multi-field path: kernel scatter == XLA scatter."""
    rng = np.random.default_rng(d + 100)
    vocab_sizes = (40, 30, 30)
    v = sum(vocab_sizes)
    offs = np.concatenate([[0], np.cumsum(vocab_sizes)[:-1]]).astype(np.int32)
    table, bt = _banked(rng, v, d, banks=4, dtype=dtype)
    idx = _multihot(rng, 9, 3, 5, vocab_sizes)
    fo = jnp.asarray(offs)

    def loss(bwd, packed):
        t2 = dataclasses.replace(bt, packed=packed)
        return (banked_embedding_bag(t2, idx, None, backend="pallas",
                                     bwd_backend=bwd,
                                     field_offsets=fo) ** 2).sum()

    gp, gj = _grad_pair(loss, bt.packed)
    _assert_bwd_match(gp, gj, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_bwd_collisions_in_tile(dtype):
    """The case the in-VMEM accumulator must get right: the same row
    duplicated WITHIN a bag and ACROSS bags of the same tile (tile_b=8, so
    bags 0..7 collide in one grid step), plus a -1 hole inside a bag."""
    rng = np.random.default_rng(5)
    v, d, b, l = 24, 16, 8, 6
    table, bt = _banked(rng, v, d, banks=2, dtype=dtype)
    idx = np.asarray(rng.integers(0, v, (b, l)), np.int32)
    idx[:, 0] = 3                  # every bag hits row 3 (cross-bag)
    idx[0, 1:4] = 3                # bag 0 hits it 3 more times (in-bag)
    idx[2, 2] = -1                 # interior hole stays masked
    idx = jnp.asarray(idx)

    def loss(bwd, packed):
        t2 = dataclasses.replace(bt, packed=packed)
        return (banked_embedding_bag(t2, idx, None, backend="pallas",
                                     bwd_backend=bwd) ** 2).sum()

    gp, gj = _grad_pair(loss, bt.packed)
    _assert_bwd_match(gp, gj, dtype)
    # the hot row really saw every colliding contribution
    hot = int(bt.remap_bank[3]) * bt.rows_per_bank + int(bt.remap_slot[3])
    assert float(jnp.abs(jnp.asarray(gp[0], jnp.float32)[hot]).sum()) > 0


@pytest.mark.parametrize("d", [8, 33])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_bwd_cache_residual_sweep(d, dtype):
    """Fused cache+residual: the DUAL scatter (one cotangent onto both the
    EMT and the cache table) matches the XLA fallback on both tables."""
    rng = np.random.default_rng(d + 200)
    v, nc = 80, 24
    table, bt = _banked(rng, v, d, banks=4, dtype=dtype)
    ctab_raw = rng.standard_normal((nc, d)).astype(np.float32)
    cbt = pack_table(ctab_raw, uniform_partition(nc, 2), dtype=dtype)
    ci = np.asarray(rng.integers(-1, nc, (10, 3, 4)), np.int32)
    ri = np.asarray(rng.integers(-1, v, (10, 3, 6)), np.int32)
    ci[:, 0, 0] = 1                # cache entry 1 collides across all bags
    ri[:, 1, 0] = 7                # EMT row 7 collides across all bags
    ci, ri = jnp.asarray(ci), jnp.asarray(ri)

    def loss(bwd, ep, cp):
        t2 = dataclasses.replace(bt, packed=ep)
        c2 = dataclasses.replace(cbt, packed=cp)
        return (banked_cache_residual_bag(t2, c2, ci, ri, None,
                                          backend="pallas",
                                          bwd_backend=bwd) ** 2).sum()

    gp, gj = _grad_pair(loss, bt.packed, cbt.packed)
    _assert_bwd_match(gp, gj, dtype)
    assert float(jnp.abs(jnp.asarray(gp[1], jnp.float32)).sum()) > 0


@pytest.mark.parametrize("d", [16, 33])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_bwd_csr_sweep(d, dtype):
    """CSR-ragged path: kernel scatter == the fallback's single scatter,
    duplicate rows across ragged bags included."""
    rng = np.random.default_rng(d + 300)
    v, total, num_bags = 64, 41, 7
    table, bt = _banked(rng, v, d, banks=4, dtype=dtype)
    indices = np.asarray(rng.integers(-1, v, (total,)), np.int32)
    indices[::5] = 11              # row 11 recurs through the flat stream
    indices = jnp.asarray(indices)
    cuts = np.sort(rng.choice(np.arange(1, total), num_bags - 1,
                              replace=False))
    offsets = jnp.asarray(np.concatenate([[0], cuts]), jnp.int32)

    def loss(bwd, packed):
        t2 = dataclasses.replace(bt, packed=packed)
        return (csr_embedding_bag(t2, indices, offsets, num_bags, None,
                                  backend="pallas",
                                  bwd_backend=bwd) ** 2).sum()

    gp, gj = _grad_pair(loss, bt.packed)
    _assert_bwd_match(gp, gj, dtype)


def test_bwd_backend_validation():
    with pytest.raises(ValueError, match="bwd_backend"):
        from repro.core.embedding import _resolve_bwd
        _resolve_bwd("kernel", "pallas")


@pytest.mark.parametrize("num_bags,total", [(7, 41), (8, 8), (5, 60)])
def test_csr_pallas_matches_jnp(num_bags, total):
    rng = np.random.default_rng(num_bags + total)
    v, d = 64, 20
    table, bt = _banked(rng, v, d, banks=4)
    indices = jnp.asarray(rng.integers(-1, v, (total,)), jnp.int32)
    cuts = np.sort(rng.choice(np.arange(1, total), num_bags - 1,
                              replace=False)) if num_bags > 1 else np.array([], int)
    offsets = jnp.asarray(np.concatenate([[0], cuts]), jnp.int32)

    got = csr_embedding_bag(bt, indices, offsets, num_bags, None,
                            backend="pallas")
    want = csr_embedding_bag(bt, indices, offsets, num_bags, None,
                             backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_forward_has_no_blfd_intermediate():
    """models/dlrm.py forward must not materialize a (B, F, L, D) gathered
    tensor on either backend — checked on the jaxpr of the traced forward."""
    from repro.models import dlrm as D
    cfg = D.DLRMConfig(name="t", vocab_sizes=(60, 60), embed_dim=16,
                       n_dense=4, bot_mlp=(8, 16), top_mlp=(8,), multi_hot=7)
    params, statics = D.init_params(cfg, jax.random.key(0))
    batch = {
        "dense": jnp.zeros((6, 4), jnp.float32),
        "sparse": jnp.asarray(
            np.random.default_rng(0).integers(-1, 60, (6, 2, 7)), jnp.int32),
    }
    B, F, L, d = 6, 2, 7, 16
    for backend in ("jnp", "pallas"):
        jaxpr = jax.make_jaxpr(
            lambda p: D.forward(cfg, p, statics, batch, None,
                                backend=backend))(params)
        shapes = {tuple(v.aval.shape) for eqn in jaxpr.jaxpr.eqns
                  for v in eqn.outvars}
        assert (B, F, L, d) not in shapes, backend
        assert (B * F, L, d) not in shapes, backend
