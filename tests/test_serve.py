"""Serving layer: micro-batcher semantics + LM decode/prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.serve_step import MicroBatcher, Request


class TestMicroBatcher:
    def test_padding_and_latency(self):
        pad = {"x": np.zeros(3, np.float32)}
        mb = MicroBatcher(batch_size=4, pad_request=pad)
        for i in range(6):
            mb.submit(Request(rid=i, features={"x": np.full(3, i, np.float32)}))
        reqs, feats = mb.next_batch()
        assert len(reqs) == 4 and feats["x"].shape == (4, 3)
        reqs2, feats2 = mb.next_batch()
        assert len(reqs2) == 2                       # tail batch
        assert feats2["x"].shape == (4, 3)           # padded to static shape
        np.testing.assert_allclose(feats2["x"][2:], 0.0)
        mb.complete(reqs)
        mb.complete(reqs2)
        assert len(mb.latencies) == 6
        assert mb.p99() >= 0.0


class TestDecodeConsistency:
    def test_decode_matches_prefill_next_token(self):
        """Greedy next-token from prefill == from token-by-token decode —
        the KV-cache path computes the same distribution as full attention."""
        from repro.configs import get_arch
        from repro.models import transformer as T
        cfg = get_arch("smollm-135m").reduced
        params = T.init_params(cfg, jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab)

        logits_p = T.prefill(cfg, params, toks)
        cache = T.KVCache.empty(cfg, 2, 16)
        for t in range(8):
            logits_d, cache = T.decode_step(cfg, params, cache, toks[:, t])
        np.testing.assert_allclose(
            np.asarray(logits_p[:, :cfg.vocab]),
            np.asarray(logits_d[:, :cfg.vocab]), atol=2e-2, rtol=2e-2)
        assert (jnp.argmax(logits_p, -1) == jnp.argmax(logits_d, -1)).all()
