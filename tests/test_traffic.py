"""Tier-1 tests for measured bank-traffic attribution (repro.obs.traffic)
and the SLO watchdog (repro.obs.slo).

What is pinned here and why it matters:

* Device counters bit-match the host twins on ALL FIVE lookup paths (plain
  banked, replicated, tiered, fused cache+residual, CSR), on both the jnp
  scan and the pallas-interpret kernel — the counters claim to be ground
  truth for traffic the cost model only projects, so an off-by-one in the
  routing reimplementation (replica hash, failover column, tier byte LUT)
  would silently corrupt every measured series and SLO verdict downstream.
* Replication actually splits a hot row's reads ~1/k across its copy banks,
  and dead banks count ZERO reads (they never served them) on both the
  plain and the failover-routed replicated path.
* The counter-instrumented step compiles ONE executable across live swaps —
  the counters are pure jnp on jit arguments, same zero-recompile contract
  as the lookups themselves.
* SLO window/breach/cooldown arithmetic is deterministic, so CI contracts
  can count breaches exactly; a fired breach delivers the hot-bank penalty
  shape the Replanner expects and arms its off-cadence early drift check
  (the measure -> plan feedback edge).
* Vector metrics keep a stable snapshot key-path schema and export as
  labeled Prometheus series.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.embedding import (
    BankedTable,
    banked_cache_residual_bag,
    banked_embedding_bag,
    csr_embedding_bag,
    pack_replicated,
    pack_table,
    replicated_embedding_bag,
    tiered_embedding_bag,
)
from repro.core.partitioning import (choose_replication, non_uniform_partition,
                                     replicated_partition)
from repro.obs import MetricRegistry, prometheus_text, snapshot_doc
from repro.obs.slo import CHECKS, SLOConfig, SLOWatchdog, hot_bank_penalty
from repro.obs.traffic import (
    TrafficAccumulator,
    bank_read_counts,
    host_bank_read_counts,
    host_cached_bank_read_counts,
    host_replicated_bank_read_counts,
    host_tiered_bank_traffic,
)

V, D, BANKS = 256, 8, 4

# both stage-2 implementations must report identical counts: the counters
# ride OUTSIDE the lookup kernel, on the same jit arguments
BACKENDS = [("jnp", None), ("pallas", True)]


def _freq(seed=0):
    rng = np.random.default_rng(seed)
    f = rng.zipf(1.3, size=V * 4) - 1
    freq = np.bincount(f[f < V], minlength=V).astype(np.float64)
    return freq + 1e-3


def _setup(seed=0):
    cap = int(np.ceil(V / BANKS) * 1.25)
    plan = non_uniform_partition(_freq(seed), BANKS, capacity_rows=cap)
    rng = np.random.default_rng(seed + 1)
    table = (rng.standard_normal((V, D)) * 0.01).astype(np.float32)
    return plan, pack_table(table, plan), table


def _bags(seed=0, n=16, length=6):
    rng = np.random.default_rng(seed + 2)
    idx = rng.integers(0, V, size=(n, length)).astype(np.int32)
    idx[rng.random((n, length)) < 0.25] = -1       # ragged padding
    return idx


# ---------------------------------------------------------------------------
# device counters bit-match the host twins (all five paths)
# ---------------------------------------------------------------------------

class TestDeviceCounters:
    @pytest.mark.parametrize("backend,interpret", BACKENDS)
    def test_banked_bit_match(self, backend, interpret):
        plan, bt, _ = _setup()
        idx = _bags()
        out, tr = banked_embedding_bag(bt, jnp.asarray(idx), None,
                                       backend=backend, interpret=interpret,
                                       with_traffic=True)
        host = host_bank_read_counts(plan.bank_of_row, idx, BANKS)
        assert np.array_equal(np.asarray(tr.reads), host)
        assert int(np.asarray(tr.reads).sum()) == int((idx >= 0).sum())
        assert np.array_equal(np.asarray(tr.nbytes),
                              np.asarray(tr.reads) * D * 4)
        # the lookup itself is unchanged by the instrumentation
        base = banked_embedding_bag(bt, jnp.asarray(idx), None,
                                    backend=backend, interpret=interpret)
        assert np.array_equal(np.asarray(out), np.asarray(base))

    def test_banked_dead_bank_counts_zero(self):
        plan, bt, _ = _setup()
        idx = _bags()
        live = np.ones(BANKS, bool)
        dead = int(plan.bank_of_row[idx[idx >= 0][0]])
        live[dead] = False
        _, tr = banked_embedding_bag(bt, jnp.asarray(idx), None,
                                     backend="jnp",
                                     bank_live=jnp.asarray(live),
                                     with_traffic=True)
        reads = np.asarray(tr.reads)
        assert reads[dead] == 0
        assert np.array_equal(
            reads, host_bank_read_counts(plan.bank_of_row, idx, BANKS,
                                         bank_live=live))

    @pytest.mark.parametrize("backend,interpret", BACKENDS)
    def test_cached_bit_match(self, backend, interpret):
        plan, bt, _ = _setup()
        E = 16
        cplan = non_uniform_partition(np.ones(E), BANKS, capacity_rows=8)
        rng = np.random.default_rng(9)
        cache = pack_table(
            (rng.standard_normal((E, D)) * 0.01).astype(np.float32), cplan)
        cache_idx = rng.integers(-1, E, size=(8, 3)).astype(np.int32)
        residual_idx = rng.integers(-1, V, size=(8, 5)).astype(np.int32)
        _, tr = banked_cache_residual_bag(
            bt, cache, jnp.asarray(cache_idx), jnp.asarray(residual_idx),
            None, backend=backend, interpret=interpret, with_traffic=True)
        host = host_cached_bank_read_counts(
            cplan.bank_of_row, cache_idx, plan.bank_of_row, residual_idx,
            BANKS)
        assert np.array_equal(np.asarray(tr.reads), host)
        # a cache hit is ONE read: totals = valid hits + valid residuals
        assert int(host.sum()) == int((cache_idx >= 0).sum()
                                      + (residual_idx >= 0).sum())

    @pytest.mark.parametrize("backend,interpret", BACKENDS)
    def test_tiered_bit_match(self, backend, interpret):
        from repro.quant import QuantSpec, assign_tiers, build_tiered_table, \
            tier_nbytes
        plan, bt, _ = _setup()
        tiers = assign_tiers(_freq(), QuantSpec(byte_budget=6.0,
                                                min_hot_rows=4),
                             D).tier_of_row
        assert len(set(tiers.tolist())) >= 2       # a real mix, not all-hot
        tt = build_tiered_table(bt, tiers)
        idx = _bags()
        _, tr = tiered_embedding_bag(bt.packed, tt, jnp.asarray(idx), None,
                                     backend=backend, interpret=interpret,
                                     with_traffic=True)
        lut = tier_nbytes(D, tt.hot_dtype)
        reads, nbytes = host_tiered_bank_traffic(
            plan.bank_of_row, plan.slot_of_row, tt.rows_per_bank,
            np.asarray(tt.tier), lut, idx, BANKS)
        assert np.array_equal(np.asarray(tr.reads), reads)
        assert np.array_equal(np.asarray(tr.nbytes), nbytes)
        # tier widths differ, so bytes must NOT be a uniform multiple of
        # reads (that would mean the tier LUT was ignored)
        with_reads = reads > 0
        ratios = nbytes[with_reads] / reads[with_reads]
        assert len(set(np.round(ratios, 6).tolist())) >= 1

    @pytest.mark.parametrize("backend,interpret", BACKENDS)
    def test_csr_bit_match(self, backend, interpret):
        plan, bt, _ = _setup()
        rng = np.random.default_rng(5)
        lens = rng.integers(1, 7, size=16)   # multiple of tile_b=8 (pallas)
        indices = rng.integers(0, V, size=int(lens.sum())).astype(np.int32)
        # offsets carry the START of each bag (length num_bags); the stream
        # end is implied by indices.shape
        offsets = np.zeros(len(lens), np.int32)
        offsets[1:] = np.cumsum(lens)[:-1]
        _, tr = csr_embedding_bag(bt, jnp.asarray(indices),
                                  jnp.asarray(offsets), len(lens), None,
                                  backend=backend, interpret=interpret,
                                  with_traffic=True)
        host = host_bank_read_counts(plan.bank_of_row, indices, BANKS)
        assert np.array_equal(np.asarray(tr.reads), host)
        assert int(host.sum()) == len(indices)

    def _replicated(self, k=4):
        freq = _freq()
        freq[0] = freq.sum() * 2.0                  # one very hot row
        cap = int(np.ceil(V / BANKS) * 2.0)
        copies = choose_replication(freq, BANKS, k_max=k)
        assert int(copies[0]) == k
        rplan = replicated_partition(freq, BANKS, copies=copies,
                                     capacity_rows=cap, k_max=k)
        rng = np.random.default_rng(3)
        table = (rng.standard_normal((V, D)) * 0.01).astype(np.float32)
        return rplan, pack_replicated(table, rplan, rows_per_bank=cap)

    @pytest.mark.parametrize("backend,interpret", BACKENDS)
    def test_replicated_bit_match_and_k_split(self, backend, interpret):
        k = 4
        rplan, rt = self._replicated(k)
        # every bag reads the SAME hot row: the hash routing must spread the
        # traffic ~1/k across its k distinct copy banks
        n = 400
        idx = np.zeros((n, 1), np.int32)
        _, tr = replicated_embedding_bag(rt, jnp.asarray(idx), None,
                                         backend=backend,
                                         interpret=interpret,
                                         with_traffic=True)
        reads = np.asarray(tr.reads)
        host = host_replicated_bank_read_counts(
            rplan.bank_of_copy, idx, BANKS, k_max=k)
        assert np.array_equal(reads, host)
        assert int(reads.sum()) == n
        copy_banks = np.unique(rplan.bank_of_copy[0])
        assert len(copy_banks) == k
        shares = reads[copy_banks] / n
        assert (shares > 1.0 / k - 0.10).all()
        assert (shares < 1.0 / k + 0.10).all()

    def test_replicated_failover_dead_bank_counts_zero(self):
        k = 4
        rplan, rt = self._replicated(k)
        idx = _bags(seed=7)
        live = np.ones(BANKS, bool)
        live[int(rplan.bank_of_copy[0, 0])] = False
        _, tr = replicated_embedding_bag(rt, jnp.asarray(idx), None,
                                         backend="jnp",
                                         bank_live=jnp.asarray(live),
                                         with_traffic=True)
        reads = np.asarray(tr.reads)
        assert reads[~live] .sum() == 0            # dead bank served nothing
        host = host_replicated_bank_read_counts(
            rplan.bank_of_copy, idx, BANKS, k_max=k, bank_live=live)
        assert np.array_equal(reads, host)
        # the hot row has k live-bank copies left, so ITS reads all survive
        hot = (idx == 0).sum()
        assert reads.sum() >= hot


# ---------------------------------------------------------------------------
# zero-recompile: counters are pure jnp on jit arguments
# ---------------------------------------------------------------------------

class TestZeroRecompile:
    def test_one_executable_across_swaps(self):
        from repro.launch.serve import CompileProbe
        plan_a, bt_a, table = _setup(seed=0)
        cap = bt_a.rows_per_bank
        # a different plan over the SAME capacity: a pure argument change
        plan_b = non_uniform_partition(_freq(seed=11), BANKS,
                                       capacity_rows=cap)
        bt_b = pack_table(table, plan_b)
        probe = CompileProbe(metrics=MetricRegistry())

        @jax.jit
        def serve(packed, remap_bank, remap_slot, idx):
            bt = BankedTable(packed=packed, remap_bank=remap_bank,
                             remap_slot=remap_slot, n_banks=BANKS,
                             rows_per_bank=cap)
            emb = banked_embedding_bag(bt, idx, None, backend="jnp")
            return emb, bank_read_counts(remap_bank, idx, BANKS)

        idx = jnp.asarray(_bags())
        jax.block_until_ready(serve(bt_a.packed, bt_a.remap_bank,
                                    bt_a.remap_slot, idx))
        warm = probe.compiles
        for plan, bt in ((plan_a, bt_a), (plan_b, bt_b), (plan_a, bt_a)):
            _, reads = serve(bt.packed, bt.remap_bank, bt.remap_slot, idx)
            assert np.array_equal(
                np.asarray(reads),
                host_bank_read_counts(plan.bank_of_row, np.asarray(idx),
                                      BANKS))
        assert probe.compiles - warm == 0
        assert serve._cache_size() == 1


# ---------------------------------------------------------------------------
# host-side aggregation + export schema
# ---------------------------------------------------------------------------

class TestTrafficAccumulator:
    def test_update_and_series(self):
        reg = MetricRegistry()
        acc = TrafficAccumulator(reg, BANKS, row_nbytes=D * 4)
        share = acc.update(np.array([6, 2, 0, 0]))
        assert share == pytest.approx(0.75)
        acc.update(np.array([0, 0, 4, 4]))
        assert reg.get("obs.bank_reads").values == [6.0, 2.0, 4.0, 4.0]
        assert reg.get("obs.bank_bytes").values == [
            v * D * 4 for v in (6.0, 2.0, 4.0, 4.0)]
        assert reg.get("obs.bank_share").count == 2
        assert acc.batches == 2
        # explicit nbytes (the tiered lane) overrides the uniform width
        acc.update(np.array([1, 0, 0, 0]), nbytes=np.array([7, 0, 0, 0]))
        assert reg.get("obs.bank_bytes").values[0] == 6.0 * D * 4 + 7.0

    def test_vector_snapshot_schema_stable_and_prometheus_labels(self):
        import os
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "benchmarks"))
        try:
            from check_regression import key_paths
        finally:
            sys.path.pop(0)

        def build(values):
            reg = MetricRegistry()
            acc = TrafficAccumulator(reg, BANKS, row_nbytes=D * 4)
            acc.update(np.asarray(values))
            return reg, snapshot_doc(reg, label="t")

        reg_a, a = build([5, 0, 0, 1])
        _, b = build([0, 9, 2, 0])
        assert a != b
        assert key_paths(a) == key_paths(b)         # values move, schema not
        snap = a["metrics"]["obs.bank_reads"]
        assert snap["type"] == "vector_counter"
        assert snap["label"] == "bank"
        assert snap["values"] == [[0, 5.0], [1, 0.0], [2, 0.0], [3, 1.0]]
        text = prometheus_text(reg_a)
        assert 'obs_bank_reads{bank="0"} 5.0' in text
        assert 'obs_bank_reads{bank="3"} 1.0' in text
        assert "# TYPE obs_bank_reads counter" in text


# ---------------------------------------------------------------------------
# SLO watchdog: deterministic windows, breaches, cooldown, planner feedback
# ---------------------------------------------------------------------------

class TestSLOWatchdog:
    def test_no_evaluation_until_window_full(self):
        wd = SLOWatchdog(SLOConfig(p99_us=10.0, window=4), n_banks=BANKS,
                         dim=D)
        reads = np.array([10, 0, 0, 0])
        for b in range(3):
            assert wd.observe(b, wall_us=1e6, reads=reads,
                              batch_size=4) == []
        assert wd.observe(3, wall_us=1e6, reads=reads,
                          batch_size=4) == ["p99"]

    def test_cooldown_rearms_exactly_one_window_later(self):
        cfg = SLOConfig(p99_us=10.0, window=4)
        wd = SLOWatchdog(cfg, n_banks=BANKS, dim=D)
        reads = np.array([4, 4, 4, 4])
        fired = [wd.observe(b, wall_us=1e6, reads=reads, batch_size=4)
                 for b in range(12)]
        assert [b for b, f in enumerate(fired) if f] == [3, 7, 11]
        assert wd.breaches == 3

    def test_hot_bank_and_divergence_checks(self):
        reg = MetricRegistry()
        # divergence is on the WHOLE Eq.-1 latency (fixed stages included),
        # so a 4x share overload moves it ~12% at this scale — 0.1 catches it
        wd = SLOWatchdog(SLOConfig(max_share=0.5, divergence=0.1, window=2),
                         n_banks=BANKS, dim=D, metrics=reg)
        wd.set_projection(1.0 / BANKS)              # the plan promised ideal
        reads = np.array([20, 0, 0, 0])             # reality: one hot bank
        wd.observe(0, wall_us=1.0, reads=reads, batch_size=4)
        kinds = wd.observe(1, wall_us=1.0, reads=reads, batch_size=4)
        assert set(kinds) == {"hot_bank", "divergence"}
        assert set(kinds) <= set(CHECKS)
        assert reg.get("obs.slo_breaches_total").value == 2.0
        assert reg.get("obs.slo_breaches_hot_bank_total").value == 1.0
        assert reg.get("obs.slo_breaches_divergence_total").value == 1.0
        assert reg.get("obs.slo_realized_latency_us").value > \
            reg.get("obs.slo_projected_latency_us").value

    def test_on_breach_names_the_hot_bank(self):
        events = []
        wd = SLOWatchdog(SLOConfig(max_share=0.3, window=2), n_banks=BANKS,
                         dim=D, on_breach=lambda k, info: events.append(
                             (k, info)))
        reads = np.array([0, 0, 9, 1])
        wd.observe(0, wall_us=1.0, reads=reads, batch_size=4)
        wd.observe(1, wall_us=1.0, reads=reads, batch_size=4)
        (kind, info), = events
        assert kind == "hot_bank"
        assert info["bank"] == 2
        assert info["batch"] == 1
        assert np.array_equal(info["window_reads"], reads * 2)

    def test_disabled_config_never_fires(self):
        cfg = SLOConfig()
        assert not cfg.enabled
        assert SLOConfig(p99_us=1.0).enabled
        wd = SLOWatchdog(cfg, n_banks=BANKS, dim=D)
        for b in range(40):
            assert wd.observe(b, wall_us=1e9,
                              reads=np.array([99, 0, 0, 0]),
                              batch_size=4) == []

    def test_hot_bank_penalty_shape(self):
        pen = hot_bank_penalty(np.array([30, 5, 5, 0]), BANKS)
        assert pen.shape == (BANKS,)
        assert pen[0] == pytest.approx(30 / 40 * BANKS)
        assert (pen[1:] == 1.0).all()
        # balanced traffic floors at 1 everywhere (no fake penalties)
        assert (hot_bank_penalty(np.array([1, 1, 1, 1]), BANKS) == 1.0).all()

    def test_penalty_arms_early_drift_check(self):
        from repro.workload import ReplanConfig, Replanner
        reg = MetricRegistry()
        cap = int(np.ceil(V / BANKS) * 1.25)
        rp = Replanner(ReplanConfig.for_vocab(V, BANKS, capacity_rows=cap,
                                              check_every=1000),
                       V, init_freq=_freq(), metrics=reg)
        rng = np.random.default_rng(0)
        for _ in range(3):
            rp.observe_rows(rng.integers(0, V, size=64))
            rp.end_batch()
        assert reg.get("replanner.drift_checks_total").value == 0.0
        rp.apply_slo_penalty(hot_bank_penalty(np.array([9, 1, 1, 1]), BANKS))
        assert reg.get("replanner.slo_penalties_total").value == 1.0
        assert rp.bank_penalty[0] > 1.0
        rp.observe_rows(rng.integers(0, V, size=64))
        rp.end_batch()                              # off-cadence, but armed
        assert reg.get("replanner.drift_checks_total").value == 1.0
        rp.observe_rows(rng.integers(0, V, size=64))
        rp.end_batch()                              # disarmed again
        assert reg.get("replanner.drift_checks_total").value == 1.0
