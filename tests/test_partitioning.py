"""Unit + property tests for the paper's §3 partitioners."""
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.partitioning import (
    cache_aware_partition,
    expert_placement,
    non_uniform_partition,
    uniform_partition,
)


def zipf_freq(n, a=1.1, seed=0):
    rng = np.random.default_rng(seed)
    p = np.arange(1, n + 1, dtype=np.float64) ** (-a)
    return rng.permutation(p * 1000)


class TestUniform:
    def test_blocks_contiguous_equal(self):
        plan = uniform_partition(100, 4)
        plan.validate()
        assert plan.rows_per_bank.tolist() == [25, 25, 25, 25]
        assert (plan.bank_of_row[:25] == 0).all()
        assert (plan.bank_of_row[-25:] == 3).all()

    def test_non_divisible(self):
        plan = uniform_partition(103, 4)
        plan.validate()
        assert plan.rows_per_bank.sum() == 103

    def test_skewed_load_imbalanced(self):
        freq = zipf_freq(1000)
        u = uniform_partition(1000, 8, freq)
        assert u.imbalance() > 1.2  # skew shows up under uniform


class TestNonUniform:
    def test_beats_uniform_on_skew(self):
        freq = zipf_freq(2000)
        u = uniform_partition(2000, 8, freq)
        nu = non_uniform_partition(freq, 8)
        nu.validate()
        assert nu.imbalance() <= u.imbalance()

    def test_respects_capacity(self):
        freq = zipf_freq(100)
        plan = non_uniform_partition(freq, 4, capacity_rows=25)
        plan.validate()
        assert plan.rows_per_bank.max() <= 25

    def test_capacity_infeasible_raises(self):
        with pytest.raises(ValueError):
            non_uniform_partition(zipf_freq(100), 4, capacity_rows=10)

    def test_batched_assignment(self):
        freq = zipf_freq(500)
        plan = non_uniform_partition(freq, 8, batch=16)
        plan.validate()

    @given(n=st.integers(16, 400), banks=st.integers(1, 16),
           a=st.floats(0.1, 2.0), seed=st.integers(0, 100))
    @settings(max_examples=40, deadline=None)
    def test_property_valid_and_balanced(self, n, banks, a, seed):
        freq = zipf_freq(n, a, seed)
        nu = non_uniform_partition(freq, banks)
        nu.validate()  # every row exactly once, slots dense
        u = uniform_partition(n, banks, freq)
        # greedy is never worse than uniform on aggregate-load balance
        assert nu.imbalance() <= u.imbalance() + 1e-9
        # total load preserved
        assert np.isclose(nu.load_per_bank.sum(), freq.sum())


class TestCacheAware:
    def _mk(self, n=300, n_groups=10, seed=0):
        rng = np.random.default_rng(seed)
        freq = zipf_freq(n, seed=seed)
        used = rng.choice(n, size=(n_groups, 3), replace=False)
        groups = [np.sort(used[g]) for g in range(n_groups)]
        benefits = np.array([freq[g].sum() * 0.4 for g in groups])
        return freq, groups, benefits

    def test_all_rows_assigned(self):
        freq, groups, benefits = self._mk()
        plan = cache_aware_partition(freq, groups, benefits, 8)
        plan.validate()

    def test_group_members_colocated(self):
        freq, groups, benefits = self._mk()
        plan = cache_aware_partition(freq, groups, benefits, 8)
        for g, members in enumerate(groups):
            banks = set(plan.bank_of_row[members].tolist())
            assert len(banks) == 1, f"group {g} split across {banks}"
            assert plan.cache_bank_of_entry[g] == banks.pop()

    def test_benefit_reduces_accounted_load(self):
        freq, groups, benefits = self._mk()
        plan = cache_aware_partition(freq, groups, benefits, 8)
        assert plan.load_per_bank.sum() <= freq.sum()

    def test_cache_capacity_respected(self):
        freq, groups, benefits = self._mk(n_groups=10)
        plan = cache_aware_partition(freq, groups, benefits, 4,
                                     cache_capacity_entries=2)
        counts = np.bincount(
            plan.cache_bank_of_entry[plan.cache_bank_of_entry >= 0],
            minlength=4)
        assert counts.max() <= 2

    @given(seed=st.integers(0, 50), banks=st.integers(2, 12))
    @settings(max_examples=25, deadline=None)
    def test_property_combined_balance(self, seed, banks):
        freq, groups, benefits = self._mk(seed=seed)
        plan = cache_aware_partition(freq, groups, benefits, banks)
        plan.validate()
        u = uniform_partition(freq.shape[0], banks, freq)
        # cache-aware should not be wildly worse than uniform on load
        assert plan.load_per_bank.max() <= u.load_per_bank.max() * 1.5 + 1


class TestExpertPlacement:
    def test_balances_and_caps(self):
        load = zipf_freq(32)
        banks = expert_placement(load, 8)
        counts = np.bincount(banks, minlength=8)
        assert counts.max() == 4  # 32 experts / 8 banks exactly
        per_bank = np.zeros(8)
        np.add.at(per_bank, banks, load)
        # greedy longest-processing-time bound: max <= mean + heaviest item
        # (a single mega-hot expert lower-bounds any placement)
        assert per_bank.max() <= per_bank.mean() + load.max() + 1e-9
