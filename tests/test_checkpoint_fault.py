"""Checkpoint/restart, async writer, elastic re-partition, fault injection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              reshard_banked_table, restore_checkpoint,
                              save_checkpoint)
from repro.core.partitioning import non_uniform_partition, uniform_partition
from repro.dist.fault import FailureInjector, StragglerWatchdog, run_with_restarts


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.array(rng.standard_normal((4, 3)), jnp.float32),
            "nested": {"b": jnp.arange(5), "c": jnp.float32(2.5)}}


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        save_checkpoint(str(tmp_path), 7, t)
        restored, step = restore_checkpoint(str(tmp_path), t)
        assert step == 7
        jax.tree.map(lambda x, y: np.testing.assert_allclose(x, y),
                     t, restored)

    def test_latest_step_picks_highest_complete(self, tmp_path):
        t = _tree()
        save_checkpoint(str(tmp_path), 1, t)
        save_checkpoint(str(tmp_path), 5, t)
        os.makedirs(tmp_path / "step_9.tmp")  # crashed partial save
        assert latest_step(str(tmp_path)) == 5

    def test_async_checkpointer_gc(self, tmp_path):
        ck = AsyncCheckpointer(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, _tree(s))
        ck.join()
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
        assert steps == [3, 4]

    def test_restore_missing_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(str(tmp_path / "nope"), _tree())


class TestElasticReshard:
    @pytest.mark.parametrize("old_banks,new_banks", [(4, 8), (8, 4), (4, 4)])
    def test_reshard_preserves_logical_rows(self, old_banks, new_banks):
        """Bank count changes (scale-out / node loss) must preserve every
        logical row — the elastic-restore invariant."""
        rng = np.random.default_rng(0)
        V, D = 100, 8
        table = rng.standard_normal((V, D)).astype(np.float32)
        freq = rng.random(V) + 0.1
        old = non_uniform_partition(freq, old_banks)
        new = non_uniform_partition(freq * 2 + 1, new_banks)  # different plan
        from repro.core.embedding import pack_table
        packed_old = np.zeros((old_banks * old.max_rows_per_bank, D),
                              np.float32)
        flat = old.bank_of_row.astype(np.int64) * old.max_rows_per_bank \
            + old.slot_of_row
        packed_old[flat] = table
        packed_new = reshard_banked_table(packed_old, old, new)
        flat_new = new.bank_of_row.astype(np.int64) * new.max_rows_per_bank \
            + new.slot_of_row
        np.testing.assert_allclose(packed_new[flat_new], table)


class TestFault:
    def test_straggler_watchdog(self):
        events = []
        wd = StragglerWatchdog(factor=3.0,
                               on_straggler=lambda s, t, m: events.append(s))
        for i in range(10):
            wd.observe(i, 0.1)
        assert not wd.observe(10, 0.15)
        assert wd.observe(11, 1.0)       # 10x median
        assert events == [11]

    def test_injected_failure_and_restart_replays(self, tmp_path):
        """End-to-end restart: crash at step 5, restore from checkpoint,
        final state identical to an uninterrupted run (determinism)."""
        from repro.data.synthetic import lm_batch

        def make_loop(inject: FailureInjector | None):
            state = {"acc": np.zeros(4)}
            ckdir = str(tmp_path / ("inj" if inject else "ref"))

            def loop(start_step: int) -> int:
                if latest_step(ckdir) is not None:
                    restored, s = restore_checkpoint(ckdir, state)
                    state["acc"] = np.asarray(restored["acc"])
                for step in range(start_step, 10):
                    if inject:
                        inject.check(step)
                    b = lm_batch(1, 4, 100, seed=0, step=step)
                    state["acc"] = state["acc"] + b["tokens"][0]
                    save_checkpoint(ckdir, step + 1, state)
                return 10

            def restore_step():
                return latest_step(ckdir) or 0

            return loop, restore_step, state

        loop_i, rs_i, state_i = make_loop(FailureInjector(fail_at_step=5))
        assert run_with_restarts(loop_i, restore_step=rs_i) == 10
        loop_r, rs_r, state_r = make_loop(None)
        run_with_restarts(loop_r, restore_step=rs_r)
        np.testing.assert_array_equal(state_i["acc"], state_r["acc"])


class TestDataDeterminism:
    def test_loader_deterministic_and_host_sharded(self):
        from repro.data.pipeline import ShardedLoader
        from repro.data.synthetic import lm_batch
        l0 = ShardedLoader(lm_batch, global_batch=8, n_hosts=2, host_id=0,
                           seed=3, seq=16, vocab=100)
        l0b = ShardedLoader(lm_batch, global_batch=8, n_hosts=2, host_id=0,
                            seed=3, seq=16, vocab=100)
        l1 = ShardedLoader(lm_batch, global_batch=8, n_hosts=2, host_id=1,
                           seed=3, seq=16, vocab=100)
        a = l0.take(3)
        b = l0b.take(3)
        c = l1.take(3)
        for (sa, ba), (sb, bb), (sc, bc) in zip(a, b, c):
            np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
            assert not np.array_equal(ba["tokens"], bc["tokens"])
            assert ba["tokens"].shape == (4, 16)  # local slice

    def test_sampler_blocks_valid(self):
        from repro.data.synthetic import random_graph
        from repro.sparse.sampler import NeighborSampler, build_csr
        g = random_graph(300, 3000, 8, 3, seed=0)
        csr = build_csr(g["edge_src"].astype(np.int64),
                        g["edge_dst"].astype(np.int64), 300)
        s = NeighborSampler(csr, (5, 3), seed=0)
        seeds = np.arange(16)
        blocks = s.sample(seeds)
        assert len(blocks) == 2
        outer, inner = blocks
        # dst-prefix invariant: inner dst (seeds) is prefix of inner src set
        np.testing.assert_array_equal(inner.src_ids[:16], seeds)
        np.testing.assert_array_equal(outer.src_ids[:len(inner.src_ids)],
                                      inner.src_ids)
        # every edge endpoint within bounds
        for blk in blocks:
            m = blk.edge_mask
            assert blk.edge_src[m].max() < len(blk.src_ids)
            assert blk.edge_dst[m].max() < len(blk.dst_ids)
