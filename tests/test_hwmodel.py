"""Analytic hardware model (Eq. 1-3) properties + system-model orderings."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.hwmodel import (UPMEM, embedding_stage_latency,
                                solve_uniform_tile, system_inference_time,
                                updlrm_layout)


class TestMramCurve:
    def test_plateau_then_rising(self):
        """Fig. 3: flat 8-32 B, then monotonically rising."""
        t8 = UPMEM.mram_read_latency(8)
        t32 = UPMEM.mram_read_latency(32)
        assert t8 == t32
        prev = t32
        for n in (64, 128, 256, 512, 1024, 2048):
            cur = UPMEM.mram_read_latency(n)
            assert cur > prev
            prev = cur


class TestStageModel:
    @given(red=st.floats(10, 400), n_c=st.sampled_from([2, 4, 6, 8]),
           banks=st.integers(2, 64))
    @settings(max_examples=30, deadline=None)
    def test_lookup_linear_in_reduction(self, red, n_c, banks):
        a = embedding_stage_latency(batch_size=64, avg_reduction=red,
                                    n_c=n_c, n_banks=banks).lookup
        b = embedding_stage_latency(batch_size=64, avg_reduction=2 * red,
                                    n_c=n_c, n_banks=banks).lookup
        assert np.isclose(b, 2 * a, rtol=1e-6)

    def test_skew_hurts_stage2(self):
        """A hot bank bounds the parallel lookup time — the §3.2 motivation."""
        balanced = embedding_stage_latency(
            batch_size=64, avg_reduction=100, n_c=4, n_banks=8).lookup
        skewed = embedding_stage_latency(
            batch_size=64, avg_reduction=100, n_c=4,
            per_bank_lookup_share=np.array([.5, .1, .1, .1, .05, .05, .05,
                                            .05])).lookup
        assert skewed > 3 * balanced

    def test_cache_reduces_lookup(self):
        no = embedding_stage_latency(batch_size=64, avg_reduction=100,
                                     n_c=4, n_banks=8)
        yes = embedding_stage_latency(batch_size=64, avg_reduction=100,
                                      n_c=4, n_banks=8, cache_hit_rate=0.4)
        assert yes.lookup < no.lookup
        assert yes.d_comm == no.d_comm   # stage 3 unchanged (paper Eq.)

    def test_dcomm_grows_with_nc(self):
        a = embedding_stage_latency(batch_size=64, avg_reduction=100,
                                    n_c=2, n_banks=8).d_comm
        b = embedding_stage_latency(batch_size=64, avg_reduction=100,
                                    n_c=8, n_banks=8).d_comm
        assert np.isclose(b, 4 * a)

    def test_layout_tradeoff(self):
        """Larger N_c => more row groups (smaller shares) but wider reads."""
        rg2, cg2 = updlrm_layout(32, 32, 2)
        rg8, cg8 = updlrm_layout(32, 32, 8)
        assert (rg2, cg2) == (2, 16)
        assert (rg8, cg8) == (8, 4)
        assert rg2 * cg2 == rg8 * cg8 == 32

    def test_tile_solver_respects_constraints(self):
        n_r, n_c = solve_uniform_tile(rows=2_360_650, cols=32, n_banks=32,
                                      batch_size=64, avg_reduction=245.8)
        assert n_c in (2, 4, 6, 8)
        assert n_r * n_c * 4 <= UPMEM.mram_bytes


class TestSystemModel:
    def test_fig8_orderings(self):
        """hybrid < cpu < fae < updlrm (the paper's Fig. 8 ranking)."""
        kw = dict(batch_size=64, avg_reduction=245.8, n_tables=8, dim=32,
                  mlp_flops=1e6, n_banks=256)
        t_cpu = system_inference_time("cpu", **kw)
        t_hyb = system_inference_time("hybrid", **kw)
        t_fae = system_inference_time("fae", **kw)
        t_up = system_inference_time("updlrm", **kw)
        assert t_hyb > t_cpu > t_fae > t_up

    def test_speedup_grows_with_reduction(self):
        """Fig. 8: higher avg-reduction => bigger UpDLRM speedup."""
        def speedup(red):
            kw = dict(batch_size=64, avg_reduction=red, n_tables=8, dim=32,
                      mlp_flops=1e6, n_banks=256)
            return (system_inference_time("cpu", **kw)
                    / system_inference_time("updlrm", **kw))
        assert speedup(300) > speedup(50)
