"""Sparse primitives (EmbeddingBag from first principles), GRACE mining,
cache runtime correctness, and banked-table semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cache_runtime import (build_cache_table, measure_hit_rate,
                                      rewrite_bags)
from repro.core.embedding import (banked_embedding_bag, banked_gather,
                                  csr_embedding_bag, pack_table)
from repro.core.grace import mine_cooccurrence
from repro.core.partitioning import non_uniform_partition, uniform_partition
from repro.sparse.ops import (embedding_bag, embedding_bag_fixed,
                              embedding_bag_onehot, segment_softmax)


class TestEmbeddingBag:
    @given(v=st.integers(4, 60), d=st.integers(1, 16), b=st.integers(1, 10),
           l=st.integers(1, 8), seed=st.integers(0, 99))
    @settings(max_examples=30, deadline=None)
    def test_fixed_matches_onehot_oracle(self, v, d, b, l, seed):
        rng = np.random.default_rng(seed)
        table = jnp.array(rng.standard_normal((v, d)), jnp.float32)
        idx = jnp.array(rng.integers(-1, v, (b, l)), jnp.int32)
        got = embedding_bag_fixed(table, idx)
        want = embedding_bag_onehot(table, idx)
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_csr_matches_fixed(self):
        rng = np.random.default_rng(0)
        table = jnp.array(rng.standard_normal((50, 8)), jnp.float32)
        # CSR bags of sizes 3,1,2
        indices = jnp.array([4, 9, 11, 7, 30, 31], jnp.int32)
        offsets = jnp.array([0, 3, 4], jnp.int32)
        got = embedding_bag(table, indices, offsets, num_bags=3)
        fixed_idx = jnp.array([[4, 9, 11], [7, -1, -1], [30, 31, -1]],
                              jnp.int32)
        want = embedding_bag_fixed(table, fixed_idx)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_mean_combiner(self):
        table = jnp.eye(4, dtype=jnp.float32)
        idx = jnp.array([[0, 1, -1]], jnp.int32)
        out = embedding_bag_fixed(table, idx, combiner="mean")
        np.testing.assert_allclose(out[0], [0.5, 0.5, 0, 0], atol=1e-6)

    def test_segment_softmax_sums_to_one(self):
        rng = np.random.default_rng(1)
        scores = jnp.array(rng.standard_normal(20), jnp.float32)
        seg = jnp.array(rng.integers(0, 5, 20), jnp.int32)
        p = segment_softmax(scores, seg, 5)
        sums = jax.ops.segment_sum(p, seg, 5)
        np.testing.assert_allclose(sums, np.ones(5), atol=1e-5)


class TestBankedTable:
    @given(v=st.integers(8, 100), banks=st.integers(1, 8),
           seed=st.integers(0, 50))
    @settings(max_examples=25, deadline=None)
    def test_banked_lookup_is_plain_lookup(self, v, banks, seed):
        """Property: packing + remap + bank-partial-sum == plain bag lookup,
        for ANY partition plan (the core PIM-runtime invariant)."""
        rng = np.random.default_rng(seed)
        table = rng.standard_normal((v, 8)).astype(np.float32)
        freq = rng.random(v) + 0.1
        plan = non_uniform_partition(freq, banks)
        bt = pack_table(table, plan)
        idx = jnp.array(rng.integers(-1, v, (6, 5)), jnp.int32)
        got = banked_embedding_bag(bt, idx, None)
        want = embedding_bag_fixed(jnp.asarray(table), idx)
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_banked_gather_dense(self):
        rng = np.random.default_rng(3)
        table = rng.standard_normal((40, 4)).astype(np.float32)
        plan = uniform_partition(40, 4)
        bt = pack_table(table, plan)
        idx = jnp.array(rng.integers(0, 40, (3, 7)), jnp.int32)
        got = banked_gather(bt, idx, None)
        np.testing.assert_allclose(got, table[np.asarray(idx)], atol=1e-6)

    def test_csr_banked(self):
        rng = np.random.default_rng(4)
        table = rng.standard_normal((30, 8)).astype(np.float32)
        plan = uniform_partition(30, 2)
        bt = pack_table(table, plan)
        indices = jnp.array([1, 2, 3, 10, 29], jnp.int32)
        offsets = jnp.array([0, 3], jnp.int32)
        got = csr_embedding_bag(bt, indices, offsets, 2, None)
        want = np.stack([table[[1, 2, 3]].sum(0), table[[10, 29]].sum(0)])
        np.testing.assert_allclose(got, want, atol=1e-5)


class TestGraceAndCache:
    def _trace(self, n_items=100, n=400, seed=0):
        rng = np.random.default_rng(seed)
        # planted co-occurrence: items (1,2,3) appear together often
        bags = []
        for _ in range(n):
            bag = rng.choice(n_items, size=rng.integers(3, 8), replace=False)
            if rng.random() < 0.5:
                bag = np.unique(np.concatenate([bag, [1, 2, 3]]))
            bags.append(bag)
        return bags

    def test_mines_planted_group(self):
        bags = self._trace()
        cp = mine_cooccurrence(bags, top_items=100, max_groups=16)
        assert len(cp.groups) >= 1
        top = set(cp.groups[0].tolist())
        assert top <= {1, 2, 3}, f"expected planted subset, got {top}"

    def test_rewrite_reconstructs_bag_sum(self):
        """The paper's Fig.-7 invariant: cached partials + residuals == full
        bag sum, for every request."""
        rng = np.random.default_rng(1)
        bags = self._trace(seed=1)
        cp = mine_cooccurrence(bags, top_items=100, max_groups=16)
        table = rng.standard_normal((100, 8)).astype(np.float32)
        ctab = build_cache_table(table, cp)
        ci, ri = rewrite_bags(bags[:100], cp, max_cache_per_bag=8,
                              max_residual_per_bag=16)
        for i, bag in enumerate(bags[:100]):
            want = table[np.unique(bag)].sum(0)
            c = ci[i][ci[i] >= 0]
            r = ri[i][ri[i] >= 0]
            got = ctab[c].sum(0) + table[r].sum(0)
            np.testing.assert_allclose(got, want, atol=1e-4)

    def test_hit_rate_positive_on_cooccurring_trace(self):
        bags = self._trace()
        cp = mine_cooccurrence(bags, top_items=100, max_groups=16)
        assert measure_hit_rate(bags, cp) > 0.05
