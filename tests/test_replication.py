"""Hot-row replication (ISSUE 8 tentpole): the replica-aware partitioner,
the k-way lookup/backward kernels, failover composition, and the runtime's
versioned replica lane.

Invariants under test:
  * k=1 replicated serving is bit-exact to the single-copy banked path
    (both backends) — replication is a strict superset, not a fork.
  * gradients through a k>1 table, summed across each row's copies,
    bit-match the single-copy gradients (fp32 scatter on both backends).
  * a replica-lane swap installs a table bit-identical to a fresh pack of
    the migrated rows (mirrors the tier-lane parity tests).
  * with a dead bank, reads of replicated rows stay exact through a
    surviving copy; only rows with NO live copy degrade to the zero row.
"""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core.embedding import (banked_embedding_bag, degraded_row_counts,
                                  pack_replicated, pack_table,
                                  replicated_embedding_bag)
from repro.core.partitioning import (choose_replication,
                                     non_uniform_partition,
                                     replicated_partition)
from repro.workload import (AdaptiveEmbeddingRuntime, ReplanConfig,
                            Replanner, migrate_replicated, migrate_table,
                            unpacked_rows)

BACKENDS = ["jnp", "pallas"]


def _setup(rng, v=96, d=16, banks=4, k_max=4, n_hot=5):
    """A table with an explicit hot head + both the single-copy and the
    k_max-copy views of it, packed at one pinned per-bank capacity."""
    table = (rng.standard_normal((v, d)) * 0.1).astype(np.float32)
    freq = rng.random(v) + 0.1
    freq[:n_hot] += 50.0
    cap = int(np.ceil((v + n_hot * (k_max - 1)) / banks) * 1.3)
    plan = non_uniform_partition(freq, banks, capacity_rows=cap)
    bt = pack_table(table, plan)
    copies = np.ones(v, np.int32)
    copies[:n_hot] = k_max
    rplan = replicated_partition(freq, banks, copies=copies,
                                 capacity_rows=cap, k_max=k_max)
    rt = pack_replicated(table, rplan, rows_per_bank=cap)
    return table, freq, plan, bt, rplan, rt, cap


def _bags(rng, n, l, v, hot_frac=0.5, n_hot=5):
    """(n, l) bags with -1 padding, biased toward the replicated head so
    every copy actually sees traffic."""
    idx = np.full((n, l), -1, np.int32)
    for i in range(n):
        k = rng.integers(1, l + 1)
        hot = rng.random(k) < hot_frac
        idx[i, :k] = np.where(hot, rng.integers(0, n_hot, k),
                              rng.integers(0, v, k))
    return jnp.asarray(idx)


def _fold_replicated(g, rplan, rows_per_bank):
    """(banks*rpb, D) packed gradient -> (V, D) by summing each row's
    copies (exact: fp32 adds of integer bag counts)."""
    v = rplan.vocab
    out = np.zeros((v, g.shape[-1]), np.float32)
    for row in range(v):
        for r in range(int(rplan.copies[row])):
            pos = (int(rplan.bank_of_copy[row, r]) * rows_per_bank
                   + int(rplan.slot_of_copy[row, r]))
            out[row] += g[pos]
    return out


def _fold_single(g, plan, rows_per_bank):
    flat = (plan.bank_of_row.astype(np.int64) * rows_per_bank
            + plan.slot_of_row)
    return np.asarray(g)[flat]


# ---------------------------------------------------------------------------
# plan invariants
# ---------------------------------------------------------------------------

class TestReplicatedPlan:
    def test_copies_all_one_matches_single_copy_plan(self):
        """k=1 is the degenerate case: same (bank, slot) homes as the §3.2
        greedy, every replica column a cyclic repeat of column 0."""
        rng = np.random.default_rng(0)
        v, banks = 200, 4
        freq = rng.random(v) + 0.1
        plan = non_uniform_partition(freq, banks)
        rplan = replicated_partition(freq, banks,
                                     copies=np.ones(v, np.int32), k_max=3)
        rplan.validate()
        assert rplan.n_replicated == 0
        np.testing.assert_array_equal(rplan.bank_of_copy[:, 0],
                                      plan.bank_of_row)
        np.testing.assert_array_equal(rplan.slot_of_copy[:, 0],
                                      plan.slot_of_row)
        for r in range(1, rplan.k_max):     # cyclic padding
            np.testing.assert_array_equal(rplan.bank_of_copy[:, r],
                                          rplan.bank_of_copy[:, 0])

    def test_copies_land_on_distinct_banks_and_cut_max_share(self):
        rng = np.random.default_rng(1)
        _, freq, plan, _, rplan, _, _ = _setup(rng, k_max=4)
        rplan.validate()
        assert rplan.n_replicated == 5
        single = _plan_share(plan)
        assert rplan.max_share() <= single + 1e-12

    def test_choose_replication_threshold(self):
        """Only rows above total/(banks*k_max) get copies; hot_rows further
        restricts candidates (the tier-composition hook)."""
        freq = np.array([100.0, 50.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0])
        copies = choose_replication(freq, 2, k_max=2)
        assert copies[0] == 2 and copies[1] == 2 and (copies[2:] == 1).all()
        gated = choose_replication(freq, 2, k_max=2,
                                   hot_rows=np.array([1]))
        assert gated[0] == 1 and gated[1] == 2

    def test_dead_bank_gets_no_copies(self):
        """bank_capacity_rows=0 (the fault path) keeps every copy off the
        dead bank."""
        rng = np.random.default_rng(2)
        v, banks = 60, 4
        freq = rng.random(v) + 0.1
        freq[:3] += 50.0
        caps = np.array([0, 40, 40, 40])
        copies = np.ones(v, np.int32)
        copies[:3] = 3
        rplan = replicated_partition(freq, banks, copies=copies,
                                     capacity_rows=40,
                                     bank_capacity_rows=caps)
        rplan.validate()
        vv, rr = np.nonzero(np.arange(rplan.k_max)[None, :]
                            < rplan.copies[:, None])
        assert (rplan.bank_of_copy[vv, rr] != 0).all()


def _plan_share(plan):
    return float(plan.load_per_bank.max() / plan.load_per_bank.sum())


# ---------------------------------------------------------------------------
# lookup parity: k=1 degenerate case + jnp/pallas agreement at k>1
# ---------------------------------------------------------------------------

class TestLookupParity:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_k1_bitmatches_single_copy(self, backend):
        rng = np.random.default_rng(3)
        v, d, banks = 96, 16, 4
        table = (rng.standard_normal((v, d)) * 0.1).astype(np.float32)
        freq = rng.random(v) + 0.1
        plan = non_uniform_partition(freq, banks)
        bt = pack_table(table, plan)
        rplan = replicated_partition(freq, banks,
                                     copies=np.ones(v, np.int32), k_max=1)
        rt = pack_replicated(table, rplan)
        idx = _bags(rng, 17, 6, v)
        want = banked_embedding_bag(bt, idx, None, backend=backend)
        got = replicated_embedding_bag(rt, idx, None, backend=backend)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_k4_pallas_matches_jnp(self):
        rng = np.random.default_rng(4)
        _, _, _, _, _, rt, _ = _setup(rng, k_max=4)
        idx = _bags(rng, 17, 6, 96)
        a = replicated_embedding_bag(rt, idx, None, backend="jnp")
        b = replicated_embedding_bag(rt, idx, None, backend="pallas")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_k4_values_match_single_copy(self):
        """Replica choice only changes WHERE a row is read, never its value:
        bag sums are bit-equal to the single-copy path (same per-bag
        summation order)."""
        rng = np.random.default_rng(5)
        _, _, _, bt, _, rt, _ = _setup(rng, k_max=4)
        idx = _bags(rng, 33, 6, 96)
        want = banked_embedding_bag(bt, idx, None, backend="jnp")
        got = replicated_embedding_bag(rt, idx, None, backend="jnp")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# gradients: copies sum back to the single-copy gradient, bit-exactly
# ---------------------------------------------------------------------------

class TestGradParity:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_copy_sum_bitmatches_single_copy_grads(self, backend):
        rng = np.random.default_rng(6)
        _, _, plan, bt, rplan, rt, cap = _setup(rng, k_max=4)
        idx = _bags(rng, 17, 6, 96)

        def loss_r(p):
            t2 = dataclasses.replace(rt, packed=p)
            return replicated_embedding_bag(t2, idx, None, backend=backend,
                                            bwd_backend=backend).sum()

        def loss_s(p):
            b2 = dataclasses.replace(bt, packed=p)
            return banked_embedding_bag(b2, idx, None, backend="jnp").sum()

        g_r = _fold_replicated(np.asarray(jax.grad(loss_r)(rt.packed)),
                               rplan, cap)
        g_s = _fold_single(np.asarray(jax.grad(loss_s)(bt.packed)),
                           plan, bt.rows_per_bank)
        np.testing.assert_array_equal(g_r, g_s)
        # the hash routing genuinely spreads traffic: with head-biased bags
        # more than one copy of some hot row received gradient
        g_packed = np.asarray(jax.grad(loss_r)(rt.packed))
        touched = 0
        for row in range(5):                 # the replicated head
            pos = (rplan.bank_of_copy[row, :rplan.copies[row]]
                   .astype(np.int64) * cap
                   + rplan.slot_of_copy[row, :rplan.copies[row]])
            touched = max(touched,
                          int((np.abs(g_packed[pos]).sum(-1) > 0).sum()))
        assert touched > 1


# ---------------------------------------------------------------------------
# fault composition: surviving copies cover a dead bank's head reads
# ---------------------------------------------------------------------------

class TestFailover:
    def test_all_live_mask_is_identity(self):
        rng = np.random.default_rng(7)
        _, _, _, _, _, rt, _ = _setup(rng, k_max=4)
        idx = _bags(rng, 17, 6, 96)
        live = jnp.ones(rt.n_banks, dtype=bool)
        a = replicated_embedding_bag(rt, idx, None, backend="jnp")
        b = replicated_embedding_bag(rt, idx, None, backend="jnp",
                                     bank_live=live)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_dead_bank_confined_to_unreplicated_rows(self, backend):
        rng = np.random.default_rng(8)
        table, _, _, _, rplan, rt, _ = _setup(rng, k_max=4)
        v = table.shape[0]
        dead = 1
        live = np.ones(rt.n_banks, bool)
        live[dead] = False
        idx = _bags(rng, 33, 6, v)
        # oracle: zero exactly the rows with NO live copy
        eff = table.copy()
        no_live = np.zeros(v, bool)
        for row in range(v):
            homes = rplan.bank_of_copy[row, :rplan.copies[row]]
            if not live[homes].any():
                eff[row] = 0.0
                no_live[row] = True
        assert not no_live[:5].any()        # k=4 copies always survive 1 kill
        assert no_live.any()                # some single-copy row did die
        rows = np.asarray(idx)
        want = np.where((rows >= 0)[..., None], eff[np.maximum(rows, 0)],
                        0.0).sum(axis=-2)
        got = replicated_embedding_bag(rt, idx, None, backend=backend,
                                       bank_live=jnp.asarray(live))
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)
        # degraded accounting agrees with the oracle, per bag
        counts = degraded_row_counts(rt.remap_bank, jnp.asarray(live),
                                     jnp.asarray(rows))
        want_counts = (no_live[np.maximum(rows, 0)] & (rows >= 0)).sum(-1)
        np.testing.assert_array_equal(np.asarray(counts), want_counts)


# ---------------------------------------------------------------------------
# runtime replica lane: versioned swaps, fresh-pack parity, guards
# ---------------------------------------------------------------------------

class TestReplicaLane:
    def test_runtime_replica_lane_versions_and_parity(self):
        rng = np.random.default_rng(9)
        v, d, banks = 400, 16, 4
        cap = int(np.ceil(v / banks) * 1.5)
        table = (rng.standard_normal((v, d)) * 0.01).astype(np.float32)
        f0 = rng.random(v) + 0.1
        f0[:4] += 400.0                      # head hot enough to replicate
        plan = non_uniform_partition(f0, banks, capacity_rows=cap)
        bt = migrate_table(pack_table(table, plan), plan, rows_per_bank=cap)
        cfg = ReplanConfig.for_vocab(v, banks, capacity_rows=cap,
                                     check_every=2, replicate_k_max=3,
                                     replicate_max_r=8)
        rt = AdaptiveEmbeddingRuntime(bt, plan, cfg, init_freq=f0)
        assert rt.replica_version == 0
        rplan0, rtable0 = rt.replicated
        assert rplan0.n_replicated >= 1
        assert rtable0.k_max == 3
        for _ in range(30):                  # rotated hot set -> drift
            rt.observe_batch(rng.integers(v // 2, v, size=(64,)))
            rt.end_batch()
        assert rt.replanner.n_replans >= 1
        ev = rt.swaps[-1]
        assert ev.replica_version == rt.replica_version >= 1
        # versioned access: current + retired-window semantics
        assert rt.replicated_for(rt.replica_version) is rt.replicated
        with pytest.raises(KeyError):
            rt.replicated_for(-1)
        # swapped table bit-matches a fresh pack of the migrated rows (the
        # serve CLI's first-swap probe, in-test) — and the on-device rebuild
        rplan, rtable = rt.replicated
        assert rtable is not rtable0
        fresh = pack_replicated(unpacked_rows(rt.table), rplan,
                                rows_per_bank=rtable.rows_per_bank)
        np.testing.assert_array_equal(np.asarray(rtable.packed),
                                      np.asarray(fresh.packed))
        np.testing.assert_array_equal(np.asarray(rtable.remap_bank),
                                      np.asarray(fresh.remap_bank))
        np.testing.assert_array_equal(np.asarray(rtable.remap_slot),
                                      np.asarray(fresh.remap_slot))
        redo = migrate_replicated(rt.table, rplan,
                                  rows_per_bank=rtable.rows_per_bank)
        np.testing.assert_array_equal(np.asarray(rtable.packed),
                                      np.asarray(redo.packed))
        # shape pinning: every version feeds the same jit signature
        assert rtable.packed.shape == rtable0.packed.shape
        assert rtable.remap_bank.shape == rtable0.remap_bank.shape

    def test_lane_disabled_by_default(self):
        rng = np.random.default_rng(10)
        v, d, banks = 100, 8, 2
        plan = non_uniform_partition(np.ones(v), banks)
        bt = pack_table((rng.standard_normal((v, d)) * 0.01)
                        .astype(np.float32), plan)
        rt = AdaptiveEmbeddingRuntime(
            bt, plan, ReplanConfig.for_vocab(v, banks))
        assert rt.replica_version is None
        with pytest.raises(ValueError, match="replica lane disabled"):
            _ = rt.replicated

    def test_replication_requires_non_uniform_partitioner(self):
        with pytest.raises(ValueError, match="non_uniform"):
            Replanner(ReplanConfig(n_banks=4, partitioner="cache_aware",
                                   replicate_k_max=2), 100)

    def test_replication_rejects_k_above_banks(self):
        with pytest.raises(ValueError, match="replicate_k_max"):
            Replanner(ReplanConfig(n_banks=2, replicate_k_max=4), 100)
