"""repro.workload: telemetry bounds, drift detection, migration parity,
balanced CSR sharding, and the early-exit fused kernel."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core.cache_runtime import (build_cache_table,
                                      build_cache_table_fixed, cap_cache_plan,
                                      entry_banks, rewrite_bag)
from repro.core.embedding import (BankedTable, balanced_csr_shards,
                                  banked_cache_residual_bag,
                                  banked_embedding_bag, pack_table,
                                  shard_csr_batch)
from repro.core.grace import mine_cooccurrence
from repro.core.partitioning import non_uniform_partition
from repro.workload import (AdaptiveEmbeddingRuntime, CountMinSketch,
                            DriftConfig, DriftDetector, DriftingZipfTrace,
                            ReplanConfig, Replanner, TableTelemetry,
                            TopKCounter, migrate_packed_leaves,
                            migrate_table, read_criteo_tsv, unpacked_rows,
                            write_criteo_tsv)
from repro.workload.migrate import permute_packed_rows


def zipf_ids(n_items, n_draws, a=1.1, seed=0):
    rng = np.random.default_rng(seed)
    p = np.arange(1, n_items + 1, dtype=np.float64) ** (-a)
    return rng.choice(n_items, n_draws, p=p / p.sum())


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

class TestCountMinSketch:
    def test_never_underestimates(self):
        ids = zipf_ids(5000, 50_000)
        cms = CountMinSketch(width=1024, depth=4)
        cms.update(ids)
        exact = np.bincount(ids, minlength=5000).astype(np.float64)
        est = cms.query(np.arange(5000))
        assert (est >= exact - 1e-9).all()

    def test_error_bound(self):
        """Overestimate <= eps * total with prob >= 1 - e^-depth; with
        depth=5 the failure prob is ~0.7% per query — check the MAX error
        over the vocab stays within the bound (generous determinstic run)."""
        ids = zipf_ids(2000, 100_000, seed=1)
        cms = CountMinSketch(width=2048, depth=5, seed=1)
        cms.update(ids)
        exact = np.bincount(ids, minlength=2000).astype(np.float64)
        err = cms.query(np.arange(2000)) - exact
        # all-query max err: allow 3x the single-query eps bound
        assert err.max() <= 3 * cms.epsilon * cms.total

    def test_error_bound_property(self):
        hypothesis = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @given(seed=st.integers(0, 50), a=st.floats(0.6, 1.5),
               width=st.sampled_from([512, 1024, 4096]))
        @settings(max_examples=20, deadline=None)
        def check(seed, a, width):
            ids = zipf_ids(1000, 20_000, a=a, seed=seed)
            cms = CountMinSketch(width=width, depth=4, seed=seed)
            cms.update(ids)
            exact = np.bincount(ids, minlength=1000).astype(np.float64)
            est = cms.query(np.arange(1000))
            assert (est >= exact - 1e-9).all()           # conservative
            # mean overestimate is far inside the eps bound
            assert (est - exact).mean() <= cms.epsilon * cms.total

        check()

    def test_scale_decay(self):
        cms = CountMinSketch(width=64, depth=2)
        cms.update(np.array([3, 3, 3, 7]))
        cms.scale(0.5)
        assert cms.query(np.array([3]))[0] == pytest.approx(1.5)
        assert cms.total == pytest.approx(2.0)


class TestTopKCounter:
    def test_exact_under_budget(self):
        ids = zipf_ids(300, 10_000)
        tk = TopKCounter(budget=300)
        tk.update(ids)
        exact = np.bincount(ids, minlength=300)
        assert tk.evictions == 0
        for i, c in tk.counts.items():
            assert c == exact[i]

    def test_heavy_hitters_survive_eviction(self):
        ids = zipf_ids(2000, 50_000, a=1.3, seed=2)
        tk = TopKCounter(budget=128)
        tk.update(ids)
        exact = np.bincount(ids, minlength=2000)
        true_top10 = set(np.argsort(-exact)[:10].tolist())
        kept = set(int(i) for i in tk.topk(64).tolist())
        assert true_top10 <= kept


class TestDriftDetector:
    def _tel(self, vocab=2000, seed=0, perm=None, n=30_000):
        ids = zipf_ids(vocab, n, seed=seed)
        if perm is not None:
            ids = perm[ids]
        t = TableTelemetry(vocab, topk_budget=512, sketch_width=1024)
        t.observe(ids)
        return t

    def test_no_trigger_same_distribution(self):
        t = self._tel(seed=0)
        det = DriftDetector(t.freq_vector(), k=128, min_observations=100)
        t.observe(zipf_ids(2000, 30_000, seed=99))       # fresh same-dist draw
        rep = det.check(t)
        assert not rep.drifted

    def test_trigger_on_rotation(self):
        t = self._tel(seed=0)
        det = DriftDetector(t.freq_vector(), k=128, min_observations=100)
        perm = np.roll(np.arange(2000), 700)
        t.observe(perm[zipf_ids(2000, 60_000, seed=1)])
        rep = det.check(t)
        assert rep.drifted and rep.topk_jaccard < 0.6

    def test_holds_fire_below_min_observations(self):
        t = TableTelemetry(2000)
        t.observe(np.arange(50))
        det = DriftDetector(np.ones(2000), k=64, min_observations=10_000)
        assert not det.check(t).drifted


# ---------------------------------------------------------------------------
# trace generation / replay
# ---------------------------------------------------------------------------

class TestDriftingTrace:
    CFG = DriftConfig(n_items=3000, zipf_a=1.1, avg_bag=6,
                      rotate_every=100, rotate_frac=0.3,
                      burst_prob=0.02, burst_len=16, burst_items=8)

    def test_deterministic_replay(self):
        a = DriftingZipfTrace(self.CFG, seed=5).bags(250)
        b = DriftingZipfTrace(self.CFG, seed=5).bags(250)
        assert all((x == y).all() for x, y in zip(a, b))

    def test_random_access_matches_stream(self):
        tr1 = DriftingZipfTrace(self.CFG, seed=5)
        stream = tr1.bags(150)
        tr2 = DriftingZipfTrace(self.CFG, seed=5)
        assert (tr2.bag(149) == stream[149]).all()
        assert (tr2.bag(3) == stream[3]).all()           # out of order too

    def test_hot_set_rotates(self):
        tr = DriftingZipfTrace(self.CFG, seed=1)
        top0 = set(np.argsort(-tr.popularity(0))[:40].tolist())
        top3 = set(np.argsort(-tr.popularity(350))[:40].tolist())
        assert len(top0 & top3) < 20

    def test_rect_padding(self):
        tr = DriftingZipfTrace(self.CFG, seed=2)
        r = tr.rect(16, 5)
        assert r.shape == (16, 5) and r.dtype == np.int32
        assert ((r >= -1) & (r < self.CFG.n_items)).all()
        assert (r[:, 0] >= 0).all()                      # bags never empty

    def test_diurnal_oscillates(self):
        cfg = DriftConfig(n_items=2000, zipf_a=1.2, diurnal_period=200)
        tr = DriftingZipfTrace(cfg, seed=0)
        day = set(np.argsort(-tr.popularity(0))[:30].tolist())
        night = set(np.argsort(-tr.popularity(100))[:30].tolist())
        day2 = set(np.argsort(-tr.popularity(200))[:30].tolist())
        assert len(day & night) < 15                     # swapped audience
        assert len(day & day2) > 25                      # and back again


class TestCriteoReader:
    def test_synthesized_drifting_tsv_roundtrip(self, tmp_path):
        """write_criteo_tsv -> read_criteo_tsv replays cleanly: shapes, the
        populated/empty field split, determinism in (seed, row index)."""
        p = tmp_path / "drift.tsv"
        cfg = DriftConfig(n_items=500, zipf_a=1.2, avg_bag=1.0,
                          rotate_every=64, rotate_frac=0.3)
        write_criteo_tsv(str(p), 128, n_fields=5, vocab_per_field=500,
                         drift=cfg, seed=3)
        out = read_criteo_tsv(str(p), hash_vocab=500)
        assert out["sparse"].shape == (128, 26)
        assert (out["sparse"][:, :5] >= 0).all()
        assert (out["sparse"][:, 5:] == -1).all()        # unpopulated fields
        assert ((out["sparse"][:, :5] < 500)).all()
        p2 = tmp_path / "drift2.tsv"
        write_criteo_tsv(str(p2), 128, n_fields=5, vocab_per_field=500,
                         drift=cfg, seed=3)
        out2 = read_criteo_tsv(str(p2), hash_vocab=500)
        np.testing.assert_array_equal(out["sparse"], out2["sparse"])
        # the hot set actually rotates across the file
        top_a = set(np.unique(out["sparse"][:32, 0]).tolist())
        top_b = set(np.unique(out["sparse"][96:, 0]).tolist())
        assert top_a != top_b

    def test_roundtrip(self, tmp_path):
        rows = ["1\t" + "\t".join(str(i) for i in range(13)) + "\t"
                + "\t".join(f"{i:x}" for i in range(26)),
                "0\t" + "\t".join("" for _ in range(13)) + "\t"
                + "\t".join("" for _ in range(26))]
        p = tmp_path / "crit.tsv"
        p.write_text("\n".join(rows) + "\n")
        out = read_criteo_tsv(str(p), hash_vocab=1000)
        assert out["label"].tolist() == [1.0, 0.0]
        assert out["dense"].shape == (2, 13)
        assert out["sparse"].shape == (2, 26)
        assert (out["sparse"][0] >= 0).all()
        assert (out["sparse"][1] == -1).all()            # missing -> pad id


# ---------------------------------------------------------------------------
# migration
# ---------------------------------------------------------------------------

def _capacity_table(table, plan, cap):
    flat = (plan.bank_of_row.astype(np.int64) * cap
            + plan.slot_of_row).astype(np.int32)
    return BankedTable(
        packed=permute_packed_rows(jnp.asarray(table),
                                   np.arange(table.shape[0], dtype=np.int32),
                                   flat, plan.n_banks * cap),
        remap_bank=jnp.asarray(plan.bank_of_row, jnp.int32),
        remap_slot=jnp.asarray(plan.slot_of_row, jnp.int32),
        n_banks=plan.n_banks, rows_per_bank=cap)


class TestMigration:
    def _plans(self, V=400, banks=4, cap=None, seed=0):
        rng = np.random.default_rng(seed)
        cap = cap or (V // banks + 20)
        p_a = non_uniform_partition(rng.random(V) + 0.1, banks,
                                    capacity_rows=cap)
        p_b = non_uniform_partition(np.roll(rng.random(V) + 0.1, V // 3),
                                    banks, capacity_rows=cap)
        return p_a, p_b, cap

    def test_bit_identical_to_fresh_pack(self):
        V, D = 400, 24
        rng = np.random.default_rng(3)
        table = rng.standard_normal((V, D)).astype(np.float32)
        p_a, p_b, cap = self._plans(V)
        t_a = _capacity_table(table, p_a, cap)
        t_mig = migrate_table(t_a, p_b, rows_per_bank=cap)
        fresh = np.zeros((p_b.n_banks * cap, D), np.float32)
        fresh[p_b.bank_of_row.astype(np.int64) * cap + p_b.slot_of_row] \
            = table
        assert (np.asarray(t_mig.packed) == fresh).all()
        assert (np.asarray(t_mig.remap_bank) == p_b.bank_of_row).all()
        assert (np.asarray(t_mig.remap_slot) == p_b.slot_of_row).all()

    def test_migrated_lookup_identical_to_fresh_build(self):
        """The acceptance bar: migrated table + new remap arrays produce
        bit-identical lookups to a fresh build of the same plan."""
        V, D = 300, 16
        rng = np.random.default_rng(4)
        table = rng.standard_normal((V, D)).astype(np.float32)
        p_a, p_b, cap = self._plans(V)
        t_mig = migrate_table(_capacity_table(table, p_a, cap), p_b,
                              rows_per_bank=cap)
        t_fresh = _capacity_table(table, p_b, cap)
        idx = jnp.asarray(rng.integers(-1, V, (16, 6)), jnp.int32)
        out_mig = banked_embedding_bag(t_mig, idx, None, backend="jnp")
        out_fresh = banked_embedding_bag(t_fresh, idx, None, backend="jnp")
        assert (np.asarray(out_mig) == np.asarray(out_fresh)).all()

    def test_rowwise_state_follows_rows(self):
        V = 200
        rng = np.random.default_rng(5)
        p_a, p_b, cap = self._plans(V, seed=5)
        acc = jnp.asarray(rng.random(p_a.n_banks * cap).astype(np.float32))
        table = rng.standard_normal((V, 8)).astype(np.float32)
        t_a = _capacity_table(table, p_a, cap)
        tree = {"emb_packed": t_a.packed, "acc": acc,
                "dense": jnp.ones((3, 3))}
        out = migrate_packed_leaves(tree, t_a, p_b, rows_per_bank=cap)
        old_flat = p_a.bank_of_row.astype(np.int64) * cap + p_a.slot_of_row
        new_flat = p_b.bank_of_row.astype(np.int64) * cap + p_b.slot_of_row
        np.testing.assert_array_equal(
            np.asarray(out["acc"])[new_flat], np.asarray(acc)[old_flat])
        assert out["dense"] is tree["dense"]             # untouched leaf

    def test_vocab_mismatch_raises(self):
        p_a, p_b, cap = self._plans(100)
        t = _capacity_table(np.zeros((100, 4), np.float32), p_a, cap)
        bad = non_uniform_partition(np.ones(50), 4)
        with pytest.raises(ValueError):
            migrate_table(t, bad)

    def test_bad_exchange_raises(self):
        p_a, p_b, cap = self._plans(100)
        t = _capacity_table(np.zeros((100, 4), np.float32), p_a, cap)
        with pytest.raises(ValueError, match="exchange"):
            migrate_table(t, p_b, exchange="broadcast")

    def test_compact_exchange_sharded_parity(self):
        """Compact (n_moved, D) psum == full packed-size psum == fresh pack,
        on a 1x1 mesh here (the pipe-cleaner; the real 4x2-mesh parity runs
        in tests/dist_checks.py with forced host devices), including the
        no-move short-circuit that drops the collective entirely."""
        from repro.core.compat import make_mesh
        from repro.core.embedding import DistCtx
        V, D, banks = 120, 8, 1
        rng = np.random.default_rng(9)
        table = rng.standard_normal((V, D)).astype(np.float32)
        cap = V + 10
        p_a = non_uniform_partition(rng.random(V) + 0.1, banks,
                                    capacity_rows=cap)
        p_b = non_uniform_partition(np.roll(rng.random(V) + 0.1, 40), banks,
                                    capacity_rows=cap)
        t_a = _capacity_table(table, p_a, cap)
        mesh = make_mesh((1, 1), ("data", "model"))
        dist = DistCtx(mesh=mesh, dp_axes=("data",))
        fresh = np.zeros((banks * cap, D), np.float32)
        fresh[p_b.bank_of_row.astype(np.int64) * cap + p_b.slot_of_row] \
            = table
        for exchange in ("compact", "full"):
            t_mig = migrate_table(t_a, p_b, dist, rows_per_bank=cap,
                                  exchange=exchange)
            assert (np.asarray(t_mig.packed) == fresh).all(), exchange
        t_same = migrate_table(t_a, p_a, dist, rows_per_bank=cap)
        assert (np.asarray(t_same.packed) == np.asarray(t_a.packed)).all()


# ---------------------------------------------------------------------------
# replanner + runtime loop
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestAdaptiveLoop:
    def test_replans_on_drift_and_improves_balance(self):
        V, banks = 1500, 4
        cap = V // banks + 60
        rng = np.random.default_rng(0)
        table = rng.standard_normal((V, 8)).astype(np.float32)
        plan0 = non_uniform_partition(np.ones(V), banks, capacity_rows=cap)
        t0 = _capacity_table(table, plan0, cap)
        rcfg = ReplanConfig.for_vocab(V, banks, capacity_rows=cap,
                                      check_every=4)
        rt = AdaptiveEmbeddingRuntime(t0, plan0, rcfg, init_freq=np.ones(V))
        tr = DriftingZipfTrace(
            DriftConfig(n_items=V, zipf_a=1.2, avg_bag=10,
                        rotate_every=120, rotate_frac=0.35), seed=9)
        for _ in range(40):
            rt.observe_bags(tr.bags(24))
            rt.end_batch()
        assert rt.replanner.n_replans >= 1
        # the LIVE traffic is balanced under the current plan
        freq = rt.replanner.telemetry.freq_vector()
        cur = rt._realized_imbalance(rt.plan, freq)
        stale = rt._realized_imbalance(plan0, freq)
        assert cur <= stale
        # swap preserved capacity: shapes never changed
        assert rt.table.packed.shape == t0.packed.shape

    def test_hysteresis_skips_non_improving_replan(self):
        """Detector trips (hot set rotated) but the candidate plan would
        serve the recent window no better than the incumbent — the replan
        is SKIPPED, counted, and the detector is NOT rebased (a later check
        can still commit)."""
        V, banks = 400, 4
        rng = np.random.default_rng(0)
        # near-uniform traffic: a candidate beats the incumbent only by
        # sampling noise, never by 30% — the gate must hold every check
        cfg = ReplanConfig.for_vocab(V, banks, check_every=2,
                                     hysteresis=0.3)
        freq0 = np.ones(V)
        incumbent = non_uniform_partition(freq0, banks)
        rp = Replanner(cfg, V, init_freq=freq0, init_plan=incumbent)
        for _ in range(20):
            rp.observe_rows(rng.integers(0, V, 200))   # uniform: topk rotates
            update = rp.end_batch()
            assert update is None
        assert rp.last_report.drifted                  # the detector DID trip
        assert rp.n_skipped_replans >= 2               # skipped every check
        assert rp.n_replans == 0
        assert rp.current_plan is incumbent

    def test_hysteresis_commits_genuinely_better_plan(self):
        """Traffic concentrated on ONE bank's contiguous block: the greedy
        candidate spreads it, beating the incumbent by far more than the
        margin — the replan commits despite hysteresis."""
        from repro.core.partitioning import uniform_partition
        V, banks = 400, 4
        rng = np.random.default_rng(1)
        cfg = ReplanConfig.for_vocab(V, banks, check_every=2,
                                     hysteresis=0.05)
        incumbent = uniform_partition(V, banks)        # contiguous blocks
        rp = Replanner(cfg, V, init_freq=np.ones(V), init_plan=incumbent)
        for _ in range(20):
            rp.observe_rows(rng.integers(0, V // banks, 200))  # bank 0 only
            update = rp.end_batch()
            if update is not None:
                break
        assert update is not None and rp.n_replans == 1
        assert rp.current_plan is update.plan
        freq = update.freq
        assert (Replanner.projected_max_share(update.plan, freq)
                < Replanner.projected_max_share(incumbent, freq) * 0.95)

    def test_hysteresis_cache_aware_counts_absorbed_reads(self):
        """The cache-aware projection replays bags through (plan, capped
        cache): a hit costs ONE read on the ENTRY's bank — raw row share
        would score the same layout very differently."""
        from repro.core.grace import CacheEntry, CachePlan
        plan = non_uniform_partition(np.array([4.0, 3.0, 2.0, 1.0]), 2,
                                     capacity_rows=2)
        cp = CachePlan(groups=[np.array([0, 1])], benefits=np.array([2.0]),
                       entries=[CacheEntry(members=(0, 1), hits=5)],
                       entry_of_subset={(0, 1): 0})
        entry_bank = 1 - plan.bank_of_row[2]     # entry away from row 2
        fcp = cap_cache_plan(cp, np.array([entry_bank]), 2, 1)
        bags = [np.array([0, 1, 2])] * 4
        # rewrite: {0,1} -> one entry read on entry_bank, residual {2} on
        # its own bank -> two reads, one per bank -> perfectly balanced
        got = Replanner.projected_max_share_cached(plan, fcp, bags)
        assert got == pytest.approx(0.5)
        # raw row share of the same traffic is lopsided (rows 0,1 share a
        # bank under the greedy), which is exactly the miscount the cached
        # projection exists to avoid
        freq = np.zeros(4)
        np.add.at(freq, np.concatenate(bags), 1.0)
        assert Replanner.projected_max_share(plan, freq) \
            == pytest.approx(2 / 3)

    def test_hysteresis_cache_aware_tracks_installed_cache(self):
        """A committed cache-aware replan retains its capped cache plan as
        the hysteresis incumbent; the loop keeps functioning with the gate
        on (commits and skips both account)."""
        rng = np.random.default_rng(3)
        V, banks = 300, 2
        cfg = ReplanConfig.for_vocab(
            V, banks, check_every=2, partitioner="cache_aware",
            cache_rows_per_bank=4, mine_min_support=2, hysteresis=0.05)
        rp = Replanner(cfg, V, init_freq=np.ones(V),
                       init_plan=non_uniform_partition(np.ones(V), banks))
        assert rp.current_cache_fixed is None
        rp.observe_bags([np.array([1, 2, 3])] * 8)
        first = rp.force_replan()
        assert rp.current_cache_fixed is first.cache_fixed is not None
        drifted_decisions = 0
        for i in range(30):
            hot = 100 + 50 * (i // 10)           # rotating grouped hot set
            rp.observe_bags([np.array([hot, hot + 1, hot + 2]),
                             rng.integers(0, V, 4)])
            update = rp.end_batch()
            if update is not None:
                assert rp.current_cache_fixed is update.cache_fixed
            drifted_decisions = rp.n_replans + rp.n_skipped_replans
        assert drifted_decisions >= 1            # the gate actually ran

    def test_hysteresis_off_by_default(self):
        """hysteresis=0.0 reproduces PR-4 behavior: every drifted check
        replans, nothing is skipped."""
        V, banks = 400, 4
        rng = np.random.default_rng(2)
        cfg = ReplanConfig.for_vocab(V, banks, check_every=2)
        rp = Replanner(cfg, V, init_freq=np.ones(V),
                       init_plan=non_uniform_partition(np.ones(V), banks))
        for _ in range(20):
            rp.observe_rows(rng.integers(0, V, 200))
            rp.end_batch()
        assert rp.n_replans >= 1
        assert rp.n_skipped_replans == 0

    def test_cache_aware_replan_builds_cache_plan(self):
        V, banks = 600, 4
        cap = V // banks + 40
        rcfg = ReplanConfig.for_vocab(
            V, banks, capacity_rows=cap, partitioner="cache_aware",
            check_every=2, mine_min_support=2, min_observations=256)
        rp = Replanner(rcfg, V, init_freq=np.ones(V))
        tr = DriftingZipfTrace(
            DriftConfig(n_items=V, zipf_a=1.3, avg_bag=8,
                        rotate_every=60, rotate_frac=0.4), seed=2)
        update = None
        for _ in range(30):
            rp.observe_bags(tr.bags(16))
            update = rp.end_batch() or update
        assert update is not None and update.cache_plan is not None
        update.plan.validate()

    def test_rebuilt_cache_table_entries_exact(self):
        """After a cache_aware replan, every rebuilt cache entry stores the
        exact partial sum of its member rows (from the LIVE table values)."""
        V, banks, D = 600, 4, 8
        cap = V // banks + 40
        rng = np.random.default_rng(0)
        table = rng.standard_normal((V, D)).astype(np.float32)
        plan0 = non_uniform_partition(np.ones(V), banks, capacity_rows=cap)
        t0 = _capacity_table(table, plan0, cap)
        rcfg = ReplanConfig.for_vocab(
            V, banks, capacity_rows=cap, partitioner="cache_aware",
            check_every=2, mine_min_support=2, min_observations=256)
        rt = AdaptiveEmbeddingRuntime(t0, plan0, rcfg, init_freq=np.ones(V))
        tr = DriftingZipfTrace(
            DriftConfig(n_items=V, zipf_a=1.3, avg_bag=8,
                        rotate_every=60, rotate_frac=0.4), seed=2)
        event = None
        for _ in range(30):
            rt.observe_bags(tr.bags(16))
            event = rt.end_batch() or event
        assert event is not None
        ct = rt.rebuild_cache_table(event.update)
        cp = event.update.cache_plan
        assert ct is not None and cp.n_entries > 0
        cflat = (np.asarray(ct.remap_bank).astype(np.int64)
                 * ct.rows_per_bank + np.asarray(ct.remap_slot))
        packed = np.asarray(ct.packed)
        for e, entry in enumerate(cp.entries):
            want = table[list(entry.members)].sum(axis=0)
            np.testing.assert_allclose(packed[cflat[e]], want, atol=1e-5)


# ---------------------------------------------------------------------------
# cache-aware serving under the adaptive runtime: fixed-capacity GRACE swaps
# ---------------------------------------------------------------------------

class TestCacheSwap:
    V, BANKS, D, CRPB = 600, 4, 8, 16

    def _runtime(self, seed=0, **overrides):
        V, banks = self.V, self.BANKS
        cap = V // banks + 40
        rng = np.random.default_rng(seed)
        table = rng.standard_normal((V, self.D)).astype(np.float32)
        plan0 = non_uniform_partition(np.ones(V), banks, capacity_rows=cap)
        t0 = _capacity_table(table, plan0, cap)
        kw = dict(partitioner="cache_aware", check_every=2,
                  mine_min_support=2, min_observations=256,
                  cache_rows_per_bank=self.CRPB)
        kw.update(overrides)
        rcfg = ReplanConfig.for_vocab(V, banks, capacity_rows=cap, **kw)
        rt = AdaptiveEmbeddingRuntime(t0, plan0, rcfg, init_freq=np.ones(V),
                                      max_cache_per_bag=4,
                                      max_residual_per_bag=12)
        return rt, table

    def _drive_to_swap(self, rt, seed=2):
        tr = DriftingZipfTrace(
            DriftConfig(n_items=self.V, zipf_a=1.3, avg_bag=8,
                        rotate_every=60, rotate_frac=0.4), seed=seed)
        event = None
        for _ in range(30):
            rt.observe_bags(tr.bags(16))
            event = rt.end_batch() or event
        assert event is not None, "drift never tripped"
        return event, tr

    def test_swap_bit_identical_to_fresh_build(self):
        """Acceptance bar: the swapped-in cache path (migrated EMT + re-summed
        fixed-capacity cache table) is fp32-EXACT against tearing everything
        down and rebuilding from scratch at the same plan — arrays AND the
        served output of the fused lookup."""
        rt, table = self._runtime()
        event, tr = self._drive_to_swap(rt)
        assert event.cache_version is not None and event.cache_entries > 0
        # row values survived migration exactly
        rows = unpacked_rows(rt.table)
        np.testing.assert_array_equal(rows, table)
        # fresh EMT pack at the same fixed capacity
        cap = rt.table.rows_per_bank
        p = rt.plan
        fresh_emt = np.zeros_like(np.asarray(rt.table.packed))
        fresh_emt[p.bank_of_row.astype(np.int64) * cap + p.slot_of_row] = rows
        np.testing.assert_array_equal(np.asarray(rt.table.packed), fresh_emt)
        # fresh cache build from the same update
        fresh_ct = build_cache_table_fixed(rows, event.update.cache_fixed,
                                           dtype=np.float32)
        ct = rt.cache_table
        np.testing.assert_array_equal(np.asarray(ct.packed),
                                      np.asarray(fresh_ct.packed))
        np.testing.assert_array_equal(np.asarray(ct.remap_bank),
                                      np.asarray(fresh_ct.remap_bank))
        np.testing.assert_array_equal(np.asarray(ct.remap_slot),
                                      np.asarray(fresh_ct.remap_slot))
        # end-to-end: serve a rewritten batch through both — bit-equal
        rb = rt.rewrite(tr.rect(8, 10)[:, None, :])
        got = banked_cache_residual_bag(
            rt.table, ct, jnp.asarray(rb.cache_idx),
            jnp.asarray(rb.residual_idx), None, backend="jnp")
        t_fresh = _capacity_table(rows, p, cap)
        want = banked_cache_residual_bag(
            t_fresh, fresh_ct, jnp.asarray(rb.cache_idx),
            jnp.asarray(rb.residual_idx), None, backend="jnp")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_inflight_batch_resolves_its_own_version(self):
        """A batch rewritten just before a swap carries OLD entry numbering;
        table_for(batch.version) must return the retired table, and serving
        with it must bit-match serving fully pre-swap."""
        rt, _ = self._runtime()
        # install a first mined plan so version 1 has live entries
        event, tr = self._drive_to_swap(rt)
        v_old = rt.rewriter.version
        rb = rt.rewrite(tr.rect(8, 10)[:, None, :])      # in-flight batch
        assert rb.version == v_old
        t_old, ct_old = rt.table, rt.cache_table
        pre = banked_cache_residual_bag(
            t_old, ct_old, jnp.asarray(rb.cache_idx),
            jnp.asarray(rb.residual_idx), None, backend="jnp")
        # force a second swap while rb is in flight
        rt.observe_bags(tr.bags(64))
        event2 = rt.apply(rt.replanner.force_replan())
        assert rt.rewriter.version == v_old + 1
        assert rt.cache_table is not ct_old
        # the in-flight batch resolves against ITS version...
        assert rt.cache_table_for(rb.version) is ct_old
        post = banked_cache_residual_bag(
            rt.table, rt.cache_table_for(rb.version),
            jnp.asarray(rb.cache_idx), jnp.asarray(rb.residual_idx), None,
            backend="jnp")
        # ...and the served output is unchanged by the swap (fp32 exact:
        # migration preserves row values bit-wise)
        np.testing.assert_array_equal(np.asarray(pre), np.asarray(post))
        # a batch rewritten AFTER the swap is tagged with the new version
        rb2 = rt.rewrite(tr.rect(4, 10)[:, None, :])
        assert rb2.version == v_old + 1

    def test_retired_version_raises(self):
        rt, _ = self._runtime()
        event, tr = self._drive_to_swap(rt)
        rt.observe_bags(tr.bags(64))
        rt.apply(rt.replanner.force_replan())
        rt.observe_bags(tr.bags(64))
        rt.apply(rt.replanner.force_replan())            # retires v, v+1
        with pytest.raises(KeyError, match="retired"):
            rt.cache_table_for(0)

    def test_fixed_capacity_pad_truncate_roundtrip(self):
        """cap_cache_plan at a TIGHT capacity: kept entries keep their exact
        partial sums at in-range positions, overflow entries leave
        entry_of_subset (degrading to residual), pad positions are zero and
        unique — and the packed shape never depends on what was mined."""
        rng = np.random.default_rng(7)
        V, banks, crpb = 300, 4, 3                       # tight: 12 entries
        table = rng.standard_normal((V, 8)).astype(np.float32)
        bags = [rng.choice(40, rng.integers(2, 8)) for _ in range(400)]
        cp = mine_cooccurrence(bags, top_items=64, max_groups=32,
                               min_support=2)
        assert cp.n_entries > banks * crpb               # mining overflows
        plan = non_uniform_partition(np.ones(V) + 0.1, banks)
        fcp = cap_cache_plan(
            cp, entry_banks(cp, plan.bank_of_row, None), banks, crpb)
        cap_total = banks * crpb
        assert fcp.capacity == cap_total
        assert fcp.n_entries + fcp.n_dropped == cp.n_entries
        assert fcp.n_entries <= cap_total
        assert fcp.entry_bank.shape == (cap_total,)
        # every (bank, slot) position used exactly once, all in range
        flat = fcp.entry_bank.astype(np.int64) * crpb + fcp.entry_slot
        assert np.unique(flat).shape[0] == cap_total
        assert fcp.entry_bank.min() >= 0 and fcp.entry_bank.max() < banks
        assert fcp.entry_slot.min() >= 0 and fcp.entry_slot.max() < crpb
        # kept entries: exact sums at their positions; pads: zero
        ct = build_cache_table_fixed(table, fcp)
        packed = np.asarray(ct.packed)
        full = build_cache_table(table, fcp.plan)
        for e in range(fcp.n_entries):
            np.testing.assert_array_equal(packed[flat[e]], full[e])
        for e in range(fcp.n_entries, cap_total):
            np.testing.assert_array_equal(packed[flat[e]], 0.0)
        # capped rewrite never emits a dropped entry id
        kept_ids = set(fcp.plan.entry_of_subset.values())
        assert all(0 <= i < fcp.n_entries for i in kept_ids)
        for bag in bags[:50]:
            c, r = rewrite_bag(bag, fcp.plan)
            assert all(0 <= eid < fcp.n_entries for eid in c)
        # a roomier capacity keeps EVERYTHING (pad-only round trip)
        fcp2 = cap_cache_plan(
            cp, entry_banks(cp, plan.bank_of_row, None), banks,
            cp.n_entries)                                # >= one bank's worth
        assert fcp2.n_dropped == 0
        assert fcp2.plan.entry_of_subset == cp.entry_of_subset

    def test_residual_overflow_refuses_instead_of_dropping(self):
        """Bags longer than the residual budget must raise, not silently
        drop lookups (the budget exists for static shapes, not sampling)."""
        rt, _ = self._runtime()
        too_long = np.zeros((2, 1, 13), np.int32)        # budget is 12
        with pytest.raises(ValueError, match="residual overflow"):
            rt.rewrite(too_long)

    def test_non_cache_replan_installs_empty_plan(self):
        """A cache-enabled runtime fed a non-cache-aware update must not
        serve stale entry sums: the swap installs the empty plan."""
        rt, _ = self._runtime()
        event, tr = self._drive_to_swap(rt)
        assert rt.cache_plan.n_entries > 0
        # hand-build a plain update (no cache side)
        rt.observe_bags(tr.bags(32))
        from repro.workload import PlanUpdate
        upd = rt.replanner.force_replan()
        upd = PlanUpdate(plan=upd.plan, freq=upd.freq, report=upd.report)
        ev = rt.apply(upd)
        assert ev.cache_entries == 0
        assert rt.cache_plan.n_entries == 0
        rb = rt.rewrite(tr.rect(8, 10)[:, None, :])
        assert (rb.cache_idx == -1).all()                # pure residual


# ---------------------------------------------------------------------------
# drift checks at scale: top-K-union path == dense path on small vocabs
# ---------------------------------------------------------------------------

class TestSparseDriftCheck:
    def test_union_path_matches_dense_on_small_vocab(self):
        """With k >= vocab and every id observed (head exact), the top-K-union
        check must be NUMERICALLY IDENTICAL to the dense (vocab,) path."""
        vocab = 300
        rng = np.random.default_rng(0)
        p = np.arange(1, vocab + 1, dtype=np.float64) ** -1.1
        p /= p.sum()
        t = TableTelemetry(vocab, topk_budget=vocab)
        t.observe(np.arange(vocab))                      # all ids seen
        t.observe(rng.choice(vocab, 20_000, p=p))
        ref = t.freq_vector()
        t.observe(np.roll(np.arange(vocab), 100)[
            rng.choice(vocab, 30_000, p=p)])
        dense = DriftDetector(ref, k=vocab, min_observations=10)
        sparse = DriftDetector(ref, k=vocab, min_observations=10,
                               sparse_above=0)           # force union path
        rd, rs = dense.check(t), sparse.check(t)
        assert rd.topk_jaccard == rs.topk_jaccard
        assert rd.weighted_l1 == pytest.approx(rs.weighted_l1, abs=1e-12)
        assert rd.drifted == rs.drifted

    def test_union_path_trips_on_rotation_small_k(self):
        vocab = 300
        rng = np.random.default_rng(1)
        p = np.arange(1, vocab + 1, dtype=np.float64) ** -1.2
        p /= p.sum()
        t = TableTelemetry(vocab, topk_budget=vocab)
        t.observe(rng.choice(vocab, 20_000, p=p))
        det = DriftDetector(t.freq_vector(), k=64, min_observations=10,
                            sparse_above=0)
        assert not det.check(t).drifted                  # no drift yet
        t.observe(np.roll(np.arange(vocab), 150)[
            rng.choice(vocab, 60_000, p=p)])
        rep = det.check(t)
        assert rep.drifted and rep.topk_jaccard < 0.5

    def test_union_path_survives_out_of_range_ids(self):
        """A corrupt log row can land an id >= vocab in the head counter;
        the union check must drop it (freq_vector's keep-guard) and keep
        checking, not die with IndexError forever after."""
        vocab = 100
        t = TableTelemetry(vocab, topk_budget=64)
        t.observe(np.arange(vocab))
        t.observe(np.full(500, vocab + 7))               # corrupt hot id
        det = DriftDetector(np.ones(vocab), k=32, min_observations=10,
                            sparse_above=0)
        rep = det.check(t)                               # must not raise
        assert 0.0 <= rep.topk_jaccard <= 1.0

    def test_freq_on_matches_freq_vector(self):
        vocab = 200
        rng = np.random.default_rng(2)
        t = TableTelemetry(vocab, topk_budget=32)        # force head eviction
        t.observe(rng.integers(0, vocab, 5000))
        ids = rng.integers(0, vocab, 64)
        np.testing.assert_array_equal(t.freq_on(ids), t.freq_vector()[ids])


# ---------------------------------------------------------------------------
# balanced CSR sharding (host-side splitter; the mesh path runs in
# tests/dist_checks.py)
# ---------------------------------------------------------------------------

class TestBalancedCsrSplit:
    def test_equal_totals(self):
        rng = np.random.default_rng(0)
        lens = rng.integers(1, 40, 200)
        offsets = np.concatenate([[0], np.cumsum(lens)])
        bounds = balanced_csr_shards(offsets, 8)
        totals = offsets[bounds[1:]] - offsets[bounds[:-1]]
        assert bounds[0] == 0 and bounds[-1] == 200
        assert (np.diff(bounds) >= 0).all()
        # each shard within one max-bag of the ideal share
        ideal = offsets[-1] / 8
        assert (np.abs(totals - ideal) <= lens.max()).all()

    def test_beats_equal_bag_count_split(self):
        """Skewed raggedness: totals-based cuts are tighter than bag-count
        cuts (the whole point vs replicating / naive splitting)."""
        rng = np.random.default_rng(1)
        lens = np.where(rng.random(160) < 0.1,
                        rng.integers(50, 100, 160), rng.integers(1, 4, 160))
        offsets = np.concatenate([[0], np.cumsum(lens)])
        bounds = balanced_csr_shards(offsets, 4)
        totals = offsets[bounds[1:]] - offsets[bounds[:-1]]
        naive = np.array([offsets[40] - offsets[0], offsets[80] - offsets[40],
                          offsets[120] - offsets[80],
                          offsets[160] - offsets[120]])
        assert totals.max() <= naive.max()

    def test_shard_csr_batch_covers_every_entry(self):
        rng = np.random.default_rng(2)
        lens = rng.integers(1, 9, 37)
        offsets = np.concatenate([[0], np.cumsum(lens)])
        indices = rng.integers(0, 500, int(offsets[-1])).astype(np.int32)
        sh = shard_csr_batch(indices, offsets, 4)
        got = sh["idx"][sh["idx"] >= 0]
        assert sorted(got.tolist()) == sorted(indices.tolist())
        seg = sh["seg"][sh["idx"] >= 0]
        assert (np.sort(np.unique(seg)) == np.arange(37)).all()

    def test_degenerate_single_shard(self):
        offsets = np.array([0, 3, 5])
        bounds = balanced_csr_shards(offsets, 1)
        assert bounds.tolist() == [0, 2]


# ---------------------------------------------------------------------------
# early-exit fused kernel (satellite): parity incl. interior -1 holes
# ---------------------------------------------------------------------------

class TestFusedEarlyExit:
    def _setup(self, seed=0):
        rng = np.random.default_rng(seed)
        V, Dc, D = 120, 10, 16
        emt = rng.standard_normal((V, D)).astype(np.float32)
        cache = rng.standard_normal((Dc, D)).astype(np.float32)
        bt = pack_table(emt, non_uniform_partition(rng.random(V) + 0.1, 2))
        cbt = pack_table(cache, non_uniform_partition(rng.random(Dc) + 0.1, 2))
        return rng, V, Dc, bt, cbt

    def test_parity_suffix_padding(self):
        rng, V, Dc, bt, cbt = self._setup()
        B, Lc, Lr = 16, 3, 7
        ci = np.full((B, Lc), -1, np.int32)
        ri = np.full((B, Lr), -1, np.int32)
        for b in range(B):
            nc, nr = rng.integers(0, Lc + 1), rng.integers(0, Lr + 1)
            ci[b, :nc] = rng.integers(0, Dc, nc)
            ri[b, :nr] = rng.integers(0, V, nr)
        got = banked_cache_residual_bag(bt, cbt, jnp.asarray(ci),
                                        jnp.asarray(ri), None,
                                        backend="pallas", interpret=True)
        want = banked_cache_residual_bag(bt, cbt, jnp.asarray(ci),
                                         jnp.asarray(ri), None, backend="jnp")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_parity_interior_holes(self):
        """-1 holes BEFORE the last valid entry must still be masked (the
        early exit trims only the trailing run)."""
        rng, V, Dc, bt, cbt = self._setup(seed=7)
        B, Lc, Lr = 8, 4, 6
        ci = rng.integers(-1, Dc, (B, Lc)).astype(np.int32)
        ri = rng.integers(-1, V, (B, Lr)).astype(np.int32)
        ri[:, -1] = -1                                   # trailing pad too
        got = banked_cache_residual_bag(bt, cbt, jnp.asarray(ci),
                                        jnp.asarray(ri), None,
                                        backend="pallas", interpret=True)
        want = banked_cache_residual_bag(bt, cbt, jnp.asarray(ci),
                                         jnp.asarray(ri), None, backend="jnp")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_effective_lengths(self):
        from repro.kernels.embedding_bag import effective_lengths
        idx = jnp.asarray([[1, -1, 2, -1, -1],
                           [-1, -1, -1, -1, -1],
                           [5, 6, 7, 8, 9]], jnp.int32)
        np.testing.assert_array_equal(np.asarray(effective_lengths(idx)),
                                      [3, 0, 5])
