"""End-to-end behaviour of the paper's system: profile -> mine -> partition
-> banked train -> cache refresh -> rewritten serving, on the reduced
updlrm-paper workload. The invariants under test are the paper's:

  1. cache-aware partitioning balances realized bank load at least as well
     as uniform under a skewed trace,
  2. the cache-rewritten serving path returns the SAME scores as the plain
     path (Fig. 7 semantics) after training has moved the table,
  3. training the banked model reduces loss (the partitioned embedding
     learns like a plain one).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.cache_runtime import build_cache_table, rewrite_bags
from repro.core.grace import mine_cooccurrence
from repro.core.partitioning import cache_aware_partition, uniform_partition
from repro.data.synthetic import WORKLOADS, multihot_trace, padded_bags
from repro.models import dlrm as D
from repro.sparse.ops import embedding_bag_fixed
from repro.train.train_step import TrainState, build_train_step, default_optimizer


def test_updlrm_system_end_to_end():
    cfg = get_arch("updlrm-paper").reduced
    n_items = cfg.vocab_sizes[0]
    rng = np.random.default_rng(0)

    # --- pre-process (Fig. 4): profile -> mine -> partition ---
    trace = multihot_trace(WORKLOADS["read"], 300, n_items=n_items, seed=0)
    freq = np.zeros(cfg.total_vocab)
    for t in range(cfg.n_sparse):
        for bag in trace:
            np.add.at(freq, bag + t * n_items, 0.125)
    cp = mine_cooccurrence(trace[:150], top_items=256, max_groups=16)
    plan = cache_aware_partition(freq, cp.groups, cp.benefits, 4)
    plan.validate()
    u = uniform_partition(cfg.total_vocab, 4, freq)
    assert plan.imbalance() <= u.imbalance() * 1.5 + 0.5

    # --- banked training ---
    params, statics = D.init_params(cfg, jax.random.key(0), plan)
    opt = default_optimizer(lr=5e-3, emb_lr=5e-2)
    loss_fn = lambda p, b: D.loss_fn(cfg, p, statics, b)
    step = jax.jit(build_train_step(loss_fn, opt))
    state = TrainState.create(params, opt)

    B = 16
    bags = [rng.choice(n_items, size=cfg.multi_hot, replace=False)
            for _ in range(B)]
    sparse = np.stack([padded_bags(bags, cfg.multi_hot)] * cfg.n_sparse,
                      axis=1)
    batch = {
        "dense": jnp.asarray(rng.standard_normal((B, cfg.n_dense)),
                             jnp.float32),
        "sparse": jnp.asarray(sparse),
        "label": jnp.asarray(rng.integers(0, 2, B), jnp.float32),
    }
    losses = []
    for _ in range(25):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (losses[0], losses[-1])

    # --- cache refresh AFTER training + rewritten serving (Fig. 7) ---
    from repro.core.embedding import lookup_unsharded, BankedTable
    trained = BankedTable(packed=state.params["emb_packed"],
                          remap_bank=statics["remap_bank"],
                          remap_slot=statics["remap_slot"],
                          n_banks=statics["n_banks"],
                          rows_per_bank=statics["rows_per_bank"])
    # logical table for field 0
    logical = np.asarray(lookup_unsharded(
        trained, jnp.arange(n_items)[:, None], reduce_bag=True))
    ctab = build_cache_table(logical, cp)
    test_bags = [np.unique(rng.choice(256, size=8)) for _ in range(8)]
    ci, ri = rewrite_bags(test_bags, cp, max_cache_per_bag=8,
                          max_residual_per_bag=16)
    got = np.asarray(embedding_bag_fixed(jnp.asarray(ctab), jnp.asarray(ci))
                     + embedding_bag_fixed(jnp.asarray(logical),
                                           jnp.asarray(ri)))
    want = np.stack([logical[b].sum(0) for b in test_bags])
    np.testing.assert_allclose(got, want, atol=1e-3)
