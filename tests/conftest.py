# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device. Multi-device distribution tests run in subprocesses
# that set --xla_force_host_platform_device_count themselves (test_dist.py).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
