"""Tier-1 tests for the observability layer (src/repro/obs/).

What is pinned here and why it matters:

* Histogram bucket/merge/percentile properties — the registry's p50/p99
  come from FIXED log-spaced buckets so merges are exact; a drifting bucket
  layout or a quantile outside the observed [min, max] silently corrupts
  every latency number the benches and CLIs report.
* ``empirical_percentile`` bit-compatibility — it is the ONE home of the
  sorted-index convention (``s[min(len-1, int(q*len))]``) the committed
  BENCH baselines were generated with; a convention change would show up as
  a fake bench regression.
* Span nesting + Chrome-trace schema — the exported JSON must stay loadable
  by Perfetto ('M' metadata first, 'X' complete events with ts/dur, 'i'
  instants with a scope).
* Registry snapshot determinism — CI gates on the snapshot's key-path
  schema (benchmarks/check_regression.py --metrics-baseline), so two runs
  of one configuration must produce structurally identical documents.
* Zero-recompile — tracing a jit'd step must not add executables; the
  whole obs layer is host-clock-only by contract.
"""
import json
import math
import os

import numpy as np
import pytest

from repro.obs import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    NULL_TRACER,
    PeriodicMetricsWriter,
    Tracer,
    chrome_trace_events,
    empirical_p50,
    empirical_p99,
    empirical_percentile,
    log_bucket_bounds,
    prometheus_text,
    snapshot_doc,
    summary_dict,
    summary_line,
    write_chrome_trace,
    write_metrics_json,
)


# ---------------------------------------------------------------------------
# histogram properties
# ---------------------------------------------------------------------------

class TestHistogram:
    def test_bucket_bounds_fixed_and_sorted(self):
        b = log_bucket_bounds()
        assert b == DEFAULT_BUCKETS
        assert list(b) == sorted(b)
        # 8/decade => adjacent bounds a constant 10**(1/8) apart
        ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
        assert np.allclose(ratios, 10 ** 0.125)

    def test_counts_partition_observations(self):
        h = Histogram("t")
        rng = np.random.default_rng(0)
        xs = rng.lognormal(mean=2.0, sigma=3.0, size=500)
        for x in xs:
            h.observe(x)
        assert h.count == 500
        assert sum(h.counts) == 500
        assert h.sum == pytest.approx(float(np.sum(xs)))
        assert h.min == pytest.approx(float(np.min(xs)))
        assert h.max == pytest.approx(float(np.max(xs)))

    def test_quantile_within_observed_range_and_one_bucket_of_exact(self):
        h = Histogram("t")
        rng = np.random.default_rng(1)
        xs = rng.lognormal(mean=0.0, sigma=2.0, size=1000)
        for x in xs:
            h.observe(x)
        for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
            est = h.quantile(q)
            assert h.min <= est <= h.max
            exact = empirical_percentile(xs, q)
            # bucket answer is the covering bucket's UPPER bound: never
            # below the exact quantile (conservative), and at most one
            # bucket ratio (10**(1/8) ~ 1.33x) above it
            assert est >= exact * 0.999
            assert est <= max(exact * 10 ** 0.125 * 1.001, h.min)

    def test_empty_and_single(self):
        h = Histogram("t")
        assert h.quantile(0.99) == 0.0
        assert h.mean == 0.0
        h.observe(7.0)
        assert h.quantile(0.5) == pytest.approx(7.0)
        assert h.quantile(0.99) == pytest.approx(7.0)

    def test_merge_is_exact(self):
        """merge(a, b) must equal the histogram that saw both streams —
        the property that lets shards/processes combine without samples."""
        rng = np.random.default_rng(2)
        xs = rng.lognormal(sigma=2.5, size=300)
        ys = rng.lognormal(sigma=1.5, size=200) * 50.0
        ha, hb, hall = Histogram("a"), Histogram("b"), Histogram("all")
        for x in xs:
            ha.observe(x)
            hall.observe(x)
        for y in ys:
            hb.observe(y)
            hall.observe(y)
        ha.merge(hb)
        assert ha.counts == hall.counts
        assert ha.count == hall.count
        assert ha.sum == pytest.approx(hall.sum)
        assert ha.min == hall.min and ha.max == hall.max
        for q in (0.5, 0.9, 0.99):
            assert ha.quantile(q) == hall.quantile(q)

    def test_merge_rejects_different_bounds(self):
        ha = Histogram("a")
        hb = Histogram("b", bounds=log_bucket_bounds(per_decade=4))
        with pytest.raises(ValueError):
            ha.merge(hb)

    def test_unsorted_bounds_rejected(self):
        with pytest.raises(ValueError):
            Histogram("t", bounds=(2.0, 1.0))

    def test_property_sweep_hypothesis(self):
        hypothesis = pytest.importorskip("hypothesis")
        st = pytest.importorskip("hypothesis.strategies")

        @hypothesis.given(st.lists(
            st.floats(min_value=1e-6, max_value=1e8,
                      allow_nan=False, allow_infinity=False),
            min_size=1, max_size=200))
        @hypothesis.settings(deadline=None, max_examples=50)
        def check(xs):
            h = Histogram("t")
            for x in xs:
                h.observe(x)
            assert sum(h.counts) == len(xs)
            for q in (0.0, 0.5, 0.99, 1.0):
                assert h.min <= h.quantile(q) <= h.max

        check()


# ---------------------------------------------------------------------------
# the empirical percentile convention
# ---------------------------------------------------------------------------

class TestEmpiricalPercentile:
    def test_matches_legacy_convention(self):
        """Bit-for-bit the historical MicroBatcher/bench convention — the
        committed BENCH baselines depend on this exact index rule."""
        rng = np.random.default_rng(3)
        for n in (1, 2, 3, 7, 100, 199):
            xs = list(rng.normal(size=n))
            for q in (0.5, 0.9, 0.99):
                s = sorted(xs)
                legacy = s[min(len(s) - 1, int(q * len(s)))]
                assert empirical_percentile(xs, q) == legacy

    def test_empty_and_aliases(self):
        assert empirical_percentile([], 0.99) == 0.0
        xs = [5.0, 1.0, 3.0]
        assert empirical_p50(xs) == empirical_percentile(xs, 0.50)
        assert empirical_p99(xs) == empirical_percentile(xs, 0.99)

    def test_bench_p99_delegates_here(self):
        import importlib.util
        spec = importlib.util.spec_from_file_location(
            "bench_workload", os.path.join(os.path.dirname(__file__), "..",
                                           "benchmarks", "bench_workload.py"))
        bench = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(bench)
        xs = list(np.random.default_rng(4).normal(size=137))
        assert bench.p99(xs) == empirical_p99(xs)


# ---------------------------------------------------------------------------
# tracer + chrome trace export
# ---------------------------------------------------------------------------

class TestTracer:
    def test_span_nesting_depth_and_args(self):
        tr = Tracer()
        with tr.span("outer", batch=3):
            with tr.span("inner"):
                pass
        assert tr.span_names() == {"outer", "inner"}
        (outer,) = tr.spans("outer")
        (inner,) = tr.spans("inner")
        assert outer.depth == 0 and inner.depth == 1
        assert outer.args == {"batch": 3}
        # inner completes first but starts later, inside the outer window
        assert outer.ts_us <= inner.ts_us
        assert inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us + 1.0

    def test_span_records_on_exception(self):
        tr = Tracer()
        with pytest.raises(RuntimeError):
            with tr.span("boom"):
                raise RuntimeError("x")
        assert len(tr.spans("boom")) == 1

    def test_disabled_tracer_records_nothing(self):
        tr = Tracer(enabled=False)
        with tr.span("a"):
            tr.instant("b")
        assert tr.records == [] and tr.instants == []
        assert NULL_TRACER.enabled is False

    def test_total_us_sums_same_name(self):
        tr = Tracer()
        for _ in range(3):
            with tr.span("step"):
                pass
        assert tr.total_us("step") == pytest.approx(
            sum(r.dur_us for r in tr.spans("step")))

    def test_chrome_trace_schema(self, tmp_path):
        tr = Tracer()
        with tr.span("rewrite", batch=0):
            pass
        with tr.span("device_step"):
            pass
        tr.instant("swap_live", reason="drift")
        path = str(tmp_path / "trace.json")
        n = write_chrome_trace(tr, path)
        with open(path) as fh:
            doc = json.load(fh)
        assert set(doc) == {"traceEvents", "displayTimeUnit"}
        events = doc["traceEvents"]
        assert len(events) == n
        # metadata first (Perfetto uses it to name tracks)
        assert events[0]["ph"] == "M" and events[0]["name"] == "process_name"
        xs = [e for e in events if e["ph"] == "X"]
        assert {e["name"] for e in xs} == {"rewrite", "device_step"}
        for e in xs:
            assert {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                    "args"} <= set(e)
            assert e["dur"] >= 0.0
        (inst,) = [e for e in events if e["ph"] == "i"]
        assert inst["name"] == "swap_live" and inst["s"] == "t"
        # spans in start-time order
        assert [e["ts"] for e in xs] == sorted(e["ts"] for e in xs)

    def test_chrome_trace_events_deterministic_pid(self):
        tr = Tracer()
        with tr.span("a"):
            pass
        ev = chrome_trace_events(tr, pid=7)
        assert all(e["pid"] == 7 for e in ev)


# ---------------------------------------------------------------------------
# registry + export
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_get_or_create_and_kind_conflict(self):
        reg = MetricRegistry()
        c1 = reg.counter("a.total")
        assert reg.counter("a.total") is c1
        with pytest.raises(TypeError):
            reg.gauge("a.total")
        with pytest.raises(TypeError):
            reg.histogram("a.total")

    def test_counter_rejects_negative(self):
        c = Counter("c")
        c.inc(2)
        with pytest.raises(ValueError):
            c.inc(-1)
        g = Gauge("g")
        g.inc(-1)                      # gauges may go down
        assert g.value == -1.0

    def test_snapshot_schema_deterministic(self):
        """Two registries with the same metric set but DIFFERENT observed
        values must export identical key-path structure — the invariant the
        CI metrics-schema gate (check_regression.py) relies on."""
        import sys
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "benchmarks"))
        try:
            from check_regression import key_paths
        finally:
            sys.path.pop(0)

        def build(seed):
            reg = MetricRegistry()
            reg.counter("serve.requests_total")
            reg.gauge("runtime.cache_version")
            h = reg.histogram("serve.request_latency_ms")
            for x in np.random.default_rng(seed).lognormal(size=20):
                h.observe(x)
            return snapshot_doc(reg, label=f"run-{seed}")

        a, b = build(0), build(1)
        assert a != b                             # values differ...
        assert key_paths(a) == key_paths(b)       # ...schema does not
        hsnap = a["metrics"]["serve.request_latency_ms"]
        assert set(hsnap) == {"type", "count", "sum", "min", "max", "mean",
                              "p50", "p99", "buckets"}
        # never-fired metrics still export (pre-registration contract)
        assert a["metrics"]["serve.requests_total"]["value"] == 0.0

    def test_snapshot_sorted_and_json_stable(self):
        reg = MetricRegistry()
        reg.counter("z.last")
        reg.counter("a.first")
        assert list(reg.snapshot()) == ["a.first", "z.last"]
        assert reg.to_json() == reg.to_json()

    def test_prometheus_text(self):
        reg = MetricRegistry()
        reg.counter("serve.requests_total", "total requests").inc(5)
        h = reg.histogram("serve.request_latency_ms")
        h.observe(0.5)
        h.observe(2.0)
        text = prometheus_text(reg)
        assert "# TYPE serve_requests_total counter" in text
        assert "serve_requests_total 5.0" in text
        assert "# HELP serve_requests_total total requests" in text
        assert '_bucket{le="+Inf"} 2' in text
        assert "serve_request_latency_ms_count 2" in text
        # cumulative buckets are monotone
        cums = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
                if "_bucket{" in line]
        assert cums == sorted(cums)

    def test_summary_line_parses(self):
        reg = MetricRegistry()
        reg.counter("a.total").inc(3)
        reg.histogram("b.ms").observe(1.0)
        line = summary_line(reg)
        assert line.startswith("OBS_SUMMARY ")
        parsed = json.loads(line.split(" ", 1)[1])
        assert parsed == summary_dict(reg)
        assert parsed["a.total"] == 3.0
        assert set(parsed["b.ms"]) == {"count", "mean", "p50", "p99"}

    def test_periodic_writer_cadence(self, tmp_path):
        reg = MetricRegistry()
        reg.counter("a.total")
        path = str(tmp_path / "m.json")
        w = PeriodicMetricsWriter(reg, path, every=4, label="t")
        wrote = [w.maybe_write(b) for b in range(10)]
        assert wrote == [False, False, False, False, True,
                         False, False, False, True, False]
        assert w.n_writes == 2
        w.flush()
        assert w.n_writes == 3
        with open(path) as fh:
            doc = json.load(fh)
        assert doc["meta"] == {"label": "t", "schema": 2}
        assert not os.path.exists(path + ".tmp")

    def test_periodic_writer_disabled_cadence(self, tmp_path):
        reg = MetricRegistry()
        w = PeriodicMetricsWriter(reg, str(tmp_path / "m.json"), every=0)
        assert not any(w.maybe_write(b) for b in range(20))
        assert w.n_writes == 0

    def test_write_metrics_json_roundtrip(self, tmp_path):
        reg = MetricRegistry()
        reg.gauge("x.v").set(2.5)
        path = str(tmp_path / "out.json")
        doc = write_metrics_json(reg, path, label="lab")
        with open(path) as fh:
            assert json.load(fh) == doc


# ---------------------------------------------------------------------------
# integration: producers + the zero-recompile contract
# ---------------------------------------------------------------------------

class TestIntegration:
    def test_microbatcher_routes_metrics(self):
        from repro.serve.serve_step import MicroBatcher, Request
        reg = MetricRegistry()
        mb = MicroBatcher(4, pad_request={"f": np.zeros(2, np.int32)},
                          metrics=reg)
        reqs = [Request(rid=i, features={"f": np.zeros(2, np.int32)})
                for i in range(3)]
        mb.complete(reqs)
        assert reg.get("serve.requests_total").value == 3.0
        assert reg.get("serve.request_latency_ms").count == 3
        assert mb.p99() == empirical_p99(mb.latencies)

    def test_tracing_jit_step_zero_recompile(self):
        """A span around a jit'd call must not add executables: the tracer
        reads only the host clock, so every traced call after warm-up is a
        cache hit (zero new compile events, one executable) — same contract
        the serve CLIs assert end-to-end. jax.monitoring may fire several
        compile events for ONE compilation, so we assert the post-warm-up
        delta is zero rather than pinning the warm-up count."""
        import jax
        import jax.numpy as jnp
        from repro.launch.serve import CompileProbe
        reg = MetricRegistry()
        probe = CompileProbe(metrics=reg)
        tr = Tracer()

        @jax.jit
        def step(x):
            return x * 2.0

        # inputs built OUTSIDE the probed window: jnp.ones/mul compile too
        xs = [jax.block_until_ready(jnp.ones(8) * i) for i in range(3)]
        jax.block_until_ready(step(xs[0]))  # warm-up compiles
        warm = probe.compiles
        assert warm >= 1
        for i in range(3):
            with tr.span("device_step", batch=i):
                jax.block_until_ready(step(xs[i]))
        assert probe.compiles - warm == 0
        assert reg.get("jax.compiles_total").value >= 1.0
        assert step._cache_size() == 1
        assert len(tr.spans("device_step")) == 3
