"""repro.quant: tiered-precision storage (ISSUE 5 tentpole).

Round-trip error bounds (property-tested), in-kernel dequant parity vs the
jnp fallback (bit-exact — both run the shared fp32 dequant), tier-swap bit
parity (incremental retier vs a from-scratch build), byte-budget tier
assignment, byte-weighted partitioning, straight-through gradients through
mixed tiers, and the adaptive runtime's versioned tier lane.
"""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core.embedding import (banked_embedding_bag, pack_table,
                                  tiered_embedding_bag)
from repro.core.partitioning import non_uniform_partition
from repro.quant import (PAD_TIER, QuantSpec, TIER_HOT, TIER_INT4, TIER_INT8,
                         assign_tiers, build_tiered_table, bytes_of_tier,
                         dequant_rows_f32, quantize_rows, retier_tiered,
                         row_bytes, tier_nbytes)
from repro.workload import (AdaptiveEmbeddingRuntime, ReplanConfig,
                            Replanner, migrate_table)


def _roundtrip(rows: np.ndarray, tier: np.ndarray,
               hot_dtype: str = "bf16") -> tuple[np.ndarray, np.ndarray]:
    payload, scale = quantize_rows(rows, tier, hot_dtype=hot_dtype)
    dq = dequant_rows_f32(jnp.asarray(payload), jnp.asarray(scale),
                          jnp.asarray(tier), rows.shape[1], hot_dtype)
    return np.asarray(dq), scale


# ---------------------------------------------------------------------------
# quantize/dequant round trips
# ---------------------------------------------------------------------------

class TestRoundTrip:
    @pytest.mark.parametrize("hot_dtype", ["bf16", "fp32"])
    @pytest.mark.parametrize("d", [16, 33, 64])
    def test_error_bounds(self, hot_dtype, d):
        """|dequant - x| <= scale/2 elementwise for the quantized tiers;
        the hot tier reproduces the storage dtype exactly."""
        rng = np.random.default_rng(d)
        rows = (rng.standard_normal((48, d)) * rng.uniform(
            1e-3, 10, (48, 1))).astype(np.float32)
        tier = np.array([TIER_HOT] * 16 + [TIER_INT8] * 16
                        + [TIER_INT4] * 16, np.int32)
        dq, scale = _roundtrip(rows, tier, hot_dtype)
        if hot_dtype == "fp32":
            np.testing.assert_array_equal(dq[:16], rows[:16])
        else:
            import ml_dtypes
            np.testing.assert_array_equal(
                dq[:16], rows[:16].astype(ml_dtypes.bfloat16)
                .astype(np.float32))
        for sl in (slice(16, 32), slice(32, 48)):
            err = np.abs(dq[sl] - rows[sl])
            bound = 0.5 * scale[sl][:, None] * (1 + 1e-6) + 1e-12
            assert (err <= bound).all()

    def test_error_bound_property(self):
        """Hypothesis sweep of the int8/int4 bound over scales and dims."""
        hypothesis = pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=40, deadline=None)
        @given(st.integers(1, 40), st.integers(0, 2 ** 31 - 1),
               st.floats(1e-6, 1e6), st.sampled_from([TIER_INT8, TIER_INT4]))
        def check(d, seed, mag, t):
            rng = np.random.default_rng(seed)
            rows = (rng.standard_normal((4, d)) * mag).astype(np.float32)
            tier = np.full(4, t, np.int32)
            dq, scale = _roundtrip(rows, tier)
            err = np.abs(dq - rows)
            assert (err <= 0.5 * scale[:, None] * (1 + 1e-5) + 1e-30).all()

        check()

    def test_zero_rows_scale_one(self):
        rows = np.zeros((3, 8), np.float32)
        dq, scale = _roundtrip(rows, np.array([TIER_HOT, TIER_INT8,
                                               TIER_INT4]))
        np.testing.assert_array_equal(dq, 0)
        np.testing.assert_array_equal(scale, 1.0)

    def test_int4_packing_is_two_per_byte(self):
        d = 10
        assert row_bytes(d) == 2 * d
        assert tuple(tier_nbytes(d)) == (2 * d, d, 5)
        # a pure int4 row only populates the first ceil(d/2) payload bytes
        rows = np.ones((1, d), np.float32)
        payload, _ = quantize_rows(rows, np.array([TIER_INT4]))
        assert (payload[0, 5:] == 0).all()
        assert (payload[0, :5] != 0).any()


# ---------------------------------------------------------------------------
# tier assignment from a byte budget
# ---------------------------------------------------------------------------

class TestAssignTiers:
    def test_budget_met_and_head_hot(self):
        rng = np.random.default_rng(0)
        freq = rng.random(5000) + 0.01
        spec = QuantSpec(byte_budget=34.0, min_hot_rows=8)
        ta = assign_tiers(freq, spec, 64)
        assert ta.avg_bytes_per_row <= 34.0 + 128 / 5000
        order = np.argsort(-freq, kind="stable")
        assert (ta.tier_of_row[order[:8]] == TIER_HOT).all()
        assert ta.n_int4 > 0
        # the int4 tail is the COLDEST rows
        assert (ta.tier_of_row[order[-ta.n_int4:]] == TIER_INT4).all()

    def test_generous_budget_promotes_instead(self):
        freq = np.arange(1000, 0, -1, dtype=float)
        ta = assign_tiers(freq, QuantSpec(byte_budget=100.0, min_hot_rows=4),
                          64)
        assert ta.n_int4 == 0 and ta.n_hot > 4
        assert ta.avg_bytes_per_row <= 100.0

    def test_int4_disabled_floors_at_int8(self):
        freq = np.ones(100)
        ta = assign_tiers(freq, QuantSpec(byte_budget=8.0, min_hot_rows=2,
                                          enable_int4=False), 64)
        assert ta.n_int4 == 0
        assert ta.n_hot == 2 and ta.n_int8 == 98

    def test_byte_weighted_partition_balances_bytes(self):
        """row_weights turns the §3.2 greedy's load into byte-load: a plan
        balanced on bytes beats the row-load plan's byte imbalance."""
        rng = np.random.default_rng(1)
        vocab, banks, dim = 2000, 8, 64
        freq = rng.zipf(1.3, vocab).astype(np.float64)
        tiers = assign_tiers(freq, QuantSpec(byte_budget=34.0,
                                             min_hot_rows=8), dim)
        weights = bytes_of_tier(tiers.tier_of_row, dim).astype(np.float64)

        def byte_imbalance(plan):
            loads = np.zeros(banks)
            np.add.at(loads, plan.bank_of_row, freq * weights)
            return loads.max() / loads.mean()

        by_rows = non_uniform_partition(freq, banks)
        by_bytes = non_uniform_partition(freq, banks, row_weights=weights)
        assert byte_imbalance(by_bytes) <= byte_imbalance(by_rows) + 1e-9
        # load_per_bank reports the weighted load it balanced
        assert np.isclose(by_bytes.load_per_bank.sum(),
                          (freq * weights).sum())


# ---------------------------------------------------------------------------
# tiered lookup: kernel parity + straight-through gradients
# ---------------------------------------------------------------------------

def _setup(rng, d=33, banks=4, budget=40.0):
    vocab_sizes = (40, 30, 30)
    v = sum(vocab_sizes)
    offs = np.concatenate([[0], np.cumsum(vocab_sizes)[:-1]]).astype(np.int32)
    table = (rng.standard_normal((v, d)) * 0.01).astype(np.float32)
    freq = rng.random(v) + 0.1
    plan = non_uniform_partition(freq, banks)
    bt = pack_table(table, plan)
    ta = assign_tiers(freq, QuantSpec(byte_budget=budget, min_hot_rows=6), d)
    tt = build_tiered_table(bt, ta.tier_of_row)
    idx = np.full((9, 3, 5), -1, np.int32)
    for b in range(9):
        for f in range(3):
            n = rng.integers(0, 6)
            idx[b, f, :n] = rng.integers(0, vocab_sizes[f], n)
    return bt, tt, jnp.asarray(idx), jnp.asarray(offs), table, plan


class TestTieredLookup:
    @pytest.mark.parametrize("d", [16, 33, 128])
    def test_pallas_bitmatches_jnp(self, d):
        """In-kernel dequant vs the jnp fallback: SAME fp32 dequant + same
        accumulate order => bit-exact, int4 rows included."""
        rng = np.random.default_rng(d)
        # budget below the int8 width forces an int4 tail at every dim
        bt, tt, idx, fo, _, _ = _setup(rng, d=d, budget=0.75 * d)
        assert int((np.asarray(tt.tier) == TIER_INT4).sum()) > 0
        got_p = tiered_embedding_bag(bt.packed, tt, idx, None,
                                     backend="pallas", field_offsets=fo)
        got_j = tiered_embedding_bag(bt.packed, tt, idx, None,
                                     backend="jnp", field_offsets=fo)
        assert got_p.dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(got_p), np.asarray(got_j))

    def test_all_hot_matches_bf16_lookup_exactly(self):
        """A tier map with every row hot reproduces the plain bf16 lookup
        bit-for-bit (the fp32-exact side of the parity criterion)."""
        rng = np.random.default_rng(7)
        bt, _, idx, fo, table, plan = _setup(rng)
        tt_hot = build_tiered_table(bt, np.full(bt.vocab, TIER_HOT,
                                                np.int32))
        bt16 = pack_table(table, plan, dtype=jnp.bfloat16)
        want = banked_embedding_bag(bt16, idx, None, backend="jnp",
                                    field_offsets=fo)
        got = tiered_embedding_bag(bt.packed, tt_hot, idx, None,
                                   backend="pallas", field_offsets=fo)
        np.testing.assert_array_equal(
            np.asarray(got.astype(jnp.bfloat16)), np.asarray(want))

    def test_quantized_tiers_within_tolerance_of_fp(self):
        rng = np.random.default_rng(3)
        bt, tt, idx, fo, _, _ = _setup(rng, budget=25.0)
        want = np.asarray(banked_embedding_bag(bt, idx, None, backend="jnp",
                                               field_offsets=fo), np.float32)
        got = np.asarray(tiered_embedding_bag(bt.packed, tt, idx, None,
                                              backend="jnp",
                                              field_offsets=fo))
        # L entries per bag, each within scale/2 of its fp row
        bound = idx.shape[-1] * 0.5 * float(np.asarray(tt.scale).max())
        assert np.abs(got - want).max() <= bound + 1e-6

    def test_one_hot_length1_bags_match_gather(self):
        """One-hot fields as length-1 bags: the tiered path's rendition of
        the dense gather (dlrm.forward's tiered one-hot branch)."""
        rng = np.random.default_rng(5)
        bt, _, _, fo, table, plan = _setup(rng)
        tt_hot = build_tiered_table(bt, np.full(bt.vocab, TIER_HOT,
                                                np.int32))
        sparse = jnp.asarray(rng.integers(0, 30, (8, 3)).astype(np.int32))
        got = tiered_embedding_bag(bt.packed, tt_hot, sparse[..., None],
                                   None, backend="pallas", field_offsets=fo)
        rows = jnp.where(sparse >= 0, sparse + fo[None, :], -1)
        from repro.core.embedding import banked_gather
        want = banked_gather(bt, rows, None)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want, np.float32),
                                   atol=1e-2)

    @pytest.mark.parametrize("bwd", ["jnp", "pallas"])
    def test_straight_through_grads_match_fp_path(self, bwd):
        """Mixed tiers, quantized rows included: d/d(fp_packed) of the
        tiered lookup EQUALS the full-precision lookup's gradient (the
        straight-through contract), on both scatter backends."""
        rng = np.random.default_rng(11)
        bt, tt, idx, fo, _, _ = _setup(rng, budget=25.0)

        def loss_tiered(p):
            return tiered_embedding_bag(p, tt, idx, None, backend="pallas",
                                        bwd_backend=bwd,
                                        field_offsets=fo).sum()

        def loss_fp(p):
            bt2 = dataclasses.replace(bt, packed=p)
            return banked_embedding_bag(bt2, idx, None, backend="jnp",
                                        field_offsets=fo).sum()

        g_t = np.asarray(jax.grad(loss_tiered)(bt.packed))
        g_f = np.asarray(jax.grad(loss_fp)(bt.packed))
        np.testing.assert_array_equal(g_t, g_f)
        # quantized rows DO receive gradient (straight-through, not zeroed)
        q_slots = np.asarray(tt.tier) != TIER_HOT
        assert (g_t[q_slots] != 0).any()


# ---------------------------------------------------------------------------
# tier swaps: incremental retier == from-scratch build; runtime tier lane
# ---------------------------------------------------------------------------

class TestTierSwap:
    def test_retier_bitmatches_fresh_build(self):
        """Migration + re-tier (promotions, demotions, pad churn) must be
        bit-identical to quantizing the migrated table from scratch."""
        rng = np.random.default_rng(0)
        V, D, B = 300, 16, 4
        cap = int(np.ceil(V / B) * 1.25)
        table = (rng.standard_normal((V, D)) * 0.01).astype(np.float32)
        f0 = rng.random(V) + 0.1
        plan0 = non_uniform_partition(f0, B, capacity_rows=cap)
        bt0 = migrate_table(pack_table(table, plan0), plan0,
                            rows_per_bank=cap)
        spec = QuantSpec(byte_budget=10.0, min_hot_rows=4)
        tt0 = build_tiered_table(bt0, assign_tiers(f0, spec, D).tier_of_row)

        f1 = rng.random(V) + 0.1            # rotated frequencies
        plan1 = non_uniform_partition(f1, B, capacity_rows=cap)
        bt1 = migrate_table(bt0, plan1, rows_per_bank=cap)
        tiers1 = assign_tiers(f1, spec, D).tier_of_row
        got, stats = retier_tiered(tt0, bt1, tiers1)
        assert stats["n_requantized"] == stats["n_promoted"] \
            + stats["n_demoted"]
        want = build_tiered_table(bt1, tiers1)
        np.testing.assert_array_equal(np.asarray(got.payload),
                                      np.asarray(want.payload))
        np.testing.assert_array_equal(np.asarray(got.scale),
                                      np.asarray(want.scale))
        np.testing.assert_array_equal(np.asarray(got.tier),
                                      np.asarray(want.tier))
        # and the lookup through the swapped table matches the fresh one
        idx = jnp.asarray(rng.integers(0, V, (8, 1, 6)).astype(np.int32))
        a = tiered_embedding_bag(bt1.packed, got, idx, None, backend="jnp")
        b = tiered_embedding_bag(bt1.packed, want, idx, None, backend="jnp")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_runtime_tier_lane_versions_and_parity(self):
        rng = np.random.default_rng(2)
        V, D, B = 400, 16, 4
        cap = int(np.ceil(V / B) * 1.25)
        table = (rng.standard_normal((V, D)) * 0.01).astype(np.float32)
        f0 = rng.random(V) + 0.1
        plan = non_uniform_partition(f0, B, capacity_rows=cap)
        bt = migrate_table(pack_table(table, plan), plan, rows_per_bank=cap)
        cfg = ReplanConfig.for_vocab(
            V, B, capacity_rows=cap, check_every=2,
            quant=QuantSpec(byte_budget=10.0, min_hot_rows=4), quant_dim=D)
        rt = AdaptiveEmbeddingRuntime(bt, plan, cfg, init_freq=f0)
        assert rt.tier_version == 0
        tt0 = rt.tiered
        for _ in range(30):                 # rotated hot set -> drift
            rt.observe_batch(rng.integers(V // 2, V, size=(64,)))
            rt.end_batch()
        assert rt.replanner.n_replans >= 1
        ev = rt.swaps[-1]
        assert ev.tier_version == rt.tier_version >= 1
        assert ev.tier_requantized == ev.tier_promoted + ev.tier_demoted > 0
        # versioned access: current + retired-window semantics
        assert rt.tiered_for(rt.tier_version) is rt.tiered
        with pytest.raises(KeyError):
            rt.tiered_for(-1)
        # swapped state bit-matches a from-scratch build (the serve CLI's
        # first-swap probe, in-test)
        tt = rt.tiered
        assert tt is not tt0
        fresh = build_tiered_table(rt.table, tt.tier_of_row())
        np.testing.assert_array_equal(np.asarray(tt.payload),
                                      np.asarray(fresh.payload))
        np.testing.assert_array_equal(np.asarray(tt.tier),
                                      np.asarray(fresh.tier))

    def test_runtime_rejects_dim_mismatch(self):
        rng = np.random.default_rng(3)
        V, D, B = 100, 8, 2
        cap = V // B
        plan = non_uniform_partition(np.ones(V), B, capacity_rows=cap)
        bt = pack_table((rng.standard_normal((V, D)) * 0.01)
                        .astype(np.float32), plan)
        cfg = ReplanConfig.for_vocab(
            V, B, capacity_rows=cap,
            quant=QuantSpec(byte_budget=8.0), quant_dim=D + 1)
        with pytest.raises(ValueError, match="quant_dim"):
            AdaptiveEmbeddingRuntime(bt, plan, cfg)

    def test_quant_requires_non_uniform_partitioner(self):
        with pytest.raises(ValueError, match="non_uniform"):
            Replanner(ReplanConfig(n_banks=2, partitioner="cache_aware",
                                   quant=QuantSpec(), quant_dim=8), 100)
