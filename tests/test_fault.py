"""Fault-tolerant serving: injection schedule, restart backoff, degraded
reads, masked partitioning, and the replanner/runtime recovery lane."""
import numpy as np
import pytest

from repro.core.partitioning import non_uniform_partition
from repro.dist.bank_fault import (DEAD, DEGRADED, HEALTHY, BankFaultState,
                                   FaultEvent, parse_fault_spec)
from repro.dist.fault import (StragglerWatchdog, backoff_schedule,
                              run_with_restarts)


# ---------------------------------------------------------------------------
# restart driver: deterministic exponential backoff + retryable filter
# ---------------------------------------------------------------------------

class _Boom(RuntimeError):
    pass


class TestRunWithRestarts:
    def test_backoff_schedule_values(self):
        assert backoff_schedule(4, base=0.1, factor=2.0, cap=0.5) \
            == [0.1, 0.2, 0.4, 0.5]

    def test_restarts_sleep_the_schedule(self):
        slept = []
        calls = []

        def loop(start):
            calls.append(start)
            if len(calls) < 3:
                raise _Boom("transient")
            return 99

        out = run_with_restarts(loop, restore_step=lambda: 7,
                                retryable=(_Boom,), base_backoff=0.1,
                                backoff_factor=2.0, sleep=slept.append)
        assert out == 99
        assert calls == [7, 7, 7]
        assert slept == [0.1, 0.2]

    def test_non_retryable_raises_immediately(self):
        calls = []

        def loop(start):
            calls.append(start)
            raise ValueError("deterministic bug")

        with pytest.raises(ValueError):
            run_with_restarts(loop, restore_step=lambda: 0,
                              retryable=(_Boom,), sleep=lambda s: None)
        assert len(calls) == 1          # never retried into the budget

    def test_budget_exhaustion_reraises(self):
        slept = []

        def loop(start):
            raise _Boom("always")

        with pytest.raises(_Boom):
            run_with_restarts(loop, restore_step=lambda: 0, max_restarts=3,
                              retryable=(_Boom,), base_backoff=0.1,
                              max_backoff=0.15, sleep=slept.append)
        assert slept == [0.1, 0.15, 0.15]       # capped, one per restart


class TestStragglerWatchdog:
    def test_flags_and_excludes_stragglers(self):
        wd = StragglerWatchdog(factor=3.0, min_history=3)
        for step in range(3):
            assert not wd.observe(step, 1.0)
        assert wd.observe(3, 10.0)              # 10 > 3 x median(1.0)
        # the straggler time was EXCLUDED from history: the baseline median
        # is still 1.0, so a second slow step still trips
        assert wd.observe(4, 10.0)
        assert wd.events == [3, 4]

    def test_min_history_gate(self):
        wd = StragglerWatchdog(factor=3.0, min_history=5)
        for step in range(4):
            assert not wd.observe(step, 1.0)
        # 4 < min_history: even an egregious time cannot trip yet (it joins
        # the history instead)
        assert not wd.observe(4, 100.0)
        assert wd.observe(5, 1000.0)


# ---------------------------------------------------------------------------
# fault model: specs, schedule determinism, advance/revive
# ---------------------------------------------------------------------------

class TestBankFaultState:
    def test_parse_fault_spec(self):
        e = parse_fault_spec("12:3")
        assert (e.batch, e.bank, e.state) == (12, 3, DEAD)
        e = parse_fault_spec("12:3:degraded:4.0")
        assert (e.state, e.factor) == (DEGRADED, 4.0)
        assert parse_fault_spec("20:3:healthy").state == HEALTHY
        with pytest.raises(ValueError):
            parse_fault_spec("12")
        with pytest.raises(ValueError):
            parse_fault_spec("12:3:zombie")

    def test_bank_range_validated(self):
        with pytest.raises(ValueError):
            BankFaultState(4, [FaultEvent(batch=1, bank=4)])

    def test_advance_fires_in_order_and_revives(self):
        st = BankFaultState(4, [
            FaultEvent(batch=2, bank=1, state=DEAD),
            FaultEvent(batch=5, bank=2, state=DEGRADED, factor=6.0),
            FaultEvent(batch=8, bank=1, state=HEALTHY),
        ])
        assert st.advance(1) == []
        assert not st.any_fault()
        fired = st.advance(2)
        assert [e.bank for e in fired] == [1]
        assert st.dead_banks() == [1]
        assert list(st.live_mask()) == [True, False, True, True]
        st.advance(6)
        assert st.degraded_banks() == [2]
        np.testing.assert_allclose(st.slow_factor(), [1.0, 1.0, 6.0, 1.0])
        st.advance(8)                       # revival
        assert st.dead_banks() == []
        assert not st.any_fault() or st.degraded_banks() == [2]
        assert list(st.live_mask()) == [True, True, True, True]

    def test_advance_catches_up_past_events(self):
        st = BankFaultState(2, [FaultEvent(batch=3, bank=0)])
        # a loop that skips batches still fires everything scheduled earlier
        assert [e.batch for e in st.advance(10)] == [3]

    def test_random_schedule_deterministic(self):
        a = BankFaultState.random_schedule(8, 100, seed=42, n_failures=3,
                                           p_degraded=0.5)
        b = BankFaultState.random_schedule(8, 100, seed=42, n_failures=3,
                                           p_degraded=0.5)
        assert a.schedule == b.schedule
        c = BankFaultState.random_schedule(8, 100, seed=43, n_failures=3,
                                           p_degraded=0.5)
        assert a.schedule != c.schedule

    def test_random_schedule_keeps_a_survivor(self):
        st = BankFaultState.random_schedule(4, 50, seed=0, n_failures=99)
        assert len(st.schedule) == 3        # capped at n_banks - 1


# ---------------------------------------------------------------------------
# bounded-degraded reads (core/embedding.py bank_live mask)
# ---------------------------------------------------------------------------

V, D, BANKS = 256, 8, 4


def _setup(seed=0):
    from repro.core.embedding import pack_table
    rng = np.random.default_rng(seed)
    freq = rng.random(V) + 0.1
    plan = non_uniform_partition(freq, BANKS)
    table = (rng.standard_normal((V, D)) * 0.1).astype(np.float32)
    t = pack_table(table, plan)
    idx = rng.integers(0, V, size=(8, 16)).astype(np.int32)
    idx[rng.random(idx.shape) < 0.2] = -1
    return t, plan, idx


class TestDegradedReads:
    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_dead_bank_equals_masked_indices(self, backend):
        """The degradation contract: serving with bank b dead is BIT-equal
        (same backend) to serving with b's rows masked out of the batch."""
        import jax.numpy as jnp

        from repro.core.embedding import banked_embedding_bag
        t, plan, idx = _setup()
        dead = 1
        live = np.ones(BANKS, dtype=bool)
        live[dead] = False
        kw = dict(backend=backend)
        if backend == "pallas":
            kw["interpret"] = True
        out = banked_embedding_bag(t, jnp.asarray(idx), None,
                                   bank_live=jnp.asarray(live), **kw)
        masked = np.where((idx >= 0) & (plan.bank_of_row[np.where(
            idx >= 0, idx, 0)] == dead), -1, idx)
        ref = banked_embedding_bag(t, jnp.asarray(masked), None, **kw)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    @pytest.mark.parametrize("backend", ["jnp", "pallas"])
    def test_all_live_bitmatches_no_mask(self, backend):
        import jax.numpy as jnp

        from repro.core.embedding import banked_embedding_bag
        t, _, idx = _setup()
        kw = dict(backend=backend)
        if backend == "pallas":
            kw["interpret"] = True
        out = banked_embedding_bag(
            t, jnp.asarray(idx), None,
            bank_live=jnp.ones(BANKS, dtype=bool), **kw)
        ref = banked_embedding_bag(t, jnp.asarray(idx), None, **kw)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_degraded_row_counts(self):
        import jax.numpy as jnp

        from repro.core.embedding import degraded_row_counts
        t, plan, idx = _setup()
        dead = 2
        live = np.ones(BANKS, dtype=bool)
        live[dead] = False
        counts = np.asarray(degraded_row_counts(
            t.remap_bank, jnp.asarray(live), jnp.asarray(idx)))
        expect = ((idx >= 0)
                  & (plan.bank_of_row[np.where(idx >= 0, idx, 0)] == dead)
                  ).sum(axis=-1)
        np.testing.assert_array_equal(counts, expect)
        all_live = np.asarray(degraded_row_counts(
            t.remap_bank, jnp.ones(BANKS, dtype=bool), jnp.asarray(idx)))
        assert (all_live == 0).all()


# ---------------------------------------------------------------------------
# masked partitioner (bank_capacity_rows / bank_cost)
# ---------------------------------------------------------------------------

class TestMaskedPartitioner:
    def test_dead_bank_gets_no_rows(self):
        freq = np.random.default_rng(0).random(V) + 0.1
        caps = np.array([0, V, V, V])
        plan = non_uniform_partition(freq, BANKS, bank_capacity_rows=caps)
        assert (plan.bank_of_row != 0).all()

    def test_capacity_exhausted_raises(self):
        freq = np.ones(V)
        caps = np.array([0, 0, 0, 100])     # 100 < 256 rows
        with pytest.raises(ValueError, match="capacity exhausted"):
            non_uniform_partition(freq, BANKS, bank_capacity_rows=caps)

    def test_bank_cost_sheds_load(self):
        freq = np.random.default_rng(1).random(V) + 0.1
        cost = np.array([8.0, 1.0, 1.0, 1.0])
        plan = non_uniform_partition(freq, BANKS, bank_cost=cost)
        base = non_uniform_partition(freq, BANKS)
        assert plan.load_per_bank[0] < base.load_per_bank[0]


# ---------------------------------------------------------------------------
# replanner fault state + realized-hit-rate discount
# ---------------------------------------------------------------------------

class TestReplannerFaultState:
    def _rp(self, **over):
        from repro.workload import ReplanConfig, Replanner
        cfg = ReplanConfig.for_vocab(V, BANKS, capacity_rows=V, **over)
        return Replanner(cfg, V, init_freq=np.ones(V))

    def test_set_bank_health_validates_shape(self):
        rp = self._rp()
        with pytest.raises(ValueError):
            rp.set_bank_health(np.ones(BANKS + 1, dtype=bool))

    def test_set_bank_penalty_validates(self):
        rp = self._rp()
        with pytest.raises(ValueError):
            rp.set_bank_penalty(np.ones(BANKS + 1))
        with pytest.raises(ValueError):
            rp.set_bank_penalty(np.array([1.0, 0.0, 1.0, 1.0]))

    def test_all_live_plans_bit_identical_to_legacy(self):
        """The trivially-off contract: healthy serving must produce EXACTLY
        the legacy planner's output (no caps array, no cost array)."""
        rp = self._rp()
        freq = np.random.default_rng(2).random(V) + 0.1
        plan, _, _ = rp.build_plan(freq)
        legacy = non_uniform_partition(freq, BANKS, capacity_rows=V)
        np.testing.assert_array_equal(plan.bank_of_row, legacy.bank_of_row)
        np.testing.assert_array_equal(plan.slot_of_row, legacy.slot_of_row)

    def test_dead_bank_excluded_after_set_bank_health(self):
        rp = self._rp()
        live = np.ones(BANKS, dtype=bool)
        live[1] = False
        rp.set_bank_health(live)
        freq = np.random.default_rng(3).random(V) + 0.1
        plan, _, _ = rp.build_plan(freq)
        assert (plan.bank_of_row != 1).all()
        # persistent: a LATER replan still avoids the dead bank
        plan2, _, _ = rp.build_plan(freq * 2)
        assert (plan2.bank_of_row != 1).all()

    def test_cache_aware_with_dead_bank_raises(self):
        from repro.workload import ReplanConfig, Replanner
        cfg = ReplanConfig.for_vocab(V, BANKS, capacity_rows=V,
                                     partitioner="cache_aware")
        rp = Replanner(cfg, V, init_freq=np.ones(V))
        rp.observe_bags([np.arange(4)])
        live = np.ones(BANKS, dtype=bool)
        live[0] = False
        rp.set_bank_health(live)
        with pytest.raises(ValueError, match="non_uniform"):
            rp.build_plan(np.ones(V))

    def test_realized_hit_rate_defaults_and_clips(self):
        rp = self._rp()
        assert rp.realized_hit_rate() == 1.0        # no committed prediction
        rp._pred_saved_per_bag = 2.0
        assert rp.realized_hit_rate() == 1.0        # no realized feed yet
        rp.observe_cache_hits(10.0, 10)             # 1.0 saved/bag vs 2.0
        assert rp.realized_hit_rate() == pytest.approx(0.5)
        rp.observe_cache_hits(1000.0, 10)           # over-delivery clips
        assert rp.realized_hit_rate() == 1.0


# ---------------------------------------------------------------------------
# runtime recovery lane (on_bank_failure / on_straggler)
# ---------------------------------------------------------------------------

class TestRuntimeRecovery:
    def _runtime(self):
        from repro.core.embedding import pack_table
        from repro.workload import ReplanConfig, Replanner
        from repro.workload.runtime import AdaptiveEmbeddingRuntime
        rng = np.random.default_rng(0)
        freq = rng.random(V) + 0.1
        cap = int(np.ceil(V / BANKS) * 1.5)
        plan = non_uniform_partition(freq, BANKS, capacity_rows=cap)
        table = (rng.standard_normal((V, D)) * 0.1).astype(np.float32)
        t = pack_table(table, plan)
        # pin the packed shape to the full capacity for shape-stable swaps
        from repro.workload.migrate import migrate_table
        t = migrate_table(t, plan, rows_per_bank=cap)
        cfg = ReplanConfig.for_vocab(V, BANKS, capacity_rows=cap)
        return AdaptiveEmbeddingRuntime(t, plan, cfg, init_freq=freq), table

    def test_on_bank_failure_repacks_and_stamps_event(self):
        runtime, table = self._runtime()
        live = np.ones(BANKS, dtype=bool)
        live[2] = False
        event = runtime.on_bank_failure(live)
        assert event.reason == "bank_failure"
        assert event.recovery_s is not None and event.recovery_s >= 0.0
        assert (np.asarray(runtime.table.remap_bank) != 2).all()
        # row values survive the emergency migration
        flat = (np.asarray(runtime.table.remap_bank, np.int64)
                * runtime.table.rows_per_bank
                + np.asarray(runtime.table.remap_slot))
        np.testing.assert_array_equal(
            np.asarray(runtime.table.packed)[flat], table)

    def test_on_straggler_sheds_load(self):
        runtime, _ = self._runtime()
        before = runtime.plan.load_per_bank.copy()
        pen = np.ones(BANKS)
        pen[0] = 8.0
        event = runtime.on_straggler(pen)
        assert event.reason == "straggler"
        assert runtime.plan.load_per_bank[0] < before[0]
