"""Dispatch-cache contract tests (ISSUE 9 tentpole):

key determinism, JSON persistence round-trip, miss-falls-back-to-default,
and — the load-bearing one — BIT-parity of every ``backend='tuned'``
dispatched lookup against the directly-invoked kernel at each
(backend, tile_b, n_slots) the autotuner sweep can pick, on all five
entry-point paths. Dispatched and direct runs share the exact code path
once resolved, so anything short of bitwise equality means the dispatch
layer changed the computation.
"""
import numpy as np
import pytest

from repro.tune.autotune import (candidates, csr_case, fused_case,
                                 plain_case, replicated_case, tiered_case)
from repro.tune.dispatch import (CACHE_ENV, CallSignature, Decision,
                                 DispatchCache, decide, default_cache_path,
                                 set_cache, signature)


@pytest.fixture(autouse=True)
def _reset_cache():
    """Never leak an installed cache (or pick up the repo's committed one)
    across tests: every test starts and ends with an explicit EMPTY cache."""
    set_cache(DispatchCache())
    yield
    set_cache(None)


# ---------------------------------------------------------------------------
# keys + persistence
# ---------------------------------------------------------------------------

def test_signature_key_deterministic():
    a = signature("plain", vocab=1000, dim=32, batch=16, bag_len=4)
    b = signature("plain", vocab=1000, dim=32, batch=16, bag_len="4")
    assert a == b and a.key() == b.key()
    assert a.key() == "plain|v1000|d32|b16|l4|f1|k1|tnone|bwauto"


@pytest.mark.parametrize("field,val", [
    ("path", "csr"), ("vocab", 999), ("dim", 64), ("batch", 8),
    ("bag_len", "8"), ("n_fields", 2), ("k_max", 2), ("tier_mix", "bf16"),
    ("bwd_backend", "jnp"),
])
def test_signature_key_covers_every_field(field, val):
    base = dict(path="plain", vocab=1000, dim=32, batch=16, bag_len="4",
                n_fields=1, k_max=1, tier_mix="none", bwd_backend="auto")
    changed = dict(base)
    changed[field] = val
    assert CallSignature(**base).key() != CallSignature(**changed).key()


def test_bad_path_and_bad_backend_rejected():
    with pytest.raises(ValueError):
        signature("nope", vocab=1, dim=1, batch=1, bag_len=1)
    with pytest.raises(ValueError):
        Decision(backend="auto", tile_b=8, n_slots=2)


def test_persistence_round_trip(tmp_path):
    cache = DispatchCache(meta={"arch": "test", "smoke": False,
                                "repeats": 1, "n_candidates": 3})
    for i, path in enumerate(("plain", "fused", "csr")):
        sig = signature(path, vocab=100 * (i + 1), dim=32, batch=8,
                        bag_len="ragged" if path == "csr" else 4)
        cache.record(sig, backend="pallas" if i % 2 else "jnp",
                     tile_b=4 * (i + 1), n_slots=2 + i,
                     timings={"best_us": 1.5, "jnp_us": 2.0,
                              "pallas_us": 1.5})
    out = tmp_path / "TUNE_dispatch.json"
    cache.save(str(out))
    reloaded = DispatchCache.load(str(out))
    assert reloaded.meta["version"] == cache.meta["version"]
    assert reloaded.decisions() == cache.decisions()


def test_load_rejects_schema_version_mismatch(tmp_path):
    out = tmp_path / "TUNE_dispatch.json"
    out.write_text('{"meta": {"version": 999}, "entries": {}}')
    with pytest.raises(ValueError):
        DispatchCache.load(str(out))


def test_env_var_wins_cache_path(tmp_path, monkeypatch):
    monkeypatch.setenv(CACHE_ENV, str(tmp_path / "elsewhere.json"))
    assert default_cache_path() == str(tmp_path / "elsewhere.json")


# ---------------------------------------------------------------------------
# decide(): hit vs miss
# ---------------------------------------------------------------------------

def test_miss_falls_back_to_callers_defaults():
    cache = DispatchCache()
    set_cache(cache)
    dec = decide("plain", vocab=50, dim=8, batch=4, bag_len=2,
                 default_backend="jnp", default_tile_b=16, default_n_slots=4)
    assert dec == Decision(backend="jnp", tile_b=16, n_slots=4,
                           source="default")
    assert cache.misses == 1 and cache.hits == 0


def test_hit_returns_recorded_decision():
    cache = DispatchCache()
    sig = signature("plain", vocab=50, dim=8, batch=4, bag_len=2)
    cache.record(sig, backend="pallas", tile_b=4, n_slots=3)
    set_cache(cache)
    dec = decide("plain", vocab=50, dim=8, batch=4, bag_len=2,
                 default_backend="jnp", default_tile_b=16, default_n_slots=2)
    assert dec == Decision(backend="pallas", tile_b=4, n_slots=3,
                           source="cache")
    assert cache.hits == 1 and cache.misses == 0


def test_near_miss_is_a_miss():
    cache = DispatchCache()
    cache.record(signature("plain", vocab=50, dim=8, batch=4, bag_len=2),
                 backend="pallas", tile_b=4, n_slots=3)
    set_cache(cache)
    dec = decide("plain", vocab=50, dim=8, batch=8, bag_len=2,  # batch differs
                 default_backend="jnp")
    assert dec.source == "default" and dec.backend == "jnp"


# ---------------------------------------------------------------------------
# bit-parity: dispatched vs direct, every sweepable candidate, all 5 paths
# ---------------------------------------------------------------------------

# small-shape TuneCases, one per entry point; each `make(backend, tile_b,
# n_slots)` builds THE production call (core/embedding.py), so running it
# with backend='tuned' exercises the real dispatch wrapper
_CASES = [
    plain_case(500, 32, 8, 4, 1, seed=10),
    plain_case(400, 16, 4, 4, 2, seed=11),          # multi-field
    fused_case(v=500, nc=32, d=32, b=8, lc=2, lr=4, seed=12),
    csr_case(v=500, d=32, num_bags=8, avg_len=4, seed=13),
    tiered_case(v=500, d=32, b=8, l=4, seed=14),
    replicated_case(v=500, d=32, b=8, l=4, k_max=2, n_hot=8, seed=15),
]


@pytest.mark.parametrize("case", _CASES, ids=lambda c: c.sig.key())
def test_dispatched_bit_matches_direct(case):
    for backend, tile_b, n_slots in candidates(smoke=False):
        direct = np.asarray(case.make(backend, tile_b, n_slots)())
        cache = DispatchCache()
        cache.record(case.sig, backend=backend, tile_b=tile_b,
                     n_slots=n_slots)
        set_cache(cache)
        # the caller's own tile/slot args are decoys: a hit must override
        tuned = np.asarray(case.make("tuned", tile_b + 3, n_slots + 1)())
        assert cache.hits >= 1, "tuned call never consulted the cache"
        assert direct.dtype == tuned.dtype and direct.shape == tuned.shape
        assert np.array_equal(direct, tuned, equal_nan=True), (
            f"dispatch changed the computation at "
            f"({backend}, tile_b={tile_b}, n_slots={n_slots})")
