"""Pallas kernel validation: interpret-mode sweeps over shapes/dtypes against
the ref.py oracles (this container is CPU; TPU is the compile target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as K
from repro.kernels import ref as REF


@pytest.mark.parametrize("v,d,b,l", [
    (100, 16, 8, 4), (64, 100, 10, 7), (256, 64, 32, 1), (50, 33, 9, 5),
    (1000, 128, 16, 64), (16, 8, 1, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(v, d, b, l, dtype):
    rng = np.random.default_rng(v + d + b + l)
    table = jnp.array(rng.standard_normal((v, d)), dtype)
    idx = jnp.array(rng.integers(-1, v, (b, l)), jnp.int32)
    got = K.embedding_bag(table, idx, interpret=True)
    want = REF.embedding_bag_ref(table, idx)
    atol = 1e-4 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("v,nc,d,b,lc,lr", [
    (80, 20, 24, 12, 3, 6), (40, 5, 8, 8, 1, 1), (200, 64, 32, 16, 8, 20),
])
def test_cache_bag_sweep(v, nc, d, b, lc, lr):
    rng = np.random.default_rng(v + d)
    emt = jnp.array(rng.standard_normal((v, d)), jnp.float32)
    cache = jnp.array(rng.standard_normal((nc, d)), jnp.float32)
    ci = jnp.array(rng.integers(-1, nc, (b, lc)), jnp.int32)
    ri = jnp.array(rng.integers(-1, v, (b, lr)), jnp.int32)
    got = K.cache_bag(emt, cache, ci, ri, interpret=True)
    want = REF.cache_bag_ref(emt, cache, ci, ri)
    np.testing.assert_allclose(got, want, atol=1e-4)


@pytest.mark.parametrize("b,f,d", [
    (16, 27, 64), (8, 5, 10), (128, 40, 10), (8, 2, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dot_interaction_sweep(b, f, d, dtype):
    rng = np.random.default_rng(b + f + d)
    z = jnp.array(rng.standard_normal((b, f, d)), dtype)
    got = K.dot_interaction(z, tile_b=8, interpret=True)
    want = REF.dot_interaction_ref(z)
    atol = 1e-3 if dtype == jnp.float32 else 0.5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_embedding_bag_trainable_grads():
    """custom_vjp: kernel forward, scatter-add backward == autodiff of ref."""
    rng = np.random.default_rng(5)
    table = jnp.array(rng.standard_normal((50, 16)), jnp.float32)
    idx = jnp.array(rng.integers(-1, 50, (8, 4)), jnp.int32)

    def loss_k(t):
        return (K.embedding_bag_trainable(t, idx) ** 2).sum()

    def loss_r(t):
        return (REF.embedding_bag_ref(t, idx) ** 2).sum()

    np.testing.assert_allclose(loss_k(table), loss_r(table), rtol=1e-5)
    gk = jax.grad(loss_k)(table)
    gr = jax.grad(loss_r)(table)
    np.testing.assert_allclose(gk, gr, atol=1e-4)


def test_kernel_matches_model_path():
    """kernels.dot_interaction is a drop-in for models.dlrm.dot_interaction."""
    from repro.models.dlrm import dot_interaction as model_dot
    rng = np.random.default_rng(0)
    z = jnp.array(rng.standard_normal((8, 27, 64)), jnp.float32)
    np.testing.assert_allclose(K.dot_interaction(z, tile_b=8, interpret=True),
                               model_dot(z), atol=1e-4)


def test_banked_stage2_fusion_equivalence():
    """Pallas bag over bank-masked indices == banked stage-2 partial sums."""
    from repro.core.embedding import pack_table
    from repro.core.partitioning import uniform_partition
    rng = np.random.default_rng(2)
    V, D, B, L, banks = 64, 16, 8, 6, 4
    table = rng.standard_normal((V, D)).astype(np.float32)
    plan = uniform_partition(V, banks)
    bt = pack_table(table, plan)
    idx = rng.integers(-1, V, (B, L)).astype(np.int32)
    local = np.asarray(bt.packed).reshape(banks, -1, D)
    total = np.zeros((B, D), np.float32)
    for mb in range(banks):
        # wrapper-side ownership mask -> kernel sees -1 for foreign rows
        safe = np.where(idx >= 0, idx, 0)
        mine = (idx >= 0) & (plan.bank_of_row[safe] == mb)
        local_idx = np.where(mine, plan.slot_of_row[safe], -1).astype(np.int32)
        part = K.embedding_bag(jnp.asarray(local[mb]),
                               jnp.asarray(local_idx), interpret=True)
        want = REF.banked_bag_ref(jnp.asarray(local[mb]),
                                  jnp.asarray(plan.bank_of_row),
                                  jnp.asarray(plan.slot_of_row),
                                  jnp.asarray(idx), mb)
        np.testing.assert_allclose(part, want, atol=1e-4)
        total += np.asarray(part)
    want_total = REF.embedding_bag_ref(jnp.asarray(table), jnp.asarray(idx))
    np.testing.assert_allclose(total, want_total, atol=1e-4)
