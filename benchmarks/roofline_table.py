"""Roofline table: read experiments/dryrun/*.json -> per-cell terms +
dominant bottleneck + useful-FLOPs ratio (deliverable g)."""
from __future__ import annotations

import glob
import json
import os


def load_records(dryrun_dir: str = "experiments/dryrun",
                 mesh: str = "pod_16x16") -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dryrun_dir, f"{mesh}__*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_table(recs: list[dict]) -> str:
    hdr = ("| arch | shape | step | compute_ms | memory_ms | collective_ms | "
           "dominant | peak_GiB | useful_flops |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        t = r["roofline"]
        uf = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['step_kind']} "
            f"| {t['compute_s']*1e3:.3f} | {t['memory_s']*1e3:.3f} "
            f"| {t['collective_s']*1e3:.3f} | {t['dominant'].replace('_s','')} "
            f"| {r['memory']['peak_bytes']/2**30:.2f} "
            f"| {uf:.2f} |" if uf is not None else
            f"| {r['arch']} | {r['shape']} | {r['step_kind']} "
            f"| {t['compute_s']*1e3:.3f} | {t['memory_s']*1e3:.3f} "
            f"| {t['collective_s']*1e3:.3f} | {t['dominant'].replace('_s','')} "
            f"| {r['memory']['peak_bytes']/2**30:.2f} | n/a |")
    return "\n".join(rows)


def interesting_cells(recs: list[dict]) -> dict[str, dict]:
    """The three hillclimb picks: worst useful-flops fraction among
    compute-relevant cells, most collective-bound, most paper-representative."""
    by_coll = max(recs, key=lambda r: r["roofline"]["collective_s"]
                  / max(r["roofline"]["bound_s"], 1e-12)
                  * r["roofline"]["collective_s"])
    train = [r for r in recs if r["step_kind"] == "train"
             and r.get("useful_flops_ratio")]
    worst = min(train, key=lambda r: r["useful_flops_ratio"])
    paper = next(r for r in recs
                 if r["arch"] == "dlrm-rm2" and r["shape"] == "train_batch")
    return {"most_collective_bound": by_coll, "worst_useful_flops": worst,
            "paper_representative": paper}


def main() -> None:
    for mesh in ("pod_16x16", "multipod_2x16x16"):
        recs = load_records(mesh=mesh)
        if not recs:
            continue
        print(f"\n## mesh {mesh} ({len(recs)} cells)\n")
        print(fmt_table(recs))
    recs = load_records()
    if recs:
        print("\n## hillclimb picks (single-pod)\n")
        for k, r in interesting_cells(recs).items():
            t = r["roofline"]
            print(f"- {k}: {r['arch']} x {r['shape']} "
                  f"(dom={t['dominant']}, bound={t['bound_s']*1e3:.2f}ms, "
                  f"useful={r.get('useful_flops_ratio')})")


if __name__ == "__main__":
    main()
