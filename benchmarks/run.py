"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Roofline terms (deliverable g) come
from launch/dryrun.py artifacts — summarized by benchmarks/roofline_table.py.

    PYTHONPATH=src python -m benchmarks.run [--skip-measured]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-measured", action="store_true",
                    help="skip wall-clock rows (CI use)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: derived rows + reduced measured set, "
                         "writing BENCH_embedding.json / BENCH_workload.json "
                         "(the workflow's uploaded artifacts)")
    ap.add_argument("--stream-bags", type=int, default=None,
                    help="override the workload scenarios' stream length "
                         "(all four: non_uniform, cache_aware, "
                         "criteo_replay, tiered). An explicit value WINS "
                         "over --smoke's reduced default, same precedence "
                         "as bench_workload's own CLI")
    args = ap.parse_args()
    if args.smoke and args.skip_measured:
        ap.error("--smoke and --skip-measured conflict: smoke EXISTS to "
                 "produce the measured BENCH_*.json artifacts")
    if args.stream_bags is not None and not args.smoke:
        ap.error("--stream-bags modifies the smoke artifact run: pass it "
                 "with --smoke (the full measured set uses the scenarios' "
                 "committed defaults)")

    from benchmarks import paper_figs as F
    benches = [
        F.table1_workloads,
        F.fig3_mram_latency,
        F.fig5_access_skew,
        F.fig6_partition_balance,
        F.fig8_inference_speedup,
        F.fig9_partition_speedup,
        F.fig10_latency_breakdown,
        F.fig11_sensitivity,
        F.tile_solver,
    ]
    if args.smoke:
        # write the artifact JSONs (reduced configs/repeats), then surface a
        # couple of headline rows in the CSV like any other bench
        from benchmarks import bench_embedding, bench_workload

        def smoke_artifacts():
            doc_e = bench_embedding.write_json(smoke=True)
            for r in doc_e["results"]:
                yield (f"smoke_embedding_{r['backend']}_d{r['dim']}"
                       f"_b{r['batch']}", r["us_per_call"],
                       f"{r['effective_gather_gbps']}GB/s")
            for r in doc_e["grad_results"]:
                yield (f"smoke_embedding_grad_bwd-{r['bwd']}_d{r['dim']}"
                       f"_b{r['batch']}", r["us_per_grad"],
                       f"{r['effective_scatter_gbps']}GB/s")
            # explicit --stream-bags wins over the smoke default, exactly
            # like bench_workload's own CLI precedence
            doc_w = bench_workload.write_json(smoke=True,
                                              stream_bags=args.stream_bags)
            a = doc_w["adaptive"]
            yield ("smoke_workload_adaptive_p99_model",
                   a["p99_model_latency_us"], f"replans{a['n_replans']}")
            t = doc_w["tiered"]
            yield ("smoke_workload_tiered_p99_model",
                   t["tiered"]["p99_model_latency_us"],
                   f"bytes_x{t['byte_load_ratio_max_bank']:.2f}"
                   f"_retiers{t['tiered']['n_retiers']}")

        benches.append(smoke_artifacts)
    elif not args.skip_measured:
        benches.append(F.measured_lookup_paths)
        from benchmarks.bench_embedding import embedding_backends
        benches.append(embedding_backends)
        from benchmarks.bench_embedding import embedding_grad_backends
        benches.append(embedding_grad_backends)
        from benchmarks.bench_workload import workload_drift
        benches.append(workload_drift)

    print("name,us_per_call,derived")
    failed = 0
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.3f},{derived}")
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{bench.__name__},nan,FAILED", file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
