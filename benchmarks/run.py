"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Roofline terms (deliverable g) come
from launch/dryrun.py artifacts — summarized by benchmarks/roofline_table.py.

    PYTHONPATH=src python -m benchmarks.run [--skip-measured]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-measured", action="store_true",
                    help="skip wall-clock rows (CI use)")
    args = ap.parse_args()

    from benchmarks import paper_figs as F
    benches = [
        F.table1_workloads,
        F.fig3_mram_latency,
        F.fig5_access_skew,
        F.fig6_partition_balance,
        F.fig8_inference_speedup,
        F.fig9_partition_speedup,
        F.fig10_latency_breakdown,
        F.fig11_sensitivity,
        F.tile_solver,
    ]
    if not args.skip_measured:
        benches.append(F.measured_lookup_paths)
        from benchmarks.bench_embedding import embedding_backends
        benches.append(embedding_backends)
        from benchmarks.bench_workload import workload_drift
        benches.append(workload_drift)

    print("name,us_per_call,derived")
    failed = 0
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.3f},{derived}")
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{bench.__name__},nan,FAILED", file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
