"""One benchmark per paper table/figure (Figs. 3, 5, 6, 8, 9, 10, 11 +
Table 1). Each ``figN()`` returns CSV rows (name, us_per_call, derived).

Methodology (EXPERIMENTS.md §Benchmarks): no UPMEM hardware exists here, so
each figure combines MEASURED algorithmic statistics (trace skew, realized
per-bank load vectors from the real partitioners, mined cache hit rates) with
the paper-calibrated analytic hardware model (core/hwmodel.py). Rows marked
``measured-cpu`` are real wall-times of the jitted JAX lookup paths.

Paper setup mirrored throughout: batch 64, 8 tables x 32-dim, 256 DPUs
(=> 32 banks/table; §3.1 layout row_groups x col_groups with C=32).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (BENCH_ITEMS, plan_shares, realized_shares,
                               time_fn, workload_stats)
from repro.core.hwmodel import (UPMEM, cpu_lookup_time,
                                embedding_stage_latency, system_inference_time,
                                updlrm_layout)
from repro.data.synthetic import WORKLOADS, zipf_popularity

Row = tuple[str, float, str]

BATCH = 64
N_TABLES = 8
DIM = 32
BANKS_PER_TABLE = 256 // N_TABLES

_STATS_CACHE: dict[str, dict] = {}
_SHARES_CACHE: dict[tuple, tuple] = {}


def _stats(key: str) -> dict:
    if key not in _STATS_CACHE:
        _STATS_CACHE[key] = workload_stats(key)
    return _STATS_CACHE[key]


def _shares(key: str, partitioner: str, n_bins: int):
    ck = (key, partitioner, n_bins)
    if ck not in _SHARES_CACHE:
        _SHARES_CACHE[ck] = plan_shares(_stats(key), partitioner, n_bins)
    return _SHARES_CACHE[ck][0]


def _stage(key: str, partitioner: str, n_c: int, with_cache: bool):
    st = _stats(key)
    p = st["profile"]
    row_groups, _ = updlrm_layout(BANKS_PER_TABLE, DIM, n_c)
    if with_cache:
        shares = realized_shares(st, partitioner, row_groups, with_cache=True)
    else:
        shares = _shares(key, partitioner, row_groups)
    return embedding_stage_latency(
        batch_size=BATCH, avg_reduction=p.avg_reduction, n_c=n_c,
        per_bank_lookup_share=shares,
        cache_hit_rate=st["hit_rate"] if with_cache else 0.0)


def _mlp_flops() -> float:
    # paper-setup DLRM: bottom 13-512-256-32, top over 8 pooled tables
    inter = (N_TABLES + 1) * N_TABLES // 2 + DIM
    return 2.0 * (13 * 512 + 512 * 256 + 256 * 32
                  + inter * 512 + 512 * 256 + 256 * 1)


# ---------------------------------------------------------------------------

def fig3_mram_latency() -> list[Row]:
    """Fig. 3: MRAM read latency vs access size (8B..2048B)."""
    rows = []
    for nbytes in (8, 16, 32, 64, 128, 256, 512, 1024, 2048):
        t = UPMEM.mram_read_latency(nbytes)
        rows.append((f"fig3/mram_read_{nbytes}B", t * 1e6,
                     f"plateau<=32B={nbytes <= 32}"))
    return rows


def fig5_access_skew() -> list[Row]:
    """Fig. 5: accesses per row block (8 id-ordered blocks). Real catalogs
    assign ids roughly chronologically => popularity correlates with id; the
    paper reports up to 340x hottest/coldest block."""
    rows = []
    for key in ("read", "meta1", "clo"):
        prof = WORKLOADS[key]
        p = np.arange(1, BENCH_ITEMS + 1, dtype=np.float64) ** (-prof.zipf_a)
        blocks = np.array_split(p / p.sum(), 8)
        counts = np.array([b.sum() for b in blocks])
        rows.append((f"fig5/{key}_block_skew", 0.0,
                     f"hot/cold={counts.max() / counts.min():.0f}x"))
    return rows


def fig6_partition_balance() -> list[Row]:
    """Fig. 6: per-partition REALIZED access balance (8 row bins): NU w/o
    cache is balanced; caching re-skews NU; Algorithm 1 (CA) re-balances."""
    st = _stats("read")
    rows = []
    for name, wc in (("U", False), ("NU", False), ("NUC", True),
                     ("CA", True)):
        sh = realized_shares(st, name, 8, with_cache=wc)
        tag = f"{name}{'_cache' if wc else ''}"
        rows.append((f"fig6/{tag}_imbalance", 0.0,
                     f"max/mean={sh.max() * len(sh):.2f}"))
    rows.append(("fig6/cache_hit_rate", 0.0, f"hit={st['hit_rate']:.2%}"))
    return rows


def fig8_inference_speedup() -> list[Row]:
    """Fig. 8: inference speedup of Hybrid/FAE/UpDLRM over DLRM-CPU."""
    rows = []
    row_groups, _ = updlrm_layout(BANKS_PER_TABLE, DIM, 8)
    for key in WORKLOADS:
        st = _stats(key)
        p = st["profile"]
        kw = dict(batch_size=BATCH, avg_reduction=p.avg_reduction,
                  n_tables=N_TABLES, dim=DIM, mlp_flops=_mlp_flops(),
                  n_banks=256)
        t_cpu = system_inference_time("cpu", **kw)
        t_hyb = system_inference_time("hybrid", **kw)
        t_fae = system_inference_time(
            "fae", fae_hot_fraction=min(0.9, 0.5 + st["hit_rate"]), **kw)
        t_up = system_inference_time(
            "updlrm", per_bank_lookup_share=_shares(key, "CA", row_groups),
            cache_hit_rate=st["hit_rate"], n_c=8, **kw)
        rows.append((f"fig8/{key}_updlrm", t_up * 1e6,
                     f"speedup_vs_cpu={t_cpu / t_up:.2f}x"
                     f" vs_hybrid={t_hyb / t_up:.2f}x"
                     f" vs_fae={t_fae / t_up:.2f}x"))
    return rows


def fig9_partition_speedup() -> list[Row]:
    """Fig. 9: embedding-layer speedup of U/NU/CA over the CPU embedding
    layer, N_c in {2,4,8}."""
    rows = []
    for key in ("clo", "meta1", "read"):
        p = WORKLOADS[key]
        t_cpu = cpu_lookup_time(BATCH * p.avg_reduction * N_TABLES, DIM * 4)
        for name in ("U", "NU", "CA"):
            for n_c in (2, 4, 8):
                t = _stage(key, name, n_c, with_cache=(name == "CA")).total
                rows.append((f"fig9/{key}_{name}_Nc{n_c}", t * 1e6,
                             f"speedup={t_cpu / t:.2f}x"))
    return rows


def fig10_latency_breakdown() -> list[Row]:
    """Fig. 10: stage 1/2/3 breakdown (GoodReads), per partitioner x N_c."""
    rows = []
    for name in ("U", "NU", "CA"):
        for n_c in (2, 4, 8):
            lat = _stage("read", name, n_c, with_cache=(name == "CA"))
            tot = lat.total
            rows.append((
                f"fig10/{name}_Nc{n_c}", tot * 1e6,
                f"c_comm={lat.c_comm / tot:.0%}"
                f" lookup={lat.lookup / tot:.0%}"
                f" d_comm={lat.d_comm / tot:.0%}"))
    return rows


def fig11_sensitivity() -> list[Row]:
    """Fig. 11: DPU lookup time vs avg reduction x lookup width (balanced
    synthetic datasets, as §4.4)."""
    rows = []
    for n_c in (2, 4, 8, 16, 32):
        row_groups, _ = updlrm_layout(BANKS_PER_TABLE, DIM, n_c)
        for red in (50, 100, 200, 300):
            lat = embedding_stage_latency(
                batch_size=BATCH, avg_reduction=red, n_c=n_c,
                n_banks=row_groups)
            rows.append((f"fig11/Nc{n_c}_red{red}", lat.lookup * 1e6,
                         f"bytes={n_c * 4}"))
    return rows


def table1_workloads() -> list[Row]:
    return [(f"table1/{k}", 0.0,
             f"avg_red={w.avg_reduction} items={w.n_items} tier={w.tier}")
            for k, w in WORKLOADS.items()]


def tile_solver() -> list[Row]:
    """§3.1 solver outputs for the paper's tables (2.36M x 32, 32 banks)."""
    from repro.core.hwmodel import solve_uniform_tile
    rows = []
    for key in ("clo", "read"):
        p = WORKLOADS[key]
        n_r, n_c = solve_uniform_tile(
            rows=p.n_items, cols=32, n_banks=BANKS_PER_TABLE,
            batch_size=BATCH, avg_reduction=p.avg_reduction)
        rows.append((f"tile_solver/{key}", 0.0, f"N_r={n_r} N_c={n_c}"))
    return rows


def measured_lookup_paths() -> list[Row]:
    """Real wall-times on this host: plain vs banked vs cache-rewritten
    lookup (jitted, CPU). Verifies the ALGORITHMIC claim that cache rewriting
    cuts lookup work — hardware-independent."""
    import jax
    import jax.numpy as jnp
    from repro.core.cache_runtime import build_cache_table, rewrite_bags
    from repro.core.embedding import banked_embedding_bag, pack_table
    from repro.sparse.ops import embedding_bag_fixed

    st = _stats("read")
    rng = np.random.default_rng(0)
    V, D, B, L = BENCH_ITEMS, DIM, BATCH, 256
    table = rng.standard_normal((V, D)).astype(np.float32)
    bags = st["trace"][:B]
    idx = np.full((B, L), -1, np.int32)
    for i, bag in enumerate(bags):
        b = bag[:L]
        idx[i, :len(b)] = b
    idx = jnp.asarray(idx)

    plain = jax.jit(lambda t, i: embedding_bag_fixed(t, i))
    t_plain = time_fn(plain, jnp.asarray(table), idx)

    _, plan = plan_shares(st, "NU", 8)
    bt = pack_table(table, plan)
    banked = jax.jit(lambda t, i: banked_embedding_bag(t, i, None))
    t_banked = time_fn(banked, bt, idx)

    cp = st["cache_plan"]
    ctab = jnp.asarray(build_cache_table(table, cp))
    ci, ri = rewrite_bags(bags, cp, max_cache_per_bag=16,
                          max_residual_per_bag=L)
    cached = jax.jit(
        lambda t, c, a, b: embedding_bag_fixed(c, a)
        + embedding_bag_fixed(t, b))
    t_cached = time_fn(cached, jnp.asarray(table), ctab, jnp.asarray(ci),
                       jnp.asarray(ri))
    return [
        ("measured-cpu/plain_bag", t_plain, "baseline"),
        ("measured-cpu/banked_bag", t_banked,
         f"vs_plain={t_plain / t_banked:.2f}x"),
        ("measured-cpu/cache_rewritten_bag", t_cached,
         f"vs_plain={t_plain / t_cached:.2f}x"),
    ]
