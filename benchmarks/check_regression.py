"""Bench-regression gate: compare a smoke run's BENCH_*.json against the
committed baselines.

What the gate certifies (and what it deliberately does not):

  schema   — FATAL. The recursive key structure must match exactly, both
             directions (list elements are collapsed to one ``[]`` path
             segment, since smoke runs measure fewer configs than the
             committed full runs). A renamed/dropped/added field means the
             artifact consumers (paper_figs, dashboards, this gate) silently
             diverge — that is the drift this job exists to catch.
  parity   — FATAL. Boolean leaves are semantic claims ("adaptive wins",
             "grads bit-match"), not measurements: the smoke configuration is
             chosen so they are DETERMINISTIC (fixed seeds, analytic models),
             so any flip is a real behavioral regression, not noise.
             ``smoke`` itself is excluded (it is the run-mode marker).
  timing   — ADVISORY. Numeric leaves whose key smells like a measurement
             (``*_us``, ``us_per_*``, ``*_gbps``, ``*latency*``) are compared
             with a ±50% sanity band and only WARN: CI wall-clock says
             nothing reliable, and smoke streams are shorter than the
             committed full runs. The warnings make gross anomalies visible
             in the job log without flaking the gate.

    python benchmarks/check_regression.py --baseline-dir .ci-baselines \
        [--candidate-dir .]

Metrics-snapshot mode (``--metrics-baseline`` + ``--metrics-candidate``):
the same key-path schema check applied to ONE pair of ``repro.obs``
metrics-snapshot JSONs (the serve CLI's ``--metrics-out``). Values are
run-dependent (latencies, counts) so only the structure is gated — the obs
layer pre-registers every metric up front precisely so a run where an event
never fires still exports the full key set.

Dispatch-cache mode (``--tune-baseline`` + ``--tune-candidate``): the same
pair check applied to the autotuner's ``TUNE_dispatch.json`` (the CI smoke
tune vs the committed cache). Entry keys are call signatures, so key-path
parity doubles as the signature-suite gate; decision values are
machine-dependent and ungated; ``meta.version`` mismatches are fatal.

All modes compose: pass any combination of flag groups to gate bench
artifacts, the metrics schema, and the dispatch cache in one call.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

TIMING_MARKERS = ("_us", "us_per", "_gbps", "latency", "_ms")
PARITY_EXCLUDE = {"smoke"}
BAND = 0.5                      # +/-50% advisory sanity band


def key_paths(doc, prefix="") -> set[str]:
    """Recursive key-path set; list indices collapse to '[]' (the union of
    element schemas), scalars terminate a path."""
    paths = set()
    if isinstance(doc, dict):
        for k, v in doc.items():
            p = f"{prefix}.{k}" if prefix else str(k)
            paths.add(p)
            paths |= key_paths(v, p)
    elif isinstance(doc, list):
        for v in doc:
            paths |= key_paths(v, f"{prefix}[]")
    return paths


def scalar_leaves(doc, prefix=""):
    """Yield (path, value) for scalar leaves at NON-list paths (list element
    values are config-dependent between smoke and full runs)."""
    if isinstance(doc, dict):
        for k, v in doc.items():
            p = f"{prefix}.{k}" if prefix else str(k)
            if isinstance(v, dict):
                yield from scalar_leaves(v, p)
            elif not isinstance(v, list):
                yield p, v


def check_pair(baseline: dict, candidate: dict, name: str
               ) -> tuple[list[str], list[str]]:
    """(fatal errors, advisory warnings) for one artifact pair."""
    errors, warnings = [], []
    bp, cp = key_paths(baseline), key_paths(candidate)
    for missing in sorted(bp - cp):
        errors.append(f"{name}: schema drift — baseline key lost: {missing}")
    for extra in sorted(cp - bp):
        errors.append(f"{name}: schema drift — new key not in committed "
                      f"baseline (regenerate it): {extra}")
    base_leaves = dict(scalar_leaves(baseline))
    for path, cval in scalar_leaves(candidate):
        if path not in base_leaves:
            continue                      # already reported as schema drift
        bval = base_leaves[path]
        leaf = path.rsplit(".", 1)[-1]
        if isinstance(cval, bool) and isinstance(bval, bool):
            if leaf not in PARITY_EXCLUDE and cval != bval:
                errors.append(f"{name}: parity drift — {path}: "
                              f"baseline {bval} != candidate {cval}")
        elif (isinstance(cval, (int, float)) and isinstance(bval, (int, float))
              and any(m in leaf for m in TIMING_MARKERS)):
            if bval and abs(cval - bval) > BAND * abs(bval):
                warnings.append(
                    f"{name}: timing outside +/-{BAND:.0%} band (advisory) — "
                    f"{path}: baseline {bval:.3f} vs candidate {cval:.3f}")
    return errors, warnings


def check_metrics_schema(baseline_path: str, candidate_path: str
                         ) -> tuple[list[str], list[str]]:
    """Key-path schema gate for one metrics-snapshot pair.

    Metric VALUES are run-dependent, so the scalar parity/timing checks of
    ``check_pair`` would be noise here — only the key structure is compared.
    ``meta.schema`` is the one value that IS gated: a version bump means the
    committed baseline must be regenerated deliberately.
    """
    name = os.path.basename(candidate_path)
    with open(baseline_path) as fh:
        baseline = json.load(fh)
    with open(candidate_path) as fh:
        candidate = json.load(fh)
    errors = []
    bp, cp = key_paths(baseline), key_paths(candidate)
    for missing in sorted(bp - cp):
        errors.append(f"{name}: metrics schema drift — baseline key lost: "
                      f"{missing}")
    for extra in sorted(cp - bp):
        errors.append(f"{name}: metrics schema drift — new key not in "
                      f"committed baseline (regenerate it): {extra}")
    bschema = baseline.get("meta", {}).get("schema")
    cschema = candidate.get("meta", {}).get("schema")
    if bschema != cschema:
        errors.append(f"{name}: metrics snapshot schema version changed — "
                      f"baseline {bschema} vs candidate {cschema}")
    return errors, []


def check_tune_cache(baseline_path: str, candidate_path: str
                     ) -> tuple[list[str], list[str]]:
    """Dispatch-cache gate for one TUNE_dispatch.json pair.

    The cache's entry KEYS are call-signature strings, so ``check_pair``'s
    key-path schema check IS the signature-suite parity gate: a CI smoke
    tune must cover exactly the committed suite (it may shrink candidates
    and repeats, never signatures), and any entry-field rename fails both
    directions. Decision values (``backend`` str, ``tile_b``/``n_slots``
    ints) are machine-dependent and deliberately NOT gated — they surface
    in review diffs of the committed file instead — while the ``*_us``
    measurements ride the usual advisory band. ``meta.version`` is the one
    value gated fatally: a schema bump means the committed cache must be
    regenerated deliberately (``python -m repro.launch.tune``).
    """
    name = os.path.basename(candidate_path)
    with open(baseline_path) as fh:
        baseline = json.load(fh)
    with open(candidate_path) as fh:
        candidate = json.load(fh)
    errors, warnings = check_pair(baseline, candidate, name)
    bver = baseline.get("meta", {}).get("version")
    cver = candidate.get("meta", {}).get("version")
    if bver != cver:
        errors.append(f"{name}: dispatch cache schema version changed — "
                      f"baseline {bver} vs candidate {cver} (regenerate "
                      f"the committed cache with repro.launch.tune)")
    return errors, warnings


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir",
                    help="directory holding the COMMITTED BENCH_*.json "
                         "(stash them before the smoke run overwrites)")
    ap.add_argument("--candidate-dir", default=".",
                    help="directory the smoke run wrote its BENCH_*.json to")
    ap.add_argument("--metrics-baseline",
                    help="committed metrics-snapshot JSON (schema-only gate)")
    ap.add_argument("--metrics-candidate",
                    help="metrics snapshot written by the smoke run "
                         "(--metrics-out)")
    ap.add_argument("--tune-baseline",
                    help="committed TUNE_dispatch.json (signature-suite "
                         "schema gate, decision values ungated)")
    ap.add_argument("--tune-candidate",
                    help="dispatch cache written by the CI smoke tune "
                         "(repro.launch.tune --smoke --out ...)")
    args = ap.parse_args()

    metrics_mode = bool(args.metrics_baseline or args.metrics_candidate)
    if metrics_mode and not (args.metrics_baseline and args.metrics_candidate):
        ap.error("--metrics-baseline and --metrics-candidate go together")
    tune_mode = bool(args.tune_baseline or args.tune_candidate)
    if tune_mode and not (args.tune_baseline and args.tune_candidate):
        ap.error("--tune-baseline and --tune-candidate go together")
    if not (metrics_mode or tune_mode) and not args.baseline_dir:
        ap.error("--baseline-dir is required unless only gating a metrics "
                 "snapshot or dispatch cache pair")

    errors, warnings = [], []
    n_artifacts = 0
    if args.baseline_dir:
        baselines = sorted(glob.glob(os.path.join(args.baseline_dir,
                                                  "BENCH_*.json")))
        if not baselines:
            sys.exit(f"no BENCH_*.json baselines under {args.baseline_dir}")
        n_artifacts += len(baselines)
        for bpath in baselines:
            name = os.path.basename(bpath)
            cpath = os.path.join(args.candidate_dir, name)
            if not os.path.exists(cpath):
                errors.append(f"{name}: smoke run produced no artifact "
                              f"({cpath} missing)")
                continue
            with open(bpath) as fh:
                baseline = json.load(fh)
            with open(cpath) as fh:
                candidate = json.load(fh)
            e, w = check_pair(baseline, candidate, name)
            errors += e
            warnings += w
            print(f"checked {name}: {len(e)} fatal, {len(w)} advisory")
    if metrics_mode:
        n_artifacts += 1
        if not os.path.exists(args.metrics_candidate):
            errors.append(f"smoke run produced no metrics snapshot "
                          f"({args.metrics_candidate} missing)")
        else:
            e, w = check_metrics_schema(args.metrics_baseline,
                                        args.metrics_candidate)
            errors += e
            warnings += w
            print(f"checked {os.path.basename(args.metrics_candidate)} "
                  f"(metrics schema): {len(e)} fatal, {len(w)} advisory")
    if tune_mode:
        n_artifacts += 1
        if not os.path.exists(args.tune_candidate):
            errors.append(f"smoke tune produced no dispatch cache "
                          f"({args.tune_candidate} missing)")
        else:
            e, w = check_tune_cache(args.tune_baseline, args.tune_candidate)
            errors += e
            warnings += w
            print(f"checked {os.path.basename(args.tune_candidate)} "
                  f"(dispatch cache): {len(e)} fatal, {len(w)} advisory")
    for w in warnings:
        print(f"WARN  {w}")
    for e in errors:
        print(f"ERROR {e}")
    if errors:
        sys.exit(f"bench regression gate FAILED: {len(errors)} schema/parity "
                 f"drift(s)")
    print(f"bench regression gate PASSED "
          f"({n_artifacts} artifacts, {len(warnings)} advisory warnings)")


if __name__ == "__main__":
    main()
