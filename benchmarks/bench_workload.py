"""Adaptive replanning vs a static plan under drifting traffic.

The experiment the repro.workload subsystem exists for: serve a drifting
Zipf trace (hot-set rotation + bursts) against

  static    — the §3.2 non-uniform plan built from the FIRST window's
              frequencies and never touched again (the paper's offline
              assumption), and
  adaptive  — the same starting plan plus the closed loop: telemetry ->
              drift detector -> replan -> live migration.

Two metrics per micro-batch, both on the paper's own cost model:

  max-bank-load share — the fraction of that batch's row reads landing on
      the hottest bank (1/n_banks is perfect). This is Fig. 6's y-axis, and
      under Eq. 1 the bank-parallel lookup time is proportional to it.
  modeled batch latency — max-bank reads x the UPMEM MRAM row-read latency
      (hwmodel Fig. 3 curve at the row's byte size): the stage-2 term of
      Eq. 1 for the slowest bank, which bounds the batch.

Writes BENCH_workload.json; ``workload_drift()`` is the benchmarks/run.py
hook. Wall-clock is NOT the claim here (CPU interpret-mode timings say
nothing about bank parallelism); the latency column is the analytic model,
the same one benchmarks/paper_figs.py uses for Figs. 8-11.

    PYTHONPATH=src python benchmarks/bench_workload.py [--out BENCH_workload.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.hwmodel import UPMEMProfile
from repro.core.partitioning import non_uniform_partition
from repro.workload import (DriftConfig, DriftingZipfTrace, ReplanConfig,
                            Replanner)

VOCAB = 30_000
DIM = 64
BANKS = 8
BATCH = 64
WARMUP_BAGS = 512          # window the static plan is built from
STREAM_BAGS = 4096         # drifting traffic both plans then serve
SEED = 0

DRIFT = DriftConfig(
    n_items=VOCAB, zipf_a=1.08, avg_bag=12.0,
    rotate_every=640, rotate_frac=0.3,
    burst_prob=0.01, burst_len=48, burst_items=24, burst_share=0.5,
)


def _batch_stats(bags: list[np.ndarray], plan) -> tuple[float, float]:
    """(max-bank-load share, modeled latency us) for one micro-batch."""
    counts = np.zeros(plan.n_banks)
    for bag in bags:
        rows = np.unique(bag)
        np.add.at(counts, plan.bank_of_row[rows], 1.0)
    total = counts.sum()
    share = float(counts.max() / total) if total else 1.0 / plan.n_banks
    t_row = UPMEMProfile().mram_read_latency(DIM * 4)
    return share, float(counts.max() * t_row * 1e6)


def run(stream_bags: int = STREAM_BAGS, *, seed: int = SEED) -> dict:
    cap = int(np.ceil(VOCAB / BANKS) * 1.25)
    trace = DriftingZipfTrace(DRIFT, seed=seed)

    # --- warmup window -> the shared starting plan -------------------------
    warm = trace.bags(WARMUP_BAGS)
    freq0 = np.zeros(VOCAB)
    for bag in warm:
        np.add.at(freq0, bag, 1.0)
    static_plan = non_uniform_partition(freq0 + 1e-3, BANKS,
                                        capacity_rows=cap)

    rcfg = ReplanConfig.for_vocab(
        VOCAB, BANKS, capacity_rows=cap, check_every=8,
        min_jaccard=0.6, max_weighted_l1=0.5)
    rp = Replanner(rcfg, VOCAB, init_freq=freq0 + 1e-3)
    adaptive_plan = static_plan

    # --- drifting stream: both plans score every batch ---------------------
    rows_static, rows_adaptive = [], []
    lat_static, lat_adaptive = [], []
    n_batches = stream_bags // BATCH
    for _ in range(n_batches):
        bags = trace.bags(BATCH)
        s_share, s_lat = _batch_stats(bags, static_plan)
        a_share, a_lat = _batch_stats(bags, adaptive_plan)
        rows_static.append(s_share)
        rows_adaptive.append(a_share)
        lat_static.append(s_lat)
        lat_adaptive.append(a_lat)
        # feed telemetry AFTER scoring (the plan serving a batch is the one
        # installed before it arrived)
        for bag in bags:
            rp.telemetry.observe(bag)
        update = rp.end_batch()
        if update is not None:
            adaptive_plan = update.plan

    def p99(xs):
        s = sorted(xs)
        return s[min(len(s) - 1, int(0.99 * len(s)))]

    return {
        "config": {
            "vocab": VOCAB, "dim": DIM, "banks": BANKS, "batch": BATCH,
            "warmup_bags": WARMUP_BAGS, "stream_bags": stream_bags,
            "drift": dataclass_dict(DRIFT), "seed": seed,
            "latency_model": "max-bank row reads x UPMEM MRAM read latency "
                             "(hwmodel Fig. 3), stage-2 term of Eq. 1",
        },
        "static": {
            "mean_max_bank_load_share": float(np.mean(rows_static)),
            "p99_max_bank_load_share": float(p99(rows_static)),
            "p99_model_latency_us": float(p99(lat_static)),
            "mean_model_latency_us": float(np.mean(lat_static)),
        },
        "adaptive": {
            "mean_max_bank_load_share": float(np.mean(rows_adaptive)),
            "p99_max_bank_load_share": float(p99(rows_adaptive)),
            "p99_model_latency_us": float(p99(lat_adaptive)),
            "mean_model_latency_us": float(np.mean(lat_adaptive)),
            "n_replans": rp.n_replans,
        },
        "adaptive_wins": {
            "lower_mean_max_bank_load":
                float(np.mean(rows_adaptive)) < float(np.mean(rows_static)),
            "no_worse_p99_latency":
                p99(lat_adaptive) <= p99(lat_static) * 1.001,
        },
        "ideal_share": 1.0 / BANKS,
    }


def dataclass_dict(dc) -> dict:
    import dataclasses
    return dataclasses.asdict(dc)


def workload_drift():
    """benchmarks/run.py hook: (name, us_per_call, derived) rows. A short
    stream keeps the CI run in seconds; the standalone script uses the full
    one."""
    doc = run(stream_bags=1024)
    s, a = doc["static"], doc["adaptive"]
    yield ("workload_static_p99_model", s["p99_model_latency_us"],
           f"maxload{s['mean_max_bank_load_share']:.3f}")
    yield ("workload_adaptive_p99_model", a["p99_model_latency_us"],
           f"maxload{a['mean_max_bank_load_share']:.3f}"
           f"_replans{a['n_replans']}")


def write_json(out: str = "BENCH_workload.json", smoke: bool = False,
               stream_bags: int | None = None) -> dict:
    """Write the benchmark doc; ``smoke=True`` is the CI artifact mode
    (short stream — the same 1024-bag budget the run.py hook uses)."""
    doc = run(stream_bags=stream_bags
              if stream_bags is not None else (1024 if smoke else STREAM_BAGS))
    doc["smoke"] = smoke
    with open(out, "w") as fh:
        json.dump(doc, fh, indent=2)
    return doc


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_workload.json")
    ap.add_argument("--stream-bags", type=int, default=STREAM_BAGS)
    ap.add_argument("--smoke", action="store_true",
                    help="short stream (the CI artifact mode); an explicit "
                         "--stream-bags still wins")
    args = ap.parse_args()
    explicit = args.stream_bags != STREAM_BAGS
    doc = write_json(args.out, smoke=args.smoke,
                     stream_bags=args.stream_bags if explicit else None)
    s, a = doc["static"], doc["adaptive"]
    print(f"{'':<10} {'mean max-bank share':>20} {'p99 share':>10} "
          f"{'p99 model us':>13}")
    print(f"{'static':<10} {s['mean_max_bank_load_share']:>20.4f} "
          f"{s['p99_max_bank_load_share']:>10.4f} "
          f"{s['p99_model_latency_us']:>13.1f}")
    print(f"{'adaptive':<10} {a['mean_max_bank_load_share']:>20.4f} "
          f"{a['p99_max_bank_load_share']:>10.4f} "
          f"{a['p99_model_latency_us']:>13.1f}   "
          f"(replans={a['n_replans']})")
    print(f"ideal share {doc['ideal_share']:.4f}; wins={doc['adaptive_wins']}")
    print(f"wrote {args.out}")
    if not all(doc["adaptive_wins"].values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
